"""Make `pytest python/tests/` work from the repository root: the tests
import the `compile` package which lives next to this file."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
