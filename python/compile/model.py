"""Layer-2: the transformer forward graphs in JAX, mirrored exactly from
the Rust model (`rust/src/model/`): same LayerNorm epsilon, same tanh-GELU,
same causal attention, same weight layout ``[out, in]``.

Two variants per preset are lowered by ``aot.py``:

* ``lm_logits_<preset>``  — fp32 forward, weights as parameters;
* ``lm_qlogits_<preset>`` — quantized forward where every linear runs the
  Pallas ``quant_matmul`` kernel on (levels, scales, zeros).

The flat parameter ORDER is the contract with the Rust side
(`runtime` marshals arguments in exactly this order — see
``param_order`` / ``qparam_order``):

fp:    tok_emb, pos_emb,
       per layer: ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w_up, w_down,
       lnf_g, lnf_b, [head if untied]
quant: tok_emb, pos_emb,
       per layer: ln1_g, ln1_b, (q,k,v,o,up,down)×(qw, scales, zeros)
                  interleaved at their fp positions, ln2_g, ln2_b,
       lnf_g, lnf_b, [head triple if untied]
"""

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from .kernels.quant_matmul import quant_matmul


@dataclasses.dataclass(frozen=True)
class Preset:
    """Mirror of rust ModelConfig::lm_presets (keep in sync!)."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    activation: str  # "gelu" | "relu"
    tied_head: bool


# Must match rust/src/model/config.rs::lm_presets exactly; the Rust
# integration test cross-checks shapes through the manifest.
PRESETS = [
    Preset("sim-opt-6.7b", 128, 4, 4, 512, 48, "relu", False),
    Preset("sim-opt-13b", 160, 6, 4, 640, 48, "relu", False),
    Preset("sim-qwen3-8b", 144, 5, 4, 576, 48, "gelu", True),
    Preset("sim-llama-3.1-8b-instruct", 144, 5, 6, 432, 48, "gelu", True),
]

# Vocab of the Rust-side synthetic lexicon (data::corpus::Lexicon). The
# Rust integration test asserts this matches Lexicon::tokenizer() so a
# lexicon change fails loudly here instead of mis-shaping artifacts.
VOCAB = 165

# Artifact-path group sizes per preset: the paper's group-128 scaled so the
# group divides every linear's input width (DESIGN.md §5). The Rust
# experiment harness uses the same values (experiments::group_size_for).
GROUP_SIZES = {
    "sim-opt-6.7b": 64,
    "sim-opt-13b": 32,
    "sim-qwen3-8b": 48,
    "sim-llama-3.1-8b-instruct": 48,
}


def preset_by_name(name: str) -> Preset:
    for p in PRESETS:
        if p.name == name:
            return p
    raise KeyError(name)


def layernorm(x, g, b):
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + 1e-5) * g + b


def activation(x, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.relu(x)


def causal_attention(q, k, v, n_heads: int):
    """q/k/v: [S, d] → [S, d] (batch handled by vmap upstream; artifacts
    use B=1 so S-major is enough)."""
    s, d = q.shape
    dh = d // n_heads
    qh = q.reshape(s, n_heads, dh).transpose(1, 0, 2)
    kh = k.reshape(s, n_heads, dh).transpose(1, 0, 2)
    vh = v.reshape(s, n_heads, dh).transpose(1, 0, 2)
    scores = jnp.einsum("hsd,htd->hst", qh, kh) / jnp.sqrt(dh).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hst,htd->hsd", probs, vh)
    return ctx.transpose(1, 0, 2).reshape(s, d)


def param_order(p: Preset) -> List[str]:
    names = ["tok_emb", "pos_emb"]
    for i in range(p.n_layers):
        names += [
            f"lm.layer{i}.ln1.g", f"lm.layer{i}.ln1.b",
            f"lm.layer{i}.attn.q", f"lm.layer{i}.attn.k",
            f"lm.layer{i}.attn.v", f"lm.layer{i}.attn.out",
            f"lm.layer{i}.ln2.g", f"lm.layer{i}.ln2.b",
            f"lm.layer{i}.mlp.up", f"lm.layer{i}.mlp.down",
        ]
    names += ["lnf.g", "lnf.b"]
    if not p.tied_head:
        names.append("lm.head")
    return names


LINEAR_FIELDS = ("attn.q", "attn.k", "attn.v", "attn.out", "mlp.up", "mlp.down")


def qparam_order(p: Preset) -> List[str]:
    """Quantized variant: every linear becomes three params
    ``<name>.qw|.scales|.zeros``; everything else unchanged."""
    names = []
    for n in param_order(p):
        if any(n.endswith(f) for f in LINEAR_FIELDS) or n == "lm.head":
            names += [f"{n}.qw", f"{n}.scales", f"{n}.zeros"]
        else:
            names.append(n)
    return names


def lm_logits(p: Preset, tokens, params: List[jnp.ndarray]):
    """fp32 forward: tokens i32 [S] → logits [S, vocab]."""
    order = param_order(p)
    d = dict(zip(order, params))
    s = tokens.shape[0]
    x = d["tok_emb"][tokens] + d["pos_emb"][:s]
    for i in range(p.n_layers):
        pre = f"lm.layer{i}."
        h = layernorm(x, d[pre + "ln1.g"], d[pre + "ln1.b"])
        q = h @ d[pre + "attn.q"].T
        k = h @ d[pre + "attn.k"].T
        v = h @ d[pre + "attn.v"].T
        ctx = causal_attention(q, k, v, p.n_heads)
        x = x + ctx @ d[pre + "attn.out"].T
        h = layernorm(x, d[pre + "ln2.g"], d[pre + "ln2.b"])
        up = activation(h @ d[pre + "mlp.up"].T, p.activation)
        x = x + up @ d[pre + "mlp.down"].T
    x = layernorm(x, d["lnf.g"], d["lnf.b"])
    head = d["tok_emb"] if p.tied_head else d["lm.head"]
    return x @ head.T


def lm_qlogits(p: Preset, group_size: int, tokens, params: List[jnp.ndarray]):
    """Quantized forward: every linear via the Pallas quant_matmul."""
    order = qparam_order(p)
    d = dict(zip(order, params))
    s = tokens.shape[0]

    def qmm(x, name):
        return quant_matmul(
            x, d[name + ".qw"], d[name + ".scales"], d[name + ".zeros"],
            group_size=group_size,
        )

    x = d["tok_emb"][tokens] + d["pos_emb"][:s]
    for i in range(p.n_layers):
        pre = f"lm.layer{i}."
        h = layernorm(x, d[pre + "ln1.g"], d[pre + "ln1.b"])
        q = qmm(h, pre + "attn.q")
        k = qmm(h, pre + "attn.k")
        v = qmm(h, pre + "attn.v")
        ctx = causal_attention(q, k, v, p.n_heads)
        x = x + qmm(ctx, pre + "attn.out")
        h = layernorm(x, d[pre + "ln2.g"], d[pre + "ln2.b"])
        up = activation(qmm(h, pre + "mlp.up"), p.activation)
        x = x + qmm(up, pre + "mlp.down")
    x = layernorm(x, d["lnf.g"], d["lnf.b"])
    if p.tied_head:
        return x @ d["tok_emb"].T
    return qmm(x, "lm.head")


def param_shapes(p: Preset, vocab: int):
    """Shape of each fp parameter, keyed by name."""
    d, ff = p.d_model, p.d_ff
    shapes = {"tok_emb": (vocab, d), "pos_emb": (p.seq_len, d)}
    for i in range(p.n_layers):
        pre = f"lm.layer{i}."
        shapes[pre + "ln1.g"] = (d,)
        shapes[pre + "ln1.b"] = (d,)
        shapes[pre + "attn.q"] = (d, d)
        shapes[pre + "attn.k"] = (d, d)
        shapes[pre + "attn.v"] = (d, d)
        shapes[pre + "attn.out"] = (d, d)
        shapes[pre + "ln2.g"] = (d,)
        shapes[pre + "ln2.b"] = (d,)
        shapes[pre + "mlp.up"] = (ff, d)
        shapes[pre + "mlp.down"] = (d, ff)
    shapes["lnf.g"] = (d,)
    shapes["lnf.b"] = (d,)
    if not p.tied_head:
        shapes["lm.head"] = (vocab, d)
    return shapes


def qparam_shapes(p: Preset, vocab: int, group_size: int):
    """Shape + dtype of each quantized-variant parameter."""
    fp = param_shapes(p, vocab)
    out = {}
    for name in qparam_order(p):
        if name.endswith(".qw"):
            base = fp[name[: -len(".qw")]]
            out[name] = (base, "i32")
        elif name.endswith(".scales") or name.endswith(".zeros"):
            base = fp[name.rsplit(".", 1)[0]]
            n, k = base
            assert k % group_size == 0, (name, base, group_size)
            out[name] = ((n, k // group_size), "f32")
        else:
            out[name] = (fp[name], "f32")
    return out
