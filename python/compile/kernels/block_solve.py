"""Layer-1 Pallas kernel: RPIQ stage-2 block update (paper Eq. 14/7/8).

Fuses the three steps of one block refinement:

1. local least squares   ``B* = (H_i⁻¹ · X_iᵀD_i)ᵀ``
2. grid projection       ``B̃ = Q(B*)``  (RTN with fixed scale/zero — the
   literal Eq. 7; the Rust engine's production path upgrades this to the
   curvature-aware feedback projector, see rpiq.rs module docs)
3. damped move           ``B ← B_old + α(B̃ − B_old)``

Shapes: ``hinv [bc, bc]``, ``xtd [bc, N]``, ``scale/zero [N]`` (one group
per block), ``b_old [N, bc]`` → ``b_new [N, bc]``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(hinv_ref, xtd_ref, scale_ref, zero_ref, b_old_ref, o_ref, *,
            alpha: float, maxq: float):
    bstar_t = jnp.dot(hinv_ref[...], xtd_ref[...],
                      preferred_element_type=jnp.float32)     # (bc, N)
    bstar = bstar_t.T                                         # (N, bc)
    scale = scale_ref[...][:, None]                           # (N, 1)
    zero = zero_ref[...][:, None]
    q = jnp.clip(jnp.round(bstar / scale + zero), 0.0, maxq)
    btilde = (q - zero) * scale
    o_ref[...] = b_old_ref[...] + alpha * (btilde - b_old_ref[...])


def block_solve(hinv, xtd, scale, zero, b_old, *, alpha: float, bits: int = 4,
                interpret: bool = True):
    """One fused stage-2 block update."""
    bc, bc2 = hinv.shape
    assert bc == bc2
    n = b_old.shape[0]
    assert xtd.shape == (bc, n)
    assert b_old.shape == (n, bc)
    assert scale.shape == (n,) and zero.shape == (n,)
    maxq = float(2 ** bits - 1)
    return pl.pallas_call(
        functools.partial(_kernel, alpha=alpha, maxq=maxq),
        out_shape=jax.ShapeDtypeStruct((n, bc), jnp.float32),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((bc, bc), lambda i: (0, 0)),
            pl.BlockSpec((bc, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, bc), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, bc), lambda i: (0, 0)),
        interpret=interpret,
    )(hinv, xtd, scale, zero, b_old)
