"""Layer-1 Pallas kernel: group-wise 4/8-bit asymmetric dequant-matmul.

The deployment hot spot (`y = x · deq(W)ᵀ`) expressed as a BlockSpec-tiled
Pallas kernel. Layout matches the Rust fallback (`QuantizedLm::qmatmul`)
and the grid conventions of `rust/src/quant/grid.rs`:

* ``x``       f32  ``[M, K]``
* ``qw``      i32  ``[N, K]``        integer levels (unpacked nibbles)
* ``scales``  f32  ``[N, K // gs]``
* ``zeros``   f32  ``[N, K // gs]``  integer zero points stored as f32
* output      f32  ``[M, N]``        with ``deq(q) = (q − zero) · scale``

Hardware adaptation (DESIGN.md §7): the CUDA implementation the paper
deploys stages packed weights through shared memory per threadblock; here
each grid step stages an ``(bm, K)`` activation stripe and a ``(bn, K)``
packed-weight stripe into VMEM via BlockSpec, dequantizes *in registers*,
and feeds the MXU with one ``dot``. On this image Pallas must run with
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls), so the
kernel's correctness is validated against ``ref.py`` and its *structural*
VMEM/MXU characteristics are documented rather than timed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, qw_ref, scales_ref, zeros_ref, o_ref, *, group_size: int):
    x = x_ref[...]              # (bm, K)
    qw = qw_ref[...]            # (bn, K)
    scales = scales_ref[...]    # (bn, G)
    zeros = zeros_ref[...]      # (bn, G)
    # Expand per-group params across their K-columns and dequantize in
    # registers: w = (q - z) * s.
    s_full = jnp.repeat(scales, group_size, axis=1)   # (bn, K)
    z_full = jnp.repeat(zeros, group_size, axis=1)    # (bn, K)
    w = (qw.astype(jnp.float32) - z_full) * s_full
    # MXU-feed: one (bm, K) x (K, bn) dot per grid step.
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def quant_matmul(x, qw, scales, zeros, *, group_size: int, block_m: int = 64,
                 block_n: int = 64, interpret: bool = True):
    """``y[M, N] = x · deq(qw)ᵀ`` with group-wise (scale, zero)."""
    m, k = x.shape
    n, k2 = qw.shape
    assert k == k2, (k, k2)
    assert k % group_size == 0, "K must be a multiple of the group size"
    g = k // group_size
    assert scales.shape == (n, g), (scales.shape, (n, g))
    assert zeros.shape == (n, g)
    bm = min(block_m, m)
    bn = min(block_n, n)
    # Grid over output tiles; K is kept whole per step (our K values are
    # small; for large K this becomes a third grid axis with accumulation).
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(_kernel, group_size=group_size),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, g), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, g), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(x, qw, scales, zeros)


def vmem_bytes_per_step(bm: int, bn: int, k: int, group_size: int) -> int:
    """Structural VMEM footprint of one grid step (DESIGN.md §7)."""
    g = k // group_size
    return 4 * (bm * k + bn * k + 2 * bn * g + bm * bn)


def arithmetic_intensity(bm: int, bn: int, k: int) -> float:
    """FLOPs per HBM byte moved for one grid step (weights counted packed
    at 0.5 byte as deployed; activations f32)."""
    flops = 2.0 * bm * bn * k
    bytes_moved = 4.0 * bm * k + 0.5 * bn * k + 4.0 * bm * bn
    return flops / bytes_moved
