"""Layer-1 Pallas kernel: streaming Hessian accumulation ``H += XᵀX``.

The calibration-stage hot spot (paper Eq. 9 / Algorithm 2 line 3). The
kernel tiles the (Cin, Cin) output; each grid step loads the full X stripe
for its row/column tiles and contracts over the sample axis. ``interpret=
True`` on this image (see quant_matmul.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, x_ref, o_ref):
    # o = h + xᵀ x for this (bi, bj) tile of H.
    xi = x_ref[...]  # (S, C) full stripe — C is small for our layers
    o_ref[...] = h_ref[...] + jax.lax.dot_general(
        xi, xi, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def hessian_update(h, x, *, interpret: bool = True):
    """``H_new = H + XᵀX`` (unnormalized; the Rust accumulator rescales)."""
    s, c = x.shape
    assert h.shape == (c, c)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((c, c), jnp.float32),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((c, c), lambda i: (0, 0)),
            pl.BlockSpec((s, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((c, c), lambda i: (0, 0)),
        interpret=interpret,
    )(h, x)
