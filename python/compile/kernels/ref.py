"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth
the pytest suite (and hypothesis sweeps) compare against."""

import jax.numpy as jnp


def dequantize(qw, scales, zeros, group_size: int):
    """``deq(q) = (q − zero) · scale`` with group-wise params."""
    s_full = jnp.repeat(scales, group_size, axis=1)
    z_full = jnp.repeat(zeros, group_size, axis=1)
    return (qw.astype(jnp.float32) - z_full) * s_full


def quant_matmul_ref(x, qw, scales, zeros, group_size: int):
    """Oracle for kernels.quant_matmul."""
    w = dequantize(qw, scales, zeros, group_size)
    return x @ w.T


def hessian_update_ref(h, x):
    """Oracle for kernels.hessian."""
    return h + x.T @ x


def block_solve_ref(hinv, xtd, scale, zero, b_old, alpha: float, bits: int = 4):
    """Oracle for kernels.block_solve."""
    maxq = float(2 ** bits - 1)
    bstar = (hinv @ xtd).T
    s = scale[:, None]
    z = zero[:, None]
    q = jnp.clip(jnp.round(bstar / s + z), 0.0, maxq)
    btilde = (q - z) * s
    return b_old + alpha * (btilde - b_old)


def rtn_quantize_ref(w, group_size: int, bits: int = 4):
    """Round-to-nearest group quantization (mirrors grid.rs find_params):
    returns (qw, scales, zeros)."""
    n, k = w.shape
    assert k % group_size == 0
    maxq = float(2 ** bits - 1)
    wg = w.reshape(n, k // group_size, group_size)
    lo = jnp.minimum(wg.min(axis=2), 0.0)
    hi = jnp.maximum(wg.max(axis=2), 0.0)
    degenerate = lo == hi
    scales = jnp.where(degenerate, 1.0, (hi - lo) / maxq)
    zeros = jnp.where(degenerate, 0.0, jnp.round(-lo / scales))
    q = jnp.clip(jnp.round(wg / scales[:, :, None] + zeros[:, :, None]), 0.0, maxq)
    return q.reshape(n, k).astype(jnp.int32), scales, zeros
