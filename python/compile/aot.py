"""AOT lowering: JAX/Pallas graphs → HLO **text** + manifest.json.

HLO text (not ``.serialize()``) is the interchange format: the image's
xla_extension 0.5.1 rejects jax≥0.5 protos with 64-bit instruction ids;
the text parser reassigns ids (see /opt/xla-example/README.md).

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(
        tuple(shape), jnp.int32 if dtype == "i32" else jnp.float32
    )


def io_entry(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-models", action="store_true",
                    help="emit only kernels + selfcheck (fast CI path)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    entries = {}

    def emit(name, fn, in_specs, outputs):
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        entries[name] = {
            "file": fname,
            "inputs": [
                io_entry(s.shape, "i32" if s.dtype == jnp.int32 else "f32")
                for s in in_specs
            ],
            "outputs": outputs,
        }
        print(f"  {name}: {len(text)} chars")

    # --- runtime selfcheck ---
    emit(
        "selfcheck_add",
        lambda x: (x + x,),
        [spec((2, 2))],
        [io_entry((2, 2))],
    )

    # --- standalone kernel entries (micro-bench + parity tests) ---
    from .kernels.quant_matmul import quant_matmul
    from .kernels.hessian import hessian_update
    from .kernels.block_solve import block_solve

    m, k, n, gs = 64, 128, 64, 64
    emit(
        f"qmatmul_{m}x{k}x{n}_g{gs}",
        lambda x, qw, s, z: (quant_matmul(x, qw, s, z, group_size=gs),),
        [spec((m, k)), spec((n, k), "i32"), spec((n, k // gs)), spec((n, k // gs))],
        [io_entry((m, n))],
    )
    s_, c_ = 48, 128
    emit(
        f"hessian_{s_}x{c_}",
        lambda h, x: (hessian_update(h, x),),
        [spec((c_, c_)), spec((s_, c_))],
        [io_entry((c_, c_))],
    )
    bc, nn = 64, 128
    emit(
        f"block_solve_g{bc}_n{nn}",
        lambda hinv, xtd, sc, ze, b: (
            block_solve(hinv, xtd, sc, ze, b, alpha=0.5),
        ),
        [spec((bc, bc)), spec((bc, nn)), spec((nn,)), spec((nn,)), spec((nn, bc))],
        [io_entry((nn, bc))],
    )

    # --- full model graphs per preset ---
    if not args.skip_models:
        for p in M.PRESETS:
            vocab = M.VOCAB
            gs_p = M.GROUP_SIZES[p.name]
            fp_shapes = M.param_shapes(p, vocab)
            fp_specs = [spec((p.seq_len,), "i32")] + [
                spec(fp_shapes[nme]) for nme in M.param_order(p)
            ]
            emit(
                f"lm_logits_{p.name}",
                lambda tokens, *params, p=p: (M.lm_logits(p, tokens, list(params)),),
                fp_specs,
                [io_entry((p.seq_len, vocab))],
            )
            q_shapes = M.qparam_shapes(p, vocab, gs_p)  # name -> (shape, dtype)
            q_specs = [spec((p.seq_len,), "i32")] + [
                spec(*q_shapes[nme]) for nme in M.qparam_order(p)
            ]
            emit(
                f"lm_qlogits_{p.name}",
                lambda tokens, *params, p=p, gs_p=gs_p: (
                    M.lm_qlogits(p, gs_p, tokens, list(params)),
                ),
                q_specs,
                [io_entry((p.seq_len, vocab))],
            )

    manifest = {
        "vocab": M.VOCAB,
        "group_sizes": M.GROUP_SIZES,
        "entries": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(entries)} entries to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
