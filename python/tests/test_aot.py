"""AOT path: lowering to HLO text must succeed and produce parseable,
non-trivial modules (the Rust runtime round-trip is covered by the Rust
integration tests against a real `make artifacts` bundle)."""

import jax
import jax.numpy as jnp

from compile import model as M
from compile.aot import to_hlo_text, spec

jax.config.update("jax_platform_name", "cpu")


def test_selfcheck_lowers_to_hlo_text():
    lowered = jax.jit(lambda x: (x + x,)).lower(spec((2, 2)))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_kernel_entry_lowers():
    from compile.kernels.quant_matmul import quant_matmul

    m, k, n, gs = 8, 16, 8, 8
    lowered = jax.jit(
        lambda x, qw, s, z: (quant_matmul(x, qw, s, z, group_size=gs),)
    ).lower(spec((m, k)), spec((n, k), "i32"), spec((n, k // gs)), spec((n, k // gs)))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    # interpret=True must lower to plain HLO, not a Mosaic custom-call
    assert "mosaic" not in text.lower()


def test_tiny_model_entry_lowers():
    p = M.Preset("tiny", 16, 1, 2, 32, 8, "gelu", True)
    shapes = M.param_shapes(p, 23)
    specs = [spec((p.seq_len,), "i32")] + [
        spec(shapes[n]) for n in M.param_order(p)
    ]
    lowered = jax.jit(
        lambda tokens, *params: (M.lm_logits(p, tokens, list(params)),)
    ).lower(*specs)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[8,23]" in text  # logits shape
