"""L2 correctness: the JAX model graphs — shapes, causality, fp-vs-quant
consistency, and preset bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

TINY = M.Preset("tiny", d_model=16, n_layers=2, n_heads=2, d_ff=32,
                seq_len=8, activation="gelu", tied_head=True)
VOCAB = 23


def make_params(p, vocab, seed=0):
    shapes = M.param_shapes(p, vocab)
    key = jax.random.PRNGKey(seed)
    params = []
    for name in M.param_order(p):
        key, sub = jax.random.split(key)
        if ".g" in name and "ln" in name:
            params.append(jnp.ones(shapes[name], jnp.float32))
        elif ".b" in name and "ln" in name:
            params.append(jnp.zeros(shapes[name], jnp.float32))
        else:
            params.append(
                0.1 * jax.random.normal(sub, shapes[name], dtype=jnp.float32)
            )
    return params


def test_fp_forward_shapes_and_finite():
    params = make_params(TINY, VOCAB)
    tokens = jnp.arange(TINY.seq_len, dtype=jnp.int32) % VOCAB
    logits = M.lm_logits(TINY, tokens, params)
    assert logits.shape == (TINY.seq_len, VOCAB)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality():
    params = make_params(TINY, VOCAB)
    t1 = jnp.arange(TINY.seq_len, dtype=jnp.int32) % VOCAB
    t2 = t1.at[-1].set((t1[-1] + 1) % VOCAB)
    l1 = M.lm_logits(TINY, t1, params)
    l2 = M.lm_logits(TINY, t2, params)
    np.testing.assert_allclose(
        np.asarray(l1[:-1]), np.asarray(l2[:-1]), atol=1e-6
    )
    assert not np.allclose(np.asarray(l1[-1]), np.asarray(l2[-1]))


def quantize_params(p, params, gs):
    """RTN-quantize the linears of a fp param list into the qparam list."""
    fp_order = M.param_order(p)
    d = dict(zip(fp_order, params))
    out = []
    for name in M.qparam_order(p):
        if name.endswith(".qw"):
            base = name[: -len(".qw")]
            qw, sc, ze = ref.rtn_quantize_ref(d[base], gs)
            out.append(qw)
            out.append(sc)
            out.append(ze)
        elif name.endswith(".scales") or name.endswith(".zeros"):
            continue  # appended with .qw
        else:
            out.append(d[name])
    return out


def test_qlogits_matches_fp_on_dequantized_weights():
    """The quantized graph with weights W' = deq(Q(W)) must equal the fp
    graph run on W' — the two graphs differ only in where dequantization
    happens."""
    gs = 8
    params = make_params(TINY, VOCAB, seed=1)
    qparams = quantize_params(TINY, params, gs)
    # Build the dequantized fp params
    fp_order = M.param_order(TINY)
    d = dict(zip(fp_order, params))
    deq_params = []
    qd = dict(zip(M.qparam_order(TINY), qparams))
    for name in fp_order:
        if name + ".qw" in qd:
            deq_params.append(
                ref.dequantize(qd[name + ".qw"], qd[name + ".scales"],
                               qd[name + ".zeros"], gs)
            )
        else:
            deq_params.append(d[name])
    tokens = (jnp.arange(TINY.seq_len, dtype=jnp.int32) * 3) % VOCAB
    lq = M.lm_qlogits(TINY, gs, tokens, qparams)
    lf = M.lm_logits(TINY, tokens, deq_params)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lf), rtol=2e-3, atol=2e-3)


def test_presets_group_sizes_divide_all_linears():
    for p in M.PRESETS:
        gs = M.GROUP_SIZES[p.name]
        shapes = M.param_shapes(p, M.VOCAB)
        for name, shape in shapes.items():
            if any(name.endswith(f) for f in M.LINEAR_FIELDS) or name == "lm.head":
                assert shape[1] % gs == 0, (p.name, name, shape, gs)


def test_param_order_deterministic_and_complete():
    for p in M.PRESETS:
        order = M.param_order(p)
        assert order == M.param_order(p)
        shapes = M.param_shapes(p, M.VOCAB)
        assert set(order) == set(shapes.keys())
        # untied presets expose the head
        assert ("lm.head" in order) == (not p.tied_head)


def test_qparam_order_triples_linears():
    p = TINY
    qo = M.qparam_order(p)
    assert "lm.layer0.attn.q.qw" in qo
    assert "lm.layer0.attn.q.scales" in qo
    assert "lm.layer0.ln1.g" in qo
    n_linears = sum(
        1 for n in M.param_order(p)
        if any(n.endswith(f) for f in M.LINEAR_FIELDS) or n == "lm.head"
    )
    assert len(qo) == len(M.param_order(p)) + 2 * n_linears


@pytest.mark.parametrize("kind", ["gelu", "relu"])
def test_activation_kinds(kind):
    x = jnp.array([-1.0, 0.0, 2.0], jnp.float32)
    y = M.activation(x, kind)
    assert y.shape == x.shape
    if kind == "relu":
        np.testing.assert_allclose(np.asarray(y), [0.0, 0.0, 2.0])
