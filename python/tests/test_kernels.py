"""L1 correctness: Pallas kernels vs pure-jnp oracles, with hypothesis
sweeping shapes/dtypes/group sizes — the core correctness signal for the
kernel layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.block_solve import block_solve
from compile.kernels.hessian import hessian_update
from compile.kernels.quant_matmul import (
    arithmetic_intensity,
    quant_matmul,
    vmem_bytes_per_step,
)

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(key, shape, minval=lo, maxval=hi, dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    n_groups=st.integers(1, 4),
    gs=st.sampled_from([4, 8, 16]),
    n=st.integers(1, 24),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_matmul_matches_ref(m, n_groups, gs, n, bits, seed):
    k = n_groups * gs
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = rand(k1, (m, k))
    w = rand(k2, (n, k))
    qw, scales, zeros = ref.rtn_quantize_ref(w, gs, bits=bits)
    got = quant_matmul(x, qw, scales, zeros, group_size=gs)
    want = ref.quant_matmul_ref(x, qw, scales, zeros, gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    s=st.integers(1, 32),
    c=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_hessian_update_matches_ref(s, c, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    h = rand(k1, (c, c))
    h = h + h.T  # symmetric start
    x = rand(k2, (s, c))
    got = hessian_update(h, x)
    want = ref.hessian_update_ref(h, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    bc=st.integers(2, 16),
    n=st.integers(1, 16),
    alpha=st.sampled_from([0.0, 0.25, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_solve_matches_ref(bc, n, alpha, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    hinv = rand(ks[0], (bc, bc), 0.01, 1.0)
    xtd = rand(ks[1], (bc, n))
    scale = rand(ks[2], (n,), 0.05, 0.5)
    zero = jnp.round(rand(ks[3], (n,), 0.0, 15.0))
    b_old = rand(ks[4], (n, bc))
    got = block_solve(hinv, xtd, scale, zero, b_old, alpha=alpha)
    want = ref.block_solve_ref(hinv, xtd, scale, zero, b_old, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_block_solve_alpha_zero_is_identity():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    hinv = rand(ks[0], (8, 8))
    xtd = rand(ks[1], (8, 4))
    scale = rand(ks[2], (4,), 0.1, 0.3)
    zero = jnp.zeros((4,))
    b_old = rand(ks[4], (4, 8))
    out = block_solve(hinv, xtd, scale, zero, b_old, alpha=0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(b_old), atol=1e-7)


def test_rtn_ref_roundtrip_error_bounded():
    key = jax.random.PRNGKey(3)
    w = rand(key, (6, 32))
    qw, scales, zeros = ref.rtn_quantize_ref(w, 8, bits=4)
    deq = ref.dequantize(qw, scales, zeros, 8)
    step = jnp.repeat(scales, 8, axis=1)
    assert jnp.all(jnp.abs(deq - w) <= 0.5 * step + 1e-6)


def test_quant_matmul_tiled_equals_untiled():
    """Block sizes must not change numerics (the BlockSpec schedule is a
    pure data-movement choice)."""
    key = jax.random.PRNGKey(4)
    k1, k2 = jax.random.split(key)
    x = rand(k1, (33, 32))
    w = rand(k2, (17, 32))
    qw, scales, zeros = ref.rtn_quantize_ref(w, 16)
    a = quant_matmul(x, qw, scales, zeros, group_size=16, block_m=8, block_n=4)
    b = quant_matmul(x, qw, scales, zeros, group_size=16, block_m=64, block_n=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_structural_metrics_sane():
    """DESIGN.md §7 numbers: default tiling fits VMEM with big margin and
    is compute-dense."""
    vmem = vmem_bytes_per_step(bm=128, bn=128, k=128, group_size=64)
    assert vmem < 16 * 1024 * 1024  # ≪ 16 MiB VMEM
    ai = arithmetic_intensity(bm=128, bn=128, k=128)
    assert ai > 20.0  # clearly MXU-bound, not HBM-bound


@pytest.mark.parametrize("gs", [4, 8])
def test_quant_matmul_rejects_bad_group(gs):
    x = jnp.zeros((2, 10), jnp.float32)
    qw = jnp.zeros((3, 10), jnp.int32)
    s = jnp.zeros((3, 10 // gs if 10 % gs == 0 else 2), jnp.float32)
    with pytest.raises(AssertionError):
        quant_matmul(x, qw, s, s, group_size=gs)
