# Convenience targets. `artifacts` runs the build-time Python layers
# (JAX + Pallas AOT lowering) and is referenced throughout the crate docs;
# it requires a Python environment with jax installed and is NOT needed for
# `cargo build` / `cargo test` (the PJRT integration tests skip when
# `artifacts/` is absent).

.PHONY: artifacts build test bench fmt clippy

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings
