# Convenience targets. `artifacts` runs the build-time Python layers
# (JAX + Pallas AOT lowering) and is referenced throughout the crate docs;
# it requires a Python environment with jax installed and is NOT needed for
# `cargo build` / `cargo test` (the PJRT integration tests skip when
# `artifacts/` is absent).

.PHONY: artifacts build test bench fmt clippy lint loom

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings

# Repo-specific invariants (unsafe island, panic-free request paths,
# deterministic iteration, ledger tag registry). The self-test proves the
# seeded fixture violations still fire before the tree scan is trusted.
lint:
	cargo run -q --manifest-path rust/tools/rpiq-lint/Cargo.toml -- --self-test
	cargo run -q --manifest-path rust/tools/rpiq-lint/Cargo.toml -- rust/src

# Loom model checks of the exec pool's synchronization skeleton. Lives in
# an excluded crate so `loom` never enters the default dependency graph.
loom:
	cd rust/tools/loom-models && cargo test --release
