//! Serving-semantics integration tests for the multi-lane engine:
//! backpressure engages exactly at `queue_cap`, shutdown drains every
//! pending request across every lane, and a mixed-length + mixed-mode
//! replay answers every id exactly once. These are the smoke tests CI
//! runs with `RPIQ_THREADS=2` so the lane/steal paths are exercised on
//! small runners.

use rpiq::coordinator::{
    Answer, LaneEngine, Payload, Response, ServeConfig, Server, SubmitError, LANE_GENERATE,
    LANE_SENTIMENT, LANE_VQA,
};
use rpiq::metrics::tags;
use rpiq::data::corpus::Lexicon;
use rpiq::data::Tokenizer;
use rpiq::exec::Channel;
use rpiq::model::{Activation, LmWeights, ModelConfig, QuantizedLm, RESIDENT_TAG};
use rpiq::quant::QuantGrid;
use rpiq::rng::Pcg64;
use rpiq::tensor::Tensor;
use rpiq::vlm::{QuantizedVlm, VlmConfig, VlmWeights};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tiny_qlm(tok: &Tokenizer) -> Arc<QuantizedLm> {
    let mcfg = ModelConfig::test_tiny(tok.vocab_size());
    let mut rng = Pcg64::seeded(901);
    let w = LmWeights::init(&mcfg, &mut rng);
    Arc::new(QuantizedLm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete"))
}

fn tiny_qvlm(tok: &Tokenizer) -> Arc<QuantizedVlm> {
    let vcfg = VlmConfig::test_tiny(tok.vocab_size());
    let mut rng = Pcg64::seeded(902);
    let w = VlmWeights::init(&vcfg, &mut rng);
    Arc::new(QuantizedVlm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete"))
}

/// A lane whose compute blocks until the test feeds the gate — makes
/// queue occupancy deterministic so backpressure is testable.
struct GatedLane {
    gate: Channel<()>,
}

impl LaneEngine for GatedLane {
    fn name(&self) -> &'static str {
        "gated"
    }

    fn accepts(&self, payload: &Payload) -> bool {
        matches!(payload, Payload::Sentiment { .. })
    }

    fn run_batch(&self, group: &[&Payload]) -> Vec<Answer> {
        // one gate token per pickup
        let _ = self.gate.recv();
        group
            .iter()
            .map(|_| Answer::Sentiment { label: 0, label_logits: [0.0; 3] })
            .collect()
    }
}

#[test]
fn backpressure_engages_at_queue_cap() {
    let queue_cap = 4;
    let gate: Channel<()> = Channel::bounded(64);
    let server = Server::start_engines(
        vec![Box::new(GatedLane { gate: gate.clone() })],
        ServeConfig {
            queue_cap,
            max_batch: 1,
            max_wait: Duration::from_millis(0),
            lanes: 1,
            ..Default::default()
        },
    );
    // First request: the lane picks it up and parks in run_batch.
    let mut pending = vec![server.submit(Payload::Sentiment { tokens: vec![1] }).unwrap()];
    let t0 = Instant::now();
    while server.queue_depth() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "lane never picked up");
        std::thread::sleep(Duration::from_millis(1));
    }
    // With the lane parked, exactly queue_cap more requests fit.
    for i in 0..queue_cap {
        pending.push(
            server
                .submit(Payload::Sentiment { tokens: vec![i as u32 + 2] })
                .unwrap(),
        );
    }
    assert_eq!(server.queue_depth(), queue_cap);
    // The queue is at capacity: a non-blocking submit reports full.
    match server.try_submit(Payload::Sentiment { tokens: vec![99] }) {
        Ok(None) => {}
        other => panic!("expected backpressure, got {:?}", other.map(|o| o.is_some())),
    }
    // Release the gate; everything accepted must drain.
    for _ in 0..pending.len() {
        gate.send(()).unwrap();
    }
    for ch in &pending {
        assert!(ch.recv().is_some(), "request dropped");
    }
    let stats = server.shutdown();
    assert_eq!(stats.count(), queue_cap + 1);
    assert_eq!(stats.lane("gated").unwrap().count(), queue_cap + 1);
}

#[test]
fn shutdown_drains_all_pending_across_every_lane() {
    let tok = Lexicon::tokenizer();
    let server = Server::start(
        tiny_qlm(&tok),
        &tok,
        ServeConfig {
            lanes: 4,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 64,
            ..Default::default()
        },
    );
    let n = 40;
    let pending: Vec<Channel<Response>> = (0..n)
        .map(|i| {
            server
                .submit_tokens(tok.encode(&format!(
                    "sentiment of text : case {} answer :",
                    i % 5
                )))
                .unwrap()
        })
        .collect();
    // Shut down immediately: lanes must drain the whole backlog (spread
    // round-robin across all 4 shards) before exiting.
    let stats = server.shutdown();
    for ch in &pending {
        assert!(ch.recv().is_some(), "request dropped at shutdown");
    }
    assert_eq!(stats.count(), n);
}

#[test]
fn mixed_mode_serving_peak_stays_under_fp32_baseline() {
    // The deployment-memory contract end to end: a mixed-mode server over
    // nibble-resident models, with the models registered on the server
    // ledger and every lane booking its transient activations, must keep
    // its ledger peak below what the fp32 weights alone would occupy.
    // Linear-dominated shapes — the class the paper's Tables 1–3 memory
    // claims live in (test_tiny is embedding-dominated and would mask the
    // effect).
    let tok = Lexicon::tokenizer();
    let mcfg = ModelConfig {
        name: "serve-footprint-lm".into(),
        vocab: tok.vocab_size(),
        d_model: 64,
        n_layers: 4,
        n_heads: 4,
        d_ff: 256,
        seq_len: 16,
        activation: Activation::Gelu,
        tied_head: true,
    };
    let vcfg = VlmConfig::sim_cogvlm2(tok.vocab_size());
    let mut rng = Pcg64::seeded(905);
    let lm_w = LmWeights::init(&mcfg, &mut rng);
    let vlm_w = VlmWeights::init(&vcfg, &mut rng);
    let fp_baseline: usize = lm_w
        .named_tensors()
        .iter()
        .map(|(_, t)| t.nbytes())
        .sum::<usize>()
        + vlm_w.n_params() * 4;
    let qlm = Arc::new(QuantizedLm::quantize_rtn(lm_w, QuantGrid::new(4, 32)).expect("complete"));
    let qvlm = Arc::new(QuantizedVlm::quantize_rtn(vlm_w, QuantGrid::new(4, 32)).expect("complete"));
    let server = Server::start_mixed(
        Arc::clone(&qlm),
        Arc::clone(&qvlm),
        &tok,
        ServeConfig {
            lanes: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 64,
            ..Default::default()
        },
    );
    qlm.register_resident(server.ledger());
    qvlm.register_resident(server.ledger());
    let ledger = server.ledger().clone();

    let mut rng2 = Pcg64::seeded(906);
    let n = 40;
    let channels: Vec<Channel<Response>> = (0..n)
        .map(|i| {
            let payload = if i % 2 == 0 {
                Payload::Sentiment {
                    tokens: tok.encode("sentiment of text : it was fine answer :"),
                }
            } else {
                Payload::Vqa {
                    patches: Tensor::randn(&[vcfg.n_patches, vcfg.patch_dim], 1.0, &mut rng2),
                    question: tok.encode("who wrote this book ? answer :"),
                }
            };
            server.submit(payload).unwrap()
        })
        .collect();
    for ch in &channels {
        assert!(ch.recv().is_some(), "request dropped");
    }
    let stats = server.shutdown();
    assert_eq!(stats.count(), n);

    // resident accounting matches the models' own deploy_bytes
    let resident = ledger.peak_for(RESIDENT_TAG) as usize;
    assert_eq!(resident, qlm.deploy_bytes() + qvlm.deploy_bytes());
    // both lanes booked transient activations during the replay
    assert!(ledger.peak_for("activations.sentiment") > 0, "sentiment transients");
    assert!(ledger.peak_for("activations.vqa") > 0, "vqa transients");
    // the headline: resident + concurrent activations under the fp32 bar
    let peak = ledger.peak_bytes() as usize;
    assert!(
        peak < fp_baseline,
        "serving peak {peak} should stay under fp32 baseline {fp_baseline}"
    );
    // transients all returned; releasing the models balances the ledger
    qlm.release_resident(&ledger);
    qvlm.release_resident(&ledger);
    assert_eq!(ledger.live_bytes(), 0, "ledger balances after release");
}

#[test]
fn mixed_replay_answers_every_id_exactly_once() {
    let tok = Lexicon::tokenizer();
    let qvlm = tiny_qvlm(&tok);
    let vcfg = qvlm.config().clone();
    let server = Server::start_mixed(
        tiny_qlm(&tok),
        qvlm,
        &tok,
        ServeConfig {
            lanes: 4,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 128,
            ..Default::default()
        },
    );
    // Mixed modes AND mixed lengths: several sentiment prompt widths plus
    // all three VQA question templates (two distinct lengths).
    let sentiments = [
        "sentiment of text : fine answer :",
        "sentiment of text : it was fine answer :",
        "sentiment of text : i loved this movie a lot answer :",
    ];
    let questions = [
        "what genre this book ? answer :",
        "who wrote this book ? answer :",
        "what year was this published ? answer :",
    ];
    let mut rng = Pcg64::seeded(903);
    let n = 60;
    let items: Vec<Payload> = (0..n)
        .map(|i| {
            if i % 2 == 0 {
                Payload::Sentiment { tokens: tok.encode(sentiments[i % 3]) }
            } else {
                Payload::Vqa {
                    patches: Tensor::randn(&[vcfg.n_patches, vcfg.patch_dim], 1.0, &mut rng),
                    question: tok.encode(questions[i % 3]),
                }
            }
        })
        .collect();
    let channels: Vec<Channel<Response>> =
        items.into_iter().map(|p| server.submit(p).unwrap()).collect();
    let mut ids: Vec<u64> = channels
        .iter()
        .map(|c| c.recv().expect("answer missing").id)
        .collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "every id answered exactly once");
    let stats = server.shutdown();
    assert_eq!(stats.count(), n);
    assert_eq!(stats.lane(LANE_SENTIMENT).unwrap().count(), n / 2);
    assert_eq!(stats.lane(LANE_VQA).unwrap().count(), n / 2);
}

#[test]
fn over_budget_requests_rejected_at_submit() {
    let tok = Lexicon::tokenizer();
    let qlm = tiny_qlm(&tok);
    let server = Server::start(
        Arc::clone(&qlm),
        &tok,
        ServeConfig { activation_budget: Some(64), ..Default::default() },
    );
    let tokens = tok.encode("sentiment of text : it was fine answer :");
    // A 64-byte budget is below any single request's booked transient, so
    // the submit is rejected before it can deadlock a lane.
    let needed = qlm.serve_transient_bytes(1, tokens.len());
    assert!(needed > 64, "test premise: one request must overshoot the budget");
    match server.submit_tokens(tokens).unwrap_err() {
        SubmitError::OverBudget { needed: n, cap } => {
            assert_eq!(n, needed);
            assert_eq!(cap, 64);
        }
        other => panic!("expected OverBudget, got {other:?}"),
    }
    let stats = server.shutdown();
    assert_eq!(stats.rejects().over_budget, 1);
    assert_eq!(stats.count(), 0);
}

#[test]
fn budget_splits_batches_and_still_answers_everything() {
    let tok = Lexicon::tokenizer();
    let qlm = tiny_qlm(&tok);
    let tokens = tok.encode("sentiment of text : it was fine answer :");
    // Budget admits exactly one request's transient at a time: fused
    // groups split into singleton sub-batches and the two lanes serialize
    // through try_alloc — yet every request must still be answered.
    let budget = qlm.serve_transient_bytes(1, tokens.len());
    assert!(
        qlm.serve_transient_bytes(2, tokens.len()) > budget,
        "test premise: two fused requests must overshoot the budget"
    );
    let server = Server::start(
        Arc::clone(&qlm),
        &tok,
        ServeConfig {
            lanes: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 64,
            activation_budget: Some(budget),
            kv_pages: None,
        },
    );
    let ledger = server.ledger().clone();
    let n = 16;
    let channels: Vec<Channel<Response>> = (0..n)
        .map(|_| server.submit_tokens(tokens.clone()).unwrap())
        .collect();
    for ch in &channels {
        assert!(ch.recv().is_some(), "request dropped under budget");
    }
    let stats = server.shutdown();
    assert_eq!(stats.count(), n);
    assert_eq!(stats.rejects().total(), 0);
    // The enforcement proof: the lane tag's ledger peak never exceeded
    // the cap even with two lanes booking concurrently.
    let peak = ledger.peak_for("activations.sentiment") as usize;
    assert!(peak > 0, "lanes booked transients");
    assert!(peak <= budget, "peak {peak} must stay within budget {budget}");
}

#[test]
fn generate_streams_each_token_exactly_once_and_matches_oracle_deterministic() {
    let tok = Lexicon::tokenizer();
    let qlm = tiny_qlm(&tok);
    let prompt = tok.encode("sentiment of text :");
    let max_new = qlm.config().seq_len + 1 - prompt.len();
    let server = Server::start_generate(
        Arc::clone(&qlm),
        &tok,
        ServeConfig {
            lanes: 1,
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            queue_cap: 16,
            ..Default::default()
        },
    );
    let pool = server.kv_pool().cloned().expect("generate server owns a kv pool");
    let ledger = server.ledger().clone();
    let ch = server.submit_generate(prompt.clone(), max_new, None).unwrap();
    // The stream contract: one Token per decoded position, indices strictly
    // 0..max_new in order, then a single terminal Generated recap.
    let mut streamed: Vec<u32> = Vec::new();
    let mut finals: Vec<Vec<u32>> = Vec::new();
    while let Some(resp) = ch.recv() {
        match resp.answer {
            Answer::Token { index, token, .. } => {
                assert_eq!(index, streamed.len(), "token indices arrive in order");
                streamed.push(token);
            }
            Answer::Generated { tokens, .. } => finals.push(tokens),
            other => panic!("unexpected answer on generate stream: {other:?}"),
        }
    }
    let oracle = qlm.generate_recompute(&prompt, max_new, None).unwrap();
    assert_eq!(streamed, oracle, "streamed tokens match the recompute oracle");
    assert_eq!(finals, vec![oracle], "exactly one terminal recap, same tokens");
    let stats = server.shutdown();
    assert_eq!(stats.count(), 1);
    let per_token = stats.lane_tokens(LANE_GENERATE).expect("per-token latency recorded");
    assert_eq!(per_token.count(), max_new);
    // KV accounting ran and fully unwound: pages back in the pool, tag at zero.
    assert!(ledger.peak_for(tags::KV_CACHE) > 0, "kv cache pages were booked");
    assert_eq!(pool.free_pages(), pool.capacity_pages(), "pool fully free after drain");
    assert_eq!(ledger.live_bytes(), 0, "ledger balances after drain");
}

#[test]
fn generate_client_disconnect_balances_kv_ledger() {
    let tok = Lexicon::tokenizer();
    let qlm = tiny_qlm(&tok);
    let prompt = tok.encode("sentiment of text :");
    let server = Server::start_generate(
        Arc::clone(&qlm),
        &tok,
        ServeConfig { lanes: 1, max_batch: 2, queue_cap: 16, ..Default::default() },
    );
    let pool = server.kv_pool().cloned().expect("generate server owns a kv pool");
    let ledger = server.ledger().clone();
    let ch = server.submit_generate(prompt, 5, None).unwrap();
    assert!(ch.recv().is_some(), "first streamed token arrives");
    // Walk away mid-stream: the lane must notice the dead reply channel,
    // retire the sequence, and hand every page and byte back.
    ch.close();
    let t0 = Instant::now();
    while pool.free_pages() != pool.capacity_pages() || ledger.live_bytes() != 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "kv pages/bytes not reclaimed after disconnect: {}/{} pages free, {} bytes live",
            pool.free_pages(),
            pool.capacity_pages(),
            ledger.live_bytes()
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    server.shutdown();
    assert_eq!(ledger.live_bytes(), 0, "ledger balances after shutdown");
}

#[test]
fn generate_pool_exhaustion_rejects_at_submit_without_deadlock() {
    let tok = Lexicon::tokenizer();
    let qlm = tiny_qlm(&tok);
    let prompt = tok.encode("sentiment of text :");
    // test_tiny needs n_layers = 2 pages per sequence; a 1-page pool can
    // never hold one, so admission must reject up front — OverBudget in
    // kv-pool bytes — rather than park the request forever.
    let server = Server::start_generate(
        Arc::clone(&qlm),
        &tok,
        ServeConfig { lanes: 1, kv_pages: Some(1), ..Default::default() },
    );
    let pool = server.kv_pool().cloned().expect("generate server owns a kv pool");
    match server.submit_generate(prompt, 3, None).unwrap_err() {
        SubmitError::OverBudget { needed, cap } => {
            assert_eq!(cap, pool.page_bytes(), "cap reported in kv-pool bytes");
            assert!(needed > cap, "request needs more pages than the pool holds");
        }
        other => panic!("expected OverBudget, got {other:?}"),
    }
    let stats = server.shutdown();
    assert_eq!(stats.rejects().over_budget, 1);
    assert_eq!(stats.count(), 0);
}
