//! Integration: the full pipeline on a trained-for-a-moment model — the
//! shapes the paper's tables rely on, at test-suite scale (the real
//! table-scale runs live in the benches).

use rpiq::coordinator::experiments as exp;
use rpiq::coordinator::{quantize_lm, Method, ServeConfig, Server};
use rpiq::model::ModelConfig;
use rpiq::quant::{QuantConfig, RpiqParams};
use rpiq::rng::Pcg64;
use std::sync::Arc;

fn mini_world_and_model() -> (exp::World, rpiq::model::LmWeights) {
    let world = exp::World::build(99);
    let mut cfg = ModelConfig::test_tiny(world.tokenizer().vocab_size());
    cfg.seq_len = 32;
    // brief training so quantization has structure to preserve
    let (w, curve) = exp::pretrain_lm(&cfg, &world, 60, 4, 7, |_, _| {});
    assert!(curve.last().unwrap().1 < curve.first().unwrap().1);
    (world, w)
}

#[test]
fn rpiq_beats_or_ties_gptq_on_task_metrics() {
    let (world, w) = mini_world_and_model();
    let windows = world.calib_windows(w.config.seq_len, 16);
    let cfg = QuantConfig { bits: 4, group_size: 8, block_size: 8, percdamp: 0.01 };

    let fp = exp::eval_lm_fp(&w, &world, 10, 60);
    let gptq = quantize_lm(&w, &windows, cfg, Method::Gptq).unwrap();
    let rpiq = quantize_lm(&w, &windows, cfg, Method::Rpiq(RpiqParams::default())).unwrap();
    let ev_g = exp::eval_lm_q(&gptq.model, &world, 10, 60);
    let ev_r = exp::eval_lm_q(&rpiq.model, &world, 10, 60);

    // Quantization hurts vs fp (or ties); both remain finite and sane.
    assert!(ev_g.ppl.is_finite() && ev_r.ppl.is_finite());
    assert!(ev_g.ppl >= fp.ppl * 0.95, "4-bit should not beat fp PPL by much");
    // Stage 2 must not make the *layer reconstruction* worse; task metrics
    // are noisy at this scale, so assert the layer-level invariant plus a
    // no-catastrophe bound on PPL.
    for (g, r) in gptq.reports.iter().zip(rpiq.reports.iter()) {
        assert!(r.final_loss() <= g.final_loss() + 1e-9, "{}", r.name);
    }
    assert!(ev_r.ppl < ev_g.ppl * 1.25);

    // Memory: 4-bit deployment is a fraction of fp32. The test model is
    // embedding-dominated (d_model=16, vocab≈165), so the bound is looser
    // than the ~27% seen on the real presets (embeddings stay fp32).
    let fp_bytes: usize = w.named_tensors().iter().map(|(_, t)| t.nbytes()).sum();
    assert!((gptq.model.deploy_bytes() as f64) < 0.8 * fp_bytes as f64);
}

#[test]
fn quantized_model_serves_under_batching() {
    let (world, w) = mini_world_and_model();
    let windows = world.calib_windows(w.config.seq_len, 8);
    let cfg = QuantConfig { bits: 4, group_size: 8, block_size: 8, percdamp: 0.01 };
    let out = quantize_lm(&w, &windows, cfg, Method::Rpiq(RpiqParams::default())).unwrap();
    let tok = world.tokenizer().clone();
    let server = Server::start(Arc::new(out.model), &tok, ServeConfig::default());
    let prompts: Vec<String> = world.sentiment.test[..12]
        .iter()
        .map(|e| e.prompt())
        .collect();
    let tput = rpiq::coordinator::serve::replay(&server, &tok, &prompts, 3);
    assert!(tput > 0.0);
    let stats = server.shutdown();
    assert_eq!(stats.count(), 12);
    assert!(stats.percentile_ms(95.0) >= stats.percentile_ms(50.0));
}

#[test]
fn snapshot_rotation_keeps_peak_memory_flat() {
    // The paper's future-work rotation: same resident bytes, different
    // anchor batches.
    let mut rng = Pcg64::seeded(5);
    let batches: Vec<rpiq::tensor::Tensor> = (0..4)
        .map(|_| rpiq::tensor::Tensor::randn(&[8, 16], 1.0, &mut rng))
        .collect();
    let bytes = batches[0].nbytes();
    let mut rot = rpiq::quant::calib::SnapshotRotator::new(batches, 2);
    assert_eq!(rot.resident_bytes(), bytes);
    let _ = rot.next();
    let _ = rot.next();
    let _ = rot.next();
    assert_eq!(rot.resident_bytes(), bytes);
}
