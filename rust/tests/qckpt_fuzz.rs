//! Hostile-input property tests for the `.rpiq` typed-container loaders.
//!
//! The loaders (`model::io::load_qlm`, `vlm::io::load_qvlm`) sit on the
//! deployment path and read untrusted bytes; their contract is a clean
//! `Err` on any malformed file — never a panic, and never an
//! attacker-sized allocation (every length field must be validated
//! against the actual file size before memory is reserved).
//!
//! Three corruption families, all derived from one known-good container
//! per format:
//! * truncation at a random byte boundary,
//! * random bit flips anywhere in the file,
//! * length-field corruption (u32 fields overwritten with huge values).

use rpiq::model::io::{load_qlm, save_qlm};
use rpiq::model::{LmWeights, ModelConfig, QuantizedLm};
use rpiq::proptest::{prop_assert, PropResult, Runner};
use rpiq::quant::QuantGrid;
use rpiq::rng::Pcg64;
use rpiq::vlm::io::{load_qvlm, save_qvlm};
use rpiq::vlm::{QuantizedVlm, VlmConfig, VlmWeights};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};

/// Build one valid container per format and return its bytes.
fn valid_qlm_bytes(dir: &Path) -> Vec<u8> {
    let cfg = ModelConfig::test_tiny(32);
    let mut rng = Pcg64::seeded(7001);
    let w = LmWeights::init(&cfg, &mut rng);
    let qlm = QuantizedLm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete");
    let path = dir.join("seed_qlm.rpiq");
    save_qlm(&qlm, &path).unwrap();
    std::fs::read(&path).unwrap()
}

fn valid_qvlm_bytes(dir: &Path) -> Vec<u8> {
    let cfg = VlmConfig::test_tiny(32);
    let mut rng = Pcg64::seeded(7002);
    let w = VlmWeights::init(&cfg, &mut rng);
    let qvlm = QuantizedVlm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete");
    let path = dir.join("seed_qvlm.rpiq");
    save_qvlm(&qvlm, &path).unwrap();
    std::fs::read(&path).unwrap()
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rpiq_qckpt_fuzz_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write `bytes` and run the loader under `catch_unwind`: `Ok(result)` is
/// the loader's verdict, `Err(label)` means it panicked — always a
/// property failure.
fn load_corrupted<T>(
    path: &Path,
    bytes: &[u8],
    load: impl Fn(&Path) -> anyhow::Result<T>,
) -> Result<anyhow::Result<T>, String> {
    std::fs::write(path, bytes).unwrap();
    catch_unwind(AssertUnwindSafe(|| load(path)))
        .map_err(|_| "loader panicked on corrupted container".to_string())
}

fn check_truncation<T>(
    name: &'static str,
    valid: &[u8],
    path: &Path,
    load: impl Fn(&Path) -> anyhow::Result<T> + Copy,
) {
    let mut runner = Runner::new(name, 48);
    runner.run(|g| -> PropResult {
        let cut = g.usize_in(0..valid.len());
        let verdict = load_corrupted(path, &valid[..cut], load)?;
        prop_assert(verdict.is_err(), "truncated container must fail to load")
    });
}

fn check_bit_flips<T>(
    name: &'static str,
    valid: &[u8],
    path: &Path,
    load: impl Fn(&Path) -> anyhow::Result<T> + Copy,
) {
    let mut runner = Runner::new(name, 48);
    runner.run(|g| -> PropResult {
        let mut bytes = valid.to_vec();
        let flips = g.usize_in(1..9);
        for _ in 0..flips {
            let at = g.usize_in(0..bytes.len());
            let bit = g.usize_in(0..8) as u8;
            bytes[at] ^= 1 << bit;
        }
        // A flip inside an f32 payload can leave the container valid, so
        // the property is panic-freedom, not rejection.
        let _verdict = load_corrupted(path, &bytes, load)?;
        Ok(())
    });
}

/// Byte offsets of the size-bearing header fields of a typed container
/// (see `model::io::read_container_typed` for the layout): version,
/// config-JSON length, entry count, and the first entry's name length,
/// dim count, and first dim. Computed from the valid bytes because the
/// JSON and name lengths vary.
fn length_field_offsets(valid: &[u8]) -> Vec<usize> {
    let u32_at = |at: usize| -> usize {
        let mut b = [0u8; 4];
        b.copy_from_slice(&valid[at..at + 4]);
        u32::from_le_bytes(b) as usize
    };
    let cfg_len = u32_at(12);
    let entries_at = 16 + cfg_len; // u32 entry count
    let name_len_at = entries_at + 4; // first entry: u32 name length
    let name_len = u32_at(name_len_at);
    let ndim_at = name_len_at + 4 + name_len + 1; // + name + dtype byte
    let dim0_at = ndim_at + 4; // first u64 dim (low half corrupted)
    vec![8, 12, entries_at, name_len_at, ndim_at, dim0_at]
}

/// Overwrite each size-bearing header field with `u32::MAX`: the loader
/// must return `Err` (every declared size is validated against the real
/// file size with checked arithmetic) rather than attempt a ~4 GiB
/// allocation or a long read loop. Magic stays intact so corruption
/// reaches the parser proper.
fn check_length_corruption<T>(
    valid: &[u8],
    path: &Path,
    load: impl Fn(&Path) -> anyhow::Result<T> + Copy,
) {
    for at in length_field_offsets(valid) {
        assert!(at + 4 <= valid.len(), "offset computation escaped the container");
        let mut bytes = valid.to_vec();
        bytes[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let verdict = load_corrupted(path, &bytes, load)
            .unwrap_or_else(|p| panic!("{p} (length field at byte {at})"));
        assert!(
            verdict.is_err(),
            "container with length field {at} = u32::MAX must be rejected"
        );
    }
}

#[test]
fn qlm_loader_survives_hostile_containers() {
    let dir = fresh_dir("qlm");
    let valid = valid_qlm_bytes(&dir);
    let path = dir.join("corrupt.rpiq");
    // sanity: the seed container itself loads
    std::fs::write(&path, &valid).unwrap();
    assert!(load_qlm(&path).is_ok());
    check_truncation("qlm_truncation_rejected", &valid, &path, load_qlm);
    check_bit_flips("qlm_bit_flips_never_panic", &valid, &path, load_qlm);
    check_length_corruption(&valid, &path, load_qlm);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn qvlm_loader_survives_hostile_containers() {
    let dir = fresh_dir("qvlm");
    let valid = valid_qvlm_bytes(&dir);
    let path = dir.join("corrupt.rpiq");
    std::fs::write(&path, &valid).unwrap();
    assert!(load_qvlm(&path).is_ok());
    check_truncation("qvlm_truncation_rejected", &valid, &path, load_qvlm);
    check_bit_flips("qvlm_bit_flips_never_panic", &valid, &path, load_qvlm);
    check_length_corruption(&valid, &path, load_qvlm);
    std::fs::remove_dir_all(&dir).ok();
}
