//! Tracing integration tests: the observability contract end to end.
//!
//! * Disabled tracing is free — no events *and no allocations* from the
//!   instrumented hot paths (a counting global allocator proves it).
//! * Span trees balance even when a lane engine panics mid-batch (the
//!   serve loop's `catch_unwind` path), and the drop/reject accounting
//!   matches what the trace records.
//! * Span counts from the quantization pipeline are deterministic across
//!   `RPIQ_THREADS`-style shard targets — the same invariant the
//!   determinism CI matrix asserts for numerics, extended to telemetry.

use rpiq::coordinator::{
    quantize_lm, Answer, LaneEngine, Method, Payload, ServeConfig, Server, SubmitError,
};
use rpiq::model::{LmWeights, ModelConfig, QuantizedLm};
use rpiq::quant::{QuantConfig, QuantGrid, RpiqParams};
use rpiq::rng::Pcg64;
use rpiq::tensor::Tensor;
use rpiq::trace;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Counting allocator: per-thread allocation counts over the System
// allocator, so the disabled-overhead test is immune to allocations from
// concurrently running test threads.
// ---------------------------------------------------------------------------

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY-free wrapper: defers entirely to System; the only addition is a
// thread-local counter bump (`try_with` so allocations during TLS
// teardown cannot panic).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// Disabled tracing: zero events, zero allocations
// ---------------------------------------------------------------------------

#[test]
fn disabled_tracing_is_event_free_and_allocation_free() {
    let _guard = trace::test_lock();
    trace::stop();
    let _ = trace::take(); // drain leftovers from other tests

    let t0 = Instant::now();
    let before = thread_allocs();
    for _ in 0..10_000 {
        let _s = trace::span("quant", "gptq");
        let _d = trace::span_detail("serve", "batch", || String::from("never built"));
        trace::instant("serve", "tick");
        trace::counter("mem.live", 1.0);
        trace::complete_at("serve", "req.queue_wait", t0, Duration::from_micros(5));
    }
    let after = thread_allocs();
    assert_eq!(after - before, 0, "disabled emission sites must not allocate");
    assert!(trace::take().events.is_empty(), "disabled emission sites must not record");

    // The deployment-path check: a fused quantized forward (qmatmul rows
    // sharded over the pool) with tracing disabled records nothing — the
    // pool's per-task spans and the model spans are all behind the flag.
    let cfg = ModelConfig::test_tiny(50);
    let mut rng = Pcg64::seeded(7001);
    let w = LmWeights::init(&cfg, &mut rng);
    let qlm = QuantizedLm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete");
    let tokens: Vec<u32> = (0..cfg.seq_len).map(|i| (i % 50) as u32).collect();
    let logits = qlm.forward(&tokens, 1, cfg.seq_len).expect("forward");
    assert!(logits.data().iter().all(|v| v.is_finite()));
    assert!(trace::take().events.is_empty(), "disabled qmatmul emitted trace events");
}

// ---------------------------------------------------------------------------
// Balance across an engine panic + drop/reject accounting
// ---------------------------------------------------------------------------

/// A lane whose compute always panics — the serve loop must contain it,
/// count the dropped group, and leave balanced span trees behind.
struct PanicLane;

impl LaneEngine for PanicLane {
    fn name(&self) -> &'static str {
        "panicky"
    }

    fn accepts(&self, payload: &Payload) -> bool {
        matches!(payload, Payload::Sentiment { .. })
    }

    fn run_batch(&self, _group: &[&Payload]) -> Vec<Answer> {
        panic!("engine bug");
    }
}

#[test]
fn span_trees_balance_across_engine_panics() {
    let _guard = trace::test_lock();
    trace::start();
    let server = Server::start_engines(
        vec![Box::new(PanicLane)],
        ServeConfig {
            lanes: 1,
            max_batch: 4,
            max_wait: Duration::from_millis(0),
            queue_cap: 16,
            ..Default::default()
        },
    );
    // The engine panics inside the lane's catch_unwind; the request's
    // reply channel closes without an answer.
    let ch = server.submit(Payload::Sentiment { tokens: vec![1, 2, 3] }).unwrap();
    assert!(ch.recv().is_none(), "a dropped group must close the reply channel");
    // An unsupported payload is rejected at submit and counted by kind.
    let mut rng = Pcg64::seeded(7002);
    let err = server.submit(Payload::Vqa {
        patches: Tensor::randn(&[2, 2], 1.0, &mut rng),
        question: vec![1],
    });
    assert!(matches!(err, Err(SubmitError::Unsupported)));
    let stats = server.shutdown();
    assert_eq!(stats.drops("panicky"), 1, "the dead group is counted as dropped");
    assert_eq!(stats.total_drops(), 1);
    assert_eq!(stats.rejects().unsupported, 1);
    assert_eq!(stats.count(), 0, "dropped requests never enter the latency counts");
    assert_eq!(stats.batch_histogram("panicky"), vec![(1, 1)]);

    let t = trace::stop_and_take();
    // The headline: even with the panic, every Begin has its End — the
    // batch span's guard dropped normally (the panic is caught inside it)
    // and the lane thread kept its stack consistent.
    let summary = t.summary().expect("span trees must balance across catch_unwind");
    assert!(t.count_spans("batch") >= 1, "the doomed batch was spanned");
    assert!(t.count_spans("req.queue_wait") >= 1, "queue wait recorded before the panic");
    assert!(
        summary.instants.iter().any(|(n, c)| n == "group.dropped" && *c == 1),
        "the drop left an instant marker on the timeline"
    );
}

// ---------------------------------------------------------------------------
// Span-count determinism across shard targets
// ---------------------------------------------------------------------------

/// Pipeline span names whose counts must not depend on the thread target.
/// `exec.task` is deliberately absent: the pool legitimately runs more
/// (smaller) tasks at higher shard targets.
const STABLE_SPANS: &[&str] =
    &["calibrate", "calib.window", "calib.finalize", "layers", "gptq", "rpiq.refine"];

#[test]
fn pipeline_span_counts_deterministic_across_thread_counts() {
    let _threads = rpiq::exec::thread_target_test_lock();
    let _trace = trace::test_lock();
    let before = rpiq::exec::num_threads();

    let vocab = 60usize;
    let mut cfg = ModelConfig::test_tiny(vocab);
    cfg.seq_len = 16;
    let mut rng = Pcg64::seeded(7003);
    let w = LmWeights::init(&cfg, &mut rng);
    let n_linears = w.linears().len();
    let n_windows = 6usize;
    let windows: Vec<Vec<u32>> = (0..n_windows)
        .map(|wi| (0..cfg.seq_len).map(|i| ((wi * 7 + i * 3) % vocab) as u32).collect())
        .collect();
    let qcfg = QuantConfig { bits: 4, group_size: 8, block_size: 8, percdamp: 0.01 };

    let run = |threads: usize| -> BTreeMap<&'static str, usize> {
        rpiq::exec::set_threads(threads);
        trace::start();
        let out = quantize_lm(&w, &windows, qcfg, Method::Rpiq(RpiqParams::default()))
            .expect("pipeline");
        assert_eq!(out.reports.len(), n_linears);
        let t = trace::stop_and_take();
        t.summary().expect("pipeline trace balances");
        STABLE_SPANS.iter().map(|&n| (n, t.count_spans(n))).collect()
    };

    let base = run(1);
    assert_eq!(base["calib.window"], n_windows, "one span per calibration window");
    assert_eq!(base["gptq"], n_linears, "one GPTQ walk per linear");
    assert_eq!(base["rpiq.refine"], n_linears, "one refine per linear");
    assert_eq!(base["calibrate"], 1);
    assert_eq!(base["calib.finalize"], 1);
    assert_eq!(base["layers"], 1);
    for threads in [2usize, 8] {
        let counts = run(threads);
        assert_eq!(counts, base, "span counts diverged at {threads} threads");
    }
    rpiq::exec::set_threads(before);
}
