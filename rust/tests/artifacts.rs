//! Integration: the three-layer contract. Loads `artifacts/` (built by
//! `make artifacts`), executes entries on the PJRT CPU client, and checks
//! numerics against the Rust implementations.
//!
//! Skips (with a loud message) if artifacts have not been built — `make
//! test` always builds them first.

use rpiq::coordinator::experiments as exp;
use rpiq::coordinator::{quantize_lm, Method};
use rpiq::model::forward::lm_forward;
use rpiq::model::weights::LmWeights;
use rpiq::model::ModelConfig;
use rpiq::quant::QuantConfig;
use rpiq::rng::Pcg64;
use rpiq::runtime::{lm_args, Arg, Engine};
use rpiq::tensor::Tensor;
use std::path::Path;

fn engine() -> Option<Engine> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP: built without the `pjrt` feature (stub Engine cannot execute artifacts)");
        return None;
    }
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

#[test]
fn selfcheck_add_runs() {
    let Some(eng) = engine() else { return };
    let x = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
    let out = eng.run("selfcheck_add", &[Arg::F32(x)]).unwrap();
    assert_eq!(out[0].data(), &[2.0, 4.0, 6.0, 8.0]);
}

#[test]
fn manifest_vocab_matches_rust_lexicon() {
    let Some(eng) = engine() else { return };
    let manifest = std::fs::read_to_string(eng.registry.dir.join("manifest.json")).unwrap();
    let json = rpiq::jsonx::Json::parse(&manifest).unwrap();
    let vocab = json.get("vocab").unwrap().as_usize().unwrap();
    let tok = rpiq::data::corpus::Lexicon::tokenizer();
    assert_eq!(
        vocab,
        tok.vocab_size(),
        "python/compile/model.py VOCAB is out of sync with the Rust lexicon"
    );
}

#[test]
fn pallas_qmatmul_artifact_matches_rust_qmatmul() {
    // L1 kernel (through PJRT) vs the Rust fused dequant-matmul.
    let Some(eng) = engine() else { return };
    let (m, k, n, gs) = (64usize, 128usize, 64usize, 64usize);
    let mut rng = Pcg64::seeded(1201);
    let x = Tensor::randn(&[m, k], 1.0, &mut rng);
    let w = Tensor::randn(&[n, k], 0.5, &mut rng);
    let q = rpiq::quant::QuantizedLinear::quantize_rtn(&w, rpiq::quant::QuantGrid::new(4, gs));
    let levels: Vec<i32> = q.levels().iter().map(|&b| b as i32).collect();
    let ng = q.n_groups();
    let out = eng
        .run(
            "qmatmul_64x128x64_g64",
            &[
                Arg::F32(x.clone()),
                Arg::I32(levels, vec![n, k]),
                Arg::F32(Tensor::from_vec(&[n, ng], q.scales.clone())),
                Arg::F32(Tensor::from_vec(&[n, ng], q.zeros.clone())),
            ],
        )
        .unwrap();
    let rust = rpiq::model::QuantizedLm::qmatmul(&x, &q).expect("shapes agree");
    let rel = out[0].sub(&rust).frob() / rust.frob().max(1e-9);
    assert!(rel < 1e-4, "kernel vs rust rel err {rel}");
}

#[test]
fn hessian_artifact_matches_rust() {
    let Some(eng) = engine() else { return };
    let (s, c) = (48usize, 128usize);
    let mut rng = Pcg64::seeded(1202);
    let h0 = Tensor::zeros(&[c, c]);
    let x = Tensor::randn(&[s, c], 1.0, &mut rng);
    let out = eng
        .run("hessian_48x128", &[Arg::F32(h0), Arg::F32(x.clone())])
        .unwrap();
    let want = rpiq::tensor::matmul_at_b(&x, &x);
    let rel = out[0].sub(&want).frob() / want.frob().max(1e-9);
    assert!(rel < 1e-4, "hessian rel err {rel}");
}

#[test]
fn fp_model_artifact_matches_rust_forward() {
    // L2 graph vs the Rust forward, random weights, preset shapes.
    let Some(eng) = engine() else { return };
    let tok = rpiq::data::corpus::Lexicon::tokenizer();
    let cfg = ModelConfig::preset("sim-opt-6.7b", tok.vocab_size()).unwrap();
    let mut rng = Pcg64::seeded(1203);
    let w = LmWeights::init(&cfg, &mut rng);
    let tokens: Vec<u32> = (0..cfg.seq_len)
        .map(|_| rng.next_below(cfg.vocab) as u32)
        .collect();
    let args = lm_args::lm_fp_args(&w, &tokens);
    let out = eng.run("lm_logits_sim-opt-6.7b", &args).unwrap();
    let rust = lm_forward(&w, &tokens, 1, cfg.seq_len, None);
    let rel = out[0].sub(&rust).frob() / rust.frob().max(1e-9);
    assert!(rel < 1e-3, "fp artifact vs rust rel err {rel}");
}

#[test]
fn quantized_model_artifact_matches_rust_qforward() {
    // The full three-layer story: GPTQ-quantized weights executed through
    // the Pallas-kernel graph on PJRT vs the Rust quantized forward.
    let Some(eng) = engine() else { return };
    let tok = rpiq::data::corpus::Lexicon::tokenizer();
    let cfg = ModelConfig::preset("sim-opt-6.7b", tok.vocab_size()).unwrap();
    let mut rng = Pcg64::seeded(1204);
    let w = LmWeights::init(&cfg, &mut rng);
    let world = exp::World::build(1);
    let windows = world.calib_windows(cfg.seq_len, 8);
    let gs = exp::group_size_for("sim-opt-6.7b");
    let qcfg = QuantConfig {
        bits: 4,
        group_size: gs,
        block_size: gs,
        percdamp: 0.01,
    };
    let out = quantize_lm(&w, &windows, qcfg, Method::Gptq).unwrap();
    let tokens: Vec<u32> = (0..cfg.seq_len)
        .map(|_| rng.next_below(cfg.vocab) as u32)
        .collect();
    let args = lm_args::lm_q_args(&out.model, &tokens);
    let got = eng.run("lm_qlogits_sim-opt-6.7b", &args).unwrap();
    let rust = out.model.forward(&tokens, 1, cfg.seq_len).expect("forward");
    let rel = got[0].sub(&rust).frob() / rust.frob().max(1e-9);
    assert!(rel < 1e-3, "quant artifact vs rust rel err {rel}");
}

#[test]
fn engine_rejects_wrong_shapes() {
    let Some(eng) = engine() else { return };
    let bad = Tensor::zeros(&[3, 3]);
    let err = eng.run("selfcheck_add", &[Arg::F32(bad)]).unwrap_err();
    assert!(err.to_string().contains("expected"));
}
