//! Execution substrate: a work-stealing-free but correct thread pool plus
//! bounded MPMC channels, used by the serving coordinator (request router,
//! dynamic batcher) in place of tokio, which is unavailable offline.
//!
//! The design is deliberately simple: a shared `Mutex<VecDeque>` job queue
//! with a condvar. On the 1-core CI machine contention is irrelevant; on
//! larger machines the coordinator's batching amortizes queue traffic.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    idle: Condvar,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (minimum 1).
    pub fn new(n: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            idle: Condvar::new(),
        });
        let workers = (0..n.max(1))
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rpiq-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Box::new(f));
        self.shared.available.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while !q.is_empty() || self.shared.in_flight.load(Ordering::SeqCst) > 0 {
            let (guard, _) = self
                .shared
                .idle
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap();
            q = guard;
        }
    }

    /// Run a batch of closures and collect results in order. Convenience
    /// used by the quantization pipeline to fan layer jobs out.
    pub fn map<T: Send + 'static, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let results: Arc<Mutex<Vec<Option<T>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        for (i, job) in jobs.into_iter().enumerate() {
            let slot = Arc::clone(&results);
            self.submit(move || {
                let out = job();
                slot.lock().unwrap()[i] = Some(out);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(results)
            .ok()
            .expect("all workers done")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|o| o.expect("job completed"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        job();
        shared.in_flight.fetch_sub(1, Ordering::SeqCst);
        shared.idle.notify_all();
    }
}

/// Bounded MPMC channel with blocking send/recv and timeout recv — the
/// backpressure primitive of the serving coordinator: when the queue is
/// full, producers (request ingestion) block, which is exactly the
/// backpressure behaviour the batcher tests assert.
pub struct Channel<T> {
    inner: Arc<ChannelInner<T>>,
}

struct ChannelInner<T> {
    buf: Mutex<ChannelBuf<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct ChannelBuf<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Channel { inner: Arc::clone(&self.inner) }
    }
}

/// Error returned when sending on a closed channel.
#[derive(Debug, PartialEq)]
pub struct SendError;

impl<T> Channel<T> {
    pub fn bounded(cap: usize) -> Self {
        Channel {
            inner: Arc::new(ChannelInner {
                buf: Mutex::new(ChannelBuf { items: VecDeque::new(), closed: false }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                cap: cap.max(1),
            }),
        }
    }

    /// Blocking send; returns Err if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), SendError> {
        let mut buf = self.inner.buf.lock().unwrap();
        while buf.items.len() >= self.inner.cap {
            if buf.closed {
                return Err(SendError);
            }
            buf = self.inner.not_full.wait(buf).unwrap();
        }
        if buf.closed {
            return Err(SendError);
        }
        buf.items.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking send attempt. Ok(false) = full.
    pub fn try_send(&self, item: T) -> Result<bool, SendError> {
        let mut buf = self.inner.buf.lock().unwrap();
        if buf.closed {
            return Err(SendError);
        }
        if buf.items.len() >= self.inner.cap {
            return Ok(false);
        }
        buf.items.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(true)
    }

    /// Blocking receive; None when channel is closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut buf = self.inner.buf.lock().unwrap();
        loop {
            if let Some(item) = buf.items.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if buf.closed {
                return None;
            }
            buf = self.inner.not_empty.wait(buf).unwrap();
        }
    }

    /// Receive with deadline; None on timeout or closed-and-empty. Used by
    /// the dynamic batcher to implement the max-wait batching window.
    pub fn recv_timeout(&self, dur: Duration) -> Option<T> {
        let deadline = Instant::now() + dur;
        let mut buf = self.inner.buf.lock().unwrap();
        loop {
            if let Some(item) = buf.items.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if buf.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _res) = self
                .inner
                .not_empty
                .wait_timeout(buf, deadline - now)
                .unwrap();
            buf = guard;
        }
    }

    /// Drain up to `max` items without blocking (batch pickup).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut buf = self.inner.buf.lock().unwrap();
        let n = buf.items.len().min(max);
        let out: Vec<T> = buf.items.drain(..n).collect();
        if n > 0 {
            self.inner.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.buf.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the channel: senders fail, receivers drain then get None.
    pub fn close(&self) {
        let mut buf = self.inner.buf.lock().unwrap();
        buf.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<_> = (0..20).map(|i| move || i * i).collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn channel_fifo() {
        let ch = Channel::bounded(10);
        for i in 0..5 {
            ch.send(i).unwrap();
        }
        let got: Vec<i32> = (0..5).map(|_| ch.recv().unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn channel_backpressure_blocks_then_releases() {
        let ch: Channel<u32> = Channel::bounded(2);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert_eq!(ch.try_send(3), Ok(false)); // full
        let ch2 = ch.clone();
        let t = std::thread::spawn(move || ch2.send(3)); // blocks
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(ch.recv(), Some(1));
        t.join().unwrap().unwrap();
        assert_eq!(ch.recv(), Some(2));
        assert_eq!(ch.recv(), Some(3));
    }

    #[test]
    fn channel_close_semantics() {
        let ch: Channel<u32> = Channel::bounded(4);
        ch.send(7).unwrap();
        ch.close();
        assert_eq!(ch.send(8), Err(SendError));
        assert_eq!(ch.recv(), Some(7)); // drain allowed
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn recv_timeout_times_out() {
        let ch: Channel<u32> = Channel::bounded(1);
        let t0 = Instant::now();
        assert_eq!(ch.recv_timeout(Duration::from_millis(30)), None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn drain_up_to_takes_batch() {
        let ch = Channel::bounded(16);
        for i in 0..10 {
            ch.send(i).unwrap();
        }
        let batch = ch.drain_up_to(4);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(ch.len(), 6);
    }
}
