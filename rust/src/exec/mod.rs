//! Execution substrate: the process-global thread pool every hot path
//! shares, a scoped fork-join API for sharding borrowed data, bounded
//! MPMC channels, and a sharded work-stealing queue ([`ShardedQueue`])
//! for the multi-lane serving coordinator — all in place of
//! tokio/rayon/crossbeam, which are unavailable offline.
//!
//! # Threading model
//!
//! * **One pool per process.** [`global`] lazily creates the shared
//!   [`ThreadPool`]; its worker count comes from the `RPIQ_THREADS`
//!   environment variable, falling back to
//!   `std::thread::available_parallelism()`, and is fixed for the life of
//!   the process. The matmul kernels (`crate::tensor`), the fused
//!   dequant-matmul (`crate::model`), the quantization pipeline — the
//!   calibration window fan-out and per-layer fan-out
//!   (`crate::coordinator::pipeline`) plus the GPTQ/RPIQ row-sharded
//!   inner loops (`crate::quant`) — and the serving batcher's group
//!   forwards all draw from this one pool — nothing else in the crate
//!   spawns compute threads. (The serve engine keeps `lanes` dedicated
//!   *event-loop* threads, which block on the sharded request queue and
//!   must not occupy pool workers; all of their compute is submitted
//!   here.)
//! * **Shard count vs worker count.** [`num_threads`] is the *target
//!   shard count* data-parallel helpers split work into. It defaults to
//!   the worker count and can be changed at runtime with [`set_threads`]
//!   (used by the bench thread-sweeps and the determinism tests); shards
//!   beyond the worker count simply queue, so any setting is safe.
//! * **Determinism guarantee.** Every parallel helper in this crate
//!   shards work so that each worker owns a *disjoint* slice of the output
//!   and performs the same floating-point operations in the same order as
//!   the sequential code. Results are therefore **bit-identical** for any
//!   thread count, including 1 — asserted by the matmul bit-equality tests
//!   and the pipeline Γ-trace determinism test.
//! * **Nested parallelism is deadlock-free.** [`ThreadPool::scope`] does
//!   not idle-block while waiting for its jobs: the waiting thread *helps*,
//!   popping queued jobs and running them inline. A pool worker that forks
//!   a nested scope (e.g. a layer-quantization job calling a parallel
//!   matmul) therefore always makes progress even when every worker is
//!   blocked in a scope.
//! * **Panics are contained.** A panicking job never kills a worker; the
//!   pool counts it ([`ThreadPool::panicked_jobs`]) and keeps serving.
//!   A panic inside a scoped job is re-raised on the thread that opened
//!   the scope, after all sibling jobs finished (so borrowed shards are
//!   never dangling).
//!
//! The queue is a shared `Mutex<VecDeque>` with condvars. On the 1-core CI
//! machine contention is irrelevant; on larger machines the shard sizes
//! chosen by the kernels (rows per worker) amortize queue traffic.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A queued job plus the identity of the scope that spawned it (0 = plain
/// `submit`). The id lets a thread joining a scope distinguish *its own*
/// shard work (genuine caller time) from jobs it merely helps with while
/// waiting — the basis of the exclusive-time accounting in
/// [`helped_secs`].
struct Queued {
    job: Job,
    scope_id: usize,
}

struct PoolShared {
    queue: Mutex<VecDeque<Queued>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    idle: Condvar,
    panicked_jobs: AtomicUsize,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `n` workers (minimum 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            idle: Condvar::new(),
            panicked_jobs: AtomicUsize::new(0),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rpiq-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers, size: n }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Jobs that panicked (and were contained) so far.
    pub fn panicked_jobs(&self) -> usize {
        self.shared.panicked_jobs.load(Ordering::SeqCst)
    }

    fn enqueue(&self, job: Job, scope_id: usize) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Queued { job, scope_id });
        self.shared.available.notify_one();
    }

    /// Submit a fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.enqueue(Box::new(f), 0);
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while !q.is_empty() || self.shared.in_flight.load(Ordering::SeqCst) > 0 {
            let (guard, _) = self
                .shared
                .idle
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap();
            q = guard;
        }
    }

    /// Fork-join over borrowed data: run `f` with a [`Scope`] whose
    /// [`Scope::spawn`] accepts non-`'static` closures, then wait for every
    /// spawned job before returning. This is what lets the matmul kernels
    /// hand disjoint `&mut` row chunks of one output buffer to the pool.
    ///
    /// The waiting thread does not sleep while jobs are pending — it pops
    /// queued pool jobs and runs them inline ("help-first" join), which
    /// makes nested scopes on a finite pool deadlock-free.
    ///
    /// If a scoped job panics, the panic is re-raised here after all
    /// sibling jobs have completed.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'_, 'env>) -> R,
    {
        let scope = Scope {
            pool: self,
            state: Arc::new(ScopeState {
                id: SCOPE_IDS.fetch_add(1, Ordering::Relaxed),
                pending: Mutex::new(0),
                done: Condvar::new(),
                panic_payload: Mutex::new(None),
            }),
            _env: PhantomData,
        };
        // Wait on drop, so that even a panic inside `f` cannot let borrowed
        // shard jobs outlive the data they reference.
        struct WaitGuard<'a> {
            pool: &'a ThreadPool,
            state: Arc<ScopeState>,
        }
        impl Drop for WaitGuard<'_> {
            fn drop(&mut self) {
                self.pool.help_until_done(&self.state);
            }
        }
        let guard = WaitGuard { pool: self, state: Arc::clone(&scope.state) };
        let out = f(&scope);
        drop(guard);
        // Re-raise the first job panic with its original payload so the
        // real message/location survives the pool hop.
        let payload = scope.state.panic_payload.lock().unwrap().take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
        out
    }

    /// Run a batch of closures on the pool and collect their results in
    /// order. Closures may borrow from the caller's stack (the pipeline
    /// fans per-layer quantization jobs out with borrowed calibration
    /// state). Panics in any job propagate after all jobs finish.
    ///
    /// Observable parallelism is the minimum of the global shard target
    /// ([`num_threads`]) and this pool's worker count: that many runner
    /// jobs pull from one work list, and an effective count of 1 runs
    /// everything inline on the calling thread — which is what makes
    /// `set_threads(1)` a true single-threaded baseline for the bench
    /// sweeps and the pipeline determinism tests.
    pub fn map<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let runners = num_threads().min(self.size).min(n);
        if runners <= 1 {
            // Inline path keeps the parallel path's contract: every job
            // runs (a panic doesn't skip the rest), panics are counted,
            // and the first payload re-raises after the batch.
            let mut first_panic: Option<Box<dyn std::any::Any + Send>> = None;
            let mut out = Vec::with_capacity(n);
            for job in jobs {
                match catch_unwind(AssertUnwindSafe(job)) {
                    Ok(v) => out.push(v),
                    Err(p) => {
                        self.shared.panicked_jobs.fetch_add(1, Ordering::SeqCst);
                        if first_panic.is_none() {
                            first_panic = Some(p);
                        }
                    }
                }
            }
            if let Some(p) = first_panic {
                std::panic::resume_unwind(p);
            }
            return out;
        }
        let work: Mutex<Vec<(usize, F)>> =
            Mutex::new(jobs.into_iter().enumerate().rev().collect());
        let mut out: Vec<Option<T>> = Vec::new();
        out.resize_with(n, || None);
        {
            let results = Mutex::new(&mut out);
            let work_ref = &work;
            let results_ref = &results;
            self.scope(|s| {
                for _ in 0..runners {
                    s.spawn(move || loop {
                        let next = work_ref.lock().unwrap().pop();
                        match next {
                            Some((i, job)) => {
                                let v = job();
                                results_ref.lock().unwrap()[i] = Some(v);
                            }
                            None => break,
                        }
                    });
                }
            });
        }
        out.into_iter().map(|o| o.expect("scoped job ran")).collect()
    }

    /// Drive queued jobs until `state.pending` hits zero.
    fn help_until_done(&self, state: &ScopeState) {
        loop {
            if *state.pending.lock().unwrap() == 0 {
                return;
            }
            let queued = self.shared.queue.lock().unwrap().pop_front();
            match queued {
                Some(q) if q.scope_id == state.id => {
                    // One of this scope's own shard jobs: running it inline
                    // IS the caller's work — no helped accounting.
                    run_one(&self.shared, q.job);
                }
                Some(q) => {
                    // A foreign job stolen while waiting: attribute its wall
                    // time to this thread's helped counter so timers stay
                    // exclusive. Setting (not adding) `before + elapsed`
                    // keeps nested help sites from double-counting — inner
                    // increments are contained in this site's window.
                    let before = HELPED_SECS.with(|c| c.get());
                    let t0 = Instant::now();
                    run_one(&self.shared, q.job);
                    HELPED_SECS.with(|c| c.set(before + t0.elapsed().as_secs_f64()));
                }
                None => {
                    // Our jobs are running on other threads; sleep until one
                    // finishes (short timeout as belt-and-braces — new help
                    // opportunities can appear in the queue meanwhile).
                    let pending = state.pending.lock().unwrap();
                    if *pending == 0 {
                        return;
                    }
                    let _ = state
                        .done
                        .wait_timeout(pending, Duration::from_millis(1))
                        .unwrap();
                }
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run one queued job with panic containment and in-flight bookkeeping.
fn run_one(shared: &PoolShared, job: Job) {
    // Span per task on the running thread's timeline: worker imbalance and
    // help-while-waiting nesting show up as gaps/stacking per tid. The
    // guard's drop emits the End even when the job panics.
    let task = crate::trace::span("exec", "task");
    if catch_unwind(AssertUnwindSafe(job)).is_err() {
        shared.panicked_jobs.fetch_add(1, Ordering::SeqCst);
    }
    drop(task);
    shared.in_flight.fetch_sub(1, Ordering::SeqCst);
    shared.idle.notify_all();
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let queued = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(queued) = q.pop_front() {
                    break queued;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        run_one(&shared, queued.job);
    }
}

thread_local! {
    /// Monotonic seconds this thread has spent inline-running *other*
    /// jobs while waiting in a scope join (help-first work stealing).
    static HELPED_SECS: std::cell::Cell<f64> = const { std::cell::Cell::new(0.0) };
}

/// Snapshot of this thread's helped-time counter (seconds). Only *foreign*
/// jobs count — a thread inline-running its own scope's shard jobs is doing
/// its own work, not helping. Subtract two snapshots to get the time a
/// window spent on stolen jobs; the stage timers use this to report
/// *exclusive* durations even when a waiting worker helps with an
/// unrelated layer's job.
pub fn helped_secs() -> f64 {
    HELPED_SECS.with(|c| c.get())
}

/// Monotonically increasing scope identities (0 is reserved for plain
/// `submit` jobs).
static SCOPE_IDS: AtomicUsize = AtomicUsize::new(1);

struct ScopeState {
    /// Identity used to tag this scope's jobs in the queue (see [`Queued`]).
    id: usize,
    pending: Mutex<usize>,
    done: Condvar,
    /// First panic payload from any job of this scope, re-raised at join.
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// Handle passed to the closure of [`ThreadPool::scope`]; invariant over
/// `'env` so a scope cannot be smuggled into a longer-lived context.
pub struct Scope<'pool, 'env> {
    pool: &'pool ThreadPool,
    state: Arc<ScopeState>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'pool, 'env> Scope<'pool, 'env> {
    /// Spawn a job that may borrow data alive for `'env`.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        *self.state.pending.lock().unwrap() += 1;
        let state = Arc::clone(&self.state);
        let shared = Arc::clone(&self.pool.shared);
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(f)) {
                // counted here because the re-raise wrapper below means
                // run_one's own catch never sees scoped-job panics
                shared.panicked_jobs.fetch_add(1, Ordering::SeqCst);
                let mut slot = state.panic_payload.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            let mut pending = state.pending.lock().unwrap();
            *pending -= 1;
            if *pending == 0 {
                state.done.notify_all();
            }
        });
        // SAFETY: `scope` does not return — even if its closure panics,
        // via the wait-on-drop guard — until `pending` reaches zero, i.e.
        // until this job has run to completion. Every borrow captured by
        // `f` therefore outlives the job, so erasing `'env` to `'static`
        // for the queue's benefit cannot be observed.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
        };
        self.pool.enqueue(job, self.state.id);
    }
}

// ---------------------------------------------------------------------------
// Process-global pool.
// ---------------------------------------------------------------------------

/// Worker count the global pool is created with: `RPIQ_THREADS` if set to a
/// positive integer, else `available_parallelism`, else 1.
///
/// A set-but-rejected `RPIQ_THREADS` (unparsable, zero, or non-unicode)
/// logs a one-line warning naming the rejected value before falling back —
/// a silently ignored override would make a determinism matrix run
/// (`RPIQ_THREADS=1/2/8`) measure the wrong configuration.
pub fn default_threads() -> usize {
    let fallback = || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    match std::env::var("RPIQ_THREADS") {
        Ok(v) => match parse_threads(&v) {
            Some(n) => n,
            None => {
                crate::trace::log(&format!(
                    "rpiq: ignoring RPIQ_THREADS={v:?} (want a positive integer); \
                     falling back to available parallelism"
                ));
                fallback()
            }
        },
        Err(std::env::VarError::NotUnicode(raw)) => {
            crate::trace::log(&format!(
                "rpiq: ignoring non-unicode RPIQ_THREADS={raw:?}; \
                 falling back to available parallelism"
            ));
            fallback()
        }
        Err(std::env::VarError::NotPresent) => fallback(),
    }
}

/// Parse an `RPIQ_THREADS` value: a positive integer (surrounding
/// whitespace tolerated), else `None`.
fn parse_threads(v: &str) -> Option<usize> {
    v.trim().parse::<usize>().ok().filter(|&n| n >= 1)
}

/// The process-global pool, created on first use. Worker count is fixed at
/// creation (see [`default_threads`]); [`set_threads`] changes only the
/// shard target used by the data-parallel helpers. Lock-free after
/// initialization — this sits on every parallel kernel's dispatch path.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: std::sync::OnceLock<ThreadPool> = std::sync::OnceLock::new();
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

/// Target shard count for data-parallel helpers; 0 = "not yet resolved".
static TARGET_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Current target shard count for data-parallel helpers (matmul row
/// sharding, per-layer fan-out). Defaults to [`default_threads`].
pub fn num_threads() -> usize {
    match TARGET_THREADS.load(Ordering::Relaxed) {
        0 => {
            let n = default_threads();
            // if a concurrent set_threads won the race, honour its value
            match TARGET_THREADS.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => n,
                Err(cur) => cur,
            }
        }
        n => n,
    }
}

/// Override the shard target (benches sweep this; tests pin it to prove
/// bit-identical results across thread counts). Values above the pool's
/// worker count are allowed — excess shards queue. Clamped to ≥ 1.
pub fn set_threads(n: usize) {
    TARGET_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Test support: serializes tests that mutate the global shard target so
/// their exact-value assertions cannot race (results are bit-identical at
/// any target, but `num_threads()` readbacks are not). Panic-poisoning is
/// ignored deliberately.
#[doc(hidden)]
pub fn thread_target_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Bounded MPMC channel with blocking send/recv and timeout recv — the
/// backpressure primitive of the serving coordinator: when the queue is
/// full, producers (request ingestion) block, which is exactly the
/// backpressure behaviour the batcher tests assert.
pub struct Channel<T> {
    inner: Arc<ChannelInner<T>>,
}

struct ChannelInner<T> {
    buf: Mutex<ChannelBuf<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct ChannelBuf<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Channel { inner: Arc::clone(&self.inner) }
    }
}

impl<T> std::fmt::Debug for Channel<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Channel")
            .field("len", &self.len())
            .field("cap", &self.inner.cap)
            .finish()
    }
}

/// Error returned when sending on a closed channel.
#[derive(Debug, PartialEq)]
pub struct SendError;

impl<T> Channel<T> {
    pub fn bounded(cap: usize) -> Self {
        Channel {
            inner: Arc::new(ChannelInner {
                buf: Mutex::new(ChannelBuf { items: VecDeque::new(), closed: false }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                cap: cap.max(1),
            }),
        }
    }

    /// Blocking send; returns Err if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), SendError> {
        let mut buf = self.inner.buf.lock().unwrap();
        while buf.items.len() >= self.inner.cap {
            if buf.closed {
                return Err(SendError);
            }
            buf = self.inner.not_full.wait(buf).unwrap();
        }
        if buf.closed {
            return Err(SendError);
        }
        buf.items.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking send attempt. Ok(false) = full.
    pub fn try_send(&self, item: T) -> Result<bool, SendError> {
        let mut buf = self.inner.buf.lock().unwrap();
        if buf.closed {
            return Err(SendError);
        }
        if buf.items.len() >= self.inner.cap {
            return Ok(false);
        }
        buf.items.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(true)
    }

    /// Blocking receive; None when channel is closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut buf = self.inner.buf.lock().unwrap();
        loop {
            if let Some(item) = buf.items.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if buf.closed {
                return None;
            }
            buf = self.inner.not_empty.wait(buf).unwrap();
        }
    }

    /// Receive with deadline; None on timeout or closed-and-empty. Used by
    /// the dynamic batcher to implement the max-wait batching window.
    pub fn recv_timeout(&self, dur: Duration) -> Option<T> {
        let deadline = Instant::now() + dur;
        let mut buf = self.inner.buf.lock().unwrap();
        loop {
            if let Some(item) = buf.items.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if buf.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _res) = self
                .inner
                .not_empty
                .wait_timeout(buf, deadline - now)
                .unwrap();
            buf = guard;
        }
    }

    /// Drain up to `max` items without blocking (batch pickup).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut buf = self.inner.buf.lock().unwrap();
        let n = buf.items.len().min(max);
        let out: Vec<T> = buf.items.drain(..n).collect();
        if n > 0 {
            self.inner.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.buf.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the channel: senders fail, receivers drain then get None.
    pub fn close(&self) {
        let mut buf = self.inner.buf.lock().unwrap();
        buf.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

/// Sharded bounded MPMC queue — the request substrate of the multi-lane
/// serving engine. Capacity is *global* (backpressure engages when the sum
/// of all shards reaches `cap`, matching the single-queue semantics the
/// server tests assert), but storage and wakeups are per-shard:
///
/// * [`ShardedQueue::push`] round-robins items across shards, so no single
///   shard's lock or condvar serializes ingestion;
/// * [`ShardedQueue::pop`] drains the caller's own shard first and *steals*
///   from sibling shards (FIFO within each shard) when its own is empty, so
///   an idle lane absorbs a busy lane's backlog instead of sleeping;
/// * a lane that finds every shard empty parks on its own shard's
///   condvar in slices, re-scanning siblings between them. Slices start
///   at 2 ms (snappy steals under load) and back off exponentially to
///   64 ms when idle so quiet lanes do not spin; each deposit notifies
///   the owning shard *and one sibling*, so under load a steal normally
///   happens via wakeup, and the backoff slice is only the fallback
///   bound (worst-case steal latency ≈ 64 ms when every notified lane is
///   busy). With a single shard the park uses the caller's full timeout.
///
/// Close semantics mirror [`Channel`]: after [`ShardedQueue::close`],
/// pushes fail with [`SendError`] and pops drain the remaining items, so a
/// shutting-down server answers everything already accepted.
pub struct ShardedQueue<T> {
    inner: Arc<ShardedInner<T>>,
}

struct ShardedInner<T> {
    shards: Vec<QueueShard<T>>,
    /// Global occupancy + closed flag; producers wait on `not_full`.
    occupancy: Mutex<Occupancy>,
    not_full: Condvar,
    cap: usize,
    /// Round-robin cursor for push.
    next: AtomicUsize,
}

struct Occupancy {
    len: usize,
    closed: bool,
}

struct QueueShard<T> {
    items: Mutex<VecDeque<T>>,
    not_empty: Condvar,
}

impl<T> Clone for ShardedQueue<T> {
    fn clone(&self) -> Self {
        ShardedQueue { inner: Arc::clone(&self.inner) }
    }
}

impl<T> ShardedQueue<T> {
    /// `shards` lanes (min 1) sharing one global capacity `cap` (min 1).
    pub fn new(shards: usize, cap: usize) -> Self {
        let shards = shards.max(1);
        ShardedQueue {
            inner: Arc::new(ShardedInner {
                shards: (0..shards)
                    .map(|_| QueueShard {
                        items: Mutex::new(VecDeque::new()),
                        not_empty: Condvar::new(),
                    })
                    .collect(),
                occupancy: Mutex::new(Occupancy { len: 0, closed: false }),
                not_full: Condvar::new(),
                cap: cap.max(1),
                next: AtomicUsize::new(0),
            }),
        }
    }

    pub fn shards(&self) -> usize {
        self.inner.shards.len()
    }

    /// Reserve one slot of global capacity, blocking while full.
    fn reserve(&self) -> Result<(), SendError> {
        let mut occ = self.inner.occupancy.lock().unwrap();
        while occ.len >= self.inner.cap {
            if occ.closed {
                return Err(SendError);
            }
            occ = self.inner.not_full.wait(occ).unwrap();
        }
        if occ.closed {
            return Err(SendError);
        }
        occ.len += 1;
        Ok(())
    }

    /// Deposit an item into the next round-robin shard (capacity already
    /// reserved).
    fn deposit(&self, item: T) {
        let n = self.inner.shards.len();
        let s = self.inner.next.fetch_add(1, Ordering::Relaxed) % n;
        let shard = &self.inner.shards[s];
        shard.items.lock().unwrap().push_back(item);
        shard.not_empty.notify_one();
        if n > 1 {
            // Also wake one sibling so an idle lane deep in its backoff
            // slice can steal promptly while the owner lane is busy. A
            // wakeup racing the sibling's pre-wait window may be lost —
            // benign: the backoff slice timeout re-scans all shards.
            self.inner.shards[(s + 1) % n].not_empty.notify_one();
        }
    }

    /// Blocking push; round-robins across shards. Blocks while the queue
    /// holds `cap` items (global backpressure); fails once closed.
    pub fn push(&self, item: T) -> Result<(), SendError> {
        self.reserve()?;
        self.deposit(item);
        Ok(())
    }

    /// Non-blocking push attempt. `Ok(false)` = full.
    pub fn try_push(&self, item: T) -> Result<bool, SendError> {
        {
            let mut occ = self.inner.occupancy.lock().unwrap();
            if occ.closed {
                return Err(SendError);
            }
            if occ.len >= self.inner.cap {
                return Ok(false);
            }
            occ.len += 1;
        }
        self.deposit(item);
        Ok(true)
    }

    /// Pop for lane `lane`: own shard first, then steal from siblings;
    /// parks in short slices when everything is empty. `None` on timeout
    /// or when closed and drained.
    pub fn pop(&self, lane: usize, timeout: Duration) -> Option<T> {
        let n = self.inner.shards.len();
        let lane = lane % n;
        let deadline = Instant::now() + timeout;
        let mut idle_rounds: u32 = 0;
        loop {
            for k in 0..n {
                let shard = &self.inner.shards[(lane + k) % n];
                let item = shard.items.lock().unwrap().pop_front();
                if let Some(item) = item {
                    let mut occ = self.inner.occupancy.lock().unwrap();
                    occ.len -= 1;
                    drop(occ);
                    self.inner.not_full.notify_one();
                    return Some(item);
                }
            }
            {
                let occ = self.inner.occupancy.lock().unwrap();
                if occ.closed && occ.len == 0 {
                    return None;
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            // Park on the own shard only. With siblings, cap the slice so
            // items deposited into shards whose condvars we are not
            // waiting on are still observed — starting at 2 ms for snappy
            // steals under load, backing off exponentially (to 64 ms)
            // when idle so a quiet multi-lane server does not spin. With
            // a single shard every push signals this condvar, so sleep
            // the full timeout.
            let slice = if n == 1 {
                deadline - now
            } else {
                let backoff = Duration::from_millis(2).saturating_mul(1 << idle_rounds.min(5));
                (deadline - now).min(backoff)
            };
            idle_rounds += 1;
            let guard = self.inner.shards[lane].items.lock().unwrap();
            if guard.is_empty() {
                // Re-check closed while holding the shard lock: `close`
                // notifies this condvar only after taking the same lock,
                // so a close landing after this check cannot slip between
                // it and the wait below (no lost wakeup).
                if self.inner.occupancy.lock().unwrap().closed {
                    continue;
                }
                let _ = self.inner.shards[lane].not_empty.wait_timeout(guard, slice).unwrap();
            }
        }
    }

    /// Items currently queued across all shards.
    pub fn len(&self) -> usize {
        self.inner.occupancy.lock().unwrap().len
    }

    /// Items currently queued in one shard (`shard` taken modulo the shard
    /// count). A momentary gauge for observability — the serve loop emits
    /// it as a per-lane queue-depth counter track.
    pub fn shard_len(&self, shard: usize) -> usize {
        let n = self.inner.shards.len();
        self.inner.shards[shard % n].items.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.inner.occupancy.lock().unwrap().closed
    }

    /// Close: pushes fail, pops drain then return `None`.
    pub fn close(&self) {
        self.inner.occupancy.lock().unwrap().closed = true;
        self.inner.not_full.notify_all();
        for shard in &self.inner.shards {
            // Notify under the shard lock: a popper that checked `closed`
            // before this close is either already waiting (gets the
            // notification) or still holds the shard lock (will observe
            // `closed` on its next pass) — never in between.
            let _guard = shard.items.lock().unwrap();
            shard.not_empty.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parse_threads_accepts_positive_integers_only() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 8 "), Some(8));
        assert_eq!(parse_threads("1"), Some(1));
        // rejected values fall back (and default_threads warns on stderr)
        assert_eq!(parse_threads("0"), None);
        assert_eq!(parse_threads(""), None);
        assert_eq!(parse_threads("two"), None);
        assert_eq!(parse_threads("-2"), None);
        assert_eq!(parse_threads("4.0"), None);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<_> = (0..20).map(|i| move || i * i).collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn pool_map_jobs_may_borrow() {
        // The scope-based map accepts non-'static closures: jobs read a
        // stack-local slice and return values derived from it.
        let pool = ThreadPool::new(2);
        let data: Vec<u64> = (0..32).collect();
        let jobs: Vec<_> = data
            .chunks(8)
            .map(|chunk| move || chunk.iter().sum::<u64>())
            .collect();
        let sums = pool.map(jobs);
        assert_eq!(sums.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("boom"));
        pool.wait_idle();
        assert_eq!(pool.panicked_jobs(), 1);
        // workers are still alive and serving
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn drop_with_queued_work_drains_first() {
        // Shutdown must not drop queued jobs on the floor: workers drain
        // the queue before honouring the shutdown flag.
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(1);
            let c0 = Arc::clone(&counter);
            pool.submit(move || {
                std::thread::sleep(Duration::from_millis(20));
                c0.fetch_add(1, Ordering::SeqCst);
            });
            for _ in 0..15 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop immediately: 1 running + 15 queued
        }
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn scope_shards_borrowed_slice() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u32; 103]; // odd length: uneven final shard
        pool.scope(|s| {
            for (si, chunk) in data.chunks_mut(25).enumerate() {
                s.spawn(move || {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v = (si * 25 + i) as u32;
                    }
                });
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32);
        }
    }

    #[test]
    #[should_panic(expected = "inner boom")]
    fn scope_propagates_job_panic_with_payload() {
        let pool = ThreadPool::new(2);
        pool.scope(|s| {
            s.spawn(|| panic!("inner boom"));
            s.spawn(|| {}); // sibling must still be joined before re-raise
        });
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        // More blocked scopes than workers: without help-while-waiting this
        // deadlocks (every worker blocked joining its own sub-jobs).
        let pool = ThreadPool::new(2);
        let pool_ref = &pool;
        let counter = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            for _ in 0..4 {
                let c = Arc::clone(&counter);
                s.spawn(move || {
                    pool_ref.scope(|inner| {
                        for _ in 0..4 {
                            let c2 = Arc::clone(&c);
                            inner.spawn(move || {
                                c2.fetch_add(1, Ordering::SeqCst);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn scope_result_and_empty_scope() {
        let pool = ThreadPool::new(1);
        let out = pool.scope(|_| 41 + 1);
        assert_eq!(out, 42);
    }

    #[test]
    fn thread_target_knobs() {
        let _guard = thread_target_test_lock();
        assert!(default_threads() >= 1);
        assert!(num_threads() >= 1);
        let before = num_threads();
        set_threads(3);
        assert_eq!(num_threads(), 3);
        set_threads(0); // clamped
        assert_eq!(num_threads(), 1);
        set_threads(before);
        assert_eq!(num_threads(), before);
        // global pool exists and accepts work
        let g = global();
        assert!(g.size() >= 1);
        let n = Arc::new(AtomicUsize::new(0));
        let n2 = Arc::clone(&n);
        g.scope(|s| {
            s.spawn(move || {
                n2.fetch_add(1, Ordering::SeqCst);
            })
        });
        assert_eq!(n.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn channel_fifo() {
        let ch = Channel::bounded(10);
        for i in 0..5 {
            ch.send(i).unwrap();
        }
        let got: Vec<i32> = (0..5).map(|_| ch.recv().unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn channel_backpressure_blocks_then_releases() {
        let ch: Channel<u32> = Channel::bounded(2);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert_eq!(ch.try_send(3), Ok(false)); // full
        let ch2 = ch.clone();
        let t = std::thread::spawn(move || ch2.send(3)); // blocks
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(ch.recv(), Some(1));
        t.join().unwrap().unwrap();
        assert_eq!(ch.recv(), Some(2));
        assert_eq!(ch.recv(), Some(3));
    }

    #[test]
    fn channel_close_semantics() {
        let ch: Channel<u32> = Channel::bounded(4);
        ch.send(7).unwrap();
        ch.close();
        assert_eq!(ch.send(8), Err(SendError));
        assert_eq!(ch.recv(), Some(7)); // drain allowed
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn recv_timeout_times_out() {
        let ch: Channel<u32> = Channel::bounded(1);
        let t0 = Instant::now();
        assert_eq!(ch.recv_timeout(Duration::from_millis(30)), None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn drain_up_to_takes_batch() {
        let ch = Channel::bounded(16);
        for i in 0..10 {
            ch.send(i).unwrap();
        }
        let batch = ch.drain_up_to(4);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(ch.len(), 6);
    }

    #[test]
    fn sharded_queue_single_shard_is_fifo() {
        let q: ShardedQueue<u32> = ShardedQueue::new(1, 8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        let got: Vec<u32> = (0..5).map(|_| q.pop(0, Duration::from_millis(10)).unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert_eq!(q.pop(0, Duration::from_millis(5)), None); // timeout, not closed
    }

    #[test]
    fn sharded_queue_shard_len_tracks_round_robin() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 8);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert_eq!(q.shard_len(0), 2);
        assert_eq!(q.shard_len(1), 2);
        assert_eq!(q.shard_len(3), 2); // taken modulo the shard count
        assert_eq!(q.shard_len(0) + q.shard_len(1), q.len());
    }

    #[test]
    fn sharded_queue_backpressure_engages_at_cap() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 3);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!(q.try_push(4), Ok(false)); // full across shards
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.push(4)); // blocks until a pop
        std::thread::sleep(Duration::from_millis(20));
        assert!(q.pop(0, Duration::from_millis(50)).is_some());
        t.join().unwrap().unwrap();
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn sharded_queue_lane_steals_from_siblings() {
        let q: ShardedQueue<u32> = ShardedQueue::new(4, 16);
        // round-robin spreads these across all 4 shards
        for i in 0..8 {
            q.push(i).unwrap();
        }
        // one lane drains everything, stealing 6 of the 8 from siblings
        let mut got: Vec<u32> = (0..8)
            .map(|_| q.pop(2, Duration::from_millis(50)).unwrap())
            .collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<u32>>());
        assert!(q.is_empty());
    }

    #[test]
    fn sharded_queue_close_fails_push_but_drains_pops() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 8);
        q.push(7).unwrap();
        q.push(8).unwrap();
        q.close();
        assert_eq!(q.push(9), Err(SendError));
        assert_eq!(q.try_push(9), Err(SendError));
        let mut got = vec![
            q.pop(0, Duration::from_millis(10)).unwrap(),
            q.pop(1, Duration::from_millis(10)).unwrap(),
        ];
        got.sort_unstable();
        assert_eq!(got, vec![7, 8]);
        // closed + drained: returns None immediately (no timeout wait)
        let t0 = Instant::now();
        assert_eq!(q.pop(0, Duration::from_secs(5)), None);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-long under the interpreter; covered by the loom model")]
    fn sharded_queue_concurrent_producers_consumers_lose_nothing() {
        let q: ShardedQueue<usize> = ShardedQueue::new(3, 8);
        let total = 300usize;
        let seen = Arc::new(Mutex::new(vec![0usize; total]));
        std::thread::scope(|s| {
            for lane in 0..3 {
                let q = q.clone();
                let seen = Arc::clone(&seen);
                s.spawn(move || loop {
                    match q.pop(lane, Duration::from_millis(20)) {
                        Some(v) => seen.lock().unwrap()[v] += 1,
                        None => {
                            if q.is_closed() && q.is_empty() {
                                return;
                            }
                        }
                    }
                });
            }
            // join all producers before closing so no push can fail
            std::thread::scope(|prod| {
                for p in 0..3 {
                    let q = q.clone();
                    prod.spawn(move || {
                        for i in 0..100 {
                            q.push(p * 100 + i).unwrap();
                        }
                    });
                }
            });
            q.close();
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }
}
