//! Zero-dependency tracing & telemetry: span-instrumented hot paths with
//! Chrome-trace export.
//!
//! The pipeline, the serve engine, and the `exec` pool emit **events**
//! here — span begin/end pairs, instants, counters (ledger bytes, queue
//! depth), and completed ranges — which export as Chrome trace-event JSON
//! (loadable in `chrome://tracing` / <https://ui.perfetto.dev>) plus a
//! self-contained text summary (`rpiq trace summarize`). See
//! rust/DESIGN.md §Observability for the event model and overhead
//! argument.
//!
//! # Design
//!
//! * **Near-zero cost when disabled.** Every emission checks one relaxed
//!   atomic ([`enabled`]) *before* touching names, formatting closures, or
//!   buffers: a disabled span/instant/counter call is a load + branch and
//!   performs **no allocation** (asserted by the disabled-overhead test in
//!   `rust/tests/trace.rs`).
//! * **Thread-local buffers, process-global drain.** Each thread appends
//!   to its own buffer (registered once in a global registry); the hot
//!   path never touches a shared lock, so pool workers helping with
//!   foreign scopes (`exec`'s help-while-waiting join) record their
//!   nested spans on their own timeline without contention. [`take`]
//!   walks the registry and drains every buffer.
//! * **Spans are RAII guards.** [`span`] emits `Begin` and its guard's
//!   `Drop` emits `End` — so trees stay balanced across early returns and
//!   `catch_unwind` (the serve lane loop contains engine panics; the
//!   guard's drop still runs during the unwind).
//! * **Cross-thread ranges** (e.g. a request's queue wait, which starts on
//!   the submitting thread and ends on a lane thread) are emitted as
//!   single `Complete` events with an explicit start timestamp
//!   ([`complete_at`]), sidestepping begin/end pairing across threads.
//!
//! Concurrency: the enable flag and buffers are process-global, so tests
//! that enable tracing must serialize on [`test_lock`] (mirroring
//! `exec::thread_target_test_lock`).

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Event model
// ---------------------------------------------------------------------------

/// Event kind, mirroring the Chrome trace-event phases we emit
/// (`B`/`E`/`i`/`C`/`X`).
#[derive(Clone, Debug, PartialEq)]
pub enum Phase {
    /// Span start (`ph: "B"`); paired with an [`Phase::End`] on the same
    /// thread.
    Begin,
    /// Span end (`ph: "E"`).
    End,
    /// A point event (`ph: "i"`).
    Instant,
    /// A gauge sample (`ph: "C"`): Perfetto renders one counter track per
    /// event name.
    Counter(f64),
    /// A completed range with explicit duration in µs (`ph: "X"`) — used
    /// for cross-thread ranges like a request's queue wait.
    Complete(f64),
}

/// One trace event on one thread's timeline.
#[derive(Clone, Debug)]
pub struct Event {
    pub name: Cow<'static, str>,
    pub cat: Cow<'static, str>,
    pub ph: Phase,
    /// Microseconds since the process trace epoch.
    pub ts_us: f64,
    /// Stable per-thread id assigned at first emission.
    pub tid: u64,
    /// Optional free-form annotation (exported as `args.detail`).
    pub detail: Option<String>,
}

// ---------------------------------------------------------------------------
// Global state: enable flag, epoch, thread-buffer registry
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static REGISTRY: Mutex<Vec<Arc<ThreadBuf>>> = Mutex::new(Vec::new());

struct ThreadBuf {
    tid: u64,
    name: String,
    events: Mutex<Vec<Event>>,
}

thread_local! {
    static BUF: std::cell::OnceCell<Arc<ThreadBuf>> = const { std::cell::OnceCell::new() };
}

fn registry() -> MutexGuard<'static, Vec<Arc<ThreadBuf>>> {
    // A panicking emitter cannot corrupt a Vec push; recover from poison.
    REGISTRY.lock().unwrap_or_else(|e| e.into_inner())
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the trace epoch, now.
fn now_us() -> f64 {
    epoch().elapsed().as_secs_f64() * 1e6
}

/// Microseconds since the trace epoch for an arbitrary [`Instant`]
/// (clamped to 0 for instants taken before the epoch was initialized).
fn instant_us(t: Instant) -> f64 {
    t.checked_duration_since(epoch())
        .map(|d| d.as_secs_f64() * 1e6)
        .unwrap_or(0.0)
}

fn with_buf(f: impl FnOnce(&ThreadBuf)) {
    BUF.with(|cell| {
        let buf = cell.get_or_init(|| {
            let tb = Arc::new(ThreadBuf {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                name: std::thread::current().name().unwrap_or("thread").to_string(),
                events: Mutex::new(Vec::new()),
            });
            registry().push(Arc::clone(&tb));
            tb
        });
        f(buf);
    });
}

fn emit(name: Cow<'static, str>, cat: Cow<'static, str>, ph: Phase, detail: Option<String>) {
    let ts_us = now_us();
    with_buf(|b| {
        b.events
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(Event { name, cat, ph, ts_us, tid: b.tid, detail });
    });
}

// ---------------------------------------------------------------------------
// Public API: enable/disable, emission, collection
// ---------------------------------------------------------------------------

/// Whether tracing is currently collecting. One relaxed load — this is the
/// whole cost of a disabled emission site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Clear every thread buffer and start collecting.
pub fn start() {
    let _ = epoch(); // pin the epoch before the first event
    for b in registry().iter() {
        b.events.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop collecting (buffers are kept for [`take`]).
pub fn stop() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Drain every thread's buffer into one time-sorted [`Trace`].
pub fn take() -> Trace {
    let bufs: Vec<Arc<ThreadBuf>> = registry().iter().cloned().collect();
    let mut events = Vec::new();
    let mut threads = Vec::new();
    for b in &bufs {
        threads.push((b.tid, b.name.clone()));
        events.append(&mut b.events.lock().unwrap_or_else(|e| e.into_inner()));
    }
    // Stable sort: each buffer is already chronological, so same-timestamp
    // events on one thread keep their emission order (Begin before End).
    events.sort_by(|a, b| a.ts_us.total_cmp(&b.ts_us));
    Trace { events, threads }
}

/// [`stop`] + [`take`].
pub fn stop_and_take() -> Trace {
    stop();
    take()
}

/// RAII span: `Begin` at creation, `End` at drop (including during an
/// unwind, which is what keeps span trees balanced across the serve lane
/// loop's `catch_unwind`).
#[must_use = "a span measures until the guard drops"]
pub struct SpanGuard {
    armed: bool,
    cat: Cow<'static, str>,
    name: Cow<'static, str>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        // Emit the End even if tracing was disabled mid-span, so collected
        // trees always balance.
        if self.armed {
            emit(std::mem::take(&mut self.name), std::mem::take(&mut self.cat), Phase::End, None);
        }
    }
}

/// Open a span named `name` under category `cat`.
pub fn span(cat: &'static str, name: impl Into<Cow<'static, str>>) -> SpanGuard {
    if !enabled() {
        return SpanGuard { armed: false, cat: Cow::Borrowed(""), name: Cow::Borrowed("") };
    }
    let name = name.into();
    emit(name.clone(), Cow::Borrowed(cat), Phase::Begin, None);
    SpanGuard { armed: true, cat: Cow::Borrowed(cat), name }
}

/// [`span`] with a lazily-built annotation (the closure runs only when
/// tracing is enabled, so disabled sites pay no formatting cost).
pub fn span_detail(
    cat: &'static str,
    name: impl Into<Cow<'static, str>>,
    detail: impl FnOnce() -> String,
) -> SpanGuard {
    if !enabled() {
        return SpanGuard { armed: false, cat: Cow::Borrowed(""), name: Cow::Borrowed("") };
    }
    let name = name.into();
    emit(name.clone(), Cow::Borrowed(cat), Phase::Begin, Some(detail()));
    SpanGuard { armed: true, cat: Cow::Borrowed(cat), name }
}

/// Emit a point event.
pub fn instant(cat: &'static str, name: impl Into<Cow<'static, str>>) {
    if !enabled() {
        return;
    }
    emit(name.into(), Cow::Borrowed(cat), Phase::Instant, None);
}

/// Emit a gauge sample; Perfetto renders one counter track per `name`
/// (the ledger emits `mem.<tag>` tracks, the serve loop `serve.qdepth`).
pub fn counter(name: impl Into<Cow<'static, str>>, value: f64) {
    if !enabled() {
        return;
    }
    emit(name.into(), Cow::Borrowed("counter"), Phase::Counter(value), None);
}

/// Emit a completed range that *started* at `start` (possibly on another
/// thread) and lasted `dur` — recorded on the calling thread's timeline.
pub fn complete_at(
    cat: &'static str,
    name: impl Into<Cow<'static, str>>,
    start: Instant,
    dur: Duration,
) {
    if !enabled() {
        return;
    }
    let ts_us = instant_us(start);
    with_buf(|b| {
        b.events.lock().unwrap_or_else(|e| e.into_inner()).push(Event {
            name: name.into(),
            cat: Cow::Borrowed(cat),
            ph: Phase::Complete(dur.as_secs_f64() * 1e6),
            ts_us,
            tid: b.tid,
            detail: None,
        });
    });
}

/// The logging facade for non-CLI modules (enforced by the rpiq-lint
/// `print` rule): one stderr line, plus an instant trace event when
/// collecting so operator-facing messages land on the timeline too.
pub fn log(msg: &str) {
    if enabled() {
        emit(Cow::Owned(msg.to_string()), Cow::Borrowed("log"), Phase::Instant, None);
    }
    // The stderr sink itself — `trace/` is the print rule's exempt sink.
    eprintln!("{msg}");
}

/// Test support: serializes tests that enable/collect the process-global
/// trace state (mirrors `exec::thread_target_test_lock`). Panic-poisoning
/// is ignored deliberately.
#[doc(hidden)]
pub fn test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// Collected trace: Chrome export, parse, summary
// ---------------------------------------------------------------------------

/// A drained trace: time-sorted events plus the thread-name table.
pub struct Trace {
    pub events: Vec<Event>,
    /// `(tid, thread name)` for every thread that ever emitted.
    pub threads: Vec<(u64, String)>,
}

fn esc(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

impl Trace {
    /// Count of span-opening events named `name` (Begin or Complete) —
    /// the unit the span-count determinism tests compare.
    pub fn count_spans(&self, name: &str) -> usize {
        self.events
            .iter()
            .filter(|e| e.name == name && matches!(e.ph, Phase::Begin | Phase::Complete(_)))
            .count()
    }

    /// Serialize as Chrome trace-event JSON (`{"traceEvents": [...]}`),
    /// loadable in `chrome://tracing` and <https://ui.perfetto.dev>.
    pub fn to_chrome_json(&self) -> String {
        let mut out = String::with_capacity(64 + self.events.len() * 96);
        out.push_str("{\"traceEvents\":[");
        let mut first = true;
        let mut push_sep = |out: &mut String| {
            if !first {
                out.push(',');
            }
            first = false;
            out.push('\n');
        };
        for (tid, name) in &self.threads {
            push_sep(&mut out);
            out.push_str(&format!(
                "{{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":1,\"tid\":{tid},\"args\":{{\"name\":\""
            ));
            esc(name, &mut out);
            out.push_str("\"}}");
        }
        for e in &self.events {
            push_sep(&mut out);
            out.push_str("{\"name\":\"");
            esc(&e.name, &mut out);
            out.push_str("\",\"cat\":\"");
            esc(&e.cat, &mut out);
            out.push_str("\",\"ph\":\"");
            match &e.ph {
                Phase::Begin => out.push('B'),
                Phase::End => out.push('E'),
                Phase::Instant => out.push('i'),
                Phase::Counter(_) => out.push('C'),
                Phase::Complete(_) => out.push('X'),
            }
            out.push_str(&format!("\",\"ts\":{:.3},\"pid\":1,\"tid\":{}", e.ts_us, e.tid));
            match &e.ph {
                Phase::Instant => out.push_str(",\"s\":\"t\""),
                Phase::Complete(dur) => out.push_str(&format!(",\"dur\":{dur:.3}")),
                Phase::Counter(v) => {
                    out.push_str(&format!(",\"args\":{{\"value\":{v}}}"));
                }
                _ => {}
            }
            if let Some(d) = &e.detail {
                out.push_str(",\"args\":{\"detail\":\"");
                esc(d, &mut out);
                out.push_str("\"}");
            }
            out.push('}');
        }
        out.push_str("\n]}\n");
        out
    }

    /// Aggregate into the per-phase table (errors on unbalanced trees).
    pub fn summary(&self) -> Result<TraceSummary, String> {
        summarize(&self.events)
    }
}

/// Parse a Chrome trace-event JSON file (either the `{"traceEvents":[…]}`
/// object or a bare event array) back into a [`Trace`]. Malformed input —
/// bad JSON, a missing `ph`/`ts`/`name`, an unknown phase — is an error,
/// which is what lets `rpiq trace summarize` gate CI on trace integrity.
pub fn parse_chrome(text: &str) -> Result<Trace, String> {
    let root = crate::jsonx::Json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let arr = root
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .or_else(|| root.as_arr())
        .ok_or("expected a traceEvents array")?;
    let mut events = Vec::new();
    let mut threads = Vec::new();
    for (i, ev) in arr.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(|p| p.as_str())
            .ok_or_else(|| format!("event {i}: missing \"ph\""))?;
        let tid = ev.get("tid").and_then(|t| t.as_f64()).unwrap_or(0.0) as u64;
        if ph == "M" {
            if ev.get("name").and_then(|n| n.as_str()) == Some("thread_name") {
                if let Some(n) = ev.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str())
                {
                    threads.push((tid, n.to_string()));
                }
            }
            continue;
        }
        let name = ev
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| format!("event {i}: missing \"name\""))?
            .to_string();
        let cat = ev.get("cat").and_then(|c| c.as_str()).unwrap_or("").to_string();
        let ts_us = ev
            .get("ts")
            .and_then(|t| t.as_f64())
            .ok_or_else(|| format!("event {i} ({name}): missing \"ts\""))?;
        let phase = match ph {
            "B" => Phase::Begin,
            "E" => Phase::End,
            "i" | "I" => Phase::Instant,
            "X" => Phase::Complete(ev.get("dur").and_then(|d| d.as_f64()).unwrap_or(0.0)),
            "C" => {
                let v = ev
                    .get("args")
                    .and_then(|a| a.get("value"))
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("event {i} ({name}): counter without args.value"))?;
                Phase::Counter(v)
            }
            other => return Err(format!("event {i} ({name}): unknown phase {other:?}")),
        };
        let detail = ev
            .get("args")
            .and_then(|a| a.get("detail"))
            .and_then(|d| d.as_str())
            .map(|s| s.to_string());
        events.push(Event {
            name: Cow::Owned(name),
            cat: Cow::Owned(cat),
            ph: phase,
            ts_us,
            tid,
            detail,
        });
    }
    Ok(Trace { events, threads })
}

/// Aggregate of one span name within one category.
#[derive(Clone, Debug)]
pub struct SpanAgg {
    pub cat: String,
    pub name: String,
    pub count: u64,
    pub total_ms: f64,
    pub max_ms: f64,
}

/// Aggregate of one counter track.
#[derive(Clone, Debug)]
pub struct CounterAgg {
    pub name: String,
    pub peak: f64,
    pub last: f64,
    pub samples: u64,
}

/// Per-phase totals of a trace (what `rpiq trace summarize` prints).
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    pub spans: Vec<SpanAgg>,
    /// `(name, count)` of instant events.
    pub instants: Vec<(String, u64)>,
    pub counters: Vec<CounterAgg>,
}

/// Aggregate events into per-(cat, name) span totals, instant counts, and
/// counter peaks. Errors on unbalanced span trees (an `End` without a
/// matching `Begin`, mismatched nesting, or spans left open), so feeding a
/// truncated or corrupted trace through `rpiq trace summarize` fails.
pub fn summarize(events: &[Event]) -> Result<TraceSummary, String> {
    let mut order: Vec<&Event> = events.iter().collect();
    order.sort_by(|a, b| a.ts_us.total_cmp(&b.ts_us));
    let mut stacks: BTreeMap<u64, Vec<(&Event, f64)>> = BTreeMap::new();
    let mut spans: BTreeMap<(String, String), (u64, f64, f64)> = BTreeMap::new();
    let mut instants: BTreeMap<String, u64> = BTreeMap::new();
    let mut counters: BTreeMap<String, (f64, f64, u64)> = BTreeMap::new();
    let mut add_span = |cat: &str, name: &str, dur_ms: f64| {
        let e = spans.entry((cat.to_string(), name.to_string())).or_insert((0, 0.0, 0.0));
        e.0 += 1;
        e.1 += dur_ms;
        e.2 = e.2.max(dur_ms);
    };
    for ev in order {
        match &ev.ph {
            Phase::Begin => stacks.entry(ev.tid).or_default().push((ev, ev.ts_us)),
            Phase::End => {
                let (open, t0) = stacks
                    .entry(ev.tid)
                    .or_default()
                    .pop()
                    .ok_or_else(|| format!("tid {}: end of {:?} without a begin", ev.tid, ev.name))?;
                if open.name != ev.name {
                    return Err(format!(
                        "tid {}: mismatched span nesting (begin {:?}, end {:?})",
                        ev.tid, open.name, ev.name
                    ));
                }
                add_span(&open.cat, &open.name, (ev.ts_us - t0) / 1e3);
            }
            Phase::Complete(dur_us) => add_span(&ev.cat, &ev.name, dur_us / 1e3),
            Phase::Instant => *instants.entry(ev.name.to_string()).or_insert(0) += 1,
            Phase::Counter(v) => {
                let e = counters.entry(ev.name.to_string()).or_insert((f64::MIN, 0.0, 0));
                e.0 = e.0.max(*v);
                e.1 = *v;
                e.2 += 1;
            }
        }
    }
    for (tid, stack) in &stacks {
        if let Some((open, _)) = stack.last() {
            return Err(format!(
                "tid {tid}: {} span(s) left open (innermost: {:?})",
                stack.len(),
                open.name
            ));
        }
    }
    let mut spans: Vec<SpanAgg> = spans
        .into_iter()
        .map(|((cat, name), (count, total_ms, max_ms))| SpanAgg {
            cat,
            name,
            count,
            total_ms,
            max_ms,
        })
        .collect();
    spans.sort_by(|a, b| b.total_ms.total_cmp(&a.total_ms));
    Ok(TraceSummary {
        spans,
        instants: instants.into_iter().collect(),
        counters: counters
            .into_iter()
            .map(|(name, (peak, last, samples))| CounterAgg { name, peak, last, samples })
            .collect(),
    })
}

impl TraceSummary {
    /// Totals of one span name (summed across categories) — what the
    /// summarize CLI test checks against the in-process trace.
    pub fn span_total_ms(&self, name: &str) -> f64 {
        self.spans.iter().filter(|s| s.name == name).map(|s| s.total_ms).sum()
    }

    /// Render the per-phase tables as text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut t = crate::report::Table::new(
            "Trace summary — spans (per phase)",
            &["cat", "name", "count", "total ms", "mean ms", "max ms"],
        );
        for s in &self.spans {
            t.row(vec![
                s.cat.clone(),
                s.name.clone(),
                s.count.to_string(),
                format!("{:.2}", s.total_ms),
                format!("{:.3}", s.total_ms / s.count.max(1) as f64),
                format!("{:.2}", s.max_ms),
            ]);
        }
        out.push_str(&t.render());
        if !self.counters.is_empty() {
            let mut t = crate::report::Table::new(
                "Trace summary — counters",
                &["name", "peak", "last", "samples"],
            );
            for c in &self.counters {
                t.row(vec![
                    c.name.clone(),
                    format!("{:.0}", c.peak),
                    format!("{:.0}", c.last),
                    c.samples.to_string(),
                ]);
            }
            out.push_str(&t.render());
        }
        if !self.instants.is_empty() {
            let mut t =
                crate::report::Table::new("Trace summary — instants", &["name", "count"]);
            for (name, n) in &self.instants {
                t.row(vec![name.clone(), n.to_string()]);
            }
            out.push_str(&t.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_and_balance() {
        let _guard = test_lock();
        start();
        {
            let _outer = span("test", "outer");
            {
                let _inner = span("test", "inner");
            }
            instant("test", "tick");
            counter("test.gauge", 42.0);
        }
        let t = stop_and_take();
        assert_eq!(t.count_spans("outer"), 1);
        assert_eq!(t.count_spans("inner"), 1);
        let s = t.summary().expect("balanced");
        assert_eq!(s.instants, vec![("tick".to_string(), 1)]);
        assert_eq!(s.counters.len(), 1);
        assert!((s.counters[0].peak - 42.0).abs() < 1e-12);
        // inner is contained in outer
        let outer = s.spans.iter().find(|x| x.name == "outer").unwrap();
        let inner = s.spans.iter().find(|x| x.name == "inner").unwrap();
        assert!(outer.total_ms >= inner.total_ms);
    }

    #[test]
    fn guard_drop_balances_across_unwind() {
        let _guard = test_lock();
        start();
        let r = std::panic::catch_unwind(|| {
            let _s = span("test", "doomed");
            panic!("boom");
        });
        assert!(r.is_err());
        let t = stop_and_take();
        t.summary().expect("the guard's drop emitted the End during the unwind");
        assert_eq!(t.count_spans("doomed"), 1);
    }

    #[test]
    fn disabled_emission_is_a_noop() {
        let _guard = test_lock();
        stop();
        let _ = take(); // drain leftovers
        {
            let _s = span("test", "nope");
            instant("test", "nope");
            counter("test.nope", 1.0);
            complete_at("test", "nope", Instant::now(), Duration::from_millis(1));
            let _d = span_detail("test", "nope", || unreachable!("lazy detail must not run"));
        }
        assert!(take().events.is_empty());
    }

    #[test]
    fn chrome_json_round_trips_through_parse() {
        let _guard = test_lock();
        start();
        {
            let _s = span_detail("test", "phase \"a\"", || "layer\n0".to_string());
            counter("test.bytes", 123.0);
            instant("test", "mark");
        }
        complete_at("test", "range", Instant::now(), Duration::from_micros(250));
        let t = stop_and_take();
        let json = t.to_chrome_json();
        let back = parse_chrome(&json).expect("parse our own export");
        assert_eq!(back.events.len(), t.events.len());
        assert!(!back.threads.is_empty(), "thread_name metadata survives");
        let (a, b) = (t.summary().unwrap(), back.summary().unwrap());
        assert_eq!(a.spans.len(), b.spans.len());
        for (x, y) in a.spans.iter().zip(b.spans.iter()) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.count, y.count);
            assert!((x.total_ms - y.total_ms).abs() < 1e-2, "{}", x.name);
        }
        assert_eq!(a.counters.len(), b.counters.len());
    }

    #[test]
    fn summarize_rejects_malformed_traces() {
        assert!(parse_chrome("not json").is_err());
        assert!(parse_chrome("{\"traceEvents\": 3}").is_err());
        // end without begin
        let text = r#"{"traceEvents":[
            {"name":"x","cat":"t","ph":"E","ts":1.0,"pid":1,"tid":1}
        ]}"#;
        let t = parse_chrome(text).unwrap();
        assert!(t.summary().is_err());
        // begin left open
        let text = r#"{"traceEvents":[
            {"name":"x","cat":"t","ph":"B","ts":1.0,"pid":1,"tid":1}
        ]}"#;
        assert!(parse_chrome(text).unwrap().summary().is_err());
        // mismatched nesting
        let text = r#"{"traceEvents":[
            {"name":"a","cat":"t","ph":"B","ts":1.0,"pid":1,"tid":1},
            {"name":"b","cat":"t","ph":"E","ts":2.0,"pid":1,"tid":1}
        ]}"#;
        assert!(parse_chrome(text).unwrap().summary().is_err());
        // unknown phase
        let text = r#"{"traceEvents":[
            {"name":"a","cat":"t","ph":"Q","ts":1.0,"pid":1,"tid":1}
        ]}"#;
        assert!(parse_chrome(text).is_err());
    }

    #[test]
    fn cross_thread_events_land_on_own_timelines() {
        let _guard = test_lock();
        start();
        let main_span = span("test", "main");
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    let _s = span("test", "worker");
                });
            }
        });
        drop(main_span);
        let t = stop_and_take();
        let s = t.summary().expect("per-thread trees balance");
        let worker = s.spans.iter().find(|x| x.name == "worker").unwrap();
        assert_eq!(worker.count, 2);
        let tids: std::collections::BTreeSet<u64> = t
            .events
            .iter()
            .filter(|e| e.name == "worker")
            .map(|e| e.tid)
            .collect();
        assert_eq!(tids.len(), 2, "each worker on its own tid");
    }

    #[test]
    fn log_emits_instant_when_enabled() {
        let _guard = test_lock();
        start();
        log("hello from the facade");
        let t = stop_and_take();
        let s = t.summary().unwrap();
        assert_eq!(s.instants.iter().filter(|(n, _)| n.contains("facade")).count(), 1);
        // and is pure stderr when disabled
        log("disabled: no event");
        assert!(take().events.is_empty());
    }
}
