//! Multi-lane serving engine: a workload-generic router + dynamic batcher
//! over quantized models — the deployment story the paper motivates (an
//! assistive device answering sentiment *and* VQA-style queries under a
//! memory budget, at heavy mixed traffic).
//!
//! Architecture (vLLM-router-like, scaled to this repo):
//!
//! * workloads are [`Payload`] variants answered by [`LaneEngine`]s — the
//!   built-ins are [`SentimentLane`] (token prompts through a
//!   [`QuantizedLm`]) and [`VqaLane`] ((patches, question) pairs through a
//!   [`QuantizedVlm`]'s batched forward); custom engines plug in via
//!   [`Server::start_engines`];
//! * producers call [`Server::submit`] (global-capacity
//!   [`ShardedQueue`] ⇒ natural backpressure at `queue_cap`; submission
//!   round-robins across shards);
//! * **N batcher lanes** (`ServeConfig::lanes` event-loop threads) each
//!   drain their own shard — and *steal from sibling shards when idle* —
//!   so p95 is no longer bound by one pickup loop; each lane fills a
//!   batch within `max_wait`, partitions it by (engine, shape key), and
//!   runs the groups — several groups in one pickup fan out as scoped
//!   pool jobs, each delivering its replies as soon as it finishes;
//! * inside an engine, equal-shape requests fuse into one batched forward,
//!   and very large equal-shape groups are sharded row-wise across the
//!   global pool explicitly (`WIDE_GROUP_ROWS` in `crate::model`);
//! * latency (queue + compute) is recorded per request into per-lane
//!   [`LaneStats`];
//! * memory is accounted on a server-owned [`MemoryLedger`]: callers
//!   register the deployed models' resident bytes
//!   (`QuantizedLm::register_resident`, tag `model_resident`) and each
//!   lane books its dominant transient under `activations.<lane>` for the
//!   duration of the batch, so the ledger's peak is `resident + max
//!   concurrent activations` and per-lane activation peaks print beside
//!   the latency stats at shutdown;
//! * the built-in lanes serve in **row-select** mode
//!   ([`crate::model::RowSelect::LastRow`]): the answer head runs only
//!   over the rows the lane reads and attention streams key blocks with
//!   an online softmax, so the booked transient is the model's
//!   [`QuantizedLm::serve_transient_bytes`] — `O(B·V + B·S·d)`, never the
//!   full `[B·S, V]` logits;
//! * an optional **activation budget** ([`ServeConfig::activation_budget`])
//!   caps each lane's concurrent transients: single requests that cannot
//!   ever fit are rejected at submit ([`SubmitError::OverBudget`], counted
//!   in [`LaneStats`]), fused groups that would overshoot are split into
//!   budget-fitting sub-batches, and admission into the cap is arbitrated
//!   through [`MemoryLedger::try_alloc`] so concurrent lanes cannot
//!   jointly overshoot their own caps.
//!
//! Threading: lanes are dedicated event-loop threads (they block on the
//! request queue, so parking them on pool workers would starve the pool).
//! All compute runs on the shared global pool (`crate::exec`): each fused
//! forward's dequant-matmuls shard rows there, wide groups chunk there,
//! and multi-engine pickups fan out there.

// Request-path module: non-test code must stay panic-free. The repo lint
// (`rpiq-lint`, rule `no-panic`) and these clippy denies enforce it.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used, clippy::panic))]
#![cfg_attr(not(test), deny(clippy::indexing_slicing))]

use crate::data::tokenizer::Tokenizer;
use crate::data::SentimentSet;
use crate::exec::{Channel, ShardedQueue};
use crate::metrics::{LaneStats, MemoryLedger};
use crate::model::{QuantizedLm, RowSelect};
use crate::tensor::Tensor;
use crate::vlm::QuantizedVlm;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Name of the sentiment lane in [`LaneStats`].
pub const LANE_SENTIMENT: &str = "sentiment";
/// Name of the VQA lane in [`LaneStats`].
pub const LANE_VQA: &str = "vqa";

/// One unit of work a lane can batch.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Classify the sentiment of a tokenized prompt.
    Sentiment { tokens: Vec<u32> },
    /// Answer a question about an image (`patches: [n_patches, patch_dim]`).
    Vqa { patches: Tensor, question: Vec<u32> },
}

/// A lane's answer to one payload.
#[derive(Clone, Debug)]
pub enum Answer {
    /// Predicted label index + logits of the three label tokens.
    Sentiment { label: usize, label_logits: [f32; 3] },
    /// Argmax answer token over the full vocabulary, decoded.
    Vqa { answer_id: u32, answer: String },
}

/// Response delivered on the per-request reply channel.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub answer: Answer,
    pub latency: Duration,
}

impl Response {
    /// Sentiment label, if this was a sentiment request.
    pub fn label(&self) -> Option<usize> {
        match &self.answer {
            Answer::Sentiment { label, .. } => Some(*label),
            _ => None,
        }
    }

    /// Decoded VQA answer word, if this was a VQA request.
    pub fn vqa_answer(&self) -> Option<&str> {
        match &self.answer {
            Answer::Vqa { answer, .. } => Some(answer.as_str()),
            _ => None,
        }
    }
}

/// A queued request: payload + routing + reply channel (capacity 1).
pub struct Request {
    pub id: u64,
    pub payload: Payload,
    /// Index into the server's engine list, resolved at submit.
    engine: usize,
    pub reply: Channel<Response>,
    pub enqueued: Instant,
}

impl Drop for Request {
    fn drop(&mut self) {
        // Close the reply channel so a client blocked in `recv` observes a
        // dropped request (`None` ⇒ `SubmitError::Closed`) instead of
        // hanging forever — e.g. when an engine panics and its group is
        // discarded. After a successful delivery the close is harmless:
        // `Channel` lets the receiver drain a closed channel.
        self.reply.close();
    }
}

/// Why a submission was rejected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The server is shutting down (queue closed) or dropped the request.
    Closed,
    /// No registered lane accepts this payload kind.
    Unsupported,
    /// The payload is malformed for its lane (e.g. patch-shape mismatch).
    Invalid(String),
    /// The request alone books more transient-activation bytes than its
    /// lane's [`ServeConfig::activation_budget`] — it could never be
    /// admitted, so it is rejected at submit instead of deadlocking a lane.
    OverBudget { needed: usize, cap: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Closed => write!(f, "server closed"),
            SubmitError::Unsupported => write!(f, "no lane accepts this payload"),
            SubmitError::Invalid(why) => write!(f, "invalid payload: {why}"),
            SubmitError::OverBudget { needed, cap } => write!(
                f,
                "request books {needed} transient bytes, over the lane's {cap}-byte activation budget"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A batchable workload: one of these per modality the server offers.
/// Engines are pure batch functions — delivery, latency accounting, and
/// wide-group fan-out are handled generically by the lane loop.
pub trait LaneEngine: Send + Sync {
    /// Lane name used for per-lane stats (e.g. [`LANE_SENTIMENT`]).
    fn name(&self) -> &'static str;

    /// Whether this lane answers `payload`.
    fn accepts(&self, payload: &Payload) -> bool;

    /// Normalize/validate a payload at submit time (before it is queued);
    /// e.g. left-truncate over-long prompts to the model context.
    fn prepare(&self, _payload: &mut Payload) -> Result<(), SubmitError> {
        Ok(())
    }

    /// Shape key for fusion: payloads of one pickup with equal keys are
    /// answered by one `run_batch` call (one fused forward) and delivered
    /// together; distinct keys run — and deliver — independently, so a
    /// short request never waits on a long group's compute.
    fn shape_key(&self, _payload: &Payload) -> usize {
        0
    }

    /// Answer a drained group of payloads (all accepted by this lane,
    /// all sharing one shape key), one answer per item, in order.
    fn run_batch(&self, group: &[&Payload]) -> Vec<Answer>;

    /// Dominant transient-activation bytes of answering `group` in one
    /// fused forward (the logits tensor at these model scales). The lane
    /// loop books this on the server ledger under `activations.<name>`
    /// for the duration of the batch; return 0 to opt out of accounting.
    fn transient_bytes(&self, _group: &[&Payload]) -> usize {
        0
    }
}

/// Sentiment lane: fuses equal-length token prompts into batched
/// quantized forwards (same chunk/fan-out skeleton as
/// [`QuantizedLm::forward_batch`], reading answer rows in place).
pub struct SentimentLane {
    model: Arc<QuantizedLm>,
    label_ids: [u32; 3],
    max_seq: usize,
}

impl SentimentLane {
    pub fn new(model: Arc<QuantizedLm>, tok: &Tokenizer) -> Self {
        let label_ids = SentimentSet::label_token_ids(tok);
        let max_seq = model.config().seq_len;
        SentimentLane { model, label_ids, max_seq }
    }
}

impl LaneEngine for SentimentLane {
    fn name(&self) -> &'static str {
        LANE_SENTIMENT
    }

    fn accepts(&self, payload: &Payload) -> bool {
        matches!(payload, Payload::Sentiment { .. })
    }

    fn prepare(&self, payload: &mut Payload) -> Result<(), SubmitError> {
        let Payload::Sentiment { tokens } = payload else {
            return Err(SubmitError::Unsupported);
        };
        if tokens.is_empty() {
            return Err(SubmitError::Invalid("empty prompt".into()));
        }
        // left-truncate, keeping the answer scaffold at the end
        if tokens.len() > self.max_seq {
            let cut = tokens.len() - self.max_seq;
            tokens.drain(..cut);
        }
        Ok(())
    }

    fn shape_key(&self, payload: &Payload) -> usize {
        match payload {
            Payload::Sentiment { tokens } => tokens.len(),
            _ => 0,
        }
    }

    fn transient_bytes(&self, group: &[&Payload]) -> usize {
        // Row-select serving: the dominant transients are the selected-row
        // logits `[B, V]` plus the widest per-layer activation `[B·S, d]`
        // (the full `[B·S, V]` logits are never built). Groups share one
        // shape key, so every prompt here has the max length.
        let seq = group
            .iter()
            .map(|p| match p {
                Payload::Sentiment { tokens } => tokens.len(),
                _ => 0,
            })
            .max()
            .unwrap_or(0);
        self.model.serve_transient_bytes(group.len(), seq)
    }

    fn run_batch(&self, group: &[&Payload]) -> Vec<Answer> {
        let mut seqs: Vec<&[u32]> = Vec::with_capacity(group.len());
        for p in group {
            match p {
                Payload::Sentiment { tokens } => seqs.push(tokens.as_slice()),
                // Misrouted payload (impossible by construction): return a
                // short answer vector so the lane loop's count check drops
                // the group cleanly instead of poisoning the lane.
                _ => return Vec::new(),
            }
        }
        // The lane loop groups by shape key, so all sequences here share
        // one length: fuse each chunk into one row-select forward
        // ([`RowSelect::LastRow`]) — the head matmul runs only over the
        // answer rows and attention streams key blocks, so the transient
        // is `[B, V]` logits plus `O(S·chunk)` scores, never `[B·S, V]`.
        let Some(seq) = seqs.first().map(|s| s.len()) else {
            return Vec::new();
        };
        debug_assert!(seqs.iter().all(|s| s.len() == seq), "mixed shapes in one group");
        let answers = crate::model::quantized::run_equal_shape_groups(seqs.len(), |_| 0, |chunk| {
            let mut tokens = Vec::with_capacity(chunk.len() * seq);
            for s in chunk.iter().filter_map(|&i| seqs.get(i)) {
                tokens.extend_from_slice(s);
            }
            let logits =
                self.model.forward_rows(&tokens, chunk.len(), seq, RowSelect::LastRow)?;
            Ok((0..chunk.len())
                .map(|gi| {
                    let last = logits.row(gi);
                    let mut ll = [f32::NEG_INFINITY; 3];
                    for (dst, &id) in ll.iter_mut().zip(self.label_ids.iter()) {
                        *dst = last.get(id as usize).copied().unwrap_or(f32::NEG_INFINITY);
                    }
                    // Total order over f32: a NaN logit degrades this one
                    // answer instead of killing the group via catch_unwind.
                    let label = ll
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i)
                        .unwrap_or(0);
                    Answer::Sentiment { label, label_logits: ll }
                })
                .collect())
        });
        match answers {
            Ok(a) => a,
            // A forward error (e.g. shape mismatch) surfaces as a short
            // answer vector — the lane loop's count check drops the group
            // cleanly instead of poisoning the lane.
            Err(e) => {
                crate::trace::log(&format!("sentiment lane batch failed: {e:#}"));
                Vec::new()
            }
        }
    }
}

/// VQA lane: fuses equal-length (patches, question) pairs into batched
/// quantized forwards (same chunk/fan-out skeleton as
/// [`QuantizedVlm::forward_batch`], reading answer rows in place) — the
/// paper's assistive workload as a first-class batched lane.
pub struct VqaLane {
    model: Arc<QuantizedVlm>,
    tok: Tokenizer,
}

impl VqaLane {
    pub fn new(model: Arc<QuantizedVlm>, tok: &Tokenizer) -> Self {
        VqaLane { model, tok: tok.clone() }
    }
}

impl LaneEngine for VqaLane {
    fn name(&self) -> &'static str {
        LANE_VQA
    }

    fn accepts(&self, payload: &Payload) -> bool {
        matches!(payload, Payload::Vqa { .. })
    }

    fn prepare(&self, payload: &mut Payload) -> Result<(), SubmitError> {
        let Payload::Vqa { patches, question } = payload else {
            return Err(SubmitError::Unsupported);
        };
        let cfg = self.model.config();
        if patches.rows() != cfg.n_patches || patches.cols() != cfg.patch_dim {
            return Err(SubmitError::Invalid(format!(
                "patches {:?}, model expects [{}, {}]",
                patches.shape(),
                cfg.n_patches,
                cfg.patch_dim
            )));
        }
        if question.is_empty() {
            return Err(SubmitError::Invalid("empty question".into()));
        }
        // left-truncate over-long questions, keeping the answer scaffold
        let text_len = cfg.text_len();
        if question.len() > text_len {
            let cut = question.len() - text_len;
            question.drain(..cut);
        }
        Ok(())
    }

    fn shape_key(&self, payload: &Payload) -> usize {
        match payload {
            Payload::Vqa { question, .. } => question.len(),
            _ => 0,
        }
    }

    fn transient_bytes(&self, group: &[&Payload]) -> usize {
        // Row-select serving: selected-row logits `[B, V]` plus the widest
        // per-layer activation over the fused `[B·(P + T), ·]` sequence —
        // see [`QuantizedVlm::serve_transient_bytes`]. One shape key ⇒ one
        // question length, so the max is the common length.
        let qlen = group
            .iter()
            .map(|p| match p {
                Payload::Vqa { question, .. } => question.len(),
                _ => 0,
            })
            .max()
            .unwrap_or(0);
        self.model.serve_transient_bytes(group.len(), qlen)
    }

    fn run_batch(&self, group: &[&Payload]) -> Vec<Answer> {
        let mut pairs: Vec<(&Tensor, &[u32])> = Vec::with_capacity(group.len());
        for p in group {
            match p {
                Payload::Vqa { patches, question } => pairs.push((patches, question.as_slice())),
                // Misrouted payload (impossible by construction): a short
                // answer vector makes the lane loop drop the group cleanly.
                _ => return Vec::new(),
            }
        }
        // Equal shape key ⇒ equal question length: stack each chunk into
        // one fused row-select forward ([`RowSelect::LastRow`]) — only the
        // answer rows reach the vocab head, so the transient is `[B, V]`
        // logits plus streamed `O(S·chunk)` attention scores.
        let cfg = self.model.config();
        let n_patches = cfg.n_patches;
        // prepare() validated every patches tensor against the config, so
        // the patch dim comes from the config rather than the group.
        let pd = cfg.patch_dim;
        let Some(tlen) = pairs.first().map(|(_, q)| q.len()) else {
            return Vec::new();
        };
        debug_assert!(pairs.iter().all(|(_, q)| q.len() == tlen), "mixed shapes in one group");
        let answers = crate::model::quantized::run_equal_shape_groups(pairs.len(), |_| 0, |chunk| {
            let b = chunk.len();
            let mut pdata = Vec::with_capacity(b * n_patches * pd);
            let mut text = Vec::with_capacity(b * tlen);
            for (p, q) in chunk.iter().filter_map(|&i| pairs.get(i)) {
                pdata.extend_from_slice(p.data());
                text.extend_from_slice(q);
            }
            let patches = Tensor::from_vec(&[b * n_patches, pd], pdata);
            let logits = self.model.forward_rows(&patches, &text, b, RowSelect::LastRow)?;
            Ok((0..b)
                .map(|gi| {
                    let last = logits.row(gi);
                    // Total order over f32 (see the sentiment argmax).
                    let pred = last
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i)
                        .unwrap_or(0) as u32;
                    Answer::Vqa { answer_id: pred, answer: self.tok.word(pred).to_string() }
                })
                .collect())
        });
        match answers {
            Ok(a) => a,
            // Same clean group drop as the sentiment lane: errors become a
            // short answer vector, never a lane-thread panic.
            Err(e) => {
                crate::trace::log(&format!("vqa lane batch failed: {e:#}"));
                Vec::new()
            }
        }
    }
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Global queue capacity (backpressure bound across all shards).
    pub queue_cap: usize,
    /// Max requests one lane fuses into one pickup.
    pub max_batch: usize,
    /// Max time a lane waits to fill a batch.
    pub max_wait: Duration,
    /// Number of batcher lanes (event-loop threads / queue shards).
    pub lanes: usize,
    /// Per-lane transient-activation budget in bytes. When set, each
    /// lane's `activations.<lane>` ledger tag is capped at this value:
    /// submissions whose single-request transient exceeds it are rejected
    /// ([`SubmitError::OverBudget`]), fused groups are split into
    /// budget-fitting sub-batches, and lanes block admission (never the
    /// ledger math) until their concurrent bookings fit. `None` disables
    /// enforcement — the ledger still observes, it just never gates.
    pub activation_budget: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_cap: 256,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            lanes: 2,
            activation_budget: None,
        }
    }
}

/// The serving coordinator: submit side + N batcher lanes over a sharded
/// queue of [`Request`]s, answered by registered [`LaneEngine`]s.
pub struct Server {
    queue: ShardedQueue<Request>,
    engines: Arc<Vec<Box<dyn LaneEngine>>>,
    next_id: AtomicU64,
    pub stats: LaneStats,
    /// Memory accounting for the serving process: model-resident bytes
    /// (registered by the caller) + per-lane transient activations
    /// (booked by the lane loop around each fused batch).
    ledger: MemoryLedger,
    /// Copied from [`ServeConfig::activation_budget`]; checked per request
    /// at submit so over-cap payloads never reach a lane.
    activation_budget: Option<usize>,
    lanes: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start a server from an explicit engine list — the generic core the
    /// typed constructors (and the serve tests' synthetic engines) use.
    #[allow(clippy::expect_used)] // lane-thread spawn failure is unrecoverable
    pub fn start_engines(engines: Vec<Box<dyn LaneEngine>>, cfg: ServeConfig) -> Self {
        // LINT-ALLOW(no-panic): construction-time invariant, checked before
        // any request exists — misconfiguration should fail loudly at startup.
        assert!(!engines.is_empty(), "server needs at least one lane engine");
        let n_lanes = cfg.lanes.max(1);
        let queue: ShardedQueue<Request> = ShardedQueue::new(n_lanes, cfg.queue_cap);
        let stats = LaneStats::new();
        let ledger = MemoryLedger::new();
        let engines = Arc::new(engines);
        if let Some(cap) = cfg.activation_budget {
            // Cap every lane's transient tag up front — lanes gate their
            // bookings through `try_alloc`, so the budget binds from the
            // first request.
            for e in engines.iter() {
                ledger.set_budget(&crate::metrics::tags::activations(e.name()), cap);
            }
        }
        let lanes = (0..n_lanes)
            .map(|i| {
                let queue = queue.clone();
                let stats = stats.clone();
                let ledger = ledger.clone();
                let engines = Arc::clone(&engines);
                std::thread::Builder::new()
                    .name(format!("rpiq-lane-{i}"))
                    .spawn(move || lane_loop(i, engines, queue, stats, ledger, cfg))
                    // LINT-ALLOW(no-panic): thread-spawn failure at server
                    // construction is unrecoverable resource exhaustion.
                    .expect("spawn lane")
            })
            .collect();
        Server {
            queue,
            engines,
            next_id: AtomicU64::new(0),
            stats,
            ledger,
            activation_budget: cfg.activation_budget,
            lanes,
        }
    }

    /// The server's memory ledger. Register deployed models' resident
    /// bytes here (`register_resident`) before replaying traffic; the
    /// lanes add their transient activations, so `peak_bytes()` reads as
    /// the serving process's high-water mark and
    /// `peak_for("activations.<lane>")` as one lane's transient peak.
    pub fn ledger(&self) -> &MemoryLedger {
        &self.ledger
    }

    /// Sentiment-only server over a quantized LM.
    pub fn start(model: Arc<QuantizedLm>, tok: &Tokenizer, cfg: ServeConfig) -> Self {
        Self::start_engines(vec![Box::new(SentimentLane::new(model, tok))], cfg)
    }

    /// VQA-only server over a quantized VLM.
    pub fn start_vqa(model: Arc<QuantizedVlm>, tok: &Tokenizer, cfg: ServeConfig) -> Self {
        Self::start_engines(vec![Box::new(VqaLane::new(model, tok))], cfg)
    }

    /// Mixed-traffic server: sentiment and VQA lanes side by side.
    pub fn start_mixed(
        lm: Arc<QuantizedLm>,
        vlm: Arc<QuantizedVlm>,
        tok: &Tokenizer,
        cfg: ServeConfig,
    ) -> Self {
        Self::start_engines(
            vec![
                Box::new(SentimentLane::new(lm, tok)),
                Box::new(VqaLane::new(vlm, tok)),
            ],
            cfg,
        )
    }

    fn make_request(&self, mut payload: Payload) -> Result<Request, SubmitError> {
        let engine = self
            .engines
            .iter()
            .position(|e| e.accepts(&payload))
            .ok_or(SubmitError::Unsupported)?;
        let lane = self.engines.get(engine).ok_or(SubmitError::Unsupported)?;
        lane.prepare(&mut payload)?;
        if let Some(cap) = self.activation_budget {
            // A request that alone overshoots its lane's budget can never
            // be admitted (sub-batches are at least one request): reject
            // here instead of letting a lane spin on it forever.
            let needed = lane.transient_bytes(&[&payload]);
            if needed > cap {
                return Err(SubmitError::OverBudget { needed, cap });
            }
        }
        let reply = Channel::bounded(1);
        Ok(Request {
            id: self.next_id.fetch_add(1, Ordering::SeqCst),
            payload,
            engine,
            reply,
            enqueued: Instant::now(),
        })
    }

    /// Count a rejected submission in the stats before handing the error
    /// back — rejections never reach a lane, so this is their only trace.
    fn reject(&self, e: SubmitError) -> SubmitError {
        self.stats.record_reject(match &e {
            SubmitError::Closed => crate::metrics::RejectKind::Closed,
            SubmitError::Unsupported => crate::metrics::RejectKind::Unsupported,
            SubmitError::Invalid(_) => crate::metrics::RejectKind::Invalid,
            SubmitError::OverBudget { .. } => crate::metrics::RejectKind::OverBudget,
        });
        e
    }

    /// Submit a payload; blocks while the queue holds `queue_cap` requests
    /// (backpressure). Returns the reply channel, or an error when the
    /// server is closed / the payload has no lane.
    pub fn submit(&self, payload: Payload) -> Result<Channel<Response>, SubmitError> {
        let req = self.make_request(payload).map_err(|e| self.reject(e))?;
        let reply = req.reply.clone();
        self.queue.push(req).map_err(|_| self.reject(SubmitError::Closed))?;
        Ok(reply)
    }

    /// Non-blocking submit attempt: `Ok(None)` when the queue is full
    /// (backpressure, not a rejection — it is not counted as one).
    pub fn try_submit(&self, payload: Payload) -> Result<Option<Channel<Response>>, SubmitError> {
        let req = self.make_request(payload).map_err(|e| self.reject(e))?;
        let reply = req.reply.clone();
        match self.queue.try_push(req) {
            Ok(true) => Ok(Some(reply)),
            Ok(false) => Ok(None),
            Err(_) => Err(self.reject(SubmitError::Closed)),
        }
    }

    /// Submit a sentiment prompt (compat shim for token-based callers).
    pub fn submit_tokens(&self, tokens: Vec<u32>) -> Result<Channel<Response>, SubmitError> {
        self.submit(Payload::Sentiment { tokens })
    }

    /// Submit a sentiment prompt and wait for the answer.
    pub fn classify(&self, tokens: Vec<u32>) -> Result<Response, SubmitError> {
        self.submit_tokens(tokens)?.recv().ok_or(SubmitError::Closed)
    }

    /// Submit a VQA pair and wait for the answer.
    pub fn ask(&self, patches: Tensor, question: Vec<u32>) -> Result<Response, SubmitError> {
        self.submit(Payload::Vqa { patches, question })?
            .recv()
            .ok_or(SubmitError::Closed)
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Number of batcher lanes.
    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Stop accepting new requests; lanes drain what is already queued.
    /// Subsequent submits fail with [`SubmitError::Closed`].
    pub fn close(&self) {
        self.queue.close();
    }

    /// Close, drain every pending request across every lane, and join.
    pub fn shutdown(mut self) -> LaneStats {
        self.queue.close();
        for l in self.lanes.drain(..) {
            let _ = l.join();
        }
        self.stats.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        for l in self.lanes.drain(..) {
            let _ = l.join();
        }
    }
}

/// One batcher lane: drain shard `lane` (stealing when idle), fill a batch
/// within the wait window, partition by engine, run the groups, deliver.
fn lane_loop(
    lane: usize,
    engines: Arc<Vec<Box<dyn LaneEngine>>>,
    queue: ShardedQueue<Request>,
    stats: LaneStats,
    ledger: MemoryLedger,
    cfg: ServeConfig,
) {
    // Per-engine ledger tags, precomputed once — the lane loop is the
    // serving hot path and engines are fixed for the server's lifetime.
    let activation_tags: Vec<String> = engines
        .iter()
        .map(|e| crate::metrics::tags::activations(e.name()))
        .collect();
    loop {
        // Block for the first request. Shutdown wakes the pop directly
        // (`close` notifies every shard condvar), so this timeout is only
        // a belt-and-braces re-check and can be long — an idle lane wakes
        // a handful of times per second, not hundreds.
        let first = match queue.pop(lane, Duration::from_millis(200)) {
            Some(r) => r,
            None => {
                if queue.is_closed() && queue.is_empty() {
                    return;
                }
                continue;
            }
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match queue.pop(lane, deadline - now) {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        // Partition the pickup by (engine, shape key); order within a
        // group preserved. Each group is one fused forward delivered as
        // soon as it finishes — a short prompt in the same pickup as a
        // long group does not wait for it.
        let mut groups: Vec<((usize, usize), Vec<Request>)> = Vec::new();
        for r in batch {
            // `r.engine` was resolved by submit() against this fixed
            // engine set; if it ever weren't, dropping `r` closes its
            // reply channel and the client observes `Closed`.
            let Some(engine) = engines.get(r.engine) else {
                continue;
            };
            let key = (r.engine, engine.shape_key(&r.payload));
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, g)) => g.push(r),
                None => groups.push((key, vec![r])),
            }
        }
        // Queue-depth gauges at pickup: the lane's own shard plus the
        // global occupancy, so Perfetto shows where backlog accumulates.
        if crate::trace::enabled() {
            crate::trace::counter(format!("serve.qdepth.lane{lane}"), queue.shard_len(lane) as f64);
            crate::trace::counter("serve.qdepth", queue.len() as f64);
        }
        let run_group = |ei: usize, group: &[Request]| {
            let (Some(engine), Some(tag)) = (engines.get(ei), activation_tags.get(ei)) else {
                return; // unreachable: `ei` indexes the fixed engine set
            };
            let picked = Instant::now();
            if crate::trace::enabled() {
                // One cross-thread range per request: enqueue→pickup. The
                // submit happened on a client thread, so this is emitted as
                // a Complete event with an explicit start timestamp.
                for r in group {
                    crate::trace::complete_at(
                        "serve",
                        "req.queue_wait",
                        r.enqueued,
                        picked.saturating_duration_since(r.enqueued),
                    );
                }
            }
            // Partition the group into contiguous sub-batches whose booked
            // transient fits the lane's activation budget (the whole group
            // when unbudgeted or already fitting). Submit-time rejection
            // guarantees every single request fits, so each sub-batch holds
            // at least one request and the partition always terminates.
            let cap = ledger.budget_for(tag);
            let mut start = 0usize;
            while start < group.len() {
                let mut end = group.len();
                if let Some(cap) = cap {
                    end = start + 1;
                    while end < group.len() {
                        let fits = group.get(start..end + 1).is_some_and(|rs| {
                            let pl: Vec<&Payload> = rs.iter().map(|r| &r.payload).collect();
                            engine.transient_bytes(&pl) <= cap
                        });
                        if !fits {
                            break;
                        }
                        end += 1;
                    }
                }
                let Some(sub) = group.get(start..end) else {
                    return; // unreachable: start < end ≤ group.len()
                };
                start = end;
                stats.record_batch(engine.name(), sub.len());
                let payloads: Vec<&Payload> = sub.iter().map(|r| &r.payload).collect();
                // Book the sub-batch's dominant transient for the duration
                // of the forward, per lane, so the ledger's peak reflects
                // resident + concurrent activations — and, when budgeted,
                // wait for admission so concurrent bookings under one tag
                // never jointly overshoot the cap.
                let transient = engine.transient_bytes(&payloads);
                let batch_span = crate::trace::span_detail("serve", "batch", || {
                    format!("{} n={}", engine.name(), sub.len())
                });
                if cap.is_some_and(|c| transient <= c) {
                    // Every holder of this tag frees its booking after a
                    // finite forward, so admission always makes progress.
                    while ledger.try_alloc(tag, transient).is_err() {
                        std::thread::sleep(Duration::from_micros(100));
                    }
                } else {
                    // Unbudgeted — or oversized despite the submit-time
                    // check (a custom engine's transient grew after
                    // prepare): book unconditionally rather than deadlock
                    // the lane; the ledger still observes the overshoot.
                    ledger.alloc(tag, transient);
                }
                // Contain engine bugs: on a panic (or a miscounted answer
                // vector) the sub-batch is discarded and each Request's
                // Drop closes its reply channel, so clients observe
                // `Closed` instead of hanging and the lane keeps serving.
                // The transient is freed outside catch_unwind so a
                // panicking engine cannot leak ledger bytes.
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    engine.run_batch(&payloads)
                }));
                ledger.free(tag, transient);
                drop(batch_span);
                let answers = match result {
                    Ok(a) if a.len() == sub.len() => a,
                    Ok(_) | Err(_) => {
                        // The whole sub-batch died (engine panic /
                        // miscounted answers): count it so lost requests
                        // are visible in the heartbeat and final report.
                        stats.record_drop(engine.name(), sub.len());
                        crate::trace::instant("serve", "group.dropped");
                        continue;
                    }
                };
                for (r, a) in sub.iter().zip(answers) {
                    let latency = r.enqueued.elapsed();
                    let queue_wait = picked.saturating_duration_since(r.enqueued);
                    let service = latency.saturating_sub(queue_wait);
                    stats.record_split(
                        engine.name(),
                        queue_wait.as_secs_f64(),
                        service.as_secs_f64(),
                    );
                    if crate::trace::enabled() {
                        crate::trace::complete_at("serve", "req.service", picked, service);
                    }
                    let _ = r.reply.send(Response { id: r.id, answer: a, latency });
                }
            }
        };
        if let [((ei, _), g)] = groups.as_slice() {
            // single group: run inline (its fused matmuls still shard rows
            // on the pool)
            run_group(*ei, g);
        } else {
            // several (engine, shape) groups in one pickup: fan them out
            // across the shared pool, each delivering independently
            let run_ref = &run_group;
            crate::exec::global().scope(|s| {
                for ((ei, _), g) in &groups {
                    s.spawn(move || run_ref(*ei, g));
                }
            });
        }
    }
}

/// Convenience for benches: replay sentiment prompts through the server
/// from `n_clients` producer threads; returns throughput (req/s).
pub fn replay(server: &Server, tok: &Tokenizer, prompts: &[String], n_clients: usize) -> f64 {
    let items: Vec<Payload> = prompts
        .iter()
        .map(|p| Payload::Sentiment { tokens: tok.encode(p) })
        .collect();
    replay_mixed(server, items, n_clients)
}

/// Replay arbitrary payloads (mixed sentiment + VQA traffic) from
/// `n_clients` producer threads, waiting for every answer; returns
/// throughput (req/s). Panics if the server rejects or drops a request —
/// replay is only meaningful on a live server.
#[allow(clippy::expect_used)] // bench harness: a dead server must abort the measurement
pub fn replay_mixed(server: &Server, items: Vec<Payload>, n_clients: usize) -> f64 {
    let n = items.len();
    let n_clients = n_clients.max(1);
    let mut per_client: Vec<Vec<Payload>> = (0..n_clients).map(|_| Vec::new()).collect();
    for (i, it) in items.into_iter().enumerate() {
        if let Some(c) = per_client.get_mut(i % n_clients) {
            c.push(it);
        }
    }
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for chunk in per_client {
            let server = &*server;
            scope.spawn(move || {
                for p in chunk {
                    // LINT-ALLOW(no-panic): replay is only meaningful on a
                    // live server; a rejected request must fail the bench.
                    let reply = server.submit(p).expect("replay submit");
                    // LINT-ALLOW(no-panic): a dropped reply means the
                    // server under test lost a request — abort loudly.
                    let _ = reply.recv().expect("replay answer");
                }
            });
        }
    });
    n as f64 / t0.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Lexicon;
    use crate::model::config::ModelConfig;
    use crate::model::weights::LmWeights;
    use crate::quant::QuantGrid;
    use crate::rng::Pcg64;
    use crate::vlm::{VlmConfig, VlmWeights};

    fn test_qlm() -> Arc<QuantizedLm> {
        let tok = Lexicon::tokenizer();
        let mcfg = ModelConfig::test_tiny(tok.vocab_size());
        let mut rng = Pcg64::seeded(801);
        let w = LmWeights::init(&mcfg, &mut rng);
        Arc::new(QuantizedLm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete"))
    }

    fn test_qvlm() -> Arc<QuantizedVlm> {
        let tok = Lexicon::tokenizer();
        let vcfg = VlmConfig::test_tiny(tok.vocab_size());
        let mut rng = Pcg64::seeded(802);
        let w = VlmWeights::init(&vcfg, &mut rng);
        Arc::new(QuantizedVlm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete"))
    }

    fn test_server(cfg: ServeConfig) -> (Server, Tokenizer) {
        let tok = Lexicon::tokenizer();
        (Server::start(test_qlm(), &tok, cfg), tok)
    }

    #[test]
    fn serves_single_request() {
        let (server, tok) = test_server(ServeConfig::default());
        let resp = server
            .classify(tok.encode("sentiment of text : i loved this movie answer :"))
            .unwrap();
        assert!(resp.label().unwrap() < 3);
        assert!(resp.latency.as_secs_f64() < 5.0);
    }

    #[test]
    fn serves_concurrent_requests_with_batching() {
        let (server, tok) = test_server(ServeConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(10),
            queue_cap: 64,
            lanes: 2,
            ..Default::default()
        });
        let prompts: Vec<String> = (0..24)
            .map(|i| {
                if i % 2 == 0 {
                    "sentiment of text : i loved this movie answer :".to_string()
                } else {
                    "sentiment of text : my phone is very broken answer :".to_string()
                }
            })
            .collect();
        let tput = replay(&server, &tok, &prompts, 3);
        assert!(tput > 0.0);
        let stats = server.shutdown();
        assert_eq!(stats.count(), 24);
        assert_eq!(stats.lane(LANE_SENTIMENT).unwrap().count(), 24);
    }

    #[test]
    fn all_ids_answered_exactly_once() {
        let (server, tok) = test_server(ServeConfig::default());
        let ids: Vec<u64> = (0..10)
            .map(|_| {
                server
                    .classify(tok.encode("sentiment of text : it was fine answer :"))
                    .unwrap()
                    .id
            })
            .collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }

    #[test]
    fn submit_after_close_returns_err_not_panic() {
        let (server, tok) = test_server(ServeConfig::default());
        let tokens = tok.encode("sentiment of text : it was fine answer :");
        assert!(server.submit_tokens(tokens.clone()).is_ok());
        server.close();
        // regression: this used to be `expect("server queue closed")`
        assert_eq!(server.submit_tokens(tokens.clone()).unwrap_err(), SubmitError::Closed);
        assert_eq!(server.classify(tokens).unwrap_err(), SubmitError::Closed);
        // the request accepted before close is still answered on shutdown
        let stats = server.shutdown();
        assert_eq!(stats.count(), 1);
    }

    #[test]
    fn unsupported_payload_rejected() {
        let (server, _tok) = test_server(ServeConfig::default());
        let patches = Tensor::zeros(&[4, 8]);
        assert_eq!(
            server.submit(Payload::Vqa { patches, question: vec![1, 2] }).unwrap_err(),
            SubmitError::Unsupported
        );
    }

    #[test]
    fn vqa_lane_answers_questions() {
        // fixed kernel: the lane's forward and the reference forward must
        // run the same numerics for the exact-argmax compare below
        let _kernel = crate::model::kernels::kernel_test_lock();
        let tok = Lexicon::tokenizer();
        let qvlm = test_qvlm();
        let vcfg = qvlm.config().clone();
        let server = Server::start_vqa(Arc::clone(&qvlm), &tok, ServeConfig::default());
        let mut rng = Pcg64::seeded(803);
        let patches = Tensor::randn(&[vcfg.n_patches, vcfg.patch_dim], 1.0, &mut rng);
        let question = tok.encode("what genre this book ? answer :");
        let resp = server.ask(patches.clone(), question.clone()).unwrap();
        // answer must match the unbatched row-select forward's argmax
        // exactly (the lane serves via RowSelect::LastRow, so the
        // reference runs the same path)
        let logits = qvlm
            .forward_rows(&patches, &question, 1, RowSelect::LastRow)
            .expect("forward");
        let last = logits.row(0);
        let pred = last
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap() as u32;
        match resp.answer {
            Answer::Vqa { answer_id, ref answer } => {
                assert_eq!(answer_id, pred);
                assert_eq!(answer, tok.word(pred));
            }
            ref other => panic!("expected vqa answer, got {other:?}"),
        }
        // malformed patches are rejected at submit
        let bad = Tensor::zeros(&[vcfg.n_patches + 1, vcfg.patch_dim]);
        assert!(matches!(
            server.submit(Payload::Vqa { patches: bad, question }).unwrap_err(),
            SubmitError::Invalid(_)
        ));
    }

    #[test]
    fn mixed_server_routes_to_both_lanes() {
        let tok = Lexicon::tokenizer();
        let qvlm = test_qvlm();
        let vcfg = qvlm.config().clone();
        let server = Server::start_mixed(
            test_qlm(),
            qvlm,
            &tok,
            ServeConfig { lanes: 2, ..Default::default() },
        );
        let mut rng = Pcg64::seeded(804);
        let mut items = Vec::new();
        for i in 0..12 {
            if i % 3 == 0 {
                let patches = Tensor::randn(&[vcfg.n_patches, vcfg.patch_dim], 1.0, &mut rng);
                items.push(Payload::Vqa {
                    patches,
                    question: tok.encode("who wrote this book ? answer :"),
                });
            } else {
                items.push(Payload::Sentiment {
                    tokens: tok.encode("sentiment of text : it was fine answer :"),
                });
            }
        }
        let tput = replay_mixed(&server, items, 3);
        assert!(tput > 0.0);
        let stats = server.shutdown();
        assert_eq!(stats.count(), 12);
        assert_eq!(stats.lane(LANE_VQA).unwrap().count(), 4);
        assert_eq!(stats.lane(LANE_SENTIMENT).unwrap().count(), 8);
    }

    #[test]
    fn four_lane_server_answers_everything() {
        let (server, tok) = test_server(ServeConfig {
            lanes: 4,
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            queue_cap: 32,
            ..Default::default()
        });
        assert_eq!(server.n_lanes(), 4);
        let prompts: Vec<String> = (0..40)
            .map(|i| format!("sentiment of text : case {} answer :", i % 7))
            .collect();
        let _ = replay(&server, &tok, &prompts, 8);
        let stats = server.shutdown();
        assert_eq!(stats.count(), 40);
    }
}
