//! Serving runtime: request router + dynamic batcher over a quantized
//! model — the deployment story the paper motivates (an assistive device
//! answering sentiment/VQA-style queries under a memory budget).
//!
//! Architecture (vLLM-router-like, scaled to this repo):
//!
//! * producers call [`Server::submit`] (bounded channel ⇒ natural
//!   backpressure);
//! * the batcher thread drains up to `max_batch` requests, padding the
//!   window by waiting at most `max_wait`;
//! * equal-length prompts are executed as one batched forward; responses
//!   are delivered through per-request channels;
//! * latency (queue + compute) is recorded per request into
//!   [`LatencyStats`].
//!
//! Threading: the batcher is one dedicated *event-loop* thread (it blocks
//! on the request queue, so parking it on a pool worker would starve the
//! pool). All compute runs on the shared global pool (`crate::exec`):
//! each batched forward's fused dequant-matmuls shard rows there, and when
//! one pickup yields several equal-length groups the groups themselves
//! fan out as scoped pool jobs.

use crate::data::tokenizer::Tokenizer;
use crate::data::SentimentSet;
use crate::exec::Channel;
use crate::metrics::LatencyStats;
use crate::model::QuantizedLm;
use crate::tensor::Tensor;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A scoring request: classify the sentiment of a prompt.
pub struct Request {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Reply channel (capacity 1).
    pub reply: Channel<Response>,
    pub enqueued: Instant,
}

/// Response: predicted label index + logits of the three label tokens.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub label: usize,
    pub label_logits: [f32; 3],
    pub latency: Duration,
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Max requests fused into one forward.
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_cap: 256,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// The serving coordinator.
pub struct Server {
    queue: Channel<Request>,
    next_id: AtomicU64,
    pub stats: LatencyStats,
    shutdown: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
    /// Model context length; longer prompts are left-truncated at submit.
    max_seq: usize,
}

impl Server {
    /// Start a server over a quantized LM. `label_ids` are the three
    /// sentiment answer tokens.
    pub fn start(model: Arc<QuantizedLm>, tok: &Tokenizer, cfg: ServeConfig) -> Self {
        let queue: Channel<Request> = Channel::bounded(cfg.queue_cap);
        let stats = LatencyStats::new();
        let shutdown = Arc::new(AtomicBool::new(false));
        let label_ids = SentimentSet::label_token_ids(tok);
        let max_seq = model.base.config.seq_len;
        let worker = {
            let queue = queue.clone();
            let stats = stats.clone();
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name("rpiq-batcher".into())
                .spawn(move || {
                    batcher_loop(model, queue, stats, shutdown, cfg, label_ids)
                })
                .expect("spawn batcher")
        };
        Server {
            queue,
            next_id: AtomicU64::new(0),
            stats,
            shutdown,
            worker: Some(worker),
            max_seq,
        }
    }

    /// Submit a request; blocks when the queue is full (backpressure).
    /// Returns the reply channel. Prompts longer than the model context
    /// are left-truncated (keeping the answer scaffold at the end).
    pub fn submit(&self, mut tokens: Vec<u32>) -> Channel<Response> {
        let max = self.max_seq;
        if tokens.len() > max {
            tokens = tokens[tokens.len() - max..].to_vec();
        }
        let reply = Channel::bounded(1);
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::SeqCst),
            tokens,
            reply: reply.clone(),
            enqueued: Instant::now(),
        };
        self.queue.send(req).expect("server queue closed");
        reply
    }

    /// Submit and wait.
    pub fn classify(&self, tokens: Vec<u32>) -> Response {
        self.submit(tokens).recv().expect("server dropped request")
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Stop the batcher after draining.
    pub fn shutdown(mut self) -> LatencyStats {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.stats.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batcher_loop(
    model: Arc<QuantizedLm>,
    queue: Channel<Request>,
    stats: LatencyStats,
    shutdown: Arc<AtomicBool>,
    cfg: ServeConfig,
    label_ids: [u32; 3],
) {
    loop {
        // Block for the first request (with timeout so shutdown is seen).
        let first = match queue.recv_timeout(Duration::from_millis(20)) {
            Some(r) => r,
            None => {
                if shutdown.load(Ordering::SeqCst) && queue.is_empty() {
                    return;
                }
                continue;
            }
        };
        let mut batch = vec![first];
        // Fill the batch within the wait window.
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match queue.recv_timeout(deadline - now) {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        // Group by sequence length so each group is one fused forward.
        batch.sort_by_key(|r| r.tokens.len());
        let mut ranges = Vec::new();
        let mut i = 0;
        while i < batch.len() {
            let seq = batch[i].tokens.len();
            let mut j = i + 1;
            while j < batch.len() && batch[j].tokens.len() == seq {
                j += 1;
            }
            ranges.push((i, j));
            i = j;
        }
        let run_group = |group: &[Request]| {
            let seq = group[0].tokens.len();
            let mut tokens = Vec::with_capacity(group.len() * seq);
            for r in group {
                tokens.extend_from_slice(&r.tokens);
            }
            let logits = model.forward(&tokens, group.len(), seq);
            for (gi, r) in group.iter().enumerate() {
                let last = logits.row(gi * seq + seq - 1);
                let ll = [
                    last[label_ids[0] as usize],
                    last[label_ids[1] as usize],
                    last[label_ids[2] as usize],
                ];
                let label = (0..3)
                    .max_by(|&a, &b| ll[a].partial_cmp(&ll[b]).unwrap())
                    .unwrap();
                let latency = r.enqueued.elapsed();
                stats.record(latency.as_secs_f64());
                let _ = r.reply.send(Response { id: r.id, label, label_logits: ll, latency });
            }
        };
        if ranges.len() <= 1 {
            // single group: run inline (its matmuls still shard rows on
            // the pool)
            for &(i, j) in &ranges {
                run_group(&batch[i..j]);
            }
        } else {
            // several length groups in one pickup: fan the group forwards
            // out across the shared pool
            let batch_ref = &batch;
            let run_ref = &run_group;
            crate::exec::global().scope(|s| {
                for &(i, j) in &ranges {
                    s.spawn(move || run_ref(&batch_ref[i..j]));
                }
            });
        }
    }
}

/// Convenience for benches: replay a set of prompts through the server
/// from `n_clients` producer threads; returns (throughput req/s, stats).
pub fn replay(
    server: &Server,
    tok: &Tokenizer,
    prompts: &[String],
    n_clients: usize,
) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let server = &*server;
            let prompts = &*prompts;
            let tok = &*tok;
            scope.spawn(move || {
                for p in prompts.iter().skip(c).step_by(n_clients) {
                    let _ = server.classify(tok.encode(p));
                }
            });
        }
    });
    prompts.len() as f64 / t0.elapsed().as_secs_f64()
}

/// `Tensor` is not used directly here but the signature parity with the
/// VQA path keeps the two serving flavours aligned.
#[allow(dead_code)]
fn _t(_: &Tensor) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Lexicon;
    use crate::model::config::ModelConfig;
    use crate::model::weights::LmWeights;
    use crate::quant::{QuantGrid, QuantizedLinear};
    use crate::rng::Pcg64;
    use std::collections::HashMap;

    fn test_server(cfg: ServeConfig) -> (Server, Tokenizer) {
        let tok = Lexicon::tokenizer();
        let mcfg = ModelConfig::test_tiny(tok.vocab_size());
        let mut rng = Pcg64::seeded(801);
        let w = LmWeights::init(&mcfg, &mut rng);
        let mut qlinears = HashMap::new();
        for (name, t) in w.linears() {
            qlinears.insert(name, QuantizedLinear::quantize_rtn(t, QuantGrid::new(4, 8)));
        }
        let qlm = Arc::new(QuantizedLm::new(w, qlinears));
        (Server::start(qlm, &tok, cfg), tok)
    }

    #[test]
    fn serves_single_request() {
        let (server, tok) = test_server(ServeConfig::default());
        let resp = server.classify(tok.encode("sentiment of text : i loved this movie answer :"));
        assert!(resp.label < 3);
        assert!(resp.latency.as_secs_f64() < 5.0);
    }

    #[test]
    fn serves_concurrent_requests_with_batching() {
        let (server, tok) = test_server(ServeConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(10),
            queue_cap: 64,
        });
        let prompts: Vec<String> = (0..24)
            .map(|i| {
                if i % 2 == 0 {
                    "sentiment of text : i loved this movie answer :".to_string()
                } else {
                    "sentiment of text : my phone is very broken answer :".to_string()
                }
            })
            .collect();
        let tput = replay(&server, &tok, &prompts, 3);
        assert!(tput > 0.0);
        let stats = server.shutdown();
        assert_eq!(stats.count(), 24);
    }

    #[test]
    fn all_ids_answered_exactly_once() {
        let (server, tok) = test_server(ServeConfig::default());
        let ids: Vec<u64> = (0..10)
            .map(|_| {
                server
                    .classify(tok.encode("sentiment of text : it was fine answer :"))
                    .id
            })
            .collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }
}
