//! Multi-lane serving engine: a workload-generic router + dynamic batcher
//! over quantized models — the deployment story the paper motivates (an
//! assistive device answering sentiment *and* VQA-style queries under a
//! memory budget, at heavy mixed traffic).
//!
//! Architecture (vLLM-router-like, scaled to this repo):
//!
//! * workloads are [`Payload`] variants answered by [`LaneEngine`]s — the
//!   built-ins are [`SentimentLane`] (token prompts through a
//!   [`QuantizedLm`]) and [`VqaLane`] ((patches, question) pairs through a
//!   [`QuantizedVlm`]'s batched forward); custom engines plug in via
//!   [`Server::start_engines`];
//! * producers call [`Server::submit`] (global-capacity
//!   [`ShardedQueue`] ⇒ natural backpressure at `queue_cap`; submission
//!   round-robins across shards);
//! * **N batcher lanes** (`ServeConfig::lanes` event-loop threads) each
//!   drain their own shard — and *steal from sibling shards when idle* —
//!   so p95 is no longer bound by one pickup loop; each lane fills a
//!   batch within `max_wait`, partitions it by (engine, shape key), and
//!   runs the groups — several groups in one pickup fan out as scoped
//!   pool jobs, each delivering its replies as soon as it finishes;
//! * inside an engine, equal-shape requests fuse into one batched forward,
//!   and very large equal-shape groups are sharded row-wise across the
//!   global pool explicitly (`WIDE_GROUP_ROWS` in `crate::model`);
//! * latency (queue + compute) is recorded per request into per-lane
//!   [`LaneStats`];
//! * memory is accounted on a server-owned [`MemoryLedger`]: callers
//!   register the deployed models' resident bytes
//!   (`QuantizedLm::register_resident`, tag `model_resident`) and each
//!   lane books its dominant transient under `activations.<lane>` for the
//!   duration of the batch, so the ledger's peak is `resident + max
//!   concurrent activations` and per-lane activation peaks print beside
//!   the latency stats at shutdown;
//! * the built-in lanes serve in **row-select** mode
//!   ([`crate::model::RowSelect::LastRow`]): the answer head runs only
//!   over the rows the lane reads and attention streams key blocks with
//!   an online softmax, so the booked transient is the model's
//!   [`QuantizedLm::serve_transient_bytes`] — `O(B·V + B·S·d)`, never the
//!   full `[B·S, V]` logits;
//! * an optional **activation budget** ([`ServeConfig::activation_budget`])
//!   caps each lane's concurrent transients: single requests that cannot
//!   ever fit are rejected at submit ([`SubmitError::OverBudget`], counted
//!   in [`LaneStats`]), fused groups that would overshoot are split into
//!   budget-fitting sub-batches, and admission into the cap blocks on
//!   [`MemoryLedger::alloc_blocking`] — the ledger's notify-on-free
//!   condvar, no sleep polling — so concurrent lanes cannot jointly
//!   overshoot their own caps;
//! * **streaming generation** ([`Server::start_generate`]): a third
//!   deployment shape where each lane runs a **continuous-batching**
//!   decode loop instead of the fused batcher — prefill seeds a
//!   sequence's pages in the paged KV cache ([`crate::model::KvPool`],
//!   ledger tag [`crate::metrics::tags::KV_CACHE`]), every further step
//!   is `O(S)` attention against the cache
//!   ([`QuantizedLm::decode_step`]), sequences join and leave the step
//!   batch *between* steps (admission gated on free pages + the
//!   activation budget), and every token streams on the reply channel as
//!   it is produced ([`Answer::Token`], then a final
//!   [`Answer::Generated`]) — greedy tokens bit-identical to the
//!   recompute-from-scratch oracle ([`QuantizedLm::generate_recompute`]).
//!
//! Threading: lanes are dedicated event-loop threads (they block on the
//! request queue, so parking them on pool workers would starve the pool).
//! All compute runs on the shared global pool (`crate::exec`): each fused
//! forward's dequant-matmuls shard rows there, wide groups chunk there,
//! and multi-engine pickups fan out there.

// Request-path module: non-test code must stay panic-free. The repo lint
// (`rpiq-lint`, rule `no-panic`) and these clippy denies enforce it.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used, clippy::panic))]
#![cfg_attr(not(test), deny(clippy::indexing_slicing))]

use crate::data::tokenizer::Tokenizer;
use crate::data::SentimentSet;
use crate::exec::{Channel, ShardedQueue};
use crate::metrics::{LaneStats, MemoryLedger};
use crate::model::{greedy_argmax, KvPool, KvSeq, QuantizedLm, RowSelect, PAGE_SLOTS};
use crate::tensor::Tensor;
use crate::vlm::QuantizedVlm;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Name of the sentiment lane in [`LaneStats`].
pub const LANE_SENTIMENT: &str = "sentiment";
/// Name of the VQA lane in [`LaneStats`].
pub const LANE_VQA: &str = "vqa";
/// Name of the streaming-generation lane in [`LaneStats`].
pub const LANE_GENERATE: &str = "generate";

/// One unit of work a lane can batch.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Classify the sentiment of a tokenized prompt.
    Sentiment { tokens: Vec<u32> },
    /// Answer a question about an image (`patches: [n_patches, patch_dim]`).
    Vqa { patches: Tensor, question: Vec<u32> },
    /// Greedy-decode up to `max_new` tokens after a tokenized prompt,
    /// streaming each one; stops early after `eos` when given (the EOS
    /// token itself is included in the output).
    Generate { tokens: Vec<u32>, max_new: usize, eos: Option<u32> },
}

/// A lane's answer to one payload.
#[derive(Clone, Debug)]
pub enum Answer {
    /// Predicted label index + logits of the three label tokens.
    Sentiment { label: usize, label_logits: [f32; 3] },
    /// Argmax answer token over the full vocabulary, decoded.
    Vqa { answer_id: u32, answer: String },
    /// One streamed token of a generate request: `index` is its 0-based
    /// position in the generated sequence, `text` its vocabulary word.
    Token { index: usize, token: u32, text: String },
    /// Final answer of a generate request: the full generated sequence
    /// (each token of which was already delivered as [`Answer::Token`]
    /// on the streaming decode path) and its decoded text.
    Generated { tokens: Vec<u32>, text: String },
}

/// Response delivered on the per-request reply channel.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub answer: Answer,
    pub latency: Duration,
}

impl Response {
    /// Sentiment label, if this was a sentiment request.
    pub fn label(&self) -> Option<usize> {
        match &self.answer {
            Answer::Sentiment { label, .. } => Some(*label),
            _ => None,
        }
    }

    /// Decoded VQA answer word, if this was a VQA request.
    pub fn vqa_answer(&self) -> Option<&str> {
        match &self.answer {
            Answer::Vqa { answer, .. } => Some(answer.as_str()),
            _ => None,
        }
    }

    /// Streamed token, if this response is one step of a generate stream.
    pub fn token(&self) -> Option<u32> {
        match &self.answer {
            Answer::Token { token, .. } => Some(*token),
            _ => None,
        }
    }

    /// Full generated sequence, if this is a generate request's final
    /// [`Answer::Generated`] answer.
    pub fn generated(&self) -> Option<&[u32]> {
        match &self.answer {
            Answer::Generated { tokens, .. } => Some(tokens.as_slice()),
            _ => None,
        }
    }
}

/// A queued request: payload + routing + reply channel (capacity 1).
pub struct Request {
    pub id: u64,
    pub payload: Payload,
    /// Index into the server's engine list, resolved at submit.
    engine: usize,
    pub reply: Channel<Response>,
    pub enqueued: Instant,
}

impl Drop for Request {
    fn drop(&mut self) {
        // Close the reply channel so a client blocked in `recv` observes a
        // dropped request (`None` ⇒ `SubmitError::Closed`) instead of
        // hanging forever — e.g. when an engine panics and its group is
        // discarded. After a successful delivery the close is harmless:
        // `Channel` lets the receiver drain a closed channel.
        self.reply.close();
    }
}

/// Why a submission was rejected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The server is shutting down (queue closed) or dropped the request.
    Closed,
    /// No registered lane accepts this payload kind.
    Unsupported,
    /// The payload is malformed for its lane (e.g. patch-shape mismatch).
    Invalid(String),
    /// The request alone books more transient-activation bytes than its
    /// lane's [`ServeConfig::activation_budget`] — it could never be
    /// admitted, so it is rejected at submit instead of deadlocking a lane.
    OverBudget { needed: usize, cap: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Closed => write!(f, "server closed"),
            SubmitError::Unsupported => write!(f, "no lane accepts this payload"),
            SubmitError::Invalid(why) => write!(f, "invalid payload: {why}"),
            SubmitError::OverBudget { needed, cap } => write!(
                f,
                "request books {needed} transient bytes, over the lane's {cap}-byte activation budget"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A batchable workload: one of these per modality the server offers.
/// Engines are pure batch functions — delivery, latency accounting, and
/// wide-group fan-out are handled generically by the lane loop.
pub trait LaneEngine: Send + Sync {
    /// Lane name used for per-lane stats (e.g. [`LANE_SENTIMENT`]).
    fn name(&self) -> &'static str;

    /// Whether this lane answers `payload`.
    fn accepts(&self, payload: &Payload) -> bool;

    /// Normalize/validate a payload at submit time (before it is queued);
    /// e.g. left-truncate over-long prompts to the model context.
    fn prepare(&self, _payload: &mut Payload) -> Result<(), SubmitError> {
        Ok(())
    }

    /// Shape key for fusion: payloads of one pickup with equal keys are
    /// answered by one `run_batch` call (one fused forward) and delivered
    /// together; distinct keys run — and deliver — independently, so a
    /// short request never waits on a long group's compute.
    fn shape_key(&self, _payload: &Payload) -> usize {
        0
    }

    /// Answer a drained group of payloads (all accepted by this lane,
    /// all sharing one shape key), one answer per item, in order.
    fn run_batch(&self, group: &[&Payload]) -> Vec<Answer>;

    /// Dominant transient-activation bytes of answering `group` in one
    /// fused forward (the logits tensor at these model scales). The lane
    /// loop books this on the server ledger under `activations.<name>`
    /// for the duration of the batch; return 0 to opt out of accounting.
    fn transient_bytes(&self, _group: &[&Payload]) -> usize {
        0
    }
}

/// Sentiment lane: fuses equal-length token prompts into batched
/// quantized forwards (same chunk/fan-out skeleton as
/// [`QuantizedLm::forward_batch`], reading answer rows in place).
pub struct SentimentLane {
    model: Arc<QuantizedLm>,
    label_ids: [u32; 3],
    max_seq: usize,
}

impl SentimentLane {
    pub fn new(model: Arc<QuantizedLm>, tok: &Tokenizer) -> Self {
        let label_ids = SentimentSet::label_token_ids(tok);
        let max_seq = model.config().seq_len;
        SentimentLane { model, label_ids, max_seq }
    }
}

impl LaneEngine for SentimentLane {
    fn name(&self) -> &'static str {
        LANE_SENTIMENT
    }

    fn accepts(&self, payload: &Payload) -> bool {
        matches!(payload, Payload::Sentiment { .. })
    }

    fn prepare(&self, payload: &mut Payload) -> Result<(), SubmitError> {
        let Payload::Sentiment { tokens } = payload else {
            return Err(SubmitError::Unsupported);
        };
        if tokens.is_empty() {
            return Err(SubmitError::Invalid("empty prompt".into()));
        }
        // left-truncate, keeping the answer scaffold at the end
        if tokens.len() > self.max_seq {
            let cut = tokens.len() - self.max_seq;
            tokens.drain(..cut);
        }
        Ok(())
    }

    fn shape_key(&self, payload: &Payload) -> usize {
        match payload {
            Payload::Sentiment { tokens } => tokens.len(),
            _ => 0,
        }
    }

    fn transient_bytes(&self, group: &[&Payload]) -> usize {
        // Row-select serving: the dominant transients are the selected-row
        // logits `[B, V]` plus the widest per-layer activation `[B·S, d]`
        // (the full `[B·S, V]` logits are never built). Groups share one
        // shape key, so every prompt here has the max length.
        let seq = group
            .iter()
            .map(|p| match p {
                Payload::Sentiment { tokens } => tokens.len(),
                _ => 0,
            })
            .max()
            .unwrap_or(0);
        self.model.serve_transient_bytes(group.len(), seq)
    }

    fn run_batch(&self, group: &[&Payload]) -> Vec<Answer> {
        let mut seqs: Vec<&[u32]> = Vec::with_capacity(group.len());
        for p in group {
            match p {
                Payload::Sentiment { tokens } => seqs.push(tokens.as_slice()),
                // Misrouted payload (impossible by construction): return a
                // short answer vector so the lane loop's count check drops
                // the group cleanly instead of poisoning the lane.
                _ => return Vec::new(),
            }
        }
        // The lane loop groups by shape key, so all sequences here share
        // one length: fuse each chunk into one row-select forward
        // ([`RowSelect::LastRow`]) — the head matmul runs only over the
        // answer rows and attention streams key blocks, so the transient
        // is `[B, V]` logits plus `O(S·chunk)` scores, never `[B·S, V]`.
        let Some(seq) = seqs.first().map(|s| s.len()) else {
            return Vec::new();
        };
        debug_assert!(seqs.iter().all(|s| s.len() == seq), "mixed shapes in one group");
        let answers = crate::model::quantized::run_equal_shape_groups(seqs.len(), |_| 0, |chunk| {
            let mut tokens = Vec::with_capacity(chunk.len() * seq);
            for s in chunk.iter().filter_map(|&i| seqs.get(i)) {
                tokens.extend_from_slice(s);
            }
            let logits =
                self.model.forward_rows(&tokens, chunk.len(), seq, RowSelect::LastRow)?;
            Ok((0..chunk.len())
                .map(|gi| {
                    let last = logits.row(gi);
                    let mut ll = [f32::NEG_INFINITY; 3];
                    for (dst, &id) in ll.iter_mut().zip(self.label_ids.iter()) {
                        *dst = last.get(id as usize).copied().unwrap_or(f32::NEG_INFINITY);
                    }
                    // Total order over f32: a NaN logit degrades this one
                    // answer instead of killing the group via catch_unwind.
                    let label = ll
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i)
                        .unwrap_or(0);
                    Answer::Sentiment { label, label_logits: ll }
                })
                .collect())
        });
        match answers {
            Ok(a) => a,
            // A forward error (e.g. shape mismatch) surfaces as a short
            // answer vector — the lane loop's count check drops the group
            // cleanly instead of poisoning the lane.
            Err(e) => {
                crate::trace::log(&format!("sentiment lane batch failed: {e:#}"));
                Vec::new()
            }
        }
    }
}

/// VQA lane: fuses equal-length (patches, question) pairs into batched
/// quantized forwards (same chunk/fan-out skeleton as
/// [`QuantizedVlm::forward_batch`], reading answer rows in place) — the
/// paper's assistive workload as a first-class batched lane.
pub struct VqaLane {
    model: Arc<QuantizedVlm>,
    tok: Tokenizer,
}

impl VqaLane {
    pub fn new(model: Arc<QuantizedVlm>, tok: &Tokenizer) -> Self {
        VqaLane { model, tok: tok.clone() }
    }
}

impl LaneEngine for VqaLane {
    fn name(&self) -> &'static str {
        LANE_VQA
    }

    fn accepts(&self, payload: &Payload) -> bool {
        matches!(payload, Payload::Vqa { .. })
    }

    fn prepare(&self, payload: &mut Payload) -> Result<(), SubmitError> {
        let Payload::Vqa { patches, question } = payload else {
            return Err(SubmitError::Unsupported);
        };
        let cfg = self.model.config();
        if patches.rows() != cfg.n_patches || patches.cols() != cfg.patch_dim {
            return Err(SubmitError::Invalid(format!(
                "patches {:?}, model expects [{}, {}]",
                patches.shape(),
                cfg.n_patches,
                cfg.patch_dim
            )));
        }
        if question.is_empty() {
            return Err(SubmitError::Invalid("empty question".into()));
        }
        // left-truncate over-long questions, keeping the answer scaffold
        let text_len = cfg.text_len();
        if question.len() > text_len {
            let cut = question.len() - text_len;
            question.drain(..cut);
        }
        Ok(())
    }

    fn shape_key(&self, payload: &Payload) -> usize {
        match payload {
            Payload::Vqa { question, .. } => question.len(),
            _ => 0,
        }
    }

    fn transient_bytes(&self, group: &[&Payload]) -> usize {
        // Row-select serving: selected-row logits `[B, V]` plus the widest
        // per-layer activation over the fused `[B·(P + T), ·]` sequence —
        // see [`QuantizedVlm::serve_transient_bytes`]. One shape key ⇒ one
        // question length, so the max is the common length.
        let qlen = group
            .iter()
            .map(|p| match p {
                Payload::Vqa { question, .. } => question.len(),
                _ => 0,
            })
            .max()
            .unwrap_or(0);
        self.model.serve_transient_bytes(group.len(), qlen)
    }

    fn run_batch(&self, group: &[&Payload]) -> Vec<Answer> {
        let mut pairs: Vec<(&Tensor, &[u32])> = Vec::with_capacity(group.len());
        for p in group {
            match p {
                Payload::Vqa { patches, question } => pairs.push((patches, question.as_slice())),
                // Misrouted payload (impossible by construction): a short
                // answer vector makes the lane loop drop the group cleanly.
                _ => return Vec::new(),
            }
        }
        // Equal shape key ⇒ equal question length: stack each chunk into
        // one fused row-select forward ([`RowSelect::LastRow`]) — only the
        // answer rows reach the vocab head, so the transient is `[B, V]`
        // logits plus streamed `O(S·chunk)` attention scores.
        let cfg = self.model.config();
        let n_patches = cfg.n_patches;
        // prepare() validated every patches tensor against the config, so
        // the patch dim comes from the config rather than the group.
        let pd = cfg.patch_dim;
        let Some(tlen) = pairs.first().map(|(_, q)| q.len()) else {
            return Vec::new();
        };
        debug_assert!(pairs.iter().all(|(_, q)| q.len() == tlen), "mixed shapes in one group");
        let answers = crate::model::quantized::run_equal_shape_groups(pairs.len(), |_| 0, |chunk| {
            let b = chunk.len();
            let mut pdata = Vec::with_capacity(b * n_patches * pd);
            let mut text = Vec::with_capacity(b * tlen);
            for (p, q) in chunk.iter().filter_map(|&i| pairs.get(i)) {
                pdata.extend_from_slice(p.data());
                text.extend_from_slice(q);
            }
            let patches = Tensor::from_vec(&[b * n_patches, pd], pdata);
            let logits = self.model.forward_rows(&patches, &text, b, RowSelect::LastRow)?;
            Ok((0..b)
                .map(|gi| {
                    let last = logits.row(gi);
                    // Total order over f32 (see the sentiment argmax).
                    let pred = last
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i)
                        .unwrap_or(0) as u32;
                    Answer::Vqa { answer_id: pred, answer: self.tok.word(pred).to_string() }
                })
                .collect())
        });
        match answers {
            Ok(a) => a,
            // Same clean group drop as the sentiment lane: errors become a
            // short answer vector, never a lane-thread panic.
            Err(e) => {
                crate::trace::log(&format!("vqa lane batch failed: {e:#}"));
                Vec::new()
            }
        }
    }
}

/// Streaming-generation lane: greedy decode over a [`QuantizedLm`]
/// through the paged KV cache ([`KvPool`]).
///
/// Under [`Server::start_generate`] the lane threads run the
/// continuous-batching decode loop (per-token streaming, `O(S)` cached
/// steps). Plugged into a generic [`Server::start_engines`] deployment
/// instead, the lane serves whole requests through
/// [`LaneEngine::run_batch`] via the recompute-from-scratch oracle
/// ([`QuantizedLm::generate_recompute`]) — bit-identical answers, no
/// cache — which is the baseline arm of the decode bench.
#[derive(Clone)]
pub struct GenerateLane {
    model: Arc<QuantizedLm>,
    tok: Tokenizer,
    pool: KvPool,
    max_seq: usize,
}

impl GenerateLane {
    pub fn new(model: Arc<QuantizedLm>, tok: &Tokenizer, pool: KvPool) -> Self {
        let max_seq = model.config().seq_len;
        GenerateLane { model, tok: tok.clone(), pool, max_seq }
    }

    /// The lane's paged KV pool (shared with [`Server::kv_pool`]).
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }
}

impl LaneEngine for GenerateLane {
    fn name(&self) -> &'static str {
        LANE_GENERATE
    }

    fn accepts(&self, payload: &Payload) -> bool {
        matches!(payload, Payload::Generate { .. })
    }

    fn prepare(&self, payload: &mut Payload) -> Result<(), SubmitError> {
        let Payload::Generate { tokens, max_new, .. } = payload else {
            return Err(SubmitError::Unsupported);
        };
        if tokens.is_empty() {
            return Err(SubmitError::Invalid("empty prompt".into()));
        }
        if *max_new == 0 {
            return Err(SubmitError::Invalid("max_new must be at least 1".into()));
        }
        // The longest prefix ever embedded is `prompt + max_new − 1`
        // rows (the final sampled token is returned but never
        // re-embedded), so the prompt may keep `seq_len + 1 − max_new`
        // tokens: left-truncate, mirroring the sentiment lane.
        let keep = (self.max_seq + 1).saturating_sub(*max_new);
        if keep == 0 {
            return Err(SubmitError::Invalid(format!(
                "max_new {max_new} exceeds the model context {}",
                self.max_seq
            )));
        }
        if tokens.len() > keep {
            let cut = tokens.len() - keep;
            tokens.drain(..cut);
        }
        // A request whose worst-case cache footprint exceeds the whole
        // pool could never be admitted — reject at submit instead of
        // parking a decode lane on it forever.
        let need = self.pool.pages_for(tokens.len() + *max_new - 1);
        if need > self.pool.capacity_pages() {
            return Err(SubmitError::OverBudget {
                needed: need * self.pool.page_bytes(),
                cap: self.pool.capacity_pages() * self.pool.page_bytes(),
            });
        }
        Ok(())
    }

    fn transient_bytes(&self, group: &[&Payload]) -> usize {
        // Decode serves one row per step, but admission must cover the
        // worst moment: the prefill forward over the full prompt on the
        // cached path, or the longest recompute prefix on the oracle
        // fallback — both bounded by the serve transient of a batch-1
        // forward over `prompt + max_new − 1` rows. The oracle runs the
        // group one request at a time, so the max (not the sum) is the
        // dominant concurrent transient.
        group
            .iter()
            .map(|p| match p {
                Payload::Generate { tokens, max_new, .. } => self
                    .model
                    .serve_transient_bytes(1, tokens.len() + max_new.saturating_sub(1)),
                _ => 0,
            })
            .max()
            .unwrap_or(0)
    }

    fn run_batch(&self, group: &[&Payload]) -> Vec<Answer> {
        let mut answers = Vec::with_capacity(group.len());
        for p in group {
            let Payload::Generate { tokens, max_new, eos } = p else {
                // Misrouted payload (impossible by construction): a short
                // answer vector makes the lane loop drop the group cleanly.
                return Vec::new();
            };
            match self.model.generate_recompute(tokens, *max_new, *eos) {
                Ok(out) => {
                    let text = self.tok.decode(&out);
                    answers.push(Answer::Generated { tokens: out, text });
                }
                // Same clean group drop as the other lanes: errors become
                // a short answer vector, never a lane-thread panic.
                Err(e) => {
                    crate::trace::log(&format!("generate lane batch failed: {e:#}"));
                    return Vec::new();
                }
            }
        }
        answers
    }
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Global queue capacity (backpressure bound across all shards).
    pub queue_cap: usize,
    /// Max requests one lane fuses into one pickup.
    pub max_batch: usize,
    /// Max time a lane waits to fill a batch.
    pub max_wait: Duration,
    /// Number of batcher lanes (event-loop threads / queue shards).
    pub lanes: usize,
    /// Per-lane transient-activation budget in bytes. When set, each
    /// lane's `activations.<lane>` ledger tag is capped at this value:
    /// submissions whose single-request transient exceeds it are rejected
    /// ([`SubmitError::OverBudget`]), fused groups are split into
    /// budget-fitting sub-batches, and lanes block admission (never the
    /// ledger math) until their concurrent bookings fit. `None` disables
    /// enforcement — the ledger still observes, it just never gates.
    pub activation_budget: Option<usize>,
    /// Paged-KV pool size, in pages, for [`Server::start_generate`]
    /// (ignored by the fused-batch servers). `None` sizes the pool for
    /// `lanes × max_batch` full-context sequences. Admission into a
    /// decode step batch is gated on free pages, so this caps the
    /// resident cache bytes booked under
    /// [`crate::metrics::tags::KV_CACHE`].
    pub kv_pages: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_cap: 256,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            lanes: 2,
            activation_budget: None,
            kv_pages: None,
        }
    }
}

/// The serving coordinator: submit side + N batcher lanes over a sharded
/// queue of [`Request`]s, answered by registered [`LaneEngine`]s.
pub struct Server {
    queue: ShardedQueue<Request>,
    engines: Arc<Vec<Box<dyn LaneEngine>>>,
    next_id: AtomicU64,
    pub stats: LaneStats,
    /// Memory accounting for the serving process: model-resident bytes
    /// (registered by the caller) + per-lane transient activations
    /// (booked by the lane loop around each fused batch).
    ledger: MemoryLedger,
    /// Copied from [`ServeConfig::activation_budget`]; checked per request
    /// at submit so over-cap payloads never reach a lane.
    activation_budget: Option<usize>,
    /// The paged KV pool of a [`Server::start_generate`] deployment;
    /// `None` on fused-batch servers.
    kv_pool: Option<KvPool>,
    lanes: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start a server from an explicit engine list — the generic core the
    /// typed constructors (and the serve tests' synthetic engines) use.
    #[allow(clippy::expect_used)] // lane-thread spawn failure is unrecoverable
    pub fn start_engines(engines: Vec<Box<dyn LaneEngine>>, cfg: ServeConfig) -> Self {
        // LINT-ALLOW(no-panic): construction-time invariant, checked before
        // any request exists — misconfiguration should fail loudly at startup.
        assert!(!engines.is_empty(), "server needs at least one lane engine");
        let n_lanes = cfg.lanes.max(1);
        let queue: ShardedQueue<Request> = ShardedQueue::new(n_lanes, cfg.queue_cap);
        let stats = LaneStats::new();
        let ledger = MemoryLedger::new();
        let engines = Arc::new(engines);
        if let Some(cap) = cfg.activation_budget {
            // Cap every lane's transient tag up front — lanes gate their
            // bookings through `try_alloc`, so the budget binds from the
            // first request.
            for e in engines.iter() {
                ledger.set_budget(&crate::metrics::tags::activations(e.name()), cap);
            }
        }
        let lanes = (0..n_lanes)
            .map(|i| {
                let queue = queue.clone();
                let stats = stats.clone();
                let ledger = ledger.clone();
                let engines = Arc::clone(&engines);
                std::thread::Builder::new()
                    .name(format!("rpiq-lane-{i}"))
                    .spawn(move || lane_loop(i, engines, queue, stats, ledger, cfg))
                    // LINT-ALLOW(no-panic): thread-spawn failure at server
                    // construction is unrecoverable resource exhaustion.
                    .expect("spawn lane")
            })
            .collect();
        Server {
            queue,
            engines,
            next_id: AtomicU64::new(0),
            stats,
            ledger,
            activation_budget: cfg.activation_budget,
            kv_pool: None,
            lanes,
        }
    }

    /// Streaming-generation server over a quantized LM: requests enter
    /// the same sharded queue, but each lane runs a
    /// **continuous-batching** decode loop instead of the fused batcher —
    /// sequences join the step batch as soon as pool pages and the
    /// activation budget admit them and leave on EOS / `max_new`, with
    /// every token streamed on the reply channel as it is produced.
    ///
    /// The paged KV pool ([`KvPool`]) holds [`ServeConfig::kv_pages`]
    /// pages (default: enough for `lanes × max_batch` full-context
    /// sequences) and is accounted on the server ledger under
    /// [`crate::metrics::tags::KV_CACHE`] — after a full drain the tag
    /// balances to zero and every page is free again.
    #[allow(clippy::expect_used)] // lane-thread spawn failure is unrecoverable
    pub fn start_generate(model: Arc<QuantizedLm>, tok: &Tokenizer, cfg: ServeConfig) -> Self {
        let n_lanes = cfg.lanes.max(1);
        let mcfg = model.config();
        let full_seq_pages = mcfg.n_layers * mcfg.seq_len.div_ceil(PAGE_SLOTS);
        let pages = cfg.kv_pages.unwrap_or(n_lanes * cfg.max_batch.max(1) * full_seq_pages);
        let ledger = MemoryLedger::new();
        let pool = KvPool::new(mcfg.n_layers, mcfg.d_model, pages, ledger.clone());
        let lane = GenerateLane::new(model, tok, pool.clone());
        let queue: ShardedQueue<Request> = ShardedQueue::new(n_lanes, cfg.queue_cap);
        let stats = LaneStats::new();
        if let Some(cap) = cfg.activation_budget {
            ledger.set_budget(&crate::metrics::tags::activations(LANE_GENERATE), cap);
        }
        let lanes = (0..n_lanes)
            .map(|i| {
                let queue = queue.clone();
                let stats = stats.clone();
                let ledger = ledger.clone();
                let engine = lane.clone();
                std::thread::Builder::new()
                    .name(format!("rpiq-decode-{i}"))
                    .spawn(move || decode_loop(i, engine, queue, stats, ledger, cfg))
                    // LINT-ALLOW(no-panic): thread-spawn failure at server
                    // construction is unrecoverable resource exhaustion.
                    .expect("spawn decode lane")
            })
            .collect();
        let engines: Vec<Box<dyn LaneEngine>> = vec![Box::new(lane)];
        Server {
            queue,
            engines: Arc::new(engines),
            next_id: AtomicU64::new(0),
            stats,
            ledger,
            activation_budget: cfg.activation_budget,
            kv_pool: Some(pool),
            lanes,
        }
    }

    /// The paged KV pool of a [`Server::start_generate`] deployment —
    /// `None` on fused-batch servers. Tests and benches read page
    /// occupancy here (`free_pages == capacity_pages` after a drain).
    pub fn kv_pool(&self) -> Option<&KvPool> {
        self.kv_pool.as_ref()
    }

    /// The server's memory ledger. Register deployed models' resident
    /// bytes here (`register_resident`) before replaying traffic; the
    /// lanes add their transient activations, so `peak_bytes()` reads as
    /// the serving process's high-water mark and
    /// `peak_for("activations.<lane>")` as one lane's transient peak.
    pub fn ledger(&self) -> &MemoryLedger {
        &self.ledger
    }

    /// Sentiment-only server over a quantized LM.
    pub fn start(model: Arc<QuantizedLm>, tok: &Tokenizer, cfg: ServeConfig) -> Self {
        Self::start_engines(vec![Box::new(SentimentLane::new(model, tok))], cfg)
    }

    /// VQA-only server over a quantized VLM.
    pub fn start_vqa(model: Arc<QuantizedVlm>, tok: &Tokenizer, cfg: ServeConfig) -> Self {
        Self::start_engines(vec![Box::new(VqaLane::new(model, tok))], cfg)
    }

    /// Mixed-traffic server: sentiment and VQA lanes side by side.
    pub fn start_mixed(
        lm: Arc<QuantizedLm>,
        vlm: Arc<QuantizedVlm>,
        tok: &Tokenizer,
        cfg: ServeConfig,
    ) -> Self {
        Self::start_engines(
            vec![
                Box::new(SentimentLane::new(lm, tok)),
                Box::new(VqaLane::new(vlm, tok)),
            ],
            cfg,
        )
    }

    fn make_request(&self, mut payload: Payload) -> Result<Request, SubmitError> {
        let engine = self
            .engines
            .iter()
            .position(|e| e.accepts(&payload))
            .ok_or(SubmitError::Unsupported)?;
        let lane = self.engines.get(engine).ok_or(SubmitError::Unsupported)?;
        lane.prepare(&mut payload)?;
        if let Some(cap) = self.activation_budget {
            // A request that alone overshoots its lane's budget can never
            // be admitted (sub-batches are at least one request): reject
            // here instead of letting a lane spin on it forever.
            let needed = lane.transient_bytes(&[&payload]);
            if needed > cap {
                return Err(SubmitError::OverBudget { needed, cap });
            }
        }
        // Generate replies stream one response per token plus the final
        // answer: size the channel so the decode lane never blocks on
        // delivery (a slow client costs it nothing). One-shot lanes keep
        // the capacity-1 channel.
        let reply = match &payload {
            Payload::Generate { max_new, .. } => Channel::bounded(max_new.saturating_add(2)),
            _ => Channel::bounded(1),
        };
        Ok(Request {
            id: self.next_id.fetch_add(1, Ordering::SeqCst),
            payload,
            engine,
            reply,
            enqueued: Instant::now(),
        })
    }

    /// Count a rejected submission in the stats before handing the error
    /// back — rejections never reach a lane, so this is their only trace.
    fn reject(&self, e: SubmitError) -> SubmitError {
        self.stats.record_reject(match &e {
            SubmitError::Closed => crate::metrics::RejectKind::Closed,
            SubmitError::Unsupported => crate::metrics::RejectKind::Unsupported,
            SubmitError::Invalid(_) => crate::metrics::RejectKind::Invalid,
            SubmitError::OverBudget { .. } => crate::metrics::RejectKind::OverBudget,
        });
        e
    }

    /// Submit a payload; blocks while the queue holds `queue_cap` requests
    /// (backpressure). Returns the reply channel, or an error when the
    /// server is closed / the payload has no lane.
    pub fn submit(&self, payload: Payload) -> Result<Channel<Response>, SubmitError> {
        let req = self.make_request(payload).map_err(|e| self.reject(e))?;
        let reply = req.reply.clone();
        self.queue.push(req).map_err(|_| self.reject(SubmitError::Closed))?;
        Ok(reply)
    }

    /// Non-blocking submit attempt: `Ok(None)` when the queue is full
    /// (backpressure, not a rejection — it is not counted as one).
    pub fn try_submit(&self, payload: Payload) -> Result<Option<Channel<Response>>, SubmitError> {
        let req = self.make_request(payload).map_err(|e| self.reject(e))?;
        let reply = req.reply.clone();
        match self.queue.try_push(req) {
            Ok(true) => Ok(Some(reply)),
            Ok(false) => Ok(None),
            Err(_) => Err(self.reject(SubmitError::Closed)),
        }
    }

    /// Submit a sentiment prompt (compat shim for token-based callers).
    pub fn submit_tokens(&self, tokens: Vec<u32>) -> Result<Channel<Response>, SubmitError> {
        self.submit(Payload::Sentiment { tokens })
    }

    /// Submit a sentiment prompt and wait for the answer.
    pub fn classify(&self, tokens: Vec<u32>) -> Result<Response, SubmitError> {
        self.submit_tokens(tokens)?.recv().ok_or(SubmitError::Closed)
    }

    /// Submit a VQA pair and wait for the answer.
    pub fn ask(&self, patches: Tensor, question: Vec<u32>) -> Result<Response, SubmitError> {
        self.submit(Payload::Vqa { patches, question })?
            .recv()
            .ok_or(SubmitError::Closed)
    }

    /// Submit a generate request: the reply channel streams one
    /// [`Answer::Token`] per decoded token followed by a final
    /// [`Answer::Generated`], then closes.
    pub fn submit_generate(
        &self,
        tokens: Vec<u32>,
        max_new: usize,
        eos: Option<u32>,
    ) -> Result<Channel<Response>, SubmitError> {
        self.submit(Payload::Generate { tokens, max_new, eos })
    }

    /// Submit a generate request and drain its stream: returns the full
    /// generated sequence after checking it against the streamed tokens.
    pub fn generate(
        &self,
        tokens: Vec<u32>,
        max_new: usize,
        eos: Option<u32>,
    ) -> Result<Vec<u32>, SubmitError> {
        let reply = self.submit_generate(tokens, max_new, eos)?;
        let mut streamed: Vec<u32> = Vec::new();
        let mut full: Option<Vec<u32>> = None;
        while let Some(resp) = reply.recv() {
            match resp.answer {
                Answer::Token { token, .. } => streamed.push(token),
                Answer::Generated { tokens, .. } => full = Some(tokens),
                _ => {}
            }
        }
        match full {
            // The oracle fallback (GenerateLane under a fused-batch
            // server) delivers only the final answer — no stream.
            Some(full) if streamed.is_empty() || full == streamed => Ok(full),
            // A stream disagreeing with the final answer would be a
            // server bug; fail loudly rather than return either.
            Some(_) => Err(SubmitError::Closed),
            None => Err(SubmitError::Closed),
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Number of batcher lanes.
    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Stop accepting new requests; lanes drain what is already queued.
    /// Subsequent submits fail with [`SubmitError::Closed`].
    pub fn close(&self) {
        self.queue.close();
    }

    /// Close, drain every pending request across every lane, and join.
    pub fn shutdown(mut self) -> LaneStats {
        self.queue.close();
        for l in self.lanes.drain(..) {
            let _ = l.join();
        }
        self.stats.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        for l in self.lanes.drain(..) {
            let _ = l.join();
        }
    }
}

/// One batcher lane: drain shard `lane` (stealing when idle), fill a batch
/// within the wait window, partition by engine, run the groups, deliver.
fn lane_loop(
    lane: usize,
    engines: Arc<Vec<Box<dyn LaneEngine>>>,
    queue: ShardedQueue<Request>,
    stats: LaneStats,
    ledger: MemoryLedger,
    cfg: ServeConfig,
) {
    // Per-engine ledger tags, precomputed once — the lane loop is the
    // serving hot path and engines are fixed for the server's lifetime.
    let activation_tags: Vec<String> = engines
        .iter()
        .map(|e| crate::metrics::tags::activations(e.name()))
        .collect();
    loop {
        // Block for the first request. Shutdown wakes the pop directly
        // (`close` notifies every shard condvar), so this timeout is only
        // a belt-and-braces re-check and can be long — an idle lane wakes
        // a handful of times per second, not hundreds.
        let first = match queue.pop(lane, Duration::from_millis(200)) {
            Some(r) => r,
            None => {
                if queue.is_closed() && queue.is_empty() {
                    return;
                }
                continue;
            }
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match queue.pop(lane, deadline - now) {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        // Partition the pickup by (engine, shape key); order within a
        // group preserved. Each group is one fused forward delivered as
        // soon as it finishes — a short prompt in the same pickup as a
        // long group does not wait for it.
        let mut groups: Vec<((usize, usize), Vec<Request>)> = Vec::new();
        for r in batch {
            // `r.engine` was resolved by submit() against this fixed
            // engine set; if it ever weren't, dropping `r` closes its
            // reply channel and the client observes `Closed`.
            let Some(engine) = engines.get(r.engine) else {
                continue;
            };
            let key = (r.engine, engine.shape_key(&r.payload));
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, g)) => g.push(r),
                None => groups.push((key, vec![r])),
            }
        }
        // Queue-depth gauges at pickup: the lane's own shard plus the
        // global occupancy, so Perfetto shows where backlog accumulates.
        if crate::trace::enabled() {
            crate::trace::counter(format!("serve.qdepth.lane{lane}"), queue.shard_len(lane) as f64);
            crate::trace::counter("serve.qdepth", queue.len() as f64);
        }
        let run_group = |ei: usize, group: &[Request]| {
            let (Some(engine), Some(tag)) = (engines.get(ei), activation_tags.get(ei)) else {
                return; // unreachable: `ei` indexes the fixed engine set
            };
            let picked = Instant::now();
            if crate::trace::enabled() {
                // One cross-thread range per request: enqueue→pickup. The
                // submit happened on a client thread, so this is emitted as
                // a Complete event with an explicit start timestamp.
                for r in group {
                    crate::trace::complete_at(
                        "serve",
                        "req.queue_wait",
                        r.enqueued,
                        picked.saturating_duration_since(r.enqueued),
                    );
                }
            }
            // Partition the group into contiguous sub-batches whose booked
            // transient fits the lane's activation budget (the whole group
            // when unbudgeted or already fitting). Submit-time rejection
            // guarantees every single request fits, so each sub-batch holds
            // at least one request and the partition always terminates.
            let cap = ledger.budget_for(tag);
            let mut start = 0usize;
            while start < group.len() {
                let mut end = group.len();
                if let Some(cap) = cap {
                    end = start + 1;
                    while end < group.len() {
                        let fits = group.get(start..end + 1).is_some_and(|rs| {
                            let pl: Vec<&Payload> = rs.iter().map(|r| &r.payload).collect();
                            engine.transient_bytes(&pl) <= cap
                        });
                        if !fits {
                            break;
                        }
                        end += 1;
                    }
                }
                let Some(sub) = group.get(start..end) else {
                    return; // unreachable: start < end ≤ group.len()
                };
                start = end;
                stats.record_batch(engine.name(), sub.len());
                let payloads: Vec<&Payload> = sub.iter().map(|r| &r.payload).collect();
                // Book the sub-batch's dominant transient for the duration
                // of the forward, per lane, so the ledger's peak reflects
                // resident + concurrent activations — and, when budgeted,
                // wait for admission so concurrent bookings under one tag
                // never jointly overshoot the cap.
                let transient = engine.transient_bytes(&payloads);
                let batch_span = crate::trace::span_detail("serve", "batch", || {
                    format!("{} n={}", engine.name(), sub.len())
                });
                // Admission blocks on the ledger's notify-on-free condvar
                // ([`MemoryLedger::alloc_blocking`]) instead of a sleep
                // poll: every holder of this tag frees its booking after
                // a finite forward, so the wait always makes progress —
                // and the lane wakes the instant bytes free, not a poll
                // interval later. `Err` means this transient alone can
                // *never* fit the tag's budget (a custom engine's
                // transient grew after the submit-time check, or the
                // budget shrank at runtime): surface it as a counted drop
                // rather than busy-waiting forever.
                if let Err(cap_now) = ledger.alloc_blocking(tag, transient) {
                    stats.record_drop(engine.name(), sub.len());
                    crate::trace::log(&format!(
                        "{}: sub-batch of {} dropped, transient {} B can never fit budget {} B",
                        engine.name(),
                        sub.len(),
                        transient,
                        cap_now
                    ));
                    crate::trace::instant("serve", "group.dropped");
                    continue;
                }
                // Contain engine bugs: on a panic (or a miscounted answer
                // vector) the sub-batch is discarded and each Request's
                // Drop closes its reply channel, so clients observe
                // `Closed` instead of hanging and the lane keeps serving.
                // The transient is freed outside catch_unwind so a
                // panicking engine cannot leak ledger bytes.
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    engine.run_batch(&payloads)
                }));
                ledger.free(tag, transient);
                drop(batch_span);
                let answers = match result {
                    Ok(a) if a.len() == sub.len() => a,
                    Ok(_) | Err(_) => {
                        // The whole sub-batch died (engine panic /
                        // miscounted answers): count it so lost requests
                        // are visible in the heartbeat and final report.
                        stats.record_drop(engine.name(), sub.len());
                        crate::trace::instant("serve", "group.dropped");
                        continue;
                    }
                };
                for (r, a) in sub.iter().zip(answers) {
                    let latency = r.enqueued.elapsed();
                    let queue_wait = picked.saturating_duration_since(r.enqueued);
                    let service = latency.saturating_sub(queue_wait);
                    stats.record_split(
                        engine.name(),
                        queue_wait.as_secs_f64(),
                        service.as_secs_f64(),
                    );
                    if crate::trace::enabled() {
                        crate::trace::complete_at("serve", "req.service", picked, service);
                    }
                    let _ = r.reply.send(Response { id: r.id, answer: a, latency });
                }
            }
        };
        if let [((ei, _), g)] = groups.as_slice() {
            // single group: run inline (its fused matmuls still shard rows
            // on the pool)
            run_group(*ei, g);
        } else {
            // several (engine, shape) groups in one pickup: fan them out
            // across the shared pool, each delivering independently
            let run_ref = &run_group;
            crate::exec::global().scope(|s| {
                for ((ei, _), g) in &groups {
                    s.spawn(move || run_ref(*ei, g));
                }
            });
        }
    }
}

/// Per-sequence decode state held by a continuous-batching lane: the
/// request (whose reply channel streams the tokens), its cache pages,
/// and the per-step ledger booking released at retire.
struct ActiveSeq {
    /// Declared before `req` on purpose: fields drop in declaration
    /// order, so the cache pages return to the pool *before* the reply
    /// channel closes — a client that observes the closed stream can
    /// rely on the pool/ledger already being balanced.
    kv: KvSeq,
    req: Request,
    out: Vec<u32>,
    next: u32,
    max_new: usize,
    eos: Option<u32>,
    /// Step-transient bytes booked under `activations.generate` for the
    /// sequence's whole decode lifetime; freed at retire.
    step_bytes: usize,
    picked: Instant,
    /// Decode error or client disconnect: stop stepping, retire as a
    /// counted drop (the cache pages and booking are still released).
    failed: bool,
}

impl ActiveSeq {
    fn done(&self) -> bool {
        self.failed || self.out.len() >= self.max_new || Some(self.next) == self.eos
    }
}

/// Outcome of one admission attempt in the decode loop.
enum Admit {
    /// Prefilled and streaming: joins the step batch.
    Active(Box<ActiveSeq>),
    /// Pool pages (or budget, on a busy lane) are held elsewhere right
    /// now: park the request and retry after the next step retires.
    Retry(Request),
    /// Unrecoverable (decode error / budget shrank): dropped and counted.
    Dropped,
}

/// Admit one request into a decode lane's step batch: reserve its cache
/// pages, book the prefill transient, seed the cache
/// ([`QuantizedLm::decode_prefill`]), and stream the first token. Only
/// an otherwise-idle lane blocks on the activation budget (`can_block`);
/// a lane with sequences mid-decode parks the request instead so the
/// step batch keeps moving.
fn admit(
    lane: &GenerateLane,
    ledger: &MemoryLedger,
    tag: &str,
    stats: &LaneStats,
    can_block: bool,
    r: Request,
) -> Admit {
    let (prompt, max_new, eos) = match &r.payload {
        Payload::Generate { tokens, max_new, eos } => (tokens.clone(), *max_new, *eos),
        // Misrouted payload (impossible by construction): dropping `r`
        // closes its reply channel so the client observes `Closed`.
        _ => {
            stats.record_drop(LANE_GENERATE, 1);
            return Admit::Dropped;
        }
    };
    let Some(mut kv) = lane.pool.alloc_seq(prompt.len() + max_new.saturating_sub(1)) else {
        // Pool full right now (other sequences hold the pages): park the
        // request; prepare() guaranteed it fits an empty pool and every
        // active sequence retires after finitely many steps, so parked
        // requests always make progress.
        return Admit::Retry(r);
    };
    let prefill_bytes = lane.model.serve_transient_bytes(1, prompt.len());
    let step_bytes = lane.model.serve_transient_bytes(1, 1);
    if can_block {
        if let Err(cap) = ledger.alloc_blocking(tag, prefill_bytes) {
            // The budget shrank below even this one prefill after the
            // submit-time check: surface a counted drop, not a hang.
            stats.record_drop(LANE_GENERATE, 1);
            crate::trace::log(&format!(
                "generate request {} dropped: prefill transient {prefill_bytes} B can never fit budget {cap} B",
                r.id
            ));
            return Admit::Dropped;
        }
    } else if ledger.try_alloc(tag, prefill_bytes).is_err() {
        return Admit::Retry(r);
    }
    let picked = Instant::now();
    let logits = match lane.model.decode_prefill(&mut kv, &prompt) {
        Ok(l) => l,
        Err(e) => {
            ledger.free(tag, prefill_bytes);
            stats.record_drop(LANE_GENERATE, 1);
            crate::trace::log(&format!("generate prefill failed: {e:#}"));
            return Admit::Dropped;
        }
    };
    // Shrink the booking to the per-step transient for the sequence's
    // remaining lifetime — one ledger op (never free-then-realloc), so
    // the tag neither transiently overshoots nor re-waits for admission.
    ledger.free(tag, prefill_bytes.saturating_sub(step_bytes));
    let next = greedy_argmax(logits.row(0)) as u32;
    let mut seq = ActiveSeq {
        req: r,
        kv,
        out: vec![next],
        next,
        max_new,
        eos,
        step_bytes,
        picked,
        failed: false,
    };
    deliver_token(lane, stats, &mut seq, picked);
    Admit::Active(Box::new(seq))
}

/// Stream the newest token of `seq` on its reply channel and record the
/// per-token latency. A failed send means the client went away
/// mid-stream: the sequence is marked failed so the next retire sweep
/// releases its pages and booking.
fn deliver_token(lane: &GenerateLane, stats: &LaneStats, seq: &mut ActiveSeq, started: Instant) {
    let Some(&token) = seq.out.last() else {
        return;
    };
    stats.record_token(LANE_GENERATE, started.elapsed().as_secs_f64());
    let answer = Answer::Token {
        index: seq.out.len() - 1,
        token,
        text: lane.tok.word(token).to_string(),
    };
    let latency = seq.req.enqueued.elapsed();
    if seq.req.reply.send(Response { id: seq.req.id, answer, latency }).is_err() {
        seq.failed = true;
    }
}

/// Retire a finished sequence: release its ledger booking, deliver the
/// final [`Answer::Generated`], and record the request's latency split.
/// Dropping `seq` afterwards releases the cache pages back to the pool
/// and closes the reply channel (the client drains the final answers
/// from the closed channel).
fn retire(lane: &GenerateLane, stats: &LaneStats, ledger: &MemoryLedger, tag: &str, seq: ActiveSeq) {
    ledger.free(tag, seq.step_bytes);
    if seq.failed {
        stats.record_drop(LANE_GENERATE, 1);
        crate::trace::instant("serve", "seq.dropped");
        return;
    }
    let latency = seq.req.enqueued.elapsed();
    let queue_wait = seq.picked.saturating_duration_since(seq.req.enqueued);
    let service = latency.saturating_sub(queue_wait);
    stats.record_split(LANE_GENERATE, queue_wait.as_secs_f64(), service.as_secs_f64());
    if crate::trace::enabled() {
        crate::trace::complete_at("serve", "req.queue_wait", seq.req.enqueued, queue_wait);
        crate::trace::complete_at("serve", "req.service", seq.picked, service);
    }
    let tokens = seq.out.clone();
    let text = lane.tok.decode(&tokens);
    let _ = seq.req.reply.send(Response {
        id: seq.req.id,
        answer: Answer::Generated { tokens, text },
        latency,
    });
}

/// One continuous-batching decode lane: admit sequences from shard
/// `lane` (stealing when idle) into a step batch as pool pages and the
/// activation budget allow, run one cached decode step across every
/// active sequence per iteration, stream each token as it is produced,
/// and retire sequences on EOS / `max_new` / client disconnect.
///
/// Admission happens *between* steps, so a new request waits at most one
/// token time — never a whole batch — before its prefill runs
/// (continuous batching); each step is `O(S)` attention against the
/// paged KV cache instead of the `O(S²)` recompute of the oracle path.
fn decode_loop(
    lane: usize,
    engine: GenerateLane,
    queue: ShardedQueue<Request>,
    stats: LaneStats,
    ledger: MemoryLedger,
    cfg: ServeConfig,
) {
    let tag = crate::metrics::tags::activations(LANE_GENERATE);
    let max_batch = cfg.max_batch.max(1);
    let mut active: Vec<ActiveSeq> = Vec::new();
    let mut pending: VecDeque<Request> = VecDeque::new();
    loop {
        // Pick up new work. An idle lane blocks (shutdown wakes the
        // pop); a lane with sequences in flight drains whatever is
        // already queued without waiting.
        if active.is_empty() && pending.is_empty() {
            match queue.pop(lane, Duration::from_millis(200)) {
                Some(r) => pending.push_back(r),
                None => {
                    if queue.is_closed() && queue.is_empty() {
                        return;
                    }
                    continue;
                }
            }
        }
        while active.len() + pending.len() < max_batch {
            match queue.pop(lane, Duration::ZERO) {
                Some(r) => pending.push_back(r),
                None => break,
            }
        }
        // Admit pending sequences into the step batch until it is full,
        // the pool runs out of pages, or the budget defers admission.
        let mut parked: VecDeque<Request> = VecDeque::new();
        while active.len() < max_batch {
            let Some(r) = pending.pop_front() else {
                break;
            };
            let can_block = active.is_empty() && parked.is_empty();
            match admit(&engine, &ledger, &tag, &stats, can_block, r) {
                Admit::Active(seq) => {
                    if seq.done() {
                        // max_new 1 or EOS on the first token.
                        retire(&engine, &stats, &ledger, &tag, *seq);
                    } else {
                        active.push(*seq);
                    }
                }
                Admit::Retry(r) => parked.push_back(r),
                Admit::Dropped => {}
            }
        }
        // Parked requests retry once the next retire frees pages or
        // budget; they keep their place ahead of newer arrivals.
        while let Some(r) = parked.pop_back() {
            pending.push_front(r);
        }
        if active.is_empty() {
            if pending.is_empty() {
                continue;
            }
            // Everything is parked on resources held by other lanes'
            // sequences: nap briefly — still picking up new arrivals —
            // instead of spinning on admission.
            if let Some(r) = queue.pop(lane, Duration::from_millis(1)) {
                pending.push_back(r);
            }
            continue;
        }
        // One decode step across the whole batch, streaming each token.
        if crate::trace::enabled() {
            crate::trace::counter(format!("serve.qdepth.lane{lane}"), queue.shard_len(lane) as f64);
            crate::trace::counter("serve.decode.batch", active.len() as f64);
        }
        stats.record_batch(LANE_GENERATE, active.len());
        let step_span =
            crate::trace::span_detail("serve", "decode.step", || format!("n={}", active.len()));
        for seq in &mut active {
            let t0 = Instant::now();
            match engine.model.decode_step(&mut seq.kv, seq.next) {
                Ok(logits) => {
                    seq.next = greedy_argmax(logits.row(0)) as u32;
                    seq.out.push(seq.next);
                    deliver_token(&engine, &stats, seq, t0);
                }
                Err(e) => {
                    crate::trace::log(&format!("decode step failed: {e:#}"));
                    seq.failed = true;
                }
            }
        }
        drop(step_span);
        // Retire finished sequences, freeing pages + booking for the
        // parked requests and future admissions.
        let mut i = 0;
        while i < active.len() {
            if active.get(i).is_some_and(|s| s.done()) {
                let seq = active.swap_remove(i);
                retire(&engine, &stats, &ledger, &tag, seq);
            } else {
                i += 1;
            }
        }
    }
}

/// Replay generate prompts through the server from `n_clients` producer
/// threads, draining every stream; returns `(tokens/sec, total tokens)`
/// over the whole replay. Panics if the server rejects or drops a
/// request — replay is only meaningful on a live server.
#[allow(clippy::expect_used)] // bench harness: a dead server must abort the measurement
pub fn replay_generate(
    server: &Server,
    prompts: Vec<Vec<u32>>,
    max_new: usize,
    n_clients: usize,
) -> (f64, usize) {
    let n_clients = n_clients.max(1);
    let mut per_client: Vec<Vec<Vec<u32>>> = (0..n_clients).map(|_| Vec::new()).collect();
    for (i, p) in prompts.into_iter().enumerate() {
        if let Some(c) = per_client.get_mut(i % n_clients) {
            c.push(p);
        }
    }
    let total = std::sync::atomic::AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for chunk in per_client {
            let server = &*server;
            let total = &total;
            scope.spawn(move || {
                for p in chunk {
                    // LINT-ALLOW(no-panic): replay is only meaningful on a
                    // live server; a rejected request must fail the bench.
                    let out = server.generate(p, max_new, None).expect("replay generate");
                    total.fetch_add(out.len(), Ordering::Relaxed);
                }
            });
        }
    });
    let total = total.into_inner();
    (total as f64 / t0.elapsed().as_secs_f64(), total)
}

/// Convenience for benches: replay sentiment prompts through the server
/// from `n_clients` producer threads; returns throughput (req/s).
pub fn replay(server: &Server, tok: &Tokenizer, prompts: &[String], n_clients: usize) -> f64 {
    let items: Vec<Payload> = prompts
        .iter()
        .map(|p| Payload::Sentiment { tokens: tok.encode(p) })
        .collect();
    replay_mixed(server, items, n_clients)
}

/// Replay arbitrary payloads (mixed sentiment + VQA traffic) from
/// `n_clients` producer threads, waiting for every answer; returns
/// throughput (req/s). Panics if the server rejects or drops a request —
/// replay is only meaningful on a live server.
#[allow(clippy::expect_used)] // bench harness: a dead server must abort the measurement
pub fn replay_mixed(server: &Server, items: Vec<Payload>, n_clients: usize) -> f64 {
    let n = items.len();
    let n_clients = n_clients.max(1);
    let mut per_client: Vec<Vec<Payload>> = (0..n_clients).map(|_| Vec::new()).collect();
    for (i, it) in items.into_iter().enumerate() {
        if let Some(c) = per_client.get_mut(i % n_clients) {
            c.push(it);
        }
    }
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for chunk in per_client {
            let server = &*server;
            scope.spawn(move || {
                for p in chunk {
                    // LINT-ALLOW(no-panic): replay is only meaningful on a
                    // live server; a rejected request must fail the bench.
                    let reply = server.submit(p).expect("replay submit");
                    // LINT-ALLOW(no-panic): a dropped reply means the
                    // server under test lost a request — abort loudly.
                    let _ = reply.recv().expect("replay answer");
                }
            });
        }
    });
    n as f64 / t0.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Lexicon;
    use crate::model::config::ModelConfig;
    use crate::model::weights::LmWeights;
    use crate::quant::QuantGrid;
    use crate::rng::Pcg64;
    use crate::vlm::{VlmConfig, VlmWeights};

    fn test_qlm() -> Arc<QuantizedLm> {
        let tok = Lexicon::tokenizer();
        let mcfg = ModelConfig::test_tiny(tok.vocab_size());
        let mut rng = Pcg64::seeded(801);
        let w = LmWeights::init(&mcfg, &mut rng);
        Arc::new(QuantizedLm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete"))
    }

    fn test_qvlm() -> Arc<QuantizedVlm> {
        let tok = Lexicon::tokenizer();
        let vcfg = VlmConfig::test_tiny(tok.vocab_size());
        let mut rng = Pcg64::seeded(802);
        let w = VlmWeights::init(&vcfg, &mut rng);
        Arc::new(QuantizedVlm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete"))
    }

    fn test_server(cfg: ServeConfig) -> (Server, Tokenizer) {
        let tok = Lexicon::tokenizer();
        (Server::start(test_qlm(), &tok, cfg), tok)
    }

    #[test]
    fn serves_single_request() {
        let (server, tok) = test_server(ServeConfig::default());
        let resp = server
            .classify(tok.encode("sentiment of text : i loved this movie answer :"))
            .unwrap();
        assert!(resp.label().unwrap() < 3);
        assert!(resp.latency.as_secs_f64() < 5.0);
    }

    #[test]
    fn serves_concurrent_requests_with_batching() {
        let (server, tok) = test_server(ServeConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(10),
            queue_cap: 64,
            lanes: 2,
            ..Default::default()
        });
        let prompts: Vec<String> = (0..24)
            .map(|i| {
                if i % 2 == 0 {
                    "sentiment of text : i loved this movie answer :".to_string()
                } else {
                    "sentiment of text : my phone is very broken answer :".to_string()
                }
            })
            .collect();
        let tput = replay(&server, &tok, &prompts, 3);
        assert!(tput > 0.0);
        let stats = server.shutdown();
        assert_eq!(stats.count(), 24);
        assert_eq!(stats.lane(LANE_SENTIMENT).unwrap().count(), 24);
    }

    #[test]
    fn all_ids_answered_exactly_once() {
        let (server, tok) = test_server(ServeConfig::default());
        let ids: Vec<u64> = (0..10)
            .map(|_| {
                server
                    .classify(tok.encode("sentiment of text : it was fine answer :"))
                    .unwrap()
                    .id
            })
            .collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }

    #[test]
    fn submit_after_close_returns_err_not_panic() {
        let (server, tok) = test_server(ServeConfig::default());
        let tokens = tok.encode("sentiment of text : it was fine answer :");
        assert!(server.submit_tokens(tokens.clone()).is_ok());
        server.close();
        // regression: this used to be `expect("server queue closed")`
        assert_eq!(server.submit_tokens(tokens.clone()).unwrap_err(), SubmitError::Closed);
        assert_eq!(server.classify(tokens).unwrap_err(), SubmitError::Closed);
        // the request accepted before close is still answered on shutdown
        let stats = server.shutdown();
        assert_eq!(stats.count(), 1);
    }

    #[test]
    fn unsupported_payload_rejected() {
        let (server, _tok) = test_server(ServeConfig::default());
        let patches = Tensor::zeros(&[4, 8]);
        assert_eq!(
            server.submit(Payload::Vqa { patches, question: vec![1, 2] }).unwrap_err(),
            SubmitError::Unsupported
        );
    }

    #[test]
    fn vqa_lane_answers_questions() {
        // fixed kernel: the lane's forward and the reference forward must
        // run the same numerics for the exact-argmax compare below
        let _kernel = crate::model::kernels::kernel_test_lock();
        let tok = Lexicon::tokenizer();
        let qvlm = test_qvlm();
        let vcfg = qvlm.config().clone();
        let server = Server::start_vqa(Arc::clone(&qvlm), &tok, ServeConfig::default());
        let mut rng = Pcg64::seeded(803);
        let patches = Tensor::randn(&[vcfg.n_patches, vcfg.patch_dim], 1.0, &mut rng);
        let question = tok.encode("what genre this book ? answer :");
        let resp = server.ask(patches.clone(), question.clone()).unwrap();
        // answer must match the unbatched row-select forward's argmax
        // exactly (the lane serves via RowSelect::LastRow, so the
        // reference runs the same path)
        let logits = qvlm
            .forward_rows(&patches, &question, 1, RowSelect::LastRow)
            .expect("forward");
        let last = logits.row(0);
        let pred = last
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap() as u32;
        match resp.answer {
            Answer::Vqa { answer_id, ref answer } => {
                assert_eq!(answer_id, pred);
                assert_eq!(answer, tok.word(pred));
            }
            ref other => panic!("expected vqa answer, got {other:?}"),
        }
        // malformed patches are rejected at submit
        let bad = Tensor::zeros(&[vcfg.n_patches + 1, vcfg.patch_dim]);
        assert!(matches!(
            server.submit(Payload::Vqa { patches: bad, question }).unwrap_err(),
            SubmitError::Invalid(_)
        ));
    }

    #[test]
    fn mixed_server_routes_to_both_lanes() {
        let tok = Lexicon::tokenizer();
        let qvlm = test_qvlm();
        let vcfg = qvlm.config().clone();
        let server = Server::start_mixed(
            test_qlm(),
            qvlm,
            &tok,
            ServeConfig { lanes: 2, ..Default::default() },
        );
        let mut rng = Pcg64::seeded(804);
        let mut items = Vec::new();
        for i in 0..12 {
            if i % 3 == 0 {
                let patches = Tensor::randn(&[vcfg.n_patches, vcfg.patch_dim], 1.0, &mut rng);
                items.push(Payload::Vqa {
                    patches,
                    question: tok.encode("who wrote this book ? answer :"),
                });
            } else {
                items.push(Payload::Sentiment {
                    tokens: tok.encode("sentiment of text : it was fine answer :"),
                });
            }
        }
        let tput = replay_mixed(&server, items, 3);
        assert!(tput > 0.0);
        let stats = server.shutdown();
        assert_eq!(stats.count(), 12);
        assert_eq!(stats.lane(LANE_VQA).unwrap().count(), 4);
        assert_eq!(stats.lane(LANE_SENTIMENT).unwrap().count(), 8);
    }

    #[test]
    fn generate_server_streams_bit_identical_to_oracle_deterministic() {
        // fixed kernel: the cached decode and the recompute oracle must
        // run the same numerics for the bit-equality below
        let _kernel = crate::model::kernels::kernel_test_lock();
        let tok = Lexicon::tokenizer();
        let qlm = test_qlm();
        let server = Server::start_generate(Arc::clone(&qlm), &tok, ServeConfig::default());
        let prompt = tok.encode("sentiment of text :");
        let max_new = qlm.config().seq_len + 1 - prompt.len();
        let oracle = qlm.generate_recompute(&prompt, max_new, None).expect("oracle");
        let reply = server.submit_generate(prompt.clone(), max_new, None).expect("submit");
        let mut streamed: Vec<u32> = Vec::new();
        let mut full: Option<Vec<u32>> = None;
        while let Some(resp) = reply.recv() {
            match resp.answer {
                Answer::Token { index, token, .. } => {
                    assert_eq!(index, streamed.len(), "tokens arrive in order");
                    streamed.push(token);
                }
                Answer::Generated { tokens, .. } => full = Some(tokens),
                ref other => panic!("unexpected answer {other:?}"),
            }
        }
        let full = full.expect("final answer after the stream");
        assert_eq!(streamed, full, "stream must match the final answer");
        assert_eq!(full, oracle, "cached decode must match the recompute oracle bitwise");
        // every page is back and the kv_cache + activation tags balance
        let pool = server.kv_pool().expect("generate server has a pool");
        assert_eq!(pool.free_pages(), pool.capacity_pages());
        assert_eq!(server.ledger().live_bytes(), 0);
        let stats = server.shutdown();
        assert_eq!(stats.lane_tokens(LANE_GENERATE).expect("token stats").count(), max_new);
    }

    #[test]
    fn generate_rejections_and_pool_cap() {
        let tok = Lexicon::tokenizer();
        let qlm = test_qlm();
        // a 1-page pool cannot hold even one sequence (2 layers ⇒ every
        // sequence needs at least 2 pages)
        let server = Server::start_generate(
            Arc::clone(&qlm),
            &tok,
            ServeConfig { kv_pages: Some(1), ..Default::default() },
        );
        let prompt = tok.encode("it was fine");
        assert!(matches!(
            server.submit_generate(prompt.clone(), 2, None).unwrap_err(),
            SubmitError::OverBudget { .. }
        ));
        assert!(matches!(
            server.submit_generate(Vec::new(), 2, None).unwrap_err(),
            SubmitError::Invalid(_)
        ));
        assert!(matches!(
            server.submit_generate(prompt.clone(), 0, None).unwrap_err(),
            SubmitError::Invalid(_)
        ));
        // max_new beyond the whole context can never run
        assert!(matches!(
            server.submit_generate(prompt, 64, None).unwrap_err(),
            SubmitError::Invalid(_)
        ));
        // fused payloads have no lane on a generate-only server
        assert_eq!(
            server.submit(Payload::Sentiment { tokens: vec![1] }).unwrap_err(),
            SubmitError::Unsupported
        );
    }

    #[test]
    fn generate_pool_contention_drains_without_deadlock() {
        let _kernel = crate::model::kernels::kernel_test_lock();
        let tok = Lexicon::tokenizer();
        let qlm = test_qlm();
        // pool fits exactly one sequence (2 layers × 1 page each): the
        // two lanes must serialize through it without deadlocking
        let server = Server::start_generate(
            Arc::clone(&qlm),
            &tok,
            ServeConfig { kv_pages: Some(2), lanes: 2, max_batch: 4, ..Default::default() },
        );
        let prompt = tok.encode("sentiment of text :");
        let oracle = qlm.generate_recompute(&prompt, 3, None).expect("oracle");
        std::thread::scope(|scope| {
            for _ in 0..3 {
                let server = &server;
                let prompt = prompt.clone();
                let oracle = oracle.clone();
                scope.spawn(move || {
                    for _ in 0..2 {
                        let out = server.generate(prompt.clone(), 3, None).expect("generate");
                        assert_eq!(out, oracle);
                    }
                });
            }
        });
        let pool = server.kv_pool().expect("pool");
        assert_eq!(pool.free_pages(), pool.capacity_pages());
        assert_eq!(server.ledger().live_bytes(), 0);
        let stats = server.shutdown();
        assert_eq!(stats.lane(LANE_GENERATE).expect("lane stats").count(), 6);
    }

    #[test]
    fn generate_client_disconnect_frees_pool_and_ledger() {
        let tok = Lexicon::tokenizer();
        let qlm = test_qlm();
        let server = Server::start_generate(Arc::clone(&qlm), &tok, ServeConfig::default());
        let prompt = tok.encode("sentiment of text :");
        let reply = server.submit_generate(prompt, 5, None).expect("submit");
        let first = reply.recv().expect("first token");
        assert!(first.token().is_some());
        // The client walks away: whether the lane observes the closed
        // channel mid-stream (send fails ⇒ retired as a drop) or had
        // already finished the short sequence, every page and booking
        // must come back.
        reply.close();
        drop(reply);
        let pool = server.kv_pool().expect("pool").clone();
        let ledger = server.ledger().clone();
        let deadline = Instant::now() + Duration::from_secs(10);
        while (pool.free_pages() != pool.capacity_pages() || ledger.live_bytes() != 0)
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(pool.free_pages(), pool.capacity_pages());
        assert_eq!(ledger.live_bytes(), 0);
    }

    #[test]
    fn generate_lane_oracle_fallback_under_fused_server() {
        let _kernel = crate::model::kernels::kernel_test_lock();
        let tok = Lexicon::tokenizer();
        let qlm = test_qlm();
        let mcfg = qlm.config().clone();
        let pool = KvPool::new(mcfg.n_layers, mcfg.d_model, 4, MemoryLedger::new());
        let lane = GenerateLane::new(Arc::clone(&qlm), &tok, pool);
        let server = Server::start_engines(vec![Box::new(lane)], ServeConfig::default());
        let prompt = tok.encode("it was fine");
        let out = server.generate(prompt.clone(), 3, None).expect("generate");
        let oracle = qlm.generate_recompute(&prompt, 3, None).expect("oracle");
        assert_eq!(out, oracle);
    }

    #[test]
    fn four_lane_server_answers_everything() {
        let (server, tok) = test_server(ServeConfig {
            lanes: 4,
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            queue_cap: 32,
            ..Default::default()
        });
        assert_eq!(server.n_lanes(), 4);
        let prompts: Vec<String> = (0..40)
            .map(|i| format!("sentiment of text : case {} answer :", i % 7))
            .collect();
        let _ = replay(&server, &tok, &prompts, 8);
        let stats = server.shutdown();
        assert_eq!(stats.count(), 40);
    }
}
