//! Shared experiment fixtures: the "world" (corpora + tasks + tokenizer),
//! pretraining drivers, and evaluation wrappers used by the CLI, the
//! examples, and every table bench — so all of them measure exactly the
//! same thing.

use crate::coordinator::serve::Payload;
use crate::data::{SentimentSet, Tokenizer, VqaSet, WikiCorpus};
use crate::eval::{perplexity, sentiment_accuracy, vqa_accuracy, VqaReport};
use crate::model::forward::lm_forward;
use crate::model::weights::LmWeights;
use crate::model::{ModelConfig, QuantizedLm};
use crate::rng::Pcg64;
use crate::tensor::Tensor;
use crate::train::Trainer;
use crate::vlm::train::VlmTrainer;
use crate::vlm::{vlm_forward, QuantizedVlm, VlmConfig, VlmWeights};

use std::path::Path;

/// Paper-protocol constants, scaled where the substitution ledger says so.
pub const CALIB_SAMPLES: usize = 128; // paper: 128 C4 samples
pub const CALIB_SAMPLES_VLM: usize = 64; // paper: 64 CogVLM-SFT samples
pub const SENTIMENT_TEST: usize = 870; // paper: 870 tweets
pub const VQA_TEST_PER_CATEGORY: usize = 40;

/// All synthetic data for one experiment run.
pub struct World {
    pub corpus: WikiCorpus,
    pub sentiment: SentimentSet,
    pub vqa: VqaSet,
    /// Mixed LM training stream (wiki + sentiment prompts).
    pub train_stream: Vec<u32>,
}

impl World {
    pub fn tokenizer(&self) -> &Tokenizer {
        &self.corpus.tokenizer
    }

    /// Build the full world deterministically.
    pub fn build(seed: u64) -> World {
        let corpus = WikiCorpus::generate(seed, 120_000, 12_000);
        let sentiment = SentimentSet::generate(seed + 1, 3_000, SENTIMENT_TEST);
        let vcfg = VlmConfig::sim_cogvlm2(corpus.tokenizer.vocab_size());
        let vqa = VqaSet::generate(
            seed + 2,
            vcfg.n_patches,
            vcfg.patch_dim,
            4_000,
            VQA_TEST_PER_CATEGORY,
        );
        // Mixed stream: wiki text with sentiment examples woven in so the
        // LMs learn both next-token modelling and the classification task.
        let tok = &corpus.tokenizer;
        let mut train_stream = Vec::with_capacity(corpus.train.len() * 2);
        let mut rng = Pcg64::new(seed + 3, 41);
        let mut wiki_pos = 0usize;
        let wiki_chunk = 96;
        let mut sent_idx = 0usize;
        while wiki_pos + wiki_chunk < corpus.train.len() {
            train_stream.extend_from_slice(&corpus.train[wiki_pos..wiki_pos + wiki_chunk]);
            wiki_pos += wiki_chunk;
            // 2-3 sentiment examples between wiki chunks
            for _ in 0..2 + rng.next_below(2) {
                let e = &sentiment.train[sent_idx % sentiment.train.len()];
                sent_idx += 1;
                train_stream.extend(tok.encode(&e.with_answer()));
            }
        }
        World { corpus, sentiment, vqa, train_stream }
    }

    /// Training batch from the mixed stream.
    pub fn sample_batch(&self, rng: &mut Pcg64, batch: usize, seq: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = rng.next_below(self.train_stream.len() - seq);
            out.extend_from_slice(&self.train_stream[start..start + seq]);
        }
        out
    }

    /// Calibration windows for the LM pipelines (the paper's 128 samples).
    pub fn calib_windows(&self, seq: usize, n: usize) -> Vec<Vec<u32>> {
        // Drawn from the mixed stream so the Hessians see task-relevant
        // activations, mirroring "C4 calibration" for instruction models.
        let mut rng = Pcg64::new(777, 42);
        (0..n)
            .map(|_| {
                let start = rng.next_below(self.train_stream.len() - seq);
                self.train_stream[start..start + seq].to_vec()
            })
            .collect()
    }

    /// Calibration samples for the VLM pipeline.
    pub fn vlm_calib(&self, n: usize) -> Vec<(Tensor, Vec<u32>)> {
        let tok = self.tokenizer();
        self.vqa
            .train
            .iter()
            .take(n)
            .map(|e| {
                let mut ids = tok.encode(&e.question);
                ids.push(tok.id(&e.answer));
                (e.cover.patches.clone(), ids)
            })
            .collect()
    }

    /// Replay payload stream for the serve CLI/bench/examples: sentiment
    /// prompts (`"sentiment"`), VQA pairs (`"vqa"`), or both interleaved
    /// (any other mode), cycled from the world's test sets to `n` items.
    pub fn replay_items(&self, mode: &str, n: usize) -> Vec<Payload> {
        let tok = self.tokenizer();
        let sent = self.sentiment.test.iter().cycle().map(|e| Payload::Sentiment {
            tokens: tok.encode(&e.prompt()),
        });
        let vqa = self.vqa.test.iter().cycle().map(|e| Payload::Vqa {
            patches: e.cover.patches.clone(),
            question: tok.encode(&e.question),
        });
        match mode {
            "sentiment" => sent.take(n).collect(),
            "vqa" => vqa.take(n).collect(),
            _ => sent.zip(vqa).flat_map(|(s, v)| [s, v]).take(n).collect(),
        }
    }
}

/// Pretrain one LM preset on the world's mixed stream.
pub fn pretrain_lm(
    cfg: &ModelConfig,
    world: &World,
    steps: usize,
    batch: usize,
    seed: u64,
    mut log: impl FnMut(usize, f64),
) -> (LmWeights, Vec<(usize, f64)>) {
    let mut rng = Pcg64::new(seed, 51);
    let mut w = LmWeights::init(cfg, &mut rng);
    let mut sampler = Pcg64::new(seed, 52);
    let mut trainer = Trainer::new(3e-3, batch);
    trainer.adam = crate::train::Adam::new(3e-3).with_cosine(steps);
    // No decoupled weight decay: like real LLM checkpoints, the subject
    // models should develop weight outliers — that magnitude spread is
    // precisely what makes low-bit PTQ lossy (and what GPTQ/RPIQ fight).
    trainer.adam.weight_decay = 0.0;
    let seq = cfg.seq_len;
    let curve = trainer.train(
        &mut w,
        steps,
        || world.sample_batch(&mut sampler, batch, seq),
        |s, l| log(s, l),
    );
    (w, curve)
}

/// Pretrain the VLM on the world's VQA training set.
pub fn pretrain_vlm(
    cfg: &VlmConfig,
    world: &World,
    steps: usize,
    batch: usize,
    seed: u64,
    mut log: impl FnMut(usize, f64),
) -> (VlmWeights, Vec<(usize, f64)>) {
    let mut rng = Pcg64::new(seed, 61);
    let mut w = VlmWeights::init(cfg, &mut rng);
    let mut trainer = VlmTrainer::new(2e-3);
    let tok = world.tokenizer();
    let curve = trainer.train(
        &mut w,
        tok,
        &world.vqa.train,
        steps,
        batch,
        &mut rng,
        |s, l| log(s, l),
    );
    (w, curve)
}

/// LM evaluation bundle: (sentiment acc %, PPL).
pub struct LmEval {
    pub acc_pct: f64,
    pub ppl: f64,
}

/// Evaluate a full-precision LM.
pub fn eval_lm_fp(w: &LmWeights, world: &World, n_eval_windows: usize, n_sent: usize) -> LmEval {
    let f = |t: &[u32], b: usize, s: usize| lm_forward(w, t, b, s, None);
    eval_with(&f, w.config.seq_len, world, n_eval_windows, n_sent)
}

/// Evaluate a quantized LM.
pub fn eval_lm_q(q: &QuantizedLm, world: &World, n_eval_windows: usize, n_sent: usize) -> LmEval {
    let f = |t: &[u32], b: usize, s: usize| q.forward(t, b, s).expect("quantized forward");
    eval_with(&f, q.config().seq_len, world, n_eval_windows, n_sent)
}

fn eval_with(
    f: &dyn Fn(&[u32], usize, usize) -> Tensor,
    seq: usize,
    world: &World,
    n_eval_windows: usize,
    n_sent: usize,
) -> LmEval {
    let windows: Vec<Vec<u32>> = world
        .corpus
        .eval_windows(seq)
        .into_iter()
        .take(n_eval_windows)
        .collect();
    let ppl = perplexity(&f, &windows);
    let acc = sentiment_accuracy(
        &f,
        world.tokenizer(),
        &world.sentiment.test[..n_sent.min(world.sentiment.test.len())],
        seq,
    );
    LmEval { acc_pct: acc, ppl }
}

/// Evaluate a fp VLM on the VQA test set.
pub fn eval_vlm_fp(w: &VlmWeights, world: &World) -> VqaReport {
    let f = |p: &Tensor, t: &[u32], b: usize| vlm_forward(w, p, t, b, None);
    vqa_accuracy(&f, world.tokenizer(), &world.vqa.test, w.config.n_patches)
}

/// Evaluate a quantized VLM on the VQA test set.
pub fn eval_vlm_q(q: &QuantizedVlm, world: &World) -> VqaReport {
    let f = |p: &Tensor, t: &[u32], b: usize| q.forward(p, t, b).expect("quantized forward");
    vqa_accuracy(&f, world.tokenizer(), &world.vqa.test, q.config().n_patches)
}

/// Checkpoint path helpers.
pub fn ckpt_path(dir: &Path, name: &str) -> std::path::PathBuf {
    dir.join(format!("{name}.ckpt"))
}

/// Default steps used by `make checkpoints` (tuned so the full pretrain of
/// 4 LMs + VLM fits the CI budget while reaching clearly-above-chance task
/// accuracy).
pub const DEFAULT_LM_STEPS: usize = 300;
pub const DEFAULT_LM_BATCH: usize = 8;
pub const DEFAULT_VLM_STEPS: usize = 400;
pub const DEFAULT_VLM_BATCH: usize = 8;

/// Standard world seed shared by CLI/benches/examples.
pub const WORLD_SEED: u64 = 20260710;

/// Artifact-path group size per preset — the paper's group-128 scaled so
/// the group divides every linear's input width. MUST stay in sync with
/// `python/compile/model.py::GROUP_SIZES` (the artifacts integration test
/// checks shapes through the manifest).
pub fn group_size_for(preset: &str) -> usize {
    match preset {
        "sim-opt-6.7b" => 64,
        "sim-opt-13b" => 32,
        "sim-qwen3-8b" | "sim-llama-3.1-8b-instruct" => 48,
        _ => 64,
    }
}

/// The standard experiment quantization config for a preset.
pub fn quant_config_for(preset: &str) -> crate::quant::QuantConfig {
    let gs = group_size_for(preset);
    crate::quant::QuantConfig { bits: 4, group_size: gs, block_size: gs, percdamp: 0.01 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_builds_and_streams() {
        let w = World::build(1);
        assert!(w.train_stream.len() > 100_000);
        let mut rng = Pcg64::seeded(2);
        let b = w.sample_batch(&mut rng, 2, 48);
        assert_eq!(b.len(), 96);
        let cal = w.calib_windows(48, 16);
        assert_eq!(cal.len(), 16);
        // calibration is deterministic across calls
        assert_eq!(cal, w.calib_windows(48, 16));
        let vc = w.vlm_calib(8);
        assert_eq!(vc.len(), 8);
    }

    #[test]
    fn train_stream_contains_sentiment_prompts() {
        let w = World::build(3);
        let tok = w.tokenizer();
        let answer_id = tok.id("answer");
        assert!(w.train_stream.iter().filter(|&&t| t == answer_id).count() > 100);
    }
}
