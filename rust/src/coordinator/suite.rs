//! The main experiment suite: runs every arm the paper's evaluation
//! section needs (Tables 1–5, Figs 4–5) **once** and caches the results to
//! `reports/suite.json`. Every table bench renders from the cache, so
//! `cargo bench` pays the quantization cost a single time regardless of
//! bench ordering.

use super::experiments::{self as exp, World};
use super::pipeline::{quantize_lm, quantize_vlm, LayerReport, Method};
use crate::jsonx::Json;
use crate::model::io::load_lm;
use crate::model::ModelConfig;
use crate::quant::{CmdqPolicy, RpiqParams};
use crate::vlm::io::load_vlm;
use anyhow::{Context, Result};
use std::path::Path;

/// One quantization arm's outcome for an LM.
#[derive(Clone, Debug)]
pub struct ArmResult {
    pub acc_pct: f64,
    pub ppl: f64,
    /// Deployment weight bytes.
    pub deploy_bytes: usize,
    /// Quantization-process peak (ledger) bytes.
    pub peak_bytes: i64,
    /// Quantization wall time.
    pub quant_secs: f64,
    pub layer_reports: Vec<LayerReportLite>,
}

/// Serializable slice of [`LayerReport`].
#[derive(Clone, Debug)]
pub struct LayerReportLite {
    pub name: String,
    pub loss_trace: Vec<f64>,
    pub iters_run: usize,
    pub early_stopped: bool,
}

impl LayerReportLite {
    fn from(r: &LayerReport) -> Self {
        LayerReportLite {
            name: r.name.clone(),
            loss_trace: r.loss_trace.clone(),
            iters_run: r.iters_run,
            early_stopped: r.early_stopped,
        }
    }

    pub fn initial_loss(&self) -> f64 {
        self.loss_trace[0]
    }

    pub fn final_loss(&self) -> f64 {
        self.loss_trace.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn reduction_pct(&self) -> f64 {
        let i = self.initial_loss();
        if i <= 0.0 {
            return 0.0;
        }
        100.0 * (i - self.final_loss()) / i
    }
}

/// All arms for one LM preset.
#[derive(Clone, Debug)]
pub struct ModelSuite {
    pub name: String,
    pub fp_acc_pct: f64,
    pub fp_ppl: f64,
    pub fp_bytes: usize,
    pub gptq: ArmResult,
    pub rpiq: ArmResult,
}

/// VLM arms (Table 2).
#[derive(Clone, Debug)]
pub struct VlmSuite {
    pub fp_overall: f64,
    pub fp_per_category: Vec<(String, f64)>,
    pub fp_bytes: usize,
    /// (label, overall, per-category, deploy bytes, peak bytes, secs,
    /// layer reports)
    pub arms: Vec<VlmArm>,
}

#[derive(Clone, Debug)]
pub struct VlmArm {
    pub label: String,
    pub overall: f64,
    pub per_category: Vec<(String, f64)>,
    pub deploy_bytes: usize,
    pub peak_bytes: i64,
    pub quant_secs: f64,
    pub layer_reports: Vec<LayerReportLite>,
}

/// The full suite result.
#[derive(Clone, Debug)]
pub struct Suite {
    pub models: Vec<ModelSuite>,
    pub vlm: VlmSuite,
}

/// Evaluation sizes (tuned for bench wall-clock on 1 core).
pub const EVAL_WINDOWS: usize = 80;
pub const EVAL_SENT: usize = 870;

/// Run (or load from cache) the full suite.
pub fn load_or_run(ckpt_dir: &Path) -> Result<Suite> {
    let cache = Path::new("reports/suite.json");
    if cache.exists() {
        let text = std::fs::read_to_string(cache)?;
        if let Ok(s) = from_json(&Json::parse(&text)?) {
            crate::trace::log("[suite] using cached reports/suite.json");
            return Ok(s);
        }
    }
    let s = run(ckpt_dir)?;
    std::fs::create_dir_all("reports")?;
    std::fs::write(cache, to_json(&s).pretty())?;
    Ok(s)
}

/// Run everything fresh.
pub fn run(ckpt_dir: &Path) -> Result<Suite> {
    let world = World::build(exp::WORLD_SEED);
    let vocab = world.tokenizer().vocab_size();
    let mut models = Vec::new();

    for cfg in ModelConfig::lm_presets(vocab) {
        let path = exp::ckpt_path(ckpt_dir, &cfg.name);
        let w = load_lm(&path)
            .with_context(|| format!("load {} (run `make checkpoints`)", path.display()))?;
        crate::trace::log(&format!("[suite] {}: fp eval", cfg.name));
        let fp = exp::eval_lm_fp(&w, &world, EVAL_WINDOWS, EVAL_SENT);
        let windows = world.calib_windows(cfg.seq_len, exp::CALIB_SAMPLES);
        let qcfg = exp::quant_config_for(&cfg.name);

        let arm = |method: Method, label: &str| -> Result<ArmResult> {
            crate::trace::log(&format!("[suite] {}: {} quantize+eval", cfg.name, label));
            let t0 = std::time::Instant::now();
            let out = quantize_lm(&w, &windows, qcfg, method)?;
            let quant_secs = t0.elapsed().as_secs_f64();
            let ev = exp::eval_lm_q(&out.model, &world, EVAL_WINDOWS, EVAL_SENT);
            Ok(ArmResult {
                acc_pct: ev.acc_pct,
                ppl: ev.ppl,
                deploy_bytes: out.model.deploy_bytes(),
                peak_bytes: out.ledger.peak_bytes(),
                quant_secs,
                layer_reports: out.reports.iter().map(LayerReportLite::from).collect(),
            })
        };

        let gptq = arm(Method::Gptq, "GPTQ")?;
        let rpiq = arm(Method::Rpiq(RpiqParams::default()), "RPIQ")?;
        models.push(ModelSuite {
            name: cfg.name.clone(),
            fp_acc_pct: fp.acc_pct,
            fp_ppl: fp.ppl,
            fp_bytes: cfg.fp32_bytes(),
            gptq,
            rpiq,
        });
    }

    // ---- VLM (Table 2) ----
    let vpath = exp::ckpt_path(ckpt_dir, "sim-cogvlm2-19b");
    let vw = load_vlm(&vpath)
        .with_context(|| format!("load {} (run `make checkpoints`)", vpath.display()))?;
    crate::trace::log("[suite] vlm: fp eval");
    let fp_rep = exp::eval_vlm_fp(&vw, &world);
    let samples = world.vlm_calib(exp::CALIB_SAMPLES_VLM);
    let mut arms = Vec::new();
    let arm_specs: Vec<(&str, Method, usize)> = vec![
        ("CMDQ (GPTQ base)", Method::Gptq, 5),
        ("CMDQ + RPIQ (5 iter)", Method::Rpiq(RpiqParams::default()), 5),
        (
            "CMDQ + RPIQ (20 iter)",
            Method::Rpiq(RpiqParams { max_iters: 20, early_stop: false, ..Default::default() }),
            20,
        ),
    ];
    for (label, method, iters) in arm_specs {
        crate::trace::log(&format!("[suite] vlm: {label}"));
        let policy = CmdqPolicy {
            rpiq: match method {
                Method::Rpiq(p) => p,
                Method::Gptq => RpiqParams::default(),
            },
            ..Default::default()
        }
        .with_iters(iters);
        let t0 = std::time::Instant::now();
        let out = quantize_vlm(&vw, &samples, &policy, method)?;
        let quant_secs = t0.elapsed().as_secs_f64();
        let rep = exp::eval_vlm_q(&out.model, &world);
        arms.push(VlmArm {
            label: label.to_string(),
            overall: rep.overall_pct,
            per_category: rep.per_category,
            deploy_bytes: out.model.deploy_bytes(),
            peak_bytes: out.ledger.peak_bytes(),
            quant_secs,
            layer_reports: out.reports.iter().map(LayerReportLite::from).collect(),
        });
    }

    Ok(Suite {
        models,
        vlm: VlmSuite {
            fp_overall: fp_rep.overall_pct,
            fp_per_category: fp_rep.per_category,
            fp_bytes: vw.n_params() * 4,
            arms,
        },
    })
}

// ---------- JSON (de)serialization ----------

fn reports_to_json(rs: &[LayerReportLite]) -> Json {
    Json::Arr(
        rs.iter()
            .map(|r| {
                Json::obj()
                    .with("name", Json::Str(r.name.clone()))
                    .with("trace", Json::from_f64s(&r.loss_trace))
                    .with("iters", Json::Num(r.iters_run as f64))
                    .with("early", Json::Bool(r.early_stopped))
            })
            .collect(),
    )
}

fn reports_from_json(j: &Json) -> Result<Vec<LayerReportLite>> {
    j.as_arr()
        .context("reports")?
        .iter()
        .map(|r| {
            Ok(LayerReportLite {
                name: r.get("name").and_then(|x| x.as_str()).context("name")?.to_string(),
                loss_trace: r
                    .get("trace")
                    .and_then(|x| x.as_arr())
                    .context("trace")?
                    .iter()
                    .map(|v| v.as_f64().context("num"))
                    .collect::<Result<_>>()?,
                iters_run: r.get("iters").and_then(|x| x.as_usize()).context("iters")?,
                early_stopped: r.get("early").and_then(|x| x.as_bool()).context("early")?,
            })
        })
        .collect()
}

fn arm_to_json(a: &ArmResult) -> Json {
    Json::obj()
        .with("acc", Json::Num(a.acc_pct))
        .with("ppl", Json::Num(a.ppl))
        .with("deploy_bytes", Json::Num(a.deploy_bytes as f64))
        .with("peak_bytes", Json::Num(a.peak_bytes as f64))
        .with("secs", Json::Num(a.quant_secs))
        .with("reports", reports_to_json(&a.layer_reports))
}

fn arm_from_json(j: &Json) -> Result<ArmResult> {
    Ok(ArmResult {
        acc_pct: j.get("acc").and_then(|x| x.as_f64()).context("acc")?,
        ppl: j.get("ppl").and_then(|x| x.as_f64()).context("ppl")?,
        deploy_bytes: j.get("deploy_bytes").and_then(|x| x.as_usize()).context("bytes")?,
        peak_bytes: j.get("peak_bytes").and_then(|x| x.as_f64()).context("peak")? as i64,
        quant_secs: j.get("secs").and_then(|x| x.as_f64()).context("secs")?,
        layer_reports: reports_from_json(j.get("reports").context("reports")?)?,
    })
}

fn cats_to_json(c: &[(String, f64)]) -> Json {
    Json::Arr(
        c.iter()
            .map(|(k, v)| Json::obj().with("cat", Json::Str(k.clone())).with("acc", Json::Num(*v)))
            .collect(),
    )
}

fn cats_from_json(j: &Json) -> Result<Vec<(String, f64)>> {
    j.as_arr()
        .context("cats")?
        .iter()
        .map(|c| {
            Ok((
                c.get("cat").and_then(|x| x.as_str()).context("cat")?.to_string(),
                c.get("acc").and_then(|x| x.as_f64()).context("acc")?,
            ))
        })
        .collect()
}

/// Serialize the suite.
pub fn to_json(s: &Suite) -> Json {
    let models = Json::Arr(
        s.models
            .iter()
            .map(|m| {
                Json::obj()
                    .with("name", Json::Str(m.name.clone()))
                    .with("fp_acc", Json::Num(m.fp_acc_pct))
                    .with("fp_ppl", Json::Num(m.fp_ppl))
                    .with("fp_bytes", Json::Num(m.fp_bytes as f64))
                    .with("gptq", arm_to_json(&m.gptq))
                    .with("rpiq", arm_to_json(&m.rpiq))
            })
            .collect(),
    );
    let vlm_arms = Json::Arr(
        s.vlm
            .arms
            .iter()
            .map(|a| {
                Json::obj()
                    .with("label", Json::Str(a.label.clone()))
                    .with("overall", Json::Num(a.overall))
                    .with("cats", cats_to_json(&a.per_category))
                    .with("deploy_bytes", Json::Num(a.deploy_bytes as f64))
                    .with("peak_bytes", Json::Num(a.peak_bytes as f64))
                    .with("secs", Json::Num(a.quant_secs))
                    .with("reports", reports_to_json(&a.layer_reports))
            })
            .collect(),
    );
    Json::obj().with("models", models).with(
        "vlm",
        Json::obj()
            .with("fp_overall", Json::Num(s.vlm.fp_overall))
            .with("fp_cats", cats_to_json(&s.vlm.fp_per_category))
            .with("fp_bytes", Json::Num(s.vlm.fp_bytes as f64))
            .with("arms", vlm_arms),
    )
}

/// Deserialize the suite.
pub fn from_json(j: &Json) -> Result<Suite> {
    let models = j
        .get("models")
        .and_then(|m| m.as_arr())
        .context("models")?
        .iter()
        .map(|m| {
            Ok(ModelSuite {
                name: m.get("name").and_then(|x| x.as_str()).context("name")?.to_string(),
                fp_acc_pct: m.get("fp_acc").and_then(|x| x.as_f64()).context("fp_acc")?,
                fp_ppl: m.get("fp_ppl").and_then(|x| x.as_f64()).context("fp_ppl")?,
                fp_bytes: m.get("fp_bytes").and_then(|x| x.as_usize()).context("fp_bytes")?,
                gptq: arm_from_json(m.get("gptq").context("gptq")?)?,
                rpiq: arm_from_json(m.get("rpiq").context("rpiq")?)?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let v = j.get("vlm").context("vlm")?;
    let arms = v
        .get("arms")
        .and_then(|a| a.as_arr())
        .context("arms")?
        .iter()
        .map(|a| {
            Ok(VlmArm {
                label: a.get("label").and_then(|x| x.as_str()).context("label")?.to_string(),
                overall: a.get("overall").and_then(|x| x.as_f64()).context("overall")?,
                per_category: cats_from_json(a.get("cats").context("cats")?)?,
                deploy_bytes: a.get("deploy_bytes").and_then(|x| x.as_usize()).context("db")?,
                peak_bytes: a.get("peak_bytes").and_then(|x| x.as_f64()).context("pb")? as i64,
                quant_secs: a.get("secs").and_then(|x| x.as_f64()).context("secs")?,
                layer_reports: reports_from_json(a.get("reports").context("reports")?)?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Suite {
        models,
        vlm: VlmSuite {
            fp_overall: v.get("fp_overall").and_then(|x| x.as_f64()).context("fpo")?,
            fp_per_category: cats_from_json(v.get("fp_cats").context("fp_cats")?)?,
            fp_bytes: v.get("fp_bytes").and_then(|x| x.as_usize()).context("fpb")?,
            arms,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_json_roundtrip() {
        let s = Suite {
            models: vec![ModelSuite {
                name: "m".into(),
                fp_acc_pct: 50.0,
                fp_ppl: 3.0,
                fp_bytes: 1000,
                gptq: ArmResult {
                    acc_pct: 49.0,
                    ppl: 3.1,
                    deploy_bytes: 300,
                    peak_bytes: 5000,
                    quant_secs: 1.5,
                    layer_reports: vec![LayerReportLite {
                        name: "l0".into(),
                        loss_trace: vec![2.0, 1.0],
                        iters_run: 1,
                        early_stopped: false,
                    }],
                },
                rpiq: ArmResult {
                    acc_pct: 50.0,
                    ppl: 3.05,
                    deploy_bytes: 300,
                    peak_bytes: 6000,
                    quant_secs: 1.8,
                    layer_reports: vec![],
                },
            }],
            vlm: VlmSuite {
                fp_overall: 70.0,
                fp_per_category: vec![("cookbooks".into(), 71.0)],
                fp_bytes: 2000,
                arms: vec![VlmArm {
                    label: "CMDQ".into(),
                    overall: 68.0,
                    per_category: vec![("cookbooks".into(), 69.0)],
                    deploy_bytes: 600,
                    peak_bytes: 7000,
                    quant_secs: 2.0,
                    layer_reports: vec![],
                }],
            },
        };
        let j = to_json(&s);
        let s2 = from_json(&Json::parse(&j.pretty()).unwrap()).unwrap();
        assert_eq!(s2.models[0].gptq.peak_bytes, 5000);
        assert_eq!(s2.vlm.arms[0].label, "CMDQ");
        assert_eq!(s2.models[0].gptq.layer_reports[0].loss_trace, vec![2.0, 1.0]);
    }

    #[test]
    fn layer_report_lite_metrics() {
        let r = LayerReportLite {
            name: "x".into(),
            loss_trace: vec![10.0, 6.0, 8.0],
            iters_run: 2,
            early_stopped: true,
        };
        assert_eq!(r.initial_loss(), 10.0);
        assert_eq!(r.final_loss(), 6.0);
        assert!((r.reduction_pct() - 40.0).abs() < 1e-9);
    }
}
