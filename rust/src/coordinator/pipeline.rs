//! The quantization pipeline: the paper's §4.1 procedure end to end.
//!
//! For each model:
//! 1. **Calibration sweep** — stream the calibration windows through the
//!    fp model with an [`ActivationTap`]; per linear layer accumulate the
//!    Hessian `H ≈ XᵀX` batch by batch and retain the **last** batch's
//!    input (the single instance, §3.2).
//! 2. **Stage 1** — GPTQ per layer.
//! 3. **Stage 2** (RPIQ only) — residual closed-loop refinement on the
//!    single instance; Γ traces are collected for Table 5 / Fig 5.
//!
//! Memory accounting: every transient the pipeline allocates is registered
//! with the [`MemoryLedger`], so `peak(GPTQ arm)` vs `peak(RPIQ arm)`
//! reproduces Table 3's ΔM on our substrate; wall-clock is split into
//! calibration/stage1/stage2 timers for Table 4.
//!
//! # Parallel quantization (end to end)
//!
//! Every stage of the pipeline draws from the global pool (`crate::exec`):
//!
//! * **Calibration** fans independent windows out in waves; each window
//!   job accumulates private per-layer `XᵀX` partials that are replay-
//!   merged in window-index order (see [`calibrate`]), so damped Hessians
//!   are byte-identical at any thread count.
//! * **Per-layer fan-out**: each linear layer's stage 1 (+ stage 2)
//!   depends only on its own calibration state (`H`, retained instance) —
//!   layers are independent, so the pipeline fans them out and joins
//!   before assembling reports.
//! * **Within a layer**, GPTQ's column walk and RPIQ's grid projector
//!   shard *output rows* (rows are independent given the shared Cholesky
//!   factor), with a flop cutoff mirroring the matmul one — see
//!   `quant::gptq` / `quant::rpiq`.
//!
//! Per-row/per-window numerics are untouched (each unit runs the exact
//! sequential float-op sequence), so Γ traces, packed levels, and Hessians
//! are **byte-identical** to a single-threaded run for any `RPIQ_THREADS`
//! — asserted by `gamma_traces_deterministic_across_thread_counts` and
//! `calibration_deterministic_across_thread_counts`, and enforced in CI by
//! the determinism matrix job at `RPIQ_THREADS=1/2/8`. Only ledger *peaks*
//! and timer totals may vary with scheduling (more work in flight ⇒ more
//! concurrent transients); live-byte accounting still balances to zero.

use crate::metrics::{tags, MemoryLedger, Timers};
use crate::model::forward::{lm_forward, ActivationTap};
use crate::model::weights::LmWeights;
use crate::model::QuantizedLm;
use crate::quant::calib::{HessianAccumulator, HessianPartial, SingleInstance};
use crate::quant::{
    gptq_quantize, rpiq_refine, CmdqPolicy, QuantConfig, QuantizedLinear, RpiqParams,
};
use crate::tensor::Tensor;
use crate::vlm::{vlm_forward, QuantizedVlm, VlmWeights};
use anyhow::Result;
use std::collections::HashMap;

/// Which quantizer to run.
#[derive(Clone, Copy, Debug)]
pub enum Method {
    /// Stage 1 only (the baseline).
    Gptq,
    /// Stage 1 + stage 2 refinement.
    Rpiq(RpiqParams),
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Gptq => "GPTQ",
            Method::Rpiq(_) => "RPIQ",
        }
    }
}

/// Per-layer outcome (Table 5 rows are drawn from these).
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    /// Γ trace; `[0]` is the stage-1 loss. Length 1 for plain GPTQ.
    pub loss_trace: Vec<f64>,
    pub iters_run: usize,
    pub early_stopped: bool,
    pub stage1_secs: f64,
    pub stage2_secs: f64,
}

impl LayerReport {
    pub fn initial_loss(&self) -> f64 {
        self.loss_trace[0]
    }

    /// Loss of the *deployed* weights: the best iterate (the trace's last
    /// entry can be the increase that triggered early stopping).
    pub fn final_loss(&self) -> f64 {
        self.loss_trace.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn reduction_pct(&self) -> f64 {
        let i = self.initial_loss();
        if i <= 0.0 {
            return 0.0;
        }
        100.0 * (i - self.final_loss()) / i
    }
}

/// Pipeline result for an LM.
pub struct PipelineOutput {
    pub model: QuantizedLm,
    pub reports: Vec<LayerReport>,
    pub ledger: MemoryLedger,
    pub timers: Timers,
}

/// Calibration state of one linear layer after the sweep.
struct LayerCalib {
    h: Tensor,
    /// The retained single instance (paper Eq. 11). `None` for the plain
    /// GPTQ arm, which — like the reference implementation — discards
    /// every calibration batch after the Hessian update. Retaining it is
    /// exactly the memory cost RPIQ pays (Table 3's ΔM).
    last_x: Option<Tensor>,
}

/// Stream calibration windows through a tap-instrumented forward,
/// returning per-layer damped Hessians (and, when `retain_last`, the
/// last-batch inputs).
///
/// # Parallel fan-out
///
/// Windows are independent given per-layer accumulators, so they fan out
/// across the global pool in **waves** of `exec::num_threads()` windows:
/// each window job runs its own tap-instrumented forward and accumulates
/// the per-layer `XᵀX` into a private [`HessianPartial`]; after each wave
/// the partials are replay-merged into the per-layer accumulators in
/// window-index order ([`HessianAccumulator::merge`]). The merge replays
/// the *sequential* float-op sequence, so damped Hessians (and the
/// retained last batch) are byte-identical at any thread count — asserted
/// by `calibration_deterministic_across_thread_counts`. Waves bound the
/// transient partial memory to `threads × layers × in²` instead of
/// `windows × layers × in²`, keeping Table 3's ΔM calibration-independent;
/// every partial byte is ledger-accounted (`hessian_partial`).
fn calibrate<F>(
    layer_names: &[String],
    windows: &[Vec<u32>],
    percdamp: f32,
    retain_last: bool,
    ledger: &MemoryLedger,
    fwd: F,
) -> HashMap<String, LayerCalib>
where
    F: Fn(&[u32], &mut ActivationTap) + Sync,
{
    let nw = windows.len();
    let wave = crate::exec::num_threads().clamp(1, nw.max(1));
    let mut accs: HashMap<String, HessianAccumulator> = HashMap::new();
    let mut last_x: HashMap<String, Tensor> = HashMap::new();
    let fwd = &fwd;
    for (ci, chunk) in windows.chunks(wave).enumerate() {
        let jobs: Vec<_> = chunk
            .iter()
            .enumerate()
            .map(|(k, w)| {
                let wi = ci * wave + k;
                move || {
                    let _span =
                        crate::trace::span_detail("quant", "calib.window", || format!("w{wi}"));
                    let mut tap = ActivationTap::new();
                    fwd(w, &mut tap);
                    let mut partials: HashMap<String, HessianPartial> = HashMap::new();
                    let mut last: HashMap<String, Tensor> = HashMap::new();
                    for name in layer_names {
                        let x = tap
                            .take(name)
                            .unwrap_or_else(|| panic!("tap missed layer {name}"));
                        let mut p = HessianPartial::new(x.cols(), ledger.clone());
                        p.add_window(wi, &x);
                        partials.insert(name.clone(), p);
                        if retain_last && wi + 1 == nw {
                            // the single instance (paper Eq. 11): only the
                            // LAST batch is retained beyond the sweep.
                            ledger.alloc(tags::CALIB_LAST_BATCH, x.nbytes());
                            last.insert(name.clone(), x);
                        }
                    }
                    (partials, last)
                }
            })
            .collect();
        // map() joins in window order; at an effective parallelism of 1 the
        // jobs run inline in that same order.
        for (mut partials, last) in crate::exec::global().map(jobs) {
            for name in layer_names {
                let p = partials.remove(name).expect("partial for every layer");
                let acc = accs.entry(name.clone()).or_insert_with(|| {
                    HessianAccumulator::new(p.in_features(), ledger.clone())
                });
                acc.merge(vec![p]);
            }
            last_x.extend(last);
        }
    }
    let mut out = HashMap::new();
    let _finalize = crate::trace::span("quant", "calib.finalize");
    for name in layer_names {
        let acc = accs.remove(name).unwrap();
        let (h, _lambda) = acc.finalize(percdamp);
        ledger.alloc(tags::HESSIAN_FINAL, h.nbytes());
        out.insert(
            name.clone(),
            LayerCalib { h, last_x: last_x.remove(name) },
        );
    }
    out
}

/// Layers tapped per re-forward when computing the GPTQ arm's Γ(0): caps
/// the number of activation clones held live at once (vs. tapping all L
/// layers in one forward) while paying only ceil(L/chunk) forwards (vs. L
/// for one-per-layer taps).
const GAMMA0_TAP_CHUNK: usize = 8;

/// Fan per-layer quantization jobs out across the global pool and join in
/// layer order (shared by the LM and VLM pipelines; `cfg_for` supplies the
/// per-layer config/method — the only part that differs between them).
fn fan_out_layers(
    linears: &[(String, &Tensor)],
    calib: &HashMap<String, LayerCalib>,
    ledger: &MemoryLedger,
    timers: &Timers,
    cfg_for: impl Fn(&str, &Tensor) -> (QuantConfig, Method),
) -> Result<(HashMap<String, QuantizedLinear>, Vec<LayerReport>)> {
    let jobs: Vec<_> = linears
        .iter()
        .map(|(name, w_fp)| {
            let c = &calib[name];
            let (fitted, m) = cfg_for(name, w_fp);
            move || quantize_layer(name, w_fp, c, fitted, m, ledger, timers)
        })
        .collect();
    let results = crate::exec::global().map(jobs);
    let mut qlinears = HashMap::new();
    let mut reports = Vec::new();
    for ((name, _), res) in linears.iter().zip(results) {
        let (q, rep) = res?;
        qlinears.insert(name.clone(), q);
        reports.push(rep);
    }
    Ok((qlinears, reports))
}

/// GPTQ-arm Γ(0) rescoring, shared by the LM and VLM pipelines: re-run
/// `forward` with a tap over [`GAMMA0_TAP_CHUNK`] layers at a time and
/// score each tapped input against the fp and quantized weights. Each
/// input is dropped as soon as its layer is scored; the scoring matmuls
/// shard rows on the pool.
fn gamma0_rescore<'w>(
    reports: &mut [LayerReport],
    qlinears: &HashMap<String, QuantizedLinear>,
    fp_of: impl Fn(&str) -> Option<&'w Tensor>,
    mut forward: impl FnMut(&mut ActivationTap),
) {
    for chunk in reports.chunks_mut(GAMMA0_TAP_CHUNK) {
        let names: Vec<String> = chunk.iter().map(|r| r.name.clone()).collect();
        let mut tap = ActivationTap::only(names);
        forward(&mut tap);
        for rep in chunk.iter_mut() {
            if let (Some(x), Some(w_fp)) = (tap.take(&rep.name), fp_of(&rep.name)) {
                let y_orig = crate::tensor::matmul_a_bt(&x, w_fp);
                let y_q = crate::tensor::matmul_a_bt(&x, &qlinears[&rep.name].dequantize());
                rep.loss_trace[0] = y_orig.sub(&y_q).frob_sq();
            }
        }
    }
}

/// Quantize one linear given its calibration state.
fn quantize_layer(
    name: &str,
    w_fp: &Tensor,
    calib: &LayerCalib,
    cfg: QuantConfig,
    method: Method,
    ledger: &MemoryLedger,
    timers: &Timers,
) -> Result<(QuantizedLinear, LayerReport)> {
    let (stage1, stage1_secs) = timers.time_secs("stage1", || {
        let _span = crate::trace::span_detail("quant", "gptq", || name.to_string());
        gptq_quantize(w_fp, &calib.h, cfg, ledger)
    });
    let stage1 = stage1?;

    match method {
        Method::Gptq => {
            // Γ(0) for reporting parity with the RPIQ arm: when the caller
            // provides a transient instance (`gamma_x`), score against it;
            // it is NOT retained (the GPTQ arm holds no calibration data).
            let loss0 = match &calib.last_x {
                Some(x) => {
                    let y_orig = crate::tensor::matmul_a_bt(x, w_fp);
                    let y_q = crate::tensor::matmul_a_bt(x, &stage1.q.dequantize());
                    y_orig.sub(&y_q).frob_sq()
                }
                None => f64::NAN,
            };
            Ok((
                stage1.q,
                LayerReport {
                    name: name.to_string(),
                    loss_trace: vec![loss0],
                    iters_run: 0,
                    early_stopped: false,
                    stage1_secs,
                    stage2_secs: 0.0,
                },
            ))
        }
        Method::Rpiq(params) => {
            let x_last = calib
                .last_x
                .as_ref()
                .expect("RPIQ arm requires the retained single instance");
            let (out, stage2_secs) = timers.time_secs("stage2", || -> Result<_> {
                let _span = crate::trace::span_detail("quant", "rpiq.refine", || name.to_string());
                let inst = SingleInstance::capture(x_last.clone(), w_fp, ledger);
                let out = rpiq_refine(&stage1.q, &inst, &calib.h, params, ledger)?;
                inst.release(ledger);
                Ok(out)
            });
            let out = out?;
            Ok((
                out.q,
                LayerReport {
                    name: name.to_string(),
                    loss_trace: out.loss_trace,
                    iters_run: out.iters_run,
                    early_stopped: out.early_stopped,
                    stage1_secs,
                    stage2_secs,
                },
            ))
        }
    }
}

/// Quantize an LM end to end.
///
/// * `windows` — calibration token windows (the paper's 128×seq samples).
/// * `cfg` — grid config (4-bit / group 128 in the paper).
/// * `method` — GPTQ baseline or RPIQ.
pub fn quantize_lm(
    w: &LmWeights,
    windows: &[Vec<u32>],
    cfg: QuantConfig,
    method: Method,
) -> Result<PipelineOutput> {
    let ledger = MemoryLedger::new();
    let timers = Timers::new();
    let names: Vec<String> = w.linears().into_iter().map(|(n, _)| n).collect();
    let seq = windows.first().map(|w| w.len()).unwrap_or(0);

    // model weights resident during quantization (as on the paper's GPU)
    let model_bytes: usize = w.named_tensors().iter().map(|(_, t)| t.nbytes()).sum();
    ledger.alloc(tags::MODEL_WEIGHTS, model_bytes);

    let retain_last = matches!(method, Method::Rpiq(_));
    let calib = timers.time("calibration", || {
        let _span = crate::trace::span("quant", "calibrate");
        calibrate(&names, windows, cfg.percdamp, retain_last, &ledger, |win, tap| {
            let _ = lm_forward(w, win, 1, seq, Some(tap));
        })
    });

    // Fan the per-layer jobs out across the global pool: given its
    // calibration state each layer is independent, and quantize_layer runs
    // the exact sequential code, so the join reassembles reports and
    // qlinears in layer order with byte-identical contents.
    let linears = w.linears();
    let layers_span = crate::trace::span("quant", "layers");
    let (qlinears, mut reports) =
        fan_out_layers(&linears, &calib, &ledger, &timers, |_, w_fp| {
            (cfg.fitted(w_fp.cols()), method)
        })?;
    drop(layers_span);

    // GPTQ arm: Γ(0) for report parity, computed transiently after the
    // fact (the arm never retains calibration data through quantization —
    // that retention is RPIQ's single-instance memory cost, Table 3).
    if !retain_last {
        if let Some(last) = windows.last() {
            gamma0_rescore(&mut reports, &qlinears, |n| w.linear(n), |tap| {
                let _ = lm_forward(w, last, 1, seq, Some(tap));
            });
        }
    }
    // release calibration state
    // ORDER-INSENSITIVE: ledger frees commute; only the summed bytes
    // matter, so hash order cannot affect any observable result.
    for (_name, c) in calib {
        ledger.free(tags::HESSIAN_FINAL, c.h.nbytes());
        if let Some(x) = &c.last_x {
            ledger.free(tags::CALIB_LAST_BATCH, x.nbytes());
        }
    }
    ledger.free(tags::MODEL_WEIGHTS, model_bytes);

    Ok(PipelineOutput {
        // The deployed model carries only the skeleton (embeddings, norms)
        // + packed linears — the caller's fp32 `w` is NOT cloned into it,
        // so the post-quantization resident footprint is deploy_bytes().
        model: QuantizedLm::new(crate::model::LmSkeleton::from_weights(w), qlinears)?,
        reports,
        ledger,
        timers,
    })
}

/// Pipeline result for a VLM.
pub struct PipelineVlmOutput {
    pub model: QuantizedVlm,
    pub reports: Vec<LayerReport>,
    pub ledger: MemoryLedger,
    pub timers: Timers,
}

/// Quantize a VLM under a CMDQ policy (per-modality configs). The
/// calibration set is (patches, question) pairs — the paper's 64
/// CogVLM-SFT samples.
pub fn quantize_vlm(
    w: &VlmWeights,
    calib_samples: &[(Tensor, Vec<u32>)],
    policy: &CmdqPolicy,
    method: Method,
) -> Result<PipelineVlmOutput> {
    let ledger = MemoryLedger::new();
    let timers = Timers::new();
    let names: Vec<String> = w.linears().into_iter().map(|(n, _)| n).collect();

    let model_bytes = w.n_params() * 4;
    ledger.alloc(tags::MODEL_WEIGHTS, model_bytes);

    // windows are indices into calib_samples; reuse the LM calibrate()
    // driver by closing over the sample list.
    let idx_windows: Vec<Vec<u32>> = (0..calib_samples.len())
        .map(|i| vec![i as u32])
        .collect();
    let retain_last = matches!(method, Method::Rpiq(_));
    let calib = timers.time("calibration", || {
        let _span = crate::trace::span("quant", "calibrate");
        calibrate(&names, &idx_windows, policy.language.percdamp, retain_last, &ledger, |win, tap| {
            let (patches, text) = &calib_samples[win[0] as usize];
            let _ = vlm_forward(w, patches, text, 1, Some(tap));
        })
    });

    // Per-layer fan-out across the global pool (see quantize_lm).
    let linears = w.linears();
    let layers_span = crate::trace::span("quant", "layers");
    let (qlinears, mut reports) =
        fan_out_layers(&linears, &calib, &ledger, &timers, |name, w_fp| {
            let m = match method {
                Method::Gptq => Method::Gptq,
                Method::Rpiq(_) => Method::Rpiq(policy.rpiq),
            };
            (policy.config_for(name).fitted(w_fp.cols()), m)
        })?;
    drop(layers_span);

    // Transient Γ(0) for the GPTQ arm (see quantize_lm).
    if !retain_last {
        if let Some((patches, text)) = calib_samples.last() {
            let fp_by_name: HashMap<String, &Tensor> = w.linears().into_iter().collect();
            gamma0_rescore(
                &mut reports,
                &qlinears,
                |n| fp_by_name.get(n).copied(),
                |tap| {
                    let _ = vlm_forward(w, patches, text, 1, Some(tap));
                },
            );
        }
    }
    // ORDER-INSENSITIVE: ledger frees commute; only the summed bytes
    // matter, so hash order cannot affect any observable result.
    for (_name, c) in calib {
        ledger.free(tags::HESSIAN_FINAL, c.h.nbytes());
        if let Some(x) = &c.last_x {
            ledger.free(tags::CALIB_LAST_BATCH, x.nbytes());
        }
    }
    ledger.free(tags::MODEL_WEIGHTS, model_bytes);

    Ok(PipelineVlmOutput {
        // Skeleton-only, like the LM pipeline: no fp32 linear survives.
        model: QuantizedVlm::new(crate::vlm::VlmSkeleton::from_weights(w), qlinears)?,
        reports,
        ledger,
        timers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::WikiCorpus;
    use crate::model::config::ModelConfig;
    use crate::rng::Pcg64;
    use crate::vlm::VlmConfig;

    fn small_cfg() -> QuantConfig {
        QuantConfig { bits: 4, group_size: 8, block_size: 8, percdamp: 0.01 }
    }

    fn setup_lm() -> (LmWeights, Vec<Vec<u32>>) {
        let corpus = WikiCorpus::generate(31, 6000, 500);
        let cfg = ModelConfig::test_tiny(corpus.tokenizer.vocab_size());
        let mut rng = Pcg64::seeded(701);
        let w = LmWeights::init(&cfg, &mut rng);
        let windows = corpus.calibration(1, 8, cfg.seq_len);
        (w, windows)
    }

    #[test]
    fn gptq_pipeline_quantizes_all_layers() {
        let (w, windows) = setup_lm();
        let out = quantize_lm(&w, &windows, small_cfg(), Method::Gptq).unwrap();
        assert_eq!(out.reports.len(), 12);
        assert_eq!(out.model.qlinears.len(), 12);
        assert!(out.ledger.peak_bytes() > 0);
        assert_eq!(out.ledger.live_bytes(), 0, "everything released");
        assert!(out.timers.get("calibration") > 0.0);
        assert!(out.timers.get("stage1") > 0.0);
        assert_eq!(out.timers.get("stage2"), 0.0);
    }

    #[test]
    fn rpiq_pipeline_improves_layer_losses() {
        let (w, windows) = setup_lm();
        let gptq = quantize_lm(&w, &windows, small_cfg(), Method::Gptq).unwrap();
        let rpiq = quantize_lm(
            &w,
            &windows,
            small_cfg(),
            Method::Rpiq(RpiqParams::default()),
        )
        .unwrap();
        // same stage-1 initialization ⇒ same Γ(0)
        for (g, r) in gptq.reports.iter().zip(rpiq.reports.iter()) {
            assert_eq!(g.name, r.name);
            assert!(
                (g.initial_loss() - r.initial_loss()).abs()
                    <= 1e-6 * g.initial_loss().max(1.0),
                "{}", g.name
            );
            // best-iterate selection ⇒ never worse on the instance
            assert!(r.final_loss() <= r.initial_loss() + 1e-9, "{}", r.name);
        }
        // and strictly better somewhere
        let total_red: f64 = rpiq.reports.iter().map(|r| r.reduction_pct()).sum();
        assert!(total_red > 1.0, "no layer improved at all: {total_red}");
    }

    #[test]
    fn calibration_deterministic_across_thread_counts() {
        // The calibration fan-out's own contract (narrower than the full
        // pipeline test below): damped Hessians and the retained last
        // batch are byte-identical at any thread count, and the ledger
        // balances once the calibration state is released.
        let _guard = crate::exec::thread_target_test_lock();
        let before = crate::exec::num_threads();
        let (w, windows) = setup_lm();
        let names: Vec<String> = w.linears().into_iter().map(|(n, _)| n).collect();
        let seq_len = windows[0].len();
        let run = |threads: usize| {
            crate::exec::set_threads(threads);
            let ledger = MemoryLedger::new();
            let calib = calibrate(&names, &windows, 0.01, true, &ledger, |win, tap| {
                let _ = lm_forward(&w, win, 1, seq_len, Some(tap));
            });
            (calib, ledger)
        };
        let release = |calib: HashMap<String, LayerCalib>, ledger: &MemoryLedger| {
            for (_name, c) in calib {
                ledger.free(tags::HESSIAN_FINAL, c.h.nbytes());
                if let Some(x) = &c.last_x {
                    ledger.free(tags::CALIB_LAST_BATCH, x.nbytes());
                }
            }
        };
        let bits = |t: &Tensor| t.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        let (c_seq, l_seq) = run(1);
        for threads in [2usize, 8] {
            let (c_par, l_par) = run(threads);
            for name in &names {
                let (a, b) = (&c_seq[name], &c_par[name]);
                assert_eq!(
                    bits(&a.h),
                    bits(&b.h),
                    "damped Hessian diverged for {name} @ {threads} threads"
                );
                let (ax, bx) = (a.last_x.as_ref().unwrap(), b.last_x.as_ref().unwrap());
                assert_eq!(
                    bits(ax),
                    bits(bx),
                    "retained instance diverged for {name} @ {threads} threads"
                );
            }
            release(c_par, &l_par);
            assert_eq!(l_par.live_bytes(), 0, "ledger balances @ {threads} threads");
        }
        release(c_seq, &l_seq);
        assert_eq!(l_seq.live_bytes(), 0);
        crate::exec::set_threads(before);
    }

    #[test]
    fn gamma_traces_deterministic_across_thread_counts() {
        // The acceptance bar of the parallel pipeline: fanning layers out
        // across the pool must leave every Γ trace and every packed level
        // buffer byte-identical to the single-threaded run.
        let _guard = crate::exec::thread_target_test_lock();
        let before = crate::exec::num_threads();
        let (w, windows) = setup_lm();
        let bits = |t: &[f64]| t.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for method in [Method::Gptq, Method::Rpiq(RpiqParams::default())] {
            crate::exec::set_threads(1);
            let seq = quantize_lm(&w, &windows, small_cfg(), method).unwrap();
            crate::exec::set_threads(4);
            let par = quantize_lm(&w, &windows, small_cfg(), method).unwrap();
            assert_eq!(seq.reports.len(), par.reports.len());
            for (rs, rp) in seq.reports.iter().zip(par.reports.iter()) {
                assert_eq!(rs.name, rp.name);
                assert_eq!(
                    bits(&rs.loss_trace),
                    bits(&rp.loss_trace),
                    "Γ trace diverged for {} [{}]",
                    rs.name,
                    method.label()
                );
                assert_eq!(rs.iters_run, rp.iters_run);
                assert_eq!(rs.early_stopped, rp.early_stopped);
            }
            for (name, qs) in seq.model.qlinears.iter() {
                let qp = par.model.qlinears.get(name).expect("same layer set");
                assert_eq!(qs.packed, qp.packed, "packed levels diverged for {name}");
                assert_eq!(qs.scales, qp.scales, "scales diverged for {name}");
                assert_eq!(qs.zeros, qp.zeros, "zeros diverged for {name}");
            }
            // accounting still balances regardless of scheduling
            assert_eq!(par.ledger.live_bytes(), 0);
        }
        crate::exec::set_threads(before);
    }

    #[test]
    fn rpiq_peak_memory_and_time_exceed_gptq() {
        // Table 3/4 shape: ΔM > 0, ΔT > 0. Ledger peaks are a property of
        // the observed interleaving, so the cross-arm comparison is only
        // deterministic fully sequential: pin the shard target to 1 (and
        // hold the test lock so nothing re-raises it mid-run).
        let _guard = crate::exec::thread_target_test_lock();
        let before = crate::exec::num_threads();
        crate::exec::set_threads(1);
        let (w, windows) = setup_lm();
        let gptq = quantize_lm(&w, &windows, small_cfg(), Method::Gptq).unwrap();
        let rpiq = quantize_lm(
            &w,
            &windows,
            small_cfg(),
            Method::Rpiq(RpiqParams::default()),
        )
        .unwrap();
        crate::exec::set_threads(before);
        assert!(rpiq.ledger.peak_bytes() >= gptq.ledger.peak_bytes());
        assert!(rpiq.timers.get("stage2") > 0.0);
    }

    #[test]
    fn vlm_pipeline_with_cmdq_policy() {
        let vcfg = VlmConfig::test_tiny(64);
        let mut rng = Pcg64::seeded(702);
        let w = crate::vlm::VlmWeights::init(&vcfg, &mut rng);
        let samples: Vec<(Tensor, Vec<u32>)> = (0..6)
            .map(|_| {
                let p = Tensor::randn(
                    &[vcfg.n_patches, vcfg.patch_dim],
                    1.0,
                    &mut rng,
                );
                let t: Vec<u32> = (0..6).map(|_| rng.next_below(64) as u32).collect();
                (p, t)
            })
            .collect();
        let policy = CmdqPolicy {
            vision: small_cfg().with_bits(8),
            cross_modal: small_cfg(),
            language: small_cfg(),
            rpiq: RpiqParams::default(),
        };
        let out = quantize_vlm(&w, &samples, &policy, Method::Rpiq(policy.rpiq)).unwrap();
        // vision layers got 8 bits, language 4
        assert_eq!(out.model.qlinears.get("vision.block0.fc1").expect("present").grid.bits, 8);
        assert_eq!(out.model.qlinears.get("lm.layer0.attn.q").expect("present").grid.bits, 4);
        assert_eq!(out.ledger.live_bytes(), 0);
        assert_eq!(out.reports.len(), w.linears().len());
    }
}
