//! Layer-3 coordination: the quantization pipeline (calibrate → GPTQ →
//! RPIQ refine, layer by layer, with byte/time accounting) and the
//! multi-lane serving engine (sharded router + per-workload dynamic
//! batcher lanes) used by the latency experiments.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

pub mod experiments;
pub mod pipeline;
pub mod serve;
pub mod suite;

pub use pipeline::{
    quantize_lm, quantize_vlm, LayerReport, Method, PipelineOutput, PipelineVlmOutput,
};
pub use serve::{
    replay, replay_generate, replay_mixed, Answer, GenerateLane, LaneEngine, Payload, Request,
    Response, SentimentLane, ServeConfig, Server, SubmitError, VqaLane, LANE_GENERATE,
    LANE_SENTIMENT, LANE_VQA,
};
