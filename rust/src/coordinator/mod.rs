//! Layer-3 coordination: the quantization pipeline (calibrate → GPTQ →
//! RPIQ refine, layer by layer, with byte/time accounting) and the serving
//! runtime (router + dynamic batcher) used by the latency experiments.

pub mod experiments;
pub mod pipeline;
pub mod serve;
pub mod suite;

pub use pipeline::{
    quantize_lm, quantize_vlm, LayerReport, Method, PipelineOutput, PipelineVlmOutput,
};
pub use serve::{Request, Response, ServeConfig, Server};
