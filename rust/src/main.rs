fn main() -> anyhow::Result<()> {
    rpiq::cli::run(std::env::args().skip(1).collect())
}
