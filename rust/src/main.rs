#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

fn main() -> anyhow::Result<()> {
    rpiq::cli::run(std::env::args().skip(1).collect())
}
