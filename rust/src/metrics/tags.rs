//! Central registry of every [`super::MemoryLedger`] tag string.
//!
//! Register/release pairs drift when the two sides of a booking spell the
//! tag independently — a typo on one side leaks "live" bytes forever and
//! silently corrupts the footprint gates in `benches/footprint.rs`. Every
//! non-test `alloc`/`free`/`scoped` call site must therefore name its tag
//! through a constant declared here (enforced by the `ledger-tags` rule of
//! `rust/tools/rpiq-lint`); the registry's own unit test pins uniqueness.
//!
//! The one dynamic family — per-lane activation tags — goes through
//! [`activations`] so the `"activations."` prefix is also single-sourced
//! (readers like `rpiq serve`'s summary build the same string).

/// Running Hessian accumulator (`HessianAccumulator`) backing store.
pub const HESSIAN: &str = "hessian";
/// Transient `XᵀX` of one calibration batch before it folds into the sum.
pub const HESSIAN_TMP: &str = "hessian_tmp";
/// Per-window partial Hessians awaiting the deterministic replay-merge.
pub const HESSIAN_PARTIAL: &str = "hessian_partial";
/// Finalized (damped, averaged) per-layer Hessian handed to the engines.
pub const HESSIAN_FINAL: &str = "hessian_final";
/// Last calibration batch retained for single-instance activation capture.
pub const CALIB_LAST_BATCH: &str = "calib_last_batch";
/// Single-instance activation snapshot (`SingleInstance`).
pub const SINGLE_INSTANCE: &str = "single_instance";
/// The fp32 model weights while the quantization pipeline holds them.
pub const MODEL_WEIGHTS: &str = "model_weights";
/// Resident deployment bytes of a quantized model (packed linears +
/// skeleton) — re-exported as `crate::model::RESIDENT_TAG`.
pub const MODEL_RESIDENT: &str = "model_resident";
/// GPTQ working copies of the weight matrix and Hessian.
pub const GPTQ_WORK: &str = "gptq_work";
/// GPTQ inverse-Cholesky factor.
pub const GPTQ_HINV: &str = "gptq_hinv";
/// GPTQ level buffer under construction.
pub const GPTQ_LEVELS: &str = "gptq_levels";
/// GPTQ per-shard lazy trailing-update error blocks.
pub const GPTQ_ERRBLOCK: &str = "gptq_errblock";
/// GPTQ per-row greedy-loss subtotals.
pub const GPTQ_ROWLOSS: &str = "gptq_rowloss";
/// RPIQ residual-projection precompute (per-block `U` factors).
pub const RPIQ_PRECOMP: &str = "rpiq_precomp";
/// RPIQ closed-loop iteration state (continuous blocks + deployment copy).
pub const RPIQ_STATE: &str = "rpiq_state";
/// RPIQ projection scratch (work matrix + level buffer).
pub const RPIQ_PROJECT: &str = "rpiq_project";
/// Paged KV-cache pages held by live decode sequences
/// ([`crate::model::decode::KvPool`]); balances to zero when every
/// sequence has retired.
pub const KV_CACHE: &str = "kv_cache";

/// Prefix of the per-lane transient activation tags booked by the serve
/// engine's lane loop.
pub const ACTIVATIONS_PREFIX: &str = "activations.";

/// Activation tag for one serve lane, e.g. `activations.sentiment`.
pub fn activations(lane: &str) -> String {
    format!("{ACTIVATIONS_PREFIX}{lane}")
}

/// Every fixed tag in the registry (the dynamic `activations.*` family is
/// represented by its prefix, which must not collide either).
pub const ALL: &[&str] = &[
    HESSIAN,
    HESSIAN_TMP,
    HESSIAN_PARTIAL,
    HESSIAN_FINAL,
    CALIB_LAST_BATCH,
    SINGLE_INSTANCE,
    MODEL_WEIGHTS,
    MODEL_RESIDENT,
    GPTQ_WORK,
    GPTQ_HINV,
    GPTQ_LEVELS,
    GPTQ_ERRBLOCK,
    GPTQ_ROWLOSS,
    RPIQ_PRECOMP,
    RPIQ_STATE,
    RPIQ_PROJECT,
    KV_CACHE,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_are_unique_and_well_formed() {
        let mut seen = std::collections::BTreeSet::new();
        for &t in ALL {
            assert!(!t.is_empty(), "empty tag");
            assert!(
                t.bytes().all(|b| b.is_ascii_lowercase() || b == b'_'),
                "tag '{t}' must be lowercase snake_case"
            );
            assert!(seen.insert(t), "duplicate tag '{t}'");
            assert!(
                !t.starts_with(ACTIVATIONS_PREFIX),
                "fixed tag '{t}' collides with the dynamic activations family"
            );
        }
    }

    #[test]
    fn activations_builds_prefixed_tags() {
        assert_eq!(activations("vqa"), "activations.vqa");
        assert!(activations("sentiment").starts_with(ACTIVATIONS_PREFIX));
    }
}
