//! Measurement substrate: a byte-accurate memory ledger and wall-clock
//! timers.
//!
//! The paper's Tables 3 and 4 report *peak GPU memory* and *total
//! quantization time* for GPTQ vs RPIQ. We have no GPU; instead every
//! tensor the quantization engines allocate is registered with a
//! [`MemoryLedger`] scope, which tracks live bytes and the high-water mark.
//! Because both engines are instrumented identically, the relative overhead
//! ΔM (Eq. 27) — the quantity the paper actually analyses — is preserved.
//!
//! Concurrency: [`MemoryLedger`], [`Timers`], and [`LatencyStats`] are
//! cheap `Clone` handles over one `Arc<Mutex<…>>` state and are shared
//! freely with pool workers (the parallel pipeline records alloc/free and
//! stage timings from many layer jobs at once). Alloc/free pairing is
//! exact under concurrency — live bytes always return to zero — while the
//! *peak* is a property of the observed interleaving: more jobs in flight
//! can legitimately raise it. Determinism-sensitive comparisons must pin
//! `exec::set_threads` (see the pipeline tests).
//!
//! Budgets: a tag can carry a live-byte cap ([`MemoryLedger::set_budget`])
//! that [`MemoryLedger::try_alloc`] checks-and-books in one critical
//! section — the serve lanes use this as admission control on their
//! `activations.<lane>` tags, so concurrent bookings under one capped tag
//! never jointly overshoot. Plain [`MemoryLedger::alloc`] is never gated:
//! accounting stays exact even when a caller opts out of enforcement.
//! [`MemoryLedger::alloc_blocking`] is the waiting variant: every `free`
//! (and budget change) notifies a condvar, so a budget-blocked lane parks
//! until bytes return instead of polling — and a request that can *never*
//! fit under the cap fails immediately rather than waiting forever.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

pub mod tags;

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Thread-safe allocation ledger with peak tracking.
#[derive(Clone, Default)]
pub struct MemoryLedger {
    inner: Arc<LedgerShared>,
}

#[derive(Default)]
struct LedgerShared {
    state: Mutex<LedgerInner>,
    /// Notified on every `free`/`set_budget`/`clear_budget` so
    /// [`MemoryLedger::alloc_blocking`] waiters re-check promptly.
    freed: Condvar,
}

#[derive(Default)]
struct LedgerInner {
    live: i64,
    peak: i64,
    /// live bytes per named category (weights, hessian, calib, residuals…)
    by_tag: HashMap<String, i64>,
    peak_by_tag: HashMap<String, i64>,
    /// per-tag live-byte caps (admission control; see [`MemoryLedger::try_alloc`])
    budgets: HashMap<String, i64>,
}

impl MemoryLedger {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> MutexGuard<'_, LedgerInner> {
        self.inner.state.lock().unwrap()
    }

    /// Record an allocation of `bytes` under `tag`.
    pub fn alloc(&self, tag: &str, bytes: usize) {
        let (tag_live, live) = {
            let mut g = self.lock();
            Self::book(&mut g, tag, bytes)
        };
        self.trace_counters(tag, tag_live, live);
    }

    /// Record a release of `bytes` under `tag`, waking any
    /// [`Self::alloc_blocking`] waiters so budget headroom is re-checked
    /// immediately instead of on a poll tick.
    pub fn free(&self, tag: &str, bytes: usize) {
        let (tag_live, live) = {
            let mut g = self.lock();
            g.live -= bytes as i64;
            let e = g.by_tag.entry(tag.to_string()).or_insert(0);
            *e -= bytes as i64;
            (*e, g.live)
        };
        self.inner.freed.notify_all();
        self.trace_counters(tag, tag_live, live);
    }

    /// Emit the per-tag and total live bytes as trace counter tracks, so a
    /// transient peak in the Chrome trace is attributable to whichever span
    /// it rises under. Outside the ledger lock; a branch when disabled.
    fn trace_counters(&self, tag: &str, tag_live: i64, live: i64) {
        if crate::trace::enabled() {
            crate::trace::counter(format!("mem.{tag}"), tag_live as f64);
            crate::trace::counter("mem.live", live as f64);
        }
    }

    /// Cap a tag's live bytes at `bytes` — subsequent [`Self::try_alloc`]
    /// calls on `tag` fail instead of exceeding the cap. Plain
    /// [`Self::alloc`] is *not* gated (resident weights and eval scopes
    /// keep exact accounting); budgets are an admission-control contract
    /// for the paths that opt in, i.e. the serve lanes' per-lane
    /// `activations.<lane>` caps derived from `ServeConfig`.
    pub fn set_budget(&self, tag: &str, bytes: usize) {
        {
            let mut g = self.lock();
            g.budgets.insert(tag.to_string(), bytes as i64);
        }
        // a raised cap may unblock waiters; a lowered one makes their
        // next check fail fast instead of waiting forever
        self.inner.freed.notify_all();
    }

    /// Remove a tag's cap.
    pub fn clear_budget(&self, tag: &str) {
        {
            let mut g = self.lock();
            g.budgets.remove(tag);
        }
        self.inner.freed.notify_all();
    }

    /// The cap set for `tag`, if any.
    pub fn budget_for(&self, tag: &str) -> Option<usize> {
        let g = self.lock();
        g.budgets.get(tag).map(|&b| b.max(0) as usize)
    }

    /// Budget-checked allocation: books `bytes` under `tag` exactly like
    /// [`Self::alloc`] unless the tag has a budget and the allocation
    /// would push its live bytes past it, in which case nothing is booked
    /// and `Err` carries the cap. The check and the booking are one
    /// critical section, so concurrent lanes cannot jointly overshoot a
    /// shared tag's cap.
    pub fn try_alloc(&self, tag: &str, bytes: usize) -> Result<(), usize> {
        let (tag_live, live) = {
            let mut g = self.lock();
            if let Some(&cap) = g.budgets.get(tag) {
                let cur = g.by_tag.get(tag).copied().unwrap_or(0);
                if cur + bytes as i64 > cap {
                    return Err(cap.max(0) as usize);
                }
            }
            Self::book(&mut g, tag, bytes)
        };
        self.trace_counters(tag, tag_live, live);
        Ok(())
    }

    /// Blocking budget-checked allocation: like [`Self::try_alloc`], but
    /// when the tag is at its cap the caller *parks* on the ledger's
    /// condvar until a `free` (or budget change) opens enough headroom —
    /// no polling. Two terminal cases return without booking anything:
    /// `bytes` alone exceeding the cap can never be satisfied by waiting
    /// (`Err(cap)` immediately — the caller should surface over-budget,
    /// not hang), and a cap lowered below `bytes` while waiting fails the
    /// same way. Without a budget on `tag` this is exactly [`Self::alloc`].
    pub fn alloc_blocking(&self, tag: &str, bytes: usize) -> Result<(), usize> {
        let (tag_live, live) = {
            let mut g = self.lock();
            loop {
                match g.budgets.get(tag) {
                    None => break,
                    Some(&cap) if (bytes as i64) > cap => return Err(cap.max(0) as usize),
                    Some(&cap) => {
                        let cur = g.by_tag.get(tag).copied().unwrap_or(0);
                        if cur + bytes as i64 <= cap {
                            break;
                        }
                    }
                }
                // The timeout is a lost-wakeup backstop only; the free/
                // budget-change notifications are what wake us in practice.
                let (guard, _) = self
                    .inner
                    .freed
                    .wait_timeout(g, Duration::from_millis(100))
                    .unwrap();
                g = guard;
            }
            Self::book(&mut g, tag, bytes)
        };
        self.trace_counters(tag, tag_live, live);
        Ok(())
    }

    /// Book `bytes` under `tag` (lock already held); returns the tag's and
    /// the ledger's live bytes for [`Self::trace_counters`].
    fn book(g: &mut LedgerInner, tag: &str, bytes: usize) -> (i64, i64) {
        g.live += bytes as i64;
        if g.live > g.peak {
            g.peak = g.live;
        }
        let e = g.by_tag.entry(tag.to_string()).or_insert(0);
        *e += bytes as i64;
        let cur = *e;
        let p = g.peak_by_tag.entry(tag.to_string()).or_insert(0);
        if cur > *p {
            *p = cur;
        }
        (cur, g.live)
    }

    /// Convenience: account `bytes` for the duration of `f`.
    pub fn scoped<T>(&self, tag: &str, bytes: usize, f: impl FnOnce() -> T) -> T {
        self.alloc(tag, bytes);
        let out = f();
        self.free(tag, bytes);
        out
    }

    pub fn live_bytes(&self) -> i64 {
        self.lock().live
    }

    pub fn peak_bytes(&self) -> i64 {
        self.lock().peak
    }

    pub fn peak_gib(&self) -> f64 {
        self.peak_bytes() as f64 / (1u64 << 30) as f64
    }

    pub fn peak_mib(&self) -> f64 {
        self.peak_bytes() as f64 / (1u64 << 20) as f64
    }

    /// Peak bytes attributed to one tag.
    pub fn peak_for(&self, tag: &str) -> i64 {
        self.lock().peak_by_tag.get(tag).copied().unwrap_or(0)
    }

    /// Snapshot of per-tag peaks, sorted descending.
    pub fn breakdown(&self) -> Vec<(String, i64)> {
        let g = self.lock();
        let mut v: Vec<_> = g.peak_by_tag.iter().map(|(k, &b)| (k.clone(), b)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v
    }

    /// Reset everything (between experiment arms).
    pub fn reset(&self) {
        let mut g = self.lock();
        *g = LedgerInner::default();
    }
}

/// Simple named wall-clock stopwatch collection.
#[derive(Clone, Default)]
pub struct Timers {
    inner: Arc<Mutex<HashMap<String, f64>>>,
}

impl Timers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` and accumulate under `name`.
    ///
    /// The duration is *exclusive* of help-first work stealing: when the
    /// current thread inline-runs another scope's job while waiting in a
    /// pool join (see `exec::helped_secs`), that stolen job's wall time is
    /// subtracted here — it is timed once, by its own `time` call, instead
    /// of inflating whichever window it happened to run inside. (A thread
    /// running its own scope's shard jobs is doing its own work and is
    /// *not* subtracted.)
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        self.time_secs(name, f).0
    }

    /// Like [`Self::time`], additionally returning the exclusive duration
    /// that was accumulated (the pipeline records per-layer stage seconds
    /// from this without double instrumentation).
    pub fn time_secs<T>(&self, name: &str, f: impl FnOnce() -> T) -> (T, f64) {
        let t0 = Instant::now();
        let h0 = crate::exec::helped_secs();
        let out = f();
        let helped = crate::exec::helped_secs() - h0;
        let dt = (t0.elapsed().as_secs_f64() - helped).max(0.0);
        *self.inner.lock().unwrap().entry(name.to_string()).or_insert(0.0) += dt;
        (out, dt)
    }

    /// Add an externally measured duration.
    pub fn add(&self, name: &str, secs: f64) {
        *self.inner.lock().unwrap().entry(name.to_string()).or_insert(0.0) += secs;
    }

    pub fn get(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().get(name).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.inner.lock().unwrap().values().sum()
    }

    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<_> = g.iter().map(|(k, &s)| (k.clone(), s)).collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }

    pub fn reset(&self) {
        self.inner.lock().unwrap().clear();
    }
}

/// Percentile estimation keeps at most this many samples; count and mean
/// stay exact at any volume (running count/sum).
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

/// SplitMix64 finalizer — the fixed, seedless hash behind the reservoir's
/// replacement decisions.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

#[derive(Default)]
struct LatencyInner {
    count: u64,
    sum_secs: f64,
    reservoir: Vec<f64>,
}

/// Streaming latency collector for the serving experiments: exact
/// count/mean plus a bounded percentile reservoir.
///
/// Memory is O([`LATENCY_RESERVOIR_CAP`]) under sustained traffic and
/// `percentile_ms` sorts at most that many samples per call (the unbounded
/// `Vec<f64>` it replaces re-sorted the full history every call).
///
/// Determinism story: below the cap every sample is retained, so results
/// are exact and order-independent. Above the cap, replacement is
/// Algorithm R driven not by an RNG but by a fixed hash of the arrival
/// index ([`splitmix64`]) — each index's keep/replace decision is a pure
/// function of that index, so a fixed arrival *order* always yields the
/// same reservoir. Concurrent recorders make the arrival order itself
/// scheduling-dependent, so percentiles above the cap are estimates — the
/// same caveat the ledger's peak carries (see the module docs); exact
/// comparisons must stay under the cap or pin the recording order.
#[derive(Clone, Default)]
pub struct LatencyStats {
    inner: Arc<Mutex<LatencyInner>>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, secs: f64) {
        let mut g = self.inner.lock().unwrap();
        g.count += 1;
        g.sum_secs += secs;
        if g.reservoir.len() < LATENCY_RESERVOIR_CAP {
            g.reservoir.push(secs);
        } else {
            // Algorithm R: sample i (1-based) replaces a uniform slot in
            // [0, i) iff that slot lands inside the reservoir.
            let slot = (splitmix64(g.count) % g.count) as usize;
            if let Some(s) = g.reservoir.get_mut(slot) {
                *s = secs;
            }
        }
    }

    pub fn count(&self) -> usize {
        self.inner.lock().unwrap().count as usize
    }

    pub fn mean_ms(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        if g.count == 0 {
            return 0.0;
        }
        g.sum_secs / g.count as f64 * 1e3
    }

    /// p in [0,100], estimated over the retained reservoir.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        let mut s = self.inner.lock().unwrap().reservoir.clone();
        if s.is_empty() {
            return 0.0;
        }
        s.sort_by(f64::total_cmp);
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx] * 1e3
    }
}

/// Why a submission never became a served request (mirrors the server's
/// `SubmitError` without depending on the coordinator).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectKind {
    /// Submitted after shutdown / queue closed.
    Closed,
    /// No engine accepts the payload.
    Unsupported,
    /// Payload failed the engine's prepare step.
    Invalid,
    /// A single request's booked activation transient exceeds its lane's
    /// `activations.<lane>` budget — it could never be scheduled.
    OverBudget,
}

/// Rejected-submission totals, by kind.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RejectCounts {
    pub closed: u64,
    pub unsupported: u64,
    pub invalid: u64,
    pub over_budget: u64,
}

impl RejectCounts {
    pub fn total(&self) -> u64 {
        self.closed + self.unsupported + self.invalid + self.over_budget
    }
}

#[derive(Default)]
struct LaneRecord {
    /// enqueue→reply latency (what [`LaneStats::record`] always fed).
    total: LatencyStats,
    /// enqueue→pickup wait in the sharded queue.
    queue: LatencyStats,
    /// pickup→reply time inside the fused forward + delivery.
    service: LatencyStats,
    /// Requests that died with their group (engine panic / bad answer
    /// count) and never produced a reply.
    drops: u64,
    /// batch size → number of fused groups of that size.
    batches: std::collections::BTreeMap<usize, u64>,
    /// per-*token* latency on streaming decode lanes (one sample per
    /// emitted token; the p50/p99 a generative SLA is written against).
    tokens: LatencyStats,
}

/// Latency stats for the multi-lane server: one aggregate collector plus
/// one per named workload lane ("sentiment", "vqa", …). Cheap `Clone`
/// handle over shared state, like [`LatencyStats`]. The aggregate methods
/// (`count`/`mean_ms`/`percentile_ms`) delegate to the overall collector
/// so single-lane callers can treat a `LaneStats` like a `LatencyStats`.
///
/// Beyond latencies, lanes carry the serve loop's error/drop accounting —
/// group drops after engine panics, `SubmitError` rejections by kind, the
/// queue-wait vs. service split, and a batch-size histogram — so lost
/// requests are visible in the heartbeat and final report instead of
/// silently missing from the counts.
#[derive(Clone, Default)]
pub struct LaneStats {
    overall: LatencyStats,
    lanes: Arc<Mutex<Vec<(String, LaneRecord)>>>,
    rejects: Arc<Mutex<RejectCounts>>,
}

impl LaneStats {
    pub fn new() -> Self {
        Self::default()
    }

    fn with_lane<T>(&self, lane: &str, f: impl FnOnce(&mut LaneRecord) -> T) -> T {
        let mut lanes = self.lanes.lock().unwrap();
        if let Some(idx) = lanes.iter().position(|(n, _)| n == lane) {
            f(&mut lanes[idx].1)
        } else {
            lanes.push((lane.to_string(), LaneRecord::default()));
            let last = lanes.len() - 1;
            f(&mut lanes[last].1)
        }
    }

    /// Record one request latency under `lane` (and in the aggregate).
    pub fn record(&self, lane: &str, secs: f64) {
        self.overall.record(secs);
        self.with_lane(lane, |rec| rec.total.record(secs));
    }

    /// Record one served request as its queue-wait + service decomposition
    /// (total = `queue_secs + service_secs` lands where [`Self::record`]
    /// would put it, so counts are unchanged).
    pub fn record_split(&self, lane: &str, queue_secs: f64, service_secs: f64) {
        let total = queue_secs + service_secs;
        self.overall.record(total);
        self.with_lane(lane, |rec| {
            rec.total.record(total);
            rec.queue.record(queue_secs);
            rec.service.record(service_secs);
        });
    }

    /// Record `n` requests dropped with their group (no reply delivered).
    pub fn record_drop(&self, lane: &str, n: usize) {
        self.with_lane(lane, |rec| rec.drops += n as u64);
    }

    /// Record one fused group of `size` requests picked up on `lane`.
    pub fn record_batch(&self, lane: &str, size: usize) {
        self.with_lane(lane, |rec| *rec.batches.entry(size).or_insert(0) += 1);
    }

    /// Record one emitted token's latency on a streaming decode lane
    /// (decode-step wall time attributed to that token, not the whole
    /// request — per-token p50/p99 is the generative serving SLA).
    pub fn record_token(&self, lane: &str, secs: f64) {
        self.with_lane(lane, |rec| rec.tokens.record(secs));
    }

    /// Record one rejected submission.
    pub fn record_reject(&self, kind: RejectKind) {
        let mut r = self.rejects.lock().unwrap();
        match kind {
            RejectKind::Closed => r.closed += 1,
            RejectKind::Unsupported => r.unsupported += 1,
            RejectKind::Invalid => r.invalid += 1,
            RejectKind::OverBudget => r.over_budget += 1,
        }
    }

    /// The all-lanes aggregate.
    pub fn overall(&self) -> &LatencyStats {
        &self.overall
    }

    /// Collector for one lane (shared handle), if it has recorded anything.
    pub fn lane(&self, name: &str) -> Option<LatencyStats> {
        self.lanes
            .lock()
            .unwrap()
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, rec)| rec.total.clone())
    }

    /// Queue-wait collector for one lane (populated by
    /// [`Self::record_split`]).
    pub fn lane_queue(&self, name: &str) -> Option<LatencyStats> {
        self.lanes
            .lock()
            .unwrap()
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, rec)| rec.queue.clone())
    }

    /// Service-time collector for one lane (populated by
    /// [`Self::record_split`]).
    pub fn lane_service(&self, name: &str) -> Option<LatencyStats> {
        self.lanes
            .lock()
            .unwrap()
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, rec)| rec.service.clone())
    }

    /// Per-token latency collector for one lane (populated by
    /// [`Self::record_token`] on streaming decode lanes).
    pub fn lane_tokens(&self, name: &str) -> Option<LatencyStats> {
        self.lanes
            .lock()
            .unwrap()
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, rec)| rec.tokens.clone())
    }

    /// Dropped-request count for one lane.
    pub fn drops(&self, name: &str) -> u64 {
        self.lanes
            .lock()
            .unwrap()
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, rec)| rec.drops)
            .unwrap_or(0)
    }

    /// Dropped-request count across every lane.
    pub fn total_drops(&self) -> u64 {
        self.lanes.lock().unwrap().iter().map(|(_, rec)| rec.drops).sum()
    }

    /// `(batch size, groups)` histogram for one lane, ascending by size.
    pub fn batch_histogram(&self, name: &str) -> Vec<(usize, u64)> {
        self.lanes
            .lock()
            .unwrap()
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, rec)| rec.batches.iter().map(|(&s, &c)| (s, c)).collect())
            .unwrap_or_default()
    }

    /// Rejected-submission totals.
    pub fn rejects(&self) -> RejectCounts {
        *self.rejects.lock().unwrap()
    }

    /// Lane names in first-recorded order.
    pub fn lane_names(&self) -> Vec<String> {
        self.lanes.lock().unwrap().iter().map(|(n, _)| n.clone()).collect()
    }

    pub fn count(&self) -> usize {
        self.overall.count()
    }

    pub fn mean_ms(&self) -> f64 {
        self.overall.mean_ms()
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.overall.percentile_ms(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_tracks_peak_not_final() {
        let led = MemoryLedger::new();
        led.alloc("a", 100);
        led.alloc("b", 50);
        led.free("a", 100);
        led.alloc("a", 20);
        assert_eq!(led.live_bytes(), 70);
        assert_eq!(led.peak_bytes(), 150);
    }

    #[test]
    fn scoped_frees() {
        let led = MemoryLedger::new();
        let out = led.scoped("tmp", 1000, || {
            assert_eq!(led.live_bytes(), 1000);
            42
        });
        assert_eq!(out, 42);
        assert_eq!(led.live_bytes(), 0);
        assert_eq!(led.peak_bytes(), 1000);
    }

    #[test]
    fn per_tag_peaks() {
        let led = MemoryLedger::new();
        led.alloc("hessian", 10);
        led.alloc("hessian", 30);
        led.free("hessian", 40);
        led.alloc("weights", 5);
        assert_eq!(led.peak_for("hessian"), 40);
        assert_eq!(led.peak_for("weights"), 5);
        assert_eq!(led.breakdown()[0].0, "hessian");
    }

    #[test]
    fn budgets_gate_try_alloc_but_not_alloc() {
        let led = MemoryLedger::new();
        led.set_budget("activations.sentiment", 100);
        assert_eq!(led.budget_for("activations.sentiment"), Some(100));
        assert_eq!(led.budget_for("activations.vqa"), None);
        // fits: booked
        assert_eq!(led.try_alloc("activations.sentiment", 60), Ok(()));
        // would overshoot: refused, nothing booked, cap reported
        assert_eq!(led.try_alloc("activations.sentiment", 50), Err(100));
        assert_eq!(led.live_bytes(), 60);
        // frees open the budget back up
        led.free("activations.sentiment", 60);
        assert_eq!(led.try_alloc("activations.sentiment", 100), Ok(()));
        led.free("activations.sentiment", 100);
        // plain alloc is exact accounting, not admission control
        led.alloc("activations.sentiment", 500);
        assert_eq!(led.live_bytes(), 500);
        led.free("activations.sentiment", 500);
        // unbudgeted tags always admit
        assert_eq!(led.try_alloc("activations.vqa", 1 << 30), Ok(()));
        led.free("activations.vqa", 1 << 30);
        // clearing removes the cap
        led.clear_budget("activations.sentiment");
        assert_eq!(led.try_alloc("activations.sentiment", 1 << 20), Ok(()));
        led.free("activations.sentiment", 1 << 20);
        assert_eq!(led.live_bytes(), 0);
    }

    #[test]
    fn alloc_blocking_waits_for_free_and_fails_fast_when_impossible() {
        let led = MemoryLedger::new();
        led.set_budget("activations.generate", 100);
        // headroom available: books immediately, like try_alloc
        assert_eq!(led.alloc_blocking("activations.generate", 80), Ok(()));
        // larger than the cap itself: can never fit — immediate Err, no hang
        assert_eq!(led.alloc_blocking("activations.generate", 150), Err(100));
        assert_eq!(led.live_bytes(), 80);
        // at the cap: parks until a concurrent free opens headroom
        let led2 = led.clone();
        let waiter = std::thread::spawn(move || led2.alloc_blocking("activations.generate", 60));
        std::thread::sleep(Duration::from_millis(20));
        led.free("activations.generate", 80);
        assert_eq!(waiter.join().unwrap(), Ok(()));
        assert_eq!(led.live_bytes(), 60);
        led.free("activations.generate", 60);
        // unbudgeted tags behave exactly like plain alloc
        assert_eq!(led.alloc_blocking("activations.vqa", 1 << 20), Ok(()));
        led.free("activations.vqa", 1 << 20);
        assert_eq!(led.live_bytes(), 0);
    }

    #[test]
    fn lane_stats_per_token_latency() {
        let s = LaneStats::new();
        for i in 1..=100 {
            s.record_token("generate", i as f64 / 1000.0);
        }
        let t = s.lane_tokens("generate").expect("token stats recorded");
        assert_eq!(t.count(), 100);
        assert!((t.percentile_ms(50.0) - 50.0).abs() <= 1.0);
        assert!((t.percentile_ms(99.0) - 99.0).abs() <= 1.0);
        // token samples never leak into the request-latency counts
        assert_eq!(s.count(), 0);
        assert!(s.lane_tokens("nope").is_none());
    }

    #[test]
    fn ledger_balances_under_concurrent_workers() {
        // The parallel pipeline's accounting contract: arbitrary
        // interleavings of alloc/free from pool workers keep live bytes
        // exact and the peak at least the largest single allocation. Pin
        // the shard target so map() actually runs the jobs concurrently.
        let _guard = crate::exec::thread_target_test_lock();
        let before = crate::exec::num_threads();
        crate::exec::set_threads(4);
        let led = MemoryLedger::new();
        let timers = Timers::new();
        let pool = crate::exec::ThreadPool::new(4);
        let jobs: Vec<_> = (0..32)
            .map(|i| {
                let led = led.clone();
                let timers = timers.clone();
                move || {
                    timers.time("job", || {
                        led.scoped("worker_tmp", 1000 + i, || {
                            std::thread::yield_now();
                        });
                    });
                }
            })
            .collect();
        let _: Vec<()> = pool.map(jobs);
        crate::exec::set_threads(before);
        assert_eq!(led.live_bytes(), 0);
        assert!(led.peak_bytes() >= 1031);
        assert!(led.peak_for("worker_tmp") >= 1031);
        assert!(timers.get("job") >= 0.0);
    }

    #[test]
    fn timers_accumulate() {
        let t = Timers::new();
        t.add("x", 0.5);
        t.add("x", 0.25);
        t.add("y", 1.0);
        assert!((t.get("x") - 0.75).abs() < 1e-9);
        assert!((t.total() - 1.75).abs() < 1e-9);
        assert_eq!(t.snapshot()[0].0, "y");
    }

    #[test]
    fn lane_stats_split_and_aggregate() {
        let s = LaneStats::new();
        for i in 1..=10 {
            s.record("sentiment", i as f64 / 1000.0);
        }
        s.record("vqa", 0.5);
        assert_eq!(s.count(), 11);
        assert_eq!(s.lane("sentiment").unwrap().count(), 10);
        assert_eq!(s.lane("vqa").unwrap().count(), 1);
        assert!(s.lane("nope").is_none());
        assert_eq!(s.lane_names(), vec!["sentiment".to_string(), "vqa".to_string()]);
        // aggregate p95 dominated by the slow vqa sample
        assert!(s.percentile_ms(99.0) >= 499.0);
        assert!(s.lane("sentiment").unwrap().percentile_ms(99.0) <= 11.0);
        // concurrent recording from worker threads is safe
        let s2 = s.clone();
        std::thread::scope(|sc| {
            for _ in 0..4 {
                let s3 = s2.clone();
                sc.spawn(move || {
                    for _ in 0..25 {
                        s3.record("sentiment", 0.001);
                    }
                });
            }
        });
        assert_eq!(s.lane("sentiment").unwrap().count(), 110);
    }

    #[test]
    fn latency_percentiles() {
        let l = LatencyStats::new();
        for i in 1..=100 {
            l.record(i as f64 / 1000.0);
        }
        assert!((l.percentile_ms(50.0) - 50.0).abs() <= 1.0);
        assert!((l.percentile_ms(95.0) - 95.0).abs() <= 1.0);
        assert!((l.mean_ms() - 50.5).abs() < 0.5);
    }

    #[test]
    fn latency_reservoir_bounds_memory_and_stays_deterministic() {
        let n = LATENCY_RESERVOIR_CAP * 4;
        let l = LatencyStats::new();
        // uniform ramp: percentiles of the reservoir should track the
        // stream's percentiles
        for i in 1..=n {
            l.record(i as f64 / n as f64);
        }
        assert_eq!(l.count(), n, "count exact past the cap");
        assert!((l.mean_ms() - 500.0 * (1.0 + 1.0 / n as f64)).abs() < 1e-6, "mean exact");
        assert!(l.inner.lock().unwrap().reservoir.len() <= LATENCY_RESERVOIR_CAP);
        let p50 = l.percentile_ms(50.0);
        assert!((p50 - 500.0).abs() < 50.0, "reservoir p50 ≈ stream p50, got {p50}");
        // fixed arrival order ⇒ identical reservoir ⇒ identical percentile
        let l2 = LatencyStats::new();
        for i in 1..=n {
            l2.record(i as f64 / n as f64);
        }
        for p in [10.0, 50.0, 99.0] {
            assert_eq!(l.percentile_ms(p).to_bits(), l2.percentile_ms(p).to_bits());
        }
    }

    #[test]
    fn lane_stats_split_drops_rejects_and_batches() {
        let s = LaneStats::new();
        s.record_split("vqa", 0.002, 0.008);
        s.record_split("vqa", 0.004, 0.006);
        // total lands where record() would put it
        assert_eq!(s.count(), 2);
        assert_eq!(s.lane("vqa").unwrap().count(), 2);
        assert!((s.lane("vqa").unwrap().mean_ms() - 10.0).abs() < 1e-9);
        assert!((s.lane_queue("vqa").unwrap().mean_ms() - 3.0).abs() < 1e-9);
        assert!((s.lane_service("vqa").unwrap().mean_ms() - 7.0).abs() < 1e-9);
        assert!(s.lane_queue("nope").is_none());
        // drops are per lane and never enter the latency counts
        s.record_drop("vqa", 3);
        s.record_drop("sentiment", 1);
        assert_eq!(s.drops("vqa"), 3);
        assert_eq!(s.total_drops(), 4);
        assert_eq!(s.count(), 2);
        // rejects by kind
        s.record_reject(RejectKind::Closed);
        s.record_reject(RejectKind::Invalid);
        s.record_reject(RejectKind::Invalid);
        let r = s.rejects();
        assert_eq!((r.closed, r.unsupported, r.invalid, r.total()), (1, 0, 2, 3));
        // batch histogram, ascending by size
        s.record_batch("vqa", 4);
        s.record_batch("vqa", 1);
        s.record_batch("vqa", 4);
        assert_eq!(s.batch_histogram("vqa"), vec![(1, 1), (4, 2)]);
        assert!(s.batch_histogram("nope").is_empty());
    }

    #[test]
    fn ledger_emits_counter_tracks_when_tracing() {
        let _guard = crate::trace::test_lock();
        crate::trace::start();
        let led = MemoryLedger::new();
        led.alloc("hessian", 1000);
        led.alloc("hessian", 500);
        led.free("hessian", 1500);
        let t = crate::trace::stop_and_take();
        let s = t.summary().unwrap();
        let mem = s.counters.iter().find(|c| c.name == "mem.hessian").unwrap();
        assert_eq!(mem.samples, 3);
        assert!((mem.peak - 1500.0).abs() < 1e-9);
        assert!((mem.last - 0.0).abs() < 1e-9);
        let live = s.counters.iter().find(|c| c.name == "mem.live").unwrap();
        assert!((live.peak - 1500.0).abs() < 1e-9);
        // and nothing when disabled
        led.alloc("hessian", 10);
        led.free("hessian", 10);
        assert!(crate::trace::take().events.is_empty());
    }
}
