//! Measurement substrate: a byte-accurate memory ledger and wall-clock
//! timers.
//!
//! The paper's Tables 3 and 4 report *peak GPU memory* and *total
//! quantization time* for GPTQ vs RPIQ. We have no GPU; instead every
//! tensor the quantization engines allocate is registered with a
//! [`MemoryLedger`] scope, which tracks live bytes and the high-water mark.
//! Because both engines are instrumented identically, the relative overhead
//! ΔM (Eq. 27) — the quantity the paper actually analyses — is preserved.
//!
//! Concurrency: [`MemoryLedger`], [`Timers`], and [`LatencyStats`] are
//! cheap `Clone` handles over one `Arc<Mutex<…>>` state and are shared
//! freely with pool workers (the parallel pipeline records alloc/free and
//! stage timings from many layer jobs at once). Alloc/free pairing is
//! exact under concurrency — live bytes always return to zero — while the
//! *peak* is a property of the observed interleaving: more jobs in flight
//! can legitimately raise it. Determinism-sensitive comparisons must pin
//! `exec::set_threads` (see the pipeline tests).

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

pub mod tags;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Thread-safe allocation ledger with peak tracking.
#[derive(Clone, Default)]
pub struct MemoryLedger {
    inner: Arc<Mutex<LedgerInner>>,
}

#[derive(Default)]
struct LedgerInner {
    live: i64,
    peak: i64,
    /// live bytes per named category (weights, hessian, calib, residuals…)
    by_tag: HashMap<String, i64>,
    peak_by_tag: HashMap<String, i64>,
}

impl MemoryLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an allocation of `bytes` under `tag`.
    pub fn alloc(&self, tag: &str, bytes: usize) {
        let mut g = self.inner.lock().unwrap();
        g.live += bytes as i64;
        if g.live > g.peak {
            g.peak = g.live;
        }
        let e = g.by_tag.entry(tag.to_string()).or_insert(0);
        *e += bytes as i64;
        let cur = *e;
        let p = g.peak_by_tag.entry(tag.to_string()).or_insert(0);
        if cur > *p {
            *p = cur;
        }
    }

    /// Record a release of `bytes` under `tag`.
    pub fn free(&self, tag: &str, bytes: usize) {
        let mut g = self.inner.lock().unwrap();
        g.live -= bytes as i64;
        *g.by_tag.entry(tag.to_string()).or_insert(0) -= bytes as i64;
    }

    /// Convenience: account `bytes` for the duration of `f`.
    pub fn scoped<T>(&self, tag: &str, bytes: usize, f: impl FnOnce() -> T) -> T {
        self.alloc(tag, bytes);
        let out = f();
        self.free(tag, bytes);
        out
    }

    pub fn live_bytes(&self) -> i64 {
        self.inner.lock().unwrap().live
    }

    pub fn peak_bytes(&self) -> i64 {
        self.inner.lock().unwrap().peak
    }

    pub fn peak_gib(&self) -> f64 {
        self.peak_bytes() as f64 / (1u64 << 30) as f64
    }

    pub fn peak_mib(&self) -> f64 {
        self.peak_bytes() as f64 / (1u64 << 20) as f64
    }

    /// Peak bytes attributed to one tag.
    pub fn peak_for(&self, tag: &str) -> i64 {
        self.inner
            .lock()
            .unwrap()
            .peak_by_tag
            .get(tag)
            .copied()
            .unwrap_or(0)
    }

    /// Snapshot of per-tag peaks, sorted descending.
    pub fn breakdown(&self) -> Vec<(String, i64)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<_> = g.peak_by_tag.iter().map(|(k, &b)| (k.clone(), b)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v
    }

    /// Reset everything (between experiment arms).
    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        *g = LedgerInner::default();
    }
}

/// Simple named wall-clock stopwatch collection.
#[derive(Clone, Default)]
pub struct Timers {
    inner: Arc<Mutex<HashMap<String, f64>>>,
}

impl Timers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` and accumulate under `name`.
    ///
    /// The duration is *exclusive* of help-first work stealing: when the
    /// current thread inline-runs another scope's job while waiting in a
    /// pool join (see `exec::helped_secs`), that stolen job's wall time is
    /// subtracted here — it is timed once, by its own `time` call, instead
    /// of inflating whichever window it happened to run inside. (A thread
    /// running its own scope's shard jobs is doing its own work and is
    /// *not* subtracted.)
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        self.time_secs(name, f).0
    }

    /// Like [`Self::time`], additionally returning the exclusive duration
    /// that was accumulated (the pipeline records per-layer stage seconds
    /// from this without double instrumentation).
    pub fn time_secs<T>(&self, name: &str, f: impl FnOnce() -> T) -> (T, f64) {
        let t0 = Instant::now();
        let h0 = crate::exec::helped_secs();
        let out = f();
        let helped = crate::exec::helped_secs() - h0;
        let dt = (t0.elapsed().as_secs_f64() - helped).max(0.0);
        *self.inner.lock().unwrap().entry(name.to_string()).or_insert(0.0) += dt;
        (out, dt)
    }

    /// Add an externally measured duration.
    pub fn add(&self, name: &str, secs: f64) {
        *self.inner.lock().unwrap().entry(name.to_string()).or_insert(0.0) += secs;
    }

    pub fn get(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().get(name).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.inner.lock().unwrap().values().sum()
    }

    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<_> = g.iter().map(|(k, &s)| (k.clone(), s)).collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }

    pub fn reset(&self) {
        self.inner.lock().unwrap().clear();
    }
}

/// Streaming percentile/latency collector for the serving experiments.
#[derive(Clone, Default)]
pub struct LatencyStats {
    samples: Arc<Mutex<Vec<f64>>>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, secs: f64) {
        self.samples.lock().unwrap().push(secs);
    }

    pub fn count(&self) -> usize {
        self.samples.lock().unwrap().len()
    }

    pub fn mean_ms(&self) -> f64 {
        let s = self.samples.lock().unwrap();
        if s.is_empty() {
            return 0.0;
        }
        s.iter().sum::<f64>() / s.len() as f64 * 1e3
    }

    /// p in [0,100].
    pub fn percentile_ms(&self, p: f64) -> f64 {
        let mut s = self.samples.lock().unwrap().clone();
        if s.is_empty() {
            return 0.0;
        }
        s.sort_by(f64::total_cmp);
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx] * 1e3
    }
}

/// Latency stats for the multi-lane server: one aggregate collector plus
/// one per named workload lane ("sentiment", "vqa", …). Cheap `Clone`
/// handle over shared state, like [`LatencyStats`]. The aggregate methods
/// (`count`/`mean_ms`/`percentile_ms`) delegate to the overall collector
/// so single-lane callers can treat a `LaneStats` like a `LatencyStats`.
#[derive(Clone, Default)]
pub struct LaneStats {
    overall: LatencyStats,
    lanes: Arc<Mutex<Vec<(String, LatencyStats)>>>,
}

impl LaneStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request latency under `lane` (and in the aggregate).
    pub fn record(&self, lane: &str, secs: f64) {
        self.overall.record(secs);
        let mut lanes = self.lanes.lock().unwrap();
        if let Some(idx) = lanes.iter().position(|(n, _)| n == lane) {
            lanes[idx].1.record(secs);
        } else {
            let s = LatencyStats::new();
            s.record(secs);
            lanes.push((lane.to_string(), s));
        }
    }

    /// The all-lanes aggregate.
    pub fn overall(&self) -> &LatencyStats {
        &self.overall
    }

    /// Collector for one lane (shared handle), if it has recorded anything.
    pub fn lane(&self, name: &str) -> Option<LatencyStats> {
        self.lanes
            .lock()
            .unwrap()
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.clone())
    }

    /// Lane names in first-recorded order.
    pub fn lane_names(&self) -> Vec<String> {
        self.lanes.lock().unwrap().iter().map(|(n, _)| n.clone()).collect()
    }

    pub fn count(&self) -> usize {
        self.overall.count()
    }

    pub fn mean_ms(&self) -> f64 {
        self.overall.mean_ms()
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.overall.percentile_ms(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_tracks_peak_not_final() {
        let led = MemoryLedger::new();
        led.alloc("a", 100);
        led.alloc("b", 50);
        led.free("a", 100);
        led.alloc("a", 20);
        assert_eq!(led.live_bytes(), 70);
        assert_eq!(led.peak_bytes(), 150);
    }

    #[test]
    fn scoped_frees() {
        let led = MemoryLedger::new();
        let out = led.scoped("tmp", 1000, || {
            assert_eq!(led.live_bytes(), 1000);
            42
        });
        assert_eq!(out, 42);
        assert_eq!(led.live_bytes(), 0);
        assert_eq!(led.peak_bytes(), 1000);
    }

    #[test]
    fn per_tag_peaks() {
        let led = MemoryLedger::new();
        led.alloc("hessian", 10);
        led.alloc("hessian", 30);
        led.free("hessian", 40);
        led.alloc("weights", 5);
        assert_eq!(led.peak_for("hessian"), 40);
        assert_eq!(led.peak_for("weights"), 5);
        assert_eq!(led.breakdown()[0].0, "hessian");
    }

    #[test]
    fn ledger_balances_under_concurrent_workers() {
        // The parallel pipeline's accounting contract: arbitrary
        // interleavings of alloc/free from pool workers keep live bytes
        // exact and the peak at least the largest single allocation. Pin
        // the shard target so map() actually runs the jobs concurrently.
        let _guard = crate::exec::thread_target_test_lock();
        let before = crate::exec::num_threads();
        crate::exec::set_threads(4);
        let led = MemoryLedger::new();
        let timers = Timers::new();
        let pool = crate::exec::ThreadPool::new(4);
        let jobs: Vec<_> = (0..32)
            .map(|i| {
                let led = led.clone();
                let timers = timers.clone();
                move || {
                    timers.time("job", || {
                        led.scoped("worker_tmp", 1000 + i, || {
                            std::thread::yield_now();
                        });
                    });
                }
            })
            .collect();
        let _: Vec<()> = pool.map(jobs);
        crate::exec::set_threads(before);
        assert_eq!(led.live_bytes(), 0);
        assert!(led.peak_bytes() >= 1031);
        assert!(led.peak_for("worker_tmp") >= 1031);
        assert!(timers.get("job") >= 0.0);
    }

    #[test]
    fn timers_accumulate() {
        let t = Timers::new();
        t.add("x", 0.5);
        t.add("x", 0.25);
        t.add("y", 1.0);
        assert!((t.get("x") - 0.75).abs() < 1e-9);
        assert!((t.total() - 1.75).abs() < 1e-9);
        assert_eq!(t.snapshot()[0].0, "y");
    }

    #[test]
    fn lane_stats_split_and_aggregate() {
        let s = LaneStats::new();
        for i in 1..=10 {
            s.record("sentiment", i as f64 / 1000.0);
        }
        s.record("vqa", 0.5);
        assert_eq!(s.count(), 11);
        assert_eq!(s.lane("sentiment").unwrap().count(), 10);
        assert_eq!(s.lane("vqa").unwrap().count(), 1);
        assert!(s.lane("nope").is_none());
        assert_eq!(s.lane_names(), vec!["sentiment".to_string(), "vqa".to_string()]);
        // aggregate p95 dominated by the slow vqa sample
        assert!(s.percentile_ms(99.0) >= 499.0);
        assert!(s.lane("sentiment").unwrap().percentile_ms(99.0) <= 11.0);
        // concurrent recording from worker threads is safe
        let s2 = s.clone();
        std::thread::scope(|sc| {
            for _ in 0..4 {
                let s3 = s2.clone();
                sc.spawn(move || {
                    for _ in 0..25 {
                        s3.record("sentiment", 0.001);
                    }
                });
            }
        });
        assert_eq!(s.lane("sentiment").unwrap().count(), 110);
    }

    #[test]
    fn latency_percentiles() {
        let l = LatencyStats::new();
        for i in 1..=100 {
            l.record(i as f64 / 1000.0);
        }
        assert!((l.percentile_ms(50.0) - 50.0).abs() <= 1.0);
        assert!((l.percentile_ms(95.0) - 95.0).abs() <= 1.0);
        assert!((l.mean_ms() - 50.5).abs() < 0.5);
    }
}
