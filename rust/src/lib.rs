//! # rpiq — Residual-Projected Multi-Collaboration Closed-Loop and Single Instance Quantization
//!
//! A production-grade reproduction of the RPIQ post-training-quantization
//! framework as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (build-time Python): Pallas kernels for the W4A16
//!   dequant-matmul hot spot, Hessian accumulation, and the stage-2 block
//!   solve (`python/compile/kernels/`).
//! * **Layer 2** (build-time Python): JAX transformer forward graphs (fp and
//!   quantized) lowered once to HLO text (`python/compile/model.py`,
//!   `python/compile/aot.py` → `artifacts/`).
//! * **Layer 3** (this crate): the quantization engines (GPTQ stage 1, RPIQ
//!   stage 2, CMDQ cross-modal policy), the calibration pipeline, the
//!   training substrate that produces the subject checkpoints, the
//!   evaluation harnesses that regenerate every paper table/figure, and a
//!   serving runtime that executes the AOT artifacts via PJRT.
//!
//! Python never runs on the request path: once `make artifacts` has been
//! run, everything here is self-contained. (PJRT execution of those
//! artifacts needs the vendored `xla` bindings and is gated behind the
//! `pjrt` cargo feature; the default build ships a validating stub — see
//! [`runtime`].)
//!
//! Compute-heavy paths — the matmul kernels, the fused dequant-matmul,
//! the calibration window sweep, per-layer quantization with row-sharded
//! GPTQ/RPIQ inner loops, and the serve batcher's group forwards — share
//! one process-global thread pool sized by `RPIQ_THREADS` (default:
//! `available_parallelism`), with results bit-identical at any thread
//! count (enforced by the CI determinism matrix at `RPIQ_THREADS=1/2/8`).
//! See [`exec`] for the threading model, and `rust/DESIGN.md` for the
//! cross-module design notes (paper deviations, substitution ledger,
//! parallel-quantization design, perf log).

pub mod tensor;
pub mod linalg;
pub mod rng;
pub mod jsonx;
pub mod cli;
pub mod exec;
pub mod proptest;
pub mod quant;
pub mod model;
pub mod train;
pub mod vlm;
pub mod data;
pub mod eval;
pub mod metrics;
pub mod runtime;
pub mod coordinator;
pub mod report;
pub mod trace;
