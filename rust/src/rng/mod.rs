//! Deterministic pseudo-random number generation.
//!
//! Everything stochastic in this repository (synthetic corpora, weight
//! initialization, calibration sampling, property-test case generation)
//! flows through [`Pcg64`], a PCG-XSL-RR 128/64 generator. Offline builds
//! cannot pull the `rand` crate, and determinism across runs is a hard
//! requirement for the experiment harness, so we implement the generator
//! ourselves and seed it explicitly everywhere.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct streams are
    /// statistically independent; the experiment harness gives each
    /// subsystem (corpus, init, calibration, ...) its own stream.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, bound)` via the widening-multiply method.
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let x = self.next_u64() as u128;
        ((x.wrapping_mul(bound as u128)) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(0, std^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fill a slice with U[lo, hi) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.range_f32(lo, hi);
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Choose a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.next_below(items.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample from softmax(logits / temperature).
    pub fn sample_softmax(&mut self, logits: &[f32], temperature: f32) -> usize {
        let t = temperature.max(1e-6);
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> = logits
            .iter()
            .map(|&l| (((l - m) / t) as f64).exp())
            .collect();
        self.weighted(&weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::seeded(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn next_below_in_range() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..1000 {
            assert!(rng.next_below(17) < 17);
        }
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.next_below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Pcg64::seeded(4);
        let w = [0.05, 0.9, 0.05];
        let hits = (0..2000).filter(|_| rng.weighted(&w) == 1).count();
        assert!(hits > 1500, "hits={hits}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(5);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
