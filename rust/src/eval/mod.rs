//! Evaluation harnesses reproducing the paper's three metrics (§4.2):
//!
//! * [`perplexity`] — the AutoGPTQ protocol (Eq. 24): batch-level mean
//!   cross-entropy, averaged across batches, exponentiated;
//! * [`sentiment_accuracy`] — prompt-format 3-way classification (Eq. 25),
//!   answer chosen by argmax over the three label tokens at the answer
//!   position;
//! * [`vqa_accuracy`] — exact-match VQA (Eq. 26) with per-category
//!   breakdown, answer = argmax over the *full* vocabulary.
//!
//! All harnesses take the model as a logits closure so the fp path
//! (`lm_forward`), the quantized Rust path (`QuantizedLm::forward`), and
//! the PJRT-artifact path (`runtime::Engine`) are evaluated by *identical*
//! code.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

use crate::data::sentiment::SentimentSet;
use crate::data::tokenizer::Tokenizer;
use crate::data::vqa::{VqaExample, CATEGORIES};
use crate::model::ops::nll_per_position;
use crate::model::forward::shift_targets;
use crate::tensor::Tensor;

/// Logits closure type for text models: `(tokens, batch, seq) → [B·S, V]`.
pub type LmLogitsFn<'a> = dyn Fn(&[u32], usize, usize) -> Tensor + 'a;

/// Perplexity per the AutoGPTQ protocol (paper Eq. 24): each evaluation
/// window is one "batch"; PPL = exp(mean over batches of per-batch mean
/// NLL).
pub fn perplexity(logits_fn: &LmLogitsFn, windows: &[Vec<u32>]) -> f64 {
    assert!(!windows.is_empty());
    let mut batch_losses = Vec::with_capacity(windows.len());
    for w in windows {
        let seq = w.len();
        let logits = logits_fn(w, 1, seq);
        let targets = shift_targets(w, 1, seq);
        let nll = nll_per_position(&logits, &targets, -100);
        let vals: Vec<f64> = nll.into_iter().filter(|v| !v.is_nan()).collect();
        batch_losses.push(vals.iter().sum::<f64>() / vals.len() as f64);
    }
    (batch_losses.iter().sum::<f64>() / batch_losses.len() as f64).exp()
}

/// Sentiment accuracy (paper Eq. 25). For each example, run the prompt and
/// compare the logits of the three label tokens at the final position.
/// Returns accuracy in percent.
pub fn sentiment_accuracy(
    logits_fn: &LmLogitsFn,
    tok: &Tokenizer,
    examples: &[crate::data::sentiment::SentimentExample],
    max_len: usize,
) -> f64 {
    let label_ids = SentimentSet::label_token_ids(tok);
    let mut correct = 0usize;
    for e in examples {
        let mut ids = tok.encode(&e.prompt());
        if ids.len() > max_len {
            // truncate from the left, keeping the answer scaffold
            ids = ids[ids.len() - max_len..].to_vec();
        }
        let seq = ids.len();
        let logits = logits_fn(&ids, 1, seq);
        let last = logits.row(seq - 1);
        let pred = (0..3)
            .max_by(|&a, &b| {
                last[label_ids[a] as usize]
                    .partial_cmp(&last[label_ids[b] as usize])
                    .unwrap()
            })
            .unwrap();
        if pred == e.label {
            correct += 1;
        }
    }
    100.0 * correct as f64 / examples.len() as f64
}

/// Per-category VQA result.
#[derive(Clone, Debug, Default)]
pub struct VqaReport {
    pub overall_pct: f64,
    /// (category name, accuracy %) in `CATEGORIES` order.
    pub per_category: Vec<(String, f64)>,
}

/// VQA logits closure: `(patches, text, batch) → [B·S, V]`.
pub type VqaLogitsFn<'a> = dyn Fn(&Tensor, &[u32], usize) -> Tensor + 'a;

/// Exact-match VQA accuracy (paper Eq. 26) with the Table 2 per-category
/// breakdown. The answer is the argmax token over the full vocabulary at
/// the position following the question.
pub fn vqa_accuracy(
    logits_fn: &VqaLogitsFn,
    tok: &Tokenizer,
    examples: &[VqaExample],
    n_patches: usize,
) -> VqaReport {
    let mut cat_total = [0usize; 5];
    let mut cat_correct = [0usize; 5];
    for e in examples {
        let q_ids = tok.encode(&e.question);
        let seq = n_patches + q_ids.len();
        let logits = logits_fn(&e.cover.patches, &q_ids, 1);
        let last = logits.row(seq - 1);
        let pred = (0..last.len())
            .max_by(|&a, &b| last[a].partial_cmp(&last[b]).unwrap())
            .unwrap() as u32;
        cat_total[e.category] += 1;
        if tok.word(pred) == e.answer {
            cat_correct[e.category] += 1;
        }
    }
    let total: usize = cat_total.iter().sum();
    let correct: usize = cat_correct.iter().sum();
    VqaReport {
        overall_pct: 100.0 * correct as f64 / total.max(1) as f64,
        per_category: CATEGORIES
            .iter()
            .enumerate()
            .map(|(c, name)| {
                (
                    name.to_string(),
                    100.0 * cat_correct[c] as f64 / cat_total[c].max(1) as f64,
                )
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Lexicon;
    use crate::data::sentiment::LABELS;
    use crate::data::sentiment::SentimentExample;
    use crate::data::vqa::VqaSet;

    #[test]
    fn ppl_of_uniform_model_is_vocab_size() {
        let v = 50usize;
        let f = move |_t: &[u32], b: usize, s: usize| Tensor::zeros(&[b * s, v]);
        let windows = vec![vec![1u32; 16], vec![2u32; 16]];
        let ppl = perplexity(&f, &windows);
        assert!((ppl - v as f64).abs() < 1e-6);
    }

    #[test]
    fn ppl_of_oracle_model_is_one() {
        // model that puts all mass on the true next token
        let windows = vec![(0u32..12).collect::<Vec<u32>>()];
        let w2 = windows.clone();
        let f = move |t: &[u32], b: usize, s: usize| {
            let _ = &w2;
            let mut l = Tensor::zeros(&[b * s, 16]);
            for i in 0..s - 1 {
                let next = t[i + 1] as usize;
                l.row_mut(i)[next] = 100.0;
            }
            l
        };
        let ppl = perplexity(&f, &windows);
        assert!(ppl < 1.001, "ppl={ppl}");
    }

    #[test]
    fn sentiment_oracle_scores_100() {
        let tok = Lexicon::tokenizer();
        let label_ids = SentimentSet::label_token_ids(&tok);
        let exs = vec![
            SentimentExample { text: "i loved this movie".into(), label: 2 },
            SentimentExample { text: "i hated this movie".into(), label: 0 },
        ];
        // oracle peeks at the prompt: if it contains "loved" answer positive
        let tok2 = tok.clone();
        let f = move |t: &[u32], b: usize, s: usize| {
            let mut l = Tensor::zeros(&[b * s, tok2.vocab_size()]);
            let text = tok2.decode(t);
            let lab = if text.contains("loved") { 2 } else { 0 };
            l.row_mut(s - 1)[label_ids[lab] as usize] = 10.0;
            l
        };
        let acc = sentiment_accuracy(&f, &tok, &exs, 48);
        assert_eq!(acc, 100.0);
    }

    #[test]
    fn sentiment_constant_model_scores_one_third_ish() {
        let tok = Lexicon::tokenizer();
        let v = tok.vocab_size();
        let f = move |_t: &[u32], b: usize, s: usize| Tensor::zeros(&[b * s, v]);
        let s = crate::data::sentiment::SentimentSet::generate(9, 0, 120);
        let acc = sentiment_accuracy(&f, &tok, &s.test, 48);
        // constant logits → ties; max_by keeps the last maximum → always
        // predicts class 2 ("positive"), i.e. the class-2 base rate.
        let class2 = 100.0 * s.test.iter().filter(|e| e.label == 2).count() as f64
            / s.test.len() as f64;
        assert!((acc - class2).abs() < 1e-9);
    }

    #[test]
    fn vqa_oracle_scores_100_and_reports_categories() {
        let tok = Lexicon::tokenizer();
        let set = VqaSet::generate(4, 8, 24, 0, 4);
        let tok2 = tok.clone();
        let answers: Vec<u32> = set.test.iter().map(|e| tok.id(&e.answer)).collect();
        let idx = std::cell::Cell::new(0usize);
        let f = move |_p: &Tensor, q: &[u32], b: usize| {
            let s = 8 + q.len();
            let mut l = Tensor::zeros(&[b * s, tok2.vocab_size()]);
            let a = answers[idx.get()];
            idx.set(idx.get() + 1);
            l.row_mut(s - 1)[a as usize] = 5.0;
            l
        };
        let rep = vqa_accuracy(&f, &tok, &set.test, 8);
        assert_eq!(rep.overall_pct, 100.0);
        assert_eq!(rep.per_category.len(), 5);
        assert!(rep.per_category.iter().all(|(_, a)| *a == 100.0));
        assert_eq!(rep.per_category[0].0, "cookbooks");
    }

    #[test]
    fn labels_constant_matches_paper_order() {
        assert_eq!(LABELS, ["negative", "neutral", "positive"]);
    }
}
