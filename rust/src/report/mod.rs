//! Report formatting: the benches print their results as the paper's
//! tables; this module renders aligned ASCII tables, CSV series (for the
//! figures), and JSON blobs for machine consumption.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

use crate::jsonx::Json;

/// A simple aligned-text table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                line.push_str(&format!("{:width$}", cells[i], width = widths[i]));
                if i + 1 < ncols {
                    line.push_str("  ");
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        out.push_str(&format!(
            "{}\n",
            "-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1))
        ));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Machine-readable JSON form.
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut obj = Json::obj();
                for (h, c) in self.headers.iter().zip(r.iter()) {
                    obj = obj.with(h, Json::Str(c.clone()));
                }
                obj
            })
            .collect();
        Json::obj()
            .with("title", Json::Str(self.title.clone()))
            .with("rows", Json::Arr(rows))
    }
}

/// CSV series writer (Fig 5-style convergence trajectories).
pub fn csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = headers.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&r.join(","));
        out.push('\n');
    }
    out
}

/// Format helpers.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn mib(bytes: i64) -> String {
    format!("{:.2} MiB", bytes as f64 / (1 << 20) as f64)
}

pub fn pct_delta(ours: f64, base: f64) -> String {
    if base == 0.0 {
        return "n/a".into();
    }
    format!("{:+.1}%", 100.0 * (ours - base) / base)
}

/// Write a report file under `reports/`, creating the directory.
pub fn write_report(name: &str, content: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("reports");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["model", "acc"]);
        t.row(vec!["sim-opt-6.7b".into(), "44.25".into()]);
        t.row(vec!["q".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // all data lines same width alignment: "model" column padded
        assert!(lines[1].starts_with("model"));
        assert!(lines[3].starts_with("sim-opt-6.7b"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_and_json_shapes() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let j = t.to_json();
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
        let c = csv(&["x", "y"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(c, "x,y\n1,2\n");
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(1.234), "1.23");
        assert_eq!(pct_delta(110.0, 100.0), "+10.0%");
        assert_eq!(mib(1 << 20), "1.00 MiB");
    }
}
