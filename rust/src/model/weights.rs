//! Weight containers and the canonical layer-name scheme used by the
//! quantization pipeline, checkpoints, and the artifact manifest.
//!
//! Canonical linear names (these are what [`crate::quant::cmdq`] matches
//! and what the coordinator's per-layer reports carry):
//!
//! * `lm.layer{i}.attn.{q,k,v,out}`
//! * `lm.layer{i}.mlp.{up,down}`
//! * `lm.head` (when untied)

use super::config::ModelConfig;
use crate::rng::Pcg64;
use crate::tensor::Tensor;
use std::sync::OnceLock;

/// One transformer block's canonical tap names (the strings
/// [`crate::model::ActivationTap`] is keyed by).
#[derive(Clone, Debug)]
pub struct LayerTapNames {
    pub attn_q: String,
    pub attn_k: String,
    pub attn_v: String,
    pub attn_out: String,
    pub mlp_up: String,
    pub mlp_down: String,
}

/// Per-layer canonical tap names, formatted once per weights instance.
///
/// The calibration sweep runs hundreds of tapped forwards against one
/// weights instance; formatting `lm.layer{i}.attn.q` etc. inside every
/// forward was the same hot-path string churn PR 8 removed from the
/// quantized plans. [`LmWeights::tap_names`] lazily builds this table
/// exactly once.
#[derive(Clone, Debug, Default)]
pub struct TapNames {
    layers: Vec<LayerTapNames>,
}

impl TapNames {
    /// Build the canonical name table for `n_layers` transformer blocks.
    pub fn for_layers(n_layers: usize) -> Self {
        let layers = (0..n_layers)
            .map(|i| LayerTapNames {
                attn_q: format!("lm.layer{i}.attn.q"),
                attn_k: format!("lm.layer{i}.attn.k"),
                attn_v: format!("lm.layer{i}.attn.v"),
                attn_out: format!("lm.layer{i}.attn.out"),
                mlp_up: format!("lm.layer{i}.mlp.up"),
                mlp_down: format!("lm.layer{i}.mlp.down"),
            })
            .collect();
        TapNames { layers }
    }

    /// Names of block `li` (panics past `n_layers`, like the forward's
    /// own layer indexing would).
    pub fn layer(&self, li: usize) -> &LayerTapNames {
        &self.layers[li]
    }
}

/// One transformer block's parameters.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub w_up: Tensor,
    pub w_down: Tensor,
    pub ln1_g: Tensor,
    pub ln1_b: Tensor,
    pub ln2_g: Tensor,
    pub ln2_b: Tensor,
}

/// Full LM parameter set.
#[derive(Clone, Debug)]
pub struct LmWeights {
    pub config: ModelConfig,
    /// `[vocab, d_model]`
    pub tok_emb: Tensor,
    /// `[seq_len, d_model]`
    pub pos_emb: Tensor,
    pub layers: Vec<LayerWeights>,
    pub lnf_g: Tensor,
    pub lnf_b: Tensor,
    /// `[vocab, d_model]`; `None` when tied to `tok_emb`.
    pub head: Option<Tensor>,
    /// Lazily-built canonical tap names (see [`TapNames`]).
    tap_names: OnceLock<TapNames>,
}

impl LmWeights {
    /// GPT-2-style initialization.
    pub fn init(config: &ModelConfig, rng: &mut Pcg64) -> Self {
        let d = config.d_model;
        let std = 0.02f32;
        let resid_std = std / (2.0 * config.n_layers as f32).sqrt();
        let layers = (0..config.n_layers)
            .map(|_| LayerWeights {
                wq: Tensor::randn(&[d, d], std, rng),
                wk: Tensor::randn(&[d, d], std, rng),
                wv: Tensor::randn(&[d, d], std, rng),
                wo: Tensor::randn(&[d, d], resid_std, rng),
                w_up: Tensor::randn(&[config.d_ff, d], std, rng),
                w_down: Tensor::randn(&[d, config.d_ff], resid_std, rng),
                ln1_g: Tensor::from_vec(&[d], vec![1.0; d]),
                ln1_b: Tensor::zeros(&[d]),
                ln2_g: Tensor::from_vec(&[d], vec![1.0; d]),
                ln2_b: Tensor::zeros(&[d]),
            })
            .collect();
        LmWeights {
            tok_emb: Tensor::randn(&[config.vocab, d], std, rng),
            pos_emb: Tensor::randn(&[config.seq_len, d], std, rng),
            layers,
            lnf_g: Tensor::from_vec(&[d], vec![1.0; d]),
            lnf_b: Tensor::zeros(&[d]),
            head: if config.tied_head {
                None
            } else {
                Some(Tensor::randn(&[config.vocab, d], std, rng))
            },
            config: config.clone(),
            tap_names: OnceLock::new(),
        }
    }

    /// The LM head matrix (tied or not).
    pub fn head_matrix(&self) -> &Tensor {
        self.head.as_ref().unwrap_or(&self.tok_emb)
    }

    /// Canonical per-layer tap names, formatted once per weights instance
    /// and cached — the tapped forwards read from here instead of
    /// rebuilding the strings per call.
    pub fn tap_names(&self) -> &TapNames {
        self.tap_names
            .get_or_init(|| TapNames::for_layers(self.config.n_layers))
    }

    /// All quantizable linear layers in forward order, with canonical
    /// names. Embeddings and LayerNorms stay fp32 (standard PTQ practice
    /// and what the paper does).
    pub fn linears(&self) -> Vec<(String, &Tensor)> {
        let mut v = Vec::new();
        for (i, l) in self.layers.iter().enumerate() {
            v.push((format!("lm.layer{i}.attn.q"), &l.wq));
            v.push((format!("lm.layer{i}.attn.k"), &l.wk));
            v.push((format!("lm.layer{i}.attn.v"), &l.wv));
            v.push((format!("lm.layer{i}.attn.out"), &l.wo));
            v.push((format!("lm.layer{i}.mlp.up"), &l.w_up));
            v.push((format!("lm.layer{i}.mlp.down"), &l.w_down));
        }
        if let Some(h) = &self.head {
            v.push(("lm.head".into(), h));
        }
        v
    }

    /// Mutable access to a linear by canonical name.
    pub fn linear_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        if name == "lm.head" {
            return self.head.as_mut();
        }
        let rest = name.strip_prefix("lm.layer")?;
        let (idx, field) = rest.split_once('.')?;
        let l = self.layers.get_mut(idx.parse::<usize>().ok()?)?;
        Some(match field {
            "attn.q" => &mut l.wq,
            "attn.k" => &mut l.wk,
            "attn.v" => &mut l.wv,
            "attn.out" => &mut l.wo,
            "mlp.up" => &mut l.w_up,
            "mlp.down" => &mut l.w_down,
            _ => return None,
        })
    }

    /// Shared access by canonical name.
    pub fn linear(&self, name: &str) -> Option<&Tensor> {
        if name == "lm.head" {
            return self.head.as_ref();
        }
        let rest = name.strip_prefix("lm.layer")?;
        let (idx, field) = rest.split_once('.')?;
        let l = self.layers.get(idx.parse::<usize>().ok()?)?;
        Some(match field {
            "attn.q" => &l.wq,
            "attn.k" => &l.wk,
            "attn.v" => &l.wv,
            "attn.out" => &l.wo,
            "mlp.up" => &l.w_up,
            "mlp.down" => &l.w_down,
            _ => return None,
        })
    }

    /// Every named tensor (for checkpointing / optimizer state), linears
    /// and non-linears alike.
    pub fn named_tensors(&self) -> Vec<(String, &Tensor)> {
        let mut v = vec![
            ("tok_emb".to_string(), &self.tok_emb),
            ("pos_emb".to_string(), &self.pos_emb),
        ];
        for (i, l) in self.layers.iter().enumerate() {
            v.push((format!("lm.layer{i}.attn.q"), &l.wq));
            v.push((format!("lm.layer{i}.attn.k"), &l.wk));
            v.push((format!("lm.layer{i}.attn.v"), &l.wv));
            v.push((format!("lm.layer{i}.attn.out"), &l.wo));
            v.push((format!("lm.layer{i}.mlp.up"), &l.w_up));
            v.push((format!("lm.layer{i}.mlp.down"), &l.w_down));
            v.push((format!("lm.layer{i}.ln1.g"), &l.ln1_g));
            v.push((format!("lm.layer{i}.ln1.b"), &l.ln1_b));
            v.push((format!("lm.layer{i}.ln2.g"), &l.ln2_g));
            v.push((format!("lm.layer{i}.ln2.b"), &l.ln2_b));
        }
        v.push(("lnf.g".to_string(), &self.lnf_g));
        v.push(("lnf.b".to_string(), &self.lnf_b));
        if let Some(h) = &self.head {
            v.push(("lm.head".to_string(), h));
        }
        v
    }

    /// Mutable named access covering every tensor in [`Self::named_tensors`].
    pub fn named_tensor_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        match name {
            "tok_emb" => return Some(&mut self.tok_emb),
            "pos_emb" => return Some(&mut self.pos_emb),
            "lnf.g" => return Some(&mut self.lnf_g),
            "lnf.b" => return Some(&mut self.lnf_b),
            _ => {}
        }
        if let Some(rest) = name.strip_prefix("lm.layer") {
            let (idx, field) = rest.split_once('.')?;
            if matches!(field, "ln1.g" | "ln1.b" | "ln2.g" | "ln2.b") {
                let l = self.layers.get_mut(idx.parse::<usize>().ok()?)?;
                return Some(match field {
                    "ln1.g" => &mut l.ln1_g,
                    "ln1.b" => &mut l.ln1_b,
                    "ln2.g" => &mut l.ln2_g,
                    _ => &mut l.ln2_b,
                });
            }
        }
        self.linear_mut(name)
    }

    /// Total parameters actually held.
    pub fn n_params(&self) -> usize {
        self.named_tensors().iter().map(|(_, t)| t.len()).sum()
    }

    /// Canonical names of every quantizable linear under this config —
    /// what [`LmSkeleton::linear_names`] (and therefore the quantized
    /// model's completeness check) enumerates without holding the fp32
    /// matrices.
    pub fn linear_names(config: &ModelConfig) -> Vec<String> {
        let mut v = Vec::new();
        for i in 0..config.n_layers {
            for field in ["attn.q", "attn.k", "attn.v", "attn.out", "mlp.up", "mlp.down"] {
                v.push(format!("lm.layer{i}.{field}"));
            }
        }
        if !config.tied_head {
            v.push("lm.head".into());
        }
        v
    }

    /// `(out, in)` dims `config` implies for a canonical linear name —
    /// what the quantized-checkpoint loader validates container payloads
    /// against. `None` for names outside the config's linear set.
    pub fn linear_dims(config: &ModelConfig, name: &str) -> Option<(usize, usize)> {
        if name == "lm.head" {
            return (!config.tied_head).then_some((config.vocab, config.d_model));
        }
        let rest = name.strip_prefix("lm.layer")?;
        let (idx, field) = rest.split_once('.')?;
        if idx.parse::<usize>().ok()? >= config.n_layers {
            return None;
        }
        match field {
            "attn.q" | "attn.k" | "attn.v" | "attn.out" => {
                Some((config.d_model, config.d_model))
            }
            "mlp.up" => Some((config.d_ff, config.d_model)),
            "mlp.down" => Some((config.d_model, config.d_ff)),
            _ => None,
        }
    }
}

/// One transformer block's non-linear parameters (LayerNorm affine pairs)
/// — the per-layer slice of the deployment skeleton.
#[derive(Clone, Debug)]
pub struct LayerNorms {
    pub ln1_g: Tensor,
    pub ln1_b: Tensor,
    pub ln2_g: Tensor,
    pub ln2_b: Tensor,
}

/// The deployment skeleton of an LM: everything a quantized forward needs
/// *except* the linears — embeddings, LayerNorms, and the config. Holding
/// a [`QuantizedLm`](super::QuantizedLm) keeps exactly `skeleton + packed
/// linears` resident; the fp32 linear matrices are released at
/// quantization time, which is where the paper's 60–75% peak-memory
/// reduction actually comes from. (A tied head needs no extra tensor —
/// the head matrix *is* `tok_emb`; an untied head lives in the quantized
/// linears as `lm.head`.)
#[derive(Clone, Debug)]
pub struct LmSkeleton {
    pub config: ModelConfig,
    /// `[vocab, d_model]`
    pub tok_emb: Tensor,
    /// `[seq_len, d_model]`
    pub pos_emb: Tensor,
    pub layers: Vec<LayerNorms>,
    pub lnf_g: Tensor,
    pub lnf_b: Tensor,
}

impl LmSkeleton {
    /// Extract the skeleton from full training weights (clones only the
    /// non-linear tensors; the fp32 linears are left behind with `w`).
    pub fn from_weights(w: &LmWeights) -> Self {
        LmSkeleton {
            config: w.config.clone(),
            tok_emb: w.tok_emb.clone(),
            pos_emb: w.pos_emb.clone(),
            layers: w
                .layers
                .iter()
                .map(|l| LayerNorms {
                    ln1_g: l.ln1_g.clone(),
                    ln1_b: l.ln1_b.clone(),
                    ln2_g: l.ln2_g.clone(),
                    ln2_b: l.ln2_b.clone(),
                })
                .collect(),
            lnf_g: w.lnf_g.clone(),
            lnf_b: w.lnf_b.clone(),
        }
    }

    /// All-zero skeleton of the right shapes (checkpoint-load scaffold).
    pub fn zeros(config: &ModelConfig) -> Self {
        let d = config.d_model;
        LmSkeleton {
            tok_emb: Tensor::zeros(&[config.vocab, d]),
            pos_emb: Tensor::zeros(&[config.seq_len, d]),
            layers: (0..config.n_layers)
                .map(|_| LayerNorms {
                    ln1_g: Tensor::zeros(&[d]),
                    ln1_b: Tensor::zeros(&[d]),
                    ln2_g: Tensor::zeros(&[d]),
                    ln2_b: Tensor::zeros(&[d]),
                })
                .collect(),
            lnf_g: Tensor::zeros(&[d]),
            lnf_b: Tensor::zeros(&[d]),
            config: config.clone(),
        }
    }

    /// Canonical names of the linears this skeleton's model must provide
    /// in quantized form.
    pub fn linear_names(&self) -> Vec<String> {
        LmWeights::linear_names(&self.config)
    }

    /// `(out, in)` dims the config implies for a canonical linear name
    /// (see [`LmWeights::linear_dims`]).
    pub fn linear_dims(&self, name: &str) -> Option<(usize, usize)> {
        LmWeights::linear_dims(&self.config, name)
    }

    /// Mutable counterpart of [`Self::named_tensors`], same names and
    /// order — what the quantized-checkpoint loader fills.
    pub fn named_tensors_mut(&mut self) -> Vec<(String, &mut Tensor)> {
        let mut v: Vec<(String, &mut Tensor)> = vec![
            ("tok_emb".to_string(), &mut self.tok_emb),
            ("pos_emb".to_string(), &mut self.pos_emb),
        ];
        for (i, l) in self.layers.iter_mut().enumerate() {
            v.push((format!("lm.layer{i}.ln1.g"), &mut l.ln1_g));
            v.push((format!("lm.layer{i}.ln1.b"), &mut l.ln1_b));
            v.push((format!("lm.layer{i}.ln2.g"), &mut l.ln2_g));
            v.push((format!("lm.layer{i}.ln2.b"), &mut l.ln2_b));
        }
        v.push(("lnf.g".to_string(), &mut self.lnf_g));
        v.push(("lnf.b".to_string(), &mut self.lnf_b));
        v
    }

    /// Every named tensor of the skeleton, using the same canonical names
    /// the full checkpoint uses (so quantized containers share the codec).
    pub fn named_tensors(&self) -> Vec<(String, &Tensor)> {
        let mut v = vec![
            ("tok_emb".to_string(), &self.tok_emb),
            ("pos_emb".to_string(), &self.pos_emb),
        ];
        for (i, l) in self.layers.iter().enumerate() {
            v.push((format!("lm.layer{i}.ln1.g"), &l.ln1_g));
            v.push((format!("lm.layer{i}.ln1.b"), &l.ln1_b));
            v.push((format!("lm.layer{i}.ln2.g"), &l.ln2_g));
            v.push((format!("lm.layer{i}.ln2.b"), &l.ln2_b));
        }
        v.push(("lnf.g".to_string(), &self.lnf_g));
        v.push(("lnf.b".to_string(), &self.lnf_b));
        v
    }

    /// Mutable named access covering every tensor in [`Self::named_tensors`].
    pub fn named_tensor_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        match name {
            "tok_emb" => return Some(&mut self.tok_emb),
            "pos_emb" => return Some(&mut self.pos_emb),
            "lnf.g" => return Some(&mut self.lnf_g),
            "lnf.b" => return Some(&mut self.lnf_b),
            _ => {}
        }
        let rest = name.strip_prefix("lm.layer")?;
        let (idx, field) = rest.split_once('.')?;
        let l = self.layers.get_mut(idx.parse::<usize>().ok()?)?;
        match field {
            "ln1.g" => Some(&mut l.ln1_g),
            "ln1.b" => Some(&mut l.ln1_b),
            "ln2.g" => Some(&mut l.ln2_g),
            "ln2.b" => Some(&mut l.ln2_b),
            _ => None,
        }
    }

    /// Resident bytes of the skeleton (the fp32 residue of a deployed
    /// model: embeddings + norms).
    pub fn nbytes(&self) -> usize {
        self.named_tensors().iter().map(|(_, t)| t.nbytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_matches_config_count() {
        let cfg = ModelConfig::test_tiny(64);
        let mut rng = Pcg64::seeded(7);
        let w = LmWeights::init(&cfg, &mut rng);
        assert_eq!(w.n_params(), cfg.n_params());
    }

    #[test]
    fn linears_enumerated_in_order() {
        let cfg = ModelConfig::test_tiny(64);
        let mut rng = Pcg64::seeded(8);
        let w = LmWeights::init(&cfg, &mut rng);
        let names: Vec<String> = w.linears().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names[0], "lm.layer0.attn.q");
        assert_eq!(names[5], "lm.layer0.mlp.down");
        assert_eq!(names.len(), 12); // tied head → no lm.head
    }

    #[test]
    fn untied_head_is_quantizable() {
        let mut cfg = ModelConfig::test_tiny(64);
        cfg.tied_head = false;
        let mut rng = Pcg64::seeded(9);
        let w = LmWeights::init(&cfg, &mut rng);
        assert!(w.linears().iter().any(|(n, _)| n == "lm.head"));
    }

    #[test]
    fn skeleton_is_exactly_the_nonlinear_residue() {
        // skeleton names = full named tensor set minus the linears, and
        // its byte count is the fp32 residue deploy_bytes() adds to the
        // packed linears.
        let mut cfg = ModelConfig::test_tiny(48);
        cfg.tied_head = false;
        let mut rng = Pcg64::seeded(11);
        let w = LmWeights::init(&cfg, &mut rng);
        let skel = LmSkeleton::from_weights(&w);
        let lin: std::collections::HashSet<String> =
            w.linears().into_iter().map(|(n, _)| n).collect();
        let full: Vec<String> = w
            .named_tensors()
            .iter()
            .filter(|(n, _)| !lin.contains(n))
            .map(|(n, _)| n.clone())
            .collect();
        let skel_names: Vec<String> =
            skel.named_tensors().iter().map(|(n, _)| n.clone()).collect();
        assert_eq!(full, skel_names);
        assert_eq!(
            skel.linear_names(),
            w.linears().into_iter().map(|(n, _)| n).collect::<Vec<_>>()
        );
        let residue: usize = w
            .named_tensors()
            .iter()
            .filter(|(n, _)| !lin.contains(n))
            .map(|(_, t)| t.nbytes())
            .sum();
        assert_eq!(skel.nbytes(), residue);
        // every skeleton tensor is reachable mutably by name
        let mut z = LmSkeleton::zeros(&cfg);
        for (n, t) in skel.named_tensors() {
            let dst = z.named_tensor_mut(&n).unwrap_or_else(|| panic!("{n}"));
            assert_eq!(dst.shape(), t.shape(), "{n}");
        }
    }

    #[test]
    fn named_access_roundtrip() {
        let cfg = ModelConfig::test_tiny(32);
        let mut rng = Pcg64::seeded(10);
        let mut w = LmWeights::init(&cfg, &mut rng);
        let names: Vec<String> = w.named_tensors().iter().map(|(n, _)| n.clone()).collect();
        for n in names {
            assert!(w.named_tensor_mut(&n).is_some(), "{n}");
        }
        // mutate through the accessor, observe through the enumerator
        w.linear_mut("lm.layer1.attn.k").unwrap().data_mut()[0] = 42.0;
        assert_eq!(w.linear("lm.layer1.attn.k").unwrap().data()[0], 42.0);
    }
}
