//! The subject models: a decoder-only transformer LM implemented from
//! scratch (forward, quantized forward, and — via [`crate::train`] —
//! manual-gradient training).
//!
//! The paper quantizes OPT/Qwen/LLaMA checkpoints; those cannot be
//! downloaded here, so we *train our own* small checkpoints on synthetic
//! corpora (rust/DESIGN.md §5 Substitution ledger). The four LM presets differ
//! in depth/width/ff-ratio/activation so the "diverse architectures" axis
//! of Table 1 is preserved.
//!
//! Forwards come in two output modes ([`RowSelect`]): `Full` returns
//! `[B·S, V]` logits (training/eval, bit-identical to the original
//! implementation), while `LastRow` returns only the `[B, V]` answer rows
//! and — on the quantized paths — streams attention key blocks with an
//! online softmax ([`ops::attention_fwd_chunked`], tolerance
//! [`ATTN_CHUNK_REL_TOL`]), so serving never materializes the full logits
//! or the `O(S²)` score matrix. See rust/DESIGN.md §Activation memory.
//!
//! Streaming generation decodes one token at a time against a paged KV
//! cache ([`decode`]): prefill seeds the cache pages, each step is `O(S)`
//! attention over the cached rows, and greedy tokens are bit-identical to
//! the recompute-from-scratch oracle. See rust/DESIGN.md §Streaming
//! decode.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

pub mod config;
pub mod decode;
pub mod forward;
pub mod io;
pub mod kernels;
pub mod ops;
pub mod quantized;
pub mod weights;

pub use config::{Activation, ModelConfig};
pub use decode::{greedy_argmax, KvPool, KvSeq, PAGE_SLOTS};
pub use forward::{lm_forward, lm_forward_rows, lm_loss, ActivationTap, FwdRecord, RowSelect};
pub use kernels::QmatmulKernel;
pub use ops::{ATTN_CHUNK, ATTN_CHUNK_REL_TOL};
pub use quantized::{QuantizedLm, RESIDENT_TAG, WIDE_GROUP_ROWS};
pub use weights::{LayerNorms, LmSkeleton, LmWeights, TapNames};
