//! Checkpoint format: a tiny self-describing binary container.
//!
//! Layout: magic `RPIQCKPT`, u32 version, u32 json-length, config JSON,
//! then for each tensor: u32 name-length, name, u32 ndim, dims (u64 each),
//! f32 LE payload. Everything little-endian. No external deps, stable
//! across runs, and diff-friendly enough via `rpiq inspect`.

use super::config::{Activation, ModelConfig};
use super::weights::LmWeights;
use crate::jsonx::Json;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"RPIQCKPT";
const VERSION: u32 = 1;

fn config_to_json(c: &ModelConfig) -> Json {
    Json::obj()
        .with("name", Json::Str(c.name.clone()))
        .with("vocab", Json::Num(c.vocab as f64))
        .with("d_model", Json::Num(c.d_model as f64))
        .with("n_layers", Json::Num(c.n_layers as f64))
        .with("n_heads", Json::Num(c.n_heads as f64))
        .with("d_ff", Json::Num(c.d_ff as f64))
        .with("seq_len", Json::Num(c.seq_len as f64))
        .with(
            "activation",
            Json::Str(match c.activation {
                Activation::Gelu => "gelu".into(),
                Activation::Relu => "relu".into(),
            }),
        )
        .with("tied_head", Json::Bool(c.tied_head))
}

fn config_from_json(j: &Json) -> Result<ModelConfig> {
    let get = |k: &str| -> Result<&Json> {
        j.get(k).with_context(|| format!("config missing '{k}'"))
    };
    Ok(ModelConfig {
        name: get("name")?.as_str().context("name")?.to_string(),
        vocab: get("vocab")?.as_usize().context("vocab")?,
        d_model: get("d_model")?.as_usize().context("d_model")?,
        n_layers: get("n_layers")?.as_usize().context("n_layers")?,
        n_heads: get("n_heads")?.as_usize().context("n_heads")?,
        d_ff: get("d_ff")?.as_usize().context("d_ff")?,
        seq_len: get("seq_len")?.as_usize().context("seq_len")?,
        activation: match get("activation")?.as_str() {
            Some("gelu") => Activation::Gelu,
            Some("relu") => Activation::Relu,
            other => bail!("unknown activation {other:?}"),
        },
        tied_head: get("tied_head")?.as_bool().context("tied_head")?,
    })
}

/// Generic container writer shared by LM and VLM checkpoints.
pub fn write_container(
    path: &Path,
    magic: &[u8; 8],
    config_json: &str,
    tensors: &[(String, &Tensor)],
) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(magic)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(config_json.len() as u32).to_le_bytes())?;
    f.write_all(config_json.as_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        for &v in t.data() {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Generic container reader: returns the config JSON and the raw tensors.
pub fn read_container(
    path: &Path,
    magic: &[u8; 8],
) -> Result<(Json, Vec<(String, Vec<usize>, Vec<f32>)>)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut got = [0u8; 8];
    f.read_exact(&mut got)?;
    if &got != magic {
        bail!("{} is not the expected rpiq container", path.display());
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let cfg_len = read_u32(&mut f)? as usize;
    let mut cfg_buf = vec![0u8; cfg_len];
    f.read_exact(&mut cfg_buf)?;
    let cfg = Json::parse(std::str::from_utf8(&cfg_buf)?)?;
    let n_tensors = read_u32(&mut f)? as usize;
    let mut tensors = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let name_len = read_u32(&mut f)? as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf)?;
        let ndim = read_u32(&mut f)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        for (i, chunk) in buf.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        tensors.push((name, shape, data));
    }
    Ok((cfg, tensors))
}

/// Save a checkpoint.
pub fn save_lm(w: &LmWeights, path: &Path) -> Result<()> {
    let cfg = config_to_json(&w.config).dump();
    let tensors: Vec<(String, &Tensor)> = w.named_tensors();
    write_container(path, MAGIC, &cfg, &tensors)
}

/// Load a checkpoint.
pub fn load_lm(path: &Path) -> Result<LmWeights> {
    let (cfg_json, tensors) = read_container(path, MAGIC)?;
    let cfg = config_from_json(&cfg_json)?;
    // Start from a zero-init model of the right shape, then fill by name.
    let mut rng = crate::rng::Pcg64::seeded(0);
    let mut w = LmWeights::init(&cfg, &mut rng);
    for (name, shape, data) in tensors {
        let dst = w
            .named_tensor_mut(&name)
            .with_context(|| format!("unknown tensor '{name}' in checkpoint"))?;
        if dst.shape() != shape.as_slice() {
            bail!("tensor '{name}' shape {shape:?} != expected {:?}", dst.shape());
        }
        dst.data_mut().copy_from_slice(&data);
    }
    Ok(w)
}

/// Expose the LM config JSON codec for the VLM container.
pub fn lm_config_to_json(c: &ModelConfig) -> Json {
    config_to_json(c)
}

/// Parse an LM config from JSON (VLM container).
pub fn lm_config_from_json(j: &Json) -> Result<ModelConfig> {
    config_from_json(j)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::test_tiny(40);
        let mut rng = Pcg64::seeded(401);
        let w = LmWeights::init(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("rpiq_io_test");
        let path = dir.join("tiny.ckpt");
        save_lm(&w, &path).unwrap();
        let w2 = load_lm(&path).unwrap();
        assert_eq!(w2.config, w.config);
        for ((n1, t1), (n2, t2)) in w.named_tensors().iter().zip(w2.named_tensors().iter()) {
            assert_eq!(n1, n2);
            assert_eq!(t1.data(), t2.data(), "{n1}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("rpiq_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load_lm(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
