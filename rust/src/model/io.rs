//! Checkpoint formats: tiny self-describing binary containers.
//!
//! **fp32 container** (magic `RPIQCKPT` / `RPIQVLM1`): magic, u32
//! version, u32 json-length, config JSON, then for each tensor: u32
//! name-length, name, u32 ndim, dims (u64 each), f32 LE payload.
//!
//! **typed container** (magic `RPIQQLM1` / `RPIQQVL1`): same frame, but
//! each entry carries a dtype byte (0 = f32, 1 = u8) before its dims —
//! the quantized checkpoint format, whose u8 entries hold nibble-packed
//! weight levels verbatim. `save_qlm`/`load_qlm` round-trip a
//! [`QuantizedLm`] bit-exactly (packed levels byte-for-byte, group params
//! and skeleton f32-bit-for-bit), so a served model cold-starts from
//! `.rpiq` without ever materializing an fp32 linear.
//!
//! Everything little-endian. No external deps, stable across runs, and
//! diff-friendly enough via `rpiq inspect`.

// Loader module: untrusted bytes in, clean `Err` out. The repo lint
// (`rpiq-lint`, rule `no-panic`) and these clippy denies enforce it.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used, clippy::panic))]
#![cfg_attr(not(test), deny(clippy::indexing_slicing))]

use super::config::{Activation, ModelConfig};
use super::quantized::QuantizedLm;
use super::weights::{LmSkeleton, LmWeights};
use crate::jsonx::Json;
use crate::quant::{QuantGrid, QuantizedLinear};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"RPIQCKPT";
/// Magic of the quantized-LM container.
pub const QLM_MAGIC: &[u8; 8] = b"RPIQQLM1";
const VERSION: u32 = 1;

fn config_to_json(c: &ModelConfig) -> Json {
    Json::obj()
        .with("name", Json::Str(c.name.clone()))
        .with("vocab", Json::Num(c.vocab as f64))
        .with("d_model", Json::Num(c.d_model as f64))
        .with("n_layers", Json::Num(c.n_layers as f64))
        .with("n_heads", Json::Num(c.n_heads as f64))
        .with("d_ff", Json::Num(c.d_ff as f64))
        .with("seq_len", Json::Num(c.seq_len as f64))
        .with(
            "activation",
            Json::Str(match c.activation {
                Activation::Gelu => "gelu".into(),
                Activation::Relu => "relu".into(),
            }),
        )
        .with("tied_head", Json::Bool(c.tied_head))
}

fn config_from_json(j: &Json) -> Result<ModelConfig> {
    let get = |k: &str| -> Result<&Json> {
        j.get(k).with_context(|| format!("config missing '{k}'"))
    };
    Ok(ModelConfig {
        name: get("name")?.as_str().context("name")?.to_string(),
        vocab: get("vocab")?.as_usize().context("vocab")?,
        d_model: get("d_model")?.as_usize().context("d_model")?,
        n_layers: get("n_layers")?.as_usize().context("n_layers")?,
        n_heads: get("n_heads")?.as_usize().context("n_heads")?,
        d_ff: get("d_ff")?.as_usize().context("d_ff")?,
        seq_len: get("seq_len")?.as_usize().context("seq_len")?,
        activation: match get("activation")?.as_str() {
            Some("gelu") => Activation::Gelu,
            Some("relu") => Activation::Relu,
            other => bail!("unknown activation {other:?}"),
        },
        tied_head: get("tied_head")?.as_bool().context("tied_head")?,
    })
}

/// Generic container writer shared by LM and VLM checkpoints.
pub fn write_container(
    path: &Path,
    magic: &[u8; 8],
    config_json: &str,
    tensors: &[(String, &Tensor)],
) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(magic)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(config_json.len() as u32).to_le_bytes())?;
    f.write_all(config_json.as_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        for &v in t.data() {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Generic container reader: returns the config JSON and the raw tensors.
pub fn read_container(
    path: &Path,
    magic: &[u8; 8],
) -> Result<(Json, Vec<(String, Vec<usize>, Vec<f32>)>)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut got = [0u8; 8];
    f.read_exact(&mut got)?;
    if &got != magic {
        bail!("{} is not the expected rpiq container", path.display());
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let cfg_len = read_u32(&mut f)? as usize;
    let mut cfg_buf = vec![0u8; cfg_len];
    f.read_exact(&mut cfg_buf)?;
    let cfg = Json::parse(std::str::from_utf8(&cfg_buf)?)?;
    let n_tensors = read_u32(&mut f)? as usize;
    let mut tensors = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let name_len = read_u32(&mut f)? as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf)?;
        let ndim = read_u32(&mut f)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        for (dst, chunk) in data.iter_mut().zip(buf.chunks_exact(4)) {
            *dst = f32_le4(chunk);
        }
        tensors.push((name, shape, data));
    }
    Ok((cfg, tensors))
}

/// Save a checkpoint.
pub fn save_lm(w: &LmWeights, path: &Path) -> Result<()> {
    let cfg = config_to_json(&w.config).dump();
    let tensors: Vec<(String, &Tensor)> = w.named_tensors();
    write_container(path, MAGIC, &cfg, &tensors)
}

/// Load a checkpoint.
pub fn load_lm(path: &Path) -> Result<LmWeights> {
    let (cfg_json, tensors) = read_container(path, MAGIC)?;
    let cfg = config_from_json(&cfg_json)?;
    // Start from a zero-init model of the right shape, then fill by name.
    let mut rng = crate::rng::Pcg64::seeded(0);
    let mut w = LmWeights::init(&cfg, &mut rng);
    for (name, shape, data) in tensors {
        let dst = w
            .named_tensor_mut(&name)
            .with_context(|| format!("unknown tensor '{name}' in checkpoint"))?;
        if dst.shape() != shape.as_slice() {
            bail!("tensor '{name}' shape {shape:?} != expected {:?}", dst.shape());
        }
        dst.data_mut().copy_from_slice(&data);
    }
    Ok(w)
}

/// Expose the LM config JSON codec for the VLM container.
pub fn lm_config_to_json(c: &ModelConfig) -> Json {
    config_to_json(c)
}

/// Parse an LM config from JSON (VLM container).
pub fn lm_config_from_json(j: &Json) -> Result<ModelConfig> {
    config_from_json(j)
}

// ---------------------------------------------------------------------
// Typed (dtype-tagged) container: the quantized checkpoint format.
// ---------------------------------------------------------------------

/// Element type of one typed-container entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    U8,
}

impl DType {
    fn tag(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::U8 => 1,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        match t {
            0 => Ok(DType::F32),
            1 => Ok(DType::U8),
            other => bail!("unknown dtype tag {other}"),
        }
    }

    fn elem_bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::U8 => 1,
        }
    }
}

/// One entry of a typed container as read back (payload as raw LE bytes).
pub struct TypedEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub bytes: Vec<u8>,
}

impl TypedEntry {
    fn into_f32(self) -> Result<Vec<f32>> {
        anyhow::ensure!(
            self.dtype == DType::F32 && self.bytes.len() % 4 == 0,
            "entry '{}' is not an f32 plane",
            self.name
        );
        Ok(self.bytes.chunks_exact(4).map(f32_le4).collect())
    }
}

/// Decode one little-endian f32 from a 4-byte chunk without a panicking
/// conversion (`chunks_exact(4)` guarantees the length; a short chunk
/// would zero-pad rather than panic).
fn f32_le4(chunk: &[u8]) -> f32 {
    let mut b = [0u8; 4];
    for (dst, src) in b.iter_mut().zip(chunk) {
        *dst = *src;
    }
    f32::from_le_bytes(b)
}

/// A borrowed payload for the write path — the writer streams straight
/// from the model's own buffers, so saving never copies the packed levels
/// or group params (no transient doubling of the resident bytes).
pub enum PayloadRef<'a> {
    F32(&'a [f32]),
    U8(&'a [u8]),
}

impl PayloadRef<'_> {
    fn dtype(&self) -> DType {
        match self {
            PayloadRef::F32(_) => DType::F32,
            PayloadRef::U8(_) => DType::U8,
        }
    }

    fn len(&self) -> usize {
        match self {
            PayloadRef::F32(d) => d.len(),
            PayloadRef::U8(d) => d.len(),
        }
    }
}

/// One entry of a typed container on the write path (payload borrowed).
pub struct EntryRef<'a> {
    pub name: String,
    pub shape: Vec<usize>,
    pub payload: PayloadRef<'a>,
}

/// Write a typed container (see module docs for the frame layout).
pub fn write_container_typed(
    path: &Path,
    magic: &[u8; 8],
    config_json: &str,
    entries: &[EntryRef],
) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(magic)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(config_json.len() as u32).to_le_bytes())?;
    f.write_all(config_json.as_bytes())?;
    f.write_all(&(entries.len() as u32).to_le_bytes())?;
    for e in entries {
        let n: usize = e.shape.iter().product();
        anyhow::ensure!(
            e.payload.len() == n,
            "entry '{}': {} payload elements for shape {:?} ({:?})",
            e.name,
            e.payload.len(),
            e.shape,
            e.payload.dtype()
        );
        f.write_all(&(e.name.len() as u32).to_le_bytes())?;
        f.write_all(e.name.as_bytes())?;
        f.write_all(&[e.payload.dtype().tag()])?;
        f.write_all(&(e.shape.len() as u32).to_le_bytes())?;
        for &d in &e.shape {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        match e.payload {
            PayloadRef::U8(bytes) => f.write_all(bytes)?,
            PayloadRef::F32(data) => {
                for &v in data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

/// Read a typed container: config JSON + raw entries. Declared sizes are
/// untrusted: every per-entry payload length is computed with checked
/// arithmetic and bounded by the file's actual length before any buffer
/// is allocated, so a corrupt header errors instead of aborting on a
/// huge allocation.
pub fn read_container_typed(path: &Path, magic: &[u8; 8]) -> Result<(Json, Vec<TypedEntry>)> {
    let file_len = std::fs::metadata(path)
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut got = [0u8; 8];
    f.read_exact(&mut got)?;
    if &got != magic {
        bail!("{} is not the expected rpiq quantized container", path.display());
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let cfg_len = read_u32(&mut f)? as usize;
    anyhow::ensure!(
        (cfg_len as u64) <= file_len,
        "config JSON length {cfg_len} exceeds file size"
    );
    let mut cfg_buf = vec![0u8; cfg_len];
    f.read_exact(&mut cfg_buf)?;
    let cfg = Json::parse(std::str::from_utf8(&cfg_buf)?)?;
    let n_entries = read_u32(&mut f)? as usize;
    // capacity grows as entries are actually read — n_entries is untrusted
    let mut entries = Vec::new();
    for _ in 0..n_entries {
        let name_len = read_u32(&mut f)? as usize;
        anyhow::ensure!(
            (name_len as u64) <= file_len,
            "entry name length {name_len} exceeds file size"
        );
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf)?;
        let mut tag = [0u8; 1];
        f.read_exact(&mut tag)?;
        let [tag_byte] = tag;
        let dtype = DType::from_tag(tag_byte).with_context(|| format!("entry '{name}'"))?;
        let ndim = read_u32(&mut f)? as usize;
        anyhow::ensure!((ndim as u64) <= file_len, "entry '{name}' declares {ndim} dims");
        let mut dims = Vec::with_capacity(ndim.min(8));
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            dims.push(u64::from_le_bytes(b));
        }
        let n = dims
            .iter()
            .try_fold(1u64, |a, &d| a.checked_mul(d))
            .with_context(|| format!("entry '{name}': shape {dims:?} overflows"))?;
        let payload_bytes = n
            .checked_mul(dtype.elem_bytes() as u64)
            .filter(|&b| b <= file_len)
            .with_context(|| {
                format!("entry '{name}' declares more payload than the {file_len}-byte file holds")
            })?;
        let shape: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let mut bytes = vec![0u8; payload_bytes as usize];
        f.read_exact(&mut bytes)
            .with_context(|| format!("truncated payload for entry '{name}'"))?;
        entries.push(TypedEntry { name, shape, dtype, bytes });
    }
    Ok((cfg, entries))
}

/// JSON descriptor of one quantized linear (grid + shape — everything
/// `from_packed` needs besides the payload planes). Shared with the VLM
/// container writer so both headers stay schema-identical for
/// [`qlinears_from_entries`].
pub(crate) fn qlinear_to_json(q: &QuantizedLinear) -> Json {
    Json::obj()
        .with("bits", Json::Num(q.grid.bits as f64))
        .with("group_size", Json::Num(q.grid.group_size as f64))
        .with("out", Json::Num(q.out_features as f64))
        .with("in", Json::Num(q.in_features as f64))
}

fn qlinear_meta_from_json(j: &Json) -> Result<(QuantGrid, usize, usize)> {
    let get = |k: &str| -> Result<usize> {
        j.get(k)
            .and_then(|x| x.as_usize())
            .with_context(|| format!("linear meta missing '{k}'"))
    };
    let grid = QuantGrid::new(get("bits")? as u32, get("group_size")?);
    Ok((grid, get("out")?, get("in")?))
}

/// The three payload entries of one quantized linear (borrowed — the
/// writer streams them, no copies).
fn push_qlinear_entries<'a>(name: &str, q: &'a QuantizedLinear, out: &mut Vec<EntryRef<'a>>) {
    out.push(EntryRef {
        name: format!("{name}.packed"),
        shape: vec![q.out_features, q.packed_cols()],
        payload: PayloadRef::U8(&q.packed),
    });
    let ng = q.n_groups();
    out.push(EntryRef {
        name: format!("{name}.scales"),
        shape: vec![q.out_features, ng],
        payload: PayloadRef::F32(&q.scales),
    });
    out.push(EntryRef {
        name: format!("{name}.zeros"),
        shape: vec![q.out_features, ng],
        payload: PayloadRef::F32(&q.zeros),
    });
}

/// Rebuild the quantized linears described by `linears_json` from an
/// entry map (shared by the LM and VLM loaders).
pub(crate) fn qlinears_from_entries(
    linears_json: &Json,
    entries: &mut HashMap<String, TypedEntry>,
) -> Result<HashMap<String, QuantizedLinear>> {
    let obj = linears_json
        .as_obj()
        .context("quantized container: 'linears' is not an object")?;
    let mut qlinears = HashMap::new();
    for (name, meta) in obj {
        let (grid, out_f, in_f) = qlinear_meta_from_json(meta)
            .with_context(|| format!("linear '{name}'"))?;
        let packed = entries
            .remove(&format!("{name}.packed"))
            .with_context(|| format!("missing packed levels for '{name}'"))?;
        anyhow::ensure!(
            packed.dtype == DType::U8,
            "'{name}.packed' must be a u8 plane"
        );
        let scales = entries
            .remove(&format!("{name}.scales"))
            .with_context(|| format!("missing scales for '{name}'"))?
            .into_f32()?;
        let zeros = entries
            .remove(&format!("{name}.zeros"))
            .with_context(|| format!("missing zeros for '{name}'"))?
            .into_f32()?;
        let q = QuantizedLinear::from_packed(packed.bytes, grid, out_f, in_f, scales, zeros)
            .with_context(|| format!("linear '{name}'"))?;
        qlinears.insert(name.clone(), q);
    }
    Ok(qlinears)
}

/// Fill a skeleton's named tensor from an f32 entry.
fn fill_skeleton_tensor(dst: &mut Tensor, name: &str, entry: TypedEntry) -> Result<()> {
    anyhow::ensure!(
        dst.shape() == entry.shape.as_slice(),
        "tensor '{name}' shape {:?} != expected {:?}",
        entry.shape,
        dst.shape()
    );
    let data = entry.into_f32()?;
    dst.data_mut().copy_from_slice(&data);
    Ok(())
}

/// The shared tail of the quantized-container loaders ([`load_qlm`] and
/// `vlm::io::load_qvlm`): fill every skeleton tensor from the leftover
/// entries, validate the linears against the config, and reject stray
/// entries — one body, so a validation fix cannot land in only one
/// container flavour.
pub(crate) fn fill_and_validate(
    mut by_name: HashMap<String, TypedEntry>,
    skeleton_tensors: Vec<(String, &mut Tensor)>,
    qlinears: &HashMap<String, QuantizedLinear>,
    linear_names: &[String],
    dims_of: impl Fn(&str) -> Option<(usize, usize)>,
) -> Result<()> {
    for (name, dst) in skeleton_tensors {
        let entry = by_name
            .remove(&name)
            .with_context(|| format!("missing skeleton tensor '{name}'"))?;
        fill_skeleton_tensor(dst, &name, entry)?;
    }
    check_linears_against_config(qlinears, linear_names, dims_of)?;
    if let Some(stray) = by_name.keys().next() {
        bail!("unexpected entry '{stray}' in quantized container");
    }
    Ok(())
}

/// Write one quantized-model container: `{kind, config, linears}` JSON
/// header + skeleton f32 entries + per-linear payload planes. The one
/// writer body behind [`save_qlm`] and `vlm::io::save_qvlm`, so the two
/// container flavours cannot drift.
pub(crate) fn write_qcontainer(
    path: &Path,
    magic: &[u8; 8],
    kind: &str,
    config_json: Json,
    skeleton_tensors: &[(String, &Tensor)],
    qlinears: &crate::quant::QLinearStore,
) -> Result<()> {
    // the store iterates in sorted name order, so the container layout is
    // deterministic without a re-sort here
    let mut linears_json = Json::obj();
    for (name, q) in qlinears.iter() {
        linears_json = linears_json.with(name, qlinear_to_json(q));
    }
    let header = Json::obj()
        .with("kind", Json::Str(kind.into()))
        .with("config", config_json)
        .with("linears", linears_json);
    let mut entries: Vec<EntryRef> = Vec::new();
    for (name, t) in skeleton_tensors {
        entries.push(EntryRef {
            name: name.clone(),
            shape: t.shape().to_vec(),
            payload: PayloadRef::F32(t.data()),
        });
    }
    for (name, q) in qlinears.iter() {
        push_qlinear_entries(name, q, &mut entries);
    }
    write_container_typed(path, magic, &header.dump(), &entries)
}

/// Read one quantized-model container back: the config JSON, the rebuilt
/// linears, and the remaining (skeleton) entries keyed by name. The one
/// reader body behind [`load_qlm`] and `vlm::io::load_qvlm`.
pub(crate) fn read_qcontainer(
    path: &Path,
    magic: &[u8; 8],
) -> Result<(Json, HashMap<String, QuantizedLinear>, HashMap<String, TypedEntry>)> {
    let (header, entries) = read_container_typed(path, magic)?;
    let cfg = header
        .get("config")
        .context("header missing 'config'")?
        .clone();
    let mut by_name: HashMap<String, TypedEntry> = HashMap::new();
    for e in entries {
        // last-wins collapsing would let a corrupt container shadow a
        // real payload silently — duplicates are an error
        anyhow::ensure!(
            !by_name.contains_key(&e.name),
            "duplicate entry '{}' in quantized container",
            e.name
        );
        by_name.insert(e.name.clone(), e);
    }
    let qlinears = qlinears_from_entries(
        header.get("linears").context("header missing 'linears'")?,
        &mut by_name,
    )?;
    Ok((cfg, qlinears, by_name))
}

/// Validate the rebuilt linears against what the config implies — every
/// declared linear must exist with exactly the dims `dims_of` derives
/// from the config, and the container must declare *nothing beyond* the
/// config's linear set (an undeclared extra like a bogus `lm.head` on a
/// tied-head model would silently reroute the forward path). A header
/// that is self-consistent but wrong for the model therefore errors at
/// load time instead of panicking — or silently misbehaving — at the
/// first forward.
pub(crate) fn check_linears_against_config(
    qlinears: &HashMap<String, QuantizedLinear>,
    linear_names: &[String],
    dims_of: impl Fn(&str) -> Option<(usize, usize)>,
) -> Result<()> {
    for name in linear_names {
        let q = qlinears
            .get(name)
            .with_context(|| format!("missing quantized layer '{name}'"))?;
        let (out_f, in_f) = dims_of(name)
            .with_context(|| format!("config derives no dims for linear '{name}'"))?;
        anyhow::ensure!(
            (q.out_features, q.in_features) == (out_f, in_f),
            "linear '{name}' is {}x{} in the container but the config implies {}x{}",
            q.out_features,
            q.in_features,
            out_f,
            in_f
        );
    }
    if qlinears.len() != linear_names.len() {
        let extra = qlinears
            .keys()
            .find(|k| !linear_names.contains(k))
            .map(String::as_str)
            .unwrap_or("?");
        bail!(
            "container declares {} linears but the config expects {} (e.g. extra '{extra}')",
            qlinears.len(),
            linear_names.len()
        );
    }
    Ok(())
}

/// Save a quantized LM as a `.rpiq` container: nibble-packed levels + group
/// params per linear, fp32 skeleton, config + per-linear grid metadata in
/// the JSON header.
pub fn save_qlm(qlm: &QuantizedLm, path: &Path) -> Result<()> {
    write_qcontainer(
        path,
        QLM_MAGIC,
        "qlm",
        config_to_json(&qlm.skeleton.config),
        &qlm.skeleton.named_tensors(),
        &qlm.qlinears,
    )
}

/// Load a quantized LM from a `.rpiq` container. No fp32 linear is ever
/// materialized; the loaded model's forward is bit-identical to the model
/// that was saved.
pub fn load_qlm(path: &Path) -> Result<QuantizedLm> {
    let (cfg_json, qlinears, by_name) = read_qcontainer(path, QLM_MAGIC)?;
    let cfg = config_from_json(&cfg_json)?;
    let mut skeleton = LmSkeleton::zeros(&cfg);
    fill_and_validate(
        by_name,
        skeleton.named_tensors_mut(),
        &qlinears,
        &LmWeights::linear_names(&cfg),
        |name| LmWeights::linear_dims(&cfg, name),
    )?;
    QuantizedLm::new(skeleton, qlinears)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::test_tiny(40);
        let mut rng = Pcg64::seeded(401);
        let w = LmWeights::init(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("rpiq_io_test");
        let path = dir.join("tiny.ckpt");
        save_lm(&w, &path).unwrap();
        let w2 = load_lm(&path).unwrap();
        assert_eq!(w2.config, w.config);
        for ((n1, t1), (n2, t2)) in w.named_tensors().iter().zip(w2.named_tensors().iter()) {
            assert_eq!(n1, n2);
            assert_eq!(t1.data(), t2.data(), "{n1}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("rpiq_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load_lm(&path).is_err());
        assert!(load_qlm(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn qlm_roundtrip_is_bit_identical() {
        // The quantized container's contract: packed levels byte-for-byte,
        // group params and skeleton f32 bit-for-bit, forward logits
        // bit-identical to the saved model's.
        let _kernel = crate::model::kernels::kernel_test_lock(); // fixed kernel across the compares
        let mut cfg = ModelConfig::test_tiny(40);
        cfg.tied_head = false; // exercise the quantized lm.head path
        let mut rng = Pcg64::seeded(402);
        let w = LmWeights::init(&cfg, &mut rng);
        let qlm = crate::model::QuantizedLm::quantize_rtn(
            w,
            crate::quant::QuantGrid::new(4, 8),
        )
        .unwrap();
        let dir = std::env::temp_dir().join("rpiq_qio_test");
        let path = dir.join("tiny.rpiq");
        save_qlm(&qlm, &path).unwrap();
        let loaded = load_qlm(&path).unwrap();
        assert_eq!(loaded.skeleton.config, qlm.skeleton.config);
        assert_eq!(loaded.qlinears.len(), qlm.qlinears.len());
        for (name, q) in qlm.qlinears.iter() {
            let l = loaded.qlinears.get(name).expect("layer present after roundtrip");
            assert_eq!(q.packed, l.packed, "{name} packed");
            assert_eq!(q.scales, l.scales, "{name} scales");
            assert_eq!(q.zeros, l.zeros, "{name} zeros");
            assert_eq!(q.grid, l.grid, "{name} grid");
        }
        assert_eq!(loaded.deploy_bytes(), qlm.deploy_bytes());
        let tokens: Vec<u32> = (0..16).map(|i| (i * 7) % 40).collect();
        let a = qlm.forward(&tokens, 2, 8).unwrap();
        let b = loaded.forward(&tokens, 2, 8).unwrap();
        assert_eq!(a.data(), b.data(), "loaded forward must be bit-identical");
        // an fp checkpoint must not load as a quantized one (and vice versa)
        assert!(load_lm(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn loader_rejects_dim_mismatched_linears() {
        // A container that is self-consistent but disagrees with the
        // config must error at load time, not panic at the first forward.
        let grid = crate::quant::QuantGrid::new(4, 8);
        let mut qlinears = HashMap::new();
        qlinears.insert(
            "lm.layer0.attn.q".to_string(),
            crate::quant::QuantizedLinear::empty(grid, 8, 8),
        );
        let names = vec!["lm.layer0.attn.q".to_string()];
        assert!(check_linears_against_config(&qlinears, &names, |_| Some((8, 8))).is_ok());
        let err = check_linears_against_config(&qlinears, &names, |_| Some((8, 16)))
            .unwrap_err();
        assert!(err.to_string().contains("implies"), "{err}");
        let missing = vec!["lm.layer1.attn.q".to_string()];
        let err = check_linears_against_config(&qlinears, &missing, |_| Some((8, 8)))
            .unwrap_err();
        assert!(err.to_string().contains("missing quantized layer"), "{err:#}");
    }

    #[test]
    fn qlm_truncated_payload_rejected() {
        let cfg = ModelConfig::test_tiny(24);
        let mut rng = Pcg64::seeded(403);
        let w = LmWeights::init(&cfg, &mut rng);
        let qlm = crate::model::QuantizedLm::quantize_rtn(
            w,
            crate::quant::QuantGrid::new(4, 8),
        )
        .unwrap();
        let dir = std::env::temp_dir().join("rpiq_qio_trunc");
        let path = dir.join("t.rpiq");
        save_qlm(&qlm, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
        let err = load_qlm(&path).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
