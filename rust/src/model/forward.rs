//! Full-precision LM forward pass.
//!
//! Two call styles:
//!
//! * [`lm_forward`] — inference + optional [`ActivationTap`] that captures
//!   the *input* activations of every linear layer. The quantization
//!   pipeline uses the tap to accumulate per-layer Hessians (`XᵀX`) and to
//!   retain the last batch for stage 2, exactly as the paper's calibration
//!   stage does with forward hooks.
//! * [`lm_forward_training`] — same math but returns the [`FwdRecord`] of
//!   every intermediate needed by the manual backward in `crate::train`.
//!
//! Both full-logits entries are [`RowSelect::Full`] specializations of
//! [`lm_forward_rows`]: serve lanes that only read answer rows pass
//! [`RowSelect::LastRow`] so the final layernorm and head matmul run over
//! one row per sequence and the `[B·S, V]` logits tensor is never
//! allocated.

use super::ops::*;
use super::weights::LmWeights;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// Captures the input activations of named linear layers during a forward
/// pass (the calibration hook).
///
/// A tap is single-forward state, not shared state: the parallel
/// calibration sweep creates one tap per window job on a pool worker, runs
/// the forward against it, and [`Self::take`]s the captured tensors into
/// that worker's private Hessian partials — taps never cross threads while
/// a forward is writing into them.
#[derive(Default)]
pub struct ActivationTap {
    /// layer name → captured `[N, in_features]` input.
    pub inputs: HashMap<String, Tensor>,
    /// If non-empty, only these layers are captured.
    pub filter: Option<Vec<String>>,
}

impl ActivationTap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn only(names: Vec<String>) -> Self {
        ActivationTap { inputs: HashMap::new(), filter: Some(names) }
    }

    /// Move a captured input out of the tap (calibration consumes each
    /// layer's activation exactly once).
    pub fn take(&mut self, name: &str) -> Option<Tensor> {
        self.inputs.remove(name)
    }

    /// Capture (if the filter allows) the input activation of a layer.
    /// Public because the VLM forward in `crate::vlm` reuses the tap.
    pub fn grab_pub(&mut self, name: &str, x: &Tensor) {
        self.grab(name, x)
    }

    fn grab(&mut self, name: &str, x: &Tensor) {
        let wanted = match &self.filter {
            Some(f) => f.iter().any(|n| n == name),
            None => true,
        };
        if wanted {
            self.inputs.insert(name.to_string(), x.clone());
        }
    }
}

/// Which logits rows a forward materializes — i.e. the row set of the
/// final layernorm + head matmul.
///
/// [`RowSelect::Full`] is the training/eval mode and is bit-identical to
/// the historical full-logits path. [`RowSelect::LastRow`] is the serve
/// mode for answer-row readers (sentiment classification, VQA answer
/// extraction): logits come back as `[B, V]` with row `b` bit-identical to
/// full-mode row `b·S + S−1`, because the head matmul computes output rows
/// independently in a fixed f32 order and layernorm is row-wise.
///
/// On the quantized serve paths, `LastRow` additionally selects the
/// chunked online-softmax attention
/// ([`super::ops::attention_fwd_chunked`], within
/// [`super::ops::ATTN_CHUNK_REL_TOL`] of the exact oracle), so both the
/// `O(S²)` score transients and the full logits disappear from serving.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RowSelect {
    /// Full logits `[B·S, V]` — training/eval; bit-identical to the
    /// pre-row-select path.
    #[default]
    Full,
    /// Only each sequence's final position: logits `[B, V]`.
    LastRow,
}

impl RowSelect {
    /// Number of logits rows this mode produces for a `[batch, seq]`
    /// forward.
    pub fn out_rows(self, batch: usize, seq: usize) -> usize {
        match self {
            RowSelect::Full => batch * seq,
            RowSelect::LastRow => batch,
        }
    }

    /// Gather the head-input rows this mode selects from `x: [B·S, d]`.
    /// Selection happens *before* the final layernorm (row-wise, so the
    /// two orders are bit-identical) to avoid normalizing rows nobody
    /// reads.
    pub fn select(self, x: Tensor, batch: usize, seq: usize) -> Tensor {
        match self {
            RowSelect::Full => x,
            RowSelect::LastRow => {
                let d = x.cols();
                let mut out = Tensor::zeros(&[batch, d]);
                for b in 0..batch {
                    out.row_mut(b).copy_from_slice(x.row(b * seq + seq - 1));
                }
                out
            }
        }
    }
}

/// Saved intermediates for one layer (training).
pub struct LayerRecord {
    pub x_in: Tensor,
    pub ln1_out: Tensor,
    pub ln1_mean: Vec<f32>,
    pub ln1_rstd: Vec<f32>,
    pub q: Tensor,
    pub k: Tensor,
    pub v: Tensor,
    pub probs: Vec<Tensor>,
    pub ctx: Tensor,
    pub x_mid: Tensor,
    pub ln2_out: Tensor,
    pub ln2_mean: Vec<f32>,
    pub ln2_rstd: Vec<f32>,
    pub up_pre: Tensor,
    pub up_act: Tensor,
}

/// Full forward record (training).
pub struct FwdRecord {
    pub batch: usize,
    pub seq: usize,
    pub emb: Tensor,
    pub layers: Vec<LayerRecord>,
    pub x_final: Tensor,
    pub lnf_out: Tensor,
    pub lnf_mean: Vec<f32>,
    pub lnf_rstd: Vec<f32>,
    pub logits: Tensor,
}

/// Embed tokens: `[B·S, d]` from ids `[B·S]` (row-major batch-major).
pub fn embed(w: &LmWeights, tokens: &[u32], batch: usize, seq: usize) -> Tensor {
    embed_rows(&w.tok_emb, &w.pos_emb, w.config.seq_len, tokens, batch, seq)
}

/// The embedding kernel on bare tensors — shared by the fp path
/// ([`embed`]) and the deployment skeleton's quantized forward, which
/// holds no [`LmWeights`].
pub fn embed_rows(
    tok_emb: &Tensor,
    pos_emb: &Tensor,
    seq_cap: usize,
    tokens: &[u32],
    batch: usize,
    seq: usize,
) -> Tensor {
    let d = tok_emb.cols();
    assert_eq!(tokens.len(), batch * seq);
    assert!(
        seq <= seq_cap,
        "sequence length {seq} exceeds model context {seq_cap}"
    );
    let mut x = Tensor::zeros(&[batch * seq, d]);
    for (i, &tok) in tokens.iter().enumerate() {
        let pos = i % seq;
        let te = tok_emb.row(tok as usize);
        let pe = pos_emb.row(pos);
        let row = x.row_mut(i);
        for j in 0..d {
            row[j] = te[j] + pe[j];
        }
    }
    x
}

/// Inference forward: tokens → logits `[B·S, vocab]`.
///
/// `tap` (optional) captures linear-layer inputs for calibration.
pub fn lm_forward(
    w: &LmWeights,
    tokens: &[u32],
    batch: usize,
    seq: usize,
    tap: Option<&mut ActivationTap>,
) -> Tensor {
    lm_forward_rows(w, tokens, batch, seq, tap, RowSelect::Full)
}

/// Inference forward with an explicit [`RowSelect`] mode: tokens → logits
/// `[rows.out_rows(B, S), vocab]`.
///
/// `RowSelect::Full` is exactly [`lm_forward`] (bit-identical);
/// `RowSelect::LastRow` runs the final layernorm and head matmul over one
/// row per sequence.
pub fn lm_forward_rows(
    w: &LmWeights,
    tokens: &[u32],
    batch: usize,
    seq: usize,
    mut tap: Option<&mut ActivationTap>,
    rows: RowSelect,
) -> Tensor {
    let cfg = &w.config;
    let names = w.tap_names();
    let mut x = embed(w, tokens, batch, seq);
    for (li, l) in w.layers.iter().enumerate() {
        let names = names.layer(li);
        let (ln1, _, _) = layernorm_fwd(&x, &l.ln1_g, &l.ln1_b);
        if let Some(t) = tap.as_deref_mut() {
            t.grab(&names.attn_q, &ln1);
            t.grab(&names.attn_k, &ln1);
            t.grab(&names.attn_v, &ln1);
        }
        let q = linear_fwd(&ln1, &l.wq);
        let k = linear_fwd(&ln1, &l.wk);
        let v = linear_fwd(&ln1, &l.wv);
        let (ctx, _) = attention_fwd(&q, &k, &v, batch, seq, cfg.n_heads);
        if let Some(t) = tap.as_deref_mut() {
            t.grab(&names.attn_out, &ctx);
        }
        let attn_out = linear_fwd(&ctx, &l.wo);
        x.add_assign(&attn_out);

        let (ln2, _, _) = layernorm_fwd(&x, &l.ln2_g, &l.ln2_b);
        if let Some(t) = tap.as_deref_mut() {
            t.grab(&names.mlp_up, &ln2);
        }
        let up = act_fwd(&linear_fwd(&ln2, &l.w_up), cfg.activation);
        if let Some(t) = tap.as_deref_mut() {
            t.grab(&names.mlp_down, &up);
        }
        let down = linear_fwd(&up, &l.w_down);
        x.add_assign(&down);
    }
    let x = rows.select(x, batch, seq);
    let (lnf, _, _) = layernorm_fwd(&x, &w.lnf_g, &w.lnf_b);
    if let Some(t) = tap.as_deref_mut() {
        if w.head.is_some() {
            t.grab("lm.head", &lnf);
        }
    }
    linear_fwd(&lnf, w.head_matrix())
}

/// Training forward: returns logits and all intermediates.
pub fn lm_forward_training(w: &LmWeights, tokens: &[u32], batch: usize, seq: usize) -> FwdRecord {
    let emb = embed(w, tokens, batch, seq);
    lm_body_forward_training(w, emb, batch, seq)
}

/// Training forward over pre-assembled input embeddings — the entry the
/// VLM trainer uses (its sequence is `[image tokens ; text]`, so token
/// embedding happens upstream).
pub fn lm_body_forward_training(
    w: &LmWeights,
    emb: Tensor,
    batch: usize,
    seq: usize,
) -> FwdRecord {
    let cfg = &w.config;
    let mut x = emb.clone();
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for l in &w.layers {
        let x_in = x.clone();
        let (ln1_out, ln1_mean, ln1_rstd) = layernorm_fwd(&x, &l.ln1_g, &l.ln1_b);
        let q = linear_fwd(&ln1_out, &l.wq);
        let k = linear_fwd(&ln1_out, &l.wk);
        let v = linear_fwd(&ln1_out, &l.wv);
        let (ctx, probs) = attention_fwd(&q, &k, &v, batch, seq, cfg.n_heads);
        let attn_out = linear_fwd(&ctx, &l.wo);
        x.add_assign(&attn_out);
        let x_mid = x.clone();
        let (ln2_out, ln2_mean, ln2_rstd) = layernorm_fwd(&x, &l.ln2_g, &l.ln2_b);
        let up_pre = linear_fwd(&ln2_out, &l.w_up);
        let up_act = act_fwd(&up_pre, cfg.activation);
        let down = linear_fwd(&up_act, &l.w_down);
        x.add_assign(&down);
        layers.push(LayerRecord {
            x_in,
            ln1_out,
            ln1_mean,
            ln1_rstd,
            q,
            k,
            v,
            probs,
            ctx,
            x_mid,
            ln2_out,
            ln2_mean,
            ln2_rstd,
            up_pre,
            up_act,
        });
    }
    let x_final = x.clone();
    let (lnf_out, lnf_mean, lnf_rstd) = layernorm_fwd(&x, &w.lnf_g, &w.lnf_b);
    let logits = linear_fwd(&lnf_out, w.head_matrix());
    FwdRecord { batch, seq, emb, layers, x_final, lnf_out, lnf_mean, lnf_rstd, logits }
}

/// Mean next-token NLL of a token batch (labels are `tokens` shifted by
/// one inside each sequence; the last position of each sequence is
/// ignored). This is the training objective and the PPL building block.
pub fn lm_loss(logits: &Tensor, tokens: &[u32], batch: usize, seq: usize) -> (f64, Tensor) {
    let targets = shift_targets(tokens, batch, seq);
    cross_entropy(logits, &targets, -100)
}

/// Next-token targets with `-100` at sequence ends.
pub fn shift_targets(tokens: &[u32], batch: usize, seq: usize) -> Vec<i64> {
    let mut targets = vec![-100i64; batch * seq];
    for b in 0..batch {
        for s in 0..seq - 1 {
            targets[b * seq + s] = tokens[b * seq + s + 1] as i64;
        }
    }
    targets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::rng::Pcg64;

    fn tiny() -> (LmWeights, Vec<u32>, usize, usize) {
        let cfg = ModelConfig::test_tiny(32);
        let mut rng = Pcg64::seeded(201);
        let w = LmWeights::init(&cfg, &mut rng);
        let (batch, seq) = (2usize, 8usize);
        let tokens: Vec<u32> = (0..batch * seq).map(|_| rng.next_below(32) as u32).collect();
        (w, tokens, batch, seq)
    }

    #[test]
    fn forward_shapes() {
        let (w, tokens, b, s) = tiny();
        let logits = lm_forward(&w, &tokens, b, s, None);
        assert_eq!(logits.shape(), &[b * s, 32]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn training_forward_matches_inference_forward() {
        let (w, tokens, b, s) = tiny();
        let l1 = lm_forward(&w, &tokens, b, s, None);
        let rec = lm_forward_training(&w, &tokens, b, s);
        assert!(l1.max_abs_diff(&rec.logits) < 1e-5);
    }

    #[test]
    fn last_row_logits_bit_identical_to_full_last_rows() {
        let (w, tokens, b, s) = tiny();
        let full = lm_forward(&w, &tokens, b, s, None);
        let last = lm_forward_rows(&w, &tokens, b, s, None, RowSelect::LastRow);
        assert_eq!(last.shape(), &[b, 32]);
        for bi in 0..b {
            assert_eq!(last.row(bi), full.row(bi * s + s - 1), "seq {bi}");
        }
    }

    #[test]
    fn row_select_out_rows() {
        assert_eq!(RowSelect::Full.out_rows(3, 7), 21);
        assert_eq!(RowSelect::LastRow.out_rows(3, 7), 3);
    }

    #[test]
    fn tap_captures_expected_layers() {
        let (w, tokens, b, s) = tiny();
        let mut tap = ActivationTap::new();
        let _ = lm_forward(&w, &tokens, b, s, Some(&mut tap));
        let names: Vec<&String> = tap.inputs.keys().collect();
        assert_eq!(names.len(), 12); // 2 layers × 6 linears, tied head
        assert!(tap.inputs.contains_key("lm.layer0.attn.q"));
        assert!(tap.inputs.contains_key("lm.layer1.mlp.down"));
        // captured shapes: [B·S, in_features]
        assert_eq!(tap.inputs["lm.layer0.attn.q"].shape(), &[b * s, 16]);
        assert_eq!(tap.inputs["lm.layer1.mlp.down"].shape(), &[b * s, 32]);
    }

    #[test]
    fn tap_filter_restricts() {
        let (w, tokens, b, s) = tiny();
        let mut tap = ActivationTap::only(vec!["lm.layer0.mlp.up".into()]);
        let _ = lm_forward(&w, &tokens, b, s, Some(&mut tap));
        assert_eq!(tap.inputs.len(), 1);
    }

    #[test]
    fn causal_prefix_invariance() {
        // Logits at position p depend only on tokens ≤ p.
        let (w, mut tokens, b, s) = tiny();
        let l1 = lm_forward(&w, &tokens, b, s, None);
        tokens[s - 1] = (tokens[s - 1] + 1) % 32; // change last token of seq 0
        let l2 = lm_forward(&w, &tokens, b, s, None);
        for p in 0..s - 1 {
            assert_eq!(l1.row(p), l2.row(p), "pos {p}");
        }
        assert_ne!(l1.row(s - 1), l2.row(s - 1));
    }

    #[test]
    fn loss_reasonable_at_init() {
        let (w, tokens, b, s) = tiny();
        let logits = lm_forward(&w, &tokens, b, s, None);
        let (loss, _) = lm_loss(&logits, &tokens, b, s);
        // near-uniform at init: loss ≈ ln(32)
        assert!((loss - (32f64).ln()).abs() < 0.5, "loss={loss}");
    }

    #[test]
    fn shift_targets_ignores_seq_ends() {
        let t = shift_targets(&[1, 2, 3, 4, 5, 6], 2, 3);
        assert_eq!(t, vec![2, 3, -100, 5, 6, -100]);
    }
}
