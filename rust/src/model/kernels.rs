//! The packed dequant-matmul inner kernels behind [`QuantizedLm::qmatmul`]
//! (and therefore every LM/VLM quantized forward and both serve lanes).
//!
//! Two kernels share one contract — compute activation rows
//! `[i0, i0 + ychunk.len()/out_f)` of `y = x · deq(W)ᵀ` into a
//! **zero-initialized** `ychunk` — and differ in schedule:
//!
//! * [`qmatmul_rows_scalar`] — the bit-identity reference and default:
//!   dequantize one weight row at a time into a thread-local scratch row,
//!   contract it against every activation row with [`crate::tensor::dot`].
//!   Per output element this runs the exact `(q − zero)·scale` + 8-way
//!   `dot` float sequence the repo has always run, so outputs are
//!   bit-identical to every previous release (the unpacked oracle in the
//!   `quantized` tests pins this).
//! * [`qmatmul_rows_tiled`] — the cache-blocked, register-tiled fast
//!   path: K-blocked ([`KC`]) loop over [`NR`]-lane K-major weight
//!   panels (packed by [`QuantizedLinear::deq_span_strided`], two 4-bit
//!   levels per packed byte read), contracted against [`MR`]-row
//!   activation tiles with an `MR×NR` register-resident accumulator and
//!   explicit `mul_add` (FMA). See `rust/DESIGN.md` §Packed microkernels
//!   for the tile-shape rationale and measured numbers.
//!
//! Numerics contract: the tiled path accumulates each output element in
//! one strict k-ascending chain per K-block (lanes vectorize over the
//! `NR` *output* columns, never over k), so its results are
//! **bit-deterministic** — independent of thread count, shard layout,
//! and `MR`/`NR` edge tiles — but NOT bit-identical to the scalar
//! kernel, whose `dot` keeps 8 interleaved partial sums, nor across
//! machines with and without hardware FMA codegen for the same binary.
//! The divergence is ordinary f32 reassociation/fusion, bounded by
//! [`TILED_REL_TOL`] (asserted by the property tests here).
//!
//! Selection: [`set_kernel`] override (tests/benches) → `RPIQ_KERNEL`
//! env (`scalar`/`tiled`) → the `tiled-kernel` cargo feature → scalar.

use crate::quant::QuantizedLinear;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Activation rows per register tile (accumulator height). 6×16 fills
/// the 16 AVX2 ymm registers exactly (12 accumulators + 2 panel lanes +
/// broadcast + spare) — the classic BLIS/GotoBLAS sgemm shape — and
/// keeps 6 independent FMA chains per lane pair in flight, enough to
/// cover FMA latency on both 256- and 512-bit units.
pub const MR: usize = 6;

/// Output columns per register tile (accumulator width): one 512-bit or
/// two 256-bit vectors of f32, and the stride of the K-major weight
/// panel ([`QuantizedLinear::deq_span_strided`] lanes).
pub const NR: usize = 16;

/// K-block depth: one `KC × NR` dequantized panel is 16 KiB — half a
/// 32 KiB L1d — leaving room for the `MR` activation row slices walking
/// beside it. Each panel is dequantized once and contracted against
/// every activation row of the shard, so the unpack cost stays the same
/// `1/rows` fraction the scalar kernel pays.
pub const KC: usize = 256;

/// Floor of activation rows per shard for [`crate::tensor::par_rows`]:
/// every shard re-dequantizes the whole weight matrix (`O(out·in)`
/// setup for either kernel), so thinner shards would spend a large
/// fraction of their time on conversion. Centralized here so the model
/// and the benches agree on the sharding geometry.
pub const MIN_ROWS_PER_SHARD: usize = 8;

/// Relative tolerance of the tiled kernel against the scalar reference:
/// `max|tiled − scalar| ≤ TILED_REL_TOL · max(1, max|scalar|)`. The
/// observed divergence (f32 reassociation + FMA fusion over the K
/// reduction) sits orders of magnitude below this at the repo's shapes;
/// the bound is asserted by the kernel property tests and documented in
/// rust/DESIGN.md §Packed microkernels.
pub const TILED_REL_TOL: f32 = 1e-4;

/// Which inner kernel [`crate::model::QuantizedLm::qmatmul`] dispatches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QmatmulKernel {
    /// Row-at-a-time reference kernel (bit-identical to all prior
    /// releases; the default).
    Scalar,
    /// Cache-blocked register-tiled kernel (fast path, [`TILED_REL_TOL`]
    /// numerics contract).
    Tiled,
}

impl QmatmulKernel {
    /// Stable label for traces, benches, and JSON artifacts.
    pub fn label(self) -> &'static str {
        match self {
            QmatmulKernel::Scalar => "scalar",
            QmatmulKernel::Tiled => "tiled",
        }
    }
}

/// Process-wide kernel override: 0 = none, 1 = scalar, 2 = tiled.
/// Mirrors `exec::set_threads` — benches and tests move it under
/// [`kernel_test_lock`]; production code never writes it.
static KERNEL_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Override the kernel selection (`None` restores env/feature default).
pub fn set_kernel(k: Option<QmatmulKernel>) {
    let v = match k {
        None => 0,
        Some(QmatmulKernel::Scalar) => 1,
        Some(QmatmulKernel::Tiled) => 2,
    };
    KERNEL_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Test support: serializes tests that move the process-global kernel
/// override (mirrors `exec::thread_target_test_lock`; take that lock
/// first when a test moves both). Panic-poisoning is ignored.
#[doc(hidden)]
pub fn kernel_test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The kernel the next [`crate::model::QuantizedLm::qmatmul`] call will
/// run: override → `RPIQ_KERNEL` env → feature default.
pub fn active_kernel() -> QmatmulKernel {
    match KERNEL_OVERRIDE.load(Ordering::Relaxed) {
        1 => QmatmulKernel::Scalar,
        2 => QmatmulKernel::Tiled,
        _ => {
            static ENV_DEFAULT: OnceLock<QmatmulKernel> = OnceLock::new();
            *ENV_DEFAULT.get_or_init(env_default)
        }
    }
}

/// Compile-time default: scalar unless the `tiled-kernel` feature flips
/// the deployment default to the fast path.
const fn feature_default() -> QmatmulKernel {
    if cfg!(feature = "tiled-kernel") {
        QmatmulKernel::Tiled
    } else {
        QmatmulKernel::Scalar
    }
}

fn env_default() -> QmatmulKernel {
    match std::env::var("RPIQ_KERNEL") {
        Ok(v) if v.eq_ignore_ascii_case("scalar") => QmatmulKernel::Scalar,
        Ok(v) if v.eq_ignore_ascii_case("tiled") => QmatmulKernel::Tiled,
        Ok(v) => {
            crate::trace::log(&format!(
                "RPIQ_KERNEL={v:?} not recognized (expected \"scalar\" or \"tiled\"); \
                 using the {} default",
                feature_default().label()
            ));
            feature_default()
        }
        Err(_) => feature_default(),
    }
}

thread_local! {
    /// Per-thread kernel scratch (the scalar kernel's dequantized weight
    /// row / the tiled kernel's weight panel). Replaces the per-shard
    /// `vec![0.0; in_f]` the old kernel allocated on every dispatch —
    /// the buffer is grown once per thread and reused across every
    /// qmatmul the pool worker ever runs. Kernels are leaf compute (they
    /// never re-enter the pool), so the borrow can never nest.
    static SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

fn with_scratch<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

/// Dispatch one shard to the selected kernel (the body `par_rows` runs).
#[inline]
pub(crate) fn run_rows(
    kernel: QmatmulKernel,
    xd: &[f32],
    q: &QuantizedLinear,
    ychunk: &mut [f32],
    i0: usize,
) {
    match kernel {
        QmatmulKernel::Scalar => qmatmul_rows_scalar(xd, q, ychunk, i0),
        QmatmulKernel::Tiled => qmatmul_rows_tiled(xd, q, ychunk, i0),
    }
}

/// The scalar reference kernel: unpack + dequantize weight row `o` once
/// into thread-local scratch, then contract it against every activation
/// row of the shard. Structurally the Pallas kernel's schedule with a
/// `(1 × K)` weight tile; bit-identical to the pre-tiling releases (the
/// scratch row replaces a per-shard allocation, not any float op).
pub(crate) fn qmatmul_rows_scalar(xd: &[f32], q: &QuantizedLinear, ychunk: &mut [f32], i0: usize) {
    let in_f = q.in_features;
    let out_f = q.out_features;
    let rows = ychunk.len() / out_f;
    with_scratch(in_f, |wbuf| {
        for o in 0..out_f {
            // unpack + dequantize row o once: w_c = (q_c − z_g)·s_g
            q.deq_row_into(o, wbuf);
            // contract against every activation row of this shard
            for r in 0..rows {
                let i = i0 + r;
                let xrow = &xd[i * in_f..(i + 1) * in_f];
                ychunk[r * out_f + o] = crate::tensor::dot(xrow, wbuf);
            }
        }
    });
}

/// The cache-blocked register-tiled kernel.
///
/// Loop structure (GEBP): for each K-block of depth ≤ [`KC`] → for each
/// [`NR`]-column output panel, dequantize the `kc × NR` K-major weight
/// panel *once* into thread-local scratch (nibble pairs unpacked a byte
/// at a time by [`QuantizedLinear::deq_span_strided`]) → sweep all
/// activation rows of the shard in [`MR`]-row tiles through
/// [`micro`], accumulating into `ychunk` (`+=`, hence the zero-init
/// contract shared with the scalar kernel, whose first write is `=`).
///
/// Each output element's value is one k-ascending `mul_add` chain per
/// K-block, summed block-by-block into `y` — independent of the shard
/// layout, thread count, and edge-tile geometry, so the tiled path is
/// bit-deterministic for a fixed [`KC`].
pub(crate) fn qmatmul_rows_tiled(xd: &[f32], q: &QuantizedLinear, ychunk: &mut [f32], i0: usize) {
    let in_f = q.in_features;
    let out_f = q.out_features;
    let rows = ychunk.len() / out_f;
    with_scratch(KC * NR, |wtile| {
        let mut k0 = 0;
        while k0 < in_f {
            let kc = KC.min(in_f - k0);
            let mut o0 = 0;
            while o0 < out_f {
                let nr = NR.min(out_f - o0);
                if nr < NR {
                    // partial edge panel: zero the padded lanes so the
                    // microkernel can run full-width regardless
                    wtile[..kc * NR].fill(0.0);
                }
                for j in 0..nr {
                    q.deq_span_strided(o0 + j, k0, k0 + kc, NR, &mut wtile[j..]);
                }
                let mut r0 = 0;
                while r0 < rows {
                    let mr = MR.min(rows - r0);
                    // const-generic dispatch so every tile height gets a
                    // fully-unrolled accumulator array
                    match mr {
                        6 => micro::<6>(xd, in_f, i0 + r0, k0, kc, wtile, ychunk, out_f, r0, o0, nr),
                        5 => micro::<5>(xd, in_f, i0 + r0, k0, kc, wtile, ychunk, out_f, r0, o0, nr),
                        4 => micro::<4>(xd, in_f, i0 + r0, k0, kc, wtile, ychunk, out_f, r0, o0, nr),
                        3 => micro::<3>(xd, in_f, i0 + r0, k0, kc, wtile, ychunk, out_f, r0, o0, nr),
                        2 => micro::<2>(xd, in_f, i0 + r0, k0, kc, wtile, ychunk, out_f, r0, o0, nr),
                        _ => micro::<1>(xd, in_f, i0 + r0, k0, kc, wtile, ychunk, out_f, r0, o0, nr),
                    }
                    r0 += mr;
                }
                o0 += nr;
            }
            k0 += kc;
        }
    });
}

/// One `M × NR` register tile over one K-block: `acc[i][j] +=
/// x[row0+i][k] · wtile[k][j]` for `k ∈ [k0, k0+kc)`, then `y += acc`
/// for the `nr` real lanes. `chunks_exact(NR)` pins the panel walk to
/// exactly `kc` steps (bounds checks vanish); the j-loop over a fixed
/// `NR` array is the vectorized axis, so the per-element k chain stays
/// strictly ordered while still filling the FMA pipes.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro<const M: usize>(
    xd: &[f32],
    in_f: usize,
    x_row0: usize,
    k0: usize,
    kc: usize,
    wtile: &[f32],
    ychunk: &mut [f32],
    out_f: usize,
    y_row0: usize,
    o0: usize,
    nr: usize,
) {
    let mut acc = [[0.0f32; NR]; M];
    let mut xs: [&[f32]; M] = [&[][..]; M];
    for (i, slot) in xs.iter_mut().enumerate() {
        let base = (x_row0 + i) * in_f + k0;
        *slot = &xd[base..base + kc];
    }
    for (k, w) in wtile[..kc * NR].chunks_exact(NR).enumerate() {
        for i in 0..M {
            let xv = xs[i][k];
            let a = &mut acc[i];
            for j in 0..NR {
                a[j] = xv.mul_add(w[j], a[j]);
            }
        }
    }
    for (i, a) in acc.iter().enumerate() {
        let base = (y_row0 + i) * out_f + o0;
        for (y, v) in ychunk[base..base + nr].iter_mut().zip(a.iter()) {
            *y += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::QuantizedLm;
    use crate::proptest::{prop_assert, Runner};
    use crate::quant::QuantGrid;
    use crate::rng::Pcg64;
    use crate::tensor::Tensor;

    fn tol_ok(tiled: &[f32], scalar: &[f32]) -> (bool, f32, f32) {
        let scale = scalar.iter().fold(1.0f32, |m, v| m.max(v.abs()));
        let diff = tiled
            .iter()
            .zip(scalar)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        (diff <= TILED_REL_TOL * scale, diff, scale)
    }

    #[test]
    fn tiled_matches_scalar_within_tolerance_property() {
        // The tiled-path numerics contract over the full edge-case grid:
        // odd in/out features (nibble tails + partial NR panels), rows
        // not a multiple of MR (partial edge tiles), group boundaries
        // straddling panel boundaries, 3/4/8-bit grids, and in_features
        // beyond one K-block (KC straddling).
        Runner::new("kernels_tiled_vs_scalar", 48).run(|g| {
            let bits = [3u32, 4, 8][g.usize_in(0..3)];
            let rows = g.usize_in(1..2 * MR + 2);
            let out_f = g.usize_in(1..2 * NR + 3);
            let in_f = if g.bool() {
                g.usize_in(1..64) // small: head/tail nibble paths
            } else {
                g.usize_in(KC - 8..KC + 40) // straddles the K-block edge
            };
            let gs = g.usize_in(1..in_f.max(2));
            let w = Tensor::from_vec(&[out_f, in_f], g.matrix(out_f, in_f, 0.5));
            let q = crate::quant::QuantizedLinear::quantize_rtn(&w, QuantGrid::new(bits, gs));
            let x = Tensor::from_vec(&[rows, in_f], g.matrix(rows, in_f, 1.0));
            let mut scalar = vec![0.0f32; rows * out_f];
            qmatmul_rows_scalar(x.data(), &q, &mut scalar, 0);
            let mut tiled = vec![0.0f32; rows * out_f];
            qmatmul_rows_tiled(x.data(), &q, &mut tiled, 0);
            let (ok, diff, scale) = tol_ok(&tiled, &scalar);
            prop_assert(
                ok,
                &format!(
                    "tiled within {TILED_REL_TOL} of scalar \
                     (diff={diff:e}, scale={scale:e}, bits={bits}, \
                     {rows}x{in_f}x{out_f}, gs={gs})"
                ),
            )
        });
    }

    #[test]
    fn tiled_equals_reference_blockwise_fma_reduction() {
        // Pin the tiled path's exact numerics (not just a tolerance): one
        // strict k-ascending mul_add chain per KC block per element,
        // block sums added in ascending block order.
        let mut rng = Pcg64::seeded(317);
        let (rows, in_f, out_f) = (5, KC + 37, 2 * NR + 5);
        let w = Tensor::randn(&[out_f, in_f], 0.5, &mut rng);
        let q = crate::quant::QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 32));
        let x = Tensor::randn(&[rows, in_f], 1.0, &mut rng);
        let mut tiled = vec![0.0f32; rows * out_f];
        qmatmul_rows_tiled(x.data(), &q, &mut tiled, 0);
        let deq = q.dequantize();
        for r in 0..rows {
            for o in 0..out_f {
                let mut y = 0.0f32;
                let mut k0 = 0;
                while k0 < in_f {
                    let kc = KC.min(in_f - k0);
                    let mut acc = 0.0f32;
                    for k in k0..k0 + kc {
                        acc = x.at(r, k).mul_add(deq.at(o, k), acc);
                    }
                    y += acc;
                    k0 += kc;
                }
                assert_eq!(tiled[r * out_f + o].to_bits(), y.to_bits(), "({r},{o})");
            }
        }
    }

    #[test]
    fn tiled_qmatmul_bit_deterministic_across_thread_counts() {
        // The CI determinism matrix (RPIQ_THREADS=1/2/8) runs this with
        // the tiled path enabled: shard layout and thread count must not
        // change a single bit (each element is one fixed reduction chain
        // regardless of which shard/tile computes it).
        let _threads = crate::exec::thread_target_test_lock();
        let _kernel = kernel_test_lock();
        let before = crate::exec::num_threads();
        let mut rng = Pcg64::seeded(313);
        // 33 rows shard unevenly; dims exercise partial MR/NR edge tiles
        let w = Tensor::randn(&[3 * NR + 7, 96], 0.5, &mut rng);
        let q = crate::quant::QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 16));
        let x = Tensor::randn(&[33, 96], 1.0, &mut rng);
        set_kernel(Some(QmatmulKernel::Tiled));
        let mut reference = vec![0.0f32; 33 * (3 * NR + 7)];
        qmatmul_rows_tiled(x.data(), &q, &mut reference, 0);
        for threads in [1, 2, 4, 8] {
            crate::exec::set_threads(threads);
            let y = QuantizedLm::qmatmul(&x, &q).expect("shapes agree");
            assert_eq!(y.data(), reference.as_slice(), "threads={threads}");
        }
        set_kernel(None);
        crate::exec::set_threads(before);
    }

    #[test]
    fn kernel_override_wins_over_default() {
        let _kernel = kernel_test_lock();
        set_kernel(Some(QmatmulKernel::Tiled));
        assert_eq!(active_kernel(), QmatmulKernel::Tiled);
        set_kernel(Some(QmatmulKernel::Scalar));
        assert_eq!(active_kernel(), QmatmulKernel::Scalar);
        set_kernel(None);
        // default is whatever env/feature give — just must not be stuck
        let d = active_kernel();
        assert!(matches!(d, QmatmulKernel::Scalar | QmatmulKernel::Tiled));
    }

    #[test]
    fn scalar_scratch_reuse_is_bit_identical_to_fresh_buffers() {
        // The thread-local scratch must be fully overwritten per weight
        // row: run a wide matmul then a narrow one on the same thread and
        // check the narrow result against a fresh computation.
        let mut rng = Pcg64::seeded(331);
        let w_wide = Tensor::randn(&[8, 200], 0.5, &mut rng);
        let q_wide = crate::quant::QuantizedLinear::quantize_rtn(&w_wide, QuantGrid::new(4, 16));
        let x_wide = Tensor::randn(&[3, 200], 1.0, &mut rng);
        let mut y = vec![0.0f32; 3 * 8];
        qmatmul_rows_scalar(x_wide.data(), &q_wide, &mut y, 0);
        let w = Tensor::randn(&[10, 24], 0.5, &mut rng);
        let q = crate::quant::QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 8));
        let x = Tensor::randn(&[4, 24], 1.0, &mut rng);
        let mut after_wide = vec![0.0f32; 4 * 10];
        qmatmul_rows_scalar(x.data(), &q, &mut after_wide, 0);
        let expect: Vec<f32> = (0..4)
            .flat_map(|r| {
                let deq = q.dequantize();
                (0..10)
                    .map(|o| crate::tensor::dot(x.row(r), deq.row(o)))
                    .collect::<Vec<_>>()
            })
            .collect();
        assert_eq!(after_wide, expect);
    }
}
