//! Quantized LM: the deployment form where every linear layer is a
//! nibble-resident [`QuantizedLinear`] and everything else lives in a
//! [`LmSkeleton`] — no fp32 linear survives quantization, so the resident
//! footprint *is* the paper's "Mem" claim rather than an accounting of it.
//! The forward path runs fused unpack→dequant→matmul through the
//! microkernels in [`super::kernels`] (numerics are cross-checked against
//! the PJRT artifacts in the integration tests).
//!
//! This module is covered by rpiq-lint's no-panic rule: the forward and
//! qmatmul paths are serve-reachable, so shape problems surface as
//! `Err`, never as a panic inside a lane thread.

use super::decode::{self, KvPool, KvSeq};
use super::forward::{embed_rows, RowSelect};
use super::kernels;
use super::ops::{
    act_fwd, attention_fwd, attention_fwd_chunked, layernorm_fwd, linear_fwd, ATTN_CHUNK,
};
use super::weights::{LmSkeleton, LmWeights};
use crate::metrics::MemoryLedger;
use crate::quant::{QLinearStore, QuantizedLinear};
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

/// Ledger tag under which a deployed model's resident bytes (packed
/// levels + group params + skeleton) are registered — the counterpart of
/// the transient per-lane activation tags the serve loop uses.
pub const RESIDENT_TAG: &str = crate::metrics::tags::MODEL_RESIDENT;

/// Equal-shape groups wider than this many sequences are sharded into
/// chunked fused forwards that fan out across the global pool (see
/// [`QuantizedLm::forward_batch`] and the VLM batched path). Within one
/// chunk the inner dequant-matmuls still shard *activation rows*, so this
/// is the coarse, inter-sequence level of the two-level row sharding.
pub const WIDE_GROUP_ROWS: usize = 16;

/// Shared skeleton of the batched forwards ([`QuantizedLm::forward_batch`],
/// the VLM pair batching, and the serve lanes' in-place answer
/// extraction): group item indices `0..n` by a shape key, split each
/// group into chunks of at most [`WIDE_GROUP_ROWS`] items, run `run` per
/// chunk, and scatter the per-item results back into input order. All
/// chunks — several distinct-shape groups as well as the row-wise splits
/// of one very wide group — fan out across the global pool together; a
/// lone chunk runs inline on the calling thread. `run` receives the
/// original item indices of one equal-shape chunk and must return one
/// result per index, in order; the first chunk `Err` aborts the batch.
pub(crate) fn run_equal_shape_groups<R, F>(
    n: usize,
    key_of: impl Fn(usize) -> usize,
    run: F,
) -> Result<Vec<R>>
where
    R: Send,
    F: Fn(&[usize]) -> Result<Vec<R>> + Sync,
{
    let mut by_key: std::collections::BTreeMap<usize, Vec<usize>> =
        std::collections::BTreeMap::new();
    for i in 0..n {
        by_key.entry(key_of(i)).or_default().push(i);
    }
    let chunks: Vec<&[usize]> = by_key
        .values()
        .flat_map(|members| members.chunks(WIDE_GROUP_ROWS))
        .collect();
    let results: Vec<Result<Vec<R>>> = if chunks.len() <= 1 {
        chunks.iter().map(|&c| run(c)).collect()
    } else {
        let run_ref = &run;
        crate::exec::global().map(chunks.iter().map(|&c| move || run_ref(c)).collect())
    };
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (chunk, res) in chunks.iter().zip(results) {
        let res = res?;
        ensure!(
            res.len() == chunk.len(),
            "equal-shape chunk returned {} results for {} items",
            res.len(),
            chunk.len()
        );
        for (&i, l) in chunk.iter().zip(res) {
            if let Some(slot) = out.get_mut(i) {
                *slot = Some(l);
            }
        }
    }
    let mut answered = Vec::with_capacity(n);
    for slot in out {
        match slot {
            Some(l) => answered.push(l),
            None => bail!("equal-shape grouping left an item unanswered"),
        }
    }
    Ok(answered)
}

/// Per-transformer-block [`QLinearStore`] indices, resolved once at model
/// construction so the forward path never formats a layer name or probes
/// a map — the hot loop addresses linears by dense index. Shared with the
/// VLM's decoder body (same canonical `lm.layer{i}.*` name space).
#[derive(Clone, Copy, Debug)]
pub(crate) struct LmLayerPlan {
    pub(crate) q: usize,
    pub(crate) k: usize,
    pub(crate) v: usize,
    pub(crate) out: usize,
    pub(crate) up: usize,
    pub(crate) down: usize,
}

/// The forward path's resolved addressing plan (one [`LmLayerPlan`] per
/// block, plus the optional untied head).
#[derive(Clone, Debug, Default)]
pub(crate) struct LmPlan {
    pub(crate) layers: Vec<LmLayerPlan>,
    pub(crate) head: Option<usize>,
}

impl LmPlan {
    /// Resolve every canonical layer name to its store index, verifying
    /// completeness (every linear the config declares must be present).
    pub(crate) fn resolve(skeleton: &LmSkeleton, store: &QLinearStore) -> Result<LmPlan> {
        let need = |name: String| -> Result<usize> {
            match store.index_of(&name) {
                Some(i) => Ok(i),
                None => bail!("missing quantized layer {name}"),
            }
        };
        let mut layers = Vec::with_capacity(skeleton.config.n_layers);
        for li in 0..skeleton.config.n_layers {
            layers.push(LmLayerPlan {
                q: need(format!("lm.layer{li}.attn.q"))?,
                k: need(format!("lm.layer{li}.attn.k"))?,
                v: need(format!("lm.layer{li}.attn.v"))?,
                out: need(format!("lm.layer{li}.attn.out"))?,
                up: need(format!("lm.layer{li}.mlp.up"))?,
                down: need(format!("lm.layer{li}.mlp.down"))?,
            });
        }
        let head = if skeleton.config.tied_head {
            // a quantized head may still be present (untied checkpoints
            // loaded under a tied config are rejected elsewhere)
            store.index_of("lm.head")
        } else {
            Some(need("lm.head".into())?)
        };
        Ok(LmPlan { layers, head })
    }
}

/// A model whose linears are quantized (nibble-packed); everything else
/// (embeddings, LayerNorm) stays fp32 in the [`LmSkeleton`], matching
/// standard PTQ deployments — but unlike the pre-refactor code, no unused
/// fp32 linear is kept alive.
pub struct QuantizedLm {
    /// fp32 residue: embeddings, norms, config — no linears.
    pub skeleton: LmSkeleton,
    /// canonical layer name → quantized weights (sorted, index-addressed).
    pub qlinears: QLinearStore,
    /// name→index resolution, computed once at construction.
    plan: LmPlan,
}

impl QuantizedLm {
    /// Assemble from a deployment skeleton and per-layer quantized
    /// matrices. Every linear the config declares must be present — a
    /// missing layer is an `Err`, since the loaders feed this from
    /// on-disk containers.
    pub fn new(skeleton: LmSkeleton, qlinears: HashMap<String, QuantizedLinear>) -> Result<Self> {
        let store = QLinearStore::from_map(qlinears);
        let plan = LmPlan::resolve(&skeleton, &store)?;
        Ok(QuantizedLm { skeleton, qlinears: store, plan })
    }

    /// Assemble from full training weights: extracts the skeleton and
    /// *drops* the fp32 linears (the caller hands over ownership — this is
    /// the release point of the 60–75% resident reduction).
    pub fn from_weights(w: LmWeights, qlinears: HashMap<String, QuantizedLinear>) -> Result<Self> {
        Self::new(LmSkeleton::from_weights(&w), qlinears)
    }

    /// The model config (lives in the skeleton).
    pub fn config(&self) -> &super::config::ModelConfig {
        &self.skeleton.config
    }

    /// Round-to-nearest quantize every linear of `w` onto `grid` — the
    /// calibration-free baseline, and the scaffolding the serve tests and
    /// benches build their models with. Consumes `w`; the fp32 linears die
    /// here.
    pub fn quantize_rtn(w: LmWeights, grid: crate::quant::QuantGrid) -> Result<Self> {
        let mut qlinears = HashMap::new();
        for (name, t) in w.linears() {
            qlinears.insert(name, QuantizedLinear::quantize_rtn(t, grid));
        }
        Self::from_weights(w, qlinears)
    }

    /// Actual resident deployment bytes: packed levels + group params of
    /// every quantized linear, plus the fp32 skeleton (embeddings, norms)
    /// — the "Mem (GB)" quantity of Tables 1–2 at our scale, and exactly
    /// what [`Self::register_resident`] books into a ledger.
    pub fn deploy_bytes(&self) -> usize {
        self.qlinears.nbytes() + self.skeleton.nbytes()
    }

    /// Book this model's resident bytes into `ledger` under
    /// [`RESIDENT_TAG`], component by component (each packed linear, then
    /// the skeleton), so ledger-observed live bytes equal
    /// [`Self::deploy_bytes`] exactly.
    pub fn register_resident(&self, ledger: &MemoryLedger) {
        account_resident(ledger, &self.qlinears, self.skeleton.nbytes(), true);
    }

    /// Release the bytes booked by [`Self::register_resident`].
    pub fn release_resident(&self, ledger: &MemoryLedger) {
        account_resident(ledger, &self.qlinears, self.skeleton.nbytes(), false);
    }

    /// Fused dequant-matmul: `y = x · deq(W)ᵀ` through the selected inner
    /// kernel (see [`super::kernels`] for the scalar/tiled contract and
    /// selection order). Only `O(K)` (scalar) or `O(KC·NR)` (tiled)
    /// transient state per worker lives in thread-local scratch — no
    /// byte-per-level copy of the matrix ever exists, and no per-call
    /// allocation happens beyond the output tensor.
    ///
    /// Parallelism: activation rows are sharded across the global pool
    /// (`crate::exec`), each worker owning a disjoint `&mut` row chunk of
    /// `y` and running the identical inner kernel — results are
    /// bit-identical across thread counts for *both* kernels (the scalar
    /// path matches the sequential walk exactly; the tiled path is a
    /// fixed per-element reduction chain regardless of sharding). Each
    /// shard re-dequantizes the weight rows; with `R` rows per shard the
    /// extra conversion cost is `1/R` of the contraction work, which is
    /// why the shard floor [`kernels::MIN_ROWS_PER_SHARD`] exists. Small
    /// problems stay on the calling thread (same cutoff as the dense
    /// matmul kernels).
    ///
    /// Perf note (rust/DESIGN.md §Perf notes, §Packed microkernels): an
    /// earlier per-(i,o) group loop re-converted each u8 level `N` times
    /// and ran 0.81× the speed of materialize-then-matmul; hoisting the
    /// row dequantization out of the activation loop amortizes the
    /// conversion `N`-fold, and the tiled kernel layers cache blocking +
    /// register tiling + FMA on top (see the `qmatmul` arm of
    /// `benches/quantize.rs` for the kernel × threads × sizes evidence).
    ///
    /// Errors when `x`'s width disagrees with the linear's `in_features`
    /// — this is serve-reachable, so it must not panic.
    pub fn qmatmul(x: &Tensor, q: &QuantizedLinear) -> Result<Tensor> {
        let (n, in_f) = (x.rows(), x.cols());
        ensure!(
            in_f == q.in_features,
            "qmatmul shape mismatch: x is {n}x{in_f} but the linear expects \
             in_features={} (out_features={})",
            q.in_features,
            q.out_features
        );
        let out_f = q.out_features;
        let kernel = kernels::active_kernel();
        // Span only on the tiled path (the attribution the tentpole
        // needs), emitted on the calling thread so span counts stay
        // thread-count-stable; alloc-free when tracing is disabled.
        let _span = match kernel {
            kernels::QmatmulKernel::Tiled => Some(crate::trace::span_detail(
                "model",
                "qmatmul.tile",
                || format!("{n}x{in_f}x{out_f}"),
            )),
            kernels::QmatmulKernel::Scalar => None,
        };
        let mut y = Tensor::zeros(&[n, out_f]);
        let xd = x.data();
        crate::tensor::par_rows(
            y.data_mut(),
            n,
            out_f,
            2 * n * in_f * out_f,
            kernels::MIN_ROWS_PER_SHARD,
            |chunk, i0| kernels::run_rows(kernel, xd, q, chunk, i0),
        );
        Ok(y)
    }

    /// Batched forward over independent sequences of possibly different
    /// lengths — the sentiment lane's entry point. Sequences are grouped
    /// by length (each group is one fused forward) and, when a group is
    /// wider than [`WIDE_GROUP_ROWS`] sequences, the group is sharded
    /// row-wise into chunked fused forwards that fan out across the global
    /// pool explicitly.
    ///
    /// Every op in [`Self::forward`] is per-row / per-sequence (embedding
    /// and LayerNorm are row-wise, attention loops sequences, and the
    /// fused dequant-matmul computes each output row independently in a
    /// fixed f32 order), so the returned per-sequence logits `[S_i, V]`
    /// are **bit-identical** to `forward(seq_i, 1, S_i)` — asserted by the
    /// batch-parity test.
    pub fn forward_batch(&self, seqs: &[&[u32]]) -> Result<Vec<Tensor>> {
        self.forward_batch_rows(seqs, RowSelect::Full)
    }

    /// [`Self::forward_batch`] with an explicit [`RowSelect`] mode. In
    /// `LastRow` mode each returned per-sequence tensor is the single
    /// answer-row logits `[1, V]`, bit-identical to the last row of the
    /// same sequence's `forward_rows(…, LastRow)` — the serve lanes'
    /// batched entry point.
    pub fn forward_batch_rows(&self, seqs: &[&[u32]], rows: RowSelect) -> Result<Vec<Tensor>> {
        for s in seqs {
            ensure!(!s.is_empty(), "empty sequence in batch");
        }
        run_equal_shape_groups(
            seqs.len(),
            |i| seqs.get(i).map_or(0, |s| s.len()),
            |chunk| {
                let Some(&first) = chunk.first() else {
                    return Ok(Vec::new());
                };
                let seq = seqs.get(first).map_or(0, |s| s.len());
                let mut tokens = Vec::with_capacity(chunk.len() * seq);
                for &i in chunk {
                    if let Some(s) = seqs.get(i) {
                        tokens.extend_from_slice(s);
                    }
                }
                ensure!(
                    tokens.len() == chunk.len() * seq,
                    "equal-shape chunk mixed sequence lengths"
                );
                let out_per = rows.out_rows(1, seq);
                let logits = self.forward_rows(&tokens, chunk.len(), seq, rows)?;
                Ok((0..chunk.len())
                    .map(|gi| logits.slice_rows(gi * out_per, (gi + 1) * out_per))
                    .collect())
            },
        )
    }

    /// Forward pass: tokens → logits, all linears via [`Self::qmatmul`]
    /// addressed through the resolved [`LmPlan`] — no name formatting or
    /// map lookups on the hot path.
    pub fn forward(&self, tokens: &[u32], batch: usize, seq: usize) -> Result<Tensor> {
        self.forward_rows(tokens, batch, seq, RowSelect::Full)
    }

    /// [`Self::forward`] with an explicit [`RowSelect`] mode.
    ///
    /// `Full` keeps the exact attention oracle and full `[B·S, V]` logits
    /// bit-identically (eval/perplexity path). `LastRow` is the serve
    /// path: attention runs chunked ([`attention_fwd_chunked`], key
    /// blocks of [`ATTN_CHUNK`], within
    /// [`super::ops::ATTN_CHUNK_REL_TOL`] of the oracle) and only each
    /// sequence's final position reaches the final layernorm + head
    /// matmul, so logits are `[B, V]` and no `O(S²)` or `O(B·S·V)`
    /// transient exists.
    pub fn forward_rows(
        &self,
        tokens: &[u32],
        batch: usize,
        seq: usize,
        rows: RowSelect,
    ) -> Result<Tensor> {
        let _span = crate::trace::span_detail("model", "lm.forward", || {
            format!("{batch}x{seq} {rows:?}")
        });
        ensure!(batch > 0 && seq > 0, "forward over an empty token grid");
        let s = &self.skeleton;
        let cfg = &s.config;
        let st = &self.qlinears;
        let mut x = embed_rows(&s.tok_emb, &s.pos_emb, cfg.seq_len, tokens, batch, seq);
        for (l, p) in s.layers.iter().zip(self.plan.layers.iter()) {
            let (ln1, _, _) = layernorm_fwd(&x, &l.ln1_g, &l.ln1_b);
            let q = Self::qmatmul(&ln1, st.at(p.q))?;
            let k = Self::qmatmul(&ln1, st.at(p.k))?;
            let v = Self::qmatmul(&ln1, st.at(p.v))?;
            let ctx = match rows {
                RowSelect::Full => attention_fwd(&q, &k, &v, batch, seq, cfg.n_heads).0,
                RowSelect::LastRow => {
                    attention_fwd_chunked(&q, &k, &v, batch, seq, cfg.n_heads, ATTN_CHUNK)
                }
            };
            let attn_out = Self::qmatmul(&ctx, st.at(p.out))?;
            x.add_assign(&attn_out);
            let (ln2, _, _) = layernorm_fwd(&x, &l.ln2_g, &l.ln2_b);
            let up = act_fwd(&Self::qmatmul(&ln2, st.at(p.up))?, cfg.activation);
            let down = Self::qmatmul(&up, st.at(p.down))?;
            x.add_assign(&down);
        }
        let x = rows.select(x, batch, seq);
        let (lnf, _, _) = layernorm_fwd(&x, &s.lnf_g, &s.lnf_b);
        match self.plan.head {
            Some(h) => Self::qmatmul(&lnf, st.at(h)),
            // tied head stays fp32 (it is the embedding)
            None => Ok(linear_fwd(&lnf, &s.tok_emb)),
        }
    }

    /// Dominant transient-activation bytes of one fused serve forward of
    /// `batch` sequences of length `seq` in [`RowSelect::LastRow`] mode:
    /// the answer-row logits `[B, V]`, the widest per-layer activation
    /// `[B·S, max(d_model, d_ff)]`, and the chunked attention path's
    /// `O(ATTN_CHUNK)` score block. This is what the serve lanes book
    /// against the `activations.<lane>` ledger budget — compare the PR 8
    /// full-logits booking of `B·S·V` f32s, which row-select removes.
    pub fn serve_transient_bytes(&self, batch: usize, seq: usize) -> usize {
        let cfg = &self.skeleton.config;
        let wide = cfg.d_model.max(cfg.d_ff);
        (batch * cfg.vocab + batch * seq * wide + ATTN_CHUNK) * 4
    }

    /// Validate that `kv` was allocated for this model's geometry and can
    /// still hold `need` more positions.
    fn check_cache(&self, kv: &KvSeq, need: usize) -> Result<()> {
        let cfg = &self.skeleton.config;
        ensure!(
            kv.n_layers() == self.skeleton.layers.len() && kv.width() == cfg.d_model,
            "kv cache geometry {}x{} does not match model {}x{}",
            kv.n_layers(),
            kv.width(),
            self.skeleton.layers.len(),
            cfg.d_model
        );
        ensure!(
            kv.len() + need <= kv.capacity(),
            "kv cache capacity {} cannot take {need} more positions (len {})",
            kv.capacity(),
            kv.len()
        );
        ensure!(
            kv.len() + need <= cfg.seq_len,
            "cached positions {} + {need} exceed model context {}",
            kv.len(),
            cfg.seq_len
        );
        Ok(())
    }

    /// Prefill for streaming decode: run the serve forward over the whole
    /// `prompt` (exactly [`Self::forward_rows`] in
    /// [`RowSelect::LastRow`] mode — chunked attention, answer-row head),
    /// additionally writing every position's per-layer key/value rows
    /// into `kv`, and return the `[1, V]` logits of the last prompt
    /// position. The returned logits — and hence the first greedy token —
    /// are bit-identical to `forward_rows(prompt, 1, len, LastRow)`; the
    /// cache writes do not perturb any float op.
    pub fn decode_prefill(&self, kv: &mut KvSeq, prompt: &[u32]) -> Result<Tensor> {
        let _span =
            crate::trace::span_detail("model", "lm.prefill", || format!("len {}", prompt.len()));
        let s = &self.skeleton;
        let cfg = &s.config;
        let st = &self.qlinears;
        ensure!(!prompt.is_empty(), "prefill over an empty prompt");
        ensure!(kv.is_empty(), "prefill into a non-empty kv cache (len {})", kv.len());
        self.check_cache(kv, prompt.len())?;
        for &t in prompt {
            ensure!((t as usize) < cfg.vocab, "token id {t} outside vocab {}", cfg.vocab);
        }
        let seq = prompt.len();
        let mut x = embed_rows(&s.tok_emb, &s.pos_emb, cfg.seq_len, prompt, 1, seq);
        for (li, (l, p)) in s.layers.iter().zip(self.plan.layers.iter()).enumerate() {
            let (ln1, _, _) = layernorm_fwd(&x, &l.ln1_g, &l.ln1_b);
            let q = Self::qmatmul(&ln1, st.at(p.q))?;
            let k = Self::qmatmul(&ln1, st.at(p.k))?;
            let v = Self::qmatmul(&ln1, st.at(p.v))?;
            for pos in 0..seq {
                kv.write(li, pos, k.row(pos), v.row(pos))?;
            }
            let ctx = attention_fwd_chunked(&q, &k, &v, 1, seq, cfg.n_heads, ATTN_CHUNK);
            let attn_out = Self::qmatmul(&ctx, st.at(p.out))?;
            x.add_assign(&attn_out);
            let (ln2, _, _) = layernorm_fwd(&x, &l.ln2_g, &l.ln2_b);
            let up = act_fwd(&Self::qmatmul(&ln2, st.at(p.up))?, cfg.activation);
            let down = Self::qmatmul(&up, st.at(p.down))?;
            x.add_assign(&down);
        }
        let x = RowSelect::LastRow.select(x, 1, seq);
        let (lnf, _, _) = layernorm_fwd(&x, &s.lnf_g, &s.lnf_b);
        let logits = match self.plan.head {
            Some(h) => Self::qmatmul(&lnf, st.at(h))?,
            None => linear_fwd(&lnf, &s.tok_emb),
        };
        kv.advance(seq)?;
        Ok(logits)
    }

    /// One streaming decode step: embed `token` at the next absolute
    /// position, run a `[1, d]` forward whose attention reads the paged
    /// cache ([`KvSeq::attend_last`]) instead of recomputing every key
    /// and value, append this position's key/value rows to `kv`, and
    /// return the `[1, V]` logits.
    ///
    /// `O(S)` per step: every non-attention op touches one row, and
    /// attention is one pass over the cached rows. Bit-identical to
    /// `forward_rows(prefix ++ [token], 1, len+1, LastRow)` because each
    /// op is row-independent in a fixed f32 order and the paged attention
    /// replays the chunked oracle's block recurrence (see
    /// [`super::decode`]).
    pub fn decode_step(&self, kv: &mut KvSeq, token: u32) -> Result<Tensor> {
        let s = &self.skeleton;
        let cfg = &s.config;
        let st = &self.qlinears;
        let pos = kv.len();
        let _span = crate::trace::span_detail("model", "lm.decode_step", || format!("pos {pos}"));
        ensure!(pos > 0, "decode_step before prefill");
        self.check_cache(kv, 1)?;
        ensure!((token as usize) < cfg.vocab, "token id {token} outside vocab {}", cfg.vocab);
        let d = cfg.d_model;
        // Same arithmetic as `embed_rows` for the single row at `pos`.
        let mut e = vec![0.0f32; d];
        let te = s.tok_emb.row(token as usize);
        let pe = s.pos_emb.row(pos);
        for ((o, &a), &b) in e.iter_mut().zip(te.iter()).zip(pe.iter()) {
            *o = a + b;
        }
        let mut x = Tensor::from_vec(&[1, d], e);
        for (li, (l, p)) in s.layers.iter().zip(self.plan.layers.iter()).enumerate() {
            let (ln1, _, _) = layernorm_fwd(&x, &l.ln1_g, &l.ln1_b);
            let q = Self::qmatmul(&ln1, st.at(p.q))?;
            let k = Self::qmatmul(&ln1, st.at(p.k))?;
            let v = Self::qmatmul(&ln1, st.at(p.v))?;
            kv.write(li, pos, k.row(0), v.row(0))?;
            let ctx = Tensor::from_vec(&[1, d], kv.attend_last(li, cfg.n_heads, q.row(0))?);
            let attn_out = Self::qmatmul(&ctx, st.at(p.out))?;
            x.add_assign(&attn_out);
            let (ln2, _, _) = layernorm_fwd(&x, &l.ln2_g, &l.ln2_b);
            let up = act_fwd(&Self::qmatmul(&ln2, st.at(p.up))?, cfg.activation);
            let down = Self::qmatmul(&up, st.at(p.down))?;
            x.add_assign(&down);
        }
        let (lnf, _, _) = layernorm_fwd(&x, &s.lnf_g, &s.lnf_b);
        let logits = match self.plan.head {
            Some(h) => Self::qmatmul(&lnf, st.at(h))?,
            None => linear_fwd(&lnf, &s.tok_emb),
        };
        kv.advance(1)?;
        Ok(logits)
    }

    /// Greedy streaming generation through a paged KV cache: allocate a
    /// worst-case sequence from `pool`, prefill on `prompt`, then decode
    /// up to `max_new` tokens (stopping after `eos` when given, which is
    /// included in the output). Token-for-token bit-identical to
    /// [`Self::generate_recompute`] — the contract the decode determinism
    /// tests pin.
    ///
    /// The context bound is `prompt.len() + max_new ≤ seq_len + 1`: the
    /// final sampled token is returned but never re-embedded.
    pub fn generate(
        &self,
        pool: &KvPool,
        prompt: &[u32],
        max_new: usize,
        eos: Option<u32>,
    ) -> Result<Vec<u32>> {
        ensure!(max_new > 0, "generate of zero tokens");
        let cfg = &self.skeleton.config;
        ensure!(
            prompt.len() + max_new <= cfg.seq_len + 1,
            "prompt {} + max_new {max_new} exceeds context {}",
            prompt.len(),
            cfg.seq_len
        );
        let cap_tokens = prompt.len() + max_new - 1;
        let Some(mut kv) = pool.alloc_seq(cap_tokens) else {
            bail!(
                "kv pool exhausted: {} of {} pages free, need {}",
                pool.free_pages(),
                pool.capacity_pages(),
                pool.pages_for(cap_tokens)
            );
        };
        let logits = self.decode_prefill(&mut kv, prompt)?;
        let mut next = decode::greedy_argmax(logits.row(0)) as u32;
        let mut out = vec![next];
        while out.len() < max_new && Some(next) != eos {
            let logits = self.decode_step(&mut kv, next)?;
            next = decode::greedy_argmax(logits.row(0)) as u32;
            out.push(next);
        }
        Ok(out)
    }

    /// The recompute-from-scratch greedy decode oracle: every step
    /// re-runs the full serve forward over the growing prefix — `O(S²)`
    /// per token, no cache. This is the reference [`Self::generate`] must
    /// match bitwise, and the baseline arm of `benches/serve.rs`'s decode
    /// comparison.
    pub fn generate_recompute(
        &self,
        prompt: &[u32],
        max_new: usize,
        eos: Option<u32>,
    ) -> Result<Vec<u32>> {
        ensure!(max_new > 0, "generate of zero tokens");
        ensure!(!prompt.is_empty(), "prefill over an empty prompt");
        let cfg = &self.skeleton.config;
        ensure!(
            prompt.len() + max_new <= cfg.seq_len + 1,
            "prompt {} + max_new {max_new} exceeds context {}",
            prompt.len(),
            cfg.seq_len
        );
        let mut toks = prompt.to_vec();
        let mut out = Vec::with_capacity(max_new);
        loop {
            let logits = self.forward_rows(&toks, 1, toks.len(), RowSelect::LastRow)?;
            let next = decode::greedy_argmax(logits.row(0)) as u32;
            out.push(next);
            if out.len() >= max_new || Some(next) == eos {
                break;
            }
            toks.push(next);
        }
        Ok(out)
    }
}

/// The one resident-accounting body behind
/// [`QuantizedLm::register_resident`]/[`QuantizedLm::release_resident`]
/// and the `QuantizedVlm` pair: book (or release) each packed linear's
/// bytes and the skeleton's bytes under [`RESIDENT_TAG`]. Keeping
/// alloc/free mirror-images of one loop is what the ledger-balance
/// assertions in the serve and footprint suites rely on.
pub(crate) fn account_resident(
    ledger: &MemoryLedger,
    qlinears: &QLinearStore,
    skeleton_bytes: usize,
    alloc: bool,
) {
    let mut book = |bytes: usize| {
        if alloc {
            ledger.alloc(RESIDENT_TAG, bytes);
        } else {
            ledger.free(RESIDENT_TAG, bytes);
        }
    };
    for q in qlinears.linears() {
        book(q.nbytes());
    }
    book(skeleton_bytes);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::forward::lm_forward;
    use crate::model::kernels::{
        kernel_test_lock, qmatmul_rows_scalar, set_kernel, QmatmulKernel,
    };
    use crate::quant::{QuantGrid, QuantizedLinear};
    use crate::rng::Pcg64;

    fn build_rtn_qlm(bits: u32) -> (LmWeights, QuantizedLm, Vec<u32>) {
        let cfg = ModelConfig::test_tiny(32);
        let mut rng = Pcg64::seeded(301);
        let w = LmWeights::init(&cfg, &mut rng);
        let qlm = QuantizedLm::quantize_rtn(w.clone(), QuantGrid::new(bits, 8)).expect("complete");
        let tokens: Vec<u32> = (0..16).map(|_| rng.next_below(32) as u32).collect();
        (w, qlm, tokens)
    }

    /// The pre-refactor byte-per-level kernel, kept as the bit-identity
    /// oracle for the packed scalar kernel: same group-hoisted dequant
    /// loop, but reading a transient unpacked level buffer.
    fn qmatmul_rows_unpacked_oracle(
        xd: &[f32],
        q: &QuantizedLinear,
        ychunk: &mut [f32],
        i0: usize,
    ) {
        let in_f = q.in_features;
        let out_f = q.out_features;
        let gs = q.grid.group_size;
        let ng = q.n_groups();
        let rows = ychunk.len() / out_f;
        let qw = q.levels();
        let mut wbuf = vec![0.0f32; in_f];
        for o in 0..out_f {
            let wrow = &qw[o * in_f..(o + 1) * in_f];
            for g in 0..ng {
                let c0 = g * gs;
                let c1 = (c0 + gs).min(in_f);
                let scale = q.scales[o * ng + g];
                let zero = q.zeros[o * ng + g];
                for c in c0..c1 {
                    wbuf[c] = (wrow[c] as f32 - zero) * scale;
                }
            }
            for r in 0..rows {
                let i = i0 + r;
                let xrow = &xd[i * in_f..(i + 1) * in_f];
                ychunk[r * out_f + o] = crate::tensor::dot(xrow, &wbuf);
            }
        }
    }

    #[test]
    fn packed_kernel_bit_identical_to_unpacked_oracle() {
        // The default path's core numeric contract: fusing the nibble
        // unpack into the dequant pass changes no float operation. Odd
        // widths (tail nibble) and 3/4/8-bit grids all pinned.
        let mut rng = Pcg64::seeded(309);
        for (bits, in_f) in [(3u32, 33usize), (4, 96), (4, 33), (8, 40)] {
            let w = Tensor::randn(&[24, in_f], 0.5, &mut rng);
            let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(bits, 16));
            let x = Tensor::randn(&[7, in_f], 1.0, &mut rng);
            let mut packed = Tensor::zeros(&[7, 24]);
            qmatmul_rows_scalar(x.data(), &q, packed.data_mut(), 0);
            let mut oracle = Tensor::zeros(&[7, 24]);
            qmatmul_rows_unpacked_oracle(x.data(), &q, oracle.data_mut(), 0);
            assert_eq!(packed.data(), oracle.data(), "bits={bits} in_f={in_f}");
        }
    }

    #[test]
    fn qmatmul_parallel_bit_identical_across_thread_counts() {
        let _threads = crate::exec::thread_target_test_lock();
        let _kernel = kernel_test_lock();
        let before = crate::exec::num_threads();
        // bit-identity to the oracle is a *scalar*-kernel contract
        set_kernel(Some(QmatmulKernel::Scalar));
        let mut rng = Pcg64::seeded(305);
        // 2·33·96·64 flops ≥ the parallel cutoff; 33 rows shard unevenly.
        let w = Tensor::randn(&[64, 96], 0.5, &mut rng);
        let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 16));
        let x = Tensor::randn(&[33, 96], 1.0, &mut rng);
        let mut reference = Tensor::zeros(&[33, 64]);
        qmatmul_rows_unpacked_oracle(x.data(), &q, reference.data_mut(), 0);
        for threads in [1, 2, 4] {
            crate::exec::set_threads(threads);
            let y = QuantizedLm::qmatmul(&x, &q).expect("shapes agree");
            assert_eq!(y.data(), reference.data(), "threads={threads}");
        }
        set_kernel(None);
        crate::exec::set_threads(before);
    }

    #[test]
    fn qmatmul_shape_mismatch_is_an_error_not_a_panic() {
        // Serve-reachable path: a malformed payload must surface as Err.
        let mut rng = Pcg64::seeded(306);
        let w = Tensor::randn(&[8, 16], 0.5, &mut rng);
        let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 8));
        let x = Tensor::randn(&[3, 12], 1.0, &mut rng);
        let err = QuantizedLm::qmatmul(&x, &q).expect_err("width 12 vs 16");
        assert!(err.to_string().contains("shape mismatch"), "{err}");
    }

    #[test]
    fn packed_forward_and_qckpt_roundtrip_deterministic_across_thread_counts() {
        // Acceptance shape of the kernel work, run by the CI determinism
        // matrix at RPIQ_THREADS=1/2/8: the packed forward and a forward
        // through a save→load round-trip of the `.rpiq` container are
        // bit-identical to the single-thread reference at any thread
        // count. Holds for either kernel (both are thread-deterministic);
        // the kernel lock keeps the selection fixed across the compares.
        let _threads = crate::exec::thread_target_test_lock();
        let _kernel = kernel_test_lock();
        let before = crate::exec::num_threads();
        let (_, qlm, tokens) = build_rtn_qlm(4);
        let dir = std::env::temp_dir().join("rpiq_qlm_det");
        let path = dir.join("m.rpiq");
        crate::model::io::save_qlm(&qlm, &path).unwrap();
        let loaded = crate::model::io::load_qlm(&path).unwrap();
        crate::exec::set_threads(1);
        let reference = qlm.forward(&tokens, 2, 8).expect("forward");
        for threads in [1usize, 2, 8] {
            crate::exec::set_threads(threads);
            assert_eq!(
                qlm.forward(&tokens, 2, 8).expect("forward").data(),
                reference.data(),
                "packed forward @ {threads} threads"
            );
            assert_eq!(
                loaded.forward(&tokens, 2, 8).expect("forward").data(),
                reference.data(),
                "qckpt-loaded forward @ {threads} threads"
            );
        }
        crate::exec::set_threads(before);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn qmatmul_matches_dequantized_matmul() {
        let mut rng = Pcg64::seeded(302);
        let w = Tensor::randn(&[6, 20], 1.0, &mut rng);
        let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 8));
        let x = Tensor::randn(&[5, 20], 1.0, &mut rng);
        let fused = QuantizedLm::qmatmul(&x, &q).expect("shapes agree");
        let reference = crate::tensor::matmul_a_bt(&x, &q.dequantize());
        assert!(fused.max_abs_diff(&reference) < 1e-4);
    }

    #[test]
    fn forward_batch_bit_identical_to_looped_forward() {
        let _kernel = kernel_test_lock(); // fixed kernel across the compares
        let (_, qlm, _) = build_rtn_qlm(4);
        let mut rng = Pcg64::seeded(307);
        // mixed lengths, with 20 sequences of one length so the wide-group
        // row-wise pool sharding path (> WIDE_GROUP_ROWS) is exercised
        let mut seqs: Vec<Vec<u32>> = Vec::new();
        for len in [4usize, 8, 4, 6] {
            seqs.push((0..len).map(|_| rng.next_below(32) as u32).collect());
        }
        for _ in 0..super::WIDE_GROUP_ROWS + 4 {
            seqs.push((0..8).map(|_| rng.next_below(32) as u32).collect());
        }
        let refs: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
        let batched = qlm.forward_batch(&refs).expect("batch forward");
        assert_eq!(batched.len(), seqs.len());
        for (s, b) in seqs.iter().zip(&batched) {
            let single = qlm.forward(s, 1, s.len()).expect("forward");
            assert_eq!(b.shape(), single.shape());
            assert_eq!(b.data(), single.data(), "len={}", s.len());
        }
    }

    #[test]
    fn last_row_batch_parity_and_tolerance_vs_full() {
        let _kernel = kernel_test_lock(); // fixed kernel across the compares
        let (_, qlm, _) = build_rtn_qlm(4);
        let mut rng = Pcg64::seeded(310);
        let mut seqs: Vec<Vec<u32>> = Vec::new();
        for len in [1usize, 4, 8, 5, 8] {
            seqs.push((0..len).map(|_| rng.next_below(32) as u32).collect());
        }
        for _ in 0..super::WIDE_GROUP_ROWS + 4 {
            seqs.push((0..8).map(|_| rng.next_below(32) as u32).collect());
        }
        let refs: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
        // Batch parity: fused LastRow forward ≡ single-sequence LastRow
        // forward, bit-identically (same code path, row-independent ops).
        let batched = qlm.forward_batch_rows(&refs, RowSelect::LastRow).expect("batch");
        for (s, b) in seqs.iter().zip(&batched) {
            let single = qlm
                .forward_rows(s, 1, s.len(), RowSelect::LastRow)
                .expect("forward");
            assert_eq!(b.shape(), &[1, 32]);
            assert_eq!(b.data(), single.data(), "len={}", s.len());
        }
        // Tolerance vs the exact oracle: LastRow runs the chunked online
        // softmax; its bounded per-layer deviation compounds across the
        // blocks, so allow 10× ATTN_CHUNK_REL_TOL end-to-end.
        for (s, b) in seqs.iter().zip(&batched) {
            let full = qlm.forward(s, 1, s.len()).expect("forward");
            let want = full.row(s.len() - 1);
            let mag = want.iter().fold(1.0f32, |a, &x| a.max(x.abs()));
            let diff = b
                .row(0)
                .iter()
                .zip(want)
                .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()));
            assert!(
                diff <= 10.0 * crate::model::ops::ATTN_CHUNK_REL_TOL * mag,
                "len={}: diff={diff:e} mag={mag:e}",
                s.len()
            );
        }
    }

    #[test]
    fn serve_transient_bytes_matches_its_documented_formula() {
        // The quantity the serve lanes book per batch. (The strict-drop
        // regression vs. the PR 8 full-logits booking only holds where
        // S·V dominates — bench scale — and lives in benches/footprint.rs;
        // here we pin the formula itself.)
        let (_, qlm, _) = build_rtn_qlm(4);
        let cfg = &qlm.skeleton.config;
        let (b, s) = (8usize, 8usize);
        let wide = cfg.d_model.max(cfg.d_ff);
        assert_eq!(
            qlm.serve_transient_bytes(b, s),
            (b * cfg.vocab + b * s * wide + super::ATTN_CHUNK) * 4
        );
    }

    #[test]
    fn forward_batch_rejects_empty_sequence() {
        let (_, qlm, _) = build_rtn_qlm(4);
        let seqs: Vec<&[u32]> = vec![&[1, 2], &[]];
        let err = qlm.forward_batch(&seqs).expect_err("empty sequence");
        assert!(err.to_string().contains("empty sequence"), "{err}");
    }

    #[test]
    fn eight_bit_forward_close_to_fp() {
        let (w, qlm, tokens) = build_rtn_qlm(8);
        let fp = lm_forward(&w, &tokens, 2, 8, None);
        let qf = qlm.forward(&tokens, 2, 8).expect("forward");
        let rel = qf.sub(&fp).frob() / fp.frob().max(1e-9);
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn four_bit_forward_degrades_more_than_eight_bit() {
        let (w, q4, tokens) = build_rtn_qlm(4);
        let (_, q8, _) = build_rtn_qlm(8);
        let fp = lm_forward(&w, &tokens, 2, 8, None);
        let e4 = q4.forward(&tokens, 2, 8).expect("forward").sub(&fp).frob();
        let e8 = q8.forward(&tokens, 2, 8).expect("forward").sub(&fp).frob();
        assert!(e4 > e8, "e4={e4} e8={e8}");
    }

    /// A linear-dominated config (unlike `test_tiny`, which is
    /// embedding-dominated): this is the shape class where the paper's
    /// Tables 1/3 memory claims live, scaled to test size.
    fn linear_heavy_cfg() -> ModelConfig {
        ModelConfig {
            name: "test-linear-heavy".into(),
            vocab: 32,
            d_model: 64,
            n_layers: 4,
            n_heads: 4,
            d_ff: 256,
            seq_len: 16,
            activation: crate::model::Activation::Gelu,
            tied_head: true,
        }
    }

    #[test]
    fn deploy_bytes_equals_ledger_observed_resident_bytes() {
        // Satellite contract: deploy_bytes() must report the *actual*
        // resident bytes of the representation — cross-checked two ways:
        // (1) against an independent from-shapes computation, and
        // (2) against the ledger-observed live bytes after registration.
        let cfg = linear_heavy_cfg();
        let mut rng = Pcg64::seeded(311);
        let w = LmWeights::init(&cfg, &mut rng);
        let gs = 32usize;
        let qlm = QuantizedLm::quantize_rtn(w.clone(), QuantGrid::new(4, gs)).expect("complete");
        // independent expectation straight from the shapes
        let mut expect = 0usize;
        for (_, t) in w.linears() {
            let (out, inf) = (t.rows(), t.cols());
            let ng = inf.div_ceil(gs);
            expect += out * inf.div_ceil(2) + 2 * out * ng * 4;
        }
        for (name, t) in w.named_tensors() {
            if w.linear(&name).is_none() {
                expect += t.nbytes();
            }
        }
        assert_eq!(qlm.deploy_bytes(), expect);
        // ledger-observed live bytes of the registered model
        let ledger = MemoryLedger::new();
        qlm.register_resident(&ledger);
        assert_eq!(ledger.live_bytes() as usize, qlm.deploy_bytes());
        assert_eq!(ledger.peak_for(RESIDENT_TAG) as usize, qlm.deploy_bytes());
        qlm.release_resident(&ledger);
        assert_eq!(ledger.live_bytes(), 0);
    }

    #[test]
    fn quantization_releases_fp32_linears_and_peak_drops() {
        // The memory claim at our scale: quantizing hands the fp32
        // weights over and keeps only skeleton + packed linears
        // resident — on a linear-dominated model the post-quantization
        // resident footprint must sit at ≤45% of fp32 (the paper's 60–75%
        // reduction band, Tables 3–4).
        let cfg = linear_heavy_cfg();
        let mut rng = Pcg64::seeded(312);
        let w = LmWeights::init(&cfg, &mut rng);
        let fp_bytes: usize = w.named_tensors().iter().map(|(_, t)| t.nbytes()).sum();
        let ledger = MemoryLedger::new();
        ledger.alloc("fp32_model", fp_bytes);
        let qlm = QuantizedLm::quantize_rtn(w, QuantGrid::new(4, 32)).expect("complete");
        qlm.register_resident(&ledger);
        // the fp32 model dies at quantization (ownership was consumed)
        ledger.free("fp32_model", fp_bytes);
        let resident = ledger.live_bytes() as usize;
        assert_eq!(resident, qlm.deploy_bytes());
        let frac = resident as f64 / fp_bytes as f64;
        assert!(frac <= 0.45, "resident {resident} is {frac:.2}x fp32 {fp_bytes}");
        assert!(frac >= 0.10, "suspiciously small ({frac:.3}x): accounting bug?");
        // peak covers the coexistence window; the steady state is the drop
        assert!(ledger.peak_bytes() as usize >= fp_bytes);
        qlm.release_resident(&ledger);
        assert_eq!(ledger.live_bytes(), 0);
    }

    #[test]
    fn deploy_bytes_smaller_than_fp() {
        let (w, qlm, _) = build_rtn_qlm(4);
        let fp_bytes: usize = w.named_tensors().iter().map(|(_, t)| t.nbytes()).sum();
        assert!(qlm.deploy_bytes() < fp_bytes);
    }

    #[test]
    fn missing_layer_rejected() {
        let cfg = ModelConfig::test_tiny(32);
        let mut rng = Pcg64::seeded(303);
        let w = LmWeights::init(&cfg, &mut rng);
        let err = QuantizedLm::from_weights(w, HashMap::new()).expect_err("no linears supplied");
        assert!(err.to_string().contains("missing quantized layer"), "{err}");
    }

    fn decode_pool(qlm: &QuantizedLm, pages: usize) -> (KvPool, MemoryLedger) {
        let ledger = MemoryLedger::new();
        let cfg = &qlm.skeleton.config;
        (KvPool::new(cfg.n_layers, cfg.d_model, pages, ledger.clone()), ledger)
    }

    #[test]
    fn paged_decode_bit_identical_to_recompute_oracle_deterministic() {
        // The PR's correctness contract, run by the CI determinism matrix
        // at RPIQ_THREADS=1/2/8: greedy decode through the paged KV cache
        // reproduces the recompute-from-scratch oracle token for token at
        // any thread count, and the kv_cache ledger tag drains to zero.
        let _threads = crate::exec::thread_target_test_lock();
        let _kernel = kernel_test_lock();
        let before = crate::exec::num_threads();
        let (_, qlm, tokens) = build_rtn_qlm(4);
        let prompt = &tokens[..3];
        let oracle = qlm.generate_recompute(prompt, 6, None).expect("oracle decode");
        assert_eq!(oracle.len(), 6);
        for threads in [1usize, 2, 8] {
            crate::exec::set_threads(threads);
            let (pool, ledger) = decode_pool(&qlm, 8);
            let cached = qlm.generate(&pool, prompt, 6, None).expect("cached decode");
            assert_eq!(cached, oracle, "threads={threads}");
            assert_eq!(ledger.live_bytes(), 0, "kv_cache must drain (threads={threads})");
            assert_eq!(pool.free_pages(), 8, "all pages returned (threads={threads})");
        }
        crate::exec::set_threads(before);
    }

    #[test]
    fn decode_prefill_matches_last_row_forward_bitwise() {
        // The first streamed token comes from prefill logits that must be
        // the serve forward's, exactly.
        let (_, qlm, tokens) = build_rtn_qlm(4);
        let prompt = &tokens[..5];
        let (pool, _ledger) = decode_pool(&qlm, 8);
        let mut kv = pool.alloc_seq(8).expect("fits");
        let prefill = qlm.decode_prefill(&mut kv, prompt).expect("prefill");
        let oracle = qlm
            .forward_rows(prompt, 1, prompt.len(), RowSelect::LastRow)
            .expect("forward");
        assert_eq!(prefill.data(), oracle.data());
        assert_eq!(kv.len(), prompt.len());
    }

    #[test]
    fn decode_eos_stops_early_and_is_included() {
        let (_, qlm, tokens) = build_rtn_qlm(4);
        let prompt = &tokens[..3];
        let free_run = qlm.generate_recompute(prompt, 6, None).expect("oracle");
        let eos = *free_run.get(2).expect("6 tokens");
        let (pool, ledger) = decode_pool(&qlm, 8);
        let stopped = qlm.generate(&pool, prompt, 6, Some(eos)).expect("cached");
        let oracle = qlm.generate_recompute(prompt, 6, Some(eos)).expect("oracle");
        assert_eq!(stopped, oracle);
        assert_eq!(stopped.last(), Some(&eos), "eos token is included");
        assert!(stopped.len() <= 3, "stopped at the first eos");
        assert_eq!(ledger.live_bytes(), 0);
    }

    #[test]
    fn decode_rejects_bad_shapes_and_exhausted_pool() {
        let (_, qlm, tokens) = build_rtn_qlm(4);
        let prompt = &tokens[..3];
        // context overflow is an Err, not a panic (serve-reachable path)
        let err = qlm.generate_recompute(prompt, 32, None).expect_err("context");
        assert!(err.to_string().contains("exceeds context"), "{err}");
        let (pool, ledger) = decode_pool(&qlm, 8);
        let err = qlm.generate(&pool, prompt, 32, None).expect_err("context");
        assert!(err.to_string().contains("exceeds context"), "{err}");
        // a drained pool surfaces as Err too, booking nothing
        let hold = pool.alloc_seq(8 * crate::model::decode::PAGE_SLOTS / 2);
        assert!(hold.is_some());
        let err = qlm.generate(&pool, prompt, 6, None).expect_err("pool drained");
        assert!(err.to_string().contains("kv pool exhausted"), "{err}");
        // a too-small cache is rejected by geometry checks
        let mut kv = KvPool::new(1, 4, 4, MemoryLedger::new()).alloc_seq(4).expect("fits");
        let err = qlm.decode_prefill(&mut kv, prompt).expect_err("geometry");
        assert!(err.to_string().contains("does not match model"), "{err}");
        drop(hold);
        assert_eq!(ledger.live_bytes(), 0);
    }
}
