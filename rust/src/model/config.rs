//! Model configuration and the experiment presets.

/// Feed-forward nonlinearity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    /// tanh-approximation GELU (LLaMA/Qwen-style MLPs use silu/gelu; we use
    /// gelu for the "modern" presets).
    Gelu,
    /// ReLU (OPT-style).
    Relu,
}

/// Decoder-only transformer configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Preset name, e.g. `sim-opt-6.7b`.
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    /// Maximum (and training) sequence length.
    pub seq_len: usize,
    pub activation: Activation,
    /// Tie the LM head to the token embedding.
    pub tied_head: bool,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        let emb = self.vocab * self.d_model + self.seq_len * self.d_model;
        let per_layer = 4 * self.d_model * self.d_model      // q,k,v,o
            + 2 * self.d_model * self.d_ff                   // up, down
            + 4 * self.d_model;                              // 2×LN (γ, β)
        let head = if self.tied_head { 0 } else { self.vocab * self.d_model };
        emb + self.n_layers * per_layer + 2 * self.d_model + head
    }

    /// fp32 byte footprint of the weights (Table 1's "Mem" baseline).
    pub fn fp32_bytes(&self) -> usize {
        self.n_params() * 4
    }

    /// The four language-model presets standing in for the paper's
    /// OPT-6.7B / OPT-13B / Qwen3-8B / LLaMA-3.1-8B-Instruct. Shapes are
    /// scaled ~3 orders of magnitude down but preserve the *relative*
    /// diversity: OPT-style ReLU + untied head, a deeper "13b", and two
    /// GELU tied-head "modern" models with different ff ratios.
    pub fn lm_presets(vocab: usize) -> Vec<ModelConfig> {
        vec![
            ModelConfig {
                name: "sim-opt-6.7b".into(),
                vocab,
                d_model: 128,
                n_layers: 4,
                n_heads: 4,
                d_ff: 512,
                seq_len: 48,
                activation: Activation::Relu,
                tied_head: false,
            },
            ModelConfig {
                name: "sim-opt-13b".into(),
                vocab,
                d_model: 160,
                n_layers: 6,
                n_heads: 4,
                d_ff: 640,
                seq_len: 48,
                activation: Activation::Relu,
                tied_head: false,
            },
            ModelConfig {
                name: "sim-qwen3-8b".into(),
                vocab,
                d_model: 144,
                n_layers: 5,
                n_heads: 4,
                d_ff: 576,
                seq_len: 48,
                activation: Activation::Gelu,
                tied_head: true,
            },
            ModelConfig {
                name: "sim-llama-3.1-8b-instruct".into(),
                vocab,
                d_model: 144,
                n_layers: 5,
                n_heads: 6,
                d_ff: 432,
                seq_len: 48,
                activation: Activation::Gelu,
                tied_head: true,
            },
        ]
    }

    /// Preset lookup by name.
    pub fn preset(name: &str, vocab: usize) -> Option<ModelConfig> {
        Self::lm_presets(vocab).into_iter().find(|c| c.name == name)
    }

    /// A minimal config for unit tests.
    pub fn test_tiny(vocab: usize) -> ModelConfig {
        ModelConfig {
            name: "test-tiny".into(),
            vocab,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 8,
            activation: Activation::Gelu,
            tied_head: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_distinct() {
        let ps = ModelConfig::lm_presets(512);
        assert_eq!(ps.len(), 4);
        for i in 0..ps.len() {
            for j in i + 1..ps.len() {
                assert_ne!(ps[i].name, ps[j].name);
                assert!(
                    ps[i].d_model != ps[j].d_model
                        || ps[i].n_layers != ps[j].n_layers
                        || ps[i].n_heads != ps[j].n_heads
                        || ps[i].d_ff != ps[j].d_ff
                );
            }
        }
    }

    #[test]
    fn param_count_sane() {
        let c = ModelConfig::test_tiny(64);
        // emb 64*16 + pos 8*16 + 2 layers*(4*256 + 2*16*32 + 64) + ln 32
        let expect = 64 * 16 + 8 * 16 + 2 * (4 * 256 + 2 * 16 * 32 + 64) + 32;
        assert_eq!(c.n_params(), expect);
        assert_eq!(c.fp32_bytes(), expect * 4);
    }

    #[test]
    fn opt13_is_largest() {
        let ps = ModelConfig::lm_presets(512);
        let p13 = ps.iter().find(|p| p.name == "sim-opt-13b").unwrap();
        for p in &ps {
            assert!(p13.n_params() >= p.n_params(), "{}", p.name);
        }
    }

    #[test]
    fn heads_divide_model_dim() {
        for p in ModelConfig::lm_presets(300) {
            assert_eq!(p.d_model % p.n_heads, 0, "{}", p.name);
        }
    }
}
