//! Differentiable primitive ops: forward + manual backward pairs.
//!
//! The trainer (`crate::train`) composes these; every backward here is
//! finite-difference-checked in the test module, which is what makes the
//! hand-written transformer backprop trustworthy.
//!
//! Shapes follow the flattened convention: token activations are
//! `[N, d] = [batch·seq, d]`; attention reshapes internally per (batch,
//! head).

use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Tensor};

/// `y = x·Wᵀ` — linear layer forward (`W: [out, in]`).
pub fn linear_fwd(x: &Tensor, w: &Tensor) -> Tensor {
    matmul_a_bt(x, w)
}

/// Backward of [`linear_fwd`]: given `dy`, returns `(dx, dw)` with
/// `dx = dy·W`, `dw = dyᵀ·x`.
pub fn linear_bwd(x: &Tensor, w: &Tensor, dy: &Tensor) -> (Tensor, Tensor) {
    let dx = matmul(dy, w);
    let dw = matmul_at_b(dy, x);
    (dx, dw)
}

/// LayerNorm forward over the last axis. Returns `(y, mean, rstd)` — the
/// saved statistics feed the backward.
pub fn layernorm_fwd(x: &Tensor, gamma: &Tensor, beta: &Tensor) -> (Tensor, Vec<f32>, Vec<f32>) {
    let (n, d) = (x.rows(), x.cols());
    let g = gamma.data();
    let b = beta.data();
    let mut y = Tensor::zeros(&[n, d]);
    let mut means = vec![0.0f32; n];
    let mut rstds = vec![0.0f32; n];
    for i in 0..n {
        let row = x.row(i);
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let rstd = 1.0 / (var + 1e-5).sqrt();
        means[i] = mean;
        rstds[i] = rstd;
        let out = y.row_mut(i);
        for j in 0..d {
            out[j] = (row[j] - mean) * rstd * g[j] + b[j];
        }
    }
    (y, means, rstds)
}

/// Backward of [`layernorm_fwd`]: returns `(dx, dgamma, dbeta)`.
pub fn layernorm_bwd(
    x: &Tensor,
    gamma: &Tensor,
    means: &[f32],
    rstds: &[f32],
    dy: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (n, d) = (x.rows(), x.cols());
    let g = gamma.data();
    let mut dx = Tensor::zeros(&[n, d]);
    let mut dgamma = Tensor::zeros(&[d]);
    let mut dbeta = Tensor::zeros(&[d]);
    for i in 0..n {
        let xrow = x.row(i);
        let dyrow = dy.row(i);
        let (mean, rstd) = (means[i], rstds[i]);
        // xhat_j = (x_j - mean)·rstd ; dy_xhat_j = dy_j·g_j
        // dx = rstd·(dy_xhat − mean(dy_xhat) − xhat·mean(dy_xhat ⊙ xhat))
        let mut sum_dyx = 0.0f32;
        let mut sum_dyx_xhat = 0.0f32;
        for j in 0..d {
            let xhat = (xrow[j] - mean) * rstd;
            let dyx = dyrow[j] * g[j];
            sum_dyx += dyx;
            sum_dyx_xhat += dyx * xhat;
        }
        let inv_d = 1.0 / d as f32;
        let dxrow = dx.row_mut(i);
        for j in 0..d {
            let xhat = (xrow[j] - mean) * rstd;
            let dyx = dyrow[j] * g[j];
            dxrow[j] = rstd * (dyx - inv_d * sum_dyx - xhat * inv_d * sum_dyx_xhat);
        }
        let dg = dgamma.data_mut();
        let db = dbeta.data_mut();
        for j in 0..d {
            let xhat = (xrow[j] - mean) * rstd;
            dg[j] += dyrow[j] * xhat;
            db[j] += dyrow[j];
        }
    }
    (dx, dgamma, dbeta)
}

/// tanh-approximation GELU.
#[inline]
pub fn gelu(v: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/π)
    0.5 * v * (1.0 + (C * (v + 0.044715 * v * v * v)).tanh())
}

/// d gelu(v) / dv.
#[inline]
pub fn gelu_grad(v: f32) -> f32 {
    const C: f32 = 0.797_884_6;
    let u = C * (v + 0.044715 * v * v * v);
    let t = u.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * v * sech2 * C * (1.0 + 3.0 * 0.044715 * v * v)
}

/// Elementwise activation forward.
pub fn act_fwd(x: &Tensor, act: super::Activation) -> Tensor {
    let mut y = x.clone();
    match act {
        super::Activation::Gelu => {
            for v in y.data_mut() {
                *v = gelu(*v);
            }
        }
        super::Activation::Relu => {
            for v in y.data_mut() {
                *v = v.max(0.0);
            }
        }
    }
    y
}

/// Elementwise activation backward: `dx = dy ⊙ act'(x)`.
pub fn act_bwd(x: &Tensor, dy: &Tensor, act: super::Activation) -> Tensor {
    let mut dx = dy.clone();
    match act {
        super::Activation::Gelu => {
            for (d, &v) in dx.data_mut().iter_mut().zip(x.data()) {
                *d *= gelu_grad(v);
            }
        }
        super::Activation::Relu => {
            for (d, &v) in dx.data_mut().iter_mut().zip(x.data()) {
                if v <= 0.0 {
                    *d = 0.0;
                }
            }
        }
    }
    dx
}

/// Causal multi-head self-attention forward over `[B·S, d]` activations.
///
/// Returns `(ctx, probs)`: the attention output (pre-`W_o`) and the
/// softmax probabilities `[B·H, S, S]` saved for backward.
pub fn attention_fwd(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    batch: usize,
    seq: usize,
    n_heads: usize,
) -> (Tensor, Vec<Tensor>) {
    let d = q.cols();
    let dh = d / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = Tensor::zeros(&[batch * seq, d]);
    let mut probs = Vec::with_capacity(batch * n_heads);
    for b in 0..batch {
        for h in 0..n_heads {
            let off = h * dh;
            // scores[s, t] = q_s · k_t · scale  (t ≤ s)
            let mut p = Tensor::zeros(&[seq, seq]);
            for s in 0..seq {
                let qrow = &q.row(b * seq + s)[off..off + dh];
                let prow = p.row_mut(s);
                let mut maxv = f32::NEG_INFINITY;
                for t in 0..=s {
                    let krow = &k.row(b * seq + t)[off..off + dh];
                    let sc = crate::tensor::dot(qrow, krow) * scale;
                    prow[t] = sc;
                    if sc > maxv {
                        maxv = sc;
                    }
                }
                let mut z = 0.0f32;
                for t in 0..=s {
                    let e = (prow[t] - maxv).exp();
                    prow[t] = e;
                    z += e;
                }
                let inv = 1.0 / z;
                for t in 0..=s {
                    prow[t] *= inv;
                }
                // strictly future stays 0 (causal mask)
            }
            // ctx_s = Σ_t p[s,t]·v_t
            for s in 0..seq {
                let prow = p.row(s);
                let crow = &mut ctx.row_mut(b * seq + s)[off..off + dh];
                for t in 0..=s {
                    let vrow = &v.row(b * seq + t)[off..off + dh];
                    let w = prow[t];
                    for x in 0..dh {
                        crow[x] += w * vrow[x];
                    }
                }
            }
            probs.push(p);
        }
    }
    (ctx, probs)
}

/// Default key-block width of the chunked attention path
/// ([`attention_fwd_chunked`]). Serve forwards process attention scores in
/// blocks of this many key positions, so the per-query transient is
/// `O(ATTN_CHUNK + d_head)` instead of the exact path's `O(S²)` per-head
/// probability matrix.
pub const ATTN_CHUNK: usize = 32;

/// Agreement bound between the chunked online-softmax attention and the
/// exact oracle [`attention_fwd`]:
/// `max|chunked − exact| ≤ ATTN_CHUNK_REL_TOL · max(1, max|exact|)`.
///
/// Both paths evaluate the same mathematical softmax; they differ only in
/// f32 summation order (the chunked path rescales its running accumulator
/// whenever a later block raises the running max, and normalizes once at
/// the end instead of per-probability). The defended bound mirrors
/// [`crate::model::kernels::TILED_REL_TOL`] and is asserted by the
/// property tests below across chunk-straddling shapes.
pub const ATTN_CHUNK_REL_TOL: f32 = 1e-5;

/// Causal multi-head self-attention forward with a chunked **online
/// softmax** — the serve-path variant of [`attention_fwd`].
///
/// Scores for each query row are produced in key blocks of `chunk`
/// positions. Per block the running maximum `m`, running normalizer `z`,
/// and the unnormalized context accumulator are updated; when a block
/// raises `m`, history is rescaled by `exp(m_old − m_new)` (the standard
/// streaming-softmax recurrence). The `[S, S]` probability matrix is never
/// materialized and no probabilities are returned, so this path cannot
/// feed [`attention_bwd`] — training keeps the exact oracle.
///
/// Numerically within [`ATTN_CHUNK_REL_TOL`] of the oracle for any
/// `chunk ≥ 1`; bit-deterministic across thread counts (the loop is
/// sequential per query row and does not parallelize).
pub fn attention_fwd_chunked(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    batch: usize,
    seq: usize,
    n_heads: usize,
    chunk: usize,
) -> Tensor {
    let d = q.cols();
    let dh = d / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let chunk = chunk.max(1);
    let mut ctx = Tensor::zeros(&[batch * seq, d]);
    // One reusable block of scores — the only O(chunk) transient.
    let mut sc = vec![0.0f32; chunk];
    for b in 0..batch {
        for h in 0..n_heads {
            let off = h * dh;
            for s in 0..seq {
                let qrow = &q.row(b * seq + s)[off..off + dh];
                let mut m = f32::NEG_INFINITY; // running max
                let mut z = 0.0f32; // running Σ exp(score − m)
                let mut t0 = 0usize;
                while t0 <= s {
                    let t1 = (t0 + chunk).min(s + 1);
                    let mut block_max = f32::NEG_INFINITY;
                    for t in t0..t1 {
                        let krow = &k.row(b * seq + t)[off..off + dh];
                        let e = crate::tensor::dot(qrow, krow) * scale;
                        sc[t - t0] = e;
                        if e > block_max {
                            block_max = e;
                        }
                    }
                    if block_max > m {
                        // Rescale history to the new max. exp(−inf) = 0
                        // handles the first block (empty history) too.
                        let r = (m - block_max).exp();
                        z *= r;
                        let crow = &mut ctx.row_mut(b * seq + s)[off..off + dh];
                        for x in crow.iter_mut() {
                            *x *= r;
                        }
                        m = block_max;
                    }
                    let crow = &mut ctx.row_mut(b * seq + s)[off..off + dh];
                    for t in t0..t1 {
                        let w = (sc[t - t0] - m).exp();
                        z += w;
                        let vrow = &v.row(b * seq + t)[off..off + dh];
                        for x in 0..dh {
                            crow[x] += w * vrow[x];
                        }
                    }
                    t0 = t1;
                }
                let inv = 1.0 / z;
                let crow = &mut ctx.row_mut(b * seq + s)[off..off + dh];
                for x in crow.iter_mut() {
                    *x *= inv;
                }
            }
        }
    }
    ctx
}

/// Backward of [`attention_fwd`]: given `dctx`, returns `(dq, dk, dv)`.
pub fn attention_bwd(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    probs: &[Tensor],
    dctx: &Tensor,
    batch: usize,
    seq: usize,
    n_heads: usize,
) -> (Tensor, Tensor, Tensor) {
    let d = q.cols();
    let dh = d / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut dq = Tensor::zeros(&[batch * seq, d]);
    let mut dk = Tensor::zeros(&[batch * seq, d]);
    let mut dv = Tensor::zeros(&[batch * seq, d]);
    for b in 0..batch {
        for h in 0..n_heads {
            let off = h * dh;
            let p = &probs[b * n_heads + h];
            for s in 0..seq {
                let prow = p.row(s);
                let dcrow = &dctx.row(b * seq + s)[off..off + dh];
                // dv_t += p[s,t]·dctx_s ; dp[s,t] = dctx_s · v_t
                let mut dp = vec![0.0f32; s + 1];
                for t in 0..=s {
                    let vrow = &v.row(b * seq + t)[off..off + dh];
                    dp[t] = crate::tensor::dot(dcrow, vrow);
                    let dvrow = &mut dv.row_mut(b * seq + t)[off..off + dh];
                    let w = prow[t];
                    for x in 0..dh {
                        dvrow[x] += w * dcrow[x];
                    }
                }
                // softmax backward: ds = p ⊙ (dp − Σ dp⊙p)
                let dot_pp: f32 = (0..=s).map(|t| dp[t] * prow[t]).sum();
                // dq_s += Σ_t ds[s,t]·k_t·scale ; dk_t += ds[s,t]·q_s·scale
                let qrow: Vec<f32> = q.row(b * seq + s)[off..off + dh].to_vec();
                let dqrow = &mut dq.row_mut(b * seq + s)[off..off + dh];
                for t in 0..=s {
                    let ds = prow[t] * (dp[t] - dot_pp) * scale;
                    if ds != 0.0 {
                        let krow = &k.row(b * seq + t)[off..off + dh];
                        for x in 0..dh {
                            dqrow[x] += ds * krow[x];
                        }
                    }
                }
                for t in 0..=s {
                    let ds = prow[t] * (dp[t] - dot_pp) * scale;
                    if ds != 0.0 {
                        let dkrow = &mut dk.row_mut(b * seq + t)[off..off + dh];
                        for x in 0..dh {
                            dkrow[x] += ds * qrow[x];
                        }
                    }
                }
            }
        }
    }
    (dq, dk, dv)
}

/// Softmax cross-entropy over logits `[N, vocab]` with integer targets.
/// `ignore_index` positions contribute nothing. Returns `(mean_nll,
/// dlogits)` where `dlogits` is already scaled by `1/n_valid`.
pub fn cross_entropy(logits: &Tensor, targets: &[i64], ignore_index: i64) -> (f64, Tensor) {
    let (n, v) = (logits.rows(), logits.cols());
    assert_eq!(targets.len(), n);
    let mut dlogits = Tensor::zeros(&[n, v]);
    let mut loss = 0.0f64;
    let n_valid = targets.iter().filter(|&&t| t != ignore_index).count().max(1);
    let inv = 1.0 / n_valid as f32;
    for i in 0..n {
        if targets[i] == ignore_index {
            continue;
        }
        let row = logits.row(i);
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f64;
        for &l in row {
            z += ((l - maxv) as f64).exp();
        }
        let t = targets[i] as usize;
        let logp = (row[t] - maxv) as f64 - z.ln();
        loss -= logp;
        let drow = dlogits.row_mut(i);
        for j in 0..v {
            let p = (((row[j] - maxv) as f64).exp() / z) as f32;
            drow[j] = (p - if j == t { 1.0 } else { 0.0 }) * inv;
        }
    }
    (loss / n_valid as f64, dlogits)
}

/// Per-position NLL values (no gradient) — the PPL protocol (Eq. 24) needs
/// per-batch mean losses.
pub fn nll_per_position(logits: &Tensor, targets: &[i64], ignore_index: i64) -> Vec<f64> {
    let (n, _v) = (logits.rows(), logits.cols());
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        if targets[i] == ignore_index {
            out.push(f64::NAN);
            continue;
        }
        let row = logits.row(i);
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f64;
        for &l in row {
            z += ((l - maxv) as f64).exp();
        }
        let t = targets[i] as usize;
        out.push(-((row[t] - maxv) as f64 - z.ln()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Activation;
    use crate::rng::Pcg64;

    /// Central finite difference of a scalar function of one tensor entry.
    fn fd<F: FnMut(&Tensor) -> f64>(t: &Tensor, idx: usize, mut f: F) -> f64 {
        let eps = 1e-3f32;
        let mut tp = t.clone();
        tp.data_mut()[idx] += eps;
        let mut tm = t.clone();
        tm.data_mut()[idx] -= eps;
        (f(&tp) - f(&tm)) / (2.0 * eps as f64)
    }

    /// Scalar objective: weighted sum of outputs (fixed random weights) so
    /// every output entry matters.
    fn obj_weights(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn weighted_sum(y: &Tensor, w: &[f32]) -> f64 {
        y.data().iter().zip(w).map(|(&a, &b)| (a * b) as f64).sum()
    }

    #[test]
    fn linear_bwd_matches_fd() {
        let mut rng = Pcg64::seeded(101);
        let x = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let w = Tensor::randn(&[5, 4], 1.0, &mut rng);
        let ow = obj_weights(15, 1);
        let dy = Tensor::from_vec(&[3, 5], ow.clone());
        let (dx, dw) = linear_bwd(&x, &w, &dy);
        for idx in [0usize, 5, 11] {
            let g = fd(&x, idx, |xp| weighted_sum(&linear_fwd(xp, &w), &ow));
            assert!((dx.data()[idx] as f64 - g).abs() < 1e-2, "dx[{idx}]");
        }
        for idx in [0usize, 7, 19] {
            let g = fd(&w, idx, |wp| weighted_sum(&linear_fwd(&x, wp), &ow));
            assert!((dw.data()[idx] as f64 - g).abs() < 1e-2, "dw[{idx}]");
        }
    }

    #[test]
    fn layernorm_bwd_matches_fd() {
        let mut rng = Pcg64::seeded(102);
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let gamma = Tensor::randn(&[6], 0.5, &mut rng);
        let beta = Tensor::randn(&[6], 0.5, &mut rng);
        let ow = obj_weights(24, 2);
        let dy = Tensor::from_vec(&[4, 6], ow.clone());
        let (_, means, rstds) = layernorm_fwd(&x, &gamma, &beta);
        let (dx, dgamma, dbeta) = layernorm_bwd(&x, &gamma, &means, &rstds, &dy);
        let run = |xp: &Tensor, gp: &Tensor, bp: &Tensor| {
            weighted_sum(&layernorm_fwd(xp, gp, bp).0, &ow)
        };
        for idx in [0usize, 9, 23] {
            let g = fd(&x, idx, |xp| run(xp, &gamma, &beta));
            assert!((dx.data()[idx] as f64 - g).abs() < 2e-2, "dx[{idx}]");
        }
        for idx in 0..6 {
            let gg = fd(&gamma, idx, |gp| run(&x, gp, &beta));
            assert!((dgamma.data()[idx] as f64 - gg).abs() < 2e-2, "dgamma[{idx}]");
            let gb = fd(&beta, idx, |bp| run(&x, &gamma, bp));
            assert!((dbeta.data()[idx] as f64 - gb).abs() < 2e-2, "dbeta[{idx}]");
        }
    }

    #[test]
    fn gelu_grad_matches_fd() {
        for v in [-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3;
            let g = (gelu(v + eps) - gelu(v - eps)) / (2.0 * eps);
            assert!((gelu_grad(v) - g).abs() < 1e-3, "v={v}");
        }
    }

    #[test]
    fn act_bwd_matches_fd_both_activations() {
        let mut rng = Pcg64::seeded(103);
        let x = Tensor::randn(&[2, 5], 1.0, &mut rng);
        let ow = obj_weights(10, 3);
        let dy = Tensor::from_vec(&[2, 5], ow.clone());
        for act in [Activation::Gelu, Activation::Relu] {
            let dx = act_bwd(&x, &dy, act);
            for idx in [0usize, 4, 9] {
                if act == Activation::Relu && x.data()[idx].abs() < 1e-2 {
                    continue; // kink
                }
                let g = fd(&x, idx, |xp| weighted_sum(&act_fwd(xp, act), &ow));
                assert!(
                    (dx.data()[idx] as f64 - g).abs() < 1e-2,
                    "{act:?} dx[{idx}]"
                );
            }
        }
    }

    #[test]
    fn attention_respects_causality() {
        let mut rng = Pcg64::seeded(104);
        let (b, s, h, d) = (1usize, 4usize, 2usize, 8usize);
        let q = Tensor::randn(&[b * s, d], 1.0, &mut rng);
        let k = Tensor::randn(&[b * s, d], 1.0, &mut rng);
        let v = Tensor::randn(&[b * s, d], 1.0, &mut rng);
        let (ctx, _) = attention_fwd(&q, &k, &v, b, s, h);
        // Changing v at position 3 must not affect ctx at positions 0..2.
        let mut v2 = v.clone();
        for x in v2.row_mut(3) {
            *x += 5.0;
        }
        let (ctx2, _) = attention_fwd(&q, &k, &v2, b, s, h);
        for pos in 0..3 {
            assert_eq!(ctx.row(pos), ctx2.row(pos), "pos {pos}");
        }
        assert_ne!(ctx.row(3), ctx2.row(3));
    }

    /// `max|got − want| ≤ tol · max(1, max|want|)` — the same shape of
    /// bound the tiled qmatmul kernel defends.
    fn assert_rel_close(got: &Tensor, want: &Tensor, tol: f32, label: &str) {
        let ref_mag = want.data().iter().fold(1.0f32, |a, &x| a.max(x.abs()));
        let diff = got.max_abs_diff(want);
        assert!(
            diff <= tol * ref_mag,
            "{label}: max abs diff {diff:e} > {tol:e} · {ref_mag:e}"
        );
    }

    #[test]
    fn chunked_attention_matches_exact_oracle_across_shapes() {
        // Odd sequence lengths and chunk widths that straddle, divide,
        // exceed, and degenerate (chunk = 1) relative to S.
        let mut rng = Pcg64::seeded(108);
        for &s in &[1usize, 2, 3, 5, 7, 8, 9, 16, 17, 31, 33] {
            for &chunk in &[1usize, 2, 3, 4, 8, 16, 64] {
                let (b, h, d) = (2usize, 2usize, 8usize);
                let q = Tensor::randn(&[b * s, d], 1.0, &mut rng);
                let k = Tensor::randn(&[b * s, d], 1.0, &mut rng);
                let v = Tensor::randn(&[b * s, d], 1.0, &mut rng);
                let (exact, _) = attention_fwd(&q, &k, &v, b, s, h);
                let chunked = attention_fwd_chunked(&q, &k, &v, b, s, h, chunk);
                assert_rel_close(
                    &chunked,
                    &exact,
                    ATTN_CHUNK_REL_TOL,
                    &format!("s={s} chunk={chunk}"),
                );
            }
        }
    }

    #[test]
    fn chunked_attention_survives_extreme_score_ranges() {
        // Large-magnitude Q/K stress the running-max rescale: later blocks
        // raise the max by tens of units, so history must be rescaled by
        // exp(large negative) without over/underflow artifacts.
        let mut rng = Pcg64::seeded(109);
        let (b, s, h, d) = (1usize, 17usize, 1usize, 8usize);
        let q = Tensor::randn(&[b * s, d], 6.0, &mut rng);
        let k = Tensor::randn(&[b * s, d], 6.0, &mut rng);
        let v = Tensor::randn(&[b * s, d], 1.0, &mut rng);
        let (exact, _) = attention_fwd(&q, &k, &v, b, s, h);
        for chunk in [1usize, 3, 5, 16] {
            let chunked = attention_fwd_chunked(&q, &k, &v, b, s, h, chunk);
            assert_rel_close(&chunked, &exact, ATTN_CHUNK_REL_TOL, &format!("chunk={chunk}"));
        }
    }

    #[test]
    fn chunked_attention_respects_causality() {
        let mut rng = Pcg64::seeded(110);
        let (b, s, h, d) = (1usize, 5usize, 2usize, 8usize);
        let q = Tensor::randn(&[b * s, d], 1.0, &mut rng);
        let k = Tensor::randn(&[b * s, d], 1.0, &mut rng);
        let v = Tensor::randn(&[b * s, d], 1.0, &mut rng);
        let ctx = attention_fwd_chunked(&q, &k, &v, b, s, h, 2);
        let mut v2 = v.clone();
        for x in v2.row_mut(4) {
            *x += 5.0;
        }
        let ctx2 = attention_fwd_chunked(&q, &k, &v2, b, s, h, 2);
        for pos in 0..4 {
            assert_eq!(ctx.row(pos), ctx2.row(pos), "pos {pos}");
        }
        assert_ne!(ctx.row(4), ctx2.row(4));
    }

    #[test]
    fn attention_bwd_matches_fd() {
        let mut rng = Pcg64::seeded(105);
        let (b, s, h, d) = (2usize, 3usize, 2usize, 4usize);
        let q = Tensor::randn(&[b * s, d], 0.7, &mut rng);
        let k = Tensor::randn(&[b * s, d], 0.7, &mut rng);
        let v = Tensor::randn(&[b * s, d], 0.7, &mut rng);
        let ow = obj_weights(b * s * d, 4);
        let dctx = Tensor::from_vec(&[b * s, d], ow.clone());
        let (_, probs) = attention_fwd(&q, &k, &v, b, s, h);
        let (dq, dk, dv) = attention_bwd(&q, &k, &v, &probs, &dctx, b, s, h);
        let run = |qp: &Tensor, kp: &Tensor, vp: &Tensor| {
            weighted_sum(&attention_fwd(qp, kp, vp, b, s, h).0, &ow)
        };
        for idx in [0usize, 7, 13, 23] {
            let g = fd(&q, idx, |t| run(t, &k, &v));
            assert!((dq.data()[idx] as f64 - g).abs() < 2e-2, "dq[{idx}]");
            let g = fd(&k, idx, |t| run(&q, t, &v));
            assert!((dk.data()[idx] as f64 - g).abs() < 2e-2, "dk[{idx}]");
            let g = fd(&v, idx, |t| run(&q, &k, t));
            assert!((dv.data()[idx] as f64 - g).abs() < 2e-2, "dv[{idx}]");
        }
    }

    #[test]
    fn cross_entropy_grad_matches_fd() {
        let mut rng = Pcg64::seeded(106);
        let logits = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let targets = vec![2i64, 0, -100, 5];
        let (_, dl) = cross_entropy(&logits, &targets, -100);
        for idx in [0usize, 8, 17, 23] {
            let g = fd(&logits, idx, |lp| cross_entropy(lp, &targets, -100).0);
            assert!((dl.data()[idx] as f64 - g).abs() < 1e-3, "dlogits[{idx}]");
        }
        // ignored row has zero grad
        assert!(dl.row(2).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn cross_entropy_of_uniform_is_log_v() {
        let logits = Tensor::zeros(&[3, 8]);
        let (loss, _) = cross_entropy(&logits, &[1, 2, 3], -100);
        assert!((loss - (8f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn nll_per_position_consistent_with_ce() {
        let mut rng = Pcg64::seeded(107);
        let logits = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let targets = vec![0i64, 3, 6, -100, 2];
        let (ce, _) = cross_entropy(&logits, &targets, -100);
        let per = nll_per_position(&logits, &targets, -100);
        let mean: f64 = per.iter().filter(|x| !x.is_nan()).sum::<f64>() / 4.0;
        assert!((ce - mean).abs() < 1e-9);
    }
}
