//! Paged KV cache for streaming autoregressive decode.
//!
//! One decode step must attend its single query row against every cached
//! key/value row written by earlier steps — `O(S)` work — instead of
//! re-running the whole `O(S²)` prefill per token. This module provides
//! the cache that makes that possible on the serve path:
//!
//! * [`KvPool`] — a fixed-capacity pool of cache *pages* shared by every
//!   in-flight sequence of a serve lane. Each page holds
//!   [`PAGE_SLOTS`] key rows and [`PAGE_SLOTS`] value rows of one layer.
//!   Page bytes are booked on the [`MemoryLedger`] under
//!   [`tags::KV_CACHE`] at allocation and released on sequence drop, so
//!   the tag balances to zero after every drain; the live page count is
//!   exported as the `kv.pages` trace counter.
//! * [`KvSeq`] — one sequence's pages across all layers, allocated
//!   worst-case up front (admission either gets every page a request can
//!   ever need or fails immediately — a mid-stream sequence can never hit
//!   pool exhaustion). Dropping the handle returns the pages and the
//!   ledger bytes, which is what keeps the ledger balanced even when a
//!   client disconnects mid-stream.
//! * [`KvSeq::attend_last`] — single-query causal attention over the
//!   cached rows, replaying the *exact* online-softmax recurrence of
//!   [`attention_fwd_chunked`](super::ops::attention_fwd_chunked).
//!
//! ## Bit determinism
//!
//! Page→slot mapping is bit-deterministic: free slot ids live in a
//! [`BTreeSet`] and allocation always pops the lowest ids first, so a
//! fixed sequence of alloc/free calls yields the same slot assignment at
//! any `RPIQ_THREADS` (the set never observes thread scheduling — only
//! call order, which admission serializes per pool lock).
//!
//! Attention is bit-identical to the chunked serve oracle because
//! [`PAGE_SLOTS`] equals [`ATTN_CHUNK`](super::ops::ATTN_CHUNK): page
//! boundaries fall exactly on the chunk boundaries
//! `t0 = 0, C, 2C, …` that `attention_fwd_chunked` uses for a query at
//! the same position, and within a block both paths run the same
//! `dot → block-max → rescale → accumulate` f32 recurrence in the same
//! order. Greedy decode through this cache therefore reproduces the
//! recompute-from-scratch oracle token for token (pinned by the
//! determinism tests below and the parity tests in `model/quantized.rs`).

#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used, clippy::panic))]
#![cfg_attr(not(test), deny(clippy::indexing_slicing))]

use std::collections::BTreeSet;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{bail, ensure, Result};

use crate::metrics::{tags, MemoryLedger};

/// Key (and value) rows per cache page. Equal to
/// [`ATTN_CHUNK`](super::ops::ATTN_CHUNK) **by construction** — the
/// paged attention below recovers the chunked oracle's block boundaries
/// from page boundaries, so the two must never diverge.
pub const PAGE_SLOTS: usize = super::ops::ATTN_CHUNK;

/// Pages needed per layer to hold `tokens` cached positions.
pub const fn pages_per_layer(tokens: usize) -> usize {
    tokens.div_ceil(PAGE_SLOTS)
}

/// Greedy (argmax) token choice over one logits row. `NaN`-safe via
/// `total_cmp`; ties resolve to the highest index, matching the serve
/// lanes' answer extraction so cached and recompute decode agree bitwise.
pub fn greedy_argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Shared fixed-capacity pool of KV-cache pages (cheap `Clone` handle).
///
/// Capacity is a page count chosen at serve start; [`Self::alloc_seq`]
/// either hands a sequence *all* the pages its worst-case length needs or
/// returns `None` (the decode lane then parks the request until pages
/// return). Bytes are booked under [`tags::KV_CACHE`] — see the module
/// docs for the balance/determinism contract.
#[derive(Clone)]
pub struct KvPool {
    inner: Arc<PoolShared>,
}

struct PoolShared {
    state: Mutex<PoolState>,
    ledger: MemoryLedger,
    n_layers: usize,
    d: usize,
    capacity_pages: usize,
}

struct PoolState {
    /// Free slot ids; lowest-first allocation keeps the page→slot mapping
    /// bit-deterministic (see module docs).
    free_slots: BTreeSet<usize>,
}

/// Lock with poison recovery: the state is a free list of slot ids, and a
/// panicking holder's pages are reclaimed by [`KvSeq`]'s `Drop` anyway,
/// so continuing with the inner value is always sound.
fn lock(m: &Mutex<PoolState>) -> MutexGuard<'_, PoolState> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl KvPool {
    /// A pool of `capacity_pages` pages for a model with `n_layers`
    /// transformer layers of width `d`, accounting page bytes on `ledger`.
    pub fn new(n_layers: usize, d: usize, capacity_pages: usize, ledger: MemoryLedger) -> Self {
        Self {
            inner: Arc::new(PoolShared {
                state: Mutex::new(PoolState { free_slots: (0..capacity_pages).collect() }),
                ledger,
                n_layers,
                d,
                capacity_pages,
            }),
        }
    }

    /// Bytes of one page: `2 · PAGE_SLOTS · d` f32s (K rows, then V rows).
    pub fn page_bytes(&self) -> usize {
        2 * PAGE_SLOTS * self.inner.d * std::mem::size_of::<f32>()
    }

    /// Total pages the pool was created with.
    pub fn capacity_pages(&self) -> usize {
        self.inner.capacity_pages
    }

    /// Pages currently free.
    pub fn free_pages(&self) -> usize {
        lock(&self.inner.state).free_slots.len()
    }

    /// Pages a sequence of up to `tokens` cached positions needs across
    /// all layers — the admission-control quantity.
    pub fn pages_for(&self, tokens: usize) -> usize {
        self.inner.n_layers * pages_per_layer(tokens)
    }

    /// Ledger bytes [`Self::alloc_seq`] would book for `tokens` positions.
    pub fn seq_bytes(&self, tokens: usize) -> usize {
        self.pages_for(tokens) * self.page_bytes()
    }

    /// Allocate every page a sequence of up to `capacity_tokens` cached
    /// positions can need, or `None` if the pool cannot supply them all
    /// right now (nothing is booked on failure). Books the page bytes
    /// under [`tags::KV_CACHE`] and updates the `kv.pages` counter.
    pub fn alloc_seq(&self, capacity_tokens: usize) -> Option<KvSeq> {
        let per_layer = pages_per_layer(capacity_tokens);
        let total = per_layer * self.inner.n_layers;
        let slots = {
            let mut g = lock(&self.inner.state);
            if g.free_slots.len() < total {
                return None;
            }
            let mut slots = Vec::with_capacity(total);
            while slots.len() < total {
                match g.free_slots.pop_first() {
                    Some(s) => slots.push(s),
                    None => break, // unreachable: len checked above
                }
            }
            slots
        };
        self.inner.ledger.alloc(tags::KV_CACHE, total * self.page_bytes());
        self.gauge();
        let d = self.inner.d;
        let layers: Vec<Vec<Box<[f32]>>> = (0..self.inner.n_layers)
            .map(|_| {
                (0..per_layer)
                    .map(|_| vec![0.0f32; 2 * PAGE_SLOTS * d].into_boxed_slice())
                    .collect()
            })
            .collect();
        Some(KvSeq { pool: self.clone(), layers, slots, d, len: 0, cap: capacity_tokens })
    }

    /// Return `slots` to the free set and release their ledger bytes —
    /// the [`KvSeq`] `Drop` body.
    fn release(&self, slots: &[usize]) {
        if slots.is_empty() {
            return;
        }
        {
            let mut g = lock(&self.inner.state);
            for &s in slots {
                g.free_slots.insert(s);
            }
        }
        self.inner.ledger.free(tags::KV_CACHE, slots.len() * self.page_bytes());
        self.gauge();
    }

    /// Export live (allocated) pages as the `kv.pages` trace counter.
    fn gauge(&self) {
        if crate::trace::enabled() {
            let free = lock(&self.inner.state).free_slots.len();
            let live = self.inner.capacity_pages.saturating_sub(free);
            crate::trace::counter("kv.pages", live as f64);
        }
    }
}

/// One sequence's cached K/V rows across all layers, backed by pages from
/// a [`KvPool`]. Dropping the handle returns every page and its ledger
/// bytes (abrupt client disconnect included — the decode lane just drops
/// the sequence).
///
/// Layout: `layers[l][p]` covers positions `p·PAGE_SLOTS ..` of layer
/// `l`; within a page the first `PAGE_SLOTS·d` f32s are key rows and the
/// second half value rows.
pub struct KvSeq {
    pool: KvPool,
    layers: Vec<Vec<Box<[f32]>>>,
    /// Pool slot ids backing this sequence, in allocation order
    /// (layer-major) — exposed for the determinism tests.
    slots: Vec<usize>,
    d: usize,
    len: usize,
    cap: usize,
}

impl KvSeq {
    /// Cached positions written and committed so far (via [`Self::advance`]).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True before the prefill has committed any positions.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum positions this sequence's pages can hold.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Transformer layers this cache spans.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Model width `d` of each cached row.
    pub fn width(&self) -> usize {
        self.d
    }

    /// The pool slot ids backing this sequence, in allocation order.
    pub fn slots(&self) -> &[usize] {
        &self.slots
    }

    /// Write the key/value rows of `layer` at position `pos` (which must
    /// be inside capacity; committing it is [`Self::advance`]'s job).
    pub fn write(&mut self, layer: usize, pos: usize, krow: &[f32], vrow: &[f32]) -> Result<()> {
        ensure!(
            krow.len() == self.d && vrow.len() == self.d,
            "kv row width {}/{} != cache width {}",
            krow.len(),
            vrow.len(),
            self.d
        );
        ensure!(pos < self.cap, "position {pos} outside cache capacity {}", self.cap);
        let d = self.d;
        let slot = pos % PAGE_SLOTS;
        let page = self
            .layers
            .get_mut(layer)
            .and_then(|pages| pages.get_mut(pos / PAGE_SLOTS));
        let Some(page) = page else {
            bail!("layer {layer} outside the cache's {} layers", self.layers.len());
        };
        let (khalf, vhalf) = page.split_at_mut(PAGE_SLOTS * d);
        if let Some(dst) = khalf.get_mut(slot * d..(slot + 1) * d) {
            dst.copy_from_slice(krow);
        }
        if let Some(dst) = vhalf.get_mut(slot * d..(slot + 1) * d) {
            dst.copy_from_slice(vrow);
        }
        Ok(())
    }

    /// Commit `n` written positions (prefill commits the whole prompt at
    /// once; each decode step commits one).
    pub fn advance(&mut self, n: usize) -> Result<()> {
        ensure!(
            self.len + n <= self.cap,
            "advance({n}) past cache capacity {} (len {})",
            self.cap,
            self.len
        );
        self.len += n;
        Ok(())
    }

    /// Causal single-query attention for the row at position
    /// [`Self::len`] of `layer` — whose key/value rows must already be
    /// [written](Self::write) — against every cached position `0..=len`.
    /// Returns the `[d]` context row.
    ///
    /// Bit-identical to the context row `attention_fwd_chunked` computes
    /// for query `len` over the same keys/values with
    /// `chunk = PAGE_SLOTS`: page boundaries *are* the chunk boundaries,
    /// and each block runs the identical score → block-max → rescale →
    /// accumulate → normalize f32 recurrence (see module docs).
    pub fn attend_last(&self, layer: usize, n_heads: usize, q: &[f32]) -> Result<Vec<f32>> {
        let d = self.d;
        ensure!(q.len() == d, "query width {} != cache width {d}", q.len());
        ensure!(
            n_heads > 0 && d > 0 && d % n_heads == 0,
            "width {d} not divisible by {n_heads} heads"
        );
        ensure!(self.len < self.cap, "attend_last on a full cache (len {})", self.len);
        let Some(pages) = self.layers.get(layer) else {
            bail!("layer {layer} outside the cache's {} layers", self.layers.len());
        };
        let total = self.len + 1; // cached history + the row being decoded
        let dh = d / n_heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let mut out = vec![0.0f32; d];
        // One reusable block of scores — same shape as the chunked oracle's.
        let mut sc = vec![0.0f32; PAGE_SLOTS];
        for h in 0..n_heads {
            let off = h * dh;
            let qh = q.get(off..off + dh).unwrap_or(&[]);
            let mut m = f32::NEG_INFINITY; // running max
            let mut z = 0.0f32; // running Σ exp(score − m)
            let mut acc = vec![0.0f32; dh];
            let mut t0 = 0usize;
            for page in pages {
                if t0 >= total {
                    break;
                }
                let t1 = (t0 + PAGE_SLOTS).min(total);
                let n = t1 - t0;
                let (khalf, vhalf) = page.split_at(PAGE_SLOTS * d);
                let mut block_max = f32::NEG_INFINITY;
                for (e, krow) in sc.iter_mut().zip(khalf.chunks_exact(d)).take(n) {
                    let kh = krow.get(off..off + dh).unwrap_or(&[]);
                    let s = crate::tensor::dot(qh, kh) * scale;
                    *e = s;
                    if s > block_max {
                        block_max = s;
                    }
                }
                if block_max > m {
                    // Rescale history to the new max (exp(−inf) = 0 covers
                    // the first block) — the streaming-softmax recurrence.
                    let r = (m - block_max).exp();
                    z *= r;
                    for x in acc.iter_mut() {
                        *x *= r;
                    }
                    m = block_max;
                }
                for (e, vrow) in sc.iter().zip(vhalf.chunks_exact(d)).take(n) {
                    let w = (e - m).exp();
                    z += w;
                    let vh = vrow.get(off..off + dh).unwrap_or(&[]);
                    for (a, &vv) in acc.iter_mut().zip(vh.iter()) {
                        *a += w * vv;
                    }
                }
                t0 = t1;
            }
            let inv = 1.0 / z;
            if let Some(oh) = out.get_mut(off..off + dh) {
                for (o, a) in oh.iter_mut().zip(acc.iter()) {
                    *o = a * inv;
                }
            }
        }
        Ok(out)
    }
}

impl Drop for KvSeq {
    fn drop(&mut self) {
        let slots = std::mem::take(&mut self.slots);
        self.layers.clear();
        self.pool.release(&slots);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ops::{attention_fwd_chunked, ATTN_CHUNK};
    use crate::rng::Pcg64;
    use crate::tensor::Tensor;

    fn pool(n_layers: usize, d: usize, pages: usize) -> (KvPool, MemoryLedger) {
        let ledger = MemoryLedger::new();
        (KvPool::new(n_layers, d, pages, ledger.clone()), ledger)
    }

    #[test]
    fn page_arithmetic() {
        assert_eq!(pages_per_layer(0), 0);
        assert_eq!(pages_per_layer(1), 1);
        assert_eq!(pages_per_layer(PAGE_SLOTS), 1);
        assert_eq!(pages_per_layer(PAGE_SLOTS + 1), 2);
        let (p, _) = pool(3, 8, 64);
        assert_eq!(p.pages_for(PAGE_SLOTS + 1), 6);
        assert_eq!(p.page_bytes(), 2 * PAGE_SLOTS * 8 * 4);
        assert_eq!(p.seq_bytes(PAGE_SLOTS), 3 * p.page_bytes());
    }

    #[test]
    fn slot_mapping_is_deterministic_lowest_first() {
        // Page→slot assignment must depend only on the alloc/free call
        // sequence, never on scheduling: lowest free ids first.
        let (p, ledger) = pool(2, 4, 8);
        let a = p.alloc_seq(2 * PAGE_SLOTS).expect("fits"); // 2 pages × 2 layers
        assert_eq!(a.slots(), &[0, 1, 2, 3]);
        let b = p.alloc_seq(PAGE_SLOTS).expect("fits");
        assert_eq!(b.slots(), &[4, 5]);
        assert_eq!(p.free_pages(), 2);
        drop(a);
        assert_eq!(p.free_pages(), 6);
        // Freed ids are reused lowest-first, independent of drop order.
        let c = p.alloc_seq(PAGE_SLOTS + 1).expect("fits");
        assert_eq!(c.slots(), &[0, 1, 2, 3]);
        drop(b);
        drop(c);
        assert_eq!(p.free_pages(), 8);
        assert_eq!(ledger.live_bytes(), 0, "kv_cache tag must balance after drain");
    }

    #[test]
    fn exhaustion_rejects_without_booking() {
        let (p, ledger) = pool(1, 4, 2);
        let a = p.alloc_seq(2 * PAGE_SLOTS).expect("exactly fits");
        assert_eq!(ledger.live_bytes() as usize, 2 * p.page_bytes());
        assert!(p.alloc_seq(1).is_none(), "pool is drained");
        assert_eq!(
            ledger.live_bytes() as usize,
            2 * p.page_bytes(),
            "a failed alloc must book nothing"
        );
        drop(a);
        assert_eq!(p.free_pages(), 2);
        assert_eq!(ledger.live_bytes(), 0);
        assert!(p.alloc_seq(1).is_some(), "pages are reusable after release");
    }

    #[test]
    fn write_and_advance_validate_bounds() {
        let (p, _) = pool(2, 4, 8);
        let mut s = p.alloc_seq(PAGE_SLOTS).expect("fits");
        let row = vec![1.0f32; 4];
        assert!(s.write(0, 0, &row, &row).is_ok());
        assert!(s.write(2, 0, &row, &row).is_err(), "layer out of range");
        assert!(s.write(0, PAGE_SLOTS, &row, &row).is_err(), "pos out of range");
        assert!(s.write(0, 0, &row, &row[..3]).is_err(), "bad row width");
        assert!(s.advance(PAGE_SLOTS).is_ok());
        assert!(s.advance(1).is_err(), "past capacity");
        assert!(s.attend_last(0, 2, &row).is_err(), "full cache has no next row");
    }

    #[test]
    fn paged_attention_matches_chunked_oracle_deterministic() {
        // Straddle several pages so the rescale path is exercised, and
        // require *bit* equality with the chunked serve oracle.
        let (b, heads, d) = (1usize, 2usize, 8usize);
        for &s in &[1usize, 5, PAGE_SLOTS, PAGE_SLOTS + 3, 2 * PAGE_SLOTS + 7] {
            let mut rng = Pcg64::seeded(1201 + s as u64);
            let q = Tensor::randn(&[b * s, d], 1.0, &mut rng);
            let k = Tensor::randn(&[b * s, d], 1.0, &mut rng);
            let v = Tensor::randn(&[b * s, d], 1.0, &mut rng);
            let oracle = attention_fwd_chunked(&q, &k, &v, b, s, heads, ATTN_CHUNK);
            let (p, ledger) = pool(1, d, pages_per_layer(s));
            let mut seq = p.alloc_seq(s).expect("fits");
            for pos in 0..s {
                seq.write(0, pos, k.row(pos), v.row(pos)).expect("in range");
                let got = seq.attend_last(0, heads, q.row(pos)).expect("attend");
                assert_eq!(
                    got,
                    oracle.row(pos),
                    "paged context row {pos} of seq {s} must be bit-identical"
                );
                seq.advance(1).expect("in range");
            }
            drop(seq);
            assert_eq!(ledger.live_bytes(), 0);
        }
    }

    #[test]
    fn greedy_argmax_is_nan_safe_and_last_tie_wins() {
        assert_eq!(greedy_argmax(&[]), 0);
        assert_eq!(greedy_argmax(&[0.5, 2.0, 1.0]), 1);
        assert_eq!(greedy_argmax(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(greedy_argmax(&[3.0, 3.0]), 1, "ties resolve to the last index");
    }
}
