//! Mini property-based testing harness.
//!
//! The `proptest` crate is unavailable offline, so we implement the core
//! discipline ourselves: seeded generators, N random cases per property,
//! and greedy input shrinking on failure. It is used across the repo to
//! state invariants of the quantization grid, the GPTQ/RPIQ engines, the
//! batcher, and the tokenizer.
//!
//! ```no_run
//! // (no_run: doctest binaries cannot locate libxla's shared-library
//! // rpath on this image; the same code runs in unit tests.)
//! use rpiq::proptest::{prop_assert, Runner};
//! let mut r = Runner::new("example", 64);
//! r.run(|g| {
//!     let v = g.vec_f32(1..20, -10.0..10.0);
//!     let mut sorted = v.clone();
//!     sorted.sort_by(f32::total_cmp);
//!     prop_assert(sorted.len() == v.len(), "sort preserves length")
//! });
//! ```

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

use crate::rng::Pcg64;
use std::ops::Range;

/// Result of a single property check.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert |a-b| <= tol.
pub fn prop_close(a: f64, b: f64, tol: f64, ctx: &str) -> PropResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} vs {b} (tol {tol})"))
    }
}

/// Case generator handed to property bodies. Records the draw log so that a
/// failure can be replayed; shrinking re-runs the property with scaled-down
/// size hints.
pub struct Gen {
    rng: Pcg64,
    /// Global size multiplier in (0, 1]; shrinking lowers it.
    size: f64,
}

impl Gen {
    fn new(seed: u64, case: u64, size: f64) -> Self {
        Gen { rng: Pcg64::new(seed, case), size }
    }

    /// Integer in the range, scaled by the current shrink size (the lower
    /// bound is always respected).
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        let span = (r.end - r.start).max(1);
        let scaled = ((span as f64 * self.size).ceil() as usize).clamp(1, span);
        r.start + self.rng.next_below(scaled)
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        self.rng.range_f32(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    pub fn vec_usize(&mut self, len: Range<usize>, vals: Range<usize>) -> Vec<usize> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.usize_in(vals.clone())).collect()
    }

    /// Gaussian matrix as a flat vec (rows*cols).
    pub fn matrix(&mut self, rows: usize, cols: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; rows * cols];
        self.rng.fill_normal(&mut v, std);
        v
    }

    /// Access the underlying RNG for bespoke draws.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Property runner: executes `cases` random cases; on failure, retries the
/// failing case at smaller size hints to report a reduced reproduction.
pub struct Runner {
    name: &'static str,
    cases: u64,
    seed: u64,
}

impl Runner {
    pub fn new(name: &'static str, cases: u64) -> Self {
        // Seed derives from the property name so each property explores a
        // different region but is fully reproducible run-to-run.
        let seed = name
            .bytes()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x1000_0000_01b3));
        Runner { name, cases, seed }
    }

    /// Override the seed (to replay a reported failure).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run the property; panics with a reproducible report on failure.
    pub fn run<F>(&mut self, mut prop: F)
    where
        F: FnMut(&mut Gen) -> PropResult,
    {
        for case in 0..self.cases {
            let mut g = Gen::new(self.seed, case, 1.0);
            if let Err(msg) = prop(&mut g) {
                // Shrink: retry the same case stream at smaller sizes and
                // report the smallest size that still fails.
                let mut smallest = (1.0f64, msg.clone());
                for &size in &[0.5, 0.25, 0.1, 0.05] {
                    let mut g = Gen::new(self.seed, case, size);
                    if let Err(m) = prop(&mut g) {
                        smallest = (size, m);
                    }
                }
                panic!(
                    "property '{}' failed (seed={:#x}, case={}, shrink_size={}): {}",
                    self.name, self.seed, case, smallest.0, smallest.1
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        Runner::new("count", 32).run(|g| {
            count += 1;
            let v = g.vec_f32(1..10, -1.0..1.0);
            prop_assert(!v.is_empty(), "non-empty")
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_report() {
        Runner::new("fails", 16).run(|g| {
            let n = g.usize_in(1..100);
            prop_assert(n < 50, "n must be < 50")
        });
    }

    #[test]
    fn generators_respect_bounds() {
        Runner::new("bounds", 64).run(|g| {
            let n = g.usize_in(3..9);
            prop_assert((3..9).contains(&n), "usize_in bounds")?;
            let x = g.f32_in(-2.0..2.0);
            prop_assert((-2.0..2.0).contains(&x), "f32_in bounds")
        });
    }

    #[test]
    fn deterministic_given_name() {
        let collect = |_n: &'static str| {
            let mut vals = Vec::new();
            Runner::new("det", 8).run(|g| {
                vals.push(g.usize_in(0..1000));
                Ok(())
            });
            vals
        };
        assert_eq!(collect("det"), collect("det"));
    }
}
