//! Word-level tokenizer over the closed synthetic lexicon.
//!
//! Real tokenizers (BPE) are unnecessary here: the corpus generators emit
//! words from a fixed lexicon, so a word-level vocab is lossless and keeps
//! the subject models' embedding tables small. `<unk>` exists for
//! robustness but never appears in generated data (a property test checks
//! this).

use std::collections::HashMap;

/// Fixed special tokens.
pub const UNK: u32 = 0;
pub const BOS: u32 = 1;

/// Word-level tokenizer.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    vocab: Vec<String>,
    map: HashMap<String, u32>,
}

impl Tokenizer {
    /// Build from the union of words (deduplicated, order-preserving).
    pub fn build(words: impl IntoIterator<Item = String>) -> Self {
        let mut vocab = vec!["<unk>".to_string(), "<bos>".to_string()];
        let mut map = HashMap::new();
        map.insert("<unk>".to_string(), UNK);
        map.insert("<bos>".to_string(), BOS);
        for w in words {
            if !map.contains_key(&w) {
                map.insert(w.clone(), vocab.len() as u32);
                vocab.push(w);
            }
        }
        Tokenizer { vocab, map }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Token id of a word (UNK if absent).
    pub fn id(&self, word: &str) -> u32 {
        self.map.get(word).copied().unwrap_or(UNK)
    }

    /// Word of a token id.
    pub fn word(&self, id: u32) -> &str {
        self.vocab.get(id as usize).map(|s| s.as_str()).unwrap_or("<unk>")
    }

    /// Encode whitespace-separated text.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    /// Decode to a space-joined string.
    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.word(i))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// True if every word of `text` is in-vocabulary.
    pub fn covers(&self, text: &str) -> bool {
        text.split_whitespace().all(|w| self.map.contains_key(w))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_dedups_and_roundtrips() {
        let t = Tokenizer::build(
            ["the", "cat", "sat", "the", "cat"].iter().map(|s| s.to_string()),
        );
        assert_eq!(t.vocab_size(), 5); // unk, bos, the, cat, sat
        let ids = t.encode("the cat sat");
        assert_eq!(t.decode(&ids), "the cat sat");
        assert!(ids.iter().all(|&i| i != UNK));
    }

    #[test]
    fn unknown_maps_to_unk() {
        let t = Tokenizer::build(["hello".to_string()]);
        assert_eq!(t.encode("hello world"), vec![2, UNK]);
        assert!(!t.covers("hello world"));
        assert!(t.covers("hello hello"));
    }
}
