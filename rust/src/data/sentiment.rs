//! Synthetic tweet sentiment task — the SemEval-2017 Task 4 stand-in
//! (paper §4.2: 870 test samples, 3 classes, prompt-format evaluation).
//!
//! Tweets are templated around polarity lexicons with mild lexical noise
//! (neutral filler, negation-free to keep the mapping learnable at our
//! model scale). The evaluation prompt mirrors the paper's template,
//! compressed to fit the 48-token training context:
//!
//! `sentiment of text : {tweet} answer : {label}`

use super::tokenizer::Tokenizer;
use crate::rng::Pcg64;

/// The three classes, in the paper's order.
pub const LABELS: [&str; 3] = ["negative", "neutral", "positive"];

/// Every word this generator can emit (fed into the shared lexicon).
pub const SENT_WORDS: [&str; 47] = [
    // template glue
    "sentiment", "text", ":", "answer", "i", "this", "it", "was", "is",
    "really", "so", "very", "my", "felt", "found",
    // positive
    "love", "loved", "amazing", "wonderful", "great", "enjoyed", "perfect",
    "brilliant", "fantastic", "happy",
    // negative
    "hate", "hated", "awful", "terrible", "boring", "broken", "worst",
    "disappointing", "sad", "angry",
    // neutral
    "okay", "fine", "average", "ordinary", "usual", "regular", "plain",
    // objects
    "movie", "phone", "dinner", "game", "book",
    // labels reuse: negative/neutral/positive appear via LABELS
];

/// One labeled example.
#[derive(Clone, Debug, PartialEq)]
pub struct SentimentExample {
    pub text: String,
    /// 0 = negative, 1 = neutral, 2 = positive.
    pub label: usize,
}

impl SentimentExample {
    /// Render the evaluation/training prompt *without* the answer word.
    pub fn prompt(&self) -> String {
        format!("sentiment of text : {} answer :", self.text)
    }

    /// Render the full training string (prompt + gold label).
    pub fn with_answer(&self) -> String {
        format!("{} {}", self.prompt(), LABELS[self.label])
    }
}

/// A generated sentiment dataset.
pub struct SentimentSet {
    pub train: Vec<SentimentExample>,
    pub test: Vec<SentimentExample>,
}

impl SentimentSet {
    /// Paper protocol: 870 test samples. Train size is ours to choose.
    pub fn generate(seed: u64, n_train: usize, n_test: usize) -> Self {
        let mut rng = Pcg64::new(seed, 21);
        let train = (0..n_train).map(|_| Self::example(&mut rng)).collect();
        let mut rng_t = Pcg64::new(seed, 22);
        let test = (0..n_test).map(|_| Self::example(&mut rng_t)).collect();
        SentimentSet { train, test }
    }

    fn adj_for(rng: &mut Pcg64, label: usize) -> &'static str {
        match label {
            0 => *rng.choose(&[
                "awful", "terrible", "boring", "broken", "worst", "disappointing",
            ]),
            1 => *rng.choose(&["okay", "fine", "average", "ordinary", "usual", "plain"]),
            _ => *rng.choose(&[
                "amazing", "wonderful", "great", "perfect", "brilliant", "fantastic",
            ]),
        }
    }

    fn example(rng: &mut Pcg64) -> SentimentExample {
        let label = rng.next_below(3);
        let obj = *rng.choose(&["movie", "phone", "dinner", "game", "book"]);
        let verb = match label {
            0 => *rng.choose(&["hated", "hate"]),
            1 => *rng.choose(&["found", "felt"]),
            _ => *rng.choose(&["loved", "love", "enjoyed"]),
        };
        let intens = *rng.choose(&["really", "so", "very"]);
        // 40% "contrast" examples: two opposing cues joined by "but". The
        // final clause carries the label with probability 0.85, the first
        // clause otherwise — the task has irreducible ambiguity, so model
        // accuracy sits in a sensitive sub-100% band where quantization
        // deltas are visible (paper Table 1 operates at 40–65%, far from
        // saturation; a saturated synthetic task would hide all deltas).
        if rng.chance(0.4) {
            let other = (label + 1 + rng.next_below(2)) % 3;
            let (first, last) = if rng.chance(0.85) {
                (other, label) // final clause wins (majority rule)
            } else {
                (label, other) // exception: first clause carried the label
            };
            let a_first = Self::adj_for(rng, first);
            let a_last = Self::adj_for(rng, last);
            let text = format!("this {obj} was {a_first} but it is {intens} {a_last}");
            return SentimentExample { text, label };
        }
        let adj = Self::adj_for(rng, label);
        let text = match rng.next_below(3) {
            0 => format!("i {verb} this {obj} it was {intens} {adj}"),
            1 => format!("my {obj} is {intens} {adj}"),
            _ => format!("this {obj} was {adj} i {verb} it"),
        };
        SentimentExample { text, label }
    }

    /// Token ids of the three label words — the answer-token candidates
    /// the evaluator compares.
    pub fn label_token_ids(tok: &Tokenizer) -> [u32; 3] {
        [tok.id("negative"), tok.id("neutral"), tok.id("positive")]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Lexicon;

    #[test]
    fn deterministic_and_balanced() {
        let a = SentimentSet::generate(3, 300, 870);
        let b = SentimentSet::generate(3, 300, 870);
        assert_eq!(a.test, b.test);
        assert_eq!(a.test.len(), 870);
        for l in 0..3 {
            let n = a.test.iter().filter(|e| e.label == l).count();
            assert!(n > 200, "class {l} has {n}");
        }
    }

    #[test]
    fn prompts_tokenize_fully() {
        let tok = Lexicon::tokenizer();
        let s = SentimentSet::generate(4, 50, 50);
        for e in s.train.iter().chain(s.test.iter()) {
            assert!(tok.covers(&e.with_answer()), "{}", e.with_answer());
        }
    }

    #[test]
    fn label_tokens_distinct() {
        let tok = Lexicon::tokenizer();
        let ids = SentimentSet::label_token_ids(&tok);
        assert_ne!(ids[0], ids[1]);
        assert_ne!(ids[1], ids[2]);
        assert!(ids.iter().all(|&i| i != super::super::tokenizer::UNK));
    }

    #[test]
    fn prompt_is_prefix_of_answered() {
        let s = SentimentSet::generate(5, 10, 10);
        for e in &s.test {
            assert!(e.with_answer().starts_with(&e.prompt()));
        }
    }
}
