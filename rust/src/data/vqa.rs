//! Synthetic book-cover VQA — the OCR-VQA stand-in (paper §4.2, Table 2).
//!
//! Each "image" is a grid of patches whose float features encode the
//! cover's attributes (genre, author, year) plus category-dependent noise;
//! the VLM has to *read the attributes out of the pixels* to answer, which
//! is the same fine-grained-recognition burden OCR-VQA places on CogVLM2.
//!
//! Five categories mirror the paper's columns (Cookbooks, Medical,
//! History, Reference, Education). Per-category noise levels differ —
//! History covers are the cleanest and Reference the noisiest, matching
//! the paper's observed robustness ordering — so quantization-induced
//! accuracy loss lands unevenly across categories exactly as in Table 2.

use super::tokenizer::Tokenizer;
use crate::rng::Pcg64;
use crate::tensor::Tensor;

/// Table 2's category columns.
pub const CATEGORIES: [&str; 5] =
    ["cookbooks", "medical", "history", "reference", "education"];

/// Per-category patch-noise std (higher = harder to read).
pub const CATEGORY_NOISE: [f32; 5] = [0.35, 0.40, 0.25, 0.55, 0.45];

pub const AUTHORS: [&str; 6] = ["smith", "chen", "garcia", "kumar", "lee", "novak"];
pub const YEARS: [&str; 6] = ["1995", "1999", "2003", "2008", "2012", "2016"];

/// All words this generator can emit.
pub const VQA_WORDS: [&str; 29] = [
    "what", "genre", "who", "wrote", "year", "published", "book", "?",
    "cookbooks", "medical", "history", "reference", "education",
    "smith", "chen", "garcia", "kumar", "lee", "novak",
    "1995", "1999", "2003", "2008", "2012", "2016",
    "this", "was", "the", "cover",
];

/// Question types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QType {
    Genre,
    Author,
    Year,
}

/// A synthetic book cover.
#[derive(Clone, Debug)]
pub struct BookCover {
    /// `[n_patches, patch_dim]` float features.
    pub patches: Tensor,
    pub category: usize,
    pub author: usize,
    pub year: usize,
}

/// One VQA example.
#[derive(Clone, Debug)]
pub struct VqaExample {
    pub cover: BookCover,
    pub qtype: QType,
    /// e.g. `what genre this book ? answer :` — fits the text window.
    pub question: String,
    /// single-word gold answer
    pub answer: String,
    pub category: usize,
}

/// A generated VQA dataset.
pub struct VqaSet {
    pub train: Vec<VqaExample>,
    pub test: Vec<VqaExample>,
    pub n_patches: usize,
    pub patch_dim: usize,
}

impl VqaSet {
    pub fn generate(
        seed: u64,
        n_patches: usize,
        patch_dim: usize,
        n_train: usize,
        n_test_per_category: usize,
    ) -> Self {
        assert!(patch_dim >= 8, "attribute signatures need >= 8 dims");
        let mut rng = Pcg64::new(seed, 31);
        let train = (0..n_train)
            .map(|i| Self::example(&mut rng, n_patches, patch_dim, i % 5))
            .collect();
        let mut rng_t = Pcg64::new(seed, 32);
        let mut test = Vec::new();
        for c in 0..5 {
            for _ in 0..n_test_per_category {
                test.push(Self::example(&mut rng_t, n_patches, patch_dim, c));
            }
        }
        VqaSet { train, test, n_patches, patch_dim }
    }

    fn example(rng: &mut Pcg64, n_patches: usize, patch_dim: usize, category: usize) -> VqaExample {
        let author = rng.next_below(AUTHORS.len());
        let year = rng.next_below(YEARS.len());
        let cover = Self::render(rng, n_patches, patch_dim, category, author, year);
        let qtype = match rng.next_below(3) {
            0 => QType::Genre,
            1 => QType::Author,
            _ => QType::Year,
        };
        let (question, answer) = match qtype {
            QType::Genre => (
                "what genre this book ? answer :".to_string(),
                CATEGORIES[category].to_string(),
            ),
            QType::Author => (
                "who wrote this book ? answer :".to_string(),
                AUTHORS[author].to_string(),
            ),
            QType::Year => (
                "what year was this published ? answer :".to_string(),
                YEARS[year].to_string(),
            ),
        };
        VqaExample { cover, qtype, question, answer, category }
    }

    /// Render attributes into patch features. Signature layout (per patch
    /// row): dims 0..5 category one-hot ·2, dims 5..11 author one-hot ·2
    /// (on patches 2,3), dims 11..17 year one-hot ·2 (on patches 4,5);
    /// remaining patches carry a category-correlated texture. All patches
    /// get N(0, noise(category)) added.
    fn render(
        rng: &mut Pcg64,
        n_patches: usize,
        patch_dim: usize,
        category: usize,
        author: usize,
        year: usize,
    ) -> BookCover {
        let noise = CATEGORY_NOISE[category];
        let mut patches = Tensor::zeros(&[n_patches, patch_dim]);
        for p in 0..n_patches {
            let row = patches.row_mut(p);
            match p {
                0 | 1 => row[category] = 2.0,
                2 | 3 => {
                    if 5 + author < patch_dim {
                        row[5 + author] = 2.0;
                    }
                }
                4 | 5 => {
                    if 11 + year < patch_dim {
                        row[11 + year] = 2.0;
                    }
                }
                _ => {
                    // texture: low-amplitude category-tinted pattern
                    for (j, v) in row.iter_mut().enumerate() {
                        *v = 0.3 * (((j + category * 3) % 5) as f32 - 2.0) / 2.0;
                    }
                }
            }
            for v in row.iter_mut() {
                *v += rng.normal() * noise;
            }
        }
        BookCover { patches, category, author, year }
    }

    /// Candidate answer token ids per question type (the evaluator scores
    /// exact match over the full vocab, but training reporting uses these).
    pub fn answer_space(tok: &Tokenizer, qtype: QType) -> Vec<u32> {
        match qtype {
            QType::Genre => CATEGORIES.iter().map(|w| tok.id(w)).collect(),
            QType::Author => AUTHORS.iter().map(|w| tok.id(w)).collect(),
            QType::Year => YEARS.iter().map(|w| tok.id(w)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Lexicon;

    #[test]
    fn deterministic_and_balanced() {
        let a = VqaSet::generate(1, 8, 24, 100, 20);
        let b = VqaSet::generate(1, 8, 24, 100, 20);
        assert_eq!(a.test.len(), 100);
        for (x, y) in a.test.iter().zip(b.test.iter()) {
            assert_eq!(x.answer, y.answer);
            assert!(x.cover.patches.max_abs_diff(&y.cover.patches) == 0.0);
        }
        for c in 0..5 {
            assert_eq!(a.test.iter().filter(|e| e.category == c).count(), 20);
        }
    }

    #[test]
    fn questions_and_answers_tokenize() {
        let tok = Lexicon::tokenizer();
        let s = VqaSet::generate(2, 8, 24, 30, 5);
        for e in s.train.iter().chain(s.test.iter()) {
            assert!(tok.covers(&e.question), "{}", e.question);
            assert!(tok.covers(&e.answer), "{}", e.answer);
        }
    }

    #[test]
    fn signatures_are_recoverable_without_noise_overwhelm() {
        // The category signature (amplitude 2.0) must dominate the noise
        // on average — otherwise the task is unlearnable.
        let s = VqaSet::generate(3, 8, 24, 0, 40);
        let mut correct = 0;
        for e in &s.test {
            let row = e.cover.patches.row(0);
            let argmax = (0..5)
                .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
                .unwrap();
            if argmax == e.category {
                correct += 1;
            }
        }
        assert!(correct > 150, "only {correct}/200 recoverable");
    }

    #[test]
    fn noise_ordering_matches_design() {
        // reference noisier than history (paper's robustness ordering)
        let hist = CATEGORY_NOISE[2];
        let refr = CATEGORY_NOISE[3];
        assert!(refr > hist);
    }

    #[test]
    fn answer_space_ids_valid() {
        let tok = Lexicon::tokenizer();
        for qt in [QType::Genre, QType::Author, QType::Year] {
            let ids = VqaSet::answer_space(&tok, qt);
            assert!(ids.iter().all(|&i| i != super::super::tokenizer::UNK));
        }
    }
}
