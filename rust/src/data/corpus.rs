//! Synthetic "wiki" corpus — the C4 (calibration) and WikiText-2 (PPL)
//! stand-in.
//!
//! Articles are generated from a small probabilistic grammar with *topic
//! coherence*: each article draws a topic, and its sentences prefer that
//! topic's nouns/verbs. The grammar gives the LM local structure to learn
//! (word order, determiners), the topic gives longer-range structure — so
//! a trained model's PPL sits well below the uniform baseline and
//! quantization-induced degradation is measurable, which is all Table 1
//! needs.

use super::tokenizer::Tokenizer;
use crate::rng::Pcg64;

/// Shared lexicon. The sentiment/VQA generators reference these too so one
/// tokenizer covers everything.
pub struct Lexicon;

impl Lexicon {
    pub const TOPICS: [&'static str; 6] =
        ["science", "music", "history", "cooking", "travel", "sport"];

    pub fn nouns(topic: &str) -> &'static [&'static str] {
        match topic {
            "science" => &["atom", "theory", "experiment", "energy", "cell", "planet"],
            "music" => &["song", "melody", "rhythm", "band", "concert", "album"],
            "history" => &["empire", "war", "treaty", "king", "revolution", "dynasty"],
            "cooking" => &["recipe", "flavor", "ingredient", "dish", "spice", "oven"],
            "travel" => &["journey", "city", "mountain", "harbor", "train", "market"],
            _ => &["match", "team", "player", "goal", "season", "record"],
        }
    }

    pub fn verbs(topic: &str) -> &'static [&'static str] {
        match topic {
            "science" => &["explains", "measures", "reveals", "predicts"],
            "music" => &["plays", "records", "performs", "composes"],
            "history" => &["conquered", "ruled", "signed", "founded"],
            "cooking" => &["bakes", "mixes", "serves", "tastes"],
            "travel" => &["crosses", "visits", "explores", "reaches"],
            _ => &["wins", "scores", "defends", "trains"],
        }
    }

    pub const ADJS: [&'static str; 8] =
        ["old", "new", "great", "small", "famous", "quiet", "bright", "rare"];
    pub const PLACES: [&'static str; 6] =
        ["europe", "asia", "america", "africa", "north", "south"];
    pub const CONNECT: [&'static str; 4] = ["and", "but", "while", "because"];

    /// Every word any generator can emit (for tokenizer construction).
    pub fn all_words() -> Vec<String> {
        let mut v: Vec<String> = Vec::new();
        let mut push = |s: &str| v.push(s.to_string());
        for w in ["the", "a", "in", "of", ".", ","] {
            push(w);
        }
        for t in Self::TOPICS {
            push(t);
            for n in Self::nouns(t) {
                push(n);
            }
            for vb in Self::verbs(t) {
                push(vb);
            }
        }
        for w in Self::ADJS {
            push(w);
        }
        for w in Self::PLACES {
            push(w);
        }
        for w in Self::CONNECT {
            push(w);
        }
        // sentiment lexicon (template words + the three label words)
        for w in super::sentiment::SENT_WORDS {
            push(w);
        }
        for w in super::sentiment::LABELS {
            push(w);
        }
        // vqa lexicon
        for w in super::vqa::VQA_WORDS {
            push(w);
        }
        v
    }

    /// The canonical tokenizer over the full lexicon.
    pub fn tokenizer() -> Tokenizer {
        Tokenizer::build(Self::all_words())
    }
}

/// Generated corpus: token streams for training, calibration, evaluation.
pub struct WikiCorpus {
    pub tokenizer: Tokenizer,
    /// Flat token stream for training batches.
    pub train: Vec<u32>,
    /// Held-out stream for perplexity evaluation.
    pub test: Vec<u32>,
}

impl WikiCorpus {
    /// Generate a corpus of ~`n_train_tokens` + ~`n_test_tokens`.
    pub fn generate(seed: u64, n_train_tokens: usize, n_test_tokens: usize) -> Self {
        let tokenizer = Lexicon::tokenizer();
        let mut rng = Pcg64::new(seed, 11);
        let train = Self::stream(&tokenizer, &mut rng, n_train_tokens);
        let mut rng_test = Pcg64::new(seed, 12);
        let test = Self::stream(&tokenizer, &mut rng_test, n_test_tokens);
        WikiCorpus { tokenizer, train, test }
    }

    fn stream(tok: &Tokenizer, rng: &mut Pcg64, n_tokens: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(n_tokens + 64);
        while out.len() < n_tokens {
            let article = Self::article(rng);
            out.extend(tok.encode(&article));
        }
        out.truncate(n_tokens);
        out
    }

    /// One topic-coherent article of a few sentences.
    pub fn article(rng: &mut Pcg64) -> String {
        let topic = *rng.choose(&Lexicon::TOPICS);
        let nouns = Lexicon::nouns(topic);
        let verbs = Lexicon::verbs(topic);
        let n_sents = 3 + rng.next_below(4);
        let mut s = format!("the {topic} ");
        for _ in 0..n_sents {
            let adj = *rng.choose(&Lexicon::ADJS);
            let n1 = *rng.choose(nouns);
            let v = *rng.choose(verbs);
            let n2 = *rng.choose(nouns);
            let place = *rng.choose(&Lexicon::PLACES);
            s.push_str(&format!("the {adj} {n1} {v} the {n2} in {place} "));
            if rng.chance(0.4) {
                let c = *rng.choose(&Lexicon::CONNECT);
                s.push_str(&format!("{c} "));
            } else {
                s.push_str(". ");
            }
        }
        s
    }

    /// Training batch sampler: `batch` random windows of length `seq`.
    pub fn sample_batch(&self, rng: &mut Pcg64, batch: usize, seq: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = rng.next_below(self.train.len() - seq);
            out.extend_from_slice(&self.train[start..start + seq]);
        }
        out
    }

    /// Calibration set: `n` deterministic windows of length `seq` from the
    /// train stream (the paper's "128 samples from C4, saved as a static
    /// file").
    pub fn calibration(&self, seed: u64, n: usize, seq: usize) -> Vec<Vec<u32>> {
        let mut rng = Pcg64::new(seed, 13);
        (0..n)
            .map(|_| {
                let start = rng.next_below(self.train.len() - seq);
                self.train[start..start + seq].to_vec()
            })
            .collect()
    }

    /// Non-overlapping evaluation windows from the test stream.
    pub fn eval_windows(&self, seq: usize) -> Vec<Vec<u32>> {
        self.test
            .chunks_exact(seq)
            .map(|c| c.to_vec())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{prop_assert, Runner};

    #[test]
    fn corpus_is_deterministic() {
        let a = WikiCorpus::generate(5, 2000, 500);
        let b = WikiCorpus::generate(5, 2000, 500);
        assert_eq!(a.train, b.train);
        assert_eq!(a.test, b.test);
        let c = WikiCorpus::generate(6, 2000, 500);
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn no_unk_in_generated_text() {
        let c = WikiCorpus::generate(7, 5000, 1000);
        assert!(c.train.iter().all(|&t| t != super::super::tokenizer::UNK));
        assert!(c.test.iter().all(|&t| t != super::super::tokenizer::UNK));
    }

    #[test]
    fn calibration_windows_have_right_shape_and_are_static() {
        let c = WikiCorpus::generate(8, 10_000, 1000);
        let cal1 = c.calibration(42, 128, 48);
        let cal2 = c.calibration(42, 128, 48);
        assert_eq!(cal1.len(), 128);
        assert!(cal1.iter().all(|w| w.len() == 48));
        assert_eq!(cal1, cal2);
    }

    #[test]
    fn eval_windows_cover_test_stream() {
        let c = WikiCorpus::generate(9, 2000, 1000);
        let w = c.eval_windows(48);
        assert_eq!(w.len(), 1000 / 48);
    }

    #[test]
    fn articles_always_tokenize_property() {
        let tok = Lexicon::tokenizer();
        Runner::new("article_in_vocab", 64).run(|g| {
            let mut rng = Pcg64::new(g.usize_in(0..100_000) as u64, 3);
            let a = WikiCorpus::article(&mut rng);
            prop_assert(tok.covers(&a), &format!("OOV word in: {a}"))
        });
    }

    #[test]
    fn batch_sampler_shapes() {
        let c = WikiCorpus::generate(10, 4000, 500);
        let mut rng = Pcg64::seeded(1);
        let b = c.sample_batch(&mut rng, 4, 32);
        assert_eq!(b.len(), 128);
        assert!(b.iter().all(|&t| (t as usize) < c.tokenizer.vocab_size()));
    }
}
