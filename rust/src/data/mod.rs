//! Synthetic data substrate — the stand-ins for every dataset the paper
//! uses but which cannot be downloaded offline (rust/DESIGN.md §5 Substitution ledger):
//!
//! * [`tokenizer`] — deterministic word-level tokenizer over the shared
//!   lexicon;
//! * [`corpus`]    — "synthetic wiki" articles (C4/WikiText-2 stand-in):
//!   templated grammar + topic coherence, split into train/calibration/
//!   held-out-PPL;
//! * [`sentiment`] — templated tweets with 3-way labels (SemEval stand-in)
//!   rendered into the paper's prompt format;
//! * [`vqa`]       — synthetic "book covers" over 5 categories with
//!   attribute-encoding patches + question/answer pairs (OCR-VQA
//!   stand-in).
//!
//! Everything is generated from seeded [`crate::rng::Pcg64`] streams, so
//! corpora are bit-identical across runs — the experiment harness depends
//! on that.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

pub mod corpus;
pub mod sentiment;
pub mod tokenizer;
pub mod vqa;

pub use corpus::WikiCorpus;
pub use sentiment::{SentimentExample, SentimentSet, LABELS};
pub use tokenizer::Tokenizer;
pub use vqa::{BookCover, VqaSet, CATEGORIES};
