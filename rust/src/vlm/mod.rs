//! Tiny vision-language model — the CogVLM2-19B stand-in (rust/DESIGN.md §5 Substitution ledger).
//!
//! Three modality modules, mirroring what the paper's CMDQ framework (and
//! its Table 5 rows "CogVLM2-Vision" / "CogVLM2-Cross") distinguishes:
//!
//! * **vision**: linear patch projection + residual MLP blocks over patch
//!   features (`vision.block{i}.fc{1,2}` — ViT-without-attention, enough
//!   to give the vision tower its own quantization-sensitive linears);
//! * **cross-modal**: a per-patch adapter MLP (`cross.vision_mlp.{up,down}`)
//!   mapping vision features into LM embedding space, one LM token per
//!   patch;
//! * **language**: the same decoder-only transformer as `crate::model`,
//!   consuming `[image tokens ; question tokens]`.
//!
//! The VQA head is next-token prediction of a single answer token after
//! the question — exact-match accuracy over answers is then Table 2's
//! metric.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

use crate::model::config::ModelConfig;

pub mod io;
pub mod train;
use crate::model::decode::{self, KvPool, KvSeq};
use crate::model::forward::{ActivationTap, RowSelect};
use crate::model::ops::*;
use crate::model::quantized::LmPlan;
use crate::model::weights::LmWeights;
use crate::model::QuantizedLm;
use crate::quant::{QLinearStore, QuantizedLinear};
use crate::rng::Pcg64;
use crate::tensor::{matmul_at_b, Tensor};
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

/// VLM configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct VlmConfig {
    pub name: String,
    /// Patches per image (= image tokens fed to the LM).
    pub n_patches: usize,
    /// Raw feature dim of one patch ("pixels").
    pub patch_dim: usize,
    /// Vision tower width.
    pub d_vision: usize,
    /// Residual MLP blocks in the vision tower.
    pub n_vision_blocks: usize,
    /// Cross-modal adapter hidden width.
    pub d_cross: usize,
    /// Language decoder config. `seq_len` must cover
    /// `n_patches + question + answer`.
    pub lm: ModelConfig,
}

impl VlmConfig {
    /// The CogVLM2 stand-in used by the Table 2/5 benches.
    pub fn sim_cogvlm2(vocab: usize) -> Self {
        VlmConfig {
            name: "sim-cogvlm2-19b".into(),
            n_patches: 8,
            patch_dim: 24,
            d_vision: 64,
            n_vision_blocks: 2,
            d_cross: 128,
            lm: ModelConfig {
                name: "sim-cogvlm2-19b.lm".into(),
                vocab,
                d_model: 128,
                n_layers: 4,
                n_heads: 4,
                d_ff: 384,
                seq_len: 32,
                activation: crate::model::Activation::Gelu,
                tied_head: false,
            },
        }
    }

    pub fn test_tiny(vocab: usize) -> Self {
        VlmConfig {
            name: "test-vlm".into(),
            n_patches: 4,
            patch_dim: 8,
            d_vision: 12,
            n_vision_blocks: 1,
            d_cross: 16,
            lm: ModelConfig {
                name: "test-vlm.lm".into(),
                vocab,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                seq_len: 16,
                activation: crate::model::Activation::Gelu,
                tied_head: false,
            },
        }
    }

    /// Text positions available after the image prefix.
    pub fn text_len(&self) -> usize {
        self.lm.seq_len - self.n_patches
    }

    /// Total parameter count a [`VlmWeights::init`] of this config holds —
    /// lets deployment surfaces report the fp32 baseline without ever
    /// materializing the fp32 weights (the `--qckpt` cold-start path).
    pub fn n_params(&self) -> usize {
        let dv = self.d_vision;
        let vis = dv * self.patch_dim
            + self.n_vision_blocks * (2 * dv * dv + dv * 2 * dv)
            + self.d_cross * dv
            + self.lm.d_model * self.d_cross;
        vis + self.lm.n_params()
    }

    /// fp32 byte footprint of the full weights (Table 2's "Mem" baseline).
    pub fn fp32_bytes(&self) -> usize {
        self.n_params() * 4
    }

    /// `(out, in)` dims this config implies for a canonical linear name
    /// (vision/cross towers here, LM names delegated) — the
    /// quantized-checkpoint loader's validation source.
    pub fn linear_dims(&self, name: &str) -> Option<(usize, usize)> {
        let dv = self.d_vision;
        match name {
            "vision.patch_proj" => return Some((dv, self.patch_dim)),
            "cross.vision_mlp.up" => return Some((self.d_cross, dv)),
            "cross.vision_mlp.down" => return Some((self.lm.d_model, self.d_cross)),
            _ => {}
        }
        if let Some(rest) = name.strip_prefix("vision.block") {
            let (idx, field) = rest.split_once('.')?;
            if idx.parse::<usize>().ok()? >= self.n_vision_blocks {
                return None;
            }
            return match field {
                "fc1" => Some((2 * dv, dv)),
                "fc2" => Some((dv, 2 * dv)),
                _ => None,
            };
        }
        crate::model::LmWeights::linear_dims(&self.lm, name)
    }
}

/// One residual vision MLP block.
#[derive(Clone, Debug)]
pub struct VisionBlock {
    pub fc1: Tensor,
    pub fc2: Tensor,
}

/// Full VLM parameter set.
#[derive(Clone, Debug)]
pub struct VlmWeights {
    pub config: VlmConfig,
    /// `[d_vision, patch_dim]`
    pub patch_proj: Tensor,
    pub vision_blocks: Vec<VisionBlock>,
    /// `[d_cross, d_vision]`
    pub cross_up: Tensor,
    /// `[d_lm, d_cross]`
    pub cross_down: Tensor,
    pub lm: LmWeights,
}

impl VlmWeights {
    pub fn init(config: &VlmConfig, rng: &mut Pcg64) -> Self {
        let dv = config.d_vision;
        let std = 0.05f32;
        VlmWeights {
            patch_proj: Tensor::randn(&[dv, config.patch_dim], std, rng),
            vision_blocks: (0..config.n_vision_blocks)
                .map(|_| VisionBlock {
                    fc1: Tensor::randn(&[2 * dv, dv], std, rng),
                    fc2: Tensor::randn(&[dv, 2 * dv], std / 2.0, rng),
                })
                .collect(),
            cross_up: Tensor::randn(&[config.d_cross, dv], std, rng),
            cross_down: Tensor::randn(&[config.lm.d_model, config.d_cross], std, rng),
            lm: LmWeights::init(&config.lm, rng),
            config: config.clone(),
        }
    }

    /// All quantizable linears with canonical modality-prefixed names.
    pub fn linears(&self) -> Vec<(String, &Tensor)> {
        let mut v = vec![("vision.patch_proj".to_string(), &self.patch_proj)];
        for (i, b) in self.vision_blocks.iter().enumerate() {
            v.push((format!("vision.block{i}.fc1"), &b.fc1));
            v.push((format!("vision.block{i}.fc2"), &b.fc2));
        }
        v.push(("cross.vision_mlp.up".to_string(), &self.cross_up));
        v.push(("cross.vision_mlp.down".to_string(), &self.cross_down));
        v.extend(self.lm.linears());
        v
    }

    pub fn linear_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        match name {
            "vision.patch_proj" => return Some(&mut self.patch_proj),
            "cross.vision_mlp.up" => return Some(&mut self.cross_up),
            "cross.vision_mlp.down" => return Some(&mut self.cross_down),
            _ => {}
        }
        if let Some(rest) = name.strip_prefix("vision.block") {
            let (idx, field) = rest.split_once('.')?;
            let b = self.vision_blocks.get_mut(idx.parse::<usize>().ok()?)?;
            return match field {
                "fc1" => Some(&mut b.fc1),
                "fc2" => Some(&mut b.fc2),
                _ => None,
            };
        }
        self.lm.linear_mut(name)
    }

    pub fn n_params(&self) -> usize {
        let vis: usize = self.patch_proj.len()
            + self
                .vision_blocks
                .iter()
                .map(|b| b.fc1.len() + b.fc2.len())
                .sum::<usize>()
            + self.cross_up.len()
            + self.cross_down.len();
        vis + self.lm.n_params()
    }
}

/// The deployment skeleton of a VLM: the LM's skeleton plus the VLM
/// config. Every vision/cross tower weight is a linear and therefore
/// lives quantized — the VLM adds *no* fp32 residue of its own beyond the
/// embedded LM's embeddings and norms.
#[derive(Clone, Debug)]
pub struct VlmSkeleton {
    pub config: VlmConfig,
    pub lm: crate::model::LmSkeleton,
}

impl VlmSkeleton {
    /// Extract the skeleton from full training weights (clones only the
    /// LM's non-linear tensors).
    pub fn from_weights(w: &VlmWeights) -> Self {
        VlmSkeleton {
            config: w.config.clone(),
            lm: crate::model::LmSkeleton::from_weights(&w.lm),
        }
    }

    /// All-zero skeleton of the right shapes (checkpoint-load scaffold).
    pub fn zeros(config: &VlmConfig) -> Self {
        VlmSkeleton {
            lm: crate::model::LmSkeleton::zeros(&config.lm),
            config: config.clone(),
        }
    }

    /// Canonical names of the linears this skeleton's model must provide
    /// in quantized form (vision + cross + LM).
    pub fn linear_names(&self) -> Vec<String> {
        let mut v = vec!["vision.patch_proj".to_string()];
        for i in 0..self.config.n_vision_blocks {
            v.push(format!("vision.block{i}.fc1"));
            v.push(format!("vision.block{i}.fc2"));
        }
        v.push("cross.vision_mlp.up".to_string());
        v.push("cross.vision_mlp.down".to_string());
        v.extend(crate::model::LmWeights::linear_names(&self.config.lm));
        v
    }

    /// `(out, in)` dims the config implies for a canonical linear name
    /// (see [`VlmConfig::linear_dims`]).
    pub fn linear_dims(&self, name: &str) -> Option<(usize, usize)> {
        self.config.linear_dims(name)
    }

    /// Resident fp32 bytes (the embedded LM skeleton).
    pub fn nbytes(&self) -> usize {
        self.lm.nbytes()
    }
}

/// Saved intermediates of the vision + cross towers (training).
pub struct VisionRecord {
    pub patches: Tensor,
    pub proj: Tensor,
    pub block_in: Vec<Tensor>,
    pub block_mid_pre: Vec<Tensor>,
    pub block_mid_act: Vec<Tensor>,
    pub feats: Tensor,
    pub cross_pre: Tensor,
    pub cross_act: Tensor,
    pub img_tokens: Tensor,
}

/// Vision tower + cross adapter forward. `patches: [B·P, patch_dim]` →
/// image tokens `[B·P, d_lm]`.
pub fn vision_forward(
    w: &VlmWeights,
    patches: &Tensor,
    mut tap: Option<&mut ActivationTap>,
) -> VisionRecord {
    let gelu_act = crate::model::Activation::Gelu;
    if let Some(t) = tap.as_deref_mut() {
        t.grab_pub("vision.patch_proj", patches);
    }
    let proj = linear_fwd(patches, &w.patch_proj);
    let mut h = proj.clone();
    let mut block_in = Vec::new();
    let mut block_mid_pre = Vec::new();
    let mut block_mid_act = Vec::new();
    for (i, b) in w.vision_blocks.iter().enumerate() {
        block_in.push(h.clone());
        if let Some(t) = tap.as_deref_mut() {
            t.grab_pub(&format!("vision.block{i}.fc1"), &h);
        }
        let mid_pre = linear_fwd(&h, &b.fc1);
        let mid_act = act_fwd(&mid_pre, gelu_act);
        if let Some(t) = tap.as_deref_mut() {
            t.grab_pub(&format!("vision.block{i}.fc2"), &mid_act);
        }
        let out = linear_fwd(&mid_act, &b.fc2);
        h.add_assign(&out);
        block_mid_pre.push(mid_pre);
        block_mid_act.push(mid_act);
    }
    let feats = h.clone();
    if let Some(t) = tap.as_deref_mut() {
        t.grab_pub("cross.vision_mlp.up", &feats);
    }
    let cross_pre = linear_fwd(&feats, &w.cross_up);
    let cross_act = act_fwd(&cross_pre, gelu_act);
    if let Some(t) = tap.as_deref_mut() {
        t.grab_pub("cross.vision_mlp.down", &cross_act);
    }
    let img_tokens = linear_fwd(&cross_act, &w.cross_down);
    VisionRecord {
        patches: patches.clone(),
        proj,
        block_in,
        block_mid_pre,
        block_mid_act,
        feats,
        cross_pre,
        cross_act,
        img_tokens,
    }
}

/// Backward through cross + vision towers given `d_img_tokens`.
/// Returns gradients keyed by canonical names.
pub fn vision_backward(
    w: &VlmWeights,
    rec: &VisionRecord,
    d_img_tokens: &Tensor,
) -> HashMap<String, Tensor> {
    let gelu_act = crate::model::Activation::Gelu;
    let mut grads = HashMap::new();
    let (dcross_act, dw_cd) = linear_bwd(&rec.cross_act, &w.cross_down, d_img_tokens);
    grads.insert("cross.vision_mlp.down".to_string(), dw_cd);
    let dcross_pre = act_bwd(&rec.cross_pre, &dcross_act, gelu_act);
    let (mut dh, dw_cu) = linear_bwd(&rec.feats, &w.cross_up, &dcross_pre);
    grads.insert("cross.vision_mlp.up".to_string(), dw_cu);
    for (i, b) in w.vision_blocks.iter().enumerate().rev() {
        let (dmid_act, dw_fc2) = linear_bwd(&rec.block_mid_act[i], &b.fc2, &dh);
        grads.insert(format!("vision.block{i}.fc2"), dw_fc2);
        let dmid_pre = act_bwd(&rec.block_mid_pre[i], &dmid_act, gelu_act);
        let (dblock_in, dw_fc1) = linear_bwd(&rec.block_in[i], &b.fc1, &dmid_pre);
        grads.insert(format!("vision.block{i}.fc1"), dw_fc1);
        dh.add_assign(&dblock_in); // residual
    }
    let dw_pp = matmul_at_b(&dh, &rec.patches);
    grads.insert("vision.patch_proj".to_string(), dw_pp);
    grads
}

/// Assemble the LM input embeddings: `[img_tokens ; tok_emb(text)+pos]`.
/// `text: [B·T]`, img_tokens `[B·P, d]` → `[B·S, d]`, S = P + T.
pub fn assemble_embeddings(
    w: &VlmWeights,
    img_tokens: &Tensor,
    text: &[u32],
    batch: usize,
) -> Tensor {
    assemble_embeddings_rows(
        &w.lm.tok_emb,
        &w.lm.pos_emb,
        w.config.n_patches,
        w.config.lm.seq_len,
        img_tokens,
        text,
        batch,
    )
}

/// The assembly kernel on bare tensors — shared by the fp path
/// ([`assemble_embeddings`]) and the deployment skeleton's quantized
/// forward, which holds no [`VlmWeights`].
fn assemble_embeddings_rows(
    tok_emb: &Tensor,
    pos_emb: &Tensor,
    n_patches: usize,
    seq_cap: usize,
    img_tokens: &Tensor,
    text: &[u32],
    batch: usize,
) -> Tensor {
    let p = n_patches;
    let t_len = text.len() / batch;
    let s = p + t_len;
    let d = tok_emb.cols();
    assert!(s <= seq_cap);
    let mut x = Tensor::zeros(&[batch * s, d]);
    for b in 0..batch {
        for i in 0..p {
            let src = img_tokens.row(b * p + i);
            let pos = pos_emb.row(i);
            let dst = x.row_mut(b * s + i);
            for j in 0..d {
                dst[j] = src[j] + pos[j];
            }
        }
        for i in 0..t_len {
            let tok = text[b * t_len + i] as usize;
            let te = tok_emb.row(tok);
            let pe = pos_emb.row(p + i);
            let dst = x.row_mut(b * s + p + i);
            for j in 0..d {
                dst[j] = te[j] + pe[j];
            }
        }
    }
    x
}

/// Full VLM inference: patches + text → logits over the combined sequence.
pub fn vlm_forward(
    w: &VlmWeights,
    patches: &Tensor,
    text: &[u32],
    batch: usize,
    tap: Option<&mut ActivationTap>,
) -> Tensor {
    vlm_forward_rows(w, patches, text, batch, tap, RowSelect::Full)
}

/// [`vlm_forward`] with an explicit [`RowSelect`] mode. `Full` is
/// bit-identical to [`vlm_forward`]; `LastRow` returns only each
/// sequence's answer-row logits `[B, V]`.
pub fn vlm_forward_rows(
    w: &VlmWeights,
    patches: &Tensor,
    text: &[u32],
    batch: usize,
    mut tap: Option<&mut ActivationTap>,
    rows: RowSelect,
) -> Tensor {
    let vrec = vision_forward(w, patches, tap.as_deref_mut());
    let x = assemble_embeddings(w, &vrec.img_tokens, text, batch);
    let s = w.config.n_patches + text.len() / batch;
    lm_body_forward(&w.lm, x, batch, s, tap, rows)
}

/// The decoder body on pre-assembled embeddings (shared by fp and
/// quantized paths).
fn lm_body_forward(
    lm: &LmWeights,
    mut x: Tensor,
    batch: usize,
    seq: usize,
    mut tap: Option<&mut ActivationTap>,
    rows: RowSelect,
) -> Tensor {
    let cfg = &lm.config;
    let names = lm.tap_names();
    for (li, l) in lm.layers.iter().enumerate() {
        let names = names.layer(li);
        let (ln1, _, _) = layernorm_fwd(&x, &l.ln1_g, &l.ln1_b);
        if let Some(t) = tap.as_deref_mut() {
            t.grab_pub(&names.attn_q, &ln1);
            t.grab_pub(&names.attn_k, &ln1);
            t.grab_pub(&names.attn_v, &ln1);
        }
        let q = linear_fwd(&ln1, &l.wq);
        let k = linear_fwd(&ln1, &l.wk);
        let v = linear_fwd(&ln1, &l.wv);
        let (ctx, _) = attention_fwd(&q, &k, &v, batch, seq, cfg.n_heads);
        if let Some(t) = tap.as_deref_mut() {
            t.grab_pub(&names.attn_out, &ctx);
        }
        x.add_assign(&linear_fwd(&ctx, &l.wo));
        let (ln2, _, _) = layernorm_fwd(&x, &l.ln2_g, &l.ln2_b);
        if let Some(t) = tap.as_deref_mut() {
            t.grab_pub(&names.mlp_up, &ln2);
        }
        let up = act_fwd(&linear_fwd(&ln2, &l.w_up), cfg.activation);
        if let Some(t) = tap.as_deref_mut() {
            t.grab_pub(&names.mlp_down, &up);
        }
        x.add_assign(&linear_fwd(&up, &l.w_down));
    }
    let x = rows.select(x, batch, seq);
    let (lnf, _, _) = layernorm_fwd(&x, &lm.lnf_g, &lm.lnf_b);
    if let Some(t) = tap.as_deref_mut() {
        if lm.head.is_some() {
            t.grab_pub("lm.head", &lnf);
        }
    }
    linear_fwd(&lnf, lm.head_matrix())
}

/// Shared-shape fused execution over `(patches, question)` pairs, the
/// engine under both [`vlm_forward_batch`] and
/// [`QuantizedVlm::forward_batch`]. Pairs are grouped by question length
/// (the patch grid is fixed by the config, so equal question length ⇒
/// equal combined shape); each group is stacked into one batched forward
/// through the vision tower and text stack. Groups wider than
/// [`crate::model::WIDE_GROUP_ROWS`] pairs are sharded row-wise into
/// chunked fused forwards that fan out across the global pool explicitly.
///
/// All VLM ops are per-row / per-sequence, so each returned `[S_i, V]`
/// logits tensor is **bit-identical** to running its pair alone — the
/// property the serve lane's correctness rests on, asserted by the
/// batch-parity tests.
fn forward_pairs_with(
    pairs: &[(&Tensor, &[u32])],
    n_patches: usize,
    rows: RowSelect,
    f: &(dyn Fn(&Tensor, &[u32], usize) -> Result<Tensor> + Sync),
) -> Result<Vec<Tensor>> {
    for (i, (p, q)) in pairs.iter().enumerate() {
        ensure!(p.rows() == n_patches, "pair {i}: patch grid mismatch");
        ensure!(!q.is_empty(), "pair {i}: empty question");
    }
    crate::model::quantized::run_equal_shape_groups(
        pairs.len(),
        |i| pairs[i].1.len(),
        |chunk| {
            let b = chunk.len();
            let tlen = pairs[chunk[0]].1.len();
            let pd = pairs[chunk[0]].0.cols();
            let mut pdata = Vec::with_capacity(b * n_patches * pd);
            let mut text = Vec::with_capacity(b * tlen);
            for &i in chunk {
                let (p, q) = &pairs[i];
                ensure!(p.cols() == pd, "pair {i}: patch dim mismatch");
                pdata.extend_from_slice(p.data());
                text.extend_from_slice(q);
            }
            let patches = Tensor::from_vec(&[b * n_patches, pd], pdata);
            let logits = f(&patches, &text, b)?;
            let out_per = rows.out_rows(1, n_patches + tlen);
            Ok((0..b)
                .map(|gi| logits.slice_rows(gi * out_per, (gi + 1) * out_per))
                .collect())
        },
    )
}

/// Batched full-precision VLM inference over `(patches, question)` pairs
/// of possibly different question lengths; returns per-pair logits
/// `[n_patches + |question_i|, vocab]`, bit-identical per pair to
/// [`vlm_forward`] on that pair alone. See [`forward_pairs_with`] for the
/// fusion/sharding policy.
pub fn vlm_forward_batch(w: &VlmWeights, pairs: &[(&Tensor, &[u32])]) -> Result<Vec<Tensor>> {
    let f = |p: &Tensor, t: &[u32], b: usize| Ok(vlm_forward(w, p, t, b, None));
    forward_pairs_with(pairs, w.config.n_patches, RowSelect::Full, &f)
}

/// Quantized VLM: vision/cross/lm linears replaced per the CMDQ policy,
/// carried over a [`VlmSkeleton`] — quantizing a VLM releases every fp32
/// linear of all three towers; only the LM's embeddings and norms stay
/// fp32-resident.
pub struct QuantizedVlm {
    pub skeleton: VlmSkeleton,
    pub qlinears: QLinearStore,
    /// name→index resolution for all three towers, computed once at
    /// construction (no name formatting on the forward path).
    plan: VlmPlan,
}

/// The VLM forward path's resolved [`QLinearStore`] addressing: vision
/// tower, cross adapter, and the embedded LM's [`LmPlan`].
#[derive(Clone, Debug)]
struct VlmPlan {
    patch_proj: usize,
    /// `(fc1, fc2)` per vision block.
    vision: Vec<(usize, usize)>,
    cross_up: usize,
    cross_down: usize,
    lm: LmPlan,
}

impl VlmPlan {
    fn resolve(skeleton: &VlmSkeleton, store: &QLinearStore) -> Result<VlmPlan> {
        let need = |name: String| -> Result<usize> {
            match store.index_of(&name) {
                Some(i) => Ok(i),
                None => bail!("missing quantized layer {name}"),
            }
        };
        let mut vision = Vec::with_capacity(skeleton.config.n_vision_blocks);
        for i in 0..skeleton.config.n_vision_blocks {
            vision.push((
                need(format!("vision.block{i}.fc1"))?,
                need(format!("vision.block{i}.fc2"))?,
            ));
        }
        Ok(VlmPlan {
            patch_proj: need("vision.patch_proj".into())?,
            vision,
            cross_up: need("cross.vision_mlp.up".into())?,
            cross_down: need("cross.vision_mlp.down".into())?,
            lm: LmPlan::resolve(&skeleton.lm, store)?,
        })
    }
}

impl QuantizedVlm {
    /// Assemble from a deployment skeleton and per-layer quantized
    /// matrices. Every linear the config declares must be present — a
    /// missing layer is an `Err`, since the loaders feed this from
    /// on-disk containers.
    pub fn new(skeleton: VlmSkeleton, qlinears: HashMap<String, QuantizedLinear>) -> Result<Self> {
        let store = QLinearStore::from_map(qlinears);
        let plan = VlmPlan::resolve(&skeleton, &store)?;
        Ok(QuantizedVlm { skeleton, qlinears: store, plan })
    }

    /// Assemble from full training weights: extracts the skeleton and
    /// *drops* the fp32 linears.
    pub fn from_weights(w: VlmWeights, qlinears: HashMap<String, QuantizedLinear>) -> Result<Self> {
        Self::new(VlmSkeleton::from_weights(&w), qlinears)
    }

    /// The VLM config (lives in the skeleton).
    pub fn config(&self) -> &VlmConfig {
        &self.skeleton.config
    }

    /// Round-to-nearest quantize every linear of `w` onto `grid` — the
    /// calibration-free baseline, and the scaffolding the serve tests and
    /// benches build their models with. Consumes `w`; the fp32 linears die
    /// here.
    pub fn quantize_rtn(w: VlmWeights, grid: crate::quant::QuantGrid) -> Result<Self> {
        let mut qlinears = HashMap::new();
        for (name, t) in w.linears() {
            qlinears.insert(name, QuantizedLinear::quantize_rtn(t, grid));
        }
        Self::from_weights(w, qlinears)
    }

    /// Actual resident deployment bytes: packed levels + group params of
    /// every quantized linear plus the fp32 skeleton (the LM's embeddings
    /// and norms — the vision/cross towers are all-linear and keep no fp32
    /// residue).
    pub fn deploy_bytes(&self) -> usize {
        self.qlinears.nbytes() + self.skeleton.nbytes()
    }

    /// Book this model's resident bytes into `ledger` under
    /// [`crate::model::RESIDENT_TAG`] (see
    /// [`QuantizedLm::register_resident`]).
    pub fn register_resident(&self, ledger: &crate::metrics::MemoryLedger) {
        crate::model::quantized::account_resident(
            ledger,
            &self.qlinears,
            self.skeleton.nbytes(),
            true,
        );
    }

    /// Release the bytes booked by [`Self::register_resident`].
    pub fn release_resident(&self, ledger: &crate::metrics::MemoryLedger) {
        crate::model::quantized::account_resident(
            ledger,
            &self.qlinears,
            self.skeleton.nbytes(),
            false,
        );
    }

    /// Quantized forward (mirrors [`vlm_forward`]); linears addressed
    /// through the resolved [`VlmPlan`].
    pub fn forward(&self, patches: &Tensor, text: &[u32], batch: usize) -> Result<Tensor> {
        self.forward_rows(patches, text, batch, RowSelect::Full)
    }

    /// [`Self::forward`] with an explicit [`RowSelect`] mode. `Full` keeps
    /// the exact attention oracle and full combined-sequence logits
    /// bit-identically; `LastRow` is the VQA serve path — chunked
    /// attention in the decoder and only the answer row through the head,
    /// so logits are `[B, V]`.
    pub fn forward_rows(
        &self,
        patches: &Tensor,
        text: &[u32],
        batch: usize,
        rows: RowSelect,
    ) -> Result<Tensor> {
        let _span =
            crate::trace::span_detail("model", "vlm.forward", || format!("b{batch} {rows:?}"));
        ensure!(batch > 0 && !text.is_empty(), "forward over an empty batch");
        let cfg = &self.skeleton.config;
        let st = &self.qlinears;
        let plan = &self.plan;
        let gelu_act = crate::model::Activation::Gelu;
        let proj = QuantizedLm::qmatmul(patches, st.at(plan.patch_proj))?;
        let mut h = proj;
        for &(fc1, fc2) in &plan.vision {
            let mid = act_fwd(&QuantizedLm::qmatmul(&h, st.at(fc1))?, gelu_act);
            let out = QuantizedLm::qmatmul(&mid, st.at(fc2))?;
            h.add_assign(&out);
        }
        let cross = act_fwd(&QuantizedLm::qmatmul(&h, st.at(plan.cross_up))?, gelu_act);
        let img_tokens = QuantizedLm::qmatmul(&cross, st.at(plan.cross_down))?;
        let lm = &self.skeleton.lm;
        let x = assemble_embeddings_rows(
            &lm.tok_emb,
            &lm.pos_emb,
            cfg.n_patches,
            cfg.lm.seq_len,
            &img_tokens,
            text,
            batch,
        );
        let s = cfg.n_patches + text.len() / batch;
        self.lm_body_rows(x, batch, s, rows)
    }

    /// Batched quantized inference over `(patches, question)` pairs — the
    /// VQA serve lane's entry point. Bit-identical per pair to
    /// [`Self::forward`] on that pair alone; see [`forward_pairs_with`].
    pub fn forward_batch(&self, pairs: &[(&Tensor, &[u32])]) -> Result<Vec<Tensor>> {
        self.forward_batch_rows(pairs, RowSelect::Full)
    }

    /// [`Self::forward_batch`] with an explicit [`RowSelect`] mode — in
    /// `LastRow` mode each returned tensor is `[1, V]`, bit-identical to
    /// the same pair's `forward_rows(…, LastRow)`.
    pub fn forward_batch_rows(
        &self,
        pairs: &[(&Tensor, &[u32])],
        rows: RowSelect,
    ) -> Result<Vec<Tensor>> {
        let f = |p: &Tensor, t: &[u32], b: usize| self.forward_rows(p, t, b, rows);
        forward_pairs_with(pairs, self.skeleton.config.n_patches, rows, &f)
    }

    /// Dominant transient-activation bytes of one fused serve forward of
    /// `batch` pairs with `question_len`-token questions in
    /// [`RowSelect::LastRow`] mode: answer-row logits `[B, V]`, the widest
    /// per-layer activation across the three towers, and the chunked
    /// attention score block — what the VQA lane books against its
    /// `activations.vqa` ledger budget.
    pub fn serve_transient_bytes(&self, batch: usize, question_len: usize) -> usize {
        let cfg = &self.skeleton.config;
        let s = cfg.n_patches + question_len;
        // Vision-tower MLPs widen to 2·d_vision; the LM's d_ff usually
        // dominates, but take the honest max across towers.
        let wide = cfg.lm.d_model.max(cfg.lm.d_ff).max(2 * cfg.d_vision).max(cfg.d_cross);
        (batch * cfg.lm.vocab + batch * s * wide + ATTN_CHUNK) * 4
    }

    fn lm_body_rows(
        &self,
        mut x: Tensor,
        batch: usize,
        seq: usize,
        rows: RowSelect,
    ) -> Result<Tensor> {
        let lm = &self.skeleton.lm;
        let cfg = &lm.config;
        let st = &self.qlinears;
        for (l, p) in lm.layers.iter().zip(self.plan.lm.layers.iter()) {
            let (ln1, _, _) = layernorm_fwd(&x, &l.ln1_g, &l.ln1_b);
            let q = QuantizedLm::qmatmul(&ln1, st.at(p.q))?;
            let k = QuantizedLm::qmatmul(&ln1, st.at(p.k))?;
            let v = QuantizedLm::qmatmul(&ln1, st.at(p.v))?;
            let ctx = match rows {
                RowSelect::Full => attention_fwd(&q, &k, &v, batch, seq, cfg.n_heads).0,
                RowSelect::LastRow => {
                    attention_fwd_chunked(&q, &k, &v, batch, seq, cfg.n_heads, ATTN_CHUNK)
                }
            };
            x.add_assign(&QuantizedLm::qmatmul(&ctx, st.at(p.out))?);
            let (ln2, _, _) = layernorm_fwd(&x, &l.ln2_g, &l.ln2_b);
            let up = act_fwd(&QuantizedLm::qmatmul(&ln2, st.at(p.up))?, cfg.activation);
            x.add_assign(&QuantizedLm::qmatmul(&up, st.at(p.down))?);
        }
        let x = rows.select(x, batch, seq);
        let (lnf, _, _) = layernorm_fwd(&x, &lm.lnf_g, &lm.lnf_b);
        match self.plan.lm.head {
            Some(h) => QuantizedLm::qmatmul(&lnf, st.at(h)),
            // tied head stays fp32 (it is the embedding)
            None => Ok(linear_fwd(&lnf, &lm.tok_emb)),
        }
    }

    /// Validate that `kv` matches this model's decoder geometry and can
    /// still hold `need` more positions (cached positions count image
    /// patches *and* text tokens — the decoder sees one combined
    /// sequence).
    fn check_cache(&self, kv: &KvSeq, need: usize) -> Result<()> {
        let lm = &self.skeleton.lm;
        ensure!(
            kv.n_layers() == lm.layers.len() && kv.width() == lm.config.d_model,
            "kv cache geometry {}x{} does not match model {}x{}",
            kv.n_layers(),
            kv.width(),
            lm.layers.len(),
            lm.config.d_model
        );
        ensure!(
            kv.len() + need <= kv.capacity(),
            "kv cache capacity {} cannot take {need} more positions (len {})",
            kv.capacity(),
            kv.len()
        );
        ensure!(
            kv.len() + need <= lm.config.seq_len,
            "cached positions {} + {need} exceed model context {}",
            kv.len(),
            lm.config.seq_len
        );
        Ok(())
    }

    /// Prefill for streaming VLM decode: run the vision tower and cross
    /// adapter on `patches`, assemble `[image tokens ; question]`
    /// embeddings (absolute positions), and run the decoder body exactly
    /// as [`Self::forward_rows`] in [`RowSelect::LastRow`] mode while
    /// writing every combined-sequence position's per-layer key/value
    /// rows into `kv`. Returns the `[1, V]` logits of the last question
    /// position, bit-identical to
    /// `forward_rows(patches, question, 1, LastRow)`.
    pub fn decode_prefill(
        &self,
        kv: &mut KvSeq,
        patches: &Tensor,
        question: &[u32],
    ) -> Result<Tensor> {
        let _span = crate::trace::span_detail("model", "vlm.prefill", || {
            format!("len {}", question.len())
        });
        let cfg = &self.skeleton.config;
        let st = &self.qlinears;
        let plan = &self.plan;
        ensure!(!question.is_empty(), "prefill over an empty question");
        ensure!(kv.is_empty(), "prefill into a non-empty kv cache (len {})", kv.len());
        ensure!(
            patches.rows() == cfg.n_patches && patches.cols() == cfg.patch_dim,
            "patch grid {}x{} does not match config {}x{}",
            patches.rows(),
            patches.cols(),
            cfg.n_patches,
            cfg.patch_dim
        );
        let seq = cfg.n_patches + question.len();
        self.check_cache(kv, seq)?;
        for &t in question {
            ensure!((t as usize) < cfg.lm.vocab, "token id {t} outside vocab {}", cfg.lm.vocab);
        }
        let gelu_act = crate::model::Activation::Gelu;
        let mut h = QuantizedLm::qmatmul(patches, st.at(plan.patch_proj))?;
        for &(fc1, fc2) in &plan.vision {
            let mid = act_fwd(&QuantizedLm::qmatmul(&h, st.at(fc1))?, gelu_act);
            let out = QuantizedLm::qmatmul(&mid, st.at(fc2))?;
            h.add_assign(&out);
        }
        let cross = act_fwd(&QuantizedLm::qmatmul(&h, st.at(plan.cross_up))?, gelu_act);
        let img_tokens = QuantizedLm::qmatmul(&cross, st.at(plan.cross_down))?;
        let lm = &self.skeleton.lm;
        let mut x = assemble_embeddings_rows(
            &lm.tok_emb,
            &lm.pos_emb,
            cfg.n_patches,
            cfg.lm.seq_len,
            &img_tokens,
            question,
            1,
        );
        for (li, (l, p)) in lm.layers.iter().zip(self.plan.lm.layers.iter()).enumerate() {
            let (ln1, _, _) = layernorm_fwd(&x, &l.ln1_g, &l.ln1_b);
            let q = QuantizedLm::qmatmul(&ln1, st.at(p.q))?;
            let k = QuantizedLm::qmatmul(&ln1, st.at(p.k))?;
            let v = QuantizedLm::qmatmul(&ln1, st.at(p.v))?;
            for pos in 0..seq {
                kv.write(li, pos, k.row(pos), v.row(pos))?;
            }
            let ctx = attention_fwd_chunked(&q, &k, &v, 1, seq, cfg.lm.n_heads, ATTN_CHUNK);
            x.add_assign(&QuantizedLm::qmatmul(&ctx, st.at(p.out))?);
            let (ln2, _, _) = layernorm_fwd(&x, &l.ln2_g, &l.ln2_b);
            let up = act_fwd(&QuantizedLm::qmatmul(&ln2, st.at(p.up))?, cfg.lm.activation);
            x.add_assign(&QuantizedLm::qmatmul(&up, st.at(p.down))?);
        }
        let x = RowSelect::LastRow.select(x, 1, seq);
        let (lnf, _, _) = layernorm_fwd(&x, &lm.lnf_g, &lm.lnf_b);
        let logits = match self.plan.lm.head {
            Some(hd) => QuantizedLm::qmatmul(&lnf, st.at(hd))?,
            None => linear_fwd(&lnf, &lm.tok_emb),
        };
        kv.advance(seq)?;
        Ok(logits)
    }

    /// One streaming VLM decode step: embed `token` at the next absolute
    /// combined-sequence position (image patches count — text token `i`
    /// of the assembled sequence sits at position `n_patches + i`, which
    /// is exactly [`KvSeq::len`]), run a `[1, d]` decoder forward whose
    /// attention reads the paged cache, and return the `[1, V]` logits.
    /// Bit-identical to re-running the full forward on the grown question
    /// — see [`crate::model::decode`] for the argument.
    pub fn decode_step(&self, kv: &mut KvSeq, token: u32) -> Result<Tensor> {
        let lm = &self.skeleton.lm;
        let cfg = &lm.config;
        let st = &self.qlinears;
        let pos = kv.len();
        let _span = crate::trace::span_detail("model", "vlm.decode_step", || format!("pos {pos}"));
        ensure!(pos > 0, "decode_step before prefill");
        self.check_cache(kv, 1)?;
        ensure!((token as usize) < cfg.vocab, "token id {token} outside vocab {}", cfg.vocab);
        let d = cfg.d_model;
        // Same arithmetic as `assemble_embeddings_rows` for one text row.
        let mut e = vec![0.0f32; d];
        let te = lm.tok_emb.row(token as usize);
        let pe = lm.pos_emb.row(pos);
        for ((o, &a), &b) in e.iter_mut().zip(te.iter()).zip(pe.iter()) {
            *o = a + b;
        }
        let mut x = Tensor::from_vec(&[1, d], e);
        for (li, (l, p)) in lm.layers.iter().zip(self.plan.lm.layers.iter()).enumerate() {
            let (ln1, _, _) = layernorm_fwd(&x, &l.ln1_g, &l.ln1_b);
            let q = QuantizedLm::qmatmul(&ln1, st.at(p.q))?;
            let k = QuantizedLm::qmatmul(&ln1, st.at(p.k))?;
            let v = QuantizedLm::qmatmul(&ln1, st.at(p.v))?;
            kv.write(li, pos, k.row(0), v.row(0))?;
            let ctx = Tensor::from_vec(&[1, d], kv.attend_last(li, cfg.n_heads, q.row(0))?);
            x.add_assign(&QuantizedLm::qmatmul(&ctx, st.at(p.out))?);
            let (ln2, _, _) = layernorm_fwd(&x, &l.ln2_g, &l.ln2_b);
            let up = act_fwd(&QuantizedLm::qmatmul(&ln2, st.at(p.up))?, cfg.activation);
            x.add_assign(&QuantizedLm::qmatmul(&up, st.at(p.down))?);
        }
        let (lnf, _, _) = layernorm_fwd(&x, &lm.lnf_g, &lm.lnf_b);
        let logits = match self.plan.lm.head {
            Some(hd) => QuantizedLm::qmatmul(&lnf, st.at(hd))?,
            None => linear_fwd(&lnf, &lm.tok_emb),
        };
        kv.advance(1)?;
        Ok(logits)
    }

    /// Greedy streaming generation for one `(patches, question)` pair
    /// through a paged KV cache — the VLM counterpart of
    /// [`QuantizedLm::generate`], bit-identical to
    /// [`Self::generate_recompute`]. Context bound:
    /// `n_patches + question + max_new ≤ lm.seq_len + 1`.
    pub fn generate(
        &self,
        pool: &KvPool,
        patches: &Tensor,
        question: &[u32],
        max_new: usize,
        eos: Option<u32>,
    ) -> Result<Vec<u32>> {
        ensure!(max_new > 0, "generate of zero tokens");
        let cfg = &self.skeleton.config;
        let s0 = cfg.n_patches + question.len();
        ensure!(
            s0 + max_new <= cfg.lm.seq_len + 1,
            "patches {} + question {} + max_new {max_new} exceeds context {}",
            cfg.n_patches,
            question.len(),
            cfg.lm.seq_len
        );
        let cap_tokens = s0 + max_new - 1;
        let Some(mut kv) = pool.alloc_seq(cap_tokens) else {
            bail!(
                "kv pool exhausted: {} of {} pages free, need {}",
                pool.free_pages(),
                pool.capacity_pages(),
                pool.pages_for(cap_tokens)
            );
        };
        let logits = self.decode_prefill(&mut kv, patches, question)?;
        let mut next = decode::greedy_argmax(logits.row(0)) as u32;
        let mut out = vec![next];
        while out.len() < max_new && Some(next) != eos {
            let logits = self.decode_step(&mut kv, next)?;
            next = decode::greedy_argmax(logits.row(0)) as u32;
            out.push(next);
        }
        Ok(out)
    }

    /// The recompute-from-scratch VLM greedy decode oracle: every step
    /// re-runs [`Self::forward_rows`] (vision tower included) over the
    /// grown question — the reference [`Self::generate`] must match
    /// bitwise.
    pub fn generate_recompute(
        &self,
        patches: &Tensor,
        question: &[u32],
        max_new: usize,
        eos: Option<u32>,
    ) -> Result<Vec<u32>> {
        ensure!(max_new > 0, "generate of zero tokens");
        ensure!(!question.is_empty(), "prefill over an empty question");
        let cfg = &self.skeleton.config;
        ensure!(
            cfg.n_patches + question.len() + max_new <= cfg.lm.seq_len + 1,
            "patches {} + question {} + max_new {max_new} exceeds context {}",
            cfg.n_patches,
            question.len(),
            cfg.lm.seq_len
        );
        let mut text = question.to_vec();
        let mut out = Vec::with_capacity(max_new);
        loop {
            let logits = self.forward_rows(patches, &text, 1, RowSelect::LastRow)?;
            let next = decode::greedy_argmax(logits.row(0)) as u32;
            out.push(next);
            if out.len() >= max_new || Some(next) == eos {
                break;
            }
            text.push(next);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantGrid;

    fn tiny() -> (VlmWeights, Tensor, Vec<u32>, usize) {
        let cfg = VlmConfig::test_tiny(24);
        let mut rng = Pcg64::seeded(601);
        let w = VlmWeights::init(&cfg, &mut rng);
        let batch = 2;
        let patches = Tensor::randn(&[batch * cfg.n_patches, cfg.patch_dim], 1.0, &mut rng);
        let text: Vec<u32> = (0..batch * 6).map(|_| rng.next_below(24) as u32).collect();
        (w, patches, text, batch)
    }

    #[test]
    fn forward_shapes() {
        let (w, patches, text, batch) = tiny();
        let logits = vlm_forward(&w, &patches, &text, batch, None);
        let s = w.config.n_patches + 6;
        assert_eq!(logits.shape(), &[batch * s, 24]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn linears_have_all_modalities() {
        let (w, _, _, _) = tiny();
        let names: Vec<String> = w.linears().into_iter().map(|(n, _)| n).collect();
        use crate::quant::Modality;
        let count = |m: Modality| {
            names.iter().filter(|n| Modality::of_layer(n) == m).count()
        };
        assert_eq!(count(Modality::Vision), 3); // patch_proj + 1 block ×2
        assert_eq!(count(Modality::CrossModal), 2);
        assert!(count(Modality::Language) >= 12);
    }

    #[test]
    fn tap_captures_vision_and_cross() {
        let (w, patches, text, batch) = tiny();
        let mut tap = ActivationTap::new();
        let _ = vlm_forward(&w, &patches, &text, batch, Some(&mut tap));
        assert!(tap.inputs.contains_key("vision.block0.fc1"));
        assert!(tap.inputs.contains_key("cross.vision_mlp.down"));
        assert!(tap.inputs.contains_key("lm.layer1.mlp.up"));
        // vision activations are [B·P, d_vision]
        assert_eq!(tap.inputs["vision.block0.fc1"].shape(), &[8, 12]);
    }

    #[test]
    fn vision_backward_matches_fd() {
        let (w, patches, _, _) = tiny();
        let mut rng = Pcg64::seeded(602);
        let n_out = patches.rows() * w.config.lm.d_model;
        let ow: Vec<f32> = (0..n_out).map(|_| rng.normal()).collect();
        let obj = |wp: &VlmWeights| {
            let rec = vision_forward(wp, &patches, None);
            rec.img_tokens
                .data()
                .iter()
                .zip(&ow)
                .map(|(&a, &b)| (a * b) as f64)
                .sum::<f64>()
        };
        let rec = vision_forward(&w, &patches, None);
        let d_img = Tensor::from_vec(&[patches.rows(), w.config.lm.d_model], ow.clone());
        let grads = vision_backward(&w, &rec, &d_img);
        for (name, idx) in [
            ("vision.patch_proj", 5usize),
            ("vision.block0.fc1", 17),
            ("vision.block0.fc2", 3),
            ("cross.vision_mlp.up", 21),
            ("cross.vision_mlp.down", 8),
        ] {
            let eps = 1e-2f32;
            let mut wp = w.clone();
            wp.linear_mut(name).unwrap().data_mut()[idx] += eps;
            let lp = obj(&wp);
            let mut wm = w.clone();
            wm.linear_mut(name).unwrap().data_mut()[idx] -= eps;
            let lm_ = obj(&wm);
            let fd = (lp - lm_) / (2.0 * eps as f64);
            let an = grads[name].data()[idx] as f64;
            assert!(
                (fd - an).abs() < 1e-3 + 0.05 * fd.abs().max(an.abs()),
                "{name}[{idx}]: fd={fd} an={an}"
            );
        }
    }

    /// Mixed-length pair set: several question lengths, one of them wide
    /// enough (> WIDE_GROUP_ROWS pairs) to force the explicit row-wise
    /// pool sharding of large equal-shape groups.
    fn mixed_pairs(
        cfg: &VlmConfig,
        rng: &mut Pcg64,
    ) -> Vec<(Tensor, Vec<u32>)> {
        let mut pairs = Vec::new();
        let widths: Vec<usize> = [3usize, 6, 3, 5]
            .into_iter()
            .chain(std::iter::repeat_n(6, crate::model::WIDE_GROUP_ROWS + 4))
            .collect();
        for t_len in widths {
            let patches = Tensor::randn(&[cfg.n_patches, cfg.patch_dim], 1.0, rng);
            let q: Vec<u32> = (0..t_len).map(|_| rng.next_below(24) as u32).collect();
            pairs.push((patches, q));
        }
        pairs
    }

    #[test]
    fn vlm_forward_batch_bit_identical_to_looped_single() {
        let (w, _, _, _) = tiny();
        let mut rng = Pcg64::seeded(611);
        let owned = mixed_pairs(&w.config, &mut rng);
        let pairs: Vec<(&Tensor, &[u32])> =
            owned.iter().map(|(p, q)| (p, q.as_slice())).collect();
        let batched = vlm_forward_batch(&w, &pairs).expect("batch forward");
        assert_eq!(batched.len(), pairs.len());
        for ((p, q), b) in pairs.iter().zip(&batched) {
            let single = vlm_forward(&w, p, q, 1, None);
            assert_eq!(b.shape(), single.shape());
            assert_eq!(b.data(), single.data(), "t_len={}", q.len());
        }
    }

    #[test]
    fn quantized_vlm_forward_batch_bit_identical_to_looped_single() {
        let _kernel = crate::model::kernels::kernel_test_lock(); // fixed kernel across compares
        let (w, _, _, _) = tiny();
        let qvlm = QuantizedVlm::quantize_rtn(w.clone(), QuantGrid::new(4, 8)).expect("complete");
        let mut rng = Pcg64::seeded(612);
        let owned = mixed_pairs(&w.config, &mut rng);
        let pairs: Vec<(&Tensor, &[u32])> =
            owned.iter().map(|(p, q)| (p, q.as_slice())).collect();
        let batched = qvlm.forward_batch(&pairs).expect("batch forward");
        for ((p, q), b) in pairs.iter().zip(&batched) {
            let single = qvlm.forward(p, q, 1).expect("forward");
            assert_eq!(b.data(), single.data(), "t_len={}", q.len());
        }
    }

    #[test]
    fn fp_last_row_bit_identical_to_full_last_rows() {
        // The fp path keeps exact attention in both modes, so LastRow is
        // pure row selection — bit-identical to the full forward's final
        // positions.
        let (w, patches, text, batch) = tiny();
        let full = vlm_forward(&w, &patches, &text, batch, None);
        let last = vlm_forward_rows(&w, &patches, &text, batch, None, RowSelect::LastRow);
        let s = w.config.n_patches + text.len() / batch;
        assert_eq!(last.shape(), &[batch, 24]);
        for b in 0..batch {
            assert_eq!(last.row(b), full.row(b * s + s - 1), "seq {b}");
        }
    }

    #[test]
    fn quantized_last_row_batch_parity_and_tolerance_vs_full() {
        let _kernel = crate::model::kernels::kernel_test_lock(); // fixed kernel across compares
        let (w, _, _, _) = tiny();
        let qvlm = QuantizedVlm::quantize_rtn(w.clone(), QuantGrid::new(4, 8)).expect("complete");
        let mut rng = Pcg64::seeded(614);
        let owned = mixed_pairs(&w.config, &mut rng);
        let pairs: Vec<(&Tensor, &[u32])> =
            owned.iter().map(|(p, q)| (p, q.as_slice())).collect();
        // Batch parity: the fused LastRow forward is the same code path as
        // the single-pair LastRow forward — bit-identical.
        let batched = qvlm.forward_batch_rows(&pairs, RowSelect::LastRow).expect("batch");
        for ((p, q), b) in pairs.iter().zip(&batched) {
            let single = qvlm.forward_rows(p, q, 1, RowSelect::LastRow).expect("forward");
            assert_eq!(b.shape(), &[1, 24]);
            assert_eq!(b.data(), single.data(), "t_len={}", q.len());
        }
        // Tolerance vs the exact full-logits oracle: LastRow swaps in the
        // chunked online softmax, whose per-layer deviation is bounded by
        // ATTN_CHUNK_REL_TOL; allow compounding across the two blocks.
        for ((p, q), b) in pairs.iter().zip(&batched) {
            let full = qvlm.forward(p, q, 1).expect("forward");
            let s = w.config.n_patches + q.len();
            let want = full.row(s - 1);
            let mag = want.iter().fold(1.0f32, |a, &x| a.max(x.abs()));
            let diff = b
                .row(0)
                .iter()
                .zip(want)
                .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()));
            assert!(diff <= 1e-4 * mag, "t_len={}: diff={diff:e} mag={mag:e}", q.len());
        }
    }

    #[test]
    fn quantized_vlm_rejects_mismatched_patch_grid() {
        let (w, _, _, _) = tiny();
        let qvlm = QuantizedVlm::quantize_rtn(w.clone(), QuantGrid::new(4, 8)).expect("complete");
        let mut rng = Pcg64::seeded(613);
        // wrong number of patch rows for the config's grid
        let bad = Tensor::randn(&[w.config.n_patches + 1, w.config.patch_dim], 1.0, &mut rng);
        let q: Vec<u32> = vec![1, 2, 3];
        let pairs: Vec<(&Tensor, &[u32])> = vec![(&bad, q.as_slice())];
        let err = qvlm.forward_batch(&pairs).expect_err("grid mismatch");
        assert!(err.to_string().contains("patch grid mismatch"), "{err}");
    }

    #[test]
    fn quantized_vlm_8bit_close_to_fp() {
        let (w, patches, text, batch) = tiny();
        let qvlm = QuantizedVlm::quantize_rtn(w.clone(), QuantGrid::new(8, 8)).expect("complete");
        let fp = vlm_forward(&w, &patches, &text, batch, None);
        let qf = qvlm.forward(&patches, &text, batch).expect("forward");
        let rel = qf.sub(&fp).frob() / fp.frob().max(1e-9);
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn deploy_bytes_compresses() {
        let (w, _, _, _) = tiny();
        let fp_bytes = w.n_params() * 4;
        assert_eq!(fp_bytes, w.config.fp32_bytes(), "config-derived count matches weights");
        let qvlm = QuantizedVlm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete");
        assert!(qvlm.deploy_bytes() < fp_bytes);
    }

    #[test]
    fn quantized_vlm_qckpt_roundtrip_bit_identical() {
        // save_qvlm → load_qvlm restores packed levels, params, and the
        // skeleton exactly; forwards are bit-identical.
        let _kernel = crate::model::kernels::kernel_test_lock(); // fixed kernel across compares
        let (w, patches, text, batch) = tiny();
        let qvlm = QuantizedVlm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete");
        let dir = std::env::temp_dir().join("rpiq_qvlm_io");
        let path = dir.join("v.rpiq");
        crate::vlm::io::save_qvlm(&qvlm, &path).unwrap();
        let loaded = crate::vlm::io::load_qvlm(&path).unwrap();
        assert_eq!(loaded.skeleton.config, qvlm.skeleton.config);
        for (name, q) in qvlm.qlinears.iter() {
            let l = loaded.qlinears.get(name).expect("layer present after roundtrip");
            assert_eq!(q.packed, l.packed, "{name}");
            assert_eq!(q.scales, l.scales, "{name}");
            assert_eq!(q.zeros, l.zeros, "{name}");
        }
        assert_eq!(loaded.deploy_bytes(), qvlm.deploy_bytes());
        let a = qvlm.forward(&patches, &text, batch).expect("forward");
        let b = loaded.forward(&patches, &text, batch).expect("forward");
        assert_eq!(a.data(), b.data(), "loaded forward must be bit-identical");
        // the fp32 VLM loader must reject the quantized container
        assert!(crate::vlm::io::load_vlm(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn vlm_paged_decode_bit_identical_to_recompute_oracle_deterministic() {
        // VLM arm of the decode contract, run by the CI determinism
        // matrix at RPIQ_THREADS=1/2/8: greedy generation through the
        // paged KV cache (image patches cached alongside text) matches
        // the recompute oracle token for token, and the kv_cache ledger
        // tag drains to zero.
        let _threads = crate::exec::thread_target_test_lock();
        let _kernel = crate::model::kernels::kernel_test_lock();
        let before = crate::exec::num_threads();
        let (w, patches, text, _) = tiny();
        let cfg = w.config.clone();
        let qvlm = QuantizedVlm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete");
        // one pair: the first image's patches, a 4-token question
        let one = patches.slice_rows(0, cfg.n_patches);
        let question = &text[..4];
        let max_new = cfg.lm.seq_len + 1 - cfg.n_patches - question.len();
        let oracle = qvlm
            .generate_recompute(&one, question, max_new, None)
            .expect("oracle decode");
        assert_eq!(oracle.len(), max_new);
        for threads in [1usize, 2, 8] {
            crate::exec::set_threads(threads);
            let ledger = crate::metrics::MemoryLedger::new();
            let pool =
                KvPool::new(cfg.lm.n_layers, cfg.lm.d_model, 8, ledger.clone());
            let cached = qvlm
                .generate(&pool, &one, question, max_new, None)
                .expect("cached decode");
            assert_eq!(cached, oracle, "threads={threads}");
            assert_eq!(ledger.live_bytes(), 0, "kv_cache must drain (threads={threads})");
            assert_eq!(pool.free_pages(), 8, "all pages returned (threads={threads})");
        }
        crate::exec::set_threads(before);
    }

    #[test]
    fn vlm_decode_prefill_matches_last_row_forward_bitwise() {
        let _kernel = crate::model::kernels::kernel_test_lock();
        let (w, patches, text, _) = tiny();
        let cfg = w.config.clone();
        let qvlm = QuantizedVlm::quantize_rtn(w, QuantGrid::new(4, 8)).expect("complete");
        let one = patches.slice_rows(0, cfg.n_patches);
        let question = &text[..4];
        let pool = KvPool::new(
            cfg.lm.n_layers,
            cfg.lm.d_model,
            8,
            crate::metrics::MemoryLedger::new(),
        );
        let mut kv = pool.alloc_seq(cfg.lm.seq_len).expect("fits");
        let prefill = qvlm.decode_prefill(&mut kv, &one, question).expect("prefill");
        let oracle = qvlm
            .forward_rows(&one, question, 1, RowSelect::LastRow)
            .expect("forward");
        assert_eq!(prefill.data(), oracle.data());
        assert_eq!(kv.len(), cfg.n_patches + question.len(), "patches are cached too");
    }
}
