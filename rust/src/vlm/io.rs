//! VLM checkpoint containers (same binary layouts as the LM ones,
//! different magics): the fp32 container carries vision/cross tensors plus
//! the embedded LM tensor set; the quantized `.rpiq` container carries
//! nibble-packed linears for all three towers plus the LM skeleton.

// Loader module: untrusted bytes in, clean `Err` out. The repo lint
// (`rpiq-lint`, rule `no-panic`) and these clippy denies enforce it.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used, clippy::panic))]
#![cfg_attr(not(test), deny(clippy::indexing_slicing))]

use super::{QuantizedVlm, VlmConfig, VlmSkeleton, VlmWeights};
use crate::jsonx::Json;
use crate::model::io::{lm_config_from_json, lm_config_to_json, read_container, write_container};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;

const MAGIC: &[u8; 8] = b"RPIQVLM1";
/// Magic of the quantized-VLM container.
pub const QVLM_MAGIC: &[u8; 8] = b"RPIQQVL1";

fn config_to_json(c: &VlmConfig) -> Json {
    Json::obj()
        .with("name", Json::Str(c.name.clone()))
        .with("n_patches", Json::Num(c.n_patches as f64))
        .with("patch_dim", Json::Num(c.patch_dim as f64))
        .with("d_vision", Json::Num(c.d_vision as f64))
        .with("n_vision_blocks", Json::Num(c.n_vision_blocks as f64))
        .with("d_cross", Json::Num(c.d_cross as f64))
        .with("lm", lm_config_to_json(&c.lm))
}

fn config_from_json(j: &Json) -> Result<VlmConfig> {
    let get = |k: &str| j.get(k).with_context(|| format!("vlm config missing '{k}'"));
    Ok(VlmConfig {
        name: get("name")?.as_str().context("name")?.to_string(),
        n_patches: get("n_patches")?.as_usize().context("n_patches")?,
        patch_dim: get("patch_dim")?.as_usize().context("patch_dim")?,
        d_vision: get("d_vision")?.as_usize().context("d_vision")?,
        n_vision_blocks: get("n_vision_blocks")?.as_usize().context("n_vision_blocks")?,
        d_cross: get("d_cross")?.as_usize().context("d_cross")?,
        lm: lm_config_from_json(get("lm")?)?,
    })
}

/// Full named tensor list (vision/cross + the LM's own names).
fn named_tensors(w: &VlmWeights) -> Vec<(String, &Tensor)> {
    let mut v: Vec<(String, &Tensor)> = vec![("vision.patch_proj".into(), &w.patch_proj)];
    for (i, b) in w.vision_blocks.iter().enumerate() {
        v.push((format!("vision.block{i}.fc1"), &b.fc1));
        v.push((format!("vision.block{i}.fc2"), &b.fc2));
    }
    v.push(("cross.vision_mlp.up".into(), &w.cross_up));
    v.push(("cross.vision_mlp.down".into(), &w.cross_down));
    v.extend(w.lm.named_tensors());
    v
}

/// Save a VLM checkpoint.
pub fn save_vlm(w: &VlmWeights, path: &Path) -> Result<()> {
    let cfg = config_to_json(&w.config).dump();
    write_container(path, MAGIC, &cfg, &named_tensors(w))
}

/// Load a VLM checkpoint.
pub fn load_vlm(path: &Path) -> Result<VlmWeights> {
    let (cfg_json, tensors) = read_container(path, MAGIC)?;
    let cfg = config_from_json(&cfg_json)?;
    let mut rng = crate::rng::Pcg64::seeded(0);
    let mut w = VlmWeights::init(&cfg, &mut rng);
    for (name, shape, data) in tensors {
        let dst = if let Some(t) = w.linear_mut(&name) {
            t
        } else if let Some(t) = w.lm.named_tensor_mut(&name) {
            t
        } else {
            bail!("unknown tensor '{name}' in VLM checkpoint");
        };
        if dst.shape() != shape.as_slice() {
            bail!("tensor '{name}' shape {shape:?} != expected {:?}", dst.shape());
        }
        dst.data_mut().copy_from_slice(&data);
    }
    Ok(w)
}

/// Save a quantized VLM as a `.rpiq` container (same frame as
/// [`crate::model::io::save_qlm`] — one shared writer body; the header's
/// `config` is the VLM config and the linears span vision/cross/lm).
pub fn save_qvlm(qvlm: &QuantizedVlm, path: &Path) -> Result<()> {
    crate::model::io::write_qcontainer(
        path,
        QVLM_MAGIC,
        "qvlm",
        config_to_json(&qvlm.skeleton.config),
        &qvlm.skeleton.lm.named_tensors(),
        &qvlm.qlinears,
    )
}

/// Load a quantized VLM from a `.rpiq` container. No fp32 linear is ever
/// materialized; the loaded model's forward is bit-identical to the model
/// that was saved.
pub fn load_qvlm(path: &Path) -> Result<QuantizedVlm> {
    use crate::model::io::{fill_and_validate, read_qcontainer};
    let (cfg_json, qlinears, by_name) = read_qcontainer(path, QVLM_MAGIC)?;
    let cfg = config_from_json(&cfg_json)?;
    let mut skeleton = VlmSkeleton::zeros(&cfg);
    let names = skeleton.linear_names();
    fill_and_validate(
        by_name,
        skeleton.lm.named_tensors_mut(),
        &qlinears,
        &names,
        |name| cfg.linear_dims(name),
    )?;
    QuantizedVlm::new(skeleton, qlinears)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::vlm::VlmConfig;

    #[test]
    fn vlm_save_load_roundtrip() {
        let cfg = VlmConfig::test_tiny(40);
        let mut rng = Pcg64::seeded(1001);
        let w = VlmWeights::init(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("rpiq_vlm_io");
        let path = dir.join("v.ckpt");
        save_vlm(&w, &path).unwrap();
        let w2 = load_vlm(&path).unwrap();
        assert_eq!(w2.config, w.config);
        for ((n1, t1), (n2, t2)) in named_tensors(&w).iter().zip(named_tensors(&w2).iter()) {
            assert_eq!(n1, n2);
            assert_eq!(t1.data(), t2.data(), "{n1}");
        }
        // an LM checkpoint must not load as a VLM
        assert!(crate::model::io::load_lm(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
