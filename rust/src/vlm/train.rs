//! VLM training: backprop through decoder + cross-modal adapter + vision
//! tower, driven by the same Adam core as the LM trainer.
//!
//! The objective is VQA next-token prediction: the loss is applied only at
//! the position that predicts the single-word answer (everything else is
//! `ignore_index`), which teaches the model to *read the attributes out of
//! the patches*.

use super::{assemble_embeddings, vision_backward, vision_forward, VlmWeights};
use crate::data::tokenizer::Tokenizer;
use crate::data::vqa::VqaExample;
use crate::model::forward::lm_body_forward_training;
use crate::model::ops::cross_entropy;
use crate::tensor::Tensor;
use crate::train::{lm_backward, Grads};
use std::collections::HashMap;

/// Build the token sequence + target labels for one VQA example:
/// text = question tokens ++ [answer token]; only the position *before*
/// the answer carries a target.
pub fn encode_example(
    tok: &Tokenizer,
    e: &VqaExample,
    text_len: usize,
) -> (Vec<u32>, Vec<i64>) {
    let mut ids = tok.encode(&e.question);
    let ans = tok.id(&e.answer);
    ids.push(ans);
    assert!(
        ids.len() <= text_len,
        "question+answer ({}) exceeds text window ({text_len})",
        ids.len()
    );
    // right-pad with BOS (acts as a pad token the loss ignores)
    let q_len = ids.len();
    ids.resize(text_len, crate::data::tokenizer::BOS);
    let mut targets = vec![-100i64; text_len];
    // position q_len-2 predicts the answer at q_len-1
    targets[q_len - 2] = ans as i64;
    (ids, targets)
}

/// Loss + gradients for a batch of VQA examples.
pub fn vlm_loss_and_grads(
    w: &VlmWeights,
    tok: &Tokenizer,
    batch_examples: &[&VqaExample],
) -> (f64, Grads) {
    let cfg = &w.config;
    let batch = batch_examples.len();
    let p = cfg.n_patches;
    let text_len = cfg.text_len();
    let seq = p + text_len;
    let d = cfg.lm.d_model;

    // assemble patches + text + targets
    let mut patches = Tensor::zeros(&[batch * p, cfg.patch_dim]);
    let mut text = Vec::with_capacity(batch * text_len);
    let mut targets = vec![-100i64; batch * seq];
    for (b, e) in batch_examples.iter().enumerate() {
        for i in 0..p {
            patches
                .row_mut(b * p + i)
                .copy_from_slice(e.cover.patches.row(i));
        }
        let (ids, tg) = encode_example(tok, e, text_len);
        text.extend_from_slice(&ids);
        for (i, &t) in tg.iter().enumerate() {
            targets[b * seq + p + i] = t;
        }
    }

    // forward
    let vrec = vision_forward(w, &patches, None);
    let emb = assemble_embeddings(w, &vrec.img_tokens, &text, batch);
    let rec = lm_body_forward_training(&w.lm, emb, batch, seq);
    let (loss, dlogits) = cross_entropy(&rec.logits, &targets, -100);

    // backward through the decoder
    let mut grads = lm_backward(&w.lm, &rec, &dlogits);
    let demb = grads.remove("__demb").expect("lm_backward ran");

    // split the embedding gradient: image positions → vision towers (+pos),
    // text positions → tok/pos embeddings.
    let mut d_img = Tensor::zeros(&[batch * p, d]);
    let mut dtok = grads
        .remove("tok_emb")
        .unwrap_or_else(|| Tensor::zeros(&[cfg.lm.vocab, d]));
    let mut dpos = Tensor::zeros(&[cfg.lm.seq_len, d]);
    for b in 0..batch {
        for i in 0..p {
            let src = demb.row(b * seq + i);
            d_img.row_mut(b * p + i).copy_from_slice(src);
            let prow = dpos.row_mut(i);
            for j in 0..d {
                prow[j] += src[j];
            }
        }
        for i in 0..text_len {
            let src = demb.row(b * seq + p + i);
            let t = text[b * text_len + i] as usize;
            let trow = dtok.row_mut(t);
            for j in 0..d {
                trow[j] += src[j];
            }
            let prow = dpos.row_mut(p + i);
            for j in 0..d {
                prow[j] += src[j];
            }
        }
    }
    grads.insert("tok_emb".into(), dtok);
    grads.insert("pos_emb".into(), dpos);

    // backward through cross + vision
    let vgrads = vision_backward(w, &vrec, &d_img);
    for (k, v) in vgrads {
        grads.insert(k, v);
    }
    (loss, grads)
}

/// Adam over the full VLM (LM tensors via the LM Adam core; vision/cross
/// tensors handled here with the same hyperparameters).
pub struct VlmTrainer {
    pub lm_adam: crate::train::Adam,
    vm: HashMap<String, Vec<f32>>,
    vv: HashMap<String, Vec<f32>>,
    step: usize,
    lr: f32,
}

impl VlmTrainer {
    pub fn new(lr: f32) -> Self {
        VlmTrainer {
            lm_adam: crate::train::Adam::new(lr),
            vm: HashMap::new(),
            vv: HashMap::new(),
            step: 0,
            lr,
        }
    }

    pub fn update(&mut self, w: &mut VlmWeights, grads: &Grads) {
        // LM tensors
        self.lm_adam.update(&mut w.lm, grads);
        // vision/cross tensors
        self.step += 1;
        let warm = ((self.step as f32) / 20.0).min(1.0);
        let lr = self.lr * warm;
        let (b1, b2, eps) = (0.9f32, 0.95f32, 1e-8f32);
        let bc1 = 1.0 - b1.powi(self.step as i32);
        let bc2 = 1.0 - b2.powi(self.step as i32);
        for (name, g) in grads {
            if !(name.starts_with("vision.") || name.starts_with("cross.")) {
                continue;
            }
            let p = match w.linear_mut(name) {
                Some(p) => p,
                None => continue,
            };
            let n = p.len();
            let m = self.vm.entry(name.clone()).or_insert_with(|| vec![0.0; n]);
            let v = self.vv.entry(name.clone()).or_insert_with(|| vec![0.0; n]);
            let pd = p.data_mut();
            let gd = g.data();
            for i in 0..n {
                m[i] = b1 * m[i] + (1.0 - b1) * gd[i];
                v[i] = b2 * v[i] + (1.0 - b2) * gd[i] * gd[i];
                pd[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
            }
        }
    }

    /// Train on a VQA set for `steps` steps of `batch` examples.
    pub fn train(
        &mut self,
        w: &mut VlmWeights,
        tok: &Tokenizer,
        examples: &[VqaExample],
        steps: usize,
        batch: usize,
        rng: &mut crate::rng::Pcg64,
        mut log: impl FnMut(usize, f64),
    ) -> Vec<(usize, f64)> {
        let mut curve = Vec::new();
        for step in 0..steps {
            let picks: Vec<&VqaExample> = (0..batch)
                .map(|_| &examples[rng.next_below(examples.len())])
                .collect();
            let (loss, grads) = vlm_loss_and_grads(w, tok, &picks);
            self.update(w, &grads);
            curve.push((step, loss));
            if step % 20 == 0 || step + 1 == steps {
                log(step, loss);
            }
        }
        curve
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Lexicon;
    use crate::data::vqa::VqaSet;
    use crate::rng::Pcg64;
    use crate::vlm::VlmConfig;

    #[test]
    fn encode_places_single_target() {
        let tok = Lexicon::tokenizer();
        let set = VqaSet::generate(11, 4, 24, 1, 1);
        let (ids, tg) = encode_example(&tok, &set.train[0], 10);
        assert_eq!(ids.len(), 10);
        assert_eq!(tg.len(), 10);
        assert_eq!(tg.iter().filter(|&&t| t != -100).count(), 1);
        // the target is the answer token
        let pos = tg.iter().position(|&t| t != -100).unwrap();
        assert_eq!(tg[pos] as u32, ids[pos + 1]);
    }

    #[test]
    fn vlm_gradcheck_spot() {
        let cfg = VlmConfig::test_tiny(80);
        let tok = Lexicon::tokenizer();
        // Need vocab >= tokenizer size for ids to be valid — use the real
        // vocab size.
        let mut cfg = cfg;
        cfg.lm.vocab = tok.vocab_size();
        let mut rng = Pcg64::seeded(901);
        let w = VlmWeights::init(&cfg, &mut rng);
        let set = VqaSet::generate(12, cfg.n_patches, cfg.patch_dim, 4, 1);
        let picks: Vec<&crate::data::vqa::VqaExample> = set.train.iter().collect();
        let (_, grads) = vlm_loss_and_grads(&w, &tok, &picks);
        for (name, idx) in [
            ("vision.block0.fc1", 11usize),
            ("cross.vision_mlp.up", 7),
            ("lm.layer0.attn.v", 19),
        ] {
            let eps = 1e-2f32;
            let mut wp = w.clone();
            wp.linear_mut(name).unwrap().data_mut()[idx] += eps;
            let lp = vlm_loss_and_grads(&wp, &tok, &picks).0;
            let mut wm = w.clone();
            wm.linear_mut(name).unwrap().data_mut()[idx] -= eps;
            let lm_ = vlm_loss_and_grads(&wm, &tok, &picks).0;
            let fd = (lp - lm_) / (2.0 * eps as f64);
            let an = grads[name].data()[idx] as f64;
            assert!(
                (fd - an).abs() < 5e-3 + 0.06 * fd.abs().max(an.abs()),
                "{name}[{idx}]: fd={fd} an={an}"
            );
        }
    }

    #[test]
    fn vlm_short_training_reduces_loss() {
        let tok = Lexicon::tokenizer();
        let mut cfg = VlmConfig::test_tiny(tok.vocab_size());
        cfg.lm.vocab = tok.vocab_size();
        let mut rng = Pcg64::seeded(902);
        let mut w = VlmWeights::init(&cfg, &mut rng);
        let set = VqaSet::generate(13, cfg.n_patches, cfg.patch_dim, 200, 1);
        let mut trainer = VlmTrainer::new(3e-3);
        let curve = trainer.train(&mut w, &tok, &set.train, 50, 8, &mut rng, |_, _| {});
        let head = curve[..5].iter().map(|&(_, l)| l).sum::<f64>() / 5.0;
        let tail = curve[curve.len() - 5..].iter().map(|&(_, l)| l).sum::<f64>() / 5.0;
        assert!(tail < head * 0.9, "head={head} tail={tail}");
    }
}
