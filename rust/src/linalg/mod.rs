//! Dense linear algebra needed by the quantization engines: Cholesky
//! factorization of SPD matrices, triangular solves, SPD inverses, and the
//! damping helper from the paper (Eq. 10: `λ = percdamp · mean(diag H)`).
//!
//! GPTQ needs the *upper* Cholesky factor of `H⁻¹` for its error-feedback
//! recursion; RPIQ stage 2 needs per-block inverse curvature
//! `H_i⁻¹ ≈ (X_iᵀX_i + λI)⁻¹` (Eq. 13). All routines are f64 internally —
//! the Hessians of real calibration activations are ill-conditioned enough
//! that f32 factorization loses the tail columns.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

use crate::tensor::Tensor;

/// Errors from factorization routines.
#[derive(Debug, Clone, PartialEq)]
pub enum LinalgError {
    /// Matrix was not positive definite at pivot `col` (value given).
    NotPositiveDefinite { col: usize, pivot: f64 },
    /// Shape precondition violated.
    Shape(String),
}

impl std::fmt::Display for LinalgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LinalgError::NotPositiveDefinite { col, pivot } => {
                write!(f, "matrix not positive definite at column {col} (pivot {pivot:.3e})")
            }
            LinalgError::Shape(s) => write!(f, "shape error: {s}"),
        }
    }
}

impl std::error::Error for LinalgError {}

/// Lower-triangular Cholesky factor `L` with `A = L·Lᵀ`, computed in f64.
/// `a` must be square symmetric positive definite.
pub fn cholesky_lower(a: &Tensor) -> Result<Vec<f64>, LinalgError> {
    let n = square_dim(a)?;
    let ad = a.data();
    let mut l = vec![0.0f64; n * n];
    for j in 0..n {
        // diagonal
        let mut s = ad[j * n + j] as f64;
        for p in 0..j {
            s -= l[j * n + p] * l[j * n + p];
        }
        if s <= 0.0 || !s.is_finite() {
            return Err(LinalgError::NotPositiveDefinite { col: j, pivot: s });
        }
        let d = s.sqrt();
        l[j * n + j] = d;
        // column below diagonal
        for i in j + 1..n {
            let mut s = ad[i * n + j] as f64;
            for p in 0..j {
                s -= l[i * n + p] * l[j * n + p];
            }
            l[i * n + j] = s / d;
        }
    }
    Ok(l)
}

/// Solve `L·y = b` (forward substitution) for lower-triangular `L` (n×n, f64
/// row-major) and one right-hand side.
pub fn solve_lower(l: &[f64], b: &[f64]) -> Vec<f64> {
    let n = b.len();
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l[i * n + j] * y[j];
        }
        y[i] = s / l[i * n + i];
    }
    y
}

/// Solve `Lᵀ·x = y` (back substitution) given lower-triangular `L`.
pub fn solve_lower_t(l: &[f64], y: &[f64]) -> Vec<f64> {
    let n = y.len();
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in i + 1..n {
            s -= l[j * n + i] * x[j];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

/// Inverse of an SPD matrix via Cholesky: `A⁻¹ = L⁻ᵀ·L⁻¹`. Returns an f32
/// [`Tensor`]. Used for the per-block curvature inverses of RPIQ stage 2.
pub fn spd_inverse(a: &Tensor) -> Result<Tensor, LinalgError> {
    let n = square_dim(a)?;
    let l = cholesky_lower(a)?;
    let mut inv = Tensor::zeros(&[n, n]);
    // Solve A x = e_j column by column.
    let mut e = vec![0.0f64; n];
    for j in 0..n {
        e.fill(0.0);
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for i in 0..n {
            inv.set(i, j, x[i] as f32);
        }
    }
    Ok(inv)
}

/// Upper Cholesky factor of `A⁻¹` — the quantity GPTQ's error-feedback
/// recursion walks. Computed as `chol(A⁻¹)ᵀ` would be, but without forming
/// `A⁻¹` in f32: we invert in f64 then factor.
///
/// Returns row-major f64 upper-triangular `U` with `A⁻¹ = Uᵀ·U`... more
/// precisely the standard GPTQ `Hinv = Cholesky(H⁻¹, upper)` matrix whose
/// rows drive the weight-update broadcast.
pub fn cholesky_inverse_upper(a: &Tensor) -> Result<Vec<f64>, LinalgError> {
    let n = square_dim(a)?;
    let l = cholesky_lower(a)?;
    // A⁻¹ in f64.
    let mut ainv = vec![0.0f64; n * n];
    let mut e = vec![0.0f64; n];
    for j in 0..n {
        e.fill(0.0);
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for i in 0..n {
            ainv[i * n + j] = x[i];
        }
    }
    // Upper Cholesky of A⁻¹: A⁻¹ = Uᵀ·U where U is upper triangular.
    // Factor via the lower factor of the reversed matrix trick is overkill;
    // we do the direct recurrence U[i][j] defined for i<=j.
    let mut u = vec![0.0f64; n * n];
    for i in 0..n {
        let mut s = ainv[i * n + i];
        for p in 0..i {
            s -= u[p * n + i] * u[p * n + i];
        }
        if s <= 0.0 || !s.is_finite() {
            return Err(LinalgError::NotPositiveDefinite { col: i, pivot: s });
        }
        let d = s.sqrt();
        u[i * n + i] = d;
        for j in i + 1..n {
            let mut s = ainv[i * n + j];
            for p in 0..i {
                s -= u[p * n + i] * u[p * n + j];
            }
            u[i * n + j] = s / d;
        }
    }
    Ok(u)
}

/// Paper Eq. 10: add damping `λI` with `λ = percdamp · mean(diag H)` in
/// place and return `λ`. If the diagonal mean is zero (degenerate layer, or
/// all-zero calibration), a tiny absolute floor keeps H factorizable.
pub fn apply_damping(h: &mut Tensor, percdamp: f32) -> f32 {
    let n = h.rows();
    assert_eq!(n, h.cols());
    let mut mean = 0.0f64;
    for i in 0..n {
        mean += h.at(i, i) as f64;
    }
    mean /= n as f64;
    let lambda = (percdamp as f64 * mean).max(1e-8) as f32;
    for i in 0..n {
        let v = h.at(i, i) + lambda;
        h.set(i, i, v);
    }
    lambda
}

/// Guard against dead input channels (all-zero rows of X ⇒ zero diagonal in
/// H): GPTQ sets `H[i,i] = 1` and zeroes the corresponding weight column.
/// Returns the indices of dead channels.
pub fn fix_dead_channels(h: &mut Tensor, w: &mut Tensor) -> Vec<usize> {
    let n = h.rows();
    let mut dead = Vec::new();
    for i in 0..n {
        if h.at(i, i) == 0.0 {
            h.set(i, i, 1.0);
            for r in 0..w.rows() {
                w.set(r, i, 0.0);
            }
            dead.push(i);
        }
    }
    dead
}

fn square_dim(a: &Tensor) -> Result<usize, LinalgError> {
    if a.shape().len() != 2 || a.rows() != a.cols() {
        return Err(LinalgError::Shape(format!("expected square 2-D, got {:?}", a.shape())));
    }
    Ok(a.rows())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::{matmul, matmul_at_b};

    /// Random SPD matrix `XᵀX + I`.
    fn random_spd(n: usize, rng: &mut Pcg64) -> Tensor {
        let x = Tensor::randn(&[n + 4, n], 1.0, rng);
        let mut h = matmul_at_b(&x, &x);
        for i in 0..n {
            h.set(i, i, h.at(i, i) + 1.0);
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg64::seeded(31);
        for n in [1usize, 2, 5, 16] {
            let a = random_spd(n, &mut rng);
            let l = cholesky_lower(&a).unwrap();
            // rebuild L Lᵀ
            let mut rec = Tensor::zeros(&[n, n]);
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0f64;
                    for p in 0..n {
                        s += l[i * n + p] * l[j * n + p];
                    }
                    rec.set(i, j, s as f32);
                }
            }
            assert!(rec.max_abs_diff(&a) < 1e-2 * (n as f32), "n={n}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 2.0, 1.0]); // eigvals 3, -1
        assert!(matches!(
            cholesky_lower(&a),
            Err(LinalgError::NotPositiveDefinite { .. })
        ));
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let mut rng = Pcg64::seeded(32);
        for n in [1usize, 3, 8, 20] {
            let a = random_spd(n, &mut rng);
            let ainv = spd_inverse(&a).unwrap();
            let prod = matmul(&a, &ainv);
            assert!(prod.max_abs_diff(&Tensor::eye(n)) < 1e-2, "n={n}");
        }
    }

    #[test]
    fn cholesky_inverse_upper_reconstructs_inverse() {
        let mut rng = Pcg64::seeded(33);
        let n = 10;
        let a = random_spd(n, &mut rng);
        let u = cholesky_inverse_upper(&a).unwrap();
        // Uᵀ·U should equal A⁻¹
        let ainv = spd_inverse(&a).unwrap();
        let mut rec = Tensor::zeros(&[n, n]);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0f64;
                for p in 0..n {
                    s += u[p * n + i] * u[p * n + j];
                }
                rec.set(i, j, s as f32);
            }
        }
        assert!(rec.max_abs_diff(&ainv) < 1e-2);
        // upper triangular
        for i in 0..n {
            for j in 0..i {
                assert_eq!(u[i * n + j], 0.0);
            }
        }
    }

    #[test]
    fn tri_solves_invert_each_other() {
        let mut rng = Pcg64::seeded(34);
        let n = 12;
        let a = random_spd(n, &mut rng);
        let l = cholesky_lower(&a).unwrap();
        let b: Vec<f64> = (0..n).map(|i| (i as f64) - 3.0).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // check A x = b
        for i in 0..n {
            let mut s = 0.0f64;
            for j in 0..n {
                s += a.at(i, j) as f64 * x[j];
            }
            assert!((s - b[i]).abs() < 1e-3, "row {i}: {s} vs {}", b[i]);
        }
    }

    #[test]
    fn damping_shifts_diagonal() {
        let mut h = Tensor::from_vec(&[2, 2], vec![2.0, 0.5, 0.5, 4.0]);
        let lambda = apply_damping(&mut h, 0.01);
        assert!((lambda - 0.03).abs() < 1e-6);
        assert!((h.at(0, 0) - 2.03).abs() < 1e-6);
        assert!((h.at(1, 1) - 4.03).abs() < 1e-6);
        assert_eq!(h.at(0, 1), 0.5);
    }

    #[test]
    fn dead_channel_fix() {
        let mut h = Tensor::from_vec(&[2, 2], vec![0.0, 0.0, 0.0, 3.0]);
        let mut w = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let dead = fix_dead_channels(&mut h, &mut w);
        assert_eq!(dead, vec![0]);
        assert_eq!(h.at(0, 0), 1.0);
        assert_eq!(w.at(0, 0), 0.0);
        assert_eq!(w.at(1, 0), 0.0);
        assert_eq!(w.at(0, 1), 2.0);
    }
}
