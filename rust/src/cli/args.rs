//! Tiny argument parser: `command --key value --flag` style.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Parsed command line.
pub struct Args {
    command: String,
    kv: HashMap<String, String>,
    flags: Vec<String>,
    /// Keys that were actually read (to report unknown arguments).
    consumed: Vec<String>,
}

impl Args {
    pub fn parse(argv: Vec<String>) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut kv = HashMap::new();
        let mut flags = Vec::new();
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument '{tok}'");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    kv.insert(key.to_string(), it.next().unwrap());
                }
                _ => flags.push(key.to_string()),
            }
        }
        Ok(Args { command, kv, flags, consumed: Vec::new() })
    }

    pub fn command(&self) -> &str {
        &self.command
    }

    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&mut self, name: &str) -> Option<String> {
        self.consumed.push(name.to_string());
        self.kv.get(name).cloned()
    }

    pub fn get(&mut self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or_else(|| default.to_string())
    }

    pub fn require(&mut self, name: &str) -> Result<String> {
        self.opt(name)
            .ok_or_else(|| anyhow::anyhow!("missing required --{name}"))
    }

    pub fn usize_of(&mut self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn f32_of(&mut self, name: &str, default: f32) -> Result<f32> {
        match self.opt(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn u64_of(&mut self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Error on unknown keys (call after all reads).
    pub fn finish(&self) -> Result<()> {
        for k in self.kv.keys() {
            if !self.consumed.contains(k) {
                bail!("unknown argument --{k}");
            }
        }
        for f in &self.flags {
            if !self.consumed.contains(f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()).collect()).unwrap()
    }

    #[test]
    fn parses_kv_and_flags() {
        let mut a = args("pretrain --steps 100 --all --out-dir ck");
        assert_eq!(a.command(), "pretrain");
        assert_eq!(a.usize_of("steps", 0).unwrap(), 100);
        assert!(a.flag("all"));
        assert_eq!(a.get("out-dir", "x"), "ck");
        assert!(a.finish().is_ok());
    }

    #[test]
    fn unknown_arg_rejected() {
        let mut a = args("eval --bogus 3");
        let _ = a.opt("ckpt");
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_required_errors() {
        let mut a = args("quantize");
        assert!(a.require("ckpt").is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(vec!["x".into(), "oops".into()]).is_err());
    }
}
