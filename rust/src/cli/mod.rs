//! Command-line interface (hand-rolled: `clap` is unavailable offline).
//!
//! ```text
//! rpiq pretrain  --all | --preset NAME   [--steps N] [--out-dir DIR]
//! rpiq quantize  --ckpt PATH --method gptq|rpiq [--bits B] [--group-size G]
//!                [--iters T] [--alpha A] [--out model.rpiq] [--trace t.json]
//! rpiq eval      --ckpt PATH [--method gptq|rpiq|fp] [--n-test N]
//! rpiq serve     --ckpt PATH | --qckpt model.rpiq [--mode sentiment|vqa|mixed|generate]
//!                [--vlm-ckpt PATH | --vlm-qckpt model.rpiq]
//!                [--lanes N] [--requests N] [--clients C] [--method ...]
//!                [--activation-budget BYTES] [--max-tokens N] [--kv-pages N]
//!                [--trace [t.json]] [--stats-every SECS]
//! rpiq generate  --ckpt PATH | --qckpt model.rpiq [--prompt "TEXT"]
//!                [--max-tokens N]       # cached vs recompute decode
//! rpiq inspect   --ckpt PATH               # fp32 or quantized .rpiq
//! rpiq artifacts --dir artifacts   # validate + smoke-run the AOT bundle
//! rpiq trace summarize --in t.json # per-phase table of a Chrome trace
//! ```

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

mod args;
mod commands;

pub use args::Args;

/// Entry point used by `main.rs`.
pub fn run(mut argv: Vec<String>) -> anyhow::Result<()> {
    // `trace` takes a sub-verb (`rpiq trace summarize --in t.json`), which
    // Args (one command + flags, no positionals) cannot express — peel the
    // word here and parse the remainder as its own command line.
    if argv.first().map(String::as_str) == Some("trace") {
        argv.remove(0);
        let mut args = Args::parse(argv)?;
        return match args.command() {
            "summarize" => commands::trace_summarize(&mut args),
            other => {
                anyhow::bail!("unknown trace subcommand '{other}' (expected: summarize)\n{HELP}")
            }
        };
    }
    let mut args = Args::parse(argv)?;
    let cmd = args.command().to_string();
    match cmd.as_str() {
        "pretrain" => commands::pretrain(&mut args),
        "quantize" => commands::quantize(&mut args),
        "eval" => commands::eval(&mut args),
        "serve" => commands::serve(&mut args),
        "generate" => commands::generate(&mut args),
        "inspect" => commands::inspect(&mut args),
        "artifacts" => commands::artifacts(&mut args),
        "help" | "" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n{HELP}"),
    }
}

pub const HELP: &str = "\
rpiq — Residual-Projected Multi-Collaboration Closed-Loop and Single Instance Quantization

USAGE:
  rpiq pretrain  --all | --preset NAME [--steps N] [--out-dir DIR] [--seed S]
  rpiq quantize  --ckpt PATH --method gptq|rpiq [--bits B] [--group-size G] [--iters T] [--alpha A]
                 [--out model.rpiq] [--trace trace.json]
  rpiq eval      --ckpt PATH [--method fp|gptq|rpiq] [--n-test N]
  rpiq serve     --ckpt PATH | --qckpt model.rpiq [--mode sentiment|vqa|mixed|generate]
                 [--vlm-ckpt PATH | --vlm-qckpt model.rpiq]
                 [--lanes N] [--requests N] [--clients C] [--max-batch B]
                 [--activation-budget BYTES] [--max-tokens N] [--kv-pages N]
                 [--trace [trace.json]] [--stats-every SECS]
  rpiq generate  --ckpt PATH | --qckpt model.rpiq [--prompt \"TEXT\"] [--max-tokens N]
  rpiq inspect   --ckpt PATH               (fp32 checkpoint or quantized .rpiq)
  rpiq artifacts [--dir artifacts]
  rpiq trace summarize --in trace.json     (per-phase table of a recorded trace)

The pretrain command produces the subject checkpoints (4 LM presets + the
VLM) that the table benches quantize. `quantize --out` writes the
nibble-packed deployment container; `serve --qckpt` cold-starts from it
without ever materializing fp32 linears. See rust/DESIGN.md for the
experiment map and §Deployment memory for the container format.

`--trace` records a Chrome trace-event JSON of the run (open it in
chrome://tracing or ui.perfetto.dev; `serve --trace` without a value
writes serve-trace.json). `serve --stats-every SECS` prints a one-line
heartbeat (queue depth, per-lane p50/p99, drops/rejects, ledger
live/peak) while the replay runs. `serve --activation-budget BYTES` caps
each lane's concurrent transient activations: over-cap single requests
are rejected at submit and fused batches split to fit. See rust/DESIGN.md
§Observability and §Activation memory.

`serve --mode generate` streams greedy decode through the paged KV cache
with continuous batching (`--max-tokens` per request, `--kv-pages` pool
size); `rpiq generate` runs one prompt through the same cached decode
and prints its speedup over the recompute-from-scratch oracle. See
rust/DESIGN.md §Streaming decode.
";
