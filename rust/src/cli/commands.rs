//! CLI command implementations — thin wrappers over the library.

use super::args::Args;
use crate::coordinator::experiments::{self as exp, World};
use crate::coordinator::{
    quantize_lm, quantize_vlm, replay_generate, replay_mixed, Method, Payload, ServeConfig,
    Server, LANE_GENERATE,
};
use crate::model::io::{load_lm, load_qlm, save_lm, save_qlm};
use crate::model::{ModelConfig, QuantizedLm};
use crate::quant::{CmdqPolicy, QuantConfig, RpiqParams};
use crate::report::Table;

use crate::vlm::io::{load_qvlm, load_vlm, save_qvlm, save_vlm};
use crate::vlm::{QuantizedVlm, VlmConfig};
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn world() -> World {
    World::build(exp::WORLD_SEED)
}

/// `rpiq pretrain` — train the subject checkpoints.
pub fn pretrain(args: &mut Args) -> Result<()> {
    let all = args.flag("all");
    let preset = args.opt("preset");
    let out_dir = PathBuf::from(args.get("out-dir", "checkpoints"));
    let lm_steps = args.usize_of("steps", exp::DEFAULT_LM_STEPS)?;
    let vlm_steps = args.usize_of("vlm-steps", exp::DEFAULT_VLM_STEPS)?;
    let seed = args.u64_of("seed", exp::WORLD_SEED)?;
    args.finish()?;

    let w = world();
    let vocab = w.tokenizer().vocab_size();
    let presets: Vec<ModelConfig> = match (&preset, all) {
        (Some(name), _) if name != "vlm" => {
            vec![ModelConfig::preset(name, vocab)
                .ok_or_else(|| anyhow::anyhow!("unknown preset '{name}'"))?]
        }
        (Some(_), _) => vec![],
        (None, true) => ModelConfig::lm_presets(vocab),
        (None, false) => bail!("pass --all or --preset NAME (or --preset vlm)"),
    };

    for cfg in &presets {
        let t0 = std::time::Instant::now();
        println!("== pretraining {} ({} params) ==", cfg.name, cfg.n_params());
        let (weights, curve) = exp::pretrain_lm(
            cfg,
            &w,
            lm_steps,
            exp::DEFAULT_LM_BATCH,
            seed,
            |s, l| println!("  step {s:4}  loss {l:.4}"),
        );
        let path = exp::ckpt_path(&out_dir, &cfg.name);
        save_lm(&weights, &path)?;
        let first = curve.first().unwrap().1;
        let last = curve.last().unwrap().1;
        println!(
            "  saved {} (loss {first:.3} -> {last:.3}, {:.1}s)",
            path.display(),
            t0.elapsed().as_secs_f64()
        );
        // loss curve alongside the checkpoint (e2e evidence)
        let csv = crate::report::csv(
            &["step", "loss"],
            &curve
                .iter()
                .map(|(s, l)| vec![s.to_string(), format!("{l:.6}")])
                .collect::<Vec<_>>(),
        );
        std::fs::write(out_dir.join(format!("{}.loss.csv", cfg.name)), csv)?;
    }

    if all || preset.as_deref() == Some("vlm") {
        let vcfg = VlmConfig::sim_cogvlm2(vocab);
        println!("== pretraining {} ==", vcfg.name);
        let t0 = std::time::Instant::now();
        let (weights, curve) = exp::pretrain_vlm(
            &vcfg,
            &w,
            vlm_steps,
            exp::DEFAULT_VLM_BATCH,
            seed,
            |s, l| println!("  step {s:4}  loss {l:.4}"),
        );
        let path = exp::ckpt_path(&out_dir, &vcfg.name);
        save_vlm(&weights, &path)?;
        println!(
            "  saved {} (loss {:.3} -> {:.3}, {:.1}s)",
            path.display(),
            curve.first().unwrap().1,
            curve.last().unwrap().1,
            t0.elapsed().as_secs_f64()
        );
        let csv = crate::report::csv(
            &["step", "loss"],
            &curve
                .iter()
                .map(|(s, l)| vec![s.to_string(), format!("{l:.6}")])
                .collect::<Vec<_>>(),
        );
        std::fs::write(out_dir.join(format!("{}.loss.csv", vcfg.name)), csv)?;
    }
    Ok(())
}

fn parse_method(args: &mut Args) -> Result<Method> {
    let m = args.get("method", "rpiq");
    let iters = args.usize_of("iters", 5)?;
    let alpha = args.f32_of("alpha", RpiqParams::default().alpha)?;
    Ok(match m.as_str() {
        "gptq" => Method::Gptq,
        "rpiq" => Method::Rpiq(RpiqParams { max_iters: iters, alpha, ..Default::default() }),
        other => bail!("unknown method '{other}' (gptq|rpiq)"),
    })
}

/// The CMDQ policy a VLM is quantized under for a given method (shared by
/// `quantize` and `serve`).
fn vlm_policy(method: Method) -> CmdqPolicy {
    CmdqPolicy {
        rpiq: match method {
            Method::Rpiq(p) => p,
            Method::Gptq => RpiqParams::default(),
        },
        ..Default::default()
    }
}

fn quant_cfg(args: &mut Args) -> Result<QuantConfig> {
    Ok(QuantConfig {
        bits: args.usize_of("bits", 4)? as u32,
        group_size: args.usize_of("group-size", 128)?,
        block_size: args.usize_of("block-size", 128)?,
        percdamp: args.f32_of("percdamp", 0.01)?,
    })
}

/// `rpiq quantize` — quantize a checkpoint, print the per-layer report,
/// and (with `--out model.rpiq`) write the quantized deployment container
/// so `rpiq serve --qckpt` can cold-start without the fp32 checkpoint.
pub fn quantize(args: &mut Args) -> Result<()> {
    let ckpt = PathBuf::from(args.require("ckpt")?);
    let out_path = args.opt("out").map(PathBuf::from);
    let trace_out = args.opt("trace").map(PathBuf::from);
    let method = parse_method(args)?;
    let cfg = quant_cfg(args)?;
    args.finish()?;

    if trace_out.is_some() {
        crate::trace::start();
    }
    let w = world();
    if is_vlm(&ckpt) {
        let weights = load_vlm(&ckpt)?;
        let policy = vlm_policy(method);
        let samples = w.vlm_calib(exp::CALIB_SAMPLES_VLM);
        let out = quantize_vlm(&weights, &samples, &policy, method)?;
        print_reports(&out.reports, out.ledger.peak_mib(), out.timers.total());
        if let Some(p) = &out_path {
            save_qvlm(&out.model, p)?;
            println!(
                "saved quantized checkpoint {} ({:.2} MiB resident vs {:.2} MiB fp32)",
                p.display(),
                out.model.deploy_bytes() as f64 / (1 << 20) as f64,
                weights.config.fp32_bytes() as f64 / (1 << 20) as f64
            );
        }
    } else {
        let weights = load_lm(&ckpt)?;
        let windows = w.calib_windows(weights.config.seq_len, exp::CALIB_SAMPLES);
        let out = quantize_lm(&weights, &windows, cfg, method)?;
        print_reports(&out.reports, out.ledger.peak_mib(), out.timers.total());
        if let Some(p) = &out_path {
            save_qlm(&out.model, p)?;
            println!(
                "saved quantized checkpoint {} ({:.2} MiB resident vs {:.2} MiB fp32)",
                p.display(),
                out.model.deploy_bytes() as f64 / (1 << 20) as f64,
                weights.config.fp32_bytes() as f64 / (1 << 20) as f64
            );
        }
    }
    if let Some(p) = &trace_out {
        write_trace(p)?;
    }
    Ok(())
}

/// Stop collecting, export the Chrome trace-event JSON to `path`, and
/// print the in-process per-phase summary (the same aggregation `rpiq
/// trace summarize` recomputes from the file).
fn write_trace(path: &Path) -> Result<()> {
    let t = crate::trace::stop_and_take();
    std::fs::write(path, t.to_chrome_json())?;
    let summary = t.summary().map_err(|e| anyhow::anyhow!("trace summary: {e}"))?;
    print!("{}", summary.render());
    println!(
        "trace: {} events -> {} (open in chrome://tracing or ui.perfetto.dev)",
        t.events.len(),
        path.display()
    );
    Ok(())
}

/// `rpiq trace summarize` — aggregate a recorded Chrome-trace JSON into
/// per-phase span/counter/instant tables. Errors (non-zero exit) on
/// malformed JSON or unbalanced span trees, so CI can gate on trace
/// integrity.
pub fn trace_summarize(args: &mut Args) -> Result<()> {
    let path = PathBuf::from(args.require("in")?);
    args.finish()?;
    let text = std::fs::read_to_string(&path)?;
    let t = crate::trace::parse_chrome(&text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let summary = t.summary().map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    print!("{}", summary.render());
    let tids: std::collections::BTreeSet<u64> = t.events.iter().map(|e| e.tid).collect();
    println!(
        "{}: {} events across {} thread(s)",
        path.display(),
        t.events.len(),
        tids.len()
    );
    Ok(())
}

fn print_reports(reports: &[crate::coordinator::LayerReport], peak_mib: f64, secs: f64) {
    let mut t = Table::new(
        "Per-layer quantization report",
        &["layer", "init loss", "final loss", "reduction %", "iters", "early stop"],
    );
    for r in reports {
        t.row(vec![
            r.name.clone(),
            format!("{:.4}", r.initial_loss()),
            format!("{:.4}", r.final_loss()),
            format!("{:.2}", r.reduction_pct()),
            r.iters_run.to_string(),
            r.early_stopped.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("peak memory: {peak_mib:.2} MiB, total time: {secs:.2}s");
}

/// `rpiq eval` — accuracy + PPL of fp/gptq/rpiq arms of one checkpoint.
pub fn eval(args: &mut Args) -> Result<()> {
    let ckpt = PathBuf::from(args.require("ckpt")?);
    let arm = args.get("method", "fp");
    let n_test = args.usize_of("n-test", 200)?;
    let n_windows = args.usize_of("n-windows", 40)?;
    let cfg = quant_cfg(args)?;
    let method = match arm.as_str() {
        "fp" => None,
        _ => Some(parse_method_named(&arm, args)?),
    };
    args.finish()?;

    let w = world();
    if is_vlm(&ckpt) {
        let weights = load_vlm(&ckpt)?;
        let rep = match method {
            None => exp::eval_vlm_fp(&weights, &w),
            Some(m) => {
                let policy = CmdqPolicy::default();
                let samples = w.vlm_calib(exp::CALIB_SAMPLES_VLM);
                let out = quantize_vlm(&weights, &samples, &policy, m)?;
                exp::eval_vlm_q(&out.model, &w)
            }
        };
        println!("overall: {:.2}%", rep.overall_pct);
        for (cat, acc) in &rep.per_category {
            println!("  {cat:12} {acc:.2}%");
        }
    } else {
        let weights = load_lm(&ckpt)?;
        let ev = match method {
            None => exp::eval_lm_fp(&weights, &w, n_windows, n_test),
            Some(m) => {
                let windows = w.calib_windows(weights.config.seq_len, exp::CALIB_SAMPLES);
                let out = quantize_lm(&weights, &windows, cfg, m)?;
                exp::eval_lm_q(&out.model, &w, n_windows, n_test)
            }
        };
        println!("sentiment acc: {:.2}%   ppl: {:.3}", ev.acc_pct, ev.ppl);
    }
    Ok(())
}

fn parse_method_named(name: &str, args: &mut Args) -> Result<Method> {
    let iters = args.usize_of("iters", 5)?;
    let alpha = args.f32_of("alpha", RpiqParams::default().alpha)?;
    Ok(match name {
        "gptq" => Method::Gptq,
        "rpiq" => Method::Rpiq(RpiqParams { max_iters: iters, alpha, ..Default::default() }),
        other => bail!("unknown method '{other}'"),
    })
}

/// `rpiq serve` — serve a replay workload through the multi-lane engine,
/// printing overall + per-lane latency and the ledger-measured memory
/// peaks (model-resident vs per-lane transient activations).
///
/// Model sources, per lane:
/// * `--qckpt model.rpiq` — cold-start from a quantized container
///   (written by `rpiq quantize --out`); no fp32 linear is ever
///   materialized and no re-quantization happens. LM or VLM is sniffed
///   from the magic.
/// * `--ckpt PATH` — fp32 checkpoint, quantized at startup (the old
///   path).
///
/// `--mode sentiment` (default) serves the LM lane; `--mode vqa` the VLM
/// lane (`--qckpt`/`--ckpt` if the file is a VLM, or
/// `--vlm-qckpt`/`--vlm-ckpt`); `--mode mixed` serves both side by side;
/// `--mode generate` streams greedy decode through the paged KV cache
/// with continuous batching (`--max-tokens` per request, `--kv-pages`
/// pool size).
pub fn serve(args: &mut Args) -> Result<()> {
    let mode = args.get("mode", "sentiment");
    let ckpt = args.opt("ckpt").map(PathBuf::from);
    let vlm_ckpt = args.opt("vlm-ckpt").map(PathBuf::from);
    let qckpt = args.opt("qckpt").map(PathBuf::from);
    let vlm_qckpt = args.opt("vlm-qckpt").map(PathBuf::from);
    let n_requests = args.usize_of("requests", 100)?;
    let n_clients = args.usize_of("clients", 4)?;
    let max_batch = args.usize_of("max-batch", 8)?;
    let lanes = args.usize_of("lanes", 2)?;
    // `--activation-budget BYTES` caps each lane's concurrent transient
    // activations on the server ledger; omitted = observe-only.
    let activation_budget: Option<usize> = match args.opt("activation-budget") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    // generate-mode knobs: tokens decoded per request and the paged KV
    // pool size (pages; omitted = sized for lanes x max_batch sequences)
    let max_tokens = args.usize_of("max-tokens", 4)?;
    let kv_pages: Option<usize> = match args.opt("kv-pages") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    // `--trace out.json` or bare `--trace` (default path)
    let trace_out = args
        .opt("trace")
        .map(PathBuf::from)
        .or_else(|| args.flag("trace").then(|| PathBuf::from("serve-trace.json")));
    // heartbeat period in seconds; 0 (the default) disables it
    let stats_every = args.f32_of("stats-every", 0.0)?;
    // Quantization flags apply only to fp32 startup quantization; record
    // which were explicitly passed so a --qckpt-only invocation can
    // reject them instead of silently serving the container's baked-in
    // grid while the user believes their settings applied.
    let quant_flags: Vec<String> =
        ["method", "bits", "group-size", "block-size", "percdamp", "iters", "alpha"]
            .iter()
            .filter(|k| args.opt(k).is_some())
            .map(|k| format!("--{k}"))
            .collect();
    let method = parse_method(args)?;
    let cfg = quant_cfg(args)?;
    args.finish()?;

    if trace_out.is_some() {
        crate::trace::start();
    }
    let w = world();
    let tok = w.tokenizer().clone();
    let scfg = ServeConfig { max_batch, lanes, activation_budget, kv_pages, ..Default::default() };

    let want_lm = mode != "vqa";
    let want_vlm = matches!(mode.as_str(), "vqa" | "mixed");
    if !matches!(mode.as_str(), "sentiment" | "vqa" | "mixed" | "generate") {
        bail!("unknown mode '{mode}' (sentiment|vqa|mixed|generate)");
    }

    let mib = |b: usize| b as f64 / (1 << 20) as f64;
    let mut lm_cold = false;
    let mut vlm_cold = false;
    let qlm: Option<Arc<QuantizedLm>> = if want_lm {
        // --qckpt is authoritative when given: a missing or wrong-magic
        // file fails loudly via load_qlm instead of silently falling back
        // to the fp32 re-quantization path the user opted out of.
        let model = if let Some(p) = qckpt.as_ref() {
            if ckpt.is_some() {
                bail!("both --ckpt and --qckpt given for the LM lane; pass exactly one");
            }
            lm_cold = true;
            let model = load_qlm(p)?;
            println!(
                "lm cold-start from {}: {:.2} MiB resident (fp32 {:.2} MiB, never materialized)",
                p.display(),
                mib(model.deploy_bytes()),
                mib(model.config().fp32_bytes())
            );
            model
        } else {
            let path = ckpt.clone().ok_or_else(|| {
                anyhow::anyhow!("--mode {mode} needs --ckpt (LM checkpoint) or --qckpt (.rpiq)")
            })?;
            if is_vlm(&path) {
                bail!(
                    "--ckpt {} is a VLM checkpoint; pass the LM via --ckpt (or use --mode vqa)",
                    path.display()
                );
            }
            let weights = load_lm(&path)?;
            let windows = w.calib_windows(weights.config.seq_len, exp::CALIB_SAMPLES);
            let out = quantize_lm(&weights, &windows, cfg, method)?;
            println!(
                "lm deploy bytes: {:.2} MiB (fp32 {:.2} MiB)",
                mib(out.model.deploy_bytes()),
                mib(weights.config.fp32_bytes())
            );
            out.model
        };
        Some(Arc::new(model))
    } else {
        None
    };

    let qvlm: Option<Arc<QuantizedVlm>> = if want_vlm {
        // quantized cold-start: --vlm-qckpt, or --qckpt in pure vqa mode
        // (authoritative when given — a bad file errors via load_qvlm
        // rather than silently falling back to fp32 re-quantization)
        let qpath = match (&vlm_qckpt, &qckpt) {
            (Some(p), _) => Some(p.clone()),
            (None, Some(p)) if mode == "vqa" => Some(p.clone()),
            _ => None,
        };
        let model = if let Some(p) = qpath {
            if vlm_qckpt.is_some() && vlm_ckpt.is_some() {
                bail!("both --vlm-ckpt and --vlm-qckpt given; pass exactly one");
            }
            if vlm_qckpt.is_some() && mode == "vqa" && qckpt.is_some() {
                bail!("both --qckpt and --vlm-qckpt given for the VQA lane; pass exactly one");
            }
            if vlm_qckpt.is_none() && ckpt.is_some() {
                bail!("both --ckpt and --qckpt given for the VQA lane; pass exactly one");
            }
            vlm_cold = true;
            let model = load_qvlm(&p)?;
            println!(
                "vlm cold-start from {}: {:.2} MiB resident (fp32 {:.2} MiB, never materialized)",
                p.display(),
                mib(model.deploy_bytes()),
                mib(model.config().fp32_bytes())
            );
            model
        } else {
            // the VLM may arrive as --vlm-ckpt, or as --ckpt in vqa mode
            let path = match (&vlm_ckpt, &ckpt) {
                (Some(p), _) => p.clone(),
                (None, Some(p)) if mode == "vqa" && is_vlm(p) => p.clone(),
                _ => bail!(
                    "--mode {mode} needs --vlm-ckpt (VLM checkpoint) or --vlm-qckpt (.rpiq)"
                ),
            };
            let weights = load_vlm(&path)?;
            let policy = vlm_policy(method);
            let samples = w.vlm_calib(exp::CALIB_SAMPLES_VLM);
            let out = quantize_vlm(&weights, &samples, &policy, method)?;
            println!(
                "vlm deploy bytes: {:.2} MiB (fp32 {:.2} MiB)",
                mib(out.model.deploy_bytes()),
                mib(weights.config.fp32_bytes())
            );
            out.model
        };
        Some(Arc::new(model))
    } else {
        None
    };

    // With every served lane cold-starting from a container, the grid is
    // baked in and the quantization flags would be silently ignored.
    let fp_lane_exists = (want_lm && !lm_cold) || (want_vlm && !vlm_cold);
    if !fp_lane_exists && !quant_flags.is_empty() {
        bail!(
            "{} have no effect with --qckpt: the grid is baked into the container \
             (re-run `rpiq quantize --out` to change it)",
            quant_flags.join("/")
        );
    }

    let server = match (&qlm, &qvlm) {
        (Some(lm), None) if mode == "generate" => Server::start_generate(Arc::clone(lm), &tok, scfg),
        (Some(lm), Some(vlm)) => {
            Server::start_mixed(Arc::clone(lm), Arc::clone(vlm), &tok, scfg)
        }
        (Some(lm), None) => Server::start(Arc::clone(lm), &tok, scfg),
        (None, Some(vlm)) => Server::start_vqa(Arc::clone(vlm), &tok, scfg),
        (None, None) => unreachable!("mode resolution left no model"),
    };
    // Book the deployed models on the server's ledger so its peak reads
    // model-resident + concurrent lane activations.
    if let Some(m) = &qlm {
        m.register_resident(server.ledger());
    }
    if let Some(m) = &qvlm {
        m.register_resident(server.ledger());
    }
    let ledger = server.ledger().clone();

    // Replay workload: sentiment prompts and/or VQA pairs from the world's
    // test sets, interleaved in mixed mode. The heartbeat thread borrows
    // the server for the replay's duration (scoped), polling in short
    // slices so it exits promptly once the replay returns.
    // generate mode replays the sentiment prompts as decode requests
    // (tokens streamed per request); the other modes replay one-shot
    // payloads through the fused lanes.
    let gen_prompts: Option<Vec<Vec<u32>>> = (mode == "generate").then(|| {
        w.replay_items("sentiment", n_requests)
            .into_iter()
            .filter_map(|p| match p {
                Payload::Sentiment { tokens } => Some(tokens),
                _ => None,
            })
            .collect()
    });
    let items =
        if mode == "generate" { Vec::new() } else { w.replay_items(&mode, n_requests) };
    let stop = std::sync::atomic::AtomicBool::new(false);
    let (tput, gen_tokens) = std::thread::scope(|sc| {
        if stats_every > 0.0 {
            let (server, ledger, stop) = (&server, &ledger, &stop);
            let period = std::time::Duration::from_secs_f32(stats_every.max(0.05));
            sc.spawn(move || {
                let mut next = std::time::Instant::now() + period;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    if std::time::Instant::now() >= next {
                        next += period;
                        print_heartbeat(server, ledger);
                    }
                }
            });
        }
        let out = match gen_prompts {
            Some(prompts) => {
                let (tok_s, total) = replay_generate(&server, prompts, max_tokens, n_clients);
                (tok_s, Some(total))
            }
            None => (replay_mixed(&server, items, n_clients), None),
        };
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        out
    });
    let kv_pool = server.kv_pool().cloned();
    let stats = server.shutdown();
    if let Some(total) = gen_tokens {
        let per_tok = stats
            .lane_tokens(LANE_GENERATE)
            .map(|t| {
                format!(
                    ", per-token p50 {:.3} ms p99 {:.3} ms",
                    t.percentile_ms(50.0),
                    t.percentile_ms(99.0)
                )
            })
            .unwrap_or_default();
        println!(
            "generated {total} tokens over {} request(s) on {} lane(s): {tput:.1} tok/s{per_tok}",
            stats.count(),
            lanes.max(1)
        );
    } else {
        println!(
            "served {} requests over {} lane(s): {:.1} req/s, mean {:.2} ms, p50 {:.2} ms, p95 {:.2} ms",
            stats.count(),
            lanes.max(1),
            tput,
            stats.mean_ms(),
            stats.percentile_ms(50.0),
            stats.percentile_ms(95.0)
        );
    }
    if let Some(pool) = &kv_pool {
        println!(
            "kv pool: {}/{} pages free after drain ({:.1} KiB/page), kv_cache peak {:.1} KiB",
            pool.free_pages(),
            pool.capacity_pages(),
            pool.page_bytes() as f64 / 1024.0,
            ledger.peak_for(crate::metrics::tags::KV_CACHE) as f64 / 1024.0
        );
    }
    for name in stats.lane_names() {
        let l = stats.lane(&name).expect("named lane exists");
        println!(
            "  lane {name:9} {:4} reqs  p50 {:.2} ms  p95 {:.2} ms  activation peak {:.2} MiB",
            l.count(),
            l.percentile_ms(50.0),
            l.percentile_ms(95.0),
            ledger.peak_for(&crate::metrics::tags::activations(&name)) as f64 / (1 << 20) as f64
        );
        // queue-wait vs service decomposition + the lane's error accounting
        if let (Some(q), Some(svc)) = (stats.lane_queue(&name), stats.lane_service(&name)) {
            let hist: Vec<String> = stats
                .batch_histogram(&name)
                .iter()
                .map(|(size, n)| format!("{size}\u{00d7}{n}"))
                .collect();
            println!(
                "       {:9} queue-wait mean {:.2} ms p95 {:.2} ms | service mean {:.2} ms p95 {:.2} ms | drops {} | batches {}",
                "",
                q.mean_ms(),
                q.percentile_ms(95.0),
                svc.mean_ms(),
                svc.percentile_ms(95.0),
                stats.drops(&name),
                if hist.is_empty() { "-".to_string() } else { hist.join(" ") }
            );
        }
    }
    let rej = stats.rejects();
    println!(
        "dropped {} request(s), rejected {} (closed {} / unsupported {} / invalid {} / over-budget {})",
        stats.total_drops(),
        rej.total(),
        rej.closed,
        rej.unsupported,
        rej.invalid,
        rej.over_budget
    );
    println!(
        "serving peak {:.2} MiB (model resident {:.2} MiB)",
        ledger.peak_mib(),
        ledger.peak_for(crate::model::RESIDENT_TAG) as f64 / (1 << 20) as f64
    );
    if let Some(p) = &trace_out {
        write_trace(p)?;
    }
    Ok(())
}

/// `rpiq generate` — greedy streaming decode of one prompt through the
/// paged KV cache, printed beside the recompute-from-scratch oracle: the
/// two must emit identical tokens (the decode determinism contract), and
/// the cached path's per-token cost is `O(S)` instead of `O(S²)`.
pub fn generate(args: &mut Args) -> Result<()> {
    let ckpt = args.opt("ckpt").map(PathBuf::from);
    let qckpt = args.opt("qckpt").map(PathBuf::from);
    let prompt_text = args.get("prompt", "sentiment of text : i loved this movie answer :");
    let max_tokens = args.usize_of("max-tokens", 8)?;
    let trace_out = args
        .opt("trace")
        .map(PathBuf::from)
        .or_else(|| args.flag("trace").then(|| PathBuf::from("generate-trace.json")));
    let method = parse_method(args)?;
    let cfg = quant_cfg(args)?;
    args.finish()?;
    if max_tokens == 0 {
        bail!("--max-tokens must be at least 1");
    }
    if trace_out.is_some() {
        crate::trace::start();
    }
    let w = world();
    let tok = w.tokenizer().clone();
    let model: Arc<QuantizedLm> = match (&qckpt, &ckpt) {
        (Some(_), Some(_)) => bail!("pass exactly one of --ckpt / --qckpt"),
        (Some(p), None) => Arc::new(load_qlm(p)?),
        (None, Some(p)) => {
            let weights = load_lm(p)?;
            let windows = w.calib_windows(weights.config.seq_len, exp::CALIB_SAMPLES);
            Arc::new(quantize_lm(&weights, &windows, cfg, method)?.model)
        }
        (None, None) => bail!("rpiq generate needs --ckpt or --qckpt"),
    };
    let mcfg = model.config().clone();
    // Same context arithmetic as the serve lane: the longest embedded
    // prefix is prompt + max_tokens − 1 rows, so left-truncate the prompt
    // to seq_len + 1 − max_tokens.
    let keep = (mcfg.seq_len + 1).saturating_sub(max_tokens);
    if keep == 0 {
        bail!("--max-tokens {max_tokens} exceeds the model context {}", mcfg.seq_len);
    }
    let mut prompt = tok.encode(&prompt_text);
    if prompt.is_empty() {
        bail!("--prompt produced no tokens");
    }
    if prompt.len() > keep {
        let cut = prompt.len() - keep;
        prompt.drain(..cut);
    }
    let ledger = crate::metrics::MemoryLedger::new();
    let pages = mcfg.n_layers * mcfg.seq_len.div_ceil(crate::model::PAGE_SLOTS);
    let pool = crate::model::KvPool::new(mcfg.n_layers, mcfg.d_model, pages, ledger.clone());
    let t0 = std::time::Instant::now();
    let out = model.generate(&pool, &prompt, max_tokens, None)?;
    let cached_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let oracle = model.generate_recompute(&prompt, max_tokens, None)?;
    let recompute_s = t1.elapsed().as_secs_f64();
    anyhow::ensure!(out == oracle, "cached decode diverged from the recompute oracle");
    println!("prompt ({} tokens): {}", prompt.len(), tok.decode(&prompt));
    println!("output ({} tokens): {}", out.len(), tok.decode(&out));
    let cached_tps = out.len() as f64 / cached_s.max(1e-12);
    let recompute_tps = oracle.len() as f64 / recompute_s.max(1e-12);
    println!(
        "cached {cached_tps:.1} tok/s | recompute {recompute_tps:.1} tok/s | speedup {:.2}x | kv peak {:.1} KiB (pool {} pages, all free: {})",
        cached_tps / recompute_tps.max(1e-12),
        ledger.peak_for(crate::metrics::tags::KV_CACHE) as f64 / 1024.0,
        pool.capacity_pages(),
        pool.free_pages() == pool.capacity_pages()
    );
    if let Some(p) = &trace_out {
        write_trace(p)?;
    }
    Ok(())
}

/// One heartbeat line while the replay runs: queue depth, per-lane
/// p50/p99, drop/reject totals, ledger live/peak.
fn print_heartbeat(server: &Server, ledger: &crate::metrics::MemoryLedger) {
    let stats = &server.stats;
    let mut lanes = String::new();
    for name in stats.lane_names() {
        if let Some(l) = stats.lane(&name) {
            lanes.push_str(&format!(
                " | {name} n={} p50={:.1}ms p99={:.1}ms",
                l.count(),
                l.percentile_ms(50.0),
                l.percentile_ms(99.0)
            ));
        }
    }
    println!(
        "[serve] qdepth={}{lanes} | drops={} rejects={} | mem live={:.1} MiB peak={:.1} MiB",
        server.queue_depth(),
        stats.total_drops(),
        stats.rejects().total(),
        ledger.live_bytes() as f64 / (1 << 20) as f64,
        ledger.peak_mib()
    );
}

/// `rpiq inspect` — describe a checkpoint (fp32 or quantized `.rpiq`).
pub fn inspect(args: &mut Args) -> Result<()> {
    let ckpt = PathBuf::from(args.require("ckpt")?);
    args.finish()?;
    if is_qlm(&ckpt) {
        let m = load_qlm(&ckpt)?;
        let c = m.config();
        println!("quantized LM {} (nibble-resident .rpiq)", c.name);
        println!(
            "  d_model={} layers={} heads={} d_ff={} vocab={} seq={} tied={}",
            c.d_model, c.n_layers, c.n_heads, c.d_ff, c.vocab, c.seq_len, c.tied_head
        );
        print_qlinear_summary(&m.qlinears, m.deploy_bytes(), c.fp32_bytes());
    } else if is_qvlm(&ckpt) {
        let m = load_qvlm(&ckpt)?;
        let c = m.config().clone();
        println!("quantized VLM {} (nibble-resident .rpiq)", c.name);
        println!("  patches {} x dim {}", c.n_patches, c.patch_dim);
        println!("  vision d={} blocks={}", c.d_vision, c.n_vision_blocks);
        print_qlinear_summary(&m.qlinears, m.deploy_bytes(), c.fp32_bytes());
    } else if is_vlm(&ckpt) {
        let w = load_vlm(&ckpt)?;
        println!("VLM {}", w.config.name);
        println!("  patches {} x dim {}", w.config.n_patches, w.config.patch_dim);
        println!("  vision d={} blocks={}", w.config.d_vision, w.config.n_vision_blocks);
        println!("  lm d={} L={} params={}", w.config.lm.d_model, w.config.lm.n_layers, w.n_params());
    } else {
        let w = load_lm(&ckpt)?;
        let c = &w.config;
        println!("LM {}", c.name);
        println!(
            "  d_model={} layers={} heads={} d_ff={} vocab={} seq={} act={:?} tied={}",
            c.d_model, c.n_layers, c.n_heads, c.d_ff, c.vocab, c.seq_len, c.activation, c.tied_head
        );
        println!("  params={} ({:.2} MiB fp32)", c.n_params(), c.fp32_bytes() as f64 / (1 << 20) as f64);
    }
    Ok(())
}

fn print_qlinear_summary(
    qlinears: &crate::quant::QLinearStore,
    deploy_bytes: usize,
    fp_bytes: usize,
) {
    let mut bit_counts: Vec<(u32, usize)> = Vec::new();
    for q in qlinears.linears() {
        match bit_counts.iter_mut().find(|(b, _)| *b == q.grid.bits) {
            Some((_, n)) => *n += 1,
            None => bit_counts.push((q.grid.bits, 1)),
        }
    }
    bit_counts.sort_unstable();
    let grids: Vec<String> =
        bit_counts.iter().map(|(b, n)| format!("{n}x{b}-bit")).collect();
    println!("  linears: {} ({})", qlinears.len(), grids.join(", "));
    println!(
        "  resident {:.2} MiB = {:.1}% of fp32 {:.2} MiB",
        deploy_bytes as f64 / (1 << 20) as f64,
        100.0 * deploy_bytes as f64 / fp_bytes as f64,
        fp_bytes as f64 / (1 << 20) as f64
    );
}

/// `rpiq artifacts` — validate the AOT bundle and smoke-run an entry.
pub fn artifacts(args: &mut Args) -> Result<()> {
    let dir = PathBuf::from(args.get("dir", "artifacts"));
    args.finish()?;
    let engine = crate::runtime::Engine::new(&dir)?;
    println!("platform: {}", engine.platform());
    let mut names: Vec<&String> = engine.registry.entries.keys().collect();
    names.sort();
    for n in &names {
        let e = &engine.registry.entries[*n];
        println!("  {n}: {} inputs, {} outputs", e.inputs.len(), e.outputs.len());
    }
    // smoke-run the kernel self-check entry if present
    if engine.registry.entries.contains_key("selfcheck_add") {
        let x = crate::tensor::Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let out = engine.run("selfcheck_add", &[crate::runtime::Arg::F32(x)])?;
        anyhow::ensure!(out[0].data() == [2.0, 4.0, 6.0, 8.0], "selfcheck_add numerics");
        println!("selfcheck_add OK");
    }
    Ok(())
}

fn sniff_magic(path: &Path) -> Option<[u8; 8]> {
    let mut f = std::fs::File::open(path).ok()?;
    use std::io::Read;
    let mut m = [0u8; 8];
    f.read_exact(&mut m).ok()?;
    Some(m)
}

fn is_vlm(path: &Path) -> bool {
    sniff_magic(path).as_ref() == Some(b"RPIQVLM1")
}

fn is_qlm(path: &Path) -> bool {
    sniff_magic(path).as_ref() == Some(crate::model::io::QLM_MAGIC)
}

fn is_qvlm(path: &Path) -> bool {
    sniff_magic(path).as_ref() == Some(crate::vlm::io::QVLM_MAGIC)
}
