//! CLI command implementations — thin wrappers over the library.

use super::args::Args;
use crate::coordinator::experiments::{self as exp, World};
use crate::coordinator::{quantize_lm, quantize_vlm, replay_mixed, Method, ServeConfig, Server};
use crate::model::io::{load_lm, save_lm};
use crate::model::ModelConfig;
use crate::quant::{CmdqPolicy, QuantConfig, RpiqParams};
use crate::report::Table;

use crate::vlm::io::{load_vlm, save_vlm};
use crate::vlm::VlmConfig;
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn world() -> World {
    World::build(exp::WORLD_SEED)
}

/// `rpiq pretrain` — train the subject checkpoints.
pub fn pretrain(args: &mut Args) -> Result<()> {
    let all = args.flag("all");
    let preset = args.opt("preset");
    let out_dir = PathBuf::from(args.get("out-dir", "checkpoints"));
    let lm_steps = args.usize_of("steps", exp::DEFAULT_LM_STEPS)?;
    let vlm_steps = args.usize_of("vlm-steps", exp::DEFAULT_VLM_STEPS)?;
    let seed = args.u64_of("seed", exp::WORLD_SEED)?;
    args.finish()?;

    let w = world();
    let vocab = w.tokenizer().vocab_size();
    let presets: Vec<ModelConfig> = match (&preset, all) {
        (Some(name), _) if name != "vlm" => {
            vec![ModelConfig::preset(name, vocab)
                .ok_or_else(|| anyhow::anyhow!("unknown preset '{name}'"))?]
        }
        (Some(_), _) => vec![],
        (None, true) => ModelConfig::lm_presets(vocab),
        (None, false) => bail!("pass --all or --preset NAME (or --preset vlm)"),
    };

    for cfg in &presets {
        let t0 = std::time::Instant::now();
        println!("== pretraining {} ({} params) ==", cfg.name, cfg.n_params());
        let (weights, curve) = exp::pretrain_lm(
            cfg,
            &w,
            lm_steps,
            exp::DEFAULT_LM_BATCH,
            seed,
            |s, l| println!("  step {s:4}  loss {l:.4}"),
        );
        let path = exp::ckpt_path(&out_dir, &cfg.name);
        save_lm(&weights, &path)?;
        let first = curve.first().unwrap().1;
        let last = curve.last().unwrap().1;
        println!(
            "  saved {} (loss {first:.3} -> {last:.3}, {:.1}s)",
            path.display(),
            t0.elapsed().as_secs_f64()
        );
        // loss curve alongside the checkpoint (e2e evidence)
        let csv = crate::report::csv(
            &["step", "loss"],
            &curve
                .iter()
                .map(|(s, l)| vec![s.to_string(), format!("{l:.6}")])
                .collect::<Vec<_>>(),
        );
        std::fs::write(out_dir.join(format!("{}.loss.csv", cfg.name)), csv)?;
    }

    if all || preset.as_deref() == Some("vlm") {
        let vcfg = VlmConfig::sim_cogvlm2(vocab);
        println!("== pretraining {} ==", vcfg.name);
        let t0 = std::time::Instant::now();
        let (weights, curve) = exp::pretrain_vlm(
            &vcfg,
            &w,
            vlm_steps,
            exp::DEFAULT_VLM_BATCH,
            seed,
            |s, l| println!("  step {s:4}  loss {l:.4}"),
        );
        let path = exp::ckpt_path(&out_dir, &vcfg.name);
        save_vlm(&weights, &path)?;
        println!(
            "  saved {} (loss {:.3} -> {:.3}, {:.1}s)",
            path.display(),
            curve.first().unwrap().1,
            curve.last().unwrap().1,
            t0.elapsed().as_secs_f64()
        );
        let csv = crate::report::csv(
            &["step", "loss"],
            &curve
                .iter()
                .map(|(s, l)| vec![s.to_string(), format!("{l:.6}")])
                .collect::<Vec<_>>(),
        );
        std::fs::write(out_dir.join(format!("{}.loss.csv", vcfg.name)), csv)?;
    }
    Ok(())
}

fn parse_method(args: &mut Args) -> Result<Method> {
    let m = args.get("method", "rpiq");
    let iters = args.usize_of("iters", 5)?;
    let alpha = args.f32_of("alpha", RpiqParams::default().alpha)?;
    Ok(match m.as_str() {
        "gptq" => Method::Gptq,
        "rpiq" => Method::Rpiq(RpiqParams { max_iters: iters, alpha, ..Default::default() }),
        other => bail!("unknown method '{other}' (gptq|rpiq)"),
    })
}

/// The CMDQ policy a VLM is quantized under for a given method (shared by
/// `quantize` and `serve`).
fn vlm_policy(method: Method) -> CmdqPolicy {
    CmdqPolicy {
        rpiq: match method {
            Method::Rpiq(p) => p,
            Method::Gptq => RpiqParams::default(),
        },
        ..Default::default()
    }
}

fn quant_cfg(args: &mut Args) -> Result<QuantConfig> {
    Ok(QuantConfig {
        bits: args.usize_of("bits", 4)? as u32,
        group_size: args.usize_of("group-size", 128)?,
        block_size: args.usize_of("block-size", 128)?,
        percdamp: args.f32_of("percdamp", 0.01)?,
    })
}

/// `rpiq quantize` — quantize a checkpoint, print the per-layer report.
pub fn quantize(args: &mut Args) -> Result<()> {
    let ckpt = PathBuf::from(args.require("ckpt")?);
    let method = parse_method(args)?;
    let cfg = quant_cfg(args)?;
    args.finish()?;

    let w = world();
    if is_vlm(&ckpt) {
        let weights = load_vlm(&ckpt)?;
        let policy = vlm_policy(method);
        let samples = w.vlm_calib(exp::CALIB_SAMPLES_VLM);
        let out = quantize_vlm(&weights, &samples, &policy, method)?;
        print_reports(&out.reports, out.ledger.peak_mib(), out.timers.total());
    } else {
        let weights = load_lm(&ckpt)?;
        let windows = w.calib_windows(weights.config.seq_len, exp::CALIB_SAMPLES);
        let out = quantize_lm(&weights, &windows, cfg, method)?;
        print_reports(&out.reports, out.ledger.peak_mib(), out.timers.total());
    }
    Ok(())
}

fn print_reports(reports: &[crate::coordinator::LayerReport], peak_mib: f64, secs: f64) {
    let mut t = Table::new(
        "Per-layer quantization report",
        &["layer", "init loss", "final loss", "reduction %", "iters", "early stop"],
    );
    for r in reports {
        t.row(vec![
            r.name.clone(),
            format!("{:.4}", r.initial_loss()),
            format!("{:.4}", r.final_loss()),
            format!("{:.2}", r.reduction_pct()),
            r.iters_run.to_string(),
            r.early_stopped.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("peak memory: {peak_mib:.2} MiB, total time: {secs:.2}s");
}

/// `rpiq eval` — accuracy + PPL of fp/gptq/rpiq arms of one checkpoint.
pub fn eval(args: &mut Args) -> Result<()> {
    let ckpt = PathBuf::from(args.require("ckpt")?);
    let arm = args.get("method", "fp");
    let n_test = args.usize_of("n-test", 200)?;
    let n_windows = args.usize_of("n-windows", 40)?;
    let cfg = quant_cfg(args)?;
    let method = match arm.as_str() {
        "fp" => None,
        _ => Some(parse_method_named(&arm, args)?),
    };
    args.finish()?;

    let w = world();
    if is_vlm(&ckpt) {
        let weights = load_vlm(&ckpt)?;
        let rep = match method {
            None => exp::eval_vlm_fp(&weights, &w),
            Some(m) => {
                let policy = CmdqPolicy::default();
                let samples = w.vlm_calib(exp::CALIB_SAMPLES_VLM);
                let out = quantize_vlm(&weights, &samples, &policy, m)?;
                exp::eval_vlm_q(&out.model, &w)
            }
        };
        println!("overall: {:.2}%", rep.overall_pct);
        for (cat, acc) in &rep.per_category {
            println!("  {cat:12} {acc:.2}%");
        }
    } else {
        let weights = load_lm(&ckpt)?;
        let ev = match method {
            None => exp::eval_lm_fp(&weights, &w, n_windows, n_test),
            Some(m) => {
                let windows = w.calib_windows(weights.config.seq_len, exp::CALIB_SAMPLES);
                let out = quantize_lm(&weights, &windows, cfg, m)?;
                exp::eval_lm_q(&out.model, &w, n_windows, n_test)
            }
        };
        println!("sentiment acc: {:.2}%   ppl: {:.3}", ev.acc_pct, ev.ppl);
    }
    Ok(())
}

fn parse_method_named(name: &str, args: &mut Args) -> Result<Method> {
    let iters = args.usize_of("iters", 5)?;
    let alpha = args.f32_of("alpha", RpiqParams::default().alpha)?;
    Ok(match name {
        "gptq" => Method::Gptq,
        "rpiq" => Method::Rpiq(RpiqParams { max_iters: iters, alpha, ..Default::default() }),
        other => bail!("unknown method '{other}'"),
    })
}

/// `rpiq serve` — quantize checkpoint(s) and serve a replay workload
/// through the multi-lane engine, printing overall + per-lane latency.
///
/// `--mode sentiment` (default) serves an LM checkpoint; `--mode vqa`
/// serves a VLM checkpoint (`--ckpt` if it is a VLM file, or
/// `--vlm-ckpt`); `--mode mixed` serves both lanes side by side
/// (`--ckpt` LM + `--vlm-ckpt` VLM).
pub fn serve(args: &mut Args) -> Result<()> {
    let mode = args.get("mode", "sentiment");
    let ckpt = args.opt("ckpt").map(PathBuf::from);
    let vlm_ckpt = args.opt("vlm-ckpt").map(PathBuf::from);
    let n_requests = args.usize_of("requests", 100)?;
    let n_clients = args.usize_of("clients", 4)?;
    let max_batch = args.usize_of("max-batch", 8)?;
    let lanes = args.usize_of("lanes", 2)?;
    let method = parse_method(args)?;
    let cfg = quant_cfg(args)?;
    args.finish()?;

    let w = world();
    let tok = w.tokenizer().clone();
    let scfg = ServeConfig { max_batch, lanes, ..Default::default() };

    let want_lm = mode != "vqa";
    let want_vlm = mode != "sentiment";
    if !matches!(mode.as_str(), "sentiment" | "vqa" | "mixed") {
        bail!("unknown mode '{mode}' (sentiment|vqa|mixed)");
    }

    let qlm = if want_lm {
        let path = ckpt
            .clone()
            .ok_or_else(|| anyhow::anyhow!("--mode {mode} needs --ckpt (LM checkpoint)"))?;
        if is_vlm(&path) {
            bail!(
                "--ckpt {} is a VLM checkpoint; pass the LM via --ckpt (or use --mode vqa)",
                path.display()
            );
        }
        let weights = load_lm(&path)?;
        let windows = w.calib_windows(weights.config.seq_len, exp::CALIB_SAMPLES);
        let out = quantize_lm(&weights, &windows, cfg, method)?;
        println!(
            "lm deploy bytes: {:.2} MiB (fp32 {:.2} MiB)",
            out.model.deploy_bytes() as f64 / (1 << 20) as f64,
            weights.config.fp32_bytes() as f64 / (1 << 20) as f64
        );
        Some(Arc::new(out.model))
    } else {
        None
    };

    let qvlm = if want_vlm {
        // the VLM may arrive as --vlm-ckpt, or as --ckpt in pure vqa mode
        let path = match (&vlm_ckpt, &ckpt) {
            (Some(p), _) => p.clone(),
            (None, Some(p)) if mode == "vqa" && is_vlm(p) => p.clone(),
            _ => bail!("--mode {mode} needs --vlm-ckpt (VLM checkpoint)"),
        };
        let weights = load_vlm(&path)?;
        let policy = vlm_policy(method);
        let samples = w.vlm_calib(exp::CALIB_SAMPLES_VLM);
        let out = quantize_vlm(&weights, &samples, &policy, method)?;
        println!(
            "vlm deploy bytes: {:.2} MiB (fp32 {:.2} MiB)",
            out.model.deploy_bytes() as f64 / (1 << 20) as f64,
            (weights.n_params() * 4) as f64 / (1 << 20) as f64
        );
        Some(Arc::new(out.model))
    } else {
        None
    };

    let server = match (qlm, qvlm) {
        (Some(lm), Some(vlm)) => Server::start_mixed(lm, vlm, &tok, scfg),
        (Some(lm), None) => Server::start(lm, &tok, scfg),
        (None, Some(vlm)) => Server::start_vqa(vlm, &tok, scfg),
        (None, None) => unreachable!("mode resolution left no model"),
    };

    // Replay workload: sentiment prompts and/or VQA pairs from the world's
    // test sets, interleaved in mixed mode.
    let tput = replay_mixed(&server, w.replay_items(&mode, n_requests), n_clients);
    let stats = server.shutdown();
    println!(
        "served {} requests over {} lane(s): {:.1} req/s, mean {:.2} ms, p50 {:.2} ms, p95 {:.2} ms",
        stats.count(),
        lanes.max(1),
        tput,
        stats.mean_ms(),
        stats.percentile_ms(50.0),
        stats.percentile_ms(95.0)
    );
    for name in stats.lane_names() {
        let l = stats.lane(&name).expect("named lane exists");
        println!(
            "  lane {name:9} {:4} reqs  p50 {:.2} ms  p95 {:.2} ms",
            l.count(),
            l.percentile_ms(50.0),
            l.percentile_ms(95.0)
        );
    }
    Ok(())
}

/// `rpiq inspect` — describe a checkpoint.
pub fn inspect(args: &mut Args) -> Result<()> {
    let ckpt = PathBuf::from(args.require("ckpt")?);
    args.finish()?;
    if is_vlm(&ckpt) {
        let w = load_vlm(&ckpt)?;
        println!("VLM {}", w.config.name);
        println!("  patches {} x dim {}", w.config.n_patches, w.config.patch_dim);
        println!("  vision d={} blocks={}", w.config.d_vision, w.config.n_vision_blocks);
        println!("  lm d={} L={} params={}", w.config.lm.d_model, w.config.lm.n_layers, w.n_params());
    } else {
        let w = load_lm(&ckpt)?;
        let c = &w.config;
        println!("LM {}", c.name);
        println!(
            "  d_model={} layers={} heads={} d_ff={} vocab={} seq={} act={:?} tied={}",
            c.d_model, c.n_layers, c.n_heads, c.d_ff, c.vocab, c.seq_len, c.activation, c.tied_head
        );
        println!("  params={} ({:.2} MiB fp32)", c.n_params(), c.fp32_bytes() as f64 / (1 << 20) as f64);
    }
    Ok(())
}

/// `rpiq artifacts` — validate the AOT bundle and smoke-run an entry.
pub fn artifacts(args: &mut Args) -> Result<()> {
    let dir = PathBuf::from(args.get("dir", "artifacts"));
    args.finish()?;
    let engine = crate::runtime::Engine::new(&dir)?;
    println!("platform: {}", engine.platform());
    let mut names: Vec<&String> = engine.registry.entries.keys().collect();
    names.sort();
    for n in &names {
        let e = &engine.registry.entries[*n];
        println!("  {n}: {} inputs, {} outputs", e.inputs.len(), e.outputs.len());
    }
    // smoke-run the kernel self-check entry if present
    if engine.registry.entries.contains_key("selfcheck_add") {
        let x = crate::tensor::Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let out = engine.run("selfcheck_add", &[crate::runtime::Arg::F32(x)])?;
        anyhow::ensure!(out[0].data() == [2.0, 4.0, 6.0, 8.0], "selfcheck_add numerics");
        println!("selfcheck_add OK");
    }
    Ok(())
}

fn is_vlm(path: &Path) -> bool {
    // sniff the magic
    if let Ok(mut f) = std::fs::File::open(path) {
        use std::io::Read;
        let mut m = [0u8; 8];
        if f.read_exact(&mut m).is_ok() {
            return &m == b"RPIQVLM1";
        }
    }
    false
}
