//! The quantization core: the paper's contribution.
//!
//! * [`grid`]  — the 4-bit/8-bit asymmetric, group-wise quantization grid
//!   (`Q(·)` in the paper), nibble packing, and round-to-nearest baseline.
//! * [`calib`] — Hessian accumulation `H ≈ XᵀX` over the calibration
//!   stream and the **single-instance store** (last batch `X_last`,
//!   `Y_orig` retained in memory — paper §3.2).
//! * [`gptq`]  — stage 1: GPTQ blockwise greedy quantization with Cholesky
//!   error feedback (the baseline, and RPIQ's initializer).
//! * [`rpiq`]  — stage 2: the residual-projected, multi-collaborative
//!   closed-loop Gauss–Seidel block refinement (paper §3.1/§3.3).
//! * [`cmdq`]  — the cross-modal differentiated quantization policy used
//!   for the VLM experiments (paper §4.1, ref. [39]).

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

pub mod calib;
pub mod cmdq;
pub mod grid;
pub mod gptq;
pub mod rpiq;
pub mod store;

pub use calib::{HessianAccumulator, HessianPartial, SingleInstance};
pub use cmdq::{CmdqPolicy, Modality};
pub use grid::{QuantGrid, QuantizedLinear};
pub use store::QLinearStore;
pub use gptq::{gptq_quantize, GptqOutput};
pub use rpiq::{rpiq_refine, RpiqOutput, RpiqParams};

/// Static quantization configuration for one weight matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantConfig {
    /// Bit width (2..=8 supported; the paper uses 4, CMDQ vision uses 8).
    pub bits: u32,
    /// Group size along the input-channel axis; one (scale, zero) pair per
    /// group per output row. The paper uses 128.
    pub group_size: usize,
    /// GPTQ lazy-update block width (columns quantized before the trailing
    /// weight update is flushed). 128 in the reference implementation.
    pub block_size: usize,
    /// Hessian damping fraction (paper Eq. 10), default 0.01.
    pub percdamp: f32,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig { bits: 4, group_size: 128, block_size: 128, percdamp: 0.01 }
    }
}

impl QuantConfig {
    pub fn with_bits(mut self, bits: u32) -> Self {
        self.bits = bits;
        self
    }

    pub fn with_group_size(mut self, gs: usize) -> Self {
        self.group_size = gs;
        self
    }

    /// Clamp the group/block sizes to the actual number of input channels
    /// (tiny test layers are narrower than the defaults).
    pub fn fitted(mut self, in_features: usize) -> Self {
        self.group_size = self.group_size.min(in_features).max(1);
        self.block_size = self.block_size.min(in_features).max(1);
        self
    }
}
