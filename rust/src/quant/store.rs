//! Name-indexed storage for a model's quantized linears.
//!
//! Deployment models used to hold `HashMap<String, QuantizedLinear>` and
//! rebuild `format!("lm.layer{li}.attn.q")` keys on *every* linear of
//! *every* forward — a per-call heap allocation plus a hash lookup on the
//! hot serve path. [`QLinearStore`] fixes the representation: names are
//! resolved to dense indices once at construction, forwards address
//! linears by index ([`QLinearStore::at`]), and the name table stays
//! around only for (de)serialization, validation, and reporting.
//!
//! Entries are kept sorted by name, so iteration order is deterministic
//! (the `.rpiq` container writers rely on sorted traversal) and `get`
//! is a binary search rather than a hash probe.

use super::grid::QuantizedLinear;
use std::collections::HashMap;

/// Sorted name → quantized-linear table with index addressing.
#[derive(Clone, Debug, Default)]
pub struct QLinearStore {
    /// Sorted, unique names; `linears[i]` belongs to `names[i]`.
    names: Vec<String>,
    linears: Vec<QuantizedLinear>,
}

impl QLinearStore {
    /// Build from a name-keyed map (the quantization pipelines and the
    /// container loaders produce maps). Entries are sorted by name.
    pub fn from_map(map: HashMap<String, QuantizedLinear>) -> Self {
        // ORDER-INSENSITIVE: the pairs are sorted by name immediately
        // below, so hash iteration order cannot reach any observable.
        let mut pairs: Vec<(String, QuantizedLinear)> = map.into_iter().collect();
        pairs.sort_by(|a, b| a.0.cmp(&b.0));
        let mut names = Vec::with_capacity(pairs.len());
        let mut linears = Vec::with_capacity(pairs.len());
        for (n, q) in pairs {
            names.push(n);
            linears.push(q);
        }
        QLinearStore { names, linears }
    }

    /// Number of linears.
    pub fn len(&self) -> usize {
        self.linears.len()
    }

    pub fn is_empty(&self) -> bool {
        self.linears.is_empty()
    }

    /// Dense index of `name`, if present (binary search over the sorted
    /// name table — resolution happens once at model build, never on the
    /// forward path).
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.names.binary_search_by(|n| n.as_str().cmp(name)).ok()
    }

    /// Linear by name (validation/reporting path).
    pub fn get(&self, name: &str) -> Option<&QuantizedLinear> {
        self.index_of(name).and_then(|i| self.linears.get(i))
    }

    /// Linear by dense index — the forward-path accessor. Indices come
    /// from [`Self::index_of`] at model construction and stay valid for
    /// the life of the store (it is append-never after build).
    #[inline]
    pub fn at(&self, idx: usize) -> &QuantizedLinear {
        &self.linears[idx]
    }

    /// `(name, linear)` pairs in sorted name order — deterministic, so
    /// the container writers and summaries need no re-sort.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &QuantizedLinear)> {
        self.names.iter().map(String::as_str).zip(self.linears.iter())
    }

    /// The linears in sorted-name order (accounting walks).
    pub fn linears(&self) -> impl Iterator<Item = &QuantizedLinear> {
        self.linears.iter()
    }

    /// Total packed + group-parameter bytes across all linears.
    pub fn nbytes(&self) -> usize {
        self.linears.iter().map(|q| q.nbytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantGrid;
    use crate::tensor::Tensor;

    fn store_of(names: &[&str]) -> QLinearStore {
        let mut map = HashMap::new();
        for n in names {
            let w = Tensor::zeros(&[4, 8]);
            map.insert(n.to_string(), QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 8)));
        }
        QLinearStore::from_map(map)
    }

    #[test]
    fn sorted_iteration_and_binary_search_agree() {
        let s = store_of(&["lm.layer1.attn.q", "lm.head", "lm.layer0.attn.q"]);
        let names: Vec<&str> = s.iter().map(|(n, _)| n).collect();
        assert_eq!(names, ["lm.head", "lm.layer0.attn.q", "lm.layer1.attn.q"]);
        for (i, n) in names.iter().enumerate() {
            assert_eq!(s.index_of(n), Some(i));
            assert!(s.get(n).is_some());
        }
        assert_eq!(s.index_of("missing"), None);
        assert!(s.get("missing").is_none());
        assert_eq!(s.len(), 3);
        assert!(!s.is_empty());
    }

    #[test]
    fn nbytes_sums_linears() {
        let s = store_of(&["a", "b"]);
        let per: usize = s.get("a").unwrap().nbytes();
        assert_eq!(s.nbytes(), 2 * per);
    }
}
