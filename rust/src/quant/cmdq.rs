//! CMDQ — the cross-modal differentiated quantization framework the paper
//! plugs RPIQ into for the VLM experiments (§4.1, reference [39]).
//!
//! The framework's premise: visual and linguistic components have different
//! quantization sensitivity, so each *modality class* gets its own
//! quantization configuration. In the paper's setup the base method inside
//! the framework is what varies (GPTQ vs RPIQ); the modality policy is
//! fixed. We reproduce that: [`CmdqPolicy`] maps a layer name to a
//! [`Modality`] and a per-modality [`QuantConfig`] + stage-2 toggle.

use super::{QuantConfig, RpiqParams};

/// Modality class of a VLM weight matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Modality {
    /// Vision encoder layers (`vision.` prefix in our VLM).
    Vision,
    /// Cross-modal adapter/projection layers (`cross.` prefix).
    CrossModal,
    /// Language decoder layers (everything else).
    Language,
}

impl Modality {
    /// Classify a layer by its canonical dotted name.
    pub fn of_layer(name: &str) -> Modality {
        // Cross-modal first: adapter layers often mention "vision" in their
        // name (e.g. CogVLM2's `mlp.vision_mlp.up` lives in the cross
        // module), so the prefix check must take precedence.
        if name.starts_with("cross.") || name.contains("cross_modal") {
            Modality::CrossModal
        } else if name.starts_with("vision.") || name.contains(".vision_") {
            Modality::Vision
        } else {
            Modality::Language
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Modality::Vision => "vision",
            Modality::CrossModal => "cross-modal",
            Modality::Language => "language",
        }
    }
}

/// Per-modality differentiated quantization policy.
#[derive(Clone, Copy, Debug)]
pub struct CmdqPolicy {
    pub vision: QuantConfig,
    pub cross_modal: QuantConfig,
    pub language: QuantConfig,
    /// Stage-2 parameters applied when the base method is RPIQ.
    pub rpiq: RpiqParams,
}

impl Default for CmdqPolicy {
    /// The differentiated defaults used in our Table 2 reproduction:
    /// vision tolerates less precision loss, so it keeps 8 bits; the
    /// cross-modal adapter gets 4-bit with a finer group; the language
    /// stack gets the paper's standard 4-bit / group-128.
    fn default() -> Self {
        CmdqPolicy {
            vision: QuantConfig::default().with_bits(8).with_group_size(64),
            cross_modal: QuantConfig::default().with_bits(4).with_group_size(64),
            language: QuantConfig::default().with_bits(4).with_group_size(128),
            rpiq: RpiqParams::default(),
        }
    }
}

impl CmdqPolicy {
    /// Config for a named layer.
    pub fn config_for(&self, layer_name: &str) -> QuantConfig {
        match Modality::of_layer(layer_name) {
            Modality::Vision => self.vision,
            Modality::CrossModal => self.cross_modal,
            Modality::Language => self.language,
        }
    }

    /// Variant with a given stage-2 iteration budget (Table 2's 5 vs 20).
    pub fn with_iters(mut self, iters: usize) -> Self {
        self.rpiq.max_iters = iters;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifies_by_prefix() {
        assert_eq!(Modality::of_layer("vision.block0.fc1"), Modality::Vision);
        assert_eq!(Modality::of_layer("cross.vision_mlp.up"), Modality::CrossModal);
        assert_eq!(Modality::of_layer("lm.layer3.attn.out"), Modality::Language);
        assert_eq!(Modality::of_layer("mlp.vision_proj"), Modality::Vision);
        assert_eq!(Modality::of_layer("encoder.cross_modal.down"), Modality::CrossModal);
    }

    #[test]
    fn default_policy_differentiates() {
        let p = CmdqPolicy::default();
        assert_eq!(p.config_for("vision.fc1").bits, 8);
        assert_eq!(p.config_for("lm.attn.q").bits, 4);
        assert_eq!(p.config_for("cross.proj").group_size, 64);
        assert_eq!(p.config_for("lm.mlp.up").group_size, 128);
    }

    #[test]
    fn with_iters_overrides_budget() {
        let p = CmdqPolicy::default().with_iters(20);
        assert_eq!(p.rpiq.max_iters, 20);
    }
}
