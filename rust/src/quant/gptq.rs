//! Stage 1: GPTQ — blockwise greedy quantization with second-order error
//! feedback (Frantar et al., 2022). This is both the baseline the paper
//! compares against and the initializer RPIQ refines.
//!
//! Algorithm (per linear layer, `W ∈ R^{Cout×Cin}`, damped `H̃`):
//!
//! 1. `U = chol(H̃⁻¹, upper)` — the error-propagation operator.
//! 2. Walk columns left→right in lazy blocks of `block_size`:
//!    a. entering a new *group*, fit (scale, zero) from the **current**
//!       (already error-compensated) weights of that group;
//!    b. quantize column `j`, compute `err_j = (w_j − q_j)/U[j,j]`;
//!    c. propagate `w_k ← w_k − err_j·U[j,k]` for `k` in the rest of the
//!       block (immediately) and for the trailing columns (batched per
//!       block — the "lazy update" that makes GPTQ fast).
//!
//! The unidirectional, one-shot nature of this walk is exactly the
//! inter-block error-accumulation problem the paper's stage 2 attacks.

use super::grid::{QuantGrid, QuantizedLinear};
use super::QuantConfig;
use crate::linalg::{cholesky_inverse_upper, fix_dead_channels};
use crate::metrics::MemoryLedger;
use crate::tensor::Tensor;

/// Output of stage-1 quantization.
pub struct GptqOutput {
    /// Deployment-format quantized weights.
    pub q: QuantizedLinear,
    /// Σ err² accumulated by the greedy walk (the GPTQ objective value).
    pub greedy_loss: f64,
    /// Input channels whose Hessian diagonal was zero (dead — weights
    /// forced to 0, matching the reference implementation).
    pub dead_channels: Vec<usize>,
}

/// Quantize one weight matrix with GPTQ.
///
/// * `w_fp` — `[out, in]` full-precision weights (not mutated).
/// * `h` — damped Hessian `H̃ = XᵀX + λI`, `[in, in]`.
pub fn gptq_quantize(
    w_fp: &Tensor,
    h: &Tensor,
    cfg: QuantConfig,
    ledger: &MemoryLedger,
) -> anyhow::Result<GptqOutput> {
    let cfg = cfg.fitted(w_fp.cols());
    let (out_f, in_f) = (w_fp.rows(), w_fp.cols());
    assert_eq!(h.rows(), in_f);
    assert_eq!(h.cols(), in_f);
    let grid = QuantGrid::new(cfg.bits, cfg.group_size);
    let gs = cfg.group_size;

    // Working copies: W is mutated by error feedback; H may need dead-column
    // fixes before factorization.
    let mut w = w_fp.clone();
    let mut hh = h.clone();
    ledger.alloc("gptq_work", w.nbytes() + hh.nbytes());
    let dead_channels = fix_dead_channels(&mut hh, &mut w);

    // U = chol(H⁻¹, upper); row j of U drives the feedback from column j.
    let u = cholesky_inverse_upper(&hh)
        .map_err(|e| anyhow::anyhow!("GPTQ Hessian factorization failed: {e}"))?;
    ledger.alloc("gptq_hinv", in_f * in_f * 8);

    let mut q = QuantizedLinear::empty(grid, out_f, in_f);
    let ng = q.n_groups();
    let mut greedy_loss = 0.0f64;

    // Per-block error buffer for the lazy trailing update.
    let bs = cfg.block_size;
    let mut err_block = vec![0.0f32; out_f * bs];
    ledger.alloc("gptq_errblock", err_block.len() * 4);

    let mut c0 = 0;
    while c0 < in_f {
        let c1 = (c0 + bs).min(in_f);
        let bw = c1 - c0;
        err_block[..out_f * bw].fill(0.0);

        for j in c0..c1 {
            // (a) group entry: fit params on the *current* weights.
            if j % gs == 0 {
                let g = j / gs;
                let gend = (j + gs).min(in_f);
                for r in 0..out_f {
                    let (scale, zero) = grid.find_params(&w.row(r)[j..gend]);
                    q.scales[r * ng + g] = scale;
                    q.zeros[r * ng + g] = zero;
                }
            }
            let d = u[j * in_f + j] as f32;
            // (b) quantize column j and compute the scaled error.
            for r in 0..out_f {
                let wv = w.at(r, j);
                let qv = grid.quantize_val(wv, q.scale_at(r, j), q.zero_at(r, j));
                q.qweight[r * in_f + j] = qv;
                let dq = grid.dequantize_val(qv, q.scale_at(r, j), q.zero_at(r, j));
                let err = (wv - dq) / d;
                greedy_loss += (err as f64) * (err as f64);
                err_block[r * bs + (j - c0)] = err;
                // (c) immediate feedback within the block.
                let urow = &u[j * in_f..(j + 1) * in_f];
                let wrow = w.row_mut(r);
                for k in j + 1..c1 {
                    wrow[k] -= err * urow[k] as f32;
                }
            }
        }

        // (c') lazy trailing update: W[:, c1:] -= Err · U[c0:c1, c1:].
        if c1 < in_f {
            for r in 0..out_f {
                let wrow = w.row_mut(r);
                for (jj, j) in (c0..c1).enumerate() {
                    let err = err_block[r * bs + jj];
                    if err != 0.0 {
                        let urow = &u[j * in_f..(j + 1) * in_f];
                        for k in c1..in_f {
                            wrow[k] -= err * urow[k] as f32;
                        }
                    }
                }
            }
        }
        c0 = c1;
    }

    ledger.free("gptq_errblock", err_block.len() * 4);
    ledger.free("gptq_hinv", in_f * in_f * 8);
    ledger.free("gptq_work", w.nbytes() + hh.nbytes());

    Ok(GptqOutput { q, greedy_loss, dead_channels })
}

/// Reconstruction loss `‖X·Wᵀ − X·Ŵᵀ‖²` of a quantized matrix on given
/// activations — the metric both stages optimize, used everywhere in the
/// benches.
pub fn reconstruction_loss(x: &Tensor, w_fp: &Tensor, q: &QuantizedLinear) -> f64 {
    let y = crate::tensor::matmul_a_bt(x, w_fp);
    let yq = crate::tensor::matmul_a_bt(x, &q.dequantize());
    y.sub(&yq).frob_sq()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{prop_assert, Runner};
    use crate::quant::calib::HessianAccumulator;
    use crate::rng::Pcg64;

    fn setup(
        out_f: usize,
        in_f: usize,
        n: usize,
        seed: u64,
    ) -> (Tensor, Tensor, Tensor) {
        let mut rng = Pcg64::seeded(seed);
        let x = Tensor::randn(&[n, in_f], 1.0, &mut rng);
        let w = Tensor::randn(&[out_f, in_f], 0.5, &mut rng);
        let mut acc = HessianAccumulator::new(in_f, MemoryLedger::new());
        acc.add_batch(&x);
        let (h, _) = acc.finalize(0.01);
        (x, w, h)
    }

    #[test]
    fn gptq_beats_rtn_on_reconstruction() {
        // The whole point of GPTQ: error feedback lowers XW reconstruction
        // loss vs round-to-nearest at equal bit width.
        let (x, w, h) = setup(16, 64, 128, 61);
        let cfg = QuantConfig { bits: 4, group_size: 16, block_size: 16, percdamp: 0.01 };
        let ledger = MemoryLedger::new();
        let out = gptq_quantize(&w, &h, cfg, &ledger).unwrap();
        let rtn = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 16));
        let l_gptq = reconstruction_loss(&x, &w, &out.q);
        let l_rtn = reconstruction_loss(&x, &w, &rtn);
        assert!(
            l_gptq < l_rtn,
            "gptq {l_gptq} should beat rtn {l_rtn}"
        );
    }

    #[test]
    fn gptq_exact_when_grid_is_fine() {
        // With 8 bits and tiny weights the quantization error is ~0 and the
        // output must match the fp weights closely.
        let (x, w, h) = setup(4, 16, 32, 62);
        let cfg = QuantConfig { bits: 8, group_size: 16, block_size: 8, percdamp: 0.01 };
        let out = gptq_quantize(&w, &h, cfg, &MemoryLedger::new()).unwrap();
        let rel = reconstruction_loss(&x, &w, &out.q)
            / crate::tensor::matmul_a_bt(&x, &w).frob_sq().max(1e-12);
        assert!(rel < 1e-3, "rel={rel}");
    }

    #[test]
    fn dead_channels_are_zeroed() {
        let mut rng = Pcg64::seeded(63);
        let n = 32;
        let in_f = 8;
        let mut x = Tensor::randn(&[n, in_f], 1.0, &mut rng);
        // kill channel 3
        for r in 0..n {
            x.row_mut(r)[3] = 0.0;
        }
        let w = Tensor::randn(&[4, in_f], 0.5, &mut rng);
        let mut acc = HessianAccumulator::new(in_f, MemoryLedger::new());
        acc.add_batch(&x);
        // no damping on the dead channel: finalize would damp it, so build
        // H manually without damping to exercise the fix path
        let h = acc.hessian().clone();
        let cfg = QuantConfig { bits: 4, group_size: 4, block_size: 4, percdamp: 0.01 };
        let out = gptq_quantize(&w, &h, cfg, &MemoryLedger::new()).unwrap();
        assert_eq!(out.dead_channels, vec![3]);
        for r in 0..4 {
            assert_eq!(out.q.deq_at(r, 3), 0.0, "row {r}");
        }
    }

    #[test]
    fn ledger_returns_to_zero() {
        let (_, w, h) = setup(8, 32, 64, 64);
        let ledger = MemoryLedger::new();
        let _ = gptq_quantize(&w, &h, QuantConfig::default(), &ledger).unwrap();
        assert_eq!(ledger.live_bytes(), 0);
        assert!(ledger.peak_bytes() > 0);
    }

    #[test]
    fn block_size_does_not_change_result_much_property() {
        // The lazy block update is an exact algebraic regrouping; results
        // across block sizes must agree to float tolerance.
        Runner::new("gptq_blocksize_invariance", 10).run(|g| {
            let in_f = 4 * g.usize_in(2..6);
            let out_f = g.usize_in(2..6);
            let n = in_f * 2;
            let xd = g.matrix(n, in_f, 1.0);
            let wd = g.matrix(out_f, in_f, 0.5);
            let x = Tensor::from_vec(&[n, in_f], xd);
            let w = Tensor::from_vec(&[out_f, in_f], wd);
            let mut acc = HessianAccumulator::new(in_f, MemoryLedger::new());
            acc.add_batch(&x);
            let (h, _) = acc.finalize(0.01);
            let led = MemoryLedger::new();
            let cfg1 = QuantConfig { bits: 4, group_size: 4, block_size: 4, percdamp: 0.01 };
            let cfg2 = QuantConfig { bits: 4, group_size: 4, block_size: in_f, percdamp: 0.01 };
            let q1 = gptq_quantize(&w, &h, cfg1, &led).unwrap();
            let q2 = gptq_quantize(&w, &h, cfg2, &led).unwrap();
            let d = q1.q.dequantize().max_abs_diff(&q2.q.dequantize());
            prop_assert(d < 2e-2, &format!("block regrouping exact-ish, d={d}"))
        });
    }

    #[test]
    fn group_params_written_for_every_group() {
        let (_, w, h) = setup(4, 20, 40, 65);
        let cfg = QuantConfig { bits: 4, group_size: 8, block_size: 8, percdamp: 0.01 };
        let out = gptq_quantize(&w, &h, cfg, &MemoryLedger::new()).unwrap();
        assert_eq!(out.q.n_groups(), 3); // ceil(20/8)
        assert!(out.q.scales.iter().all(|&s| s > 0.0));
    }
}
