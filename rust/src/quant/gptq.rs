//! Stage 1: GPTQ — blockwise greedy quantization with second-order error
//! feedback (Frantar et al., 2022). This is both the baseline the paper
//! compares against and the initializer RPIQ refines.
//!
//! Algorithm (per linear layer, `W ∈ R^{Cout×Cin}`, damped `H̃`):
//!
//! 1. `U = chol(H̃⁻¹, upper)` — the error-propagation operator.
//! 2. Walk columns left→right in lazy blocks of `block_size`:
//!    a. entering a new *group*, fit (scale, zero) from the **current**
//!       (already error-compensated) weights of that group;
//!    b. quantize column `j`, compute `err_j = (w_j − q_j)/U[j,j]`;
//!    c. propagate `w_k ← w_k − err_j·U[j,k]` for `k` in the rest of the
//!       block (immediately) and for the trailing columns (batched per
//!       block — the "lazy update" that makes GPTQ fast).
//!
//! The unidirectional, one-shot nature of this walk is exactly the
//! inter-block error-accumulation problem the paper's stage 2 attacks.
//!
//! # Row-sharded inner loops
//!
//! The column walk is sequential *per row* by construction, but rows never
//! interact: row `r`'s group fits, rounding, and error feedback read and
//! write only row `r` of `W`/the level buffer/`scales`/`zeros` (the
//! Cholesky factor `U` is shared read-only). The walk therefore shards **output
//! rows** across the global pool — each worker runs the complete
//! multi-block walk over its own disjoint row chunk via the same
//! [`gptq_walk_rows`] kernel the sequential path uses, so results are
//! bit-identical at any thread count. Problems under the matmul flop
//! cutoff (`tensor::shard_count`, with `flops ≈ out·in²` for the feedback
//! updates) stay on the calling thread. `greedy_loss` is accumulated per
//! row and folded in ascending row order after the join, making it
//! thread-count-invariant too.

use super::grid::{QuantGrid, QuantizedLinear};
use super::QuantConfig;
use crate::linalg::{cholesky_inverse_upper, fix_dead_channels};
use crate::metrics::{tags, MemoryLedger};
use crate::tensor::Tensor;

/// Output of stage-1 quantization.
pub struct GptqOutput {
    /// Deployment-format quantized weights.
    pub q: QuantizedLinear,
    /// Σ err² accumulated by the greedy walk (the GPTQ objective value).
    pub greedy_loss: f64,
    /// Input channels whose Hessian diagonal was zero (dead — weights
    /// forced to 0, matching the reference implementation).
    pub dead_channels: Vec<usize>,
}

/// Quantize one weight matrix with GPTQ.
///
/// * `w_fp` — `[out, in]` full-precision weights (not mutated).
/// * `h` — damped Hessian `H̃ = XᵀX + λI`, `[in, in]`.
pub fn gptq_quantize(
    w_fp: &Tensor,
    h: &Tensor,
    cfg: QuantConfig,
    ledger: &MemoryLedger,
) -> anyhow::Result<GptqOutput> {
    let cfg = cfg.fitted(w_fp.cols());
    let (out_f, in_f) = (w_fp.rows(), w_fp.cols());
    assert_eq!(h.rows(), in_f);
    assert_eq!(h.cols(), in_f);
    let grid = QuantGrid::new(cfg.bits, cfg.group_size);

    // Working copies: W is mutated by error feedback; H may need dead-column
    // fixes before factorization.
    let mut w = w_fp.clone();
    let mut hh = h.clone();
    ledger.alloc(tags::GPTQ_WORK, w.nbytes() + hh.nbytes());
    let dead_channels = fix_dead_channels(&mut hh, &mut w);

    // U = chol(H⁻¹, upper); row j of U drives the feedback from column j.
    let u = cholesky_inverse_upper(&hh)
        .map_err(|e| anyhow::anyhow!("GPTQ Hessian factorization failed: {e}"))?;
    ledger.alloc(tags::GPTQ_HINV, in_f * in_f * 8);

    // The walk mutates levels column-by-column, so it runs over a
    // transient byte-per-level working buffer; the resident nibble-packed
    // form is built once at the end (`QuantizedLinear::from_levels`).
    let ng = grid.n_groups(in_f);
    let mut levels = vec![0u8; out_f * in_f];
    let mut scales = vec![1.0f32; out_f * ng];
    let mut zeros = vec![0.0f32; out_f * ng];
    ledger.alloc(tags::GPTQ_LEVELS, levels.len());
    let bs = cfg.block_size;

    // Rows are independent (see module docs): shard the complete walk
    // across output rows on the pool, with the matmul flop heuristic
    // deciding when forking is worth it (feedback work ≈ out·in² MACs).
    let shards = crate::tensor::shard_count(out_f, out_f * in_f * in_f);
    // Per-shard error buffer for the lazy trailing update.
    ledger.alloc(tags::GPTQ_ERRBLOCK, shards * bs * 4);
    // Per-row Σ err² subtotals, folded in row order after the join so the
    // greedy objective is identical at any shard count.
    let mut row_loss = vec![0.0f64; out_f];
    ledger.alloc(tags::GPTQ_ROWLOSS, out_f * 8);

    if shards <= 1 {
        gptq_walk_rows(
            w.data_mut(),
            &mut levels,
            &mut scales,
            &mut zeros,
            &mut row_loss,
            &u,
            grid,
            bs,
        );
    } else {
        let rows_per = out_f.div_ceil(shards);
        let u_ref = &u[..];
        let w_chunks = w.data_mut().chunks_mut(rows_per * in_f);
        let q_chunks = levels.chunks_mut(rows_per * in_f);
        let s_chunks = scales.chunks_mut(rows_per * ng);
        let z_chunks = zeros.chunks_mut(rows_per * ng);
        let l_chunks = row_loss.chunks_mut(rows_per);
        crate::exec::global().scope(|s| {
            for ((((wc, qc), sc), zc), lc) in
                w_chunks.zip(q_chunks).zip(s_chunks).zip(z_chunks).zip(l_chunks)
            {
                s.spawn(move || gptq_walk_rows(wc, qc, sc, zc, lc, u_ref, grid, bs));
            }
        });
    }
    let greedy_loss: f64 = row_loss.iter().sum();
    let q = QuantizedLinear::from_levels(grid, out_f, in_f, &levels, scales, zeros);

    ledger.free(tags::GPTQ_LEVELS, levels.len());
    ledger.free(tags::GPTQ_ROWLOSS, out_f * 8);
    ledger.free(tags::GPTQ_ERRBLOCK, shards * bs * 4);
    ledger.free(tags::GPTQ_HINV, in_f * in_f * 8);
    ledger.free(tags::GPTQ_WORK, w.nbytes() + hh.nbytes());

    Ok(GptqOutput { q, greedy_loss, dead_channels })
}

/// The complete GPTQ walk over a contiguous chunk of output rows — the
/// one kernel both the sequential and the row-sharded dispatch run, so
/// shard boundaries cannot change a single float operation:
///
/// * `w` — `rows×in_f` working weights (mutated by error feedback);
/// * `qw`/`scales`/`zeros` — this chunk's slices of the output linear;
/// * `row_loss` — per-row `Σ err²` subtotals (`rows` entries);
/// * `u` — the full upper Cholesky factor of `H⁻¹` (shared, read-only);
/// * `bs` — the lazy-update block width (`in_f`, `ng`, and the group size
///   are derived from the chunk shape and `grid`).
#[allow(clippy::too_many_arguments)]
fn gptq_walk_rows(
    w: &mut [f32],
    qw: &mut [u8],
    scales: &mut [f32],
    zeros: &mut [f32],
    row_loss: &mut [f64],
    u: &[f64],
    grid: QuantGrid,
    bs: usize,
) {
    let rows = row_loss.len();
    if rows == 0 {
        return; // zero-row chunk (e.g. an empty weight matrix): nothing to walk
    }
    let in_f = w.len() / rows;
    let ng = grid.n_groups(in_f);
    let gs = grid.group_size;
    debug_assert_eq!(w.len(), rows * in_f);
    debug_assert_eq!(qw.len(), rows * in_f);
    debug_assert_eq!(scales.len(), rows * ng);
    let mut err_block = vec![0.0f32; bs];
    for r in 0..rows {
        let wrow = &mut w[r * in_f..(r + 1) * in_f];
        let qrow = &mut qw[r * in_f..(r + 1) * in_f];
        let mut loss = 0.0f64;
        let mut c0 = 0;
        while c0 < in_f {
            let c1 = (c0 + bs).min(in_f);
            err_block[..c1 - c0].fill(0.0);

            for j in c0..c1 {
                // (a) group entry: fit params on the *current* weights.
                if j % gs == 0 {
                    let g = j / gs;
                    let gend = (j + gs).min(in_f);
                    let (scale, zero) = grid.find_params(&wrow[j..gend]);
                    scales[r * ng + g] = scale;
                    zeros[r * ng + g] = zero;
                }
                let d = u[j * in_f + j] as f32;
                let scale = scales[r * ng + j / gs];
                let zero = zeros[r * ng + j / gs];
                // (b) quantize column j and compute the scaled error.
                let wv = wrow[j];
                let qv = grid.quantize_val(wv, scale, zero);
                qrow[j] = qv;
                let dq = grid.dequantize_val(qv, scale, zero);
                let err = (wv - dq) / d;
                loss += (err as f64) * (err as f64);
                err_block[j - c0] = err;
                // (c) immediate feedback within the block.
                let urow = &u[j * in_f..(j + 1) * in_f];
                for k in j + 1..c1 {
                    wrow[k] -= err * urow[k] as f32;
                }
            }

            // (c') lazy trailing update: W[r, c1:] -= err · U[c0:c1, c1:].
            if c1 < in_f {
                for (jj, j) in (c0..c1).enumerate() {
                    let err = err_block[jj];
                    if err != 0.0 {
                        let urow = &u[j * in_f..(j + 1) * in_f];
                        for k in c1..in_f {
                            wrow[k] -= err * urow[k] as f32;
                        }
                    }
                }
            }
            c0 = c1;
        }
        row_loss[r] = loss;
    }
}

/// Reconstruction loss `‖X·Wᵀ − X·Ŵᵀ‖²` of a quantized matrix on given
/// activations — the metric both stages optimize, used everywhere in the
/// benches.
pub fn reconstruction_loss(x: &Tensor, w_fp: &Tensor, q: &QuantizedLinear) -> f64 {
    let y = crate::tensor::matmul_a_bt(x, w_fp);
    let yq = crate::tensor::matmul_a_bt(x, &q.dequantize());
    y.sub(&yq).frob_sq()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{prop_assert, Runner};
    use crate::quant::calib::HessianAccumulator;
    use crate::rng::Pcg64;

    fn setup(
        out_f: usize,
        in_f: usize,
        n: usize,
        seed: u64,
    ) -> (Tensor, Tensor, Tensor) {
        let mut rng = Pcg64::seeded(seed);
        let x = Tensor::randn(&[n, in_f], 1.0, &mut rng);
        let w = Tensor::randn(&[out_f, in_f], 0.5, &mut rng);
        let mut acc = HessianAccumulator::new(in_f, MemoryLedger::new());
        acc.add_batch(&x);
        let (h, _) = acc.finalize(0.01);
        (x, w, h)
    }

    #[test]
    fn gptq_beats_rtn_on_reconstruction() {
        // The whole point of GPTQ: error feedback lowers XW reconstruction
        // loss vs round-to-nearest at equal bit width.
        let (x, w, h) = setup(16, 64, 128, 61);
        let cfg = QuantConfig { bits: 4, group_size: 16, block_size: 16, percdamp: 0.01 };
        let ledger = MemoryLedger::new();
        let out = gptq_quantize(&w, &h, cfg, &ledger).unwrap();
        let rtn = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 16));
        let l_gptq = reconstruction_loss(&x, &w, &out.q);
        let l_rtn = reconstruction_loss(&x, &w, &rtn);
        assert!(
            l_gptq < l_rtn,
            "gptq {l_gptq} should beat rtn {l_rtn}"
        );
    }

    #[test]
    fn gptq_exact_when_grid_is_fine() {
        // With 8 bits and tiny weights the quantization error is ~0 and the
        // output must match the fp weights closely.
        let (x, w, h) = setup(4, 16, 32, 62);
        let cfg = QuantConfig { bits: 8, group_size: 16, block_size: 8, percdamp: 0.01 };
        let out = gptq_quantize(&w, &h, cfg, &MemoryLedger::new()).unwrap();
        let rel = reconstruction_loss(&x, &w, &out.q)
            / crate::tensor::matmul_a_bt(&x, &w).frob_sq().max(1e-12);
        assert!(rel < 1e-3, "rel={rel}");
    }

    #[test]
    fn dead_channels_are_zeroed() {
        let mut rng = Pcg64::seeded(63);
        let n = 32;
        let in_f = 8;
        let mut x = Tensor::randn(&[n, in_f], 1.0, &mut rng);
        // kill channel 3
        for r in 0..n {
            x.row_mut(r)[3] = 0.0;
        }
        let w = Tensor::randn(&[4, in_f], 0.5, &mut rng);
        let mut acc = HessianAccumulator::new(in_f, MemoryLedger::new());
        acc.add_batch(&x);
        // no damping on the dead channel: finalize would damp it, so build
        // H manually without damping to exercise the fix path
        let h = acc.hessian().clone();
        let cfg = QuantConfig { bits: 4, group_size: 4, block_size: 4, percdamp: 0.01 };
        let out = gptq_quantize(&w, &h, cfg, &MemoryLedger::new()).unwrap();
        assert_eq!(out.dead_channels, vec![3]);
        for r in 0..4 {
            assert_eq!(out.q.deq_at(r, 3), 0.0, "row {r}");
        }
    }

    #[test]
    fn row_shards_deterministic_across_thread_counts() {
        // out·in² = 16·128² = 2¹⁸ sits exactly at the flop cutoff, so the
        // sharded dispatch genuinely forks; every output (and the greedy
        // objective) must match the pinned single-thread walk bit for bit.
        let _guard = crate::exec::thread_target_test_lock();
        let before = crate::exec::num_threads();
        let (_, w, h) = setup(16, 128, 160, 66);
        let cfg = QuantConfig { bits: 4, group_size: 16, block_size: 16, percdamp: 0.01 };
        crate::exec::set_threads(1);
        let seq = gptq_quantize(&w, &h, cfg, &MemoryLedger::new()).unwrap();
        for threads in [2usize, 4, 8] {
            crate::exec::set_threads(threads);
            let ledger = MemoryLedger::new();
            let par = gptq_quantize(&w, &h, cfg, &ledger).unwrap();
            assert_eq!(seq.q.packed, par.q.packed, "packed levels @ {threads} threads");
            assert_eq!(seq.q.scales, par.q.scales, "scales @ {threads} threads");
            assert_eq!(seq.q.zeros, par.q.zeros, "zeros @ {threads} threads");
            assert_eq!(
                seq.greedy_loss.to_bits(),
                par.greedy_loss.to_bits(),
                "greedy loss @ {threads} threads"
            );
            assert_eq!(ledger.live_bytes(), 0);
        }
        crate::exec::set_threads(before);
    }

    #[test]
    fn ledger_returns_to_zero() {
        let (_, w, h) = setup(8, 32, 64, 64);
        let ledger = MemoryLedger::new();
        let _ = gptq_quantize(&w, &h, QuantConfig::default(), &ledger).unwrap();
        assert_eq!(ledger.live_bytes(), 0);
        assert!(ledger.peak_bytes() > 0);
    }

    #[test]
    fn block_size_does_not_change_result_much_property() {
        // The lazy block update is an exact algebraic regrouping; results
        // across block sizes must agree to float tolerance.
        Runner::new("gptq_blocksize_invariance", 10).run(|g| {
            let in_f = 4 * g.usize_in(2..6);
            let out_f = g.usize_in(2..6);
            let n = in_f * 2;
            let xd = g.matrix(n, in_f, 1.0);
            let wd = g.matrix(out_f, in_f, 0.5);
            let x = Tensor::from_vec(&[n, in_f], xd);
            let w = Tensor::from_vec(&[out_f, in_f], wd);
            let mut acc = HessianAccumulator::new(in_f, MemoryLedger::new());
            acc.add_batch(&x);
            let (h, _) = acc.finalize(0.01);
            let led = MemoryLedger::new();
            let cfg1 = QuantConfig { bits: 4, group_size: 4, block_size: 4, percdamp: 0.01 };
            let cfg2 = QuantConfig { bits: 4, group_size: 4, block_size: in_f, percdamp: 0.01 };
            let q1 = gptq_quantize(&w, &h, cfg1, &led).unwrap();
            let q2 = gptq_quantize(&w, &h, cfg2, &led).unwrap();
            let d = q1.q.dequantize().max_abs_diff(&q2.q.dequantize());
            prop_assert(d < 2e-2, &format!("block regrouping exact-ish, d={d}"))
        });
    }

    #[test]
    fn group_params_written_for_every_group() {
        let (_, w, h) = setup(4, 20, 40, 65);
        let cfg = QuantConfig { bits: 4, group_size: 8, block_size: 8, percdamp: 0.01 };
        let out = gptq_quantize(&w, &h, cfg, &MemoryLedger::new()).unwrap();
        assert_eq!(out.q.n_groups(), 3); // ceil(20/8)
        assert!(out.q.scales.iter().all(|&s| s > 0.0));
    }
}
