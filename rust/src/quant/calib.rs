//! Calibration-stage statistics: streaming Hessian accumulation and the
//! single-instance store.
//!
//! Paper §3.2 (Algorithm 2): the first stage accumulates `H ≈ XᵀX` over
//! every calibration batch, damps it (Eq. 10), and retains **only the last
//! batch** `(X_orig, Y_orig)` for the second stage. The memory claim
//! (Eq. 15–16) is that stage 2 needs `O(‖X‖)` instead of
//! `O(‖[X⁽¹⁾…X⁽ᵏ⁾]‖)`; the [`MemoryLedger`] instrumentation here is what
//! lets the Table 3 bench verify that claim on our substrate.

use crate::linalg::apply_damping;
use crate::metrics::MemoryLedger;
use crate::tensor::{matmul_at_b_acc, Tensor};

/// Streaming `H += XᵀX` accumulator for one linear layer.
pub struct HessianAccumulator {
    h: Tensor,
    /// Rows (samples·tokens) accumulated so far.
    pub nsamples: usize,
    ledger: MemoryLedger,
}

impl HessianAccumulator {
    pub fn new(in_features: usize, ledger: MemoryLedger) -> Self {
        let h = Tensor::zeros(&[in_features, in_features]);
        ledger.alloc("hessian", h.nbytes());
        HessianAccumulator { h, nsamples: 0, ledger }
    }

    /// Accumulate one calibration batch `x: [rows, in_features]`.
    ///
    /// Following GPTQ's reference implementation we keep a running *mean*
    /// of `2·XᵀX` — the rescale keeps `percdamp` meaningful regardless of
    /// how many batches stream through.
    pub fn add_batch(&mut self, x: &Tensor) {
        assert_eq!(x.cols(), self.h.rows(), "activation width mismatch");
        let rows = x.rows();
        if rows == 0 {
            return;
        }
        let total = self.nsamples + rows;
        // H <- H * n/(n+r)  then  H += 2/(n+r) · XᵀX
        self.h.scale(self.nsamples as f32 / total as f32);
        let mut xtx = Tensor::zeros(&[x.cols(), x.cols()]);
        self.ledger.alloc("hessian_tmp", xtx.nbytes());
        matmul_at_b_acc(x, x, &mut xtx);
        self.h.axpy(2.0 / total as f32, &xtx);
        self.ledger.free("hessian_tmp", xtx.nbytes());
        self.nsamples = total;
    }

    /// Finish: damp (Eq. 10) and hand out the Hessian. Returns `(H̃, λ)`.
    pub fn finalize(mut self, percdamp: f32) -> (Tensor, f32) {
        let lambda = apply_damping(&mut self.h, percdamp);
        // Hand ownership (and its ledger accounting) to the caller; the
        // Drop impl then frees the zero bytes of the empty placeholder.
        let h = std::mem::replace(&mut self.h, Tensor::zeros(&[0]));
        self.ledger.free("hessian", h.nbytes());
        (h, lambda)
    }

    /// Borrow the running Hessian (tests / diagnostics).
    pub fn hessian(&self) -> &Tensor {
        &self.h
    }
}

impl Drop for HessianAccumulator {
    fn drop(&mut self) {
        self.ledger.free("hessian", self.h.nbytes());
    }
}

/// The single retained calibration instance for stage 2 (paper Eq. 11):
/// the **last** batch's layer input and the full-precision layer output.
#[derive(Clone)]
pub struct SingleInstance {
    /// `X_orig ∈ R^{N×Cin}` — last batch input to this layer.
    pub x: Tensor,
    /// `Y_orig ∈ R^{N×Cout}` — full-precision output `X·W_fpᵀ`.
    pub y_orig: Tensor,
}

impl SingleInstance {
    /// Capture from the last batch + fp weights (`Y_orig = X·Wᵀ`).
    pub fn capture(x_last: Tensor, w_fp: &Tensor, ledger: &MemoryLedger) -> Self {
        let y_orig = crate::tensor::matmul_a_bt(&x_last, w_fp);
        ledger.alloc("single_instance", x_last.nbytes() + y_orig.nbytes());
        SingleInstance { x: x_last, y_orig }
    }

    pub fn nbytes(&self) -> usize {
        self.x.nbytes() + self.y_orig.nbytes()
    }

    pub fn release(self, ledger: &MemoryLedger) {
        ledger.free("single_instance", self.nbytes());
    }
}

/// A rotating snapshot selector — the paper's *future work* ("automated
/// dynamic snapshot selection … periodically rotate calibration data in
/// memory without increasing peak memory"). We implement it so the
/// ablation bench can compare `last-batch` vs `rotating` stage-2 anchors:
/// it keeps exactly one batch resident (same peak memory) but swaps which
/// batch every `period` accesses.
pub struct SnapshotRotator {
    snapshots: Vec<Tensor>,
    period: usize,
    accesses: usize,
}

impl SnapshotRotator {
    /// `candidates` are *indices* the caller may re-stream on demand; we
    /// model re-streaming by storing the batches but accounting only one
    /// as resident (the rotation cost is time, not memory — matching the
    /// paper's framing).
    pub fn new(candidates: Vec<Tensor>, period: usize) -> Self {
        assert!(!candidates.is_empty());
        SnapshotRotator { snapshots: candidates, period: period.max(1), accesses: 0 }
    }

    /// Current resident snapshot; advances the rotation clock.
    pub fn next(&mut self) -> &Tensor {
        let idx = (self.accesses / self.period) % self.snapshots.len();
        self.accesses += 1;
        &self.snapshots[idx]
    }

    pub fn resident_bytes(&self) -> usize {
        self.snapshots[0].nbytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::matmul_at_b;

    #[test]
    fn hessian_matches_direct_computation() {
        let mut rng = Pcg64::seeded(51);
        let ledger = MemoryLedger::new();
        let x1 = Tensor::randn(&[10, 8], 1.0, &mut rng);
        let x2 = Tensor::randn(&[6, 8], 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(8, ledger);
        acc.add_batch(&x1);
        acc.add_batch(&x2);
        // Expected: 2/(16) * (X1ᵀX1 + X2ᵀX2)
        let mut expect = matmul_at_b(&x1, &x1);
        expect.add_assign(&matmul_at_b(&x2, &x2));
        expect.scale(2.0 / 16.0);
        assert!(acc.hessian().max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn hessian_batch_order_invariance() {
        let mut rng = Pcg64::seeded(52);
        let ledger = MemoryLedger::new();
        let a = Tensor::randn(&[5, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[9, 4], 1.0, &mut rng);
        let mut acc1 = HessianAccumulator::new(4, ledger.clone());
        acc1.add_batch(&a);
        acc1.add_batch(&b);
        let mut acc2 = HessianAccumulator::new(4, ledger);
        acc2.add_batch(&b);
        acc2.add_batch(&a);
        assert!(acc1.hessian().max_abs_diff(acc2.hessian()) < 1e-4);
    }

    #[test]
    fn finalize_damps_diagonal() {
        let mut rng = Pcg64::seeded(53);
        let ledger = MemoryLedger::new();
        let x = Tensor::randn(&[20, 6], 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(6, ledger);
        acc.add_batch(&x);
        let before = acc.hessian().clone();
        let (h, lambda) = acc.finalize(0.01);
        assert!(lambda > 0.0);
        for i in 0..6 {
            assert!((h.at(i, i) - before.at(i, i) - lambda).abs() < 1e-6);
        }
    }

    #[test]
    fn ledger_sees_single_instance_and_frees() {
        let mut rng = Pcg64::seeded(54);
        let ledger = MemoryLedger::new();
        let x = Tensor::randn(&[4, 8], 1.0, &mut rng);
        let w = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let inst = SingleInstance::capture(x, &w, &ledger);
        assert_eq!(
            ledger.live_bytes() as usize,
            inst.nbytes()
        );
        assert_eq!(inst.y_orig.shape(), &[4, 3]);
        inst.release(&ledger);
        assert_eq!(ledger.live_bytes(), 0);
    }

    #[test]
    fn y_orig_is_x_w_t() {
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let inst = SingleInstance::capture(x, &w, &MemoryLedger::new());
        assert_eq!(inst.y_orig.data(), &[1.0, 2.0]);
    }

    #[test]
    fn rotator_cycles_with_period() {
        let mk = |v: f32| Tensor::from_vec(&[1, 1], vec![v]);
        let mut rot = SnapshotRotator::new(vec![mk(1.0), mk(2.0), mk(3.0)], 2);
        let seq: Vec<f32> = (0..8).map(|_| rot.next().data()[0]).collect();
        assert_eq!(seq, vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0, 1.0, 1.0]);
    }
}
