//! Calibration-stage statistics: streaming Hessian accumulation and the
//! single-instance store.
//!
//! Paper §3.2 (Algorithm 2): the first stage accumulates `H ≈ XᵀX` over
//! every calibration batch, damps it (Eq. 10), and retains **only the last
//! batch** `(X_orig, Y_orig)` for the second stage. The memory claim
//! (Eq. 15–16) is that stage 2 needs `O(‖X‖)` instead of
//! `O(‖[X⁽¹⁾…X⁽ᵏ⁾]‖)`; the [`MemoryLedger`] instrumentation here is what
//! lets the Table 3 bench verify that claim on our substrate.
//!
//! # Parallel sweep support
//!
//! The pipeline's calibration sweep fans windows out across the global
//! pool; each worker computes its windows' `XᵀX` products into a private
//! [`HessianPartial`], and [`HessianAccumulator::merge`] then *replays*
//! those per-window products in **global window-index order** through the
//! exact running-mean update [`HessianAccumulator::add_batch`] uses. The
//! float-op sequence applied to `H` is therefore identical to streaming
//! the batches sequentially — byte-identical Hessians for any partition of
//! windows into partials and any thread count. (Summing partial `XᵀX`
//! folds per worker and adding the subtotals would NOT be: f32 addition is
//! non-associative, so the grouping must never depend on the partition.)

use crate::linalg::apply_damping;
use crate::metrics::{tags, MemoryLedger};
use crate::tensor::{matmul_at_b_acc, Tensor};

/// Streaming `H += XᵀX` accumulator for one linear layer.
pub struct HessianAccumulator {
    h: Tensor,
    /// Rows (samples·tokens) accumulated so far.
    pub nsamples: usize,
    /// Highest window index replayed by [`Self::merge`] so far — guards the
    /// cross-call ordering contract (merges must arrive in window order).
    last_merged: Option<usize>,
    ledger: MemoryLedger,
}

impl HessianAccumulator {
    pub fn new(in_features: usize, ledger: MemoryLedger) -> Self {
        let h = Tensor::zeros(&[in_features, in_features]);
        ledger.alloc(tags::HESSIAN, h.nbytes());
        HessianAccumulator { h, nsamples: 0, last_merged: None, ledger }
    }

    /// Accumulate one calibration batch `x: [rows, in_features]`.
    ///
    /// Following GPTQ's reference implementation we keep a running *mean*
    /// of `2·XᵀX` — the rescale keeps `percdamp` meaningful regardless of
    /// how many batches stream through.
    pub fn add_batch(&mut self, x: &Tensor) {
        assert_eq!(x.cols(), self.h.rows(), "activation width mismatch");
        if x.rows() == 0 {
            return;
        }
        let mut xtx = Tensor::zeros(&[x.cols(), x.cols()]);
        self.ledger.alloc(tags::HESSIAN_TMP, xtx.nbytes());
        matmul_at_b_acc(x, x, &mut xtx);
        self.add_precomputed(&xtx, x.rows());
        self.ledger.free(tags::HESSIAN_TMP, xtx.nbytes());
    }

    /// The running-mean update given a precomputed `xtx = XᵀX` over `rows`
    /// samples — the float-op core shared by [`Self::add_batch`] and
    /// [`Self::merge`] (which is what makes the parallel sweep's merged
    /// Hessian byte-identical to the sequential stream).
    pub fn add_precomputed(&mut self, xtx: &Tensor, rows: usize) {
        assert_eq!(xtx.rows(), self.h.rows(), "XᵀX width mismatch");
        if rows == 0 {
            return;
        }
        let total = self.nsamples + rows;
        // H <- H * n/(n+r)  then  H += 2/(n+r) · XᵀX
        self.h.scale(self.nsamples as f32 / total as f32);
        self.h.axpy(2.0 / total as f32, xtx);
        self.nsamples = total;
    }

    /// Merge window-indexed partial accumulators by replaying their
    /// per-window `XᵀX` products through [`Self::add_precomputed`] in
    /// ascending window-index order. Any partition of the windows into
    /// partials yields a Hessian byte-identical to streaming the windows
    /// through [`Self::add_batch`] sequentially (asserted by the
    /// `merge_partition_*` property test).
    ///
    /// Successive `merge` calls must present strictly increasing window
    /// ranges (the pipeline merges wave by wave); duplicate or
    /// out-of-order indices panic. Each window's `hessian_partial` bytes
    /// are freed on the ledger of the partial that charged them (the
    /// pipeline clones one ledger everywhere, but the accounting stays
    /// exact even for a caller mixing ledgers).
    pub fn merge(&mut self, partials: Vec<HessianPartial>) {
        let mut entries: Vec<(PartialEntry, MemoryLedger)> = Vec::new();
        for mut p in partials {
            assert_eq!(p.in_features, self.h.rows(), "partial width mismatch");
            let led = p.ledger.clone();
            entries.extend(p.entries.drain(..).map(|e| (e, led.clone())));
        }
        entries.sort_by_key(|(e, _)| e.window);
        for pair in entries.windows(2) {
            assert!(pair[0].0.window < pair[1].0.window, "duplicate window index");
        }
        for (e, led) in entries {
            if let Some(last) = self.last_merged {
                assert!(e.window > last, "merge calls must be window-ordered");
            }
            self.last_merged = Some(e.window);
            self.add_precomputed(&e.xtx, e.rows);
            led.free(tags::HESSIAN_PARTIAL, e.xtx.nbytes());
        }
    }

    /// Finish: damp (Eq. 10) and hand out the Hessian. Returns `(H̃, λ)`.
    pub fn finalize(mut self, percdamp: f32) -> (Tensor, f32) {
        let lambda = apply_damping(&mut self.h, percdamp);
        // Hand ownership (and its ledger accounting) to the caller; the
        // Drop impl then frees the zero bytes of the empty placeholder.
        let h = std::mem::replace(&mut self.h, Tensor::zeros(&[0]));
        self.ledger.free(tags::HESSIAN, h.nbytes());
        (h, lambda)
    }

    /// Borrow the running Hessian (tests / diagnostics).
    pub fn hessian(&self) -> &Tensor {
        &self.h
    }
}

impl Drop for HessianAccumulator {
    fn drop(&mut self) {
        self.ledger.free(tags::HESSIAN, self.h.nbytes());
    }
}

/// One window's contribution held by a partial accumulator.
struct PartialEntry {
    /// Global calibration-window index (the merge replay key).
    window: usize,
    /// Precomputed `XᵀX` for that window.
    xtx: Tensor,
    /// Sample rows in the window.
    rows: usize,
}

/// Worker-private partial accumulator for the parallel calibration sweep.
///
/// A partial does the *expensive* part of [`HessianAccumulator::add_batch`]
/// — the `XᵀX` product — on the worker thread, but defers the cheap
/// running-mean fold to [`HessianAccumulator::merge`], which replays the
/// products in window-index order. Deliberately NOT a running sum: folding
/// within a partial would make the float grouping depend on how windows
/// were partitioned across workers, breaking the bit-identity guarantee.
///
/// Every stored product is ledger-accounted under `hessian_partial`;
/// merging (or dropping an unmerged partial) releases it.
pub struct HessianPartial {
    entries: Vec<PartialEntry>,
    in_features: usize,
    ledger: MemoryLedger,
}

impl HessianPartial {
    pub fn new(in_features: usize, ledger: MemoryLedger) -> Self {
        HessianPartial { entries: Vec::new(), in_features, ledger }
    }

    /// Record calibration window `index` (`x: [rows, in_features]`),
    /// computing its `XᵀX` immediately (this is the worker-side compute).
    pub fn add_window(&mut self, index: usize, x: &Tensor) {
        assert_eq!(x.cols(), self.in_features, "activation width mismatch");
        if x.rows() == 0 {
            return; // matches add_batch: empty batches contribute nothing
        }
        let mut xtx = Tensor::zeros(&[self.in_features, self.in_features]);
        self.ledger.alloc(tags::HESSIAN_PARTIAL, xtx.nbytes());
        matmul_at_b_acc(x, x, &mut xtx);
        self.entries.push(PartialEntry { window: index, xtx, rows: x.rows() });
    }

    pub fn in_features(&self) -> usize {
        self.in_features
    }

    /// Number of windows recorded (not yet merged).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl Drop for HessianPartial {
    fn drop(&mut self) {
        for e in &self.entries {
            self.ledger.free(tags::HESSIAN_PARTIAL, e.xtx.nbytes());
        }
    }
}

/// The single retained calibration instance for stage 2 (paper Eq. 11):
/// the **last** batch's layer input and the full-precision layer output.
#[derive(Clone)]
pub struct SingleInstance {
    /// `X_orig ∈ R^{N×Cin}` — last batch input to this layer.
    pub x: Tensor,
    /// `Y_orig ∈ R^{N×Cout}` — full-precision output `X·W_fpᵀ`.
    pub y_orig: Tensor,
}

impl SingleInstance {
    /// Capture from the last batch + fp weights (`Y_orig = X·Wᵀ`).
    pub fn capture(x_last: Tensor, w_fp: &Tensor, ledger: &MemoryLedger) -> Self {
        let y_orig = crate::tensor::matmul_a_bt(&x_last, w_fp);
        ledger.alloc(tags::SINGLE_INSTANCE, x_last.nbytes() + y_orig.nbytes());
        SingleInstance { x: x_last, y_orig }
    }

    pub fn nbytes(&self) -> usize {
        self.x.nbytes() + self.y_orig.nbytes()
    }

    pub fn release(self, ledger: &MemoryLedger) {
        ledger.free(tags::SINGLE_INSTANCE, self.nbytes());
    }
}

/// A rotating snapshot selector — the paper's *future work* ("automated
/// dynamic snapshot selection … periodically rotate calibration data in
/// memory without increasing peak memory"). We implement it so the
/// ablation bench can compare `last-batch` vs `rotating` stage-2 anchors:
/// it keeps exactly one batch resident (same peak memory) but swaps which
/// batch every `period` accesses.
pub struct SnapshotRotator {
    snapshots: Vec<Tensor>,
    period: usize,
    accesses: usize,
}

impl SnapshotRotator {
    /// `candidates` are *indices* the caller may re-stream on demand; we
    /// model re-streaming by storing the batches but accounting only one
    /// as resident (the rotation cost is time, not memory — matching the
    /// paper's framing).
    pub fn new(candidates: Vec<Tensor>, period: usize) -> Self {
        assert!(!candidates.is_empty());
        SnapshotRotator { snapshots: candidates, period: period.max(1), accesses: 0 }
    }

    /// Current resident snapshot; advances the rotation clock.
    pub fn next(&mut self) -> &Tensor {
        let idx = (self.accesses / self.period) % self.snapshots.len();
        self.accesses += 1;
        &self.snapshots[idx]
    }

    pub fn resident_bytes(&self) -> usize {
        self.snapshots[0].nbytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{prop_assert, Runner};
    use crate::rng::Pcg64;
    use crate::tensor::matmul_at_b;

    fn h_bits(acc: &HessianAccumulator) -> Vec<u32> {
        acc.hessian().data().iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn merge_single_partial_bitwise_matches_streaming_deterministic() {
        let mut rng = Pcg64::seeded(55);
        let windows: Vec<Tensor> =
            (0..5).map(|_| Tensor::randn(&[7, 6], 1.0, &mut rng)).collect();
        let mut seq = HessianAccumulator::new(6, MemoryLedger::new());
        for x in &windows {
            seq.add_batch(x);
        }
        let ledger = MemoryLedger::new();
        let mut p = HessianPartial::new(6, ledger.clone());
        for (wi, x) in windows.iter().enumerate() {
            p.add_window(wi, x);
        }
        assert_eq!(p.len(), 5);
        let mut merged = HessianAccumulator::new(6, ledger.clone());
        merged.merge(vec![p]);
        assert_eq!(h_bits(&seq), h_bits(&merged), "H must be byte-identical");
        assert_eq!(merged.nsamples, seq.nsamples);
        drop(merged);
        assert_eq!(ledger.live_bytes(), 0, "partial bytes released by merge");
    }

    #[test]
    fn merge_partition_matches_single_accumulator_deterministic() {
        // The parallel-sweep contract (property form): ANY partition of the
        // windows into partial accumulators, merged in window-index order,
        // reproduces the sequential stream exactly — bitwise — and the
        // ledger balances to zero once the accumulators drop.
        Runner::new("hessian_merge_partition", 16).run(|g| {
            let in_f = 2 * g.usize_in(1..5);
            let nw = g.usize_in(1..7);
            let k = g.usize_in(1..4).min(nw);
            let windows: Vec<Tensor> = (0..nw)
                .map(|_| {
                    let rows = g.usize_in(1..6);
                    Tensor::from_vec(&[rows, in_f], g.matrix(rows, in_f, 1.0))
                })
                .collect();
            let led_seq = MemoryLedger::new();
            let mut seq = HessianAccumulator::new(in_f, led_seq.clone());
            for x in &windows {
                seq.add_batch(x);
            }
            let led_par = MemoryLedger::new();
            let mut parts: Vec<HessianPartial> =
                (0..k).map(|_| HessianPartial::new(in_f, led_par.clone())).collect();
            for (wi, x) in windows.iter().enumerate() {
                let owner = g.usize_in(0..k);
                parts[owner].add_window(wi, x);
            }
            let mut merged = HessianAccumulator::new(in_f, led_par.clone());
            merged.merge(parts);
            prop_assert(h_bits(&seq) == h_bits(&merged), "H bitwise equal")?;
            prop_assert(merged.nsamples == seq.nsamples, "nsamples equal")?;
            prop_assert(led_par.peak_bytes() > 0, "partials were accounted")?;
            drop(seq);
            drop(merged);
            prop_assert(
                led_seq.live_bytes() == 0 && led_par.live_bytes() == 0,
                "ledgers balance to zero after drop",
            )
        });
    }

    #[test]
    fn merge_across_waves_stays_ordered_and_exact() {
        // The pipeline merges wave by wave: successive merge calls with
        // ascending window ranges must chain into the same running mean.
        let mut rng = Pcg64::seeded(56);
        let windows: Vec<Tensor> =
            (0..6).map(|_| Tensor::randn(&[4, 4], 1.0, &mut rng)).collect();
        let mut seq = HessianAccumulator::new(4, MemoryLedger::new());
        for x in &windows {
            seq.add_batch(x);
        }
        let ledger = MemoryLedger::new();
        let mut merged = HessianAccumulator::new(4, ledger.clone());
        for (ci, chunk) in windows.chunks(2).enumerate() {
            let mut p = HessianPartial::new(4, ledger.clone());
            for (k, x) in chunk.iter().enumerate() {
                p.add_window(ci * 2 + k, x);
            }
            merged.merge(vec![p]);
        }
        assert_eq!(h_bits(&seq), h_bits(&merged));
    }

    #[test]
    #[should_panic(expected = "window-ordered")]
    fn merge_rejects_out_of_order_waves() {
        let mut rng = Pcg64::seeded(57);
        let x = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let ledger = MemoryLedger::new();
        let mut acc = HessianAccumulator::new(4, ledger.clone());
        let mut p1 = HessianPartial::new(4, ledger.clone());
        p1.add_window(3, &x);
        acc.merge(vec![p1]);
        let mut p0 = HessianPartial::new(4, ledger);
        p0.add_window(1, &x); // earlier window after a later one: refuse
        acc.merge(vec![p0]);
    }

    #[test]
    fn merge_frees_partial_bytes_on_their_own_ledger() {
        // A caller may (unusually) charge partials to a different ledger
        // than the accumulator's; the bytes must be freed where charged.
        let mut rng = Pcg64::seeded(59);
        let x = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let led_a = MemoryLedger::new();
        let led_b = MemoryLedger::new();
        let mut p = HessianPartial::new(4, led_a.clone());
        p.add_window(0, &x);
        assert_eq!(led_a.live_bytes() as usize, 4 * 4 * 4);
        let mut acc = HessianAccumulator::new(4, led_b.clone());
        acc.merge(vec![p]);
        assert_eq!(led_a.live_bytes(), 0, "partial bytes freed where charged");
        drop(acc);
        assert_eq!(led_b.live_bytes(), 0, "accumulator ledger untouched by partials");
    }

    #[test]
    fn unmerged_partial_drop_releases_ledger() {
        let mut rng = Pcg64::seeded(58);
        let ledger = MemoryLedger::new();
        let mut p = HessianPartial::new(8, ledger.clone());
        p.add_window(0, &Tensor::randn(&[5, 8], 1.0, &mut rng));
        p.add_window(1, &Tensor::randn(&[5, 8], 1.0, &mut rng));
        assert!(!p.is_empty());
        assert_eq!(ledger.live_bytes() as usize, 2 * 8 * 8 * 4);
        drop(p);
        assert_eq!(ledger.live_bytes(), 0);
        assert_eq!(ledger.peak_for(tags::HESSIAN_PARTIAL) as usize, 2 * 8 * 8 * 4);
    }

    #[test]
    fn hessian_matches_direct_computation() {
        let mut rng = Pcg64::seeded(51);
        let ledger = MemoryLedger::new();
        let x1 = Tensor::randn(&[10, 8], 1.0, &mut rng);
        let x2 = Tensor::randn(&[6, 8], 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(8, ledger);
        acc.add_batch(&x1);
        acc.add_batch(&x2);
        // Expected: 2/(16) * (X1ᵀX1 + X2ᵀX2)
        let mut expect = matmul_at_b(&x1, &x1);
        expect.add_assign(&matmul_at_b(&x2, &x2));
        expect.scale(2.0 / 16.0);
        assert!(acc.hessian().max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn hessian_batch_order_invariance() {
        let mut rng = Pcg64::seeded(52);
        let ledger = MemoryLedger::new();
        let a = Tensor::randn(&[5, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[9, 4], 1.0, &mut rng);
        let mut acc1 = HessianAccumulator::new(4, ledger.clone());
        acc1.add_batch(&a);
        acc1.add_batch(&b);
        let mut acc2 = HessianAccumulator::new(4, ledger);
        acc2.add_batch(&b);
        acc2.add_batch(&a);
        assert!(acc1.hessian().max_abs_diff(acc2.hessian()) < 1e-4);
    }

    #[test]
    fn finalize_damps_diagonal() {
        let mut rng = Pcg64::seeded(53);
        let ledger = MemoryLedger::new();
        let x = Tensor::randn(&[20, 6], 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(6, ledger);
        acc.add_batch(&x);
        let before = acc.hessian().clone();
        let (h, lambda) = acc.finalize(0.01);
        assert!(lambda > 0.0);
        for i in 0..6 {
            assert!((h.at(i, i) - before.at(i, i) - lambda).abs() < 1e-6);
        }
    }

    #[test]
    fn ledger_sees_single_instance_and_frees() {
        let mut rng = Pcg64::seeded(54);
        let ledger = MemoryLedger::new();
        let x = Tensor::randn(&[4, 8], 1.0, &mut rng);
        let w = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let inst = SingleInstance::capture(x, &w, &ledger);
        assert_eq!(
            ledger.live_bytes() as usize,
            inst.nbytes()
        );
        assert_eq!(inst.y_orig.shape(), &[4, 3]);
        inst.release(&ledger);
        assert_eq!(ledger.live_bytes(), 0);
    }

    #[test]
    fn y_orig_is_x_w_t() {
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let inst = SingleInstance::capture(x, &w, &MemoryLedger::new());
        assert_eq!(inst.y_orig.data(), &[1.0, 2.0]);
    }

    #[test]
    fn rotator_cycles_with_period() {
        let mk = |v: f32| Tensor::from_vec(&[1, 1], vec![v]);
        let mut rot = SnapshotRotator::new(vec![mk(1.0), mk(2.0), mk(3.0)], 2);
        let seq: Vec<f32> = (0..8).map(|_| rot.next().data()[0]).collect();
        assert_eq!(seq, vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0, 1.0, 1.0]);
    }
}
