//! Stage 2: RPIQ — residual-projected, multi-collaborative, closed-loop
//! refinement of the GPTQ initialization (paper §3.1–3.3, Algorithms 1–3).
//!
//! Per layer, with the single retained instance `(X, Y_orig)` and the
//! damped global Hessian `H̃`:
//!
//! * Partition the columns into `M` blocks aligned with the quantization
//!   groups. Precompute per-block inverse curvature
//!   `H_i⁻¹ = (H̃[c₁:c₂, c₁:c₂])⁻¹ ≈ (X_iᵀX_i + λI)⁻¹` (Eq. 12–13).
//! * Maintain the quantized output `Y_q = Σ_j X_j·B_jᵀ` **incrementally**:
//!   after block `i` updates, `Y_q += X_i·(B_iⁿᵉʷ − B_iᵒˡᵈ)ᵀ` (Eq. 21–22).
//!   This is the Gauss–Seidel property: block `i+1` sees block `i`'s
//!   refreshed contribution within the same sweep.
//! * For block `i`: directed residual `D_i = Y_orig − (Y_q − Y_{q,i})`
//!   (Eq. 4/20), local least squares `B_i* = (H_i⁻¹·X_iᵀ·D_i)ᵀ` (Eq. 14),
//!   grid projection `B̃_i = Q(B_i*)` with the **stage-1 (scale, zero)**
//!   (Eq. 7), damped move `B_i ← B_i + α(B̃_i − B_i)` (Eq. 8).
//! * Track `Γ⁽ᵗ⁾ = ‖Y_orig − Y_q‖²` (Eq. 23) on the *grid-projected*
//!   weights; early-stop when Γ stops decreasing or `T_max` is reached,
//!   and return the best (lowest-Γ) projected iterate.
//!
//! Three deliberate implementation clarifications of the paper's text
//! (documented in rust/DESIGN.md §Deviations):
//!
//! 1. Eq. 8 yields off-grid weights for `α < 1`. We keep the continuous
//!    iterate `B_i` as optimizer state but always *deploy and score* its
//!    projection `Q(B_i)` — otherwise Γ would be measured on weights one
//!    cannot actually ship.
//! 2. `Q(·)` is **curvature-aware**: naive round-to-nearest of the block
//!    LS solution discards the within-block error compensation GPTQ
//!    already had, and empirically cannot beat stage 1. We therefore
//!    project with the same Cholesky error-feedback walk GPTQ uses, but
//!    *restricted to the block* and with the stage-1 (scale, zero) kept
//!    fixed. With this projector the closed loop reliably lowers Γ.
//! 3. The block curvature of Eq. 13 is computed from the retained
//!    instance (`X_iᵀX_i + λI`), which is the scale-consistent reading of
//!    the equation; the "extract from global H̃" reading is kept as the
//!    [`Curvature::GlobalHessian`] ablation arm.
//!
//! # Pool-aware refinement
//!
//! The Gauss–Seidel sweep itself is sequential over blocks (block `i+1`
//! must see block `i`'s refreshed contribution), but everything inside a
//! block parallelizes on the global pool: the per-block curvature
//! precompute fans out across blocks before the sweep starts, the
//! least-squares matmuls row-shard like every other matmul, and the grid
//! projector shards *output rows* (rows are independent within an
//! iteration — see [`project_block_feedback`]). All of it is bit-identical
//! at any thread count (`refine_deterministic_across_thread_counts`).

use super::calib::SingleInstance;
use super::grid::{QuantGrid, QuantizedLinear};
use crate::linalg::spd_inverse;
use crate::metrics::{tags, MemoryLedger};
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Tensor};

/// Where the per-block inverse curvature `H_i⁻¹` comes from.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Curvature {
    /// Eq. 13 literally: `H_i⁻¹ = (X_iᵀX_i + λI)⁻¹` from the retained
    /// instance. The default — scale-consistent with the least-squares
    /// residual fit, which is computed on the same instance.
    Instance,
    /// Ablation arm: reuse the *globally accumulated* Hessian block,
    /// rescaled into instance units (`H` here is the running mean
    /// `(2/n)·ΣXᵀX`, so the block must be multiplied by `n_inst/2` to sit
    /// in `X_iᵀX_i` units). Exercised by the `ablations` bench to measure
    /// whether global second-order structure helps the local solve.
    GlobalHessian,
}

/// Stage-2 hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct RpiqParams {
    /// Max refinement sweeps `T_max`. Paper default: 5.
    pub max_iters: usize,
    /// Interpolation step `α ∈ (0, 1]` (Eq. 8). The paper reports an
    /// "iterative learning rate of 0.01"; our ablations (bench `ablations`)
    /// show the closed loop needs a materially larger step to move off the
    /// GPTQ point within 5 sweeps on our substrate, so the default is 0.5
    /// and `alpha` is swept in the ablation bench (0.01 included).
    pub alpha: f32,
    /// Block width in columns. `None` ⇒ one block per quantization group
    /// (which is also what keeps `Q(·)` params block-local).
    pub block_cols: Option<usize>,
    /// Stop as soon as Γ fails to decrease (Algorithm 3 line 2).
    pub early_stop: bool,
    /// Damping fraction for the block curvature solve (Eq. 10 reused).
    pub percdamp: f32,
    /// Curvature source (see [`Curvature`]).
    pub curvature: Curvature,
}

impl Default for RpiqParams {
    fn default() -> Self {
        RpiqParams {
            max_iters: 5,
            alpha: 0.5,
            block_cols: None,
            early_stop: true,
            percdamp: 0.01,
            curvature: Curvature::Instance,
        }
    }
}

/// Stage-2 result.
pub struct RpiqOutput {
    /// Refined deployment weights (projection of the best iterate).
    pub q: QuantizedLinear,
    /// `Γ⁽ᵗ⁾` per sweep; index 0 is the stage-1 (GPTQ) loss, i.e. the
    /// paper's "Initial Loss" column of Table 5.
    pub loss_trace: Vec<f64>,
    /// Sweeps actually executed.
    pub iters_run: usize,
    /// True if the Γ-based criterion fired before `max_iters`.
    pub early_stopped: bool,
}

impl RpiqOutput {
    /// Total loss reduction fraction (Table 5 "Reduction (%)").
    pub fn reduction_pct(&self) -> f64 {
        let init = self.loss_trace[0];
        let last = *self.loss_trace.last().unwrap();
        if init <= 0.0 {
            return 0.0;
        }
        100.0 * (init - last) / init
    }
}

/// Refine a GPTQ-quantized layer.
///
/// * `q_init` — stage-1 output (provides the grid and (scale, zero)).
/// * `inst` — the single retained calibration instance.
/// * `h` — damped global Hessian `H̃` (`[in, in]`); only consulted when
///   `params.curvature == Curvature::GlobalHessian`.
pub fn rpiq_refine(
    q_init: &QuantizedLinear,
    inst: &SingleInstance,
    h: &Tensor,
    params: RpiqParams,
    ledger: &MemoryLedger,
) -> anyhow::Result<RpiqOutput> {
    let in_f = q_init.in_features;
    let out_f = q_init.out_features;
    assert_eq!(inst.x.cols(), in_f, "instance width mismatch");
    assert_eq!(inst.y_orig.cols(), out_f, "instance output mismatch");
    assert_eq!(h.rows(), in_f);

    let bc = params
        .block_cols
        .unwrap_or(q_init.grid.group_size)
        .clamp(1, in_f);
    // Block boundaries [c0, c1).
    let blocks: Vec<(usize, usize)> = (0..in_f)
        .step_by(bc)
        .map(|c0| (c0, (c0 + bc).min(in_f)))
        .collect();
    let m = blocks.len();

    // ---- Precompute per-block slices and inverse curvature (Eq. 12-13) ----
    // Blocks are independent here, so the slice + damp + invert work fans
    // out across the pool; map() joins in block order, so the precomputed
    // state (and any inversion error) is identical at any thread count.
    let n_inst = inst.x.rows();
    let jobs: Vec<_> = blocks
        .iter()
        .map(|&(c0, c1)| {
            move || -> anyhow::Result<(Tensor, Tensor, Vec<f64>)> {
                let xi = inst.x.slice_cols(c0, c1);
                let mut hi = match params.curvature {
                    // Eq. 13: block curvature from the instance itself.
                    Curvature::Instance => matmul_at_b(&xi, &xi),
                    // Global Hessian block, rescaled into instance units:
                    // the accumulator stores the running mean (2/n)·ΣXᵀX,
                    // and under a stationary calibration distribution
                    // ΣXᵀX ≈ (n/n_inst)·X_iᵀX_i, so (n_inst/2)·H_block ≈
                    // X_iᵀX_i.
                    Curvature::GlobalHessian => {
                        let mut hb = slice_square(h, c0, c1);
                        hb.scale(n_inst as f32 / 2.0);
                        hb
                    }
                };
                crate::linalg::apply_damping(&mut hi, params.percdamp);
                // Upper Cholesky factor of the block's H_i⁻¹ drives the
                // error-feedback projector (clarification 2, module docs).
                let (hinv, u) = invert_with_retry(hi)?;
                Ok((xi, hinv, u))
            }
        })
        .collect();
    let mut x_blocks: Vec<Tensor> = Vec::with_capacity(m);
    let mut hinv_blocks: Vec<Tensor> = Vec::with_capacity(m);
    let mut u_blocks: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut precomp_bytes = 0usize;
    for res in crate::exec::global().map(jobs) {
        let (xi, hinv, u) = res?;
        precomp_bytes += xi.nbytes() + hinv.nbytes() + u.len() * 8;
        x_blocks.push(xi);
        hinv_blocks.push(hinv);
        u_blocks.push(u);
    }
    ledger.alloc(tags::RPIQ_PRECOMP, precomp_bytes);

    // ---- State: continuous blocks + projected deployment copy ----
    // Continuous iterate starts at the dequantized stage-1 weights.
    let mut b_cont: Vec<Tensor> = blocks
        .iter()
        .map(|&(c0, c1)| q_init.deq_cols(c0, c1))
        .collect();
    let mut q_best = q_init.clone();
    let mut q_cur = q_init.clone();
    // Y_q from the projected (deployable) weights.
    let mut y_q = matmul_a_bt(&inst.x, &q_cur.dequantize());
    let state_bytes =
        b_cont.iter().map(|b| b.nbytes()).sum::<usize>() + y_q.nbytes() + 2 * q_init.packed.len();
    ledger.alloc(tags::RPIQ_STATE, state_bytes);

    let gamma = |yq: &Tensor| inst.y_orig.sub(yq).frob_sq();
    let mut loss_trace = vec![gamma(&y_q)];
    let mut best_loss = loss_trace[0];
    let mut early_stopped = false;
    let mut iters_run = 0;

    for _t in 0..params.max_iters {
        // One Gauss-Seidel sweep over the blocks.
        for (i, &(c0, c1)) in blocks.iter().enumerate() {
            let xi = &x_blocks[i];
            // Old projected contribution of this block.
            let b_old_proj = q_cur.deq_cols(c0, c1);
            let y_qi = matmul_a_bt(xi, &b_old_proj);
            // Directed residual D_i = Y_orig − (Y_q − Y_{q,i})   (Eq. 4)
            let mut d_i = inst.y_orig.clone();
            d_i.sub_assign(&y_q);
            d_i.add_assign(&y_qi);
            // Local least squares (Eq. 14): B*ᵀ = H_i⁻¹ · X_iᵀ · D_i.
            let xtd = matmul_at_b(xi, &d_i); // [bc, out]
            let bstar_t = matmul(&hinv_blocks[i], &xtd); // [bc, out]
            let bstar = bstar_t.transpose(); // [out, bc]
            // Damped move in continuous space (Eq. 8) toward the LS
            // solution, then curvature-aware grid projection (Eq. 7 with
            // the feedback projector).
            let bc_i = &mut b_cont[i];
            for (dst, new) in bc_i.data_mut().iter_mut().zip(bstar.data().iter()) {
                *dst += params.alpha * (*new - *dst);
            }
            project_block_feedback(&mut q_cur, c0, c1, bc_i, &u_blocks[i], ledger);
            // Update Y_q incrementally (Eq. 21-22) so block i+1 sees the
            // refreshed contribution within this sweep (Gauss-Seidel).
            let b_new_proj = q_cur.deq_cols(c0, c1);
            let mut delta = b_new_proj;
            delta.sub_assign(&b_old_proj);
            let y_delta = matmul_a_bt(xi, &delta);
            y_q.add_assign(&y_delta);
        }

        iters_run += 1;
        let loss = gamma(&y_q);
        let prev = *loss_trace.last().unwrap();
        loss_trace.push(loss);
        if loss < best_loss {
            best_loss = loss;
            q_best = q_cur.clone();
        }
        // Algorithm 3's "Γ no longer decreases": we stop on a strict
        // increase relative to the previous sweep. Exactly-flat sweeps are
        // allowed to continue — with α < 1 the first move often rounds back
        // to the same grid points and only escapes on a later sweep.
        if params.early_stop && loss > prev * (1.0 + 1e-9) {
            early_stopped = true;
            break;
        }
    }

    ledger.free(tags::RPIQ_STATE, state_bytes);
    ledger.free(tags::RPIQ_PRECOMP, precomp_bytes);

    Ok(RpiqOutput { q: q_best, loss_trace, iters_run, early_stopped })
}

/// Curvature-aware projection of a continuous block onto the grid of `q`
/// (columns `[c0, c1)`), writing the integer levels into `q`.
///
/// This is GPTQ's Cholesky error-feedback walk restricted to the block:
/// after rounding column `j`, the scaled rounding error is propagated to
/// the not-yet-rounded columns via the upper factor `U` of `H_i⁻¹`, so the
/// block's *output* error — not its weight error — is what the rounding
/// minimizes. (scale, zero) stay fixed to the stage-1 values. The input
/// block is not mutated; an idempotence property holds: projecting an
/// already-on-grid block is the identity (zero rounding error ⇒ zero
/// feedback).
///
/// Rows are independent within the Gauss–Seidel residual-feedback sweep
/// (each row's walk reads only its own work row and (scale, zero)), so the
/// projector shards output rows across the pool — the same
/// [`project_rows`] kernel either way, behind the matmul flop cutoff — and
/// scatters the rounded levels into `q` after the join. Bit-identical at
/// any thread count.
fn project_block_feedback(
    q: &mut QuantizedLinear,
    c0: usize,
    c1: usize,
    block: &Tensor,
    u: &[f64],
    ledger: &MemoryLedger,
) {
    let bc = c1 - c0;
    debug_assert_eq!(block.cols(), bc);
    debug_assert_eq!(u.len(), bc * bc);
    let out_f = block.rows();
    let grid = q.grid;
    let mut work = block.clone();
    let mut levels = vec![0u8; out_f * bc];
    // Projector working set: the mutable copy of the block plus the level
    // buffer the kernels write (scattered into `q` after the join).
    let scratch_bytes = work.nbytes() + levels.len();
    ledger.alloc(tags::RPIQ_PROJECT, scratch_bytes);
    // Feedback work ≈ out·bc² MACs; small blocks stay on the caller.
    let shards = crate::tensor::shard_count(out_f, out_f * bc * bc);
    if shards <= 1 {
        let params = (&q.scales[..], &q.zeros[..], q.n_groups());
        project_rows(work.data_mut(), &mut levels, 0, c0, bc, u, grid, params);
    } else {
        let rows_per = out_f.div_ceil(shards);
        let params = (&q.scales[..], &q.zeros[..], q.n_groups());
        let w_chunks = work.data_mut().chunks_mut(rows_per * bc);
        let l_chunks = levels.chunks_mut(rows_per * bc);
        crate::exec::global().scope(|s| {
            for (si, (wc, lc)) in w_chunks.zip(l_chunks).enumerate() {
                let r0 = si * rows_per;
                s.spawn(move || project_rows(wc, lc, r0, c0, bc, u, grid, params));
            }
        });
    }
    // Scatter the rounded levels into the packed deployment matrix
    // (columns are a strided nibble window of each packed row, so the
    // kernels write a compact byte-per-level block buffer instead).
    for r in 0..out_f {
        for (j, &lv) in levels[r * bc..(r + 1) * bc].iter().enumerate() {
            q.set_level(r, c0 + j, lv);
        }
    }
    ledger.free(tags::RPIQ_PROJECT, scratch_bytes);
}

/// The projector walk over a contiguous chunk of output rows (rows
/// `[r0, r0 + chunk)` of the block): round each column with the stage-1
/// (scale, zero), feed the scaled rounding error forward through `U`, and
/// record the integer levels. One kernel for both the sequential and the
/// sharded dispatch — shard boundaries cannot change a float operation.
/// `params` bundles the full (scales, zeros, n_groups) of the linear being
/// projected (indexed with the absolute row `r0 + r`).
#[allow(clippy::too_many_arguments)]
fn project_rows(
    work: &mut [f32],
    levels: &mut [u8],
    r0: usize,
    c0: usize,
    bc: usize,
    u: &[f64],
    grid: QuantGrid,
    params: (&[f32], &[f32], usize),
) {
    let (scales, zeros, ng) = params;
    let gs = grid.group_size;
    let rows = levels.len() / bc;
    for r in 0..rows {
        let wrow = &mut work[r * bc..(r + 1) * bc];
        let lrow = &mut levels[r * bc..(r + 1) * bc];
        for j in 0..bc {
            let g = (c0 + j) / gs;
            let scale = scales[(r0 + r) * ng + g];
            let zero = zeros[(r0 + r) * ng + g];
            let d = u[j * bc + j] as f32;
            let wv = wrow[j];
            let qv = grid.quantize_val(wv, scale, zero);
            lrow[j] = qv;
            let dq = grid.dequantize_val(qv, scale, zero);
            let err = (wv - dq) / d;
            if err != 0.0 {
                let urow = &u[j * bc..(j + 1) * bc];
                for k in j + 1..bc {
                    wrow[k] -= err * urow[k] as f32;
                }
            }
        }
    }
}

fn slice_square(h: &Tensor, c0: usize, c1: usize) -> Tensor {
    let n = c1 - c0;
    let mut out = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..n {
            out.set(i, j, h.at(c0 + i, c0 + j));
        }
    }
    out
}

/// SPD inverse + upper Cholesky factor of the inverse, with escalating
/// diagonal damping: single-instance blocks can be numerically
/// semidefinite (N < block width).
fn invert_with_retry(mut hi: Tensor) -> anyhow::Result<(Tensor, Vec<f64>)> {
    let n = hi.rows();
    let mut boost = 0.0f32;
    for attempt in 0..6 {
        match (spd_inverse(&hi), crate::linalg::cholesky_inverse_upper(&hi)) {
            (Ok(inv), Ok(u)) => return Ok((inv, u)),
            _ => {
                let mean_diag: f32 =
                    (0..n).map(|i| hi.at(i, i)).sum::<f32>() / n as f32;
                let add = (mean_diag.abs().max(1e-6)) * 10f32.powi(attempt - 2);
                boost += add;
                for i in 0..n {
                    hi.set(i, i, hi.at(i, i) + add);
                }
            }
        }
    }
    anyhow::bail!("block Hessian not invertible even with damping boost {boost}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::MemoryLedger;
    use crate::proptest::{prop_assert, Runner};
    use crate::quant::calib::HessianAccumulator;
    use crate::quant::gptq::{gptq_quantize, reconstruction_loss};
    use crate::quant::QuantConfig;
    use crate::rng::Pcg64;

    struct Fixture {
        x: Tensor,
        w: Tensor,
        h: Tensor,
        q1: QuantizedLinear,
        inst: SingleInstance,
    }

    fn fixture(out_f: usize, in_f: usize, n: usize, gs: usize, seed: u64) -> Fixture {
        let mut rng = Pcg64::seeded(seed);
        let ledger = MemoryLedger::new();
        let x = Tensor::randn(&[n, in_f], 1.0, &mut rng);
        let w = Tensor::randn(&[out_f, in_f], 0.5, &mut rng);
        let mut acc = HessianAccumulator::new(in_f, ledger.clone());
        acc.add_batch(&x);
        let (h, _) = acc.finalize(0.01);
        let cfg = QuantConfig { bits: 4, group_size: gs, block_size: gs, percdamp: 0.01 };
        let q1 = gptq_quantize(&w, &h, cfg, &ledger).unwrap().q;
        let inst = SingleInstance::capture(x.clone(), &w, &ledger);
        Fixture { x, w, h, q1, inst }
    }

    #[test]
    fn rpiq_never_worse_than_gptq_on_instance() {
        // Best-iterate selection guarantees Γ(final) <= Γ(0) on the
        // calibration instance.
        for seed in [71u64, 72, 73, 74] {
            let f = fixture(12, 48, 96, 12, seed);
            let out = rpiq_refine(
                &f.q1,
                &f.inst,
                &f.h,
                RpiqParams::default(),
                &MemoryLedger::new(),
            )
            .unwrap();
            let l_gptq = reconstruction_loss(&f.x, &f.w, &f.q1);
            let l_rpiq = reconstruction_loss(&f.x, &f.w, &out.q);
            assert!(
                l_rpiq <= l_gptq + 1e-9,
                "seed {seed}: rpiq {l_rpiq} vs gptq {l_gptq}"
            );
        }
    }

    #[test]
    fn rpiq_strictly_improves_typically() {
        // On generic Gaussian layers stage 2 should find real improvement
        // (this is the paper's headline claim at layer level).
        let mut improved = 0;
        for seed in [81u64, 82, 83, 84, 85, 86] {
            let f = fixture(8, 32, 64, 8, seed);
            let out = rpiq_refine(
                &f.q1,
                &f.inst,
                &f.h,
                RpiqParams::default(),
                &MemoryLedger::new(),
            )
            .unwrap();
            if out.reduction_pct() > 1.0 {
                improved += 1;
            }
        }
        assert!(improved >= 4, "only {improved}/6 layers improved >1%");
    }

    #[test]
    fn loss_trace_starts_at_gptq_loss() {
        let f = fixture(6, 24, 48, 8, 91);
        let out = rpiq_refine(
            &f.q1,
            &f.inst,
            &f.h,
            RpiqParams::default(),
            &MemoryLedger::new(),
        )
        .unwrap();
        let direct = reconstruction_loss(&f.x, &f.w, &f.q1);
        assert!(
            (out.loss_trace[0] - direct).abs() < 1e-6 * direct.max(1.0),
            "{} vs {direct}",
            out.loss_trace[0]
        );
        assert_eq!(out.loss_trace.len(), out.iters_run + 1);
    }

    #[test]
    fn zero_alpha_is_a_no_op() {
        let f = fixture(6, 24, 48, 8, 92);
        // alpha=0 ⇒ no movement ⇒ Γ exactly flat ⇒ runs to T_max but the
        // weights never change (flat sweeps are not an "increase").
        let params = RpiqParams { alpha: 0.0, max_iters: 5, ..Default::default() };
        let out = rpiq_refine(&f.q1, &f.inst, &f.h, params, &MemoryLedger::new()).unwrap();
        assert!(!out.early_stopped);
        assert_eq!(out.iters_run, 5);
        assert_eq!(out.q.packed, f.q1.packed);
        let l0 = out.loss_trace[0];
        assert!(out.loss_trace.iter().all(|&l| (l - l0).abs() < 1e-9 * l0.max(1.0)));
    }

    #[test]
    fn early_stop_fires_on_increase() {
        // Find a seed where the trace increases at some sweep with alpha=1
        // and check that early stopping truncates it there.
        let f = fixture(8, 32, 64, 8, 83);
        let free = rpiq_refine(
            &f.q1,
            &f.inst,
            &f.h,
            RpiqParams { alpha: 1.0, max_iters: 8, early_stop: false, ..Default::default() },
            &MemoryLedger::new(),
        )
        .unwrap();
        let increases = free
            .loss_trace
            .windows(2)
            .any(|w| w[1] > w[0] * (1.0 + 1e-9));
        if increases {
            let stopped = rpiq_refine(
                &f.q1,
                &f.inst,
                &f.h,
                RpiqParams { alpha: 1.0, max_iters: 8, early_stop: true, ..Default::default() },
                &MemoryLedger::new(),
            )
            .unwrap();
            assert!(stopped.early_stopped);
            assert!(stopped.iters_run < 8);
        }
    }

    #[test]
    fn max_iters_respected_without_early_stop() {
        let f = fixture(6, 24, 48, 8, 93);
        let params = RpiqParams { early_stop: false, max_iters: 3, ..Default::default() };
        let out = rpiq_refine(&f.q1, &f.inst, &f.h, params, &MemoryLedger::new()).unwrap();
        assert_eq!(out.iters_run, 3);
        assert!(!out.early_stopped);
    }

    #[test]
    fn output_stays_on_grid() {
        // Every returned weight must be exactly representable: deq(q) must
        // round-trip through the grid unchanged.
        let f = fixture(5, 20, 40, 5, 94);
        let out = rpiq_refine(
            &f.q1,
            &f.inst,
            &f.h,
            RpiqParams::default(),
            &MemoryLedger::new(),
        )
        .unwrap();
        let deq = out.q.dequantize();
        let reproj = out.q.project(&deq);
        assert!(deq.max_abs_diff(&reproj) < 1e-6);
        // params are inherited from stage 1 (single-instance refinement
        // does not refit scales)
        assert_eq!(out.q.scales, f.q1.scales);
        assert_eq!(out.q.zeros, f.q1.zeros);
    }

    #[test]
    fn refine_deterministic_across_thread_counts() {
        // out·bc² = 64·64² = 2¹⁸ reaches the flop cutoff, so the projector
        // genuinely row-shards; the refined weights, Γ trace, and stopping
        // behaviour must match the pinned single-thread run bit for bit.
        let _guard = crate::exec::thread_target_test_lock();
        let before = crate::exec::num_threads();
        let f = fixture(64, 128, 160, 64, 97);
        crate::exec::set_threads(1);
        let seq = rpiq_refine(&f.q1, &f.inst, &f.h, RpiqParams::default(), &MemoryLedger::new())
            .unwrap();
        let bits = |t: &[f64]| t.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for threads in [2usize, 4, 8] {
            crate::exec::set_threads(threads);
            let ledger = MemoryLedger::new();
            let par = rpiq_refine(&f.q1, &f.inst, &f.h, RpiqParams::default(), &ledger).unwrap();
            assert_eq!(seq.q.packed, par.q.packed, "packed levels @ {threads} threads");
            assert_eq!(
                bits(&seq.loss_trace),
                bits(&par.loss_trace),
                "Γ trace @ {threads} threads"
            );
            assert_eq!(seq.iters_run, par.iters_run);
            assert_eq!(seq.early_stopped, par.early_stopped);
            assert_eq!(ledger.live_bytes(), 0);
        }
        crate::exec::set_threads(before);
    }

    #[test]
    fn ledger_balanced() {
        let f = fixture(6, 24, 48, 8, 95);
        let ledger = MemoryLedger::new();
        let _ = rpiq_refine(&f.q1, &f.inst, &f.h, RpiqParams::default(), &ledger).unwrap();
        assert_eq!(ledger.live_bytes(), 0);
        assert!(ledger.peak_for(tags::RPIQ_PRECOMP) > 0);
    }

    #[test]
    fn gauss_seidel_beats_jacobi_style_single_sweep() {
        // With the incremental Y_q update disabled (simulated by running
        // alpha on isolated copies), later blocks wouldn't see earlier
        // corrections. We approximate the comparison by checking that two
        // sweeps with GS ordering reduce loss at least as much as one
        // sweep, i.e. the closed loop keeps making progress.
        let f = fixture(10, 40, 80, 10, 96);
        let one = rpiq_refine(
            &f.q1,
            &f.inst,
            &f.h,
            RpiqParams { max_iters: 1, early_stop: false, ..Default::default() },
            &MemoryLedger::new(),
        )
        .unwrap();
        let five = rpiq_refine(
            &f.q1,
            &f.inst,
            &f.h,
            RpiqParams { max_iters: 5, early_stop: false, ..Default::default() },
            &MemoryLedger::new(),
        )
        .unwrap();
        let l1 = *one.loss_trace.last().unwrap();
        let l5 = five.loss_trace.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(l5 <= l1 + 1e-9);
    }

    #[test]
    fn property_best_iterate_monotone_vs_trace() {
        Runner::new("rpiq_best_not_worse_than_trace", 8).run(|g| {
            let in_f = 8 * g.usize_in(2..5);
            let out_f = g.usize_in(3..8);
            let n = in_f * 2;
            let seed = g.usize_in(0..10_000) as u64;
            let f = fixture(out_f, in_f, n, 8, seed);
            let out = rpiq_refine(
                &f.q1,
                &f.inst,
                &f.h,
                RpiqParams::default(),
                &MemoryLedger::new(),
            )
            .unwrap();
            let best = reconstruction_loss(&f.x, &f.w, &out.q);
            let trace_min = out
                .loss_trace
                .iter()
                .cloned()
                .fold(f64::INFINITY, f64::min);
            prop_assert(
                (best - trace_min).abs() <= 1e-6 * trace_min.max(1.0),
                &format!("returned weights realize min of trace: {best} vs {trace_min}"),
            )
        });
    }
}
