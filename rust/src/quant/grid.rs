//! The quantization grid `Q(·)`: asymmetric, group-wise, low-bit integer
//! representation of weight matrices, with the 4-bit deployment format
//! stored **nibble-resident** — the packed buffer is the only level
//! storage a [`QuantizedLinear`] holds, consumed directly by the fused
//! dequant-matmul and the Pallas `quant_matmul` kernel's argument
//! marshalling.
//!
//! Layout conventions (shared with `python/compile/kernels/quant_matmul.py`
//! — keep in sync, the pytest suite cross-checks via golden files):
//!
//! * weights are `[out_features, in_features]` (paper's `W ∈ R^{Cout×Cin}`);
//! * groups run along the **input** axis: group `g` covers input channels
//!   `[g·gs, (g+1)·gs)`;
//! * `scales`/`zeros` are `[out_features, n_groups]`, with `zero` stored as
//!   the *integer* zero point so `deq(q) = (q - zero) · scale`;
//! * grids of ≤ 4 bits pack two channels per byte: channel `2k` in the low
//!   nibble and `2k+1` in the high nibble of byte `k` of a row (odd
//!   `in_features` leaves the tail byte's high nibble zero); ≥ 5-bit grids
//!   keep one byte per channel.
//!
//! The quantization engines (`gptq`, `rpiq`) build levels in transient
//! byte-per-level working buffers and convert via [`QuantizedLinear::from_levels`]
//! — only the packed form is ever resident in a deployed model.

use crate::tensor::Tensor;

/// A (bits, group_size) grid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantGrid {
    pub bits: u32,
    pub group_size: usize,
}

impl QuantGrid {
    pub fn new(bits: u32, group_size: usize) -> Self {
        assert!((2..=8).contains(&bits), "bits must be in 2..=8, got {bits}");
        assert!(group_size >= 1);
        QuantGrid { bits, group_size }
    }

    /// Maximum integer level (`2^bits - 1`).
    #[inline]
    pub fn maxq(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }

    /// Whether this grid's levels fit a nibble (and therefore pack two
    /// channels per byte in the resident form).
    #[inline]
    pub fn nibble_packed(&self) -> bool {
        self.bits <= 4
    }

    /// Resident bytes of one packed row of `in_features` levels.
    #[inline]
    pub fn packed_row_bytes(&self, in_features: usize) -> usize {
        if self.nibble_packed() {
            in_features.div_ceil(2)
        } else {
            in_features
        }
    }

    /// Asymmetric (scale, zero) for one group of weights.
    ///
    /// Matches GPTQ's `find_params`: the range always includes 0 so that
    /// exact zeros stay exact; degenerate all-constant groups get scale 1.
    pub fn find_params(&self, group: &[f32]) -> (f32, f32) {
        let mut lo = 0.0f32;
        let mut hi = 0.0f32;
        for &v in group {
            if v < lo {
                lo = v;
            }
            if v > hi {
                hi = v;
            }
        }
        if lo == hi {
            // all-zero (or constant-zero-range) group
            return (1.0, 0.0);
        }
        let scale = (hi - lo) / self.maxq();
        let zero = (-lo / scale).round();
        (scale, zero)
    }

    /// Quantize one value to its integer level under (scale, zero).
    #[inline]
    pub fn quantize_val(&self, w: f32, scale: f32, zero: f32) -> u8 {
        let q = (w / scale + zero).round();
        q.clamp(0.0, self.maxq()) as u8
    }

    /// Dequantize an integer level.
    #[inline]
    pub fn dequantize_val(&self, q: u8, scale: f32, zero: f32) -> f32 {
        (q as f32 - zero) * scale
    }

    /// Round-trip a value through the grid (the paper's `Q(·)` projection
    /// for a *fixed* (scale, zero)).
    #[inline]
    pub fn project_val(&self, w: f32, scale: f32, zero: f32) -> f32 {
        self.dequantize_val(self.quantize_val(w, scale, zero), scale, zero)
    }

    /// Number of groups covering `in_features` channels.
    pub fn n_groups(&self, in_features: usize) -> usize {
        in_features.div_ceil(self.group_size)
    }
}

/// A quantized weight matrix in deployment format: the integer levels live
/// **packed** (two channels per byte on ≤4-bit grids) — there is no
/// byte-per-level copy resident, matching the memory the paper's "Mem"
/// columns claim for the deployed model.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub grid: QuantGrid,
    pub out_features: usize,
    pub in_features: usize,
    /// Packed integer levels, `[out, packed_cols]` row-major: nibble pairs
    /// on ≤4-bit grids (low nibble = even channel), one byte per channel
    /// on ≥5-bit grids. See [`Self::packed_cols`].
    pub packed: Vec<u8>,
    /// `[out, n_groups]` row-major.
    pub scales: Vec<f32>,
    /// `[out, n_groups]` row-major, integer zero points stored as f32.
    pub zeros: Vec<f32>,
}

impl QuantizedLinear {
    /// Allocate an all-zero quantized matrix with the given params.
    pub fn empty(grid: QuantGrid, out_features: usize, in_features: usize) -> Self {
        let ng = grid.n_groups(in_features);
        QuantizedLinear {
            grid,
            out_features,
            in_features,
            packed: vec![0; out_features * grid.packed_row_bytes(in_features)],
            scales: vec![1.0; out_features * ng],
            zeros: vec![0.0; out_features * ng],
        }
    }

    /// Bytes per packed row (`div_ceil(in, 2)` nibble-packed, `in` else).
    #[inline]
    pub fn packed_cols(&self) -> usize {
        self.grid.packed_row_bytes(self.in_features)
    }

    /// Build the resident form from a transient byte-per-level buffer
    /// (`[out, in]` row-major) — the hand-off point of the quantization
    /// engines, which walk columns over unpacked working levels.
    pub fn from_levels(
        grid: QuantGrid,
        out_features: usize,
        in_features: usize,
        levels: &[u8],
        scales: Vec<f32>,
        zeros: Vec<f32>,
    ) -> Self {
        assert_eq!(levels.len(), out_features * in_features);
        let ng = grid.n_groups(in_features);
        assert_eq!(scales.len(), out_features * ng);
        assert_eq!(zeros.len(), out_features * ng);
        let pcols = grid.packed_row_bytes(in_features);
        let packed = if grid.nibble_packed() {
            let mut out = vec![0u8; out_features * pcols];
            for r in 0..out_features {
                let lrow = &levels[r * in_features..(r + 1) * in_features];
                let prow = &mut out[r * pcols..(r + 1) * pcols];
                for (c, &q) in lrow.iter().enumerate() {
                    let q = q & 0x0F;
                    if c % 2 == 0 {
                        prow[c / 2] |= q;
                    } else {
                        prow[c / 2] |= q << 4;
                    }
                }
            }
            out
        } else {
            levels.to_vec()
        };
        QuantizedLinear { grid, out_features, in_features, packed, scales, zeros }
    }

    /// Round-to-nearest quantization of a full matrix (the non-GPTQ
    /// baseline, also used to initialize per-group params).
    pub fn quantize_rtn(w: &Tensor, grid: QuantGrid) -> Self {
        let (out_f, in_f) = (w.rows(), w.cols());
        let ng = grid.n_groups(in_f);
        let mut levels = vec![0u8; out_f * in_f];
        let mut scales = vec![1.0f32; out_f * ng];
        let mut zeros = vec![0.0f32; out_f * ng];
        for r in 0..out_f {
            let row = w.row(r);
            for g in 0..ng {
                let c0 = g * grid.group_size;
                let c1 = (c0 + grid.group_size).min(in_f);
                let (scale, zero) = grid.find_params(&row[c0..c1]);
                scales[r * ng + g] = scale;
                zeros[r * ng + g] = zero;
                for c in c0..c1 {
                    levels[r * in_f + c] = grid.quantize_val(row[c], scale, zero);
                }
            }
        }
        Self::from_levels(grid, out_f, in_f, &levels, scales, zeros)
    }

    #[inline]
    pub fn n_groups(&self) -> usize {
        self.grid.n_groups(self.in_features)
    }

    #[inline]
    pub fn scale_at(&self, r: usize, c: usize) -> f32 {
        self.scales[r * self.n_groups() + c / self.grid.group_size]
    }

    #[inline]
    pub fn zero_at(&self, r: usize, c: usize) -> f32 {
        self.zeros[r * self.n_groups() + c / self.grid.group_size]
    }

    /// Integer level of element (r, c), read out of the packed buffer.
    #[inline]
    pub fn level_at(&self, r: usize, c: usize) -> u8 {
        if self.grid.nibble_packed() {
            let byte = self.packed[r * self.packed_cols() + c / 2];
            if c % 2 == 0 {
                byte & 0x0F
            } else {
                byte >> 4
            }
        } else {
            self.packed[r * self.in_features + c]
        }
    }

    /// Overwrite the integer level of element (r, c) in the packed buffer.
    #[inline]
    pub fn set_level(&mut self, r: usize, c: usize, q: u8) {
        if self.grid.nibble_packed() {
            let byte = &mut self.packed[r * self.grid.packed_row_bytes(self.in_features) + c / 2];
            if c % 2 == 0 {
                *byte = (*byte & 0xF0) | (q & 0x0F);
            } else {
                *byte = (*byte & 0x0F) | ((q & 0x0F) << 4);
            }
        } else {
            self.packed[r * self.in_features + c] = q;
        }
    }

    /// Unpacked byte-per-level copy `[out, in]` — a *transient* view for
    /// the artifact marshalling and tests; the resident form stays packed.
    pub fn levels(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.out_features * self.in_features];
        for r in 0..self.out_features {
            for c in 0..self.in_features {
                out[r * self.in_features + c] = self.level_at(r, c);
            }
        }
        out
    }

    /// Set the integer level of element (r, c) by projecting `w`.
    #[inline]
    pub fn set_from_float(&mut self, r: usize, c: usize, w: f32) {
        let q = self
            .grid
            .quantize_val(w, self.scale_at(r, c), self.zero_at(r, c));
        self.set_level(r, c, q);
    }

    /// Dequantized element.
    #[inline]
    pub fn deq_at(&self, r: usize, c: usize) -> f32 {
        self.grid
            .dequantize_val(self.level_at(r, c), self.scale_at(r, c), self.zero_at(r, c))
    }

    /// Dequantize row `r` into `out` (`in_features` slots), fusing the
    /// nibble unpack with the group-wise dequant — the per-row kernel under
    /// the fused dequant-matmul (`model::quantized::qmatmul_rows`). Per
    /// element this runs the exact float op `(q − zero)·scale` the old
    /// byte-per-level kernel ran, so outputs are bit-identical.
    pub fn deq_row_into(&self, r: usize, out: &mut [f32]) {
        let in_f = self.in_features;
        debug_assert_eq!(out.len(), in_f);
        let ng = self.n_groups();
        let gs = self.grid.group_size;
        if self.grid.nibble_packed() {
            let pcols = self.packed_cols();
            let prow = &self.packed[r * pcols..(r + 1) * pcols];
            for g in 0..ng {
                let c0 = g * gs;
                let c1 = (c0 + gs).min(in_f);
                let scale = self.scales[r * ng + g];
                let zero = self.zeros[r * ng + g];
                for c in c0..c1 {
                    let byte = prow[c / 2];
                    let q = if c % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                    out[c] = (q as f32 - zero) * scale;
                }
            }
        } else {
            let prow = &self.packed[r * in_f..(r + 1) * in_f];
            for g in 0..ng {
                let c0 = g * gs;
                let c1 = (c0 + gs).min(in_f);
                let scale = self.scales[r * ng + g];
                let zero = self.zeros[r * ng + g];
                for c in c0..c1 {
                    out[c] = (prow[c] as f32 - zero) * scale;
                }
            }
        }
    }

    /// Dequantize the column span `[c0, c1)` of row `r` into strided
    /// slots: element `c` lands at `out[(c − c0) · stride]`. This is the
    /// lane-batched sibling of [`Self::deq_row_into`] that the tiled
    /// microkernel uses to pack K-major weight panels: with `stride = NR`
    /// each call writes one panel *lane* and consecutive k steps stay
    /// `NR` floats apart, so the panel ends up `[kc][NR]` K-major.
    ///
    /// The nibble path walks packed *bytes* rather than elements: after
    /// an odd-alignment head, each byte emits its two levels (low nibble
    /// = even channel) in one read, halving the packed-buffer loads of
    /// the per-element walk. Per element the float op is the exact
    /// `(q − zero)·scale` of [`Self::deq_row_into`], so a stride-1 call
    /// over `[0, in_features)` is bit-identical to it.
    pub fn deq_span_strided(&self, r: usize, c0: usize, c1: usize, stride: usize, out: &mut [f32]) {
        debug_assert!(c1 <= self.in_features && c0 <= c1);
        debug_assert!(stride >= 1);
        if c0 == c1 {
            return;
        }
        debug_assert!(out.len() > (c1 - c0 - 1) * stride);
        let ng = self.n_groups();
        let gs = self.grid.group_size;
        let g_last = (c1 - 1) / gs;
        if self.grid.nibble_packed() {
            let pcols = self.packed_cols();
            let prow = &self.packed[r * pcols..(r + 1) * pcols];
            for g in (c0 / gs)..=g_last {
                let scale = self.scales[r * ng + g];
                let zero = self.zeros[r * ng + g];
                let lo = (g * gs).max(c0);
                let hi = ((g + 1) * gs).min(c1);
                let mut c = lo;
                if c < hi && c % 2 == 1 {
                    // odd head: high nibble of the straddling byte
                    out[(c - c0) * stride] = ((prow[c / 2] >> 4) as f32 - zero) * scale;
                    c += 1;
                }
                while c + 1 < hi {
                    // byte-at-a-time body: two levels per packed read
                    let byte = prow[c / 2];
                    out[(c - c0) * stride] = ((byte & 0x0F) as f32 - zero) * scale;
                    out[(c + 1 - c0) * stride] = ((byte >> 4) as f32 - zero) * scale;
                    c += 2;
                }
                if c < hi {
                    // even tail: low nibble only
                    out[(c - c0) * stride] = ((prow[c / 2] & 0x0F) as f32 - zero) * scale;
                }
            }
        } else {
            let in_f = self.in_features;
            let prow = &self.packed[r * in_f..(r + 1) * in_f];
            for g in (c0 / gs)..=g_last {
                let scale = self.scales[r * ng + g];
                let zero = self.zeros[r * ng + g];
                let lo = (g * gs).max(c0);
                let hi = ((g + 1) * gs).min(c1);
                for c in lo..hi {
                    out[(c - c0) * stride] = (prow[c] as f32 - zero) * scale;
                }
            }
        }
    }

    /// Full dequantized matrix `[out, in]`.
    pub fn dequantize(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.out_features, self.in_features]);
        for r in 0..self.out_features {
            self.deq_row_into(r, out.row_mut(r));
        }
        out
    }

    /// Project an arbitrary float matrix onto *this* grid (fixed params),
    /// returning the dequantized projection. This is the paper's Eq. 7
    /// `B̃ = Q(B*)` — stage-2 keeps stage-1's (scale, zero).
    pub fn project(&self, w: &Tensor) -> Tensor {
        assert_eq!(w.rows(), self.out_features);
        assert_eq!(w.cols(), self.in_features);
        let ng = self.n_groups();
        let mut out = Tensor::zeros(&[self.out_features, self.in_features]);
        for r in 0..self.out_features {
            let src = w.row(r);
            let dst = out.row_mut(r);
            for c in 0..self.in_features {
                let g = c / self.grid.group_size;
                dst[c] = self
                    .grid
                    .project_val(src[c], self.scales[r * ng + g], self.zeros[r * ng + g]);
            }
        }
        out
    }

    /// Overwrite integer levels for columns `[c0, c1)` from a float block
    /// (projection with fixed params).
    pub fn set_cols_from_float(&mut self, c0: usize, block: &Tensor) {
        let bc = block.cols();
        assert_eq!(block.rows(), self.out_features);
        assert!(c0 + bc <= self.in_features);
        for r in 0..self.out_features {
            let src = block.row(r);
            for (j, &v) in src.iter().enumerate() {
                self.set_from_float(r, c0 + j, v);
            }
        }
    }

    /// Dequantized copy of columns `[c0, c1)`.
    pub fn deq_cols(&self, c0: usize, c1: usize) -> Tensor {
        let mut out = Tensor::zeros(&[self.out_features, c1 - c0]);
        for r in 0..self.out_features {
            let dst = out.row_mut(r);
            for c in c0..c1 {
                dst[c - c0] = self.deq_at(r, c);
            }
        }
        out
    }

    /// The deployment byte buffer handed to the PJRT artifacts — with the
    /// nibble-resident representation this is simply a copy of the packed
    /// levels (no conversion happens; the model already lives packed).
    pub fn pack(&self) -> Vec<u8> {
        self.packed.clone()
    }

    /// Reconstruct a linear from a packed nibble buffer (the inverse of
    /// [`Self::pack`] for ≤4-bit grids). Errors — instead of panicking —
    /// when the buffer or param lengths don't match the declared shape,
    /// so corrupt checkpoint payloads surface as messages, not slice
    /// panics.
    pub fn unpack4(
        packed: &[u8],
        grid: QuantGrid,
        out_features: usize,
        in_features: usize,
        scales: Vec<f32>,
        zeros: Vec<f32>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            grid.nibble_packed(),
            "unpack4 expects a ≤4-bit grid, got {} bits",
            grid.bits
        );
        Self::from_packed(packed.to_vec(), grid, out_features, in_features, scales, zeros)
    }

    /// Adopt an already-packed level buffer (any bit width) — the
    /// checkpoint loader's entry point. Validates every length against the
    /// declared shape with a clear error.
    pub fn from_packed(
        packed: Vec<u8>,
        grid: QuantGrid,
        out_features: usize,
        in_features: usize,
        scales: Vec<f32>,
        zeros: Vec<f32>,
    ) -> anyhow::Result<Self> {
        let want = out_features * grid.packed_row_bytes(in_features);
        anyhow::ensure!(
            packed.len() == want,
            "packed buffer holds {} bytes, but a {}x{} {}-bit linear needs {}",
            packed.len(),
            out_features,
            in_features,
            grid.bits,
            want
        );
        let ng = grid.n_groups(in_features);
        anyhow::ensure!(
            scales.len() == out_features * ng && zeros.len() == out_features * ng,
            "group params hold {}/{} entries, expected {} ({} rows x {} groups)",
            scales.len(),
            zeros.len(),
            out_features * ng,
            out_features,
            ng
        );
        Ok(QuantizedLinear { grid, out_features, in_features, packed, scales, zeros })
    }

    /// Resident deployment size in bytes (packed levels + group params) —
    /// exactly the bytes this struct keeps alive, and the quantity the
    /// paper's "Mem (GB)" columns report per weight matrix.
    pub fn nbytes(&self) -> usize {
        self.packed.len() + (self.scales.len() + self.zeros.len()) * 4
    }

    /// Worst-case absolute reconstruction error of this grid's step.
    pub fn max_step(&self) -> f32 {
        self.scales.iter().cloned().fold(0.0, f32::max) * 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{prop_assert, Runner};
    use crate::rng::Pcg64;

    #[test]
    fn rtn_roundtrip_error_bounded() {
        let mut rng = Pcg64::seeded(41);
        let w = Tensor::randn(&[8, 32], 1.0, &mut rng);
        let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 16));
        let deq = q.dequantize();
        // error bounded by half a step per group
        for r in 0..8 {
            for c in 0..32 {
                let step = q.scale_at(r, c);
                assert!(
                    (deq.at(r, c) - w.at(r, c)).abs() <= 0.5 * step + 1e-6,
                    "({r},{c})"
                );
            }
        }
    }

    #[test]
    fn eight_bit_is_finer_than_four_bit() {
        let mut rng = Pcg64::seeded(42);
        let w = Tensor::randn(&[4, 64], 1.0, &mut rng);
        let q4 = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 64));
        let q8 = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(8, 64));
        let e4 = q4.dequantize().sub(&w).frob_sq();
        let e8 = q8.dequantize().sub(&w).frob_sq();
        assert!(e8 < e4 / 4.0, "e8={e8} e4={e4}");
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Pcg64::seeded(43);
        for in_f in [6usize, 7, 16, 33] {
            let w = Tensor::randn(&[5, in_f], 1.0, &mut rng);
            let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 8));
            let packed = q.pack();
            assert_eq!(packed.len(), 5 * in_f.div_ceil(2), "in_f={in_f}");
            let q2 = QuantizedLinear::unpack4(
                &packed,
                q.grid,
                q.out_features,
                q.in_features,
                q.scales.clone(),
                q.zeros.clone(),
            )
            .unwrap();
            assert_eq!(q.levels(), q2.levels(), "in_f={in_f}");
            assert_eq!(q.packed, q2.packed, "in_f={in_f}");
        }
    }

    #[test]
    fn pack_unpack_roundtrip_property_all_grids() {
        // The satellite contract: round-trips hold for odd in_features
        // (the div_ceil tail byte) and across bit widths — nibble-packed
        // 3-bit as well as byte-resident 8-bit grids.
        Runner::new("grid_pack_unpack_roundtrip", 64).run(|g| {
            let bits = [3u32, 4, 8][g.usize_in(0..3)];
            let rows = g.usize_in(1..6);
            let cols = g.usize_in(1..40); // odd widths included
            let gs = g.usize_in(1..cols.max(2));
            let data = g.matrix(rows, cols, 2.0);
            let w = Tensor::from_vec(&[rows, cols], data);
            let grid = QuantGrid::new(bits, gs);
            let q = QuantizedLinear::quantize_rtn(&w, grid);
            let q2 = QuantizedLinear::from_packed(
                q.pack(),
                grid,
                rows,
                cols,
                q.scales.clone(),
                q.zeros.clone(),
            )
            .expect("valid buffer");
            prop_assert(q.levels() == q2.levels(), "levels round-trip")?;
            prop_assert(q.packed == q2.packed, "packed bytes round-trip")?;
            // from_levels is the inverse direction of levels()
            let q3 = QuantizedLinear::from_levels(
                grid,
                rows,
                cols,
                &q.levels(),
                q.scales.clone(),
                q.zeros.clone(),
            );
            prop_assert(q3.packed == q.packed, "from_levels(levels()) identity")
        });
    }

    #[test]
    fn unpack_rejects_wrong_lengths_with_clear_error() {
        let grid = QuantGrid::new(4, 8);
        // 5 rows x 7 cols nibble-packed needs 5 * ceil(7/2) = 20 bytes
        let err = QuantizedLinear::unpack4(&[0u8; 19], grid, 5, 7, vec![1.0; 5], vec![0.0; 5])
            .unwrap_err();
        assert!(err.to_string().contains("19 bytes"), "{err}");
        // wrong group-param length
        let err =
            QuantizedLinear::unpack4(&[0u8; 20], grid, 5, 7, vec![1.0; 4], vec![0.0; 5])
                .unwrap_err();
        assert!(err.to_string().contains("group params"), "{err}");
        // a ≥5-bit grid is not nibble-packed
        let err = QuantizedLinear::unpack4(
            &[0u8; 35],
            QuantGrid::new(8, 8),
            5,
            7,
            vec![1.0; 5],
            vec![0.0; 5],
        )
        .unwrap_err();
        assert!(err.to_string().contains("4-bit"), "{err}");
    }

    #[test]
    fn level_accessors_roundtrip_odd_width() {
        // set_level/level_at cover both nibbles and the tail byte.
        let mut q = QuantizedLinear::empty(QuantGrid::new(4, 8), 3, 7);
        for r in 0..3 {
            for c in 0..7 {
                q.set_level(r, c, ((r * 7 + c) % 16) as u8);
            }
        }
        for r in 0..3 {
            for c in 0..7 {
                assert_eq!(q.level_at(r, c), ((r * 7 + c) % 16) as u8, "({r},{c})");
            }
        }
        // writing one nibble never clobbers its neighbour
        q.set_level(1, 2, 0xF);
        assert_eq!(q.level_at(1, 3), (7 + 3) % 16, "high nibble intact");
    }

    #[test]
    fn zero_stays_exact() {
        // find_params includes 0 in the range, so an exact 0 weight must
        // round-trip to exactly 0 — GPTQ relies on this for pruned weights.
        let w = Tensor::from_vec(&[1, 4], vec![0.0, 0.5, 1.0, -0.25]);
        let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 4));
        assert_eq!(q.deq_at(0, 0), 0.0);
    }

    #[test]
    fn all_zero_group_safe() {
        let w = Tensor::zeros(&[2, 8]);
        let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 4));
        let deq = q.dequantize();
        assert_eq!(deq.data(), w.data());
    }

    #[test]
    fn projection_is_idempotent_property() {
        Runner::new("grid_projection_idempotent", 64).run(|g| {
            let rows = g.usize_in(1..6);
            let cols = g.usize_in(1..40);
            let gs = g.usize_in(1..cols.max(2));
            let data = g.matrix(rows, cols, 2.0);
            let w = Tensor::from_vec(&[rows, cols], data);
            let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, gs));
            let p1 = q.project(&w);
            let p2 = q.project(&p1);
            prop_assert(p1.max_abs_diff(&p2) < 1e-6, "Q(Q(w)) == Q(w)")
        });
    }

    #[test]
    fn quantize_levels_in_range_property() {
        Runner::new("grid_levels_in_range", 64).run(|g| {
            let bits = g.usize_in(2..9) as u32;
            let grid = QuantGrid::new(bits, 8);
            let vals = g.vec_f32(1..64, -100.0..100.0);
            let (scale, zero) = grid.find_params(&vals);
            for &v in &vals {
                let q = grid.quantize_val(v, scale, zero);
                prop_assert(
                    (q as f32) <= grid.maxq(),
                    "level within maxq",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn deq_span_strided_matches_per_element_dequant_property() {
        // The tiled kernel's panel packer: any (bits, group_size, span,
        // stride) combination — odd span starts (the nibble head/tail
        // paths), group-straddling spans, 3/4/8-bit grids — must emit
        // exactly deq_at(r, c) at out[(c - c0)·stride], touching nothing
        // else.
        Runner::new("grid_deq_span_strided", 96).run(|g| {
            let bits = [3u32, 4, 8][g.usize_in(0..3)];
            let rows = g.usize_in(1..5);
            let cols = g.usize_in(1..48); // odd widths included
            let gs = g.usize_in(1..cols.max(2));
            let data = g.matrix(rows, cols, 2.0);
            let w = Tensor::from_vec(&[rows, cols], data);
            let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(bits, gs));
            let r = g.usize_in(0..rows);
            let c0 = g.usize_in(0..cols);
            let c1 = c0 + g.usize_in(0..cols + 1 - c0);
            let stride = g.usize_in(1..5);
            let span = c1 - c0;
            let len = span.max(1) * stride + 2; // slack slots must stay untouched
            let mut out = vec![f32::NAN; len];
            q.deq_span_strided(r, c0, c1, stride, &mut out);
            for c in c0..c1 {
                prop_assert(
                    out[(c - c0) * stride] == q.deq_at(r, c),
                    "strided slot == deq_at",
                )?;
            }
            for (i, v) in out.iter().enumerate() {
                let on_span = i % stride == 0 && i / stride < span;
                if !on_span {
                    prop_assert(v.is_nan(), "off-span slot untouched")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn deq_span_strided_full_row_bit_identical_to_deq_row_into() {
        // stride-1 full-span call must be bit-identical to the scalar
        // kernel's row dequant (the documented contract).
        let mut rng = Pcg64::seeded(47);
        for (bits, cols) in [(3u32, 33usize), (4, 96), (4, 33), (8, 40)] {
            let w = Tensor::randn(&[6, cols], 1.0, &mut rng);
            let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(bits, 16));
            for r in 0..6 {
                let mut a = vec![0.0f32; cols];
                let mut b = vec![0.0f32; cols];
                q.deq_row_into(r, &mut a);
                q.deq_span_strided(r, 0, cols, 1, &mut b);
                assert_eq!(
                    a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "bits={bits} cols={cols} r={r}"
                );
            }
        }
    }

    #[test]
    fn nbytes_reflects_4bit_compression() {
        let mut rng = Pcg64::seeded(44);
        let w = Tensor::randn(&[128, 256], 1.0, &mut rng);
        let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 128));
        let fp_bytes = 128 * 256 * 4;
        // nibble-resident levels: exactly out * ceil(in/2) bytes live
        assert_eq!(q.packed.len(), 128 * 128);
        assert_eq!(q.nbytes(), q.packed.len() + (q.scales.len() + q.zeros.len()) * 4);
        // 4-bit + params should be well under 30% of fp32 — and with the
        // packed representation this is the *resident* footprint, not an
        // accounting fiction.
        assert!((q.nbytes() as f64) < 0.30 * fp_bytes as f64);
    }
}
