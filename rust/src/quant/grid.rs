//! The quantization grid `Q(·)`: asymmetric, group-wise, low-bit integer
//! representation of weight matrices, plus nibble packing for the 4-bit
//! deployment format consumed by the Pallas `quant_matmul` kernel and the
//! Rust fallback path.
//!
//! Layout conventions (shared with `python/compile/kernels/quant_matmul.py`
//! — keep in sync, the pytest suite cross-checks via golden files):
//!
//! * weights are `[out_features, in_features]` (paper's `W ∈ R^{Cout×Cin}`);
//! * groups run along the **input** axis: group `g` covers input channels
//!   `[g·gs, (g+1)·gs)`;
//! * `scales`/`zeros` are `[out_features, n_groups]`, with `zero` stored as
//!   the *integer* zero point so `deq(q) = (q - zero) · scale`;
//! * 4-bit packing puts channel `2k` in the low nibble and `2k+1` in the
//!   high nibble of byte `k` of a row.

use crate::tensor::Tensor;

/// A (bits, group_size) grid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantGrid {
    pub bits: u32,
    pub group_size: usize,
}

impl QuantGrid {
    pub fn new(bits: u32, group_size: usize) -> Self {
        assert!((2..=8).contains(&bits), "bits must be in 2..=8, got {bits}");
        assert!(group_size >= 1);
        QuantGrid { bits, group_size }
    }

    /// Maximum integer level (`2^bits - 1`).
    #[inline]
    pub fn maxq(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }

    /// Asymmetric (scale, zero) for one group of weights.
    ///
    /// Matches GPTQ's `find_params`: the range always includes 0 so that
    /// exact zeros stay exact; degenerate all-constant groups get scale 1.
    pub fn find_params(&self, group: &[f32]) -> (f32, f32) {
        let mut lo = 0.0f32;
        let mut hi = 0.0f32;
        for &v in group {
            if v < lo {
                lo = v;
            }
            if v > hi {
                hi = v;
            }
        }
        if lo == hi {
            // all-zero (or constant-zero-range) group
            return (1.0, 0.0);
        }
        let scale = (hi - lo) / self.maxq();
        let zero = (-lo / scale).round();
        (scale, zero)
    }

    /// Quantize one value to its integer level under (scale, zero).
    #[inline]
    pub fn quantize_val(&self, w: f32, scale: f32, zero: f32) -> u8 {
        let q = (w / scale + zero).round();
        q.clamp(0.0, self.maxq()) as u8
    }

    /// Dequantize an integer level.
    #[inline]
    pub fn dequantize_val(&self, q: u8, scale: f32, zero: f32) -> f32 {
        (q as f32 - zero) * scale
    }

    /// Round-trip a value through the grid (the paper's `Q(·)` projection
    /// for a *fixed* (scale, zero)).
    #[inline]
    pub fn project_val(&self, w: f32, scale: f32, zero: f32) -> f32 {
        self.dequantize_val(self.quantize_val(w, scale, zero), scale, zero)
    }

    /// Number of groups covering `in_features` channels.
    pub fn n_groups(&self, in_features: usize) -> usize {
        in_features.div_ceil(self.group_size)
    }
}

/// A quantized weight matrix in deployment format.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub grid: QuantGrid,
    pub out_features: usize,
    pub in_features: usize,
    /// Integer levels, one byte per weight, `[out, in]` row-major.
    /// (The packed nibble form is produced on demand by [`Self::pack`].)
    pub qweight: Vec<u8>,
    /// `[out, n_groups]` row-major.
    pub scales: Vec<f32>,
    /// `[out, n_groups]` row-major, integer zero points stored as f32.
    pub zeros: Vec<f32>,
}

impl QuantizedLinear {
    /// Allocate an all-zero quantized matrix with the given params.
    pub fn empty(grid: QuantGrid, out_features: usize, in_features: usize) -> Self {
        let ng = grid.n_groups(in_features);
        QuantizedLinear {
            grid,
            out_features,
            in_features,
            qweight: vec![0; out_features * in_features],
            scales: vec![1.0; out_features * ng],
            zeros: vec![0.0; out_features * ng],
        }
    }

    /// Round-to-nearest quantization of a full matrix (the non-GPTQ
    /// baseline, also used to initialize per-group params).
    pub fn quantize_rtn(w: &Tensor, grid: QuantGrid) -> Self {
        let (out_f, in_f) = (w.rows(), w.cols());
        let mut q = Self::empty(grid, out_f, in_f);
        let ng = grid.n_groups(in_f);
        for r in 0..out_f {
            let row = w.row(r);
            for g in 0..ng {
                let c0 = g * grid.group_size;
                let c1 = (c0 + grid.group_size).min(in_f);
                let (scale, zero) = grid.find_params(&row[c0..c1]);
                q.scales[r * ng + g] = scale;
                q.zeros[r * ng + g] = zero;
                for c in c0..c1 {
                    q.qweight[r * in_f + c] = grid.quantize_val(row[c], scale, zero);
                }
            }
        }
        q
    }

    #[inline]
    pub fn n_groups(&self) -> usize {
        self.grid.n_groups(self.in_features)
    }

    #[inline]
    pub fn scale_at(&self, r: usize, c: usize) -> f32 {
        self.scales[r * self.n_groups() + c / self.grid.group_size]
    }

    #[inline]
    pub fn zero_at(&self, r: usize, c: usize) -> f32 {
        self.zeros[r * self.n_groups() + c / self.grid.group_size]
    }

    /// Set the integer level of element (r, c) by projecting `w`.
    #[inline]
    pub fn set_from_float(&mut self, r: usize, c: usize, w: f32) {
        let q = self
            .grid
            .quantize_val(w, self.scale_at(r, c), self.zero_at(r, c));
        self.qweight[r * self.in_features + c] = q;
    }

    /// Dequantized element.
    #[inline]
    pub fn deq_at(&self, r: usize, c: usize) -> f32 {
        self.grid.dequantize_val(
            self.qweight[r * self.in_features + c],
            self.scale_at(r, c),
            self.zero_at(r, c),
        )
    }

    /// Full dequantized matrix `[out, in]`.
    pub fn dequantize(&self) -> Tensor {
        let ng = self.n_groups();
        let mut out = Tensor::zeros(&[self.out_features, self.in_features]);
        for r in 0..self.out_features {
            let row = out.row_mut(r);
            for c in 0..self.in_features {
                let g = c / self.grid.group_size;
                let scale = self.scales[r * ng + g];
                let zero = self.zeros[r * ng + g];
                row[c] = (self.qweight[r * self.in_features + c] as f32 - zero) * scale;
            }
        }
        out
    }

    /// Project an arbitrary float matrix onto *this* grid (fixed params),
    /// returning the dequantized projection. This is the paper's Eq. 7
    /// `B̃ = Q(B*)` — stage-2 keeps stage-1's (scale, zero).
    pub fn project(&self, w: &Tensor) -> Tensor {
        assert_eq!(w.rows(), self.out_features);
        assert_eq!(w.cols(), self.in_features);
        let ng = self.n_groups();
        let mut out = Tensor::zeros(&[self.out_features, self.in_features]);
        for r in 0..self.out_features {
            let src = w.row(r);
            let dst = out.row_mut(r);
            for c in 0..self.in_features {
                let g = c / self.grid.group_size;
                dst[c] = self
                    .grid
                    .project_val(src[c], self.scales[r * ng + g], self.zeros[r * ng + g]);
            }
        }
        out
    }

    /// Overwrite integer levels for columns `[c0, c1)` from a float block
    /// (projection with fixed params).
    pub fn set_cols_from_float(&mut self, c0: usize, block: &Tensor) {
        let bc = block.cols();
        assert_eq!(block.rows(), self.out_features);
        assert!(c0 + bc <= self.in_features);
        for r in 0..self.out_features {
            let src = block.row(r);
            for (j, &v) in src.iter().enumerate() {
                self.set_from_float(r, c0 + j, v);
            }
        }
    }

    /// Dequantized copy of columns `[c0, c1)`.
    pub fn deq_cols(&self, c0: usize, c1: usize) -> Tensor {
        let mut out = Tensor::zeros(&[self.out_features, c1 - c0]);
        for r in 0..self.out_features {
            let dst = out.row_mut(r);
            for c in c0..c1 {
                dst[c - c0] = self.deq_at(r, c);
            }
        }
        out
    }

    /// Pack integer levels into nibbles (4-bit) or keep bytes (else).
    /// Returns the deployment byte buffer handed to the PJRT artifacts.
    pub fn pack(&self) -> Vec<u8> {
        if self.grid.bits == 4 {
            let cols = self.in_features.div_ceil(2);
            let mut out = vec![0u8; self.out_features * cols];
            for r in 0..self.out_features {
                for c in 0..self.in_features {
                    let q = self.qweight[r * self.in_features + c] & 0x0F;
                    let byte = &mut out[r * cols + c / 2];
                    if c % 2 == 0 {
                        *byte |= q;
                    } else {
                        *byte |= q << 4;
                    }
                }
            }
            out
        } else {
            self.qweight.clone()
        }
    }

    /// Inverse of [`Self::pack`] for 4-bit buffers.
    pub fn unpack4(
        packed: &[u8],
        grid: QuantGrid,
        out_features: usize,
        in_features: usize,
        scales: Vec<f32>,
        zeros: Vec<f32>,
    ) -> Self {
        assert_eq!(grid.bits, 4);
        let cols = in_features.div_ceil(2);
        assert_eq!(packed.len(), out_features * cols);
        let mut qweight = vec![0u8; out_features * in_features];
        for r in 0..out_features {
            for c in 0..in_features {
                let byte = packed[r * cols + c / 2];
                qweight[r * in_features + c] = if c % 2 == 0 { byte & 0x0F } else { byte >> 4 };
            }
        }
        QuantizedLinear { grid, out_features, in_features, qweight, scales, zeros }
    }

    /// Deployment size in bytes (packed levels + params), the quantity the
    /// paper's "Mem (GB)" columns report per weight matrix.
    pub fn nbytes(&self) -> usize {
        let level_bytes = if self.grid.bits == 4 {
            self.out_features * self.in_features.div_ceil(2)
        } else {
            self.out_features * self.in_features
        };
        level_bytes + (self.scales.len() + self.zeros.len()) * 4
    }

    /// Worst-case absolute reconstruction error of this grid's step.
    pub fn max_step(&self) -> f32 {
        self.scales.iter().cloned().fold(0.0, f32::max) * 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{prop_assert, Runner};
    use crate::rng::Pcg64;

    #[test]
    fn rtn_roundtrip_error_bounded() {
        let mut rng = Pcg64::seeded(41);
        let w = Tensor::randn(&[8, 32], 1.0, &mut rng);
        let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 16));
        let deq = q.dequantize();
        // error bounded by half a step per group
        for r in 0..8 {
            for c in 0..32 {
                let step = q.scale_at(r, c);
                assert!(
                    (deq.at(r, c) - w.at(r, c)).abs() <= 0.5 * step + 1e-6,
                    "({r},{c})"
                );
            }
        }
    }

    #[test]
    fn eight_bit_is_finer_than_four_bit() {
        let mut rng = Pcg64::seeded(42);
        let w = Tensor::randn(&[4, 64], 1.0, &mut rng);
        let q4 = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 64));
        let q8 = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(8, 64));
        let e4 = q4.dequantize().sub(&w).frob_sq();
        let e8 = q8.dequantize().sub(&w).frob_sq();
        assert!(e8 < e4 / 4.0, "e8={e8} e4={e4}");
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Pcg64::seeded(43);
        for in_f in [6usize, 7, 16, 33] {
            let w = Tensor::randn(&[5, in_f], 1.0, &mut rng);
            let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 8));
            let packed = q.pack();
            let q2 = QuantizedLinear::unpack4(
                &packed,
                q.grid,
                q.out_features,
                q.in_features,
                q.scales.clone(),
                q.zeros.clone(),
            );
            assert_eq!(q.qweight, q2.qweight, "in_f={in_f}");
        }
    }

    #[test]
    fn zero_stays_exact() {
        // find_params includes 0 in the range, so an exact 0 weight must
        // round-trip to exactly 0 — GPTQ relies on this for pruned weights.
        let w = Tensor::from_vec(&[1, 4], vec![0.0, 0.5, 1.0, -0.25]);
        let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 4));
        assert_eq!(q.deq_at(0, 0), 0.0);
    }

    #[test]
    fn all_zero_group_safe() {
        let w = Tensor::zeros(&[2, 8]);
        let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 4));
        let deq = q.dequantize();
        assert_eq!(deq.data(), w.data());
    }

    #[test]
    fn projection_is_idempotent_property() {
        Runner::new("grid_projection_idempotent", 64).run(|g| {
            let rows = g.usize_in(1..6);
            let cols = g.usize_in(1..40);
            let gs = g.usize_in(1..cols.max(2));
            let data = g.matrix(rows, cols, 2.0);
            let w = Tensor::from_vec(&[rows, cols], data);
            let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, gs));
            let p1 = q.project(&w);
            let p2 = q.project(&p1);
            prop_assert(p1.max_abs_diff(&p2) < 1e-6, "Q(Q(w)) == Q(w)")
        });
    }

    #[test]
    fn quantize_levels_in_range_property() {
        Runner::new("grid_levels_in_range", 64).run(|g| {
            let bits = g.usize_in(2..9) as u32;
            let grid = QuantGrid::new(bits, 8);
            let vals = g.vec_f32(1..64, -100.0..100.0);
            let (scale, zero) = grid.find_params(&vals);
            for &v in &vals {
                let q = grid.quantize_val(v, scale, zero);
                prop_assert(
                    (q as f32) <= grid.maxq(),
                    "level within maxq",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn nbytes_reflects_4bit_compression() {
        let mut rng = Pcg64::seeded(44);
        let w = Tensor::randn(&[128, 256], 1.0, &mut rng);
        let q = QuantizedLinear::quantize_rtn(&w, QuantGrid::new(4, 128));
        let fp_bytes = 128 * 256 * 4;
        // 4-bit + params should be well under 30% of fp32.
        assert!((q.nbytes() as f64) < 0.30 * fp_bytes as f64);
    }
}
