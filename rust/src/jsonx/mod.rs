//! Minimal JSON parser/emitter.
//!
//! Used for the artifact manifest written by `python/compile/aot.py`, for
//! run configs, and for machine-readable bench reports. The offline vendor
//! set has no `serde` facade crate, so we implement the small subset we
//! need: full JSON parsing into a dynamic [`Json`] value, and emission with
//! stable key order (insertion order preserved) so diffs are reviewable.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Dynamic JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with sorted keys (BTreeMap keeps output deterministic).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ----- typed accessors (return None on type mismatch) -----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Builder: empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder: insert into an object (panics if not an object).
    pub fn with(mut self, key: &str, val: Json) -> Json {
        match &mut self {
            Json::Obj(o) => {
                o.insert(key.to_string(), val);
            }
            _ => panic!("with() on non-object"),
        }
        self
    }

    pub fn from_strs(items: &[&str]) -> Json {
        Json::Arr(items.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    pub fn from_usizes(items: &[usize]) -> Json {
        Json::Arr(items.iter().map(|&u| Json::Num(u as f64)).collect())
    }

    pub fn from_f64s(items: &[f64]) -> Json {
        Json::Arr(items.iter().map(|&u| Json::Num(u)).collect())
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    nl(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    nl(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn nl(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // BMP only; surrogate pairs unsupported (not needed
                            // for manifests/configs which are ASCII).
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.b.len() {
                            return Err(self.err("bad utf-8"));
                        }
                        let frag = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(frag);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.dump()).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn dump_parse_roundtrip_complex() {
        let v = Json::obj()
            .with("name", Json::Str("qmatmul_64x128x64".into()))
            .with("shape", Json::from_usizes(&[64, 128]))
            .with("ok", Json::Bool(true))
            .with("scale", Json::Num(0.125));
        let s = v.pretty();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2"] {
            assert!(Json::parse(src).is_err(), "{src}");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse("\"caf\u{e9} \\u0041\"").unwrap();
        assert_eq!(v.as_str(), Some("café A"));
        let s = Json::Str("tab\t\"q\"".into()).dump();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("tab\t\"q\""));
    }

    #[test]
    fn numbers_int_and_float_emission() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.25).dump(), "3.25");
    }
}
