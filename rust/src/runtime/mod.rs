//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** + `manifest.json`) and executes them on the CPU PJRT
//! client. This is the bridge between Layer 3 (this crate) and Layers 1–2
//! (JAX + Pallas, build-time only).
//!
//! The actual PJRT execution path needs the (not-on-crates.io) `xla`
//! bindings and is therefore gated behind the `pjrt` cargo feature, which
//! builds against the vendored `rust/vendor/xla` crate — a **stub** of the
//! real bindings with the same API surface, so `--features pjrt` compiles
//! and lints in CI (the `pjrt-stub` job) and fails loudly at `execute`
//! until the real bindings replace it. The default (featureless) build
//! ships a **stub [`Engine`]** with the same API: it still loads and
//! validates `manifest.json` (so `rpiq artifacts` can lint a bundle) but
//! `run` fails with a clear error. Everything that consumes artifacts
//! (`rust/tests/artifacts.rs`, the `micro` bench, the `e2e_assist`
//! example) already skips when `artifacts/` is absent, so neither stub
//! changes test outcomes on a clean checkout.
//!
//! With `--features pjrt`, wiring follows `/opt/xla-example/load_hlo`:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. Compiled
//! executables are cached per entry name; inputs/outputs are validated
//! against the manifest so a stale `artifacts/` directory fails loudly
//! instead of mis-executing.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

pub mod lm_args;

use crate::jsonx::Json;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

/// Dtypes the artifact boundary supports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" | "float32" => Ok(Dtype::F32),
            "i32" | "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}' in manifest"),
        }
    }
}

/// One artifact entry as declared by the manifest.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<(Vec<usize>, Dtype)>,
    pub outputs: Vec<(Vec<usize>, Dtype)>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    pub entries: HashMap<String, Entry>,
}

impl ArtifactRegistry {
    /// Load and validate the manifest.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {} (run `make artifacts`)", manifest_path.display()))?;
        let json = Json::parse(&text).context("parse manifest.json")?;
        let entries_json = json
            .get("entries")
            .and_then(|e| e.as_obj())
            .context("manifest missing 'entries'")?;
        let mut entries = HashMap::new();
        for (name, spec) in entries_json {
            let parse_sig = |key: &str| -> Result<Vec<(Vec<usize>, Dtype)>> {
                spec.get(key)
                    .and_then(|v| v.as_arr())
                    .with_context(|| format!("entry {name} missing '{key}'"))?
                    .iter()
                    .map(|io| {
                        let shape = io
                            .get("shape")
                            .and_then(|s| s.as_arr())
                            .context("shape")?
                            .iter()
                            .map(|d| d.as_usize().context("dim"))
                            .collect::<Result<Vec<_>>>()?;
                        let dtype =
                            Dtype::parse(io.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32"))?;
                        Ok((shape, dtype))
                    })
                    .collect()
            };
            let file = dir.join(
                spec.get("file")
                    .and_then(|f| f.as_str())
                    .with_context(|| format!("entry {name} missing 'file'"))?,
            );
            if !file.exists() {
                bail!("artifact file {} missing (re-run `make artifacts`)", file.display());
            }
            entries.insert(
                name.clone(),
                Entry { name: name.clone(), file, inputs: parse_sig("inputs")?, outputs: parse_sig("outputs")? },
            );
        }
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), entries })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .with_context(|| format!("no artifact entry '{name}'; have: {:?}", {
                let mut k: Vec<&String> = self.entries.keys().collect();
                k.sort();
                k
            }))
    }
}

/// A runtime argument.
pub enum Arg {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>),
}

impl Arg {
    fn shape(&self) -> &[usize] {
        match self {
            Arg::F32(t) => t.shape(),
            Arg::I32(_, s) => s,
        }
    }

    fn dtype(&self) -> Dtype {
        match self {
            Arg::F32(_) => Dtype::F32,
            Arg::I32(..) => Dtype::I32,
        }
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        Ok(match self {
            Arg::F32(t) => xla::Literal::vec1(t.data()).reshape(&dims)?,
            Arg::I32(v, _) => xla::Literal::vec1(v).reshape(&dims)?,
        })
    }
}

/// Validate a call signature against a manifest entry (shared by the real
/// and stub engines so misuse fails identically in both builds).
fn check_inputs(entry: &Entry, args: &[Arg]) -> Result<()> {
    if args.len() != entry.inputs.len() {
        bail!(
            "'{}' expects {} inputs, got {}",
            entry.name,
            entry.inputs.len(),
            args.len()
        );
    }
    for (i, (arg, (shape, dtype))) in args.iter().zip(entry.inputs.iter()).enumerate() {
        if arg.shape() != shape.as_slice() || arg.dtype() != *dtype {
            bail!(
                "'{}' input {i}: expected {:?} {:?}, got {:?} {:?}",
                entry.name,
                shape,
                dtype,
                arg.shape(),
                arg.dtype()
            );
        }
    }
    Ok(())
}

/// Stub engine used when the `pjrt` feature is off: manifest loading and
/// validation work, execution does not.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    pub registry: ArtifactRegistry,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Create an engine over `artifacts/` (validates the manifest).
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let registry = ArtifactRegistry::load(artifacts_dir)?;
        Ok(Engine { registry })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        "stub (build with --features pjrt to execute artifacts)".to_string()
    }

    /// Validates the call against the manifest, then fails: execution
    /// requires the `pjrt` feature.
    pub fn run(&self, name: &str, args: &[Arg]) -> Result<Vec<Tensor>> {
        let entry = self.registry.entry(name)?;
        check_inputs(entry, args)?;
        bail!(
            "cannot execute artifact '{name}': this build has no PJRT backend \
             (rebuild with `--features pjrt` and a vendored `xla` crate)"
        )
    }
}

/// Compiled-executable cache over a PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct Engine {
    pub registry: ArtifactRegistry,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create an engine over `artifacts/`.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let registry = ArtifactRegistry::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine { registry, client, cache: Mutex::new(HashMap::new()) })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an entry.
    fn compiled(&self, name: &str) -> Result<()> {
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(name) {
            return Ok(());
        }
        let entry = self.registry.entry(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text for '{name}'"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile '{name}'"))?;
        cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry. Inputs are validated against the manifest; the
    /// (tupled) outputs come back as f32 tensors.
    pub fn run(&self, name: &str, args: &[Arg]) -> Result<Vec<Tensor>> {
        let entry = self.registry.entry(name)?.clone();
        check_inputs(&entry, args)?;
        self.compiled(name)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(name).unwrap();
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "'{name}' returned {} outputs, manifest says {}",
                parts.len(),
                entry.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, (shape, _)) in parts.into_iter().zip(entry.outputs.iter()) {
            let v: Vec<f32> = lit.to_vec()?;
            out.push(Tensor::from_vec(shape, v));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_rejects_missing_dir() {
        let err = ArtifactRegistry::load(Path::new("/nonexistent/artifacts")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn registry_parses_manifest_and_validates_files() {
        let dir = std::env::temp_dir().join("rpiq_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("f.hlo.txt"), "HloModule fake").unwrap();
        let manifest = r#"{
            "entries": {
                "f": {
                    "file": "f.hlo.txt",
                    "inputs": [{"shape": [2, 3], "dtype": "f32"}],
                    "outputs": [{"shape": [2], "dtype": "f32"}]
                }
            }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let reg = ArtifactRegistry::load(&dir).unwrap();
        let e = reg.entry("f").unwrap();
        assert_eq!(e.inputs, vec![(vec![2, 3], Dtype::F32)]);
        assert!(reg.entry("missing").is_err());
        // missing file fails load
        let manifest2 = r#"{"entries": {"g": {"file": "nope.hlo.txt", "inputs": [], "outputs": []}}}"#;
        std::fs::write(dir.join("manifest.json"), manifest2).unwrap();
        assert!(ArtifactRegistry::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn arg_shapes_and_dtypes() {
        let a = Arg::F32(Tensor::zeros(&[2, 2]));
        assert_eq!(a.shape(), &[2, 2]);
        assert_eq!(a.dtype(), Dtype::F32);
        let b = Arg::I32(vec![1, 2, 3], vec![3]);
        assert_eq!(b.dtype(), Dtype::I32);
        #[cfg(feature = "pjrt")]
        assert!(b.to_literal().is_ok());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_validates_but_refuses_to_run() {
        // unique per process: concurrent `cargo test` runs share TMPDIR
        let dir = std::env::temp_dir().join(format!("rpiq_rt_stub_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("f.hlo.txt"), "HloModule fake").unwrap();
        let manifest = r#"{
            "entries": {
                "f": {
                    "file": "f.hlo.txt",
                    "inputs": [{"shape": [2, 2], "dtype": "f32"}],
                    "outputs": [{"shape": [2], "dtype": "f32"}]
                }
            }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let eng = Engine::new(&dir).unwrap();
        assert!(eng.platform().contains("stub"));
        // wrong shape caught by the shared validator
        let bad = eng.run("f", &[Arg::F32(Tensor::zeros(&[3, 3]))]).unwrap_err();
        assert!(bad.to_string().contains("expected"));
        // right shape fails with the feature hint, not a shape error
        let err = eng.run("f", &[Arg::F32(Tensor::zeros(&[2, 2]))]).unwrap_err();
        assert!(err.to_string().contains("pjrt"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
