//! Argument marshalling for the `lm_logits_*` / `lm_qlogits_*` artifacts.
//!
//! The flat parameter ORDER here mirrors `python/compile/model.py`'s
//! `param_order` / `qparam_order` exactly — that ordering is the contract
//! between Layer 2 and Layer 3, and the integration tests verify numerics
//! end to end through it.

use super::Arg;
use crate::model::weights::LmWeights;
use crate::model::QuantizedLm;
use crate::tensor::Tensor;

/// Token argument (i32, `[S]`).
pub fn tokens_arg(tokens: &[u32]) -> Arg {
    Arg::I32(
        tokens.iter().map(|&t| t as i32).collect(),
        vec![tokens.len()],
    )
}

/// fp-variant arguments: tokens followed by `param_order`.
pub fn lm_fp_args(w: &LmWeights, tokens: &[u32]) -> Vec<Arg> {
    let mut args = vec![tokens_arg(tokens)];
    args.push(Arg::F32(w.tok_emb.clone()));
    args.push(Arg::F32(w.pos_emb.clone()));
    for l in &w.layers {
        args.push(Arg::F32(l.ln1_g.clone()));
        args.push(Arg::F32(l.ln1_b.clone()));
        args.push(Arg::F32(l.wq.clone()));
        args.push(Arg::F32(l.wk.clone()));
        args.push(Arg::F32(l.wv.clone()));
        args.push(Arg::F32(l.wo.clone()));
        args.push(Arg::F32(l.ln2_g.clone()));
        args.push(Arg::F32(l.ln2_b.clone()));
        args.push(Arg::F32(l.w_up.clone()));
        args.push(Arg::F32(l.w_down.clone()));
    }
    args.push(Arg::F32(w.lnf_g.clone()));
    args.push(Arg::F32(w.lnf_b.clone()));
    if let Some(h) = &w.head {
        args.push(Arg::F32(h.clone()));
    }
    args
}

fn qlinear_args(q: &crate::quant::QuantizedLinear, args: &mut Vec<Arg>) {
    // The artifact entry takes byte-per-level i32 planes; unpack the
    // resident nibble buffer transiently at marshalling time.
    let levels: Vec<i32> = q.levels().iter().map(|&b| b as i32).collect();
    args.push(Arg::I32(levels, vec![q.out_features, q.in_features]));
    let ng = q.n_groups();
    args.push(Arg::F32(Tensor::from_vec(
        &[q.out_features, ng],
        q.scales.clone(),
    )));
    args.push(Arg::F32(Tensor::from_vec(
        &[q.out_features, ng],
        q.zeros.clone(),
    )));
}

/// quant-variant arguments: tokens followed by `qparam_order`.
pub fn lm_q_args(qlm: &QuantizedLm, tokens: &[u32]) -> Vec<Arg> {
    let s = &qlm.skeleton;
    let get = |name: String| {
        qlm.qlinears
            .get(&name)
            .unwrap_or_else(|| panic!("quantized layer {name} missing at marshalling time"))
    };
    let mut args = vec![tokens_arg(tokens)];
    args.push(Arg::F32(s.tok_emb.clone()));
    args.push(Arg::F32(s.pos_emb.clone()));
    for (i, l) in s.layers.iter().enumerate() {
        args.push(Arg::F32(l.ln1_g.clone()));
        args.push(Arg::F32(l.ln1_b.clone()));
        for field in ["attn.q", "attn.k", "attn.v", "attn.out"] {
            qlinear_args(get(format!("lm.layer{i}.{field}")), &mut args);
        }
        args.push(Arg::F32(l.ln2_g.clone()));
        args.push(Arg::F32(l.ln2_b.clone()));
        qlinear_args(get(format!("lm.layer{i}.mlp.up")), &mut args);
        qlinear_args(get(format!("lm.layer{i}.mlp.down")), &mut args);
    }
    args.push(Arg::F32(s.lnf_g.clone()));
    args.push(Arg::F32(s.lnf_b.clone()));
    if !s.config.tied_head {
        qlinear_args(get("lm.head".to_string()), &mut args);
    }
    args
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::quant::{QuantGrid, QuantizedLinear};
    use crate::rng::Pcg64;
    use std::collections::HashMap;

    #[test]
    fn fp_arg_count_matches_param_order() {
        // per-layer 10 params + tok/pos + lnf 2 (+ head if untied), +1 tokens
        let mut cfg = ModelConfig::test_tiny(32);
        cfg.tied_head = false;
        let mut rng = Pcg64::seeded(1101);
        let w = LmWeights::init(&cfg, &mut rng);
        let args = lm_fp_args(&w, &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(args.len(), 1 + 2 + cfg.n_layers * 10 + 2 + 1);
    }

    #[test]
    fn q_arg_count_triples_linears() {
        let cfg = ModelConfig::test_tiny(32); // tied head
        let mut rng = Pcg64::seeded(1102);
        let w = LmWeights::init(&cfg, &mut rng);
        let mut ql = HashMap::new();
        for (name, t) in w.linears() {
            ql.insert(name, QuantizedLinear::quantize_rtn(t, QuantGrid::new(4, 8)));
        }
        let qlm = QuantizedLm::from_weights(w, ql).expect("complete layer set");
        let args = lm_q_args(&qlm, &[0; 8]);
        // 1 tokens + 2 emb + per layer (2 ln + 6 linears×3 + 2 ln) + 2 lnf
        assert_eq!(args.len(), 1 + 2 + cfg.n_layers * (4 + 18) + 2);
    }
}
