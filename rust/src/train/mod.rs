//! Training substrate: manual-gradient backprop through the transformer
//! plus an Adam optimizer.
//!
//! Why this exists: the paper quantizes *pretrained* checkpoints. Offline,
//! the only way to obtain a checkpoint whose PPL/accuracy degradation
//! under quantization is meaningful is to train one — so the repo trains
//! its subject models from scratch on the synthetic corpora
//! (`rpiq pretrain`). The backward pass composes the finite-difference-
//! verified primitives in [`crate::model::ops`]; an end-to-end gradient
//! check lives in this module's tests.

#![forbid(unsafe_code)] // `exec` is the repo's only unsafe island (see rust/DESIGN.md)

use crate::model::forward::{lm_forward_training, shift_targets, FwdRecord};
use crate::model::ops::*;
use crate::model::weights::LmWeights;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// Gradients, keyed like [`LmWeights::named_tensors`].
pub type Grads = HashMap<String, Tensor>;

/// Backward pass: given the forward record and `dlogits`, produce all
/// parameter gradients.
pub fn lm_backward(w: &LmWeights, rec: &FwdRecord, dlogits: &Tensor) -> Grads {
    let cfg = &w.config;
    let mut grads: Grads = HashMap::new();
    let (batch, seq) = (rec.batch, rec.seq);

    // head: logits = lnf_out · Hᵀ
    let (mut dx, dhead) = linear_bwd(&rec.lnf_out, w.head_matrix(), dlogits);
    let head_key = if w.head.is_some() { "lm.head" } else { "tok_emb" };
    grads.insert(head_key.to_string(), dhead);

    // final layernorm
    let (dxf, dg, db) = layernorm_bwd(&rec.x_final, &w.lnf_g, &rec.lnf_mean, &rec.lnf_rstd, &dx);
    grads.insert("lnf.g".into(), dg);
    grads.insert("lnf.b".into(), db);
    dx = dxf;

    // layers in reverse
    for (li, (l, r)) in w.layers.iter().zip(rec.layers.iter()).enumerate().rev() {
        let p = |s: &str| format!("lm.layer{li}.{s}");
        // --- MLP branch: x = x_mid + down(act(up(ln2(x_mid)))) ---
        // residual: dx flows both into the branch and straight through.
        let (dup_act, dw_down) = linear_bwd(&r.up_act, &l.w_down, &dx);
        grads.insert(p("mlp.down"), dw_down);
        let dup_pre = act_bwd(&r.up_pre, &dup_act, cfg.activation);
        let (dln2, dw_up) = linear_bwd(&r.ln2_out, &l.w_up, &dup_pre);
        grads.insert(p("mlp.up"), dw_up);
        let (dx_mid_branch, dg2, db2) =
            layernorm_bwd(&r.x_mid, &l.ln2_g, &r.ln2_mean, &r.ln2_rstd, &dln2);
        grads.insert(p("ln2.g"), dg2);
        grads.insert(p("ln2.b"), db2);
        dx.add_assign(&dx_mid_branch);

        // --- attention branch: x_mid = x_in + wo(attn(q,k,v)) ---
        let (dctx, dw_o) = linear_bwd(&r.ctx, &l.wo, &dx);
        grads.insert(p("attn.out"), dw_o);
        let (dq, dk, dv) =
            attention_bwd(&r.q, &r.k, &r.v, &r.probs, &dctx, batch, seq, cfg.n_heads);
        let (dln1_q, dw_q) = linear_bwd(&r.ln1_out, &l.wq, &dq);
        let (dln1_k, dw_k) = linear_bwd(&r.ln1_out, &l.wk, &dk);
        let (dln1_v, dw_v) = linear_bwd(&r.ln1_out, &l.wv, &dv);
        grads.insert(p("attn.q"), dw_q);
        grads.insert(p("attn.k"), dw_k);
        grads.insert(p("attn.v"), dw_v);
        let mut dln1 = dln1_q;
        dln1.add_assign(&dln1_k);
        dln1.add_assign(&dln1_v);
        let (dx_in_branch, dg1, db1) =
            layernorm_bwd(&r.x_in, &l.ln1_g, &r.ln1_mean, &r.ln1_rstd, &dln1);
        grads.insert(p("ln1.g"), dg1);
        grads.insert(p("ln1.b"), db1);
        dx.add_assign(&dx_in_branch);
    }

    // embeddings: x0[i] = tok_emb[tokens[i]] + pos_emb[i % seq]
    // handled by the caller via `accumulate_embedding_grads` (needs tokens).
    grads.insert("__demb".into(), dx);
    grads
}

/// Scatter the embedding gradient into tok_emb / pos_emb grads.
pub fn accumulate_embedding_grads(
    w: &LmWeights,
    grads: &mut Grads,
    tokens: &[u32],
    batch: usize,
    seq: usize,
) {
    let demb = grads.remove("__demb").expect("lm_backward ran");
    let d = w.config.d_model;
    let mut dtok = grads
        .remove("tok_emb")
        .unwrap_or_else(|| Tensor::zeros(&[w.config.vocab, d]));
    let mut dpos = Tensor::zeros(&[w.config.seq_len, d]);
    for i in 0..batch * seq {
        let t = tokens[i] as usize;
        let row = demb.row(i);
        let trow = dtok.row_mut(t);
        for j in 0..d {
            trow[j] += row[j];
        }
        let prow = dpos.row_mut(i % seq);
        for j in 0..d {
            prow[j] += row[j];
        }
    }
    grads.insert("tok_emb".into(), dtok);
    grads.insert("pos_emb".into(), dpos);
}

/// One full loss + gradient evaluation.
pub fn loss_and_grads(
    w: &LmWeights,
    tokens: &[u32],
    batch: usize,
    seq: usize,
) -> (f64, Grads) {
    let rec = lm_forward_training(w, tokens, batch, seq);
    let targets = shift_targets(tokens, batch, seq);
    let (loss, dlogits) = cross_entropy(&rec.logits, &targets, -100);
    let mut grads = lm_backward(w, &rec, &dlogits);
    accumulate_embedding_grads(w, &mut grads, tokens, batch, seq);
    (loss, grads)
}

/// Adam optimizer with decoupled weight decay and linear warmup.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub warmup_steps: usize,
    pub grad_clip: f32,
    /// Cosine decay horizon (steps); `None` = constant lr after warmup.
    cosine_total: Option<usize>,
    step: usize,
    m: HashMap<String, Vec<f32>>,
    v: HashMap<String, Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.01,
            warmup_steps: 20,
            grad_clip: 1.0,
            cosine_total: None,
            step: 0,
            m: HashMap::new(),
            v: HashMap::new(),
        }
    }

    /// Set a cosine-decay horizon: after warmup, lr decays to 10% of peak
    /// by `total_steps`.
    pub fn with_cosine(mut self, total_steps: usize) -> Self {
        self.cosine_total = Some(total_steps);
        self
    }

    /// Apply one update.
    pub fn update(&mut self, w: &mut LmWeights, grads: &Grads) {
        self.step += 1;
        let warm = ((self.step as f32) / (self.warmup_steps.max(1) as f32)).min(1.0);
        let decay = match self.cosine_total {
            Some(total) if total > 0 => {
                let t = (self.step as f32 / total as f32).min(1.0);
                0.1 + 0.45 * (1.0 + (std::f32::consts::PI * t).cos())
            }
            _ => 1.0,
        };
        let lr = self.lr * warm * decay;
        // global grad-norm clip
        let mut norm_sq = 0.0f64;
        for g in grads.values() {
            norm_sq += g.frob_sq();
        }
        let norm = norm_sq.sqrt() as f32;
        let clip_scale = if norm > self.grad_clip { self.grad_clip / norm } else { 1.0 };

        let bc1 = 1.0 - self.beta1.powi(self.step as i32);
        let bc2 = 1.0 - self.beta2.powi(self.step as i32);
        let names: Vec<String> = grads.keys().cloned().collect();
        for name in names {
            let g = &grads[&name];
            let p = match w.named_tensor_mut(&name) {
                Some(p) => p,
                None => continue,
            };
            let n = p.len();
            let m = self.m.entry(name.clone()).or_insert_with(|| vec![0.0; n]);
            let v = self.v.entry(name.clone()).or_insert_with(|| vec![0.0; n]);
            let decay = if name.contains("ln") || name.contains(".b") {
                0.0 // no decay on norms/biases
            } else {
                self.weight_decay
            };
            let pd = p.data_mut();
            let gd = g.data();
            for i in 0..n {
                let gi = gd[i] * clip_scale;
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gi;
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gi * gi;
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                pd[i] -= lr * (mhat / (vhat.sqrt() + self.eps) + decay * pd[i]);
            }
        }
    }

    pub fn steps_taken(&self) -> usize {
        self.step
    }
}

/// Training loop driver. Batches are drawn by the provided sampler
/// (`data::corpus` supplies them); returns the loss curve.
pub struct Trainer {
    pub adam: Adam,
    pub batch: usize,
    pub log_every: usize,
}

impl Trainer {
    pub fn new(lr: f32, batch: usize) -> Self {
        Trainer { adam: Adam::new(lr), batch, log_every: 20 }
    }

    /// Run `steps` optimizer steps. `sample` must fill `batch·seq` token
    /// ids per call. Returns `(step, loss)` pairs.
    pub fn train<F>(
        &mut self,
        w: &mut LmWeights,
        steps: usize,
        mut sample: F,
        mut log: impl FnMut(usize, f64),
    ) -> Vec<(usize, f64)>
    where
        F: FnMut() -> Vec<u32>,
    {
        let seq = w.config.seq_len;
        let mut curve = Vec::new();
        for step in 0..steps {
            let tokens = sample();
            assert_eq!(tokens.len(), self.batch * seq);
            let (loss, grads) = loss_and_grads(w, &tokens, self.batch, seq);
            self.adam.update(w, &grads);
            curve.push((step, loss));
            if step % self.log_every == 0 || step + 1 == steps {
                log(step, loss);
            }
        }
        curve
    }
}

/// Helper used by trainer tests and the e2e example: verify the loss went
/// down by a meaningful factor.
pub fn loss_improved(curve: &[(usize, f64)], min_ratio: f64) -> bool {
    if curve.len() < 4 {
        return false;
    }
    let head: f64 =
        curve.iter().take(3).map(|&(_, l)| l).sum::<f64>() / 3.0;
    let tail: f64 =
        curve.iter().rev().take(3).map(|&(_, l)| l).sum::<f64>() / 3.0;
    tail < head * min_ratio
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::rng::Pcg64;

    #[test]
    fn end_to_end_gradcheck() {
        // Finite-difference check of the FULL model gradient wrt a sample
        // of parameters in every tensor class.
        let cfg = ModelConfig::test_tiny(24);
        let mut rng = Pcg64::seeded(501);
        let w = LmWeights::init(&cfg, &mut rng);
        let (batch, seq) = (2usize, 6usize);
        let tokens: Vec<u32> = (0..batch * seq).map(|_| rng.next_below(24) as u32).collect();
        let tokens2 = tokens.clone();
        let loss_of = |wp: &LmWeights| {
            let rec = lm_forward_training(wp, &tokens2, batch, seq);
            let targets = shift_targets(&tokens2, batch, seq);
            cross_entropy(&rec.logits, &targets, -100).0
        };
        let (_, grads) = loss_and_grads(&w, &tokens, batch, seq);
        let check = [
            ("lm.layer0.attn.q", 5usize),
            ("lm.layer1.attn.out", 17),
            ("lm.layer0.mlp.up", 33),
            ("lm.layer1.mlp.down", 2),
            ("lm.layer0.ln1.g", 3),
            ("lm.layer1.ln2.b", 7),
            ("lnf.g", 0),
            ("tok_emb", 40),
            ("pos_emb", 11),
        ];
        for (name, idx) in check {
            let eps = 1e-2f32;
            let mut wp = w.clone();
            wp.named_tensor_mut(name).unwrap().data_mut()[idx] += eps;
            let lp = loss_of(&wp);
            let mut wm = w.clone();
            wm.named_tensor_mut(name).unwrap().data_mut()[idx] -= eps;
            let lm = loss_of(&wm);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = grads[name].data()[idx] as f64;
            assert!(
                (fd - an).abs() < 5e-3 + 0.05 * fd.abs().max(an.abs()),
                "{name}[{idx}]: fd={fd:.6} analytic={an:.6}"
            );
        }
    }

    #[test]
    fn short_training_reduces_loss() {
        let cfg = ModelConfig::test_tiny(16);
        let mut rng = Pcg64::seeded(502);
        let mut w = LmWeights::init(&cfg, &mut rng);
        // Learnable synthetic pattern: strictly cyclic token sequences.
        let seq = cfg.seq_len;
        let batch = 4;
        let mut sampler_rng = Pcg64::seeded(503);
        let mut trainer = Trainer::new(3e-3, batch);
        let curve = trainer.train(
            &mut w,
            60,
            || {
                let mut t = Vec::with_capacity(batch * seq);
                for _ in 0..batch {
                    let start = sampler_rng.next_below(16) as u32;
                    for s in 0..seq {
                        t.push((start + s as u32) % 16);
                    }
                }
                t
            },
            |_, _| {},
        );
        assert!(
            loss_improved(&curve, 0.5),
            "loss should halve on a cyclic pattern: first={:?} last={:?}",
            &curve[..3],
            &curve[curve.len() - 3..]
        );
    }

    #[test]
    fn adam_skips_unknown_and_clips() {
        let cfg = ModelConfig::test_tiny(16);
        let mut rng = Pcg64::seeded(504);
        let mut w = LmWeights::init(&cfg, &mut rng);
        let before = w.tok_emb.clone();
        let mut grads: Grads = HashMap::new();
        grads.insert("not_a_tensor".into(), Tensor::zeros(&[1]));
        let mut huge = Tensor::zeros(&[cfg.vocab, cfg.d_model]);
        huge.data_mut().fill(1e6);
        grads.insert("tok_emb".into(), huge);
        let mut adam = Adam::new(1e-3);
        adam.update(&mut w, &grads);
        // clipped: update magnitude stays bounded (no explosion)
        let delta = w.tok_emb.max_abs_diff(&before);
        assert!(delta < 1.0, "delta={delta}");
        assert!(delta > 0.0);
    }
}
