//! Blocked matrix multiplication kernels.
//!
//! Four layouts are provided because the quantization engines and the
//! trainer each have a natural one:
//!
//! * [`matmul`]        — `C = A·B`        (A: m×k, B: k×n)
//! * [`matmul_a_bt`]   — `C = A·Bᵀ`       (A: m×k, B: n×k) — linear layers,
//!   where weights are stored `[out, in]` like the paper's `W ∈ R^{Cout×Cin}`.
//! * [`matmul_at_b`]   — `C = Aᵀ·B`       (A: k×m, B: k×n) — Hessian
//!   accumulation `XᵀX` and weight gradients.
//!
//! The kernels are cache-blocked over k and use the unrolled [`dot`] /
//! [`axpy_slice`] primitives so LLVM emits SIMD; on the single-core CI
//! machine this reaches a few GFLOP/s which is the practical roofline
//! without hand-written intrinsics (EXPERIMENTS.md §Perf records the
//! measured numbers and iteration log).

use super::{axpy_slice, dot, Tensor};

/// `C = A·Bᵀ` where A is m×k and B is n×k. This is the hot layout: every
/// linear layer forward is `y = x·Wᵀ` with W stored `[out, in]`, and both
/// operands walk rows contiguously.
pub fn matmul_a_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_a_bt: inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_a_bt_into(a, b, &mut c);
    c
}

/// In-place variant of [`matmul_a_bt`] writing into a preallocated output.
pub fn matmul_a_bt_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.rows();
    assert_eq!(b.cols(), k);
    assert_eq!(c.rows(), m);
    assert_eq!(c.cols(), n);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut cd[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] = dot(arow, &bd[j * k..(j + 1) * k]);
        }
    }
}

/// `C = A·B` with A m×k, B k×n. Implemented as rank-1 style row updates
/// (`c_row += a_ik * b_row_k`) so B is traversed contiguously.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul: inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_into(a, b, &mut c);
    c
}

/// In-place variant of [`matmul`]; `c` is overwritten.
pub fn matmul_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k);
    assert_eq!(c.rows(), m);
    assert_eq!(c.cols(), n);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    cd.fill(0.0);
    for i in 0..m {
        let crow = &mut cd[i * n..(i + 1) * n];
        let arow = &ad[i * k..(i + 1) * k];
        for (p, &aip) in arow.iter().enumerate() {
            if aip != 0.0 {
                axpy_slice(crow, aip, &bd[p * n..(p + 1) * n]);
            }
        }
    }
}

/// `C = Aᵀ·B` with A k×m, B k×n (result m×n). Used for `XᵀX` Hessian
/// accumulation and for weight gradients `∂W = ∂yᵀ·x` in the trainer.
pub fn matmul_at_b(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k, "matmul_at_b: inner dims");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_at_b_into(a, b, &mut c);
    c
}

/// In-place variant of [`matmul_at_b`]: `c += Aᵀ·B` (accumulating — callers
/// like the Hessian builder rely on accumulation).
pub fn matmul_at_b_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (k, m) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k);
    assert_eq!(c.rows(), m);
    assert_eq!(c.cols(), n);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for p in 0..k {
        let arow = &ad[p * m..(p + 1) * m];
        let brow = &bd[p * n..(p + 1) * n];
        for (i, &aip) in arow.iter().enumerate() {
            if aip != 0.0 {
                axpy_slice(&mut cd[i * n..(i + 1) * n], aip, brow);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.rows(), a.cols());
        let n = b.cols();
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for p in 0..k {
                    s += (a.at(i, p) as f64) * (b.at(p, j) as f64);
                }
                c.set(i, j, s as f32);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::seeded(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 4), (8, 16, 8), (13, 31, 17)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul(&a, &b);
            let cn = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&cn) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn a_bt_matches_naive() {
        let mut rng = Pcg64::seeded(22);
        for (m, k, n) in [(2, 3, 2), (7, 9, 5), (16, 32, 16)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[n, k], 1.0, &mut rng);
            let c = matmul_a_bt(&a, &b);
            let cn = naive_matmul(&a, &b.transpose());
            assert!(c.max_abs_diff(&cn) < 1e-3);
        }
    }

    #[test]
    fn at_b_matches_naive() {
        let mut rng = Pcg64::seeded(23);
        for (k, m, n) in [(4, 3, 5), (9, 9, 9), (32, 8, 24)] {
            let a = Tensor::randn(&[k, m], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul_at_b(&a, &b);
            let cn = naive_matmul(&a.transpose(), &b);
            assert!(c.max_abs_diff(&cn) < 1e-3);
        }
    }

    #[test]
    fn at_b_into_accumulates() {
        let mut rng = Pcg64::seeded(24);
        let a = Tensor::randn(&[6, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[6, 4], 1.0, &mut rng);
        let mut acc = Tensor::zeros(&[4, 4]);
        matmul_at_b_into(&a, &b, &mut acc);
        matmul_at_b_into(&a, &b, &mut acc);
        let once = matmul_at_b(&a, &b);
        let mut twice = once.clone();
        twice.add_assign(&once);
        assert!(acc.max_abs_diff(&twice) < 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seeded(25);
        let a = Tensor::randn(&[5, 5], 1.0, &mut rng);
        let c = matmul(&a, &Tensor::eye(5));
        assert!(c.max_abs_diff(&a) < 1e-6);
    }
}
